#!/usr/bin/env python3
"""Promote measured bench values from a CI artifact into a committed
repo-root baseline.

The repo-root baselines (BENCH_overlap.json, BENCH_serving.json) gate CI
via scripts/check_bench_overlap.py. Where no local toolchain run exists,
tracked keys hold conservative contract bounds rather than measurements;
this tool replaces them with real measured values once a trustworthy run
is available — download the `bench-baselines` artifact from a green CI
run of this commit, then:

    python3 scripts/promote_bench_baseline.py BENCH_overlap.json fresh/BENCH_overlap.json
    python3 scripts/promote_bench_baseline.py BENCH_serving.json fresh/BENCH_serving.json

For every key that is TRACKED in the baseline (non-null and matched by a
gate rule), the measured value is written back with gate-aware headroom
so normal runner jitter cannot trip the diff:
  * ``*_overlap_fraction``  -> 0.8 * measured (gate fails < 0.9 * base)
  * ``*_step_ratio``        -> 1.2 * measured (gate fails > 1.1 * base)
  * ``*_p99_tpot_ms``       -> 2.0 * measured (generous guard-rail)
  * ``*_recovery_ms``       -> 10.0 * measured (absolute bound; recovery
                               latency varies widely across runners)
  * ``*_stall_ns``          -> 10.0 * measured (absolute bound on the
                               step-path checkpoint handoff; a blocking
                               writer overshoots any sane multiple)
  * ``*allocs*``            -> exact measured value (deterministic
                               schedules; any increase is a real bug)
Null (informational) keys are never touched. The file is rewritten in
place with the same key order; review the diff before committing.
"""

import json
import sys


def is_num(v):
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def promoted(key, bval, mval):
    if not (is_num(bval) and is_num(mval)):
        return None
    if key.endswith("_overlap_fraction"):
        return round(0.8 * mval, 6)
    if key.endswith("_step_ratio"):
        return round(1.2 * mval, 6)
    if key.endswith("_p99_tpot_ms"):
        return round(2.0 * mval, 4)
    if key.endswith(("_recovery_ms", "_stall_ns")):
        return round(10.0 * mval, 1)
    if "allocs" in key:
        return mval
    return None


def main():
    if len(sys.argv) != 3:
        print(__doc__)
        return 2
    baseline_path, fresh_path = sys.argv[1], sys.argv[2]
    with open(baseline_path) as f:
        base = json.load(f)
    with open(fresh_path) as f:
        fresh = json.load(f)

    changed = 0
    for key, bval in base.items():
        p = promoted(key, bval, fresh.get(key))
        if p is not None and p != bval:
            print(f"  {key}: {bval} -> {p} (measured {fresh[key]})")
            base[key] = p
            changed += 1

    if not changed:
        print("nothing to promote (no tracked keys changed)")
        return 0
    with open(baseline_path, "w") as f:
        json.dump(base, f, indent=2)
        f.write("\n")
    print(f"rewrote {baseline_path} with {changed} promoted value(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
