#!/usr/bin/env python3
"""Diff a fresh bench JSON against its committed repo-root baseline and
fail on perf regressions. General over bench files: CI runs it once per
(baseline, fresh) pair — BENCH_overlap.json for the training hot path,
BENCH_serving.json for the serving path.

Rules, keyed by name pattern (see each baseline's "note" field):
  * keys ending in ``_overlap_fraction`` tracked in the baseline fail on a
    relative regression of more than 10% (fresh < 0.9 * baseline);
  * keys ending in ``_step_ratio`` tracked in the baseline fail on a
    relative regression of more than 10% (fresh > 1.1 * baseline; lower is
    better — e.g. the hop scheduler's scheduled/convoy step-time ratio,
    where a baseline of 1.0 means "scheduled must never cost more than
    ~10% over the FIFO convoy");
  * keys ending in ``_p99_tpot_ms`` tracked in the baseline fail when the
    fresh p99 time-per-output-token exceeds the baseline guard-rail by
    more than 10% (fresh > 1.1 * baseline; lower is better);
  * keys containing ``allocs`` tracked in the baseline fail on ANY
    increase (the steady-state hot paths are allocation-free by
    construction, and the serving KV page schedule is deterministic; the
    baseline values are explicit headroom);
  * keys ending in ``_recovery_ms`` or ``_stall_ns`` tracked in the
    baseline are ABSOLUTE bounds, not regression ratios: the fresh value
    must not exceed the baseline (elastic recovery must stay bounded,
    and the async checkpointer's step-path submit stall must stay
    off-disk-scale — a blocking writer blows the ns bound by orders of
    magnitude, so no relative tolerance is needed);
  * ``fsdp_measured_overlap_fraction``, when tracked in the baseline,
    must be strictly positive in the fresh run — the background
    collective engine's acceptance bar: prefetch allgather and backward
    reduce-scatter genuinely overlap compute on the data path;
  * a baseline value of null means "informational only, not tracked".

Usage: check_bench_overlap.py BASELINE FRESH
"""

import json
import sys


def is_num(v):
    return isinstance(v, (int, float)) and not isinstance(v, bool)


TRACKED_SUFFIXES = (
    "_overlap_fraction",
    "_step_ratio",
    "_p99_tpot_ms",
    "_recovery_ms",
    "_stall_ns",
)


def main():
    if len(sys.argv) != 3:
        print(__doc__)
        return 2
    with open(sys.argv[1]) as f:
        base = json.load(f)
    with open(sys.argv[2]) as f:
        fresh = json.load(f)

    failures = []
    checked = 0

    for key, bval in sorted(base.items()):
        if not is_num(bval):
            continue
        fval = fresh.get(key)
        if not is_num(fval):
            if key.endswith(TRACKED_SUFFIXES) or "allocs" in key:
                failures.append(f"{key}: tracked in baseline but missing from fresh run")
            continue
        if key.endswith("_overlap_fraction"):
            checked += 1
            if fval < 0.9 * bval:
                failures.append(
                    f"{key}: overlap regressed >10% ({fval:.4f} < 0.9 * {bval:.4f})"
                )
            else:
                print(f"ok  {key}: {fval:.4f} (baseline {bval:.4f})")
        elif key.endswith("_step_ratio"):
            checked += 1
            if fval > 1.1 * bval:
                failures.append(
                    f"{key}: step-time ratio regressed >10% "
                    f"({fval:.4f} > 1.1 * {bval:.4f})"
                )
            else:
                print(f"ok  {key}: {fval:.4f} (baseline {bval:.4f})")
        elif key.endswith("_p99_tpot_ms"):
            checked += 1
            if fval > 1.1 * bval:
                failures.append(
                    f"{key}: p99 TPOT regressed >10% over the guard-rail "
                    f"({fval:.4f} ms > 1.1 * {bval:.4f} ms)"
                )
            else:
                print(f"ok  {key}: {fval:.4f} ms (guard-rail {bval:.4f} ms)")
        elif key.endswith(("_recovery_ms", "_stall_ns")):
            checked += 1
            unit = "ms" if key.endswith("_recovery_ms") else "ns"
            if fval > bval:
                failures.append(
                    f"{key}: absolute bound exceeded "
                    f"({fval:.1f} {unit} > bound {bval:.1f} {unit})"
                )
            else:
                print(f"ok  {key}: {fval:.1f} {unit} (bound {bval:.1f} {unit})")
        elif "allocs" in key:
            checked += 1
            if fval > bval:
                failures.append(
                    f"{key}: steady-state allocations increased "
                    f"({fval:.4f} > {bval:.4f})"
                )
            else:
                print(f"ok  {key}: {fval:.4f} (baseline headroom {bval:.4f})")

    # acceptance bar (overlap baseline only): the background collective
    # engine must measurably hide FSDP's collectives behind compute
    if "fsdp_measured_overlap_fraction" in base:
        fsdp = fresh.get("fsdp_measured_overlap_fraction")
        if not is_num(fsdp):
            failures.append("fsdp_measured_overlap_fraction: missing from fresh run")
        elif fsdp <= 0.0:
            failures.append(
                f"fsdp_measured_overlap_fraction: not strictly positive ({fsdp})"
            )
        else:
            print(f"ok  fsdp_measured_overlap_fraction strictly positive: {fsdp:.4f}")

    if failures:
        print("\nFAIL: bench regression vs committed baseline:")
        for f_ in failures:
            print(f"  - {f_}")
        return 1
    print(f"\nPASS: {checked} tracked metrics within baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
