#!/usr/bin/env python3
"""Diff a fresh figures/BENCH_overlap.json against the committed
repo-root baseline and fail on perf regressions.

Rules (see BENCH_overlap.json's "note" field):
  * keys ending in ``_overlap_fraction`` tracked in the baseline fail on a
    relative regression of more than 10% (fresh < 0.9 * baseline);
  * keys ending in ``_step_ratio`` tracked in the baseline fail on a
    relative regression of more than 10% (fresh > 1.1 * baseline; lower is
    better — e.g. the hop scheduler's scheduled/convoy step-time ratio,
    where a baseline of 1.0 means "scheduled must never cost more than
    ~10% over the FIFO convoy");
  * keys containing ``allocs`` tracked in the baseline fail on ANY
    increase (the steady-state hot paths are allocation-free by
    construction; the baseline values are explicit headroom);
  * ``fsdp_measured_overlap_fraction`` must be strictly positive — the
    background collective engine's acceptance bar: prefetch allgather and
    backward reduce-scatter genuinely overlap compute on the data path;
  * a baseline value of null means "informational only, not tracked".

Usage: check_bench_overlap.py BASELINE FRESH
"""

import json
import sys


def is_num(v):
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def main():
    if len(sys.argv) != 3:
        print(__doc__)
        return 2
    with open(sys.argv[1]) as f:
        base = json.load(f)
    with open(sys.argv[2]) as f:
        fresh = json.load(f)

    failures = []
    checked = 0

    for key, bval in sorted(base.items()):
        if not is_num(bval):
            continue
        fval = fresh.get(key)
        if not is_num(fval):
            if (
                key.endswith("_overlap_fraction")
                or key.endswith("_step_ratio")
                or "allocs" in key
            ):
                failures.append(f"{key}: tracked in baseline but missing from fresh run")
            continue
        if key.endswith("_overlap_fraction"):
            checked += 1
            if fval < 0.9 * bval:
                failures.append(
                    f"{key}: overlap regressed >10% ({fval:.4f} < 0.9 * {bval:.4f})"
                )
            else:
                print(f"ok  {key}: {fval:.4f} (baseline {bval:.4f})")
        elif key.endswith("_step_ratio"):
            checked += 1
            if fval > 1.1 * bval:
                failures.append(
                    f"{key}: step-time ratio regressed >10% "
                    f"({fval:.4f} > 1.1 * {bval:.4f})"
                )
            else:
                print(f"ok  {key}: {fval:.4f} (baseline {bval:.4f})")
        elif "allocs" in key:
            checked += 1
            if fval > bval:
                failures.append(
                    f"{key}: steady-state allocations increased ({fval:.0f} > {bval:.0f})"
                )
            else:
                print(f"ok  {key}: {fval:.0f} (baseline headroom {bval:.0f})")

    # acceptance bar: the background collective engine must measurably
    # hide FSDP's collectives behind compute
    fsdp = fresh.get("fsdp_measured_overlap_fraction")
    if not is_num(fsdp):
        failures.append("fsdp_measured_overlap_fraction: missing from fresh run")
    elif fsdp <= 0.0:
        failures.append(
            f"fsdp_measured_overlap_fraction: not strictly positive ({fsdp})"
        )
    else:
        print(f"ok  fsdp_measured_overlap_fraction strictly positive: {fsdp:.4f}")

    if failures:
        print("\nFAIL: BENCH_overlap regression vs committed baseline:")
        for f_ in failures:
            print(f"  - {f_}")
        return 1
    print(f"\nPASS: {checked} tracked metrics within baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
