"""Layer-2 op-contract tests.

Two families:
  1. Shard composition: concat/sum of the partition-op outputs equals the
     full (P=1) op — the algebraic fact the RTP rotation relies on.
  2. Backward-chain: composing the *_bwd ops the way the rust engine does
     reproduces jax.grad of the monolithic model — the op contract the
     coordinator is written against.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref

RT = dict(rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# param helpers: canonical full layouts + the shard slicing rule shared with
# rust/src/model/partition.rs
# ---------------------------------------------------------------------------

def make_params(r, v, h, nh, s, f, layers):
    def a(*shape):
        return jnp.array((r.randn(*shape) * 0.05).astype(np.float32))

    return {
        "wte": a(v, h),
        "wpe": a(s, h),
        "layers": [
            {
                "ln1_g": jnp.ones(h), "ln1_b": jnp.zeros(h),
                "wqkv": a(h, 3 * h), "bqkv": a(3 * h),
                "wo": a(h, h), "bo": a(h),
                "ln2_g": jnp.ones(h), "ln2_b": jnp.zeros(h),
                "w1": a(h, f), "b1": a(f), "w2": a(f, h), "b2": a(h),
            }
            for _ in range(layers)
        ],
        "lnf_g": jnp.ones(h), "lnf_b": jnp.zeros(h),
        "wlm": a(h, v),
    }


def shard_attn(lyr, h, nh, n, s):
    """Head-shard s of n: wqkv [H,3Hp], bqkv [3Hp], wo [Hp,H]."""
    hd = h // nh
    nh_p = nh // n
    wq = lyr["wqkv"].reshape(h, 3, nh, hd)[:, :, s * nh_p:(s + 1) * nh_p, :]
    bq = lyr["bqkv"].reshape(3, nh, hd)[:, s * nh_p:(s + 1) * nh_p, :]
    wo = lyr["wo"].reshape(nh, hd, h)[s * nh_p:(s + 1) * nh_p]
    hp = h // n
    return (
        wq.reshape(h, 3 * hp), bq.reshape(3 * hp), wo.reshape(hp, h), nh_p
    )


def shard_mlp(lyr, f, n, s):
    fp = f // n
    return (
        lyr["w1"][:, s * fp:(s + 1) * fp],
        lyr["b1"][s * fp:(s + 1) * fp],
        lyr["w2"][s * fp:(s + 1) * fp, :],
    )


# ---------------------------------------------------------------------------
# 1. shard composition
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 2, 4])
def test_attn_head_partition_sums_to_full(n):
    r = np.random.RandomState(0)
    v, h, nh, s, f = 32, 16, 4, 8, 64
    p = make_params(r, v, h, nh, s, f, 1)
    lyr = p["layers"][0]
    x = jnp.array(r.randn(2, s, h).astype(np.float32))
    full = model.attn_fwd(x, lyr["wqkv"], lyr["bqkv"], lyr["wo"], nh_p=nh)[0]
    acc = jnp.zeros_like(full)
    for sh in range(n):
        wq, bq, wo, nh_p = shard_attn(lyr, h, nh, n, sh)
        acc = acc + model.attn_fwd(x, wq, bq, wo, nh_p=nh_p)[0]
    np.testing.assert_allclose(acc, full, **RT)


@pytest.mark.parametrize("n", [1, 2, 4])
def test_mlp_partition_sums_to_full(n):
    r = np.random.RandomState(1)
    v, h, nh, s, f = 32, 16, 4, 8, 64
    p = make_params(r, v, h, nh, s, f, 1)
    lyr = p["layers"][0]
    x = jnp.array(r.randn(2, s, h).astype(np.float32))
    full = model.mlp_fwd(x, lyr["w1"], lyr["b1"], lyr["w2"])[0]
    acc = jnp.zeros_like(full)
    for sh in range(n):
        w1, b1, w2 = shard_mlp(lyr, f, n, sh)
        acc = acc + model.mlp_fwd(x, w1, b1, w2)[0]
    np.testing.assert_allclose(acc, full, **RT)


@pytest.mark.parametrize("n", [1, 2, 4])
def test_lmhead_partition_concats_to_full(n):
    r = np.random.RandomState(2)
    v, h = 32, 16
    wlm = jnp.array(r.randn(h, v).astype(np.float32))
    x = jnp.array(r.randn(2, 8, h).astype(np.float32))
    full = model.lmhead_fwd(x, wlm)[0]
    vp = v // n
    slices = [
        model.lmhead_fwd(x, wlm[:, s * vp:(s + 1) * vp])[0] for s in range(n)
    ]
    np.testing.assert_allclose(jnp.concatenate(slices, axis=-1), full, **RT)


@pytest.mark.parametrize("n", [1, 2, 4])
def test_emb_partition_concats_to_full(n):
    r = np.random.RandomState(3)
    v, h, s = 32, 16, 8
    wte = jnp.array(r.randn(v, h).astype(np.float32))
    wpe = jnp.array(r.randn(s, h).astype(np.float32))
    ids = jnp.array(r.randint(0, v, size=(2, s)).astype(np.int32))
    full = model.emb_fwd(ids, wte, wpe)[0]
    hp = h // n
    slices = [
        model.emb_fwd(ids, wte[:, s_ * hp:(s_ + 1) * hp],
                      wpe[:, s_ * hp:(s_ + 1) * hp])[0]
        for s_ in range(n)
    ]
    np.testing.assert_allclose(jnp.concatenate(slices, axis=-1), full, **RT)


def test_moe_expert_partition_sums_to_routed():
    """Sum over experts of gated partials == route-then-compute reference."""
    r = np.random.RandomState(4)
    b, s, h, e, fe = 2, 8, 16, 4, 32
    x = jnp.array(r.randn(b, s, h).astype(np.float32))
    wr = jnp.array(r.randn(h, e).astype(np.float32))
    experts = [
        (
            jnp.array(r.randn(h, fe).astype(np.float32)),
            jnp.array(r.randn(fe).astype(np.float32)),
            jnp.array(r.randn(fe, h).astype(np.float32)),
        )
        for _ in range(e)
    ]
    probs = model.router_fwd(x, wr)[0]
    top = jnp.argmax(probs, axis=-1)  # [b,s]
    gate = jnp.take_along_axis(probs, top[..., None], axis=-1)[..., 0]

    acc = jnp.zeros_like(x)
    for ei, (w1, b1, w2) in enumerate(experts):
        gates_e = jnp.where(top == ei, gate, 0.0)
        acc = acc + model.moe_fwd(x, gates_e, w1, b1, w2)[0]

    # reference: per-token dispatch
    want = np.zeros((b, s, h), np.float32)
    xn = np.asarray(x)
    for bi in range(b):
        for si in range(s):
            ei = int(top[bi, si])
            w1, b1, w2 = experts[ei]
            hdn = ref.gelu(jnp.array(xn[bi, si]) @ w1 + b1)
            want[bi, si] = np.asarray(hdn @ w2) * float(gate[bi, si])
    np.testing.assert_allclose(acc, want, **RT)


# ---------------------------------------------------------------------------
# 2. backward chain == jax.grad of the monolithic model
# ---------------------------------------------------------------------------

def mini_engine_grads(p, ids, targets, nh):
    """Compose the AOT ops exactly the way the rust single-engine does."""
    grads = {"layers": [dict() for _ in p["layers"]]}
    x = model.emb_fwd(ids, p["wte"], p["wpe"])[0]
    saves = []
    for lyr in p["layers"]:
        a = model.ln_fwd(x, lyr["ln1_g"], lyr["ln1_b"])[0]
        part = model.attn_fwd(a, lyr["wqkv"], lyr["bqkv"], lyr["wo"],
                              nh_p=nh)[0]
        x1 = x + part + lyr["bo"]
        m = model.ln_fwd(x1, lyr["ln2_g"], lyr["ln2_b"])[0]
        part2 = model.mlp_fwd(m, lyr["w1"], lyr["b1"], lyr["w2"])[0]
        x2 = x1 + part2 + lyr["b2"]
        saves.append((x, a, x1, m))
        x = x2
    xf = model.ln_fwd(x, p["lnf_g"], p["lnf_b"])[0]
    logits = model.lmhead_fwd(xf, p["wlm"])[0]
    loss, dlogits = model.xent(logits, targets)

    dxf, grads["wlm"] = model.lmhead_bwd(xf, p["wlm"], dlogits)
    dx, grads["lnf_g"], grads["lnf_b"] = model.ln_bwd(x, p["lnf_g"], dxf
    )
    for li in reversed(range(len(p["layers"]))):
        lyr = p["layers"][li]
        g = grads["layers"][li]
        x0, a, x1, m = saves[li]
        g["b2"] = jnp.sum(dx, axis=(0, 1))
        dm, g["w1"], g["b1"], g["w2"] = model.mlp_bwd(
            m, lyr["w1"], lyr["b1"], lyr["w2"], dx
        )
        dx1_ln, g["ln2_g"], g["ln2_b"] = model.ln_bwd(x1, lyr["ln2_g"], dm
        )
        dx1 = dx + dx1_ln
        g["bo"] = jnp.sum(dx1, axis=(0, 1))
        da, g["wqkv"], g["bqkv"], g["wo"] = model.attn_bwd(
            a, lyr["wqkv"], lyr["bqkv"], lyr["wo"], dx1, nh_p=nh
        )
        dx_ln, g["ln1_g"], g["ln1_b"] = model.ln_bwd(x0, lyr["ln1_g"], da
        )
        dx = dx1 + dx_ln
    grads["wte"], grads["wpe"] = model.emb_bwd(ids, dx, vocab=p["wte"].shape[0])
    return loss, grads


def test_bwd_chain_matches_jax_grad():
    r = np.random.RandomState(5)
    v, h, nh, s, f, L, b = 32, 16, 2, 8, 32, 2, 2
    p = make_params(r, v, h, nh, s, f, L)
    ids = jnp.array(r.randint(0, v, size=(b, s)).astype(np.int32))
    tg = jnp.array(r.randint(0, v, size=(b, s)).astype(np.int32))

    loss, got = mini_engine_grads(p, ids, tg, nh)
    want_loss = model.full_model_loss(p, ids, tg, heads=nh)
    want = jax.grad(model.full_model_loss)(p, ids, tg, heads=nh)

    np.testing.assert_allclose(loss, want_loss, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got["wlm"], want["wlm"], **RT)
    np.testing.assert_allclose(got["wte"], want["wte"], **RT)
    np.testing.assert_allclose(got["wpe"], want["wpe"], **RT)
    for li in range(L):
        for key in ["wqkv", "bqkv", "wo", "bo", "w1", "b1", "w2", "b2",
                    "ln1_g", "ln1_b", "ln2_g", "ln2_b"]:
            np.testing.assert_allclose(
                got["layers"][li][key], want["layers"][li][key],
                err_msg=f"layer {li} {key}", **RT
            )


def test_pallas_ops_match_jnp_ops():
    """Forward AND backward parity of the pallas-dispatch path."""
    r = np.random.RandomState(6)
    h, nh, s, f, b = 16, 2, 8, 32, 2
    x = jnp.array(r.randn(b, s, h).astype(np.float32))
    dy = jnp.array(r.randn(b, s, h).astype(np.float32))
    p = make_params(r, 32, h, nh, s, f, 1)
    lyr = p["layers"][0]

    f_j = model.mlp_fwd(x, lyr["w1"], lyr["b1"], lyr["w2"])[0]
    f_p = model.mlp_fwd(x, lyr["w1"], lyr["b1"], lyr["w2"], use_pallas=True)[0]
    np.testing.assert_allclose(f_p, f_j, **RT)

    b_j = model.mlp_bwd(x, lyr["w1"], lyr["b1"], lyr["w2"], dy)
    b_p = model.mlp_bwd(x, lyr["w1"], lyr["b1"], lyr["w2"], dy,
                        use_pallas=True)
    for gj, gp in zip(b_j, b_p):
        np.testing.assert_allclose(gp, gj, **RT)

    a_j = model.attn_fwd(x, lyr["wqkv"], lyr["bqkv"], lyr["wo"], nh_p=nh)[0]
    a_p = model.attn_fwd(x, lyr["wqkv"], lyr["bqkv"], lyr["wo"], nh_p=nh,
                         use_pallas=True)[0]
    np.testing.assert_allclose(a_p, a_j, **RT)

    ab_j = model.attn_bwd(x, lyr["wqkv"], lyr["bqkv"], lyr["wo"], dy, nh_p=nh)
    ab_p = model.attn_bwd(x, lyr["wqkv"], lyr["bqkv"], lyr["wo"], dy,
                          nh_p=nh, use_pallas=True)
    for gj, gp in zip(ab_j, ab_p):
        np.testing.assert_allclose(gp, gj, **RT)
