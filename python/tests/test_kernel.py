"""Kernel vs ref allclose — the CORE Layer-1 correctness signal."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import attention, layernorm, matmul, ref, softmax_xent

RTOL, ATOL = 2e-4, 2e-4


def _rng(seed=0):
    return np.random.RandomState(seed)


@pytest.mark.parametrize(
    "m,k,n", [(1, 1, 1), (4, 8, 16), (130, 70, 200), (256, 512, 384), (33, 5, 7)]
)
@pytest.mark.parametrize("bias", [True, False])
@pytest.mark.parametrize("act", ["none", "gelu"])
def test_matmul(m, k, n, bias, act):
    r = _rng(m * 1000 + k * 10 + n)
    x = jnp.array(r.randn(m, k).astype(np.float32))
    w = jnp.array(r.randn(k, n).astype(np.float32))
    b = jnp.array(r.randn(n).astype(np.float32)) if bias else None
    got = matmul.matmul_bias_act(x, w, b, act)
    want = ref.matmul_bias_act(x, w, b, act)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_matmul_batched_input():
    r = _rng(7)
    x = jnp.array(r.randn(2, 5, 16).astype(np.float32))
    w = jnp.array(r.randn(16, 24).astype(np.float32))
    got = matmul.matmul_bias_act(x, w)
    np.testing.assert_allclose(got, ref.matmul_bias_act(x, w), rtol=RTOL, atol=ATOL)
    assert got.shape == (2, 5, 24)


@pytest.mark.parametrize(
    "b,nh,s,hd", [(1, 1, 4, 8), (2, 4, 16, 8), (1, 2, 130, 16), (2, 2, 33, 4)]
)
def test_attention(b, nh, s, hd):
    r = _rng(b + nh + s + hd)
    q, k, v = (
        jnp.array(r.randn(b, nh, s, hd).astype(np.float32)) for _ in range(3)
    )
    got = attention.attention(q, k, v)
    want = ref.attention(q, k, v)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_attention_is_causal():
    """Future kv positions must not influence the output."""
    r = _rng(3)
    b, nh, s, hd = 1, 2, 12, 8
    q, k, v = (
        jnp.array(r.randn(b, nh, s, hd).astype(np.float32)) for _ in range(3)
    )
    base = attention.attention(q, k, v)
    k2 = k.at[:, :, -1, :].set(99.0)
    v2 = v.at[:, :, -1, :].set(-99.0)
    pert = attention.attention(q, k2, v2)
    # all rows except the last are unchanged
    np.testing.assert_allclose(base[:, :, :-1], pert[:, :, :-1], rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [(3, 16, 32), (7, 5), (300, 64), (1, 1)])
def test_layernorm(shape):
    r = _rng(sum(shape))
    x = jnp.array(r.randn(*shape).astype(np.float32))
    g = jnp.array(r.randn(shape[-1]).astype(np.float32))
    b = jnp.array(r.randn(shape[-1]).astype(np.float32))
    np.testing.assert_allclose(
        layernorm.layernorm(x, g, b), ref.layernorm(x, g, b), rtol=RTOL, atol=ATOL
    )


@pytest.mark.parametrize("t,v", [(1, 2), (8, 16), (33, 128), (260, 512)])
def test_softmax_xent(t, v):
    r = _rng(t + v)
    lg = jnp.array(r.randn(t, v).astype(np.float32) * 3)
    tg = jnp.array(r.randint(0, v, size=t).astype(np.int32))
    l1, d1 = softmax_xent.softmax_xent(lg, tg)
    l2, d2 = ref.softmax_xent(lg, tg)
    np.testing.assert_allclose(l1, l2, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(d1, d2, rtol=1e-4, atol=1e-5)


def test_softmax_xent_grad_is_probs_minus_onehot():
    """dlogits rows must sum to ~0 (softmax minus onehot property)."""
    r = _rng(11)
    lg = jnp.array(r.randn(9, 33).astype(np.float32))
    tg = jnp.array(r.randint(0, 33, size=9).astype(np.int32))
    _, d = softmax_xent.softmax_xent(lg, tg)
    np.testing.assert_allclose(np.asarray(d).sum(axis=1), 0.0, atol=1e-6)


def test_reports_have_vmem_budget():
    """Every kernel's block working set must fit VMEM (perf deliverable)."""
    reps = [
        matmul.report(2048, 2560, 640),
        attention.report(1024, 160),
        layernorm.report(2048, 2560),
        softmax_xent.report(2048, 50257),
    ]
    for rep in reps:
        assert rep["vmem_frac"] < 1.0, rep
