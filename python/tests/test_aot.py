"""AOT manifest sanity: the artifact contract the rust runtime loads."""

import json
import os

import pytest

from compile import aot, presets

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _manifest(preset, pallas=False):
    name = "manifest_pallas.json" if pallas else "manifest.json"
    path = os.path.join(ART, preset, name)
    if not os.path.exists(path):
        pytest.skip(f"{path} not built (run `make artifacts`)")
    with open(path) as fh:
        return json.load(fh)


@pytest.mark.parametrize("preset", ["tiny", "tiny-moe", "e2e-small"])
def test_manifest_files_exist_and_keys_unique(preset):
    man = _manifest(preset)
    keys = [e["key"] for e in man["entries"]]
    assert len(keys) == len(set(keys))
    for e in man["entries"]:
        path = os.path.join(ART, e["file"])
        assert os.path.exists(path), e["file"]
        text = open(path).read()
        assert text.startswith("HloModule"), e["file"]


def test_manifest_covers_all_combos():
    man = _manifest("tiny")
    cfg = presets.get("tiny")
    have = {(e["op"], e["b"], e["p"]) for e in man["entries"]}
    for (b, p) in cfg.combos:
        for op in ["emb_fwd", "emb_bwd", "attn_fwd", "attn_bwd",
                   "mlp_fwd", "mlp_bwd", "lmhead_fwd", "lmhead_bwd",
                   "ln_fwd", "ln_bwd"]:
            assert (op, b, p) in have, (op, b, p)
        assert ("xent", b, 1) in have


def test_manifest_shapes_match_shape_plan():
    man = _manifest("tiny")
    cfg = presets.get("tiny")
    planned = {
        key: [list(a.shape) for a in args]
        for key, _, args in aot.op_instances(cfg, use_pallas=False)
    }
    for e in man["entries"]:
        assert e["key"] in planned, e["key"]
        assert [sh for _, sh in e["inputs"]] == planned[e["key"]], e["key"]


def test_moe_manifest_has_expert_ops():
    man = _manifest("tiny-moe")
    ops = {e["op"] for e in man["entries"]}
    assert {"router_fwd", "router_bwd", "moe_fwd", "moe_bwd"} <= ops


def test_pallas_manifest_marked():
    man = _manifest("tiny", pallas=True)
    assert all(e["pallas"] for e in man["entries"])
    assert all(e["key"].endswith("__pallas") for e in man["entries"])


def test_config_embedded_in_manifest():
    man = _manifest("tiny")
    cfg = man["config"]
    assert cfg["hidden"] % cfg["heads"] == 0
    assert cfg["params_dense"] == presets.get("tiny").params_dense()
