"""Hypothesis sweeps: Pallas kernels vs ref over random shapes/values.

The brief for Layer 1: hypothesis sweeps the kernels' shape space and
asserts allclose against ref.py. Examples counts are tuned for the 1-core
CI box (interpret-mode pallas is slow); the shape strategies still cover
the ragged/non-multiple cases that break naive BlockSpec code.
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, layernorm, matmul, ref, softmax_xent

COMMON = dict(deadline=None, max_examples=20, derandomize=True)


def _arr(r, *shape):
    return jnp.array(r.randn(*shape).astype(np.float32))


@settings(**COMMON)
@given(
    m=st.integers(1, 80),
    k=st.integers(1, 80),
    n=st.integers(1, 80),
    bias=st.booleans(),
    act=st.sampled_from(["none", "gelu"]),
    seed=st.integers(0, 2**16),
)
def test_matmul_sweep(m, k, n, bias, act, seed):
    r = np.random.RandomState(seed)
    x, w = _arr(r, m, k), _arr(r, k, n)
    b = _arr(r, n) if bias else None
    got = matmul.matmul_bias_act(x, w, b, act)
    want = ref.matmul_bias_act(x, w, b, act)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


@settings(**COMMON)
@given(
    b=st.integers(1, 3),
    nh=st.integers(1, 4),
    s=st.integers(1, 48),
    hd=st.sampled_from([2, 4, 8, 16]),
    seed=st.integers(0, 2**16),
)
def test_attention_sweep(b, nh, s, hd, seed):
    r = np.random.RandomState(seed)
    q, k, v = _arr(r, b, nh, s, hd), _arr(r, b, nh, s, hd), _arr(r, b, nh, s, hd)
    got = attention.attention(q, k, v)
    want = ref.attention(q, k, v)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


@settings(**COMMON)
@given(
    rows=st.integers(1, 300),
    h=st.integers(1, 96),
    seed=st.integers(0, 2**16),
)
def test_layernorm_sweep(rows, h, seed):
    r = np.random.RandomState(seed)
    x, g, b = _arr(r, rows, h), _arr(r, h), _arr(r, h)
    np.testing.assert_allclose(
        layernorm.layernorm(x, g, b), ref.layernorm(x, g, b),
        rtol=3e-4, atol=3e-4,
    )


@settings(**COMMON)
@given(
    t=st.integers(1, 120),
    v=st.integers(2, 300),
    scale=st.floats(0.1, 8.0),
    seed=st.integers(0, 2**16),
)
def test_xent_sweep(t, v, scale, seed):
    r = np.random.RandomState(seed)
    lg = jnp.array((r.randn(t, v) * scale).astype(np.float32))
    tg = jnp.array(r.randint(0, v, size=t).astype(np.int32))
    l1, d1 = softmax_xent.softmax_xent(lg, tg)
    l2, d2 = ref.softmax_xent(lg, tg)
    np.testing.assert_allclose(l1, l2, rtol=2e-4, atol=1e-5)
    np.testing.assert_allclose(d1, d2, rtol=2e-4, atol=1e-5)
