"""Layer 2: the RTP shard ops — JAX fwd/bwd for every partition unit.

The rust coordinator (Layer 3) decomposes a GPT-style transformer into RTP
units (paper §3.2/§4) and drives these ops once per (worker, rotation step):

  Output-Partition  -> emb_fwd/bwd, lmhead_fwd/bwd       (merge = concat)
  Head-Partition    -> attn_fwd/bwd                      (merge = add)
  Input+Output pair -> mlp_fwd/bwd                       (merge = add)
  Expert-Partition  -> router_fwd/bwd, moe_fwd/bwd       (merge = add)
  replicated        -> ln_fwd/bwd, xent

Conventions shared with rust (rust/src/model/partition.rs):
  * every op returns a TUPLE (uniform unwrapping on the rust side);
  * weight shards use the canonical layouts documented per-op below, so the
    rust partitioner can slice a full weight into shards with plain strided
    copies;
  * biases that would be double-counted by sum-merges (attention bo, mlp
    b2) are NOT applied here; the engine adds them once after merging;
  * backward ops recompute internals from the saved layer *inputs* (flash
    style), so engines only stash per-layer inputs — this is the activation
    memory model Table 1 assumes.

Every op has a `use_pallas` switch: False lowers through plain jnp, True
routes the hot math through the Layer-1 Pallas kernels (interpret=True) so
the kernels end up inside the same HLO artifact.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import pallas_ops
from .kernels import ref
from .kernels import softmax_xent as kxent


# ---------------------------------------------------------------------------
# primitive dispatch (jnp vs pallas). The pallas path goes through the
# custom_vjp wrappers in kernels/pallas_ops.py so jax.vjp works in the
# *_bwd ops below.
# ---------------------------------------------------------------------------

def _matmul(x, w, b=None, activation="none", *, use_pallas=False):
    if use_pallas:
        return pallas_ops.matmul(x, w, b, activation)
    return ref.matmul_bias_act(x, w, b, activation)


def _attention(q, k, v, *, use_pallas=False):
    if use_pallas:
        return pallas_ops.attention(q, k, v)
    return ref.attention(q, k, v)


def _layernorm(x, g, b, *, use_pallas=False):
    if use_pallas:
        return pallas_ops.layernorm(x, g, b)
    return ref.layernorm(x, g, b)


def _softmax_xent(logits, targets, *, use_pallas=False):
    if use_pallas:
        return kxent.softmax_xent(logits, targets)
    return ref.softmax_xent(logits, targets)


# ---------------------------------------------------------------------------
# Output-Partition: embedding (token + positional), sharded on hidden dim.
# wte: [V, Hp], wpe: [S, Hp] — column shard `s` of the full [V, H] / [S, H].
# ---------------------------------------------------------------------------

def emb_fwd(ids, wte, wpe, *, use_pallas=False):
    """ids [b,S] i32 -> (x [b,S,Hp],)."""
    del use_pallas  # pure gather; nothing to tile
    return (wte[ids] + wpe[None, :, :],)


def emb_bwd(ids, dx, *, vocab, use_pallas=False):
    """-> (dwte [V,Hp], dwpe [S,Hp]). Scatter-add of the output grad."""
    del use_pallas
    dwte = jnp.zeros((vocab, dx.shape[-1]), dx.dtype).at[ids].add(dx)
    dwpe = jnp.sum(dx, axis=0)
    return (dwte, dwpe)


# ---------------------------------------------------------------------------
# replicated LayerNorm
# ---------------------------------------------------------------------------

def ln_fwd(x, g, b, *, use_pallas=False):
    return (_layernorm(x, g, b, use_pallas=use_pallas),)


def ln_bwd(x, g, dy, *, use_pallas=False):
    """-> (dx, dg, db). The bias VALUE does not enter any gradient, so it
    is not an input (jax would dead-code-eliminate the parameter from the
    lowered HLO, desyncing the manifest — see runtime/manifest.rs)."""
    zero_b = jnp.zeros_like(g)
    _, vjp = jax.vjp(lambda x_, g_, b_: _layernorm(x_, g_, b_,
                                                   use_pallas=use_pallas),
                     x, g, zero_b)
    return tuple(vjp(dy))


# ---------------------------------------------------------------------------
# Head-Partition: attention. Canonical full layout wqkv [H, 3, NH, HD]
# (flattened [H, 3H]); a shard takes a contiguous range of heads ->
# wqkv [H, 3*Hp], bqkv [3*Hp], wo [Hp, H] (row shard). Output is a PARTIAL
# sum over head shards; bo is added by the engine exactly once.
# ---------------------------------------------------------------------------

def attn_fwd(x, wqkv, bqkv, wo, *, nh_p, use_pallas=False):
    """x [b,S,H] -> (partial [b,S,H],)."""
    b, s, _ = x.shape
    hp3 = wqkv.shape[1]
    hp = hp3 // 3
    hd = hp // nh_p
    qkv = _matmul(x, wqkv, bqkv, use_pallas=use_pallas)  # [b,S,3Hp]
    qkv = qkv.reshape(b, s, 3, nh_p, hd).transpose(2, 0, 3, 1, 4)
    q, k, v = qkv[0], qkv[1], qkv[2]  # [b,nh_p,S,hd]
    o = _attention(q, k, v, use_pallas=use_pallas)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, hp)
    return (_matmul(o, wo, use_pallas=use_pallas),)


def attn_bwd(x, wqkv, bqkv, wo, dpartial, *, nh_p, use_pallas=False):
    """Recomputes attention from the saved input.

    -> (dx, dwqkv, dbqkv, dwo)."""
    f = lambda x_, wq_, bq_, wo_: attn_fwd(
        x_, wq_, bq_, wo_, nh_p=nh_p, use_pallas=use_pallas
    )[0]
    _, vjp = jax.vjp(f, x, wqkv, bqkv, wo)
    return tuple(vjp(dpartial))


# ---------------------------------------------------------------------------
# Megatron-pair MLP: w1 [H, Fp] column shard (+GeLU), w2 [Fp, H] row shard.
# Output is a PARTIAL sum; b2 added once by the engine.
# ---------------------------------------------------------------------------

def mlp_fwd(x, w1, b1, w2, *, use_pallas=False):
    """x [b,S,H] -> (partial [b,S,H],)."""
    h = _matmul(x, w1, b1, activation="gelu", use_pallas=use_pallas)
    return (_matmul(h, w2, use_pallas=use_pallas),)


def mlp_bwd(x, w1, b1, w2, dpartial, *, use_pallas=False):
    """-> (dx, dw1, db1, dw2). Recomputes the GeLU hidden."""
    f = lambda x_, w1_, b1_, w2_: mlp_fwd(
        x_, w1_, b1_, w2_, use_pallas=use_pallas
    )[0]
    _, vjp = jax.vjp(f, x, w1, b1, w2)
    return tuple(vjp(dpartial))


# ---------------------------------------------------------------------------
# Output-Partition: LM head, vocab-sharded, no bias.
# wlm [H, Vp] column shard of [H, V].
# ---------------------------------------------------------------------------

def lmhead_fwd(x, wlm, *, use_pallas=False):
    """x [b,S,H] -> (logits slice [b,S,Vp],)."""
    return (_matmul(x, wlm, use_pallas=use_pallas),)


def lmhead_bwd(x, wlm, dlogits, *, use_pallas=False):
    """-> (dx partial [b,S,H], dwlm)."""
    f = lambda x_, w_: lmhead_fwd(x_, w_, use_pallas=use_pallas)[0]
    _, vjp = jax.vjp(f, x, wlm)
    return tuple(vjp(dlogits))


# ---------------------------------------------------------------------------
# loss (replicated over the worker's batch shard)
# ---------------------------------------------------------------------------

def xent(logits, targets, *, use_pallas=False):
    """logits [b,S,V], targets [b,S] i32 -> (loss scalar, dlogits)."""
    b, s, v = logits.shape
    loss, dl = _softmax_xent(
        logits.reshape(b * s, v), targets.reshape(b * s),
        use_pallas=use_pallas,
    )
    return (loss, dl.reshape(b, s, v))


# ---------------------------------------------------------------------------
# Expert-Partition: MoE router + per-expert FFN.
# The router is replicated (tiny); experts rotate. The engine computes the
# top-1 assignment from `probs`, builds per-expert gate vectors
# (prob if routed-to-this-expert else 0) and calls moe_fwd once per
# (expert visit). Sum over experts of the partials == full MoE output.
# ---------------------------------------------------------------------------

def router_fwd(x, wr, *, use_pallas=False):
    """x [b,S,H], wr [H,E] -> (probs [b,S,E],)."""
    logits = _matmul(x, wr, use_pallas=use_pallas)
    return (jax.nn.softmax(logits, axis=-1),)


def router_bwd(x, wr, dprobs, *, use_pallas=False):
    f = lambda x_, w_: router_fwd(x_, w_, use_pallas=use_pallas)[0]
    _, vjp = jax.vjp(f, x, wr)
    return tuple(vjp(dprobs))


def moe_fwd(x, gates, w1, b1, w2, *, use_pallas=False):
    """One expert on a gated token set.

    x [b,S,H], gates [b,S] (top-1 prob, 0 for tokens routed elsewhere),
    w1 [H,Fe], b1 [Fe], w2 [Fe,H] -> (partial [b,S,H],).

    Dense-masked formulation: every token runs through the expert and the
    gate zeroes non-routed tokens. This keeps shapes static for AOT (the
    paper's all-to-all shuffles tokens instead; the FLOP difference is
    charged in the perf model, see perfmodel/compute.rs).
    """
    h = _matmul(x, w1, b1, activation="gelu", use_pallas=use_pallas)
    y = _matmul(h, w2, use_pallas=use_pallas)
    return (y * gates[:, :, None],)


def moe_bwd(x, gates, w1, b1, w2, dpartial, *, use_pallas=False):
    """-> (dx, dgates, dw1, db1, dw2)."""
    f = lambda x_, g_, w1_, b1_, w2_: moe_fwd(
        x_, g_, w1_, b1_, w2_, use_pallas=use_pallas
    )[0]
    _, vjp = jax.vjp(f, x, gates, w1, b1, w2)
    return tuple(vjp(dpartial))


# ---------------------------------------------------------------------------
# Monolithic reference model (tests only, never AOT'd): a full GPT forward +
# loss through jax.grad, used to validate that the decomposed op chain and
# the rust engine composition produce the true gradient.
# ---------------------------------------------------------------------------

def full_model_loss(params, ids, targets, *, heads):
    """Dense GPT-2 forward + mean xent, params as a pytree dict."""
    x = params["wte"][ids] + params["wpe"][None, :, :]
    for lyr in params["layers"]:
        a = ref.layernorm(x, lyr["ln1_g"], lyr["ln1_b"])
        part = attn_fwd(a, lyr["wqkv"], lyr["bqkv"], lyr["wo"], nh_p=heads)[0]
        x = x + part + lyr["bo"]
        m = ref.layernorm(x, lyr["ln2_g"], lyr["ln2_b"])
        part = mlp_fwd(m, lyr["w1"], lyr["b1"], lyr["w2"])[0]
        x = x + part + lyr["b2"]
    xf = ref.layernorm(x, params["lnf_g"], params["lnf_b"])
    logits = xf @ params["wlm"]
    loss, _ = ref.softmax_xent(
        logits.reshape(-1, logits.shape[-1]), targets.reshape(-1)
    )
    return loss
