"""Model presets shared between the compile path and the rust coordinator.

Table-2 presets mirror the paper's evaluation configs (GPT2 .. GPT2-neo);
they are used by the rust side in *virtual* (accounting-only) mode and never
need artifacts. Runtime presets (`tiny`, `tiny-moe`, `e2e-small`,
`e2e-100m`) are small enough to execute for real on the CPU PJRT client and
get HLO artifacts emitted by `aot.py`.

The rust side has a mirrored copy in `rust/src/config/presets.rs`; the
`test_presets_match_rust` test keeps the two in sync.
"""

from dataclasses import dataclass, field, asdict
from typing import Dict, List, Optional


@dataclass(frozen=True)
class ModelConfig:
    """GPT-style transformer hyperparameters (paper Table 2 schema)."""

    name: str
    vocab: int
    hidden: int
    heads: int
    layers: int
    seq: int
    ffn: int  # MLP inner dim (paper's "Embedding Size" column = 4*hidden)
    # Mixture-of-experts: 0 = dense MLP; otherwise number of experts and the
    # per-expert ffn dim (paper Fig 7 rotates one expert per worker).
    experts: int = 0
    expert_ffn: int = 0
    # Whether aot.py emits runtime artifacts for this preset.
    artifacts: bool = False
    # (batch, partition) combos the artifact set must cover. `batch` is the
    # *local* batch (per-worker activation shard), `p` the weight-partition
    # factor N. p=1 entries are the full-weight ops used by DDP/FSDP/single.
    combos: tuple = ()

    @property
    def head_dim(self) -> int:
        return self.hidden // self.heads

    def params_dense(self) -> int:
        """Parameter count (dense variant), untied LM head."""
        emb = self.vocab * self.hidden + self.seq * self.hidden
        per_layer = (
            # attn: wqkv [H,3H] + bqkv [3H] + wo [H,H] + bo [H]
            3 * self.hidden * self.hidden
            + 3 * self.hidden
            + self.hidden * self.hidden
            + self.hidden
            # mlp: w1 [H,F] + b1 [F] + w2 [F,H] + b2 [H]
            + 2 * self.hidden * self.ffn
            + self.ffn
            + self.hidden
            # ln1, ln2
            + 4 * self.hidden
        )
        lm = self.hidden * self.vocab
        lnf = 2 * self.hidden
        return emb + self.layers * per_layer + lm + lnf


def _t2(name, vocab, hidden, heads, layers, seq, ffn) -> ModelConfig:
    return ModelConfig(
        name=name, vocab=vocab, hidden=hidden, heads=heads, layers=layers,
        seq=seq, ffn=ffn,
    )


# ---------------------------------------------------------------------------
# Paper Table 2 (virtual-mode only: memory/capacity/throughput figures).
# ---------------------------------------------------------------------------
TABLE2: List[ModelConfig] = [
    _t2("gpt2-117m", 50257, 768, 16, 12, 512, 3072),
    _t2("bert-large-340m", 30522, 1024, 16, 24, 512, 4096),
    _t2("gpt2-500m", 50257, 1280, 16, 20, 1024, 5120),
    _t2("gpt2-large-774m", 50257, 1280, 16, 32, 1024, 5120),
    _t2("gpt2-xl-1.5b", 50257, 1600, 16, 48, 1024, 6400),
    _t2("gpt2-neo-2.7b", 50257, 2560, 16, 32, 1024, 10240),
]

# ---------------------------------------------------------------------------
# Runtime presets: executed for real on the CPU PJRT client.
#
# combos: (local_batch, partition) pairs. For a tested global batch B and
# worker count N we need:
#   RTP / FSDP / DDP: (B/N, p) with p in {1, N}  (p=N shard ops for RTP,
#   p=1 full ops for DDP/FSDP compute after allgather)
#   Megatron-TP: (B, N) — full batch on sharded weights.
#   single oracle: (B, 1).
# ---------------------------------------------------------------------------
RUNTIME: Dict[str, ModelConfig] = {
    # CI workhorse: global batch 4, N in {1, 2, 4}.
    "tiny": ModelConfig(
        name="tiny", vocab=128, hidden=32, heads=4, layers=2, seq=16,
        ffn=128, artifacts=True,
        combos=(
            (4, 1), (2, 1), (1, 1),          # single/DDP/FSDP at N=1,2,4
            (2, 2), (1, 4),                  # RTP shard ops at N=2,4
            (4, 2), (4, 4),                  # Megatron-TP (full batch, sharded)
        ),
    ),
    # MoE variant of tiny: 4 experts, expert-parallel over N=2,4.
    "tiny-moe": ModelConfig(
        name="tiny-moe", vocab=128, hidden=32, heads=4, layers=2, seq=16,
        ffn=128, experts=4, expert_ffn=128, artifacts=True,
        combos=((4, 1), (2, 1), (1, 1), (2, 2), (1, 4)),
    ),
    # End-to-end training demo (~34M params): global batch 4, N=2.
    "e2e-small": ModelConfig(
        name="e2e-small", vocab=8192, hidden=512, heads=8, layers=8, seq=64,
        ffn=2048, artifacts=True,
        combos=((4, 1), (2, 1), (2, 2)),
    ),
    # The required ~100M-param end-to-end run (~110M): global batch 2, N=2.
    "e2e-100m": ModelConfig(
        name="e2e-100m", vocab=16384, hidden=768, heads=12, layers=12,
        seq=64, ffn=3072, artifacts=True,
        combos=((2, 1), (1, 1), (1, 2)),
    ),
}

PRESETS: Dict[str, ModelConfig] = {**{m.name: m for m in TABLE2}, **RUNTIME}


def get(name: str) -> ModelConfig:
    try:
        return PRESETS[name]
    except KeyError:
        raise SystemExit(
            f"unknown preset {name!r}; available: {', '.join(sorted(PRESETS))}"
        )


def as_dict(cfg: ModelConfig) -> dict:
    d = asdict(cfg)
    d["combos"] = [list(c) for c in cfg.combos]
    d["params_dense"] = cfg.params_dense()
    return d
