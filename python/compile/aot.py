"""AOT compile path: lower every shard op to HLO *text* + manifest.json.

Run once by `make artifacts`; python never appears on the training path.

Interchange format is HLO text, NOT `lowered.compile()` / serialized protos:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the xla
crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Usage:
  python -m compile.aot --out-dir ../artifacts --preset tiny
  python -m compile.aot --out-dir ../artifacts --preset tiny --pallas
  python -m compile.aot --preset e2e-small --report-kernels
  python -m compile.aot --preset tiny --report-hlo
"""

import argparse
import functools
import json
import os
import re
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, presets
from .kernels import attention as kattn
from .kernels import matmul as kmm
from .kernels import layernorm as kln
from .kernels import softmax_xent as kxent

F32 = jnp.float32
I32 = jnp.int32


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple for uniform
    unwrapping on the rust side)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# shape plan
# ---------------------------------------------------------------------------

def op_instances(cfg: presets.ModelConfig, use_pallas: bool):
    """Yield (key, fn, arg_specs) for every op instance the preset needs.

    Keys are `op__b{b}__p{p}` (+ `__pallas`), matching
    rust/src/runtime/artifacts.rs::ArtifactKey.
    """
    v, h, s, f = cfg.vocab, cfg.hidden, cfg.seq, cfg.ffn
    nh = cfg.heads
    up = {"use_pallas": use_pallas}
    seen = set()

    for (b, p) in cfg.combos:
        hp, fp, vp, nh_p = h // p, f // p, v // p, nh // p
        ops = {
            "emb_fwd": (
                functools.partial(model.emb_fwd, **up),
                [spec((b, s), I32), spec((v, hp)), spec((s, hp))],
            ),
            "emb_bwd": (
                functools.partial(model.emb_bwd, vocab=v, **up),
                [spec((b, s), I32), spec((b, s, hp))],
            ),
            "ln_fwd": (
                functools.partial(model.ln_fwd, **up),
                [spec((b, s, h)), spec((h,)), spec((h,))],
            ),
            "ln_bwd": (
                functools.partial(model.ln_bwd, **up),
                [spec((b, s, h)), spec((h,)), spec((b, s, h))],
            ),
            "attn_fwd": (
                functools.partial(model.attn_fwd, nh_p=nh_p, **up),
                [spec((b, s, h)), spec((h, 3 * hp)), spec((3 * hp,)),
                 spec((hp, h))],
            ),
            "attn_bwd": (
                functools.partial(model.attn_bwd, nh_p=nh_p, **up),
                [spec((b, s, h)), spec((h, 3 * hp)), spec((3 * hp,)),
                 spec((hp, h)), spec((b, s, h))],
            ),
            "mlp_fwd": (
                functools.partial(model.mlp_fwd, **up),
                [spec((b, s, h)), spec((h, fp)), spec((fp,)), spec((fp, h))],
            ),
            "mlp_bwd": (
                functools.partial(model.mlp_bwd, **up),
                [spec((b, s, h)), spec((h, fp)), spec((fp,)), spec((fp, h)),
                 spec((b, s, h))],
            ),
            "lmhead_fwd": (
                functools.partial(model.lmhead_fwd, **up),
                [spec((b, s, h)), spec((h, vp))],
            ),
            "lmhead_bwd": (
                functools.partial(model.lmhead_bwd, **up),
                [spec((b, s, h)), spec((h, vp)), spec((b, s, vp))],
            ),
        }
        # loss + MoE ops depend on the local batch only; emit once per b
        # under p=1 keys.
        if (b, 1) not in seen:
            ops_b1 = {
                "xent": (
                    functools.partial(model.xent, **up),
                    [spec((b, s, v)), spec((b, s), I32)],
                ),
            }
            if cfg.experts:
                e, fe = cfg.experts, cfg.expert_ffn
                ops_b1.update({
                    "router_fwd": (
                        functools.partial(model.router_fwd, **up),
                        [spec((b, s, h)), spec((h, e))],
                    ),
                    "router_bwd": (
                        functools.partial(model.router_bwd, **up),
                        [spec((b, s, h)), spec((h, e)), spec((b, s, e))],
                    ),
                    "moe_fwd": (
                        functools.partial(model.moe_fwd, **up),
                        [spec((b, s, h)), spec((b, s)), spec((h, fe)),
                         spec((fe,)), spec((fe, h))],
                    ),
                    "moe_bwd": (
                        functools.partial(model.moe_bwd, **up),
                        [spec((b, s, h)), spec((b, s)), spec((h, fe)),
                         spec((fe,)), spec((fe, h)), spec((b, s, h))],
                    ),
                })
            for name, (fn, args) in ops_b1.items():
                yield f"{name}__b{b}__p1", fn, args

        for name, (fn, args) in ops.items():
            key = f"{name}__b{b}__p{p}"
            if key not in seen:
                yield key, fn, args
        seen.add((b, p))
        seen.update(f"{name}__b{b}__p{p}" for name in ops)


def shaped(args):
    return [
        ["i32" if a.dtype == jnp.int32 else "f32", list(a.shape)]
        for a in args
    ]


def lower_entry(key, fn, args):
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    outs = jax.eval_shape(fn, *args)
    return text, shaped(args), shaped(list(outs))


# ---------------------------------------------------------------------------
# main build
# ---------------------------------------------------------------------------

def build(cfg: presets.ModelConfig, out_dir: str, use_pallas: bool):
    if not cfg.artifacts:
        raise SystemExit(f"preset {cfg.name} is virtual-only (no artifacts)")
    pdir = os.path.join(out_dir, cfg.name)
    os.makedirs(pdir, exist_ok=True)
    entries = []
    # The pallas build only covers the shard combos actually exercised by
    # the pallas integration test (smallest shard combo) — interpret-mode
    # lowering is slow and the pallas path is a correctness demonstration.
    instances = list(op_instances(cfg, use_pallas))
    if use_pallas:
        p_max = max(p for _, p in cfg.combos)
        keep = (f"__p{p_max}", "xent__")
        instances = [
            (k, f, a) for (k, f, a) in instances
            if any(t in k for t in keep)
        ]
    for key, fn, args in instances:
        fkey = key + ("__pallas" if use_pallas else "")
        fname = f"{fkey}.hlo.txt"
        text, ins, outs = lower_entry(key, fn, args)
        with open(os.path.join(pdir, fname), "w") as fh:
            fh.write(text)
        op, bs, ps = key.split("__")
        entries.append({
            "key": fkey,
            "op": op,
            "b": int(bs[1:]),
            "p": int(ps[1:]),
            "pallas": use_pallas,
            "file": f"{cfg.name}/{fname}",
            "inputs": ins,
            "outputs": outs,
        })
        print(f"  lowered {fkey}  ({len(text)} chars)")
    mname = "manifest_pallas.json" if use_pallas else "manifest.json"
    manifest = {
        "preset": cfg.name,
        "config": presets.as_dict(cfg),
        "entries": entries,
    }
    with open(os.path.join(pdir, mname), "w") as fh:
        json.dump(manifest, fh, indent=1)
    print(f"wrote {pdir}/{mname}: {len(entries)} artifacts")


# ---------------------------------------------------------------------------
# perf reports (L1 / L2 profiling for the §Perf pass)
# ---------------------------------------------------------------------------

def report_kernels(cfg: presets.ModelConfig):
    """L1 profile: VMEM footprint + MXU utilization per kernel/BlockSpec."""
    b = max(b for b, _ in cfg.combos) if cfg.combos else 1
    p = max(p for _, p in cfg.combos) if cfg.combos else 1
    t = b * cfg.seq
    reps = [
        kmm.report(t, cfg.hidden, 3 * cfg.hidden // p),
        kmm.report(t, cfg.hidden, cfg.ffn // p),
        kmm.report(t, cfg.ffn // p, cfg.hidden),
        kmm.report(t, cfg.hidden, cfg.vocab // p),
        kattn.report(cfg.seq, cfg.hidden // cfg.heads),
        kln.report(t, cfg.hidden),
        kxent.report(t, cfg.vocab),
    ]
    print(json.dumps({"preset": cfg.name, "kernels": reps}, indent=1))


_HLO_OP = re.compile(r"=\s+[a-z0-9\[\],\{\} ]+\s+([a-z][a-z0-9\-]*)\(")


def report_hlo(cfg: presets.ModelConfig, out_dir: str):
    """L2 profile: HLO op histogram per artifact (fusion sanity check)."""
    pdir = os.path.join(out_dir, cfg.name)
    man = json.load(open(os.path.join(pdir, "manifest.json")))
    for e in man["entries"]:
        text = open(os.path.join(out_dir, e["file"])).read()
        hist = {}
        for line in text.splitlines():
            m = _HLO_OP.search(line)
            if m:
                hist[m.group(1)] = hist.get(m.group(1), 0) + 1
        top = sorted(hist.items(), key=lambda kv: -kv[1])[:6]
        print(f"{e['key']:40s} " + " ".join(f"{k}:{n}" for k, n in top))


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--preset", default="tiny")
    ap.add_argument("--pallas", action="store_true",
                    help="lower ops through the Pallas kernels (interpret)")
    ap.add_argument("--report-kernels", action="store_true")
    ap.add_argument("--report-hlo", action="store_true")
    args = ap.parse_args()

    cfg = presets.get(args.preset)
    if args.report_kernels:
        report_kernels(cfg)
        return
    if args.report_hlo:
        report_hlo(cfg, args.out_dir)
        return
    build(cfg, args.out_dir, args.pallas)


if __name__ == "__main__":
    main()
