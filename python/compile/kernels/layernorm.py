"""Row-tiled LayerNorm Pallas kernel.

Each grid program normalizes a block of rows entirely in VMEM; H stays
un-tiled because LayerNorm needs whole-row moments (for the model sizes in
the paper H <= 2560 -> a (256, 2560) f32 block is 2.6 MB, well inside VMEM).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _ln_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    o_ref[...] = (x - mu) * jax.lax.rsqrt(var + eps) * g_ref[...] + b_ref[...]


def blocks_for(rows: int, h: int):
    return common.pick_block(rows, 256)


def layernorm(x, g, b, eps: float = 1e-5):
    """LayerNorm over the last axis. x: [..., H]."""
    *lead, h = x.shape
    x2 = x.reshape(-1, h)
    br = blocks_for(x2.shape[0], h)
    x2, r0 = common.pad_to(x2, 0, br)
    rows = x2.shape[0]

    out = pl.pallas_call(
        functools.partial(_ln_kernel, eps=eps),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, h), lambda i: (i, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, h), jnp.float32),
        interpret=True,
    )(x2, g, b)
    return out[:r0].reshape(*lead, h)


def report(rows: int, h: int) -> dict:
    br = blocks_for(rows, h)
    rep = common.kernel_report(
        "layernorm", {"x": (br, h), "g": (h,), "b": (h,), "out": (br, h)}
    )
    rep["problem"] = [rows, h]
    return rep
