"""Shared helpers for the Pallas kernels.

All kernels in this package are written TPU-shaped (blocks sized for the
128x128 MXU and a ~16MB VMEM budget) but are executed with interpret=True:
the CPU PJRT plugin cannot run Mosaic custom-calls, so interpret mode is the
correctness path and real-TPU efficiency is *estimated* from the BlockSpec
geometry (see `vmem_bytes` / `mxu_utilization`, surfaced by
`python -m compile.aot --report-kernels`).
"""

import math

# TPU geometry used for the efficiency estimates.
MXU_EDGE = 128          # systolic array edge
VMEM_BYTES = 16 * 2**20  # per-core VMEM budget
LANE = 128               # vector lane width
SUBLANE = 8              # f32 sublane packing


def next_multiple(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def pick_block(dim: int, target: int) -> int:
    """Block size for `dim`: `target` when the dim is big enough, otherwise
    the dim rounded up to a sublane multiple (tiny test shapes)."""
    if dim >= target:
        return target
    return max(1, min(dim, target))


def pad_to(x, axis: int, multiple: int, value=0.0):
    """Pad `x` along `axis` up to a multiple; returns (padded, orig_len)."""
    import jax.numpy as jnp

    n = x.shape[axis]
    m = next_multiple(n, multiple)
    if m == n:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, m - n)
    return jnp.pad(x, widths, constant_values=value), n


def vmem_bytes(*block_shapes, dtype_bytes: int = 4) -> int:
    """Total VMEM working set of one grid step (all live blocks)."""
    return sum(dtype_bytes * math.prod(s) for s in block_shapes)


def mxu_utilization(bm: int, bn: int, bk: int) -> float:
    """Fraction of the MXU's 128x128 tiles that carry real data for a
    (bm x bk) @ (bk x bn) block matmul — the TPU analogue of the paper's
    small-CUDA-kernel occupancy concern (paper §3.4.1)."""

    def eff(d):
        return d / next_multiple(d, MXU_EDGE)

    return eff(bm) * eff(bn) * min(1.0, bk / MXU_EDGE)


def kernel_report(name: str, blocks: dict, dtype_bytes: int = 4) -> dict:
    """Standard per-kernel report entry for --report-kernels."""
    vm = vmem_bytes(*blocks.values(), dtype_bytes=dtype_bytes)
    return {
        "kernel": name,
        "blocks": {k: list(v) for k, v in blocks.items()},
        "vmem_bytes": vm,
        "vmem_frac": round(vm / VMEM_BYTES, 4),
    }
