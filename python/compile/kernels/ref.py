"""Pure-jnp oracles for every Pallas kernel.

These are the CORE correctness signal for Layer 1: every kernel in this
package must agree with its oracle to float32 tolerance across the
hypothesis shape/dtype sweeps in python/tests/.
"""

import jax
import jax.numpy as jnp


def gelu(x):
    """tanh-approximate GeLU (GPT-2 convention, matches jax.nn.gelu default)."""
    return jax.nn.gelu(x, approximate=True)


def matmul_bias_act(x, w, b=None, activation="none"):
    """y = act(x @ w + b). x: [..., K], w: [K, N], b: [N] or None."""
    y = x @ w
    if b is not None:
        y = y + b
    if activation == "gelu":
        y = gelu(y)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return y


def attention(q, k, v, scale=None):
    """Multi-head causal attention.

    q, k, v: [B, NH, S, HD] -> out [B, NH, S, HD].
    """
    s = q.shape[-2]
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    logits = jnp.where(mask, logits, jnp.finfo(logits.dtype).min)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def layernorm(x, g, b, eps=1e-5):
    """LayerNorm over the last axis. x: [..., H], g/b: [H]."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def softmax_xent(logits, targets):
    """Mean cross-entropy + dlogits (already scaled by 1/T).

    logits: [T, V] float, targets: [T] int32 -> (scalar loss, dlogits [T, V]).
    """
    t = logits.shape[0]
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    nll = lse - jnp.take_along_axis(logits, targets[:, None], axis=-1)[:, 0]
    loss = jnp.mean(nll)
    probs = jax.nn.softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=logits.dtype)
    dlogits = (probs - onehot) / t
    return loss, dlogits
