"""Differentiable wrappers around the Pallas kernels.

`pallas_call` (interpret or not) has no built-in reverse-mode rule, so the
L2 `*_bwd` ops cannot `jax.vjp` through a raw kernel. Each wrapper here is a
`jax.custom_vjp` whose forward runs the Pallas kernel and whose backward
expresses its own heavy GEMMs *through the same Pallas matmul kernel* —
i.e. the hot math stays in Layer 1 in both directions. Elementwise glue
(GeLU derivative, softmax algebra) stays in jnp: it is bandwidth-trivial
and XLA fuses it anyway.

attention/layernorm backward use an analytic jnp recompute (a flash-backward
Pallas kernel is listed as an extension in DESIGN.md).
"""

import jax
import jax.numpy as jnp

from . import attention as kattn
from . import layernorm as kln
from . import matmul as kmm
from . import ref


def _dgelu(pre):
    """d/dx gelu_tanh(x) (GPT-2 tanh approximation)."""
    c = jnp.sqrt(2.0 / jnp.pi).astype(pre.dtype)
    inner = c * (pre + 0.044715 * pre**3)
    t = jnp.tanh(inner)
    dinner = c * (1.0 + 3 * 0.044715 * pre**2)
    return 0.5 * (1.0 + t) + 0.5 * pre * (1.0 - t**2) * dinner


def _mm_bwd_core(activation, x, w, b, dy):
    """Shared backward: both GEMMs dispatched to the Pallas kernel."""
    if activation == "gelu":
        # recompute the PRE-activation (including bias!) with the kernel
        pre = kmm.matmul_bias_act(x, w, b, "none")
        dpre = dy * _dgelu(pre)
    else:
        dpre = dy
    kdim = x.shape[-1]
    n = w.shape[1]
    dp2 = dpre.reshape(-1, n)
    x2 = x.reshape(-1, kdim)
    dx = kmm.matmul_bias_act(dp2, w.T, None, "none").reshape(x.shape)
    dw = kmm.matmul_bias_act(x2.T, dp2, None, "none")
    db = jnp.sum(dp2, axis=0)
    return dx, dw, db


def _make_matmul(activation: str, with_bias: bool):
    if with_bias:

        @jax.custom_vjp
        def mm(x, w, b):
            return kmm.matmul_bias_act(x, w, b, activation)

        def fwd(x, w, b):
            return kmm.matmul_bias_act(x, w, b, activation), (x, w, b)

        def bwd(res, dy):
            return _mm_bwd_core(activation, *res, dy)

        mm.defvjp(fwd, bwd)
        return mm

    @jax.custom_vjp
    def mm_nb(x, w):
        return kmm.matmul_bias_act(x, w, None, activation)

    def fwd_nb(x, w):
        return kmm.matmul_bias_act(x, w, None, activation), (x, w)

    def bwd_nb(res, dy):
        x, w = res
        dx, dw, _ = _mm_bwd_core(activation, x, w, None, dy)
        return dx, dw

    mm_nb.defvjp(fwd_nb, bwd_nb)
    return mm_nb


_MM = {
    ("none", True): _make_matmul("none", True),
    ("none", False): _make_matmul("none", False),
    ("gelu", True): _make_matmul("gelu", True),
    ("gelu", False): _make_matmul("gelu", False),
}


def matmul(x, w, b=None, activation="none"):
    """Differentiable Pallas matmul with fused bias + activation."""
    fn = _MM[(activation, b is not None)]
    return fn(x, w, b) if b is not None else fn(x, w)


@jax.custom_vjp
def attention(q, k, v):
    """Differentiable Pallas flash attention (causal)."""
    return kattn.attention(q, k, v)


def _attn_fwd(q, k, v):
    return kattn.attention(q, k, v), (q, k, v)


def _attn_bwd(res, do):
    _, vjp = jax.vjp(ref.attention, *res)
    return vjp(do)


attention.defvjp(_attn_fwd, _attn_bwd)


@jax.custom_vjp
def layernorm(x, g, b):
    """Differentiable Pallas LayerNorm."""
    return kln.layernorm(x, g, b)


def _ln_fwd(x, g, b):
    return kln.layernorm(x, g, b), (x, g, b)


def _ln_bwd(res, dy):
    _, vjp = jax.vjp(ref.layernorm, *res)
    return vjp(dy)


layernorm.defvjp(_ln_fwd, _ln_bwd)
