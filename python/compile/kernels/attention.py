"""Flash-style causal attention Pallas kernel.

Tiled online-softmax attention (the appendix of the paper points at
FlashAttention as the fix for the memory-transfer wall on fast
interconnects). One grid program owns one (batch*head, q-block); the kv
sequence is walked with `fori_loop` keeping running max / normalizer in
registers, so the full [S, S] score matrix never materializes — the HBM<->
VMEM traffic is exactly q-block + streamed kv blocks, which is the TPU
translation of FlashAttention's SRAM tiling.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int, s: int,
                 scale: float):
    """q block: [bq, HD]; k/v: [S, HD] streamed in bk chunks."""
    iq = pl.program_id(1)
    q = q_ref[0, :, :] * scale  # [bq, hd]
    hd = q.shape[-1]

    q_pos = iq * bq + jax.lax.iota(jnp.int32, bq)  # absolute q rows

    nkv = s // bk

    def body(j, carry):
        acc, m_i, l_i = carry
        k_blk = pl.load(k_ref, (0, pl.dslice(j * bk, bk), slice(None)))
        v_blk = pl.load(v_ref, (0, pl.dslice(j * bk, bk), slice(None)))
        sc = jnp.dot(q, k_blk.T, preferred_element_type=jnp.float32)
        k_pos = j * bk + jax.lax.iota(jnp.int32, bk)
        mask = q_pos[:, None] >= k_pos[None, :]
        sc = jnp.where(mask, sc, NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(sc, axis=-1))
        p = jnp.exp(sc - m_new[:, None])
        alpha = jnp.exp(m_i - m_new)
        l_new = l_i * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + jnp.dot(
            p, v_blk, preferred_element_type=jnp.float32
        )
        return acc, m_new, l_new

    acc0 = jnp.zeros((bq, hd), jnp.float32)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc, _, l_i = jax.lax.fori_loop(0, nkv, body, (acc0, m0, l0))
    # Fully-masked (padded) rows have l == 0; keep them at 0 output.
    l_safe = jnp.where(l_i == 0.0, 1.0, l_i)
    o_ref[0, :, :] = acc / l_safe[:, None]


def blocks_for(s: int, hd: int):
    bq = common.pick_block(s, 128)
    bk = common.pick_block(s, 128)
    return bq, bk


def attention(q, k, v, scale=None):
    """Causal MHA. q/k/v: [B, NH, S, HD] -> [B, NH, S, HD]."""
    b, nh, s, hd = q.shape
    if scale is None:
        scale = 1.0 / float(hd) ** 0.5
    bq, bk = blocks_for(s, hd)

    qf = q.reshape(b * nh, s, hd)
    kf = k.reshape(b * nh, s, hd)
    vf = v.reshape(b * nh, s, hd)
    # Pad S so both the q grid and the kv fori_loop walk whole blocks.
    # Padded kv rows come *after* every real q row, so the causal mask
    # already excludes them; padded q rows are sliced off below.
    qf, s0 = common.pad_to(qf, 1, bq)
    kf, _ = common.pad_to(kf, 1, bk)
    vf, _ = common.pad_to(vf, 1, bk)
    sq = qf.shape[1]
    sk = kf.shape[1]

    kernel = functools.partial(
        _attn_kernel, bq=bq, bk=bk, s=sk, scale=scale
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * nh, sq // bq),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, sk, hd), lambda h, i: (h, 0, 0)),
            pl.BlockSpec((1, sk, hd), lambda h, i: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * nh, sq, hd), jnp.float32),
        interpret=True,
    )(qf, kf, vf)

    return out[:, :s0, :].reshape(b, nh, s, hd)


def report(s: int, hd: int) -> dict:
    bq, bk = blocks_for(s, hd)
    rep = common.kernel_report(
        "flash_attention",
        {"q": (bq, hd), "k": (bk, hd), "v": (bk, hd), "acc": (bq, hd)},
    )
    rep["mxu_utilization"] = round(common.mxu_utilization(bq, bk, hd), 4)
    rep["problem"] = [s, hd]
    return rep
