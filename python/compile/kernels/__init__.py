"""Layer 1: Pallas kernels for RTP's compute hot-spots.

Every kernel has a pure-jnp oracle in `ref.py`; pytest + hypothesis sweep
shapes and assert allclose. Kernels run with interpret=True (CPU PJRT can't
execute Mosaic custom-calls); the TPU efficiency story is estimated from the
BlockSpec geometry (see common.py and DESIGN.md §3).
"""

from . import attention, common, layernorm, matmul, ref, softmax_xent

__all__ = [
    "attention",
    "common",
    "layernorm",
    "matmul",
    "ref",
    "softmax_xent",
]
