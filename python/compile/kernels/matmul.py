"""Blocked matmul Pallas kernel with fused bias + activation.

This is the partition hot-spot of RTP: every rotation step runs one
(1/N-sized) GEMM per unit, so the whole paper lives or dies on this kernel.

TPU mapping of the paper's GPU concerns (DESIGN.md §3):
  * threadblock tiling      -> BlockSpec grid over (M/bm, N/bn) with the K
                               loop innermost, accumulating in the output
                               block resident in VMEM;
  * shared-memory staging   -> HBM->VMEM block copies expressed by the
                               index_maps;
  * tensor-core WMMA        -> MXU-shaped (multiple-of-128) bm/bn/bk when
                               the operands are big enough;
  * small-kernel occupancy  -> when dout/N < 128 the MXU runs partially
                               empty; `report()` quantifies that penalty.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _mm_kernel(x_ref, w_ref, o_ref, *, nk: int, activation: str, bias):
    """One (bm, bn) output block; grid dim 2 walks the K blocks."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        acc = o_ref[...]
        if bias is not None:
            acc = acc + bias[...]
        if activation == "gelu":
            acc = jax.nn.gelu(acc, approximate=True)
        o_ref[...] = acc


def _mm_bias_kernel(x_ref, w_ref, b_ref, o_ref, *, nk: int, activation: str):
    _mm_kernel(x_ref, w_ref, o_ref, nk=nk, activation=activation, bias=b_ref)


def _mm_nobias_kernel(x_ref, w_ref, o_ref, *, nk: int, activation: str):
    _mm_kernel(x_ref, w_ref, o_ref, nk=nk, activation=activation, bias=None)


def blocks_for(m: int, k: int, n: int):
    """Block geometry: MXU-shaped when the problem is big enough."""
    bm = common.pick_block(m, 128)
    bn = common.pick_block(n, 128)
    bk = common.pick_block(k, 512)
    return bm, bk, bn


def matmul_bias_act(x, w, b=None, activation: str = "none"):
    """act(x @ w + b) as a Pallas kernel. x: [..., K], w: [K, N], b: [N]|None.

    Arbitrary shapes are handled by padding up to block multiples and
    slicing the result back (hypothesis sweeps hit ragged shapes).
    """
    *lead, kdim = x.shape
    n = w.shape[1]
    x2 = x.reshape(-1, kdim)
    m = x2.shape[0]

    bm, bk, bn = blocks_for(m, kdim, n)
    x2, m0 = common.pad_to(x2, 0, bm)
    x2, _ = common.pad_to(x2, 1, bk)
    wp, _ = common.pad_to(w, 0, bk)
    wp, n0 = common.pad_to(wp, 1, bn)
    mp, kp = x2.shape
    np_ = wp.shape[1]
    nk = kp // bk
    grid = (mp // bm, np_ // bn, nk)

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
        pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
    ]
    args = [x2, wp]
    if b is not None:
        bp, _ = common.pad_to(b, 0, bn)
        in_specs.append(pl.BlockSpec((bn,), lambda i, j, k: (j,)))
        args.append(bp)
        kernel = functools.partial(
            _mm_bias_kernel, nk=nk, activation=activation
        )
    else:
        kernel = functools.partial(
            _mm_nobias_kernel, nk=nk, activation=activation
        )

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(*args)

    return out[:m0, :n0].reshape(*lead, n)


def report(m: int, k: int, n: int) -> dict:
    """VMEM/MXU estimate for the --report-kernels perf pass."""
    bm, bk, bn = blocks_for(m, k, n)
    rep = common.kernel_report(
        "matmul_bias_act",
        {"x": (bm, bk), "w": (bk, bn), "acc": (bm, bn)},
    )
    rep["mxu_utilization"] = round(common.mxu_utilization(bm, bn, bk), 4)
    rep["problem"] = [m, k, n]
    return rep
