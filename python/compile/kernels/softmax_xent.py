"""Fused softmax cross-entropy (+ gradient) Pallas kernel.

The LM-head loss is the last memory hot-spot: logits are [T, V] with V up to
50k. The kernel fuses log-softmax, NLL gather and dlogits into one pass over
a row block, so logits are read once from HBM and probs are never
materialized separately from dlogits.

Outputs per row block: the summed NLL (one scalar per block, reduced by the
wrapper) and dlogits (already scaled by 1/T, the mean-loss convention shared
with ref.softmax_xent and the rust engines).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _xent_kernel(lg_ref, tg_ref, loss_ref, dl_ref, *, v: int, inv_t: float):
    lg = lg_ref[...]  # [br, V]
    tg = tg_ref[...]  # [br]
    m = jnp.max(lg, axis=-1, keepdims=True)
    ex = jnp.exp(lg - m)
    se = jnp.sum(ex, axis=-1, keepdims=True)
    lse = jnp.log(se) + m  # [br, 1]
    cols = jax.lax.iota(jnp.int32, v)[None, :]
    onehot = (cols == tg[:, None]).astype(lg.dtype)
    picked = jnp.sum(lg * onehot, axis=-1)
    # Padded rows carry target -1 -> onehot all-zero; mask them out of the
    # loss and gradient entirely.
    valid = (tg >= 0).astype(lg.dtype)
    nll = (lse[:, 0] - picked) * valid
    loss_ref[0] = jnp.sum(nll)
    probs = ex / se
    dl_ref[...] = (probs - onehot) * valid[:, None] * inv_t


def blocks_for(t: int, v: int):
    # §Perf L1 iteration 2: budget ~4 MB for the logits block so that
    # logits + dlogits together stay at ~50% of the 16 MB VMEM — leaving
    # room for double-buffering the next row block (the first cut used
    # 8 MB and reported 100% VMEM occupancy, no prefetch headroom).
    budget_rows = max(1, (4 * 2**20) // (4 * max(v, 1)))
    return common.pick_block(t, min(128, budget_rows))


def softmax_xent(logits, targets):
    """Mean cross-entropy. logits [T, V] f32, targets [T] i32."""
    t, v = logits.shape
    br = blocks_for(t, v)
    lg, t0 = common.pad_to(logits, 0, br)
    tg = jnp.pad(targets, (0, lg.shape[0] - t), constant_values=-1)
    rows = lg.shape[0]
    nb = rows // br

    loss_b, dl = pl.pallas_call(
        functools.partial(_xent_kernel, v=v, inv_t=1.0 / t),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((br, v), lambda i: (i, 0)),
            pl.BlockSpec((br,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((br, v), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb,), jnp.float32),
            jax.ShapeDtypeStruct((rows, v), jnp.float32),
        ],
        interpret=True,
    )(lg, tg)
    return jnp.sum(loss_b) / t, dl[:t0]


def report(t: int, v: int) -> dict:
    br = blocks_for(t, v)
    rep = common.kernel_report(
        "softmax_xent", {"logits": (br, v), "dlogits": (br, v)}
    )
    rep["problem"] = [t, v]
    return rep
