//! The end-to-end validation driver (DESIGN.md §6): train a real
//! transformer through the FULL three-layer stack — rust RTP coordinator
//! → AOT'd JAX/Pallas HLO → PJRT — on the synthetic Markov corpus, and
//! log the loss curve. The run recorded in EXPERIMENTS.md §E2E used:
//!
//!     cargo run --release --example train_e2e -- \
//!         --preset e2e-100m --engine rtp-outofplace --workers 2 \
//!         --steps 300 --exec pjrt
//!
//! Presets: `e2e-small` (~34M params, fast) and `e2e-100m` (~110M — the
//! required ~100M-parameter run; build its artifacts first with
//! `make artifacts-e2e-100m`). `--engine single|ddp|fsdp` rerun the same
//! seed for the cross-engine loss-curve equivalence check.

use rtp::cli::Args;
use rtp::config::{presets, OptimizerKind, Strategy, TrainCfg};
use rtp::parallel::{build_engine, EngineOpts, ExecKind};
use rtp::train::{train, MarkovCorpus, Optimizer};
use rtp::util::bytes::human;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let preset = args.get_or("preset", "e2e-small").to_string();
    let engine_name = args.get_or("engine", "rtp-outofplace");
    let strategy = Strategy::parse(engine_name)
        .ok_or_else(|| anyhow::anyhow!("unknown engine {engine_name:?}"))?;
    let workers = args.usize_or("workers", 2)?;
    let global_batch = args.usize_or("global-batch", 4)?;
    let exec = match args.get_or("exec", "pjrt") {
        "pjrt" => ExecKind::Pjrt,
        "pallas" => ExecKind::PjrtPallas,
        "oracle" => ExecKind::Oracle,
        other => anyhow::bail!("unknown exec {other:?}"),
    };
    let tcfg = TrainCfg {
        steps: args.usize_or("steps", 200)?,
        lr: args.f32_or("lr", 3e-4)?,
        optimizer: OptimizerKind::Adam,
        seed: args.u64_or("seed", 42)?,
        log_every: args.usize_or("log-every", 10)?,
    };

    let cfg = presets::get(&preset)
        .ok_or_else(|| anyhow::anyhow!("unknown preset {preset:?}"))?;
    println!(
        "== end-to-end run: {preset} ({} params, {}) ==",
        cfg.params_total(),
        human(cfg.weight_bytes())
    );
    let opts = EngineOpts::new(&preset, strategy, workers, global_batch)
        .exec(exec)
        .seed(tcfg.seed);
    let mut engine = build_engine(&opts)?;
    println!(
        "engine {} × {} workers, global batch {global_batch}, exec {:?}, {} steps @ lr {}",
        engine.name(),
        engine.ctx().cluster.n(),
        exec,
        tcfg.steps,
        tcfg.lr
    );

    let mut corpus = MarkovCorpus::new(&cfg, tcfg.seed);
    println!("corpus entropy floor ≈ {:.3} nats/token", corpus.entropy_floor());
    let mut opt = Optimizer::new(tcfg.optimizer, tcfg.lr);
    let report = train(&mut *engine, &mut opt, &mut corpus, &tcfg, global_batch, false)?;

    let (head, tail) = report.head_tail_means(10);
    println!("\n== result ==");
    println!("loss curve: {head:.4} (first 10) -> {tail:.4} (last 10)");
    println!(
        "wall {:.1}s, {:.0} tokens/s, peak/worker {}",
        report.wall_s,
        report.tokens_per_s,
        human(report.peak_bytes_per_worker)
    );
    // dump the curve for EXPERIMENTS.md before asserting
    let dir = rtp::bench_util::figures_dir();
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("e2e_{preset}_{engine_name}.csv"));
    let mut csv = String::from("step,loss\n");
    for (i, l) in report.losses.iter().enumerate() {
        csv.push_str(&format!("{i},{l}\n"));
    }
    std::fs::write(&path, csv)?;
    println!("loss curve written to {}", path.display());

    // the smoke assertion recorded in EXPERIMENTS.md
    anyhow::ensure!(
        tail < 0.97 * head,
        "loss did not decrease ({head:.4} -> {tail:.4})"
    );
    println!("loss decreased — all three layers compose. ✓");
    Ok(())
}
