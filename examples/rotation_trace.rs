//! Paper Figs 1-2 — the rotation schedule, traced from a REAL engine step
//! (not a mock): which worker computes which shard at each step, the
//! clockwise forward rotations, the counter-clockwise backward rotations
//! carrying gradients, and the end-of-step home invariant.
//!
//!     cargo run --release --example rotation_trace -- 4

use rtp::config::Strategy;
use rtp::parallel::{build_engine, Batch, EngineOpts, ExecKind};
use rtp::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(2);
    let opts = EngineOpts::new("tiny", Strategy::RtpInplace, n, n)
        .exec(ExecKind::Oracle)
        .trace(true);
    let cfg = opts.cfg()?;
    let mut engine = build_engine(&opts)?;
    let batch = Batch::synth(&cfg, n, &mut Rng::new(1));
    engine.step(&batch)?;

    let trace = &engine.ctx().cluster.trace;
    println!("{}", trace.render());

    // Fig-1 invariants, checked on the live trace: every (worker, shard)
    // pair appears exactly twice per unit — once in the clockwise forward
    // pass, once in the counter-clockwise backward pass ("emb" matches
    // both "emb" and "emb.bwd" events).
    for unit in ["emb", "attn.l0", "mlp.l0", "lmhead"] {
        let pairs = trace.compute_pairs(unit);
        assert_eq!(pairs.len(), 2 * n * n, "{unit}: {} pairs", pairs.len());
        for w in 0..n {
            for s in 0..n {
                assert_eq!(
                    pairs.iter().filter(|&&(pw, ps)| pw == w && ps == s).count(),
                    2,
                    "{unit}: (w{w}, shard{s})"
                );
            }
        }
    }
    // the ring fabric traces the hop schedule of every collective too:
    // the replicated-grad allreduce at the end of the step appears as its
    // full 2(N-1)-hop chunked ring schedule
    let fabric_hops = trace.fabric_hops();
    if n > 1 {
        assert_eq!(fabric_hops, 2 * (n - 1), "collective hop schedule incomplete");
    }
    println!(
        "invariants hold: {} rotation hops + {} collective ring hops, every worker \
         met every shard exactly once, all shards home, fabric drained ({} in flight).",
        trace.rotations(),
        fabric_hops,
        engine.ctx().cluster.fabric().in_flight()
    );
    Ok(())
}
