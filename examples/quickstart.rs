//! Quickstart: build an RTP engine, take a few training steps on the
//! synthetic corpus, check the loss moves, and print the memory ledger.
//!
//!     cargo run --release --example quickstart

use rtp::config::{presets, OptimizerKind, Strategy, TrainCfg};
use rtp::memory::tracker::MemCategory;
use rtp::parallel::{build_engine, EngineOpts, ExecKind};
use rtp::train::{train, MarkovCorpus, Optimizer};
use rtp::util::bytes::human;

fn main() -> anyhow::Result<()> {
    // 2-way Rotated Tensor Parallelism on the CI-sized model. Swap
    // ExecKind::Pjrt to run the AOT HLO artifacts (after `make artifacts`).
    let opts = EngineOpts::new("tiny", Strategy::RtpInplace, 2, 4).exec(ExecKind::Oracle);
    let cfg = presets::get("tiny").unwrap();
    let mut engine = build_engine(&opts)?;
    println!("engine: {} on {} workers", engine.name(), engine.ctx().cluster.n());

    let mut corpus = MarkovCorpus::new(&cfg, 42);
    let mut opt = Optimizer::new(OptimizerKind::Adam, 5e-3);
    let tcfg = TrainCfg { steps: 40, log_every: 10, ..TrainCfg::default() };
    let report = train(&mut *engine, &mut opt, &mut corpus, &tcfg, 4, false)?;

    let (head, tail) = report.head_tail_means(5);
    println!("\nloss {head:.4} -> {tail:.4} over {} steps", report.steps);
    assert!(tail < head, "loss should decrease");

    println!("\nper-worker memory at peak:");
    let t = &engine.ctx().cluster.workers[0].tracker;
    for cat in MemCategory::ALL {
        println!("  {cat:<12} {}", human(t.peak_of(cat)));
    }
    println!("  {:<12} {}", "TOTAL", human(t.peak()));
    Ok(())
}
