//! Paper-scale memory report: pick any Table-2 model and print the full
//! per-category ledger per strategy at 8 workers — the raw material of
//! Figs 8/9/12.
//!
//!     cargo run --release --example memory_report -- gpt2-xl-1.5b

use rtp::bench_util::Table;
use rtp::config::Strategy;
use rtp::memory::tracker::MemCategory;
use rtp::perfmodel::{a100_nvlink, simulate, SimSpec};
use rtp::util::bytes::human;

fn main() -> anyhow::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "gpt2-xl-1.5b".to_string());
    let mut t = Table::new(
        &format!("{model} — per-worker peak by category (N=8, global batch 8)"),
        &["strategy", "weights", "grads", "activations", "comm-buf", "TOTAL", "status"],
    );
    for strategy in Strategy::ALL {
        if strategy == Strategy::MegatronTp
            && rtp::config::presets::get(&model).map(|m| m.is_moe()).unwrap_or(false)
        {
            continue;
        }
        let workers = if strategy == Strategy::Single { 1 } else { 8 };
        let mut spec = SimSpec::new(&model, strategy, workers, 8, a100_nvlink());
        spec.enforce_capacity = false;
        let r = simulate(&spec)?;
        let of = |cat: MemCategory| {
            r.peak_by_cat
                .iter()
                .find(|(c, _)| *c == cat)
                .map(|(_, v)| human(*v))
                .unwrap_or_default()
        };
        let status = {
            let mut cap = SimSpec::new(&model, strategy, workers, 8, a100_nvlink());
            cap.enforce_capacity = true;
            match simulate(&cap)?.oom {
                Some(_) => "OOM @80GB",
                None => "fits",
            }
        };
        t.row(vec![
            strategy.to_string(),
            of(MemCategory::Weights),
            of(MemCategory::Grads),
            of(MemCategory::Activations),
            of(MemCategory::CommBuf),
            human(r.peak_per_worker),
            status.to_string(),
        ]);
    }
    t.print();
    Ok(())
}
