//! Expert-Partition showcase (paper §3.2, Fig 7): a Mixture-of-Experts
//! transformer where RTP ROTATES the experts instead of all-to-all'ing
//! the tokens. Verifies the MoE gradient path against the single-device
//! oracle, trains for a few steps, and prints the expert-rotation trace.
//!
//!     cargo run --release --example moe_rtp

use rtp::config::{presets, OptimizerKind, Strategy, TrainCfg};
use rtp::parallel::{build_engine, Batch, EngineOpts, ExecKind};
use rtp::train::{train, MarkovCorpus, Optimizer};
use rtp::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let cfg = presets::get("tiny-moe").unwrap();
    println!(
        "tiny-moe: {} experts × ffn {}, {} params total",
        cfg.experts,
        cfg.expert_ffn,
        cfg.params_total()
    );

    // 1. gradient equivalence vs the idealized computer
    let batch = Batch::synth(&cfg, 4, &mut Rng::new(5));
    let mut single = build_engine(
        &EngineOpts::new("tiny-moe", Strategy::Single, 1, 4).exec(ExecKind::Oracle),
    )?;
    let mut rtp = build_engine(
        &EngineOpts::new("tiny-moe", Strategy::RtpInplace, 2, 4).exec(ExecKind::Oracle),
    )?;
    let ls = single.step(&batch)?;
    let lr = rtp.step(&batch)?;
    println!("loss single {ls:.5} vs rtp {lr:.5}");
    rtp.gather_grads()
        .allclose(&single.gather_grads(), 2e-3)
        .map_err(|e| anyhow::anyhow!("gradient mismatch: {e}"))?;
    println!("expert-rotation gradients == single-device gradients ✓");

    // 2. the rotation trace of one MoE layer (Fig 7's dataflow)
    let opts = EngineOpts::new("tiny-moe", Strategy::RtpInplace, 2, 2)
        .exec(ExecKind::Oracle)
        .trace(true);
    let mut traced = build_engine(&opts)?;
    traced.step(&Batch::synth(&cfg, 2, &mut Rng::new(6)))?;
    println!("\nexpert rotation schedule (layer 0 forward):");
    for (w, s) in traced.ctx().cluster.trace.compute_pairs("mlp.l0") {
        println!("  worker {w} ran expert group {s}");
    }

    // 3. it learns
    let mut engine = build_engine(
        &EngineOpts::new("tiny-moe", Strategy::RtpOutOfPlace, 2, 4).exec(ExecKind::Oracle),
    )?;
    let mut corpus = MarkovCorpus::new(&cfg, 42);
    let mut opt = Optimizer::new(OptimizerKind::Adam, 5e-3);
    let tcfg = TrainCfg { steps: 30, log_every: 10, ..TrainCfg::default() };
    let r = train(&mut *engine, &mut opt, &mut corpus, &tcfg, 4, false)?;
    let (head, tail) = r.head_tail_means(5);
    println!("\nMoE training: loss {head:.4} -> {tail:.4}");
    anyhow::ensure!(tail < head, "MoE should learn");
    Ok(())
}
