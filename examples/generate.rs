//! Inference through the RTP stack: train briefly on the Markov corpus,
//! checkpoint, reload, then greedy-decode continuations and measure how
//! often the model predicts the chain's dominant successor — a
//! train→save→load→serve round trip over the same engines.
//!
//!     cargo run --release --example generate

use rtp::config::{presets, OptimizerKind, Strategy, TrainCfg};
use rtp::model::oracle;
use rtp::parallel::{build_engine, EngineOpts, ExecKind};
use rtp::serve::{build_serve_engine_with_params, GenRequest, ServeOpts};
use rtp::tensor::IntTensor;
use rtp::train::{load_params, save_params, train, MarkovCorpus, Optimizer};

fn main() -> anyhow::Result<()> {
    let cfg = presets::get("tiny").unwrap();

    // 1. train with RTP
    let mut engine = build_engine(
        &EngineOpts::new("tiny", Strategy::RtpInplace, 2, 8).exec(ExecKind::Oracle),
    )?;
    let mut corpus = MarkovCorpus::new(&cfg, 42);
    let mut opt = Optimizer::new(OptimizerKind::Adam, 5e-3);
    let tcfg = TrainCfg { steps: 60, log_every: 20, ..TrainCfg::default() };
    let report = train(&mut *engine, &mut opt, &mut corpus, &tcfg, 8, false)?;
    let (head, tail) = report.head_tail_means(5);
    println!("trained: loss {head:.3} -> {tail:.3}");

    // 2. checkpoint round trip
    let path = std::env::temp_dir().join("rtp-generate.ckpt");
    save_params(&engine.gather_params(), &path)?;
    let params = load_params(&cfg, &path)?;
    println!("checkpoint round trip via {} ✓", path.display());

    // 3. incremental greedy decoding through the serving engine: one
    //    KV-cached decode step per token instead of the old O(seq²)
    //    full re-forward per token
    let prompt_len = 4;
    let gen_len = cfg.seq - prompt_len;
    let seed_batch = corpus.next_batch(1);
    let prompt: Vec<i32> = seed_batch.ids.data[..prompt_len].to_vec();

    let sopts = ServeOpts::new("tiny")
        .strategy(Strategy::Single)
        .workers(1)
        .max_batch(1)
        .page_tokens(4);
    let mut serve = build_serve_engine_with_params(&sopts, &params)?;
    serve.submit(GenRequest { id: 0, prompt: prompt.clone(), max_new: gen_len });
    serve.drain()?;
    let generated = serve.report().finished[0].tokens.clone();
    anyhow::ensure!(generated.len() == gen_len);

    // oracle cross-check: the full-sequence re-forward argmax stream
    // (the path this example used to decode with) must match the
    // incremental stream token for token — the decode kernels replay
    // the full kernels' float order bit-exactly
    let mut ids = vec![0i32; cfg.seq];
    ids[..prompt_len].copy_from_slice(&prompt);
    let mut reference = Vec::with_capacity(gen_len);
    for pos in prompt_len..prompt_len + gen_len {
        let x = forward_logits(&params, &cfg, &ids);
        // logits at position pos-1 predict token pos
        let v = cfg.vocab;
        let row = &x[(pos - 1) * v..pos * v];
        let next = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        reference.push(next as i32);
        ids[pos] = next as i32;
    }
    anyhow::ensure!(
        generated == reference,
        "incremental KV decode diverged from the full-forward argmax stream\n  \
         kv:   {generated:?}\n  full: {reference:?}"
    );
    println!("incremental KV decode == full-forward argmax stream ({gen_len} tokens) ✓");

    // compare against the chain's dominant successor
    let mut hits = 0;
    for (i, &tok) in generated.iter().enumerate() {
        let prev = if i == 0 { prompt[prompt_len - 1] } else { generated[i - 1] };
        if tok as usize == corpus.dominant_successor(prev as usize) {
            hits += 1;
        }
    }
    let acc = hits as f64 / gen_len as f64;
    println!(
        "greedy decode: {hits}/{gen_len} steps predicted the chain's dominant \
         successor ({:.0}%)",
        acc * 100.0
    );
    anyhow::ensure!(
        acc > 0.5,
        "a trained model should usually follow the dominant transition"
    );
    std::fs::remove_file(path).ok();
    Ok(())
}

/// Full forward to logits using the oracle ops (inference path).
fn forward_logits(
    params: &rtp::model::ModelParams,
    cfg: &rtp::config::ModelCfg,
    ids: &[i32],
) -> Vec<f32> {
    use rtp::model::MlpParams;
    let idt = IntTensor::from_vec(&[1, cfg.seq], ids.to_vec());
    let mut x = oracle::emb_fwd(&idt, &params.wte, &params.wpe);
    for lp in &params.layers {
        let a = oracle::ln_fwd(&x, &lp.ln1_g, &lp.ln1_b);
        let mut part = oracle::attn_fwd(&a, &lp.wqkv, &lp.bqkv, &lp.wo, cfg.heads);
        part.add_row_broadcast(&lp.bo);
        part.add_assign(&x);
        let m = oracle::ln_fwd(&part, &lp.ln2_g, &lp.ln2_b);
        let (w1, b1, w2, b2) = match &lp.mlp {
            MlpParams::Dense { w1, b1, w2, b2 } => (w1, b1, w2, b2),
            _ => panic!("generate uses the dense preset"),
        };
        let mut mo = oracle::mlp_fwd(&m, w1, b1, w2);
        mo.add_row_broadcast(b2);
        mo.add_assign(&part);
        x = mo;
    }
    let xf = oracle::ln_fwd(&x, &params.lnf_g, &params.lnf_b);
    oracle::lmhead_fwd(&xf, &params.wlm).data
}
