//! The AOT path end-to-end: engines running real HLO artifacts on the
//! PJRT CPU client must match the pure-rust oracle — per-op AND through a
//! whole fwd+bwd step — including the Pallas-kernel artifact set.
//!
//! These tests require `make artifacts` (skipped gracefully otherwise so
//! `cargo test` works on a fresh checkout).

use rtp::config::{presets, Strategy};
use rtp::model::ops::{self, Op};
use rtp::parallel::{build_engine, Batch, EngineOpts, ExecKind};
use rtp::runtime::{artifacts_root, ArgRef, Buf, Exec, PjrtRuntime};
use rtp::tensor::{HostTensor, IntTensor};
use rtp::util::rng::Rng;

fn have_artifacts(preset: &str) -> bool {
    let ok = artifacts_root().join(preset).join("manifest.json").exists();
    if !ok {
        eprintln!("skipping: no artifacts for {preset} (run `make artifacts`)");
    }
    ok
}

/// Random real args for an op instance per the catalog shapes.
fn synth_args(
    op: Op,
    cfg: &rtp::config::ModelCfg,
    b: usize,
    p: usize,
    rng: &mut Rng,
) -> Vec<Buf> {
    ops::input_shapes(op, cfg, b, p)
        .into_iter()
        .map(|(dt, shape)| match dt {
            ops::DType::F32 => Buf::Real(HostTensor::randn(&shape, 0.5, rng)),
            ops::DType::I32 => {
                Buf::Ids(IntTensor::rand_below(&shape, cfg.vocab as i32, rng))
            }
        })
        .collect()
}

/// Every artifact in the tiny manifest must agree with the oracle.
#[test]
fn every_tiny_artifact_matches_oracle() {
    if !have_artifacts("tiny") {
        return;
    }
    let cfg = presets::get("tiny").unwrap();
    let mut pjrt = Exec::Pjrt(Box::new(
        PjrtRuntime::new(&artifacts_root(), "tiny").unwrap(),
    ));
    let mut oracle = Exec::Oracle;
    let mut rng = Rng::new(31);
    let mut checked = 0;
    // iterate the catalog over the combos the preset promises
    for (b, p) in [(4, 1), (2, 1), (1, 1), (2, 2), (1, 4), (4, 2), (4, 4)] {
        for op in Op::ALL {
            if matches!(op, Op::RouterFwd | Op::RouterBwd | Op::MoeFwd | Op::MoeBwd) {
                continue; // tiny is dense
            }
            let args = synth_args(op, &cfg, b, p, &mut rng);
            let argrefs: Vec<ArgRef> = args.iter().map(|a| a.arg()).collect();
            let want = oracle.call(op, &cfg, b, p, &argrefs).unwrap();
            let got = pjrt.call(op, &cfg, b, p, &argrefs).unwrap();
            assert_eq!(want.len(), got.len(), "{op} b{b} p{p}");
            for (wb, gb) in want.iter().zip(&got) {
                let (w, g) = (wb.f(), gb.f());
                assert!(
                    g.allclose(w, 5e-4),
                    "{op} b{b} p{p}: max diff {}",
                    g.max_abs_diff(w)
                );
            }
            checked += 1;
        }
    }
    assert!(checked >= 70, "only {checked} instances checked");
}

/// MoE artifacts vs oracle.
#[test]
fn moe_artifacts_match_oracle() {
    if !have_artifacts("tiny-moe") {
        return;
    }
    let cfg = presets::get("tiny-moe").unwrap();
    let mut pjrt = Exec::Pjrt(Box::new(
        PjrtRuntime::new(&artifacts_root(), "tiny-moe").unwrap(),
    ));
    let mut oracle = Exec::Oracle;
    let mut rng = Rng::new(33);
    for op in [Op::RouterFwd, Op::RouterBwd, Op::MoeFwd, Op::MoeBwd] {
        for b in [1, 2, 4] {
            let args = synth_args(op, &cfg, b, 1, &mut rng);
            let argrefs: Vec<ArgRef> = args.iter().map(|a| a.arg()).collect();
            let want = oracle.call(op, &cfg, b, 1, &argrefs).unwrap();
            let got = pjrt.call(op, &cfg, b, 1, &argrefs).unwrap();
            for (wb, gb) in want.iter().zip(&got) {
                assert!(
                    gb.f().allclose(wb.f(), 5e-4),
                    "{op} b{b}: max diff {}",
                    gb.f().max_abs_diff(wb.f())
                );
            }
        }
    }
}

/// Full engine step on PJRT == oracle step, for every strategy.
#[test]
fn engine_step_pjrt_matches_oracle() {
    if !have_artifacts("tiny") {
        return;
    }
    let cfg = presets::get("tiny").unwrap();
    let batch = Batch::synth(&cfg, 4, &mut Rng::new(41));
    for (strategy, n) in [
        (Strategy::Single, 1),
        (Strategy::Ddp, 2),
        (Strategy::Fsdp, 2),
        (Strategy::MegatronTp, 2),
        (Strategy::RtpInplace, 2),
        (Strategy::RtpInplace, 4),
        (Strategy::RtpOutOfPlace, 4),
    ] {
        let mut a = build_engine(
            &EngineOpts::new("tiny", strategy, n, 4).exec(ExecKind::Oracle),
        )
        .unwrap();
        let mut b = build_engine(
            &EngineOpts::new("tiny", strategy, n, 4).exec(ExecKind::Pjrt),
        )
        .unwrap();
        let la = a.step(&batch).unwrap();
        let lb = b.step(&batch).unwrap();
        assert!(
            (la - lb).abs() < 1e-3 * la.abs().max(1.0),
            "{strategy} N={n}: loss {la} (oracle) vs {lb} (pjrt)"
        );
        b.gather_grads()
            .allclose(&a.gather_grads(), 2e-3)
            .unwrap_or_else(|e| panic!("{strategy} N={n} pjrt vs oracle grads: {e}"));
    }
}

/// The Pallas-kernel artifact set (interpret-mode lowering of the L1
/// kernels) must agree with the oracle through a full RTP step.
#[test]
fn rtp_step_through_pallas_kernels_matches_oracle() {
    if !have_artifacts("tiny") {
        return;
    }
    let cfg = presets::get("tiny").unwrap();
    let batch = Batch::synth(&cfg, 4, &mut Rng::new(43));
    let mut a = build_engine(
        &EngineOpts::new("tiny", Strategy::RtpInplace, 4, 4).exec(ExecKind::Oracle),
    )
    .unwrap();
    let mut b = build_engine(
        &EngineOpts::new("tiny", Strategy::RtpInplace, 4, 4).exec(ExecKind::PjrtPallas),
    )
    .unwrap();
    let la = a.step(&batch).unwrap();
    let lb = b.step(&batch).unwrap();
    assert!(
        (la - lb).abs() < 1e-3 * la.abs().max(1.0),
        "pallas loss {lb} vs oracle {la}"
    );
    b.gather_grads()
        .allclose(&a.gather_grads(), 2e-3)
        .unwrap_or_else(|e| panic!("pallas vs oracle grads: {e}"));
}

/// The e2e-small artifact set loads and one RTP step runs.
#[test]
fn e2e_small_pjrt_step_runs() {
    if !have_artifacts("e2e-small") {
        return;
    }
    let cfg = presets::get("e2e-small").unwrap();
    let batch = Batch::synth(&cfg, 4, &mut Rng::new(44));
    let mut e = build_engine(
        &EngineOpts::new("e2e-small", Strategy::RtpInplace, 2, 4).exec(ExecKind::Pjrt),
    )
    .unwrap();
    let loss = e.step(&batch).unwrap();
    // untrained model: loss ≈ ln(vocab)
    let expect = (cfg.vocab as f32).ln();
    assert!(
        (loss - expect).abs() < 1.0,
        "initial loss {loss}, expected ≈ ln(V) = {expect}"
    );
}
