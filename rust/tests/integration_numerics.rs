//! Engine equivalence — the strongest correctness statement in the repo:
//! every distributed engine (DDP, FSDP, Megatron-TP, RTP in-place and
//! out-of-place, at N ∈ {1, 2, 4}) must produce the SAME loss and the
//! SAME fully-reduced gradients as the single-device idealized computer,
//! to f32 tolerance, for both the dense and the MoE model — first against
//! the pure-rust oracle executor, then (in integration_runtime.rs)
//! against the AOT PJRT artifacts.

use rtp::config::Strategy;
use rtp::parallel::{build_engine, Batch, EngineOpts, ExecKind};
use rtp::util::rng::Rng;

const TOL: f32 = 2e-3;

fn batch(preset: &str, global: usize, seed: u64) -> Batch {
    let cfg = rtp::config::presets::get(preset).unwrap();
    Batch::synth(&cfg, global, &mut Rng::new(seed))
}

fn check_equivalence(preset: &str, strategy: Strategy, workers: usize, exec: ExecKind) {
    let global = 4;
    let b = batch(preset, global, 7);

    let mut oracle = build_engine(
        &EngineOpts::new(preset, Strategy::Single, 1, global).exec(exec),
    )
    .unwrap();
    let loss_ref = oracle.step(&b).unwrap();
    let grads_ref = oracle.gather_grads();

    let mut eng =
        build_engine(&EngineOpts::new(preset, strategy, workers, global).exec(exec)).unwrap();
    let loss = eng.step(&b).unwrap();
    assert!(
        (loss - loss_ref).abs() <= TOL * loss_ref.abs().max(1.0),
        "{strategy} N={workers}: loss {loss} vs single {loss_ref}"
    );
    let grads = eng.gather_grads();
    grads.allclose(&grads_ref, TOL).unwrap_or_else(|e| {
        panic!("{strategy} N={workers}: gradient mismatch: {e}")
    });

    // params must also reassemble exactly (same init partitioned back)
    let params = eng.gather_params();
    params
        .allclose(&oracle.gather_params(), 1e-6)
        .unwrap_or_else(|e| panic!("{strategy} N={workers}: param mismatch: {e}"));

    // no leaked transient buffers
    assert_eq!(
        eng.ctx().cluster.outstanding(),
        eng.ctx().cluster.n() * expected_persistent(strategy),
        "{strategy} N={workers}: leaked allocations"
    );
}

/// Persistent allocations per worker: weights + grads (+ RTP-oop comm buf).
fn expected_persistent(strategy: Strategy) -> usize {
    match strategy {
        Strategy::RtpOutOfPlace => 3,
        _ => 2,
    }
}

#[test]
fn ddp_matches_single_oracle() {
    for n in [1, 2, 4] {
        check_equivalence("tiny", Strategy::Ddp, n, ExecKind::Oracle);
    }
}

#[test]
fn fsdp_matches_single_oracle() {
    for n in [1, 2, 4] {
        check_equivalence("tiny", Strategy::Fsdp, n, ExecKind::Oracle);
    }
}

#[test]
fn megatron_tp_matches_single_oracle() {
    for n in [1, 2, 4] {
        check_equivalence("tiny", Strategy::MegatronTp, n, ExecKind::Oracle);
    }
}

#[test]
fn rtp_inplace_matches_single_oracle() {
    for n in [1, 2, 4] {
        check_equivalence("tiny", Strategy::RtpInplace, n, ExecKind::Oracle);
    }
}

#[test]
fn rtp_outofplace_matches_single_oracle() {
    for n in [1, 2, 4] {
        check_equivalence("tiny", Strategy::RtpOutOfPlace, n, ExecKind::Oracle);
    }
}

#[test]
fn moe_engines_match_single_oracle() {
    for strategy in [
        Strategy::Ddp,
        Strategy::Fsdp,
        Strategy::RtpInplace,
        Strategy::RtpOutOfPlace,
    ] {
        for n in [2, 4] {
            check_equivalence("tiny-moe", strategy, n, ExecKind::Oracle);
        }
    }
}

#[test]
fn rtp_inplace_equals_outofplace_bitwise() {
    // The two variants run the same arithmetic in the same order — they
    // must agree exactly, not just within tolerance.
    let b = batch("tiny", 4, 9);
    let mut a = build_engine(
        &EngineOpts::new("tiny", Strategy::RtpInplace, 4, 4).exec(ExecKind::Oracle),
    )
    .unwrap();
    let mut o = build_engine(
        &EngineOpts::new("tiny", Strategy::RtpOutOfPlace, 4, 4).exec(ExecKind::Oracle),
    )
    .unwrap();
    let la = a.step(&b).unwrap();
    let lo = o.step(&b).unwrap();
    assert_eq!(la, lo);
    assert_eq!(a.gather_grads().max_abs_diff(&o.gather_grads()), 0.0);
}

#[test]
fn grads_accumulate_across_steps() {
    // two steps without zero_grads == sum of the two single-step grads
    let b1 = batch("tiny", 4, 11);
    let b2 = batch("tiny", 4, 12);
    for strategy in [Strategy::Ddp, Strategy::RtpInplace, Strategy::Fsdp] {
        let opts = EngineOpts::new("tiny", strategy, 2, 4).exec(ExecKind::Oracle);
        let mut e1 = build_engine(&opts).unwrap();
        e1.step(&b1).unwrap();
        let g1 = e1.gather_grads();
        e1.step(&b2).unwrap();
        let g12 = e1.gather_grads();

        let mut e2 = build_engine(&opts).unwrap();
        e2.step(&b2).unwrap();
        let g2 = e2.gather_grads();

        let mut sum = g1.clone();
        sum.axpy(1.0, &g2);
        sum.allclose(&g12, 1e-4)
            .unwrap_or_else(|e| panic!("{strategy}: accumulation broken: {e}"));
    }
}

#[test]
fn zero_grads_resets() {
    let b = batch("tiny", 4, 13);
    let mut e = build_engine(
        &EngineOpts::new("tiny", Strategy::RtpInplace, 2, 4).exec(ExecKind::Oracle),
    )
    .unwrap();
    e.step(&b).unwrap();
    e.zero_grads();
    let z = e.gather_grads();
    let mut max = 0.0f32;
    z.visit(&mut |_, t| {
        for v in &t.data {
            max = max.max(v.abs());
        }
    });
    assert_eq!(max, 0.0);
}
