//! Memory accounting cross-checks: the MEASURED virtual-mode peaks must
//! obey the paper's Table-1 structure — per-strategy ordering, the
//! duplication formulas (whole-model FSDP granularity reproduces the
//! table exactly), and the near-ideal claim for RTP.

use rtp::config::{presets, Strategy};
use rtp::memory::analytic::{per_worker_expected, table1_row};
use rtp::memory::tracker::MemCategory;
use rtp::parallel::fsdp::Granularity;
use rtp::parallel::{build_engine, Batch, EngineOpts, ExecKind};
use rtp::tensor::IntTensor;

/// One virtual step; returns (max peak/worker, total peak).
fn measure(preset: &str, strategy: Strategy, n: usize, batch: usize) -> (u64, u64) {
    measure_opts(
        EngineOpts::new(preset, strategy, n, batch).exec(ExecKind::Virtual),
        batch,
    )
}

fn measure_opts(opts: EngineOpts, batch: usize) -> (u64, u64) {
    let cfg = opts.cfg().unwrap();
    let mut e = build_engine(&opts).unwrap();
    let b = Batch {
        ids: IntTensor::zeros(&[batch, cfg.seq]),
        targets: IntTensor::zeros(&[batch, cfg.seq]),
    };
    e.step(&b).unwrap();
    (e.ctx().cluster.max_peak(), e.ctx().cluster.total_peak())
}

const PRESET: &str = "gpt2-500m";
const N: usize = 8;
const BATCH: usize = 8; // local batch 1, the Fig-8 setting

fn awg() -> (u64, u64, u64) {
    let cfg = presets::get(PRESET).unwrap();
    let w = cfg.weight_bytes();
    (BATCH as u64 * cfg.activation_bytes_per_sample(), w, w)
}

#[test]
fn strategy_peak_ordering_matches_table1() {
    let rtp_in = measure(PRESET, Strategy::RtpInplace, N, BATCH).0;
    let rtp_out = measure(PRESET, Strategy::RtpOutOfPlace, N, BATCH).0;
    let fsdp = measure(PRESET, Strategy::Fsdp, N, BATCH).0;
    let ddp = measure(PRESET, Strategy::Ddp, N, BATCH).0;
    assert!(rtp_in <= rtp_out, "in {rtp_in} out {rtp_out}");
    assert!(rtp_out < fsdp, "out {rtp_out} fsdp {fsdp}");
    assert!(fsdp < ddp, "fsdp {fsdp} ddp {ddp}");
}

#[test]
fn whole_model_fsdp_matches_table1_formula() {
    // With Granularity::Model, FSDP's measured per-worker peak must land
    // on the analytic row: A/N + (W+G)/N + max(W,G)·(N-1)/N (+ staging).
    let (a, w, g) = awg();
    let measured = measure_opts(
        EngineOpts::new(PRESET, Strategy::Fsdp, N, BATCH)
            .exec(ExecKind::Virtual)
            .fsdp_granularity(Granularity::Model),
        BATCH,
    )
    .0;
    let expected = per_worker_expected(Strategy::Fsdp, a, w, g, N as u64);
    // the full-model grad staging buffer adds one more max(W,G); allow
    // [expected, expected + max(W,G) + 10% slack]
    assert!(
        measured as f64 >= expected as f64 * 0.9,
        "measured {measured} << analytic {expected}"
    );
    // +20% slack for the activation-gradient transients (dlogits, dx)
    // the closed-form row does not model
    assert!(
        (measured as f64) <= (expected + w.max(g)) as f64 * 1.2,
        "measured {measured} >> analytic {expected} + staging"
    );
}

#[test]
fn rtp_inplace_peak_is_near_ideal_over_n() {
    // The paper's headline: RTP-inplace per-worker ≈ (A + W + G)/N.
    let (a, w, g) = awg();
    let measured = measure(PRESET, Strategy::RtpInplace, N, BATCH).0;
    let ideal = per_worker_expected(Strategy::RtpInplace, a, w, g, N as u64);
    let ratio = measured as f64 / ideal as f64;
    assert!(
        (0.8..1.35).contains(&ratio),
        "measured {measured} vs ideal/N {ideal} (ratio {ratio:.2})"
    );
}

#[test]
fn rtp_outofplace_duplication_is_one_extra_buffer() {
    // Table 1: RTP(out) − RTP(in) system-wide ≈ one unit-shard comm
    // buffer per worker — far below max(W,G)·(N-1) (FSDP).
    let rtp_in = measure(PRESET, Strategy::RtpInplace, N, BATCH).1;
    let rtp_out = measure(PRESET, Strategy::RtpOutOfPlace, N, BATCH).1;
    let fsdp = measure(PRESET, Strategy::Fsdp, N, BATCH).1;
    let dup_out = rtp_out - rtp_in;
    let dup_fsdp = fsdp - rtp_in;
    assert!(dup_out > 0);
    assert!(
        (dup_out as f64) < 0.25 * dup_fsdp as f64,
        "RTP-oop dup {dup_out} not << FSDP dup {dup_fsdp}"
    );
}

#[test]
fn ddp_peak_matches_replica_formula() {
    let (a, w, g) = awg();
    let measured = measure(PRESET, Strategy::Ddp, N, BATCH).0;
    let expected = per_worker_expected(Strategy::Ddp, a, w, g, N as u64);
    let ratio = measured as f64 / expected as f64;
    assert!((0.8..1.25).contains(&ratio), "ddp ratio {ratio:.3}");
}

#[test]
fn tp_replicates_activations() {
    // Megatron-TP's activation residency must scale with the FULL batch
    // while RTP's scales with batch/N.
    let cfg = presets::get(PRESET).unwrap();
    let measure_acts = |strategy| {
        let opts =
            EngineOpts::new(PRESET, strategy, N, BATCH).exec(ExecKind::Virtual);
        let mut e = build_engine(&opts).unwrap();
        let b = Batch {
            ids: IntTensor::zeros(&[BATCH, cfg.seq]),
            targets: IntTensor::zeros(&[BATCH, cfg.seq]),
        };
        e.step(&b).unwrap();
        e.ctx().cluster.workers[0].tracker.peak_of(MemCategory::Activations)
    };
    let tp = measure_acts(Strategy::MegatronTp);
    let rtp = measure_acts(Strategy::RtpInplace);
    let ratio = tp as f64 / rtp as f64;
    assert!(
        ratio > 0.6 * N as f64,
        "TP activations only {ratio:.1}× RTP's (expected ≈{N}×)"
    );
}

#[test]
fn moe_rtp_shards_expert_weights() {
    let n = 8;
    let moe_rtp = measure("gpt2-500m-moe", Strategy::RtpInplace, n, 8).0;
    let moe_ddp = measure("gpt2-500m-moe", Strategy::Ddp, n, 8).0;
    // DDP replicates all experts; RTP holds 1/N of them
    assert!(
        (moe_ddp as f64) > 3.0 * moe_rtp as f64,
        "ddp {moe_ddp} vs rtp {moe_rtp}"
    );
}

#[test]
fn analytic_duplication_consistent_with_measured_deltas() {
    // Fig 9 shape: total-system duplication over the single-device ideal
    // orders RTP-in < RTP-out << FSDP < DDP, matching the Table-1 rows.
    let (a, w, g) = awg();
    let single = per_worker_expected(Strategy::Single, a, w, g, 1);
    let mut last = 0u64;
    for strategy in [
        Strategy::RtpInplace,
        Strategy::RtpOutOfPlace,
        Strategy::Fsdp,
        Strategy::Ddp,
    ] {
        let total = measure(PRESET, strategy, N, BATCH).1;
        let dup = total.saturating_sub(single);
        assert!(dup >= last, "{strategy}: dup {dup} < previous {last}");
        last = dup;
        // and the analytic table agrees on the ordering
        let row = table1_row(strategy, a, w, g, N as u64);
        assert!(row.duplication < 2 * (a + w + g) * N as u64);
    }
}

#[test]
fn rtp_recycle_reduces_peak() {
    // §3.4.4 ablation: recycling the rotation buffer into the loss
    // activations must not increase the peak (it helps when the logits
    // window is the peak).
    let with = measure_opts(
        EngineOpts::new(PRESET, Strategy::RtpOutOfPlace, N, BATCH)
            .exec(ExecKind::Virtual)
            .rtp_recycle(true),
        BATCH,
    )
    .0;
    let without = measure_opts(
        EngineOpts::new(PRESET, Strategy::RtpOutOfPlace, N, BATCH)
            .exec(ExecKind::Virtual)
            .rtp_recycle(false),
        BATCH,
    )
    .0;
    assert!(with <= without, "recycle {with} > no-recycle {without}");
}

#[test]
fn real_and_virtual_mode_track_identically() {
    // The core design claim (DESIGN.md §4): the allocation schedule is a
    // property of the engine code, not the storage mode.
    for strategy in [Strategy::RtpInplace, Strategy::Ddp, Strategy::Fsdp] {
        let cfg = presets::get("tiny").unwrap();
        let batch = Batch::synth(&cfg, 4, &mut rtp::util::rng::Rng::new(3));
        let peak_of = |exec: ExecKind| {
            let mut e = build_engine(
                &EngineOpts::new("tiny", strategy, 2, 4).exec(exec),
            )
            .unwrap();
            e.step(&batch).unwrap();
            e.ctx().cluster.max_peak()
        };
        let virt = peak_of(ExecKind::Virtual);
        let real = peak_of(ExecKind::Oracle);
        assert_eq!(virt, real, "{strategy}: virtual {virt} != real {real}");
    }
}
