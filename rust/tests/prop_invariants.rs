//! Property suites over the whole stack: rotation-schedule invariants on
//! the real engine trace, collective algebra, flat-param round-trips,
//! tracker accounting, and timeline consistency — randomized via the
//! seeded prop harness (replay with PROP_SEED).

use rtp::cluster::TraceEvent;
use rtp::comm::{self, reference, RingFabric, RotationDir};
use rtp::config::Strategy;
use rtp::flat_param::FlatLayout;
use rtp::memory::tracker::{MemCategory, MemTracker};
use rtp::model::ops::{op_cost, Op};
use rtp::parallel::{build_engine, Batch, EngineOpts, ExecKind};
use rtp::perfmodel::{a100_nvlink, Timeline};
use rtp::tensor::IntTensor;
use rtp::util::prop;
use rtp::util::rng::Rng;

/// Run one traced virtual RTP step and return the trace events.
fn traced_step(preset: &str, n: usize) -> Vec<TraceEvent> {
    let opts = EngineOpts::new(preset, Strategy::RtpInplace, n, n)
        .exec(ExecKind::Virtual)
        .trace(true);
    let cfg = opts.cfg().unwrap();
    let mut e = build_engine(&opts).unwrap();
    let b = Batch {
        ids: IntTensor::zeros(&[n, cfg.seq]),
        targets: IntTensor::zeros(&[n, cfg.seq]),
    };
    e.step(&b).unwrap();
    std::mem::take(&mut e.ctx_mut().cluster.trace.events)
}

#[test]
fn prop_every_worker_computes_every_shard_exactly_once_per_unit() {
    prop::check("rtp coverage", 6, |rng| {
        let n = [1, 2, 4][rng.below(3)];
        let events = traced_step("tiny", n);
        // group compute events by unit name
        let mut units: std::collections::BTreeMap<String, Vec<(usize, usize)>> =
            Default::default();
        for ev in &events {
            if let TraceEvent::Compute { worker, unit, shard, .. } = ev {
                units.entry(unit.clone()).or_default().push((*worker, *shard));
            }
        }
        if units.is_empty() {
            return Err("no compute events traced".into());
        }
        for (unit, pairs) in units {
            let mut seen = vec![vec![0usize; n]; n];
            for (w, s) in pairs {
                seen[w][s] += 1;
            }
            for w in 0..n {
                for s in 0..n {
                    if seen[w][s] != 1 {
                        return Err(format!(
                            "unit {unit} n={n}: worker {w} saw shard {s} {}×",
                            seen[w][s]
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_rotation_count_is_per_unit_n_minus_1() {
    prop::check("rtp rotation count", 6, |rng| {
        let n = [1, 2, 4][rng.below(3)];
        let events = traced_step("tiny", n);
        let rotations = events
            .iter()
            .filter(|e| matches!(e, TraceEvent::Rotate { .. }))
            .count();
        // units that rotate: emb, L× (attn + mlp), lmhead — each fwd+bwd
        let cfg = rtp::config::presets::get("tiny").unwrap();
        let units = 2 * (1 + 2 * cfg.layers + 1);
        let expect = units * (n - 1);
        if rotations != expect {
            return Err(format!("n={n}: {rotations} rotations, expected {expect}"));
        }
        Ok(())
    });
}

#[test]
fn prop_traced_step_exposes_collective_hops() {
    // the replicated-grad allreduce at the end of an RTP step must appear
    // in the trace as its full 2(N-1)-hop schedule
    prop::check("per-hop trace", 4, |rng| {
        let n = [2, 4][rng.below(2)];
        let events = traced_step("tiny", n);
        let hops = events
            .iter()
            .filter(|e| matches!(e, TraceEvent::Hop { .. }))
            .count();
        let want = 2 * (n - 1);
        if hops != want {
            return Err(format!("n={n}: {hops} hop events, expected {want}"));
        }
        // hop indices must form the complete schedule 0..2(N-1)
        for e in &events {
            if let TraceEvent::Hop { hop, of, .. } = e {
                if *of != want || *hop >= *of {
                    return Err(format!("bad hop event {hop}/{of}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_collectives_algebra() {
    prop::check("collective algebra", 80, |rng| {
        let n = 1 + rng.below(6);
        let len = n * (1 + rng.below(6));
        let mut r = Rng::new(rng.next_u64());
        let bufs: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..len).map(|_| r.normal() as f32).collect())
            .collect();
        let fab = RingFabric::new(n);
        let root = rng.below(n);
        // every rank runs its own side: allreduce == allgather(reduce_
        // scatter), broadcast copies root everywhere
        let out = comm::spmd(&fab, |port| {
            let w = port.rank();
            let mut ar = bufs[w].clone();
            comm::allreduce_sum(&port, &mut ar);
            let rs = comm::reduce_scatter(&port, &bufs[w]);
            let ag = comm::allgather(&port, &rs);
            let mut bc = bufs[w].clone();
            comm::broadcast(&port, &mut bc, root);
            (ar, ag, bc)
        });
        let ar0 = &out[0].0;
        for (_, ag, bc) in &out {
            prop::close(ag, ar0, 1e-4)?;
            prop::close(bc, &bufs[root], 0.0)?;
        }
        if fab.in_flight() != 0 {
            return Err("fabric not drained after collectives".into());
        }
        Ok(())
    });
}

#[test]
fn prop_ring_collectives_match_god_view_references() {
    // The tentpole equivalence: every chunked ring collective must agree
    // with the one-shot god-view reference (kept only as a test oracle)
    // for random N and lengths.
    prop::check("ring == reference", 80, |rng| {
        let n = 1 + rng.below(8);
        let mut r = Rng::new(rng.next_u64());
        let fab = RingFabric::new(n);

        // allreduce: any length, including 0 and < n
        let len = rng.below(40);
        let bufs: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..len).map(|_| r.normal() as f32).collect())
            .collect();
        // reduce-scatter + all-to-all need divisible lengths
        let dlen = n * rng.below(6);
        let dbufs: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..dlen).map(|_| r.normal() as f32).collect())
            .collect();
        // allgather tolerates ragged shards
        let shards: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let l = rng.below(6);
                (0..l).map(|_| r.normal() as f32).collect()
            })
            .collect();

        let mut want = bufs.clone();
        reference::allreduce_sum(&mut want);
        let want_rs = reference::reduce_scatter(&dbufs);
        let want_a2a = reference::all_to_all(&dbufs);
        let want_ag = reference::allgather(&shards);

        let out = comm::spmd(&fab, |port| {
            let w = port.rank();
            let mut ar = bufs[w].clone();
            comm::allreduce_sum(&port, &mut ar);
            let rs = comm::reduce_scatter(&port, &dbufs[w]);
            let a2a = comm::all_to_all(&port, &dbufs[w]);
            let ag = comm::allgather(&port, &shards[w]);
            (ar, rs, a2a, ag)
        });
        for (w, (ar, rs, a2a, ag)) in out.iter().enumerate() {
            prop::close(ar, &want[w], 1e-4)?;
            prop::close(rs, &want_rs[w], 1e-4)?;
            prop::close(a2a, &want_a2a[w], 0.0)?;
            prop::close(ag, &want_ag, 0.0)?;
        }

        if fab.in_flight() != 0 {
            return Err("fabric not drained".into());
        }
        Ok(())
    });
}

#[test]
fn prop_fabric_rotation_round_trips_and_tracks_shard_at() {
    // N-1 rotations in each direction form the forward/backward halves of
    // a round trip: after N-1 cw hops followed by N-1 ccw hops every
    // payload is home, and at every intermediate t the placement matches
    // comm::shard_at.
    prop::check("rotation round trip", 80, |rng| {
        let n = 1 + rng.below(8);
        let fab = RingFabric::new(n);
        for dir in [RotationDir::Clockwise, RotationDir::CounterClockwise] {
            // each rank tracks the shard id it holds through its own port
            let results = comm::spmd(&fab, |port| {
                let w = port.rank();
                let mut held = w;
                for t in 1..n {
                    held = comm::rotate_ring(&port, held, dir);
                    let want = comm::shard_at(dir, w, t, n);
                    if held != want {
                        return Err(format!(
                            "{dir:?} n={n} t={t} w={w}: got {held} want {want}"
                        ));
                    }
                }
                // N-1 hops back in the mirror direction must return home
                let back = match dir {
                    RotationDir::Clockwise => RotationDir::CounterClockwise,
                    RotationDir::CounterClockwise => RotationDir::Clockwise,
                };
                for _ in 1..n {
                    held = comm::rotate_ring(&port, held, back);
                }
                if held != w {
                    return Err(format!("{dir:?} n={n} w={w}: round trip broken: {held}"));
                }
                Ok(())
            });
            for r in results {
                r?;
            }
        }
        if fab.in_flight() != 0 {
            return Err("fabric not drained".into());
        }
        Ok(())
    });
}

#[test]
fn prop_fabric_message_conservation() {
    // hop accounting: a ring allreduce is exactly 2(N-1) hops of N
    // rank-messages each; every message sent is delivered.
    prop::check("fabric conservation", 30, |rng| {
        let n = 2 + rng.below(7);
        let len = n * (1 + rng.below(4));
        let mut r = Rng::new(rng.next_u64());
        let bufs: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..len).map(|_| r.normal() as f32).collect())
            .collect();
        let fab = RingFabric::new(n);
        comm::spmd(&fab, |port| {
            let mut b = bufs[port.rank()].clone();
            comm::allreduce_sum(&port, &mut b);
        });
        let want = (2 * (n - 1) * n) as u64;
        if fab.messages_sent() != want {
            return Err(format!(
                "n={n}: {} messages, expected {want}",
                fab.messages_sent()
            ));
        }
        if fab.messages_delivered() != fab.messages_sent() {
            return Err("messages lost in flight".into());
        }
        Ok(())
    });
}

#[test]
fn prop_flat_param_roundtrip_any_layout() {
    prop::check("flat roundtrip", 60, |rng| {
        let n = 1 + rng.below(8);
        let parts = 1 + rng.below(5);
        let shapes: Vec<(String, Vec<usize>)> = (0..parts)
            .map(|i| {
                let dims = 1 + rng.below(3);
                (
                    format!("p{i}"),
                    (0..dims).map(|_| 1 + rng.below(6)).collect(),
                )
            })
            .collect();
        let named: Vec<(&str, Vec<usize>)> =
            shapes.iter().map(|(s, v)| (s.as_str(), v.clone())).collect();
        let layout = FlatLayout::new(&named, n);
        let mut r = Rng::new(rng.next_u64());
        let tensors: Vec<rtp::tensor::HostTensor> = layout
            .specs
            .iter()
            .map(|s| rtp::tensor::HostTensor::randn(&s.shape, 1.0, &mut r))
            .collect();
        let refs: Vec<&rtp::tensor::HostTensor> = tensors.iter().collect();
        let flat = layout.pack(&refs);
        if flat.len() % n != 0 {
            return Err("padding not multiple of n".into());
        }
        // shard + fabric-gather + unpack is the identity, on every rank
        let fab = RingFabric::new(n);
        let shards = layout.shards(&flat);
        let fulls = comm::spmd(&fab, |port| {
            layout.allgather_via(&port, &shards[port.rank()])
        });
        for full in fulls {
            let back = layout.unpack(&full);
            for (a, b) in back.iter().zip(&tensors) {
                if a != b {
                    return Err("roundtrip mismatch".into());
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_tracker_live_never_exceeds_peak_and_frees_balance() {
    prop::check("tracker invariants", 100, |rng| {
        let mut t = MemTracker::new(0, None);
        let mut live_ids = Vec::new();
        let mut expected_live = 0u64;
        for _ in 0..rng.below(60) {
            if live_ids.is_empty() || rng.below(3) < 2 {
                let bytes = 1 + rng.below(1000) as u64;
                let cat = MemCategory::ALL[rng.below(MemCategory::ALL.len())];
                live_ids.push((t.alloc(cat, bytes).unwrap(), bytes));
                expected_live += bytes;
            } else {
                let (id, bytes) = live_ids.swap_remove(rng.below(live_ids.len()));
                t.free(id);
                expected_live -= bytes;
            }
            if t.live() != expected_live {
                return Err(format!("live {} != expected {expected_live}", t.live()));
            }
            if t.peak() < t.live() {
                return Err("peak < live".into());
            }
            let cat_sum: u64 = MemCategory::ALL.iter().map(|&c| t.live_of(c)).sum();
            if cat_sum != t.live() {
                return Err("category sum != live".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_timeline_time_bounds() {
    // total time >= max(compute_busy, comm_busy); overlap never yields
    // time < either stream's busy total
    prop::check("timeline bounds", 60, |rng| {
        let mut tl = Timeline::new(a100_nvlink(), 8);
        let cfg = rtp::config::presets::get("gpt2-117m").unwrap();
        for _ in 0..1 + rng.below(20) {
            match rng.below(3) {
                0 => tl.compute("c", &op_cost(Op::MlpFwd, &cfg, 1 + rng.below(4), 1)),
                1 => tl.comm_blocking(
                    "b",
                    comm::CommPrim::AllReduce,
                    1 + rng.below(1 << 22) as u64,
                ),
                _ => {
                    let tok = tl.comm_async(
                        "a",
                        comm::CommPrim::Rotation,
                        1 + rng.below(1 << 22) as u64,
                    );
                    tl.compute("c2", &op_cost(Op::LnFwd, &cfg, 1, 1));
                    tl.wait(tok);
                }
            }
        }
        tl.barrier();
        let t = tl.time();
        if t + 1e-12 < tl.compute_busy {
            return Err(format!("time {t} < compute busy {}", tl.compute_busy));
        }
        if t + 1e-12 < tl.comm_busy {
            return Err(format!("time {t} < comm busy {}", tl.comm_busy));
        }
        Ok(())
    });
}

#[test]
fn prop_engine_peaks_scale_down_with_workers() {
    // For batch-and-weight-sharding strategies, per-worker peak must be
    // non-increasing in N (the paper's near-linear memory scalability).
    prop::check("peak monotone in N", 4, |rng| {
        let strategy =
            [Strategy::RtpInplace, Strategy::RtpOutOfPlace, Strategy::Fsdp][rng.below(3)];
        let peak = |n: usize| {
            let opts = EngineOpts::new("gpt2-117m", strategy, n, 8)
                .exec(ExecKind::Virtual);
            let cfg = opts.cfg().unwrap();
            let mut e = build_engine(&opts).unwrap();
            let b = Batch {
                ids: IntTensor::zeros(&[8, cfg.seq]),
                targets: IntTensor::zeros(&[8, cfg.seq]),
            };
            e.step(&b).unwrap();
            e.ctx().cluster.max_peak()
        };
        let (p2, p4, p8) = (peak(2), peak(4), peak(8));
        if !(p8 < p4 && p4 < p2) {
            return Err(format!("{strategy}: peaks not decreasing {p2} {p4} {p8}"));
        }
        Ok(())
    });
}
