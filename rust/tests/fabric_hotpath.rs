//! The fabric hot-path contract: the lock-sharded lane + buffer-pool
//! fabric performs ZERO steady-state heap allocations on the pooled
//! rotation and collective paths — including the BACKGROUND collective
//! engine's comm-thread allgather — its counters (allocations, lock
//! acquisitions, wakeups, background busy/wait) account honestly, the
//! main and background lane namespaces never interleave, and a stalled
//! threaded recv names the exact link that never delivered.

use std::time::Duration;

use rtp::comm::{self, LaunchPolicy, RingFabric, RotationDir};

/// One full rotation cycle per rank (N hops of the pooled path).
fn pooled_rotation_round(fab: &RingFabric, policy: LaunchPolicy, elems: usize) {
    let n = fab.n();
    comm::spmd_with(fab, policy, |port| {
        let mut buf = vec![port.rank() as f32; elems];
        for _ in 0..n {
            buf = comm::rotate_ring_vec(&port, buf, RotationDir::Clockwise);
        }
        // after N hops the buffer is back home
        assert_eq!(buf[0], port.rank() as f32);
        buf.len()
    });
    assert_eq!(fab.in_flight(), 0);
}

#[test]
fn pooled_rotation_is_allocation_free_in_steady_state() {
    for policy in [LaunchPolicy::Lockstep, LaunchPolicy::Threaded] {
        let fab = RingFabric::new(4);
        // prime: queues grow once
        pooled_rotation_round(&fab, policy, 4096);
        let c0 = fab.counters();
        for _ in 0..5 {
            pooled_rotation_round(&fab, policy, 4096);
        }
        let c1 = fab.counters();
        assert_eq!(
            c1.msg_allocs, c0.msg_allocs,
            "{policy:?}: pooled rotation allocated in steady state ({c0:?} -> {c1:?})"
        );
        // messages definitely moved
        assert_eq!(c1.sent - c0.sent, 5 * 4 * 4);
        assert_eq!(c1.delivered, c1.sent);
    }
}

#[test]
fn pooled_allreduce_is_allocation_free_in_steady_state() {
    let n = 4;
    let fab = RingFabric::new(n);
    let run = |fab: &RingFabric| {
        comm::spmd(fab, |port| {
            let mut b = vec![port.rank() as f32; 64];
            comm::allreduce_sum(&port, &mut b);
            b[0]
        });
    };
    // two priming passes: the first allocates send scratch, the second
    // lets the released buffers settle into every lane's pool
    run(&fab);
    run(&fab);
    let c0 = fab.counters();
    for _ in 0..5 {
        run(&fab);
    }
    let c1 = fab.counters();
    assert_eq!(
        c1.msg_allocs, c0.msg_allocs,
        "pooled allreduce allocated in steady state ({c0:?} -> {c1:?})"
    );
    assert!(c1.pool_hits > c0.pool_hits, "pool never hit");
}

#[test]
fn pooled_reduce_scatter_steady_state() {
    // reduce-scatter is ring-symmetric: every rank leases on its outgoing
    // lane and releases on its incoming lane, so the buffers cycle and
    // the fabric-side message path stays allocation-free. (Broadcast is
    // deliberately NOT asserted: its pipeline is asymmetric — the root
    // only ever leases and the terminal rank only ever releases — so its
    // root lane legitimately allocates per call.)
    let n = 4;
    let fab = RingFabric::new(n);
    let run = |fab: &RingFabric| {
        comm::spmd(fab, |port| {
            let full = vec![1.0f32; 8 * n];
            comm::reduce_scatter(&port, &full).len()
        });
    };
    run(&fab);
    run(&fab);
    let c0 = fab.counters();
    for _ in 0..4 {
        run(&fab);
    }
    let c1 = fab.counters();
    // reduce_scatter's RESULT shard is a fresh Vec by contract (not a
    // fabric allocation); the fabric-side message path must stay flat
    assert_eq!(
        c1.msg_allocs, c0.msg_allocs,
        "pooled reduce-scatter allocated in steady state ({c0:?} -> {c1:?})"
    );
}

#[test]
fn comm_thread_allgather_is_allocation_free_in_steady_state() {
    // the background collective engine's hot path: per-rank comm threads
    // drive queued allgathers over the background lanes, recycling both
    // the caller's full buffer and every per-hop lane buffer. After
    // priming, the fabric performs ZERO heap allocations per collective
    // (the per-collective control message to the comm thread is not a
    // fabric allocation and is O(1) per collective, not per hop).
    use rtp::comm::CollectiveStream;
    let n = 4;
    let elems = 1024usize;
    let fab = RingFabric::new(n);
    let run = |fab: &RingFabric, bufs: Vec<Vec<f32>>| -> Vec<Vec<f32>> {
        let tasks: Vec<Box<dyn FnOnce() -> Vec<f32> + Send>> = bufs
            .into_iter()
            .enumerate()
            .map(|(r, buf)| {
                let stream = CollectiveStream::new(fab.port(r), true);
                Box::new(move || {
                    assert!(stream.is_background());
                    let shard = vec![r as f32; elems];
                    let h = stream.issue_allgather(&shard, buf);
                    let full = stream.join(h);
                    assert_eq!(full.len(), n * elems);
                    assert_eq!(full[r * elems], r as f32);
                    full
                }) as Box<dyn FnOnce() -> Vec<f32> + Send>
            })
            .collect();
        fab.run_round(LaunchPolicy::Threaded, tasks)
    };
    // prime each clockwise BG lane pool to capacity (8): free-running
    // comm threads can skew up to n-1 hops apart, so the pool must hold
    // enough buffers for the worst-case skew DETERMINISTICALLY — warm
    // rounds alone would make the steady-state assertion timing-dependent
    for r in 0..n {
        let tx = fab.bg_port(r);
        let rx = fab.bg_port((r + 1) % n);
        let mut held = Vec::new();
        for _ in 0..8 {
            let mut v = tx.lease((r + 1) % n, elems);
            v.resize(elems, 0.0);
            tx.send_vec((r + 1) % n, v);
            held.push(rx.recv_vec(r));
        }
        for v in held {
            rx.release(r, v);
        }
    }
    // two warm rounds settle the caller-side full buffers' capacity
    let mut bufs: Vec<Vec<f32>> = (0..n).map(|_| Vec::new()).collect();
    for _ in 0..2 {
        bufs = run(&fab, bufs);
    }
    let c0 = fab.counters();
    for _ in 0..5 {
        bufs = run(&fab, bufs);
    }
    let c1 = fab.counters();
    assert_eq!(
        c1.msg_allocs, c0.msg_allocs,
        "comm-thread allgather allocated in steady state ({c0:?} -> {c1:?})"
    );
    assert_eq!(c1.bg_collectives - c0.bg_collectives, (5 * n) as u64);
    assert!(c1.pool_hits > c0.pool_hits, "bg lane pools never hit");
    assert_eq!(fab.in_flight(), 0);
}

#[test]
fn background_collectives_and_main_rotation_share_links_without_crosstalk() {
    // a background allgather in flight on a link must not interleave with
    // the main thread's rotation traffic on the same edge: the two lane
    // namespaces are independent FIFOs
    use rtp::comm::CollectiveStream;
    let n = 4;
    let hops = 6usize;
    let fab = RingFabric::new(n);
    let tasks: Vec<Box<dyn FnOnce() + Send>> = (0..n)
        .map(|r| {
            let port = fab.port(r);
            let stream = CollectiveStream::new(fab.port(r), true);
            Box::new(move || {
                // issue a background collective, then rotate on the main
                // lanes while it runs
                let h = stream.issue_allreduce(vec![r as f32; 256]);
                let mut held = vec![r as f32; 64];
                for _ in 0..hops {
                    held = comm::rotate_ring_vec(&port, held, RotationDir::Clockwise);
                }
                let reduced = stream.join(h);
                let want = (0..n).map(|x| x as f32).sum::<f32>();
                assert!(reduced.iter().all(|&v| v == want));
                assert_eq!(held[0], ((r + n - (hops % n)) % n) as f32);
            }) as Box<dyn FnOnce() + Send>
        })
        .collect();
    fab.run_round(LaunchPolicy::Threaded, tasks);
    assert_eq!(fab.in_flight(), 0);
}

#[test]
fn counters_move_and_reset() {
    let fab = RingFabric::new(2);
    fab.reset_counters();
    let ports = fab.ports();
    ports[0].send(1, 1usize);
    let _: usize = ports[1].recv(0);
    let c = fab.counters();
    assert_eq!(c.msg_allocs, 1, "{c:?}"); // exactly the one boxed message
    assert!(c.lock_acquisitions >= 2, "{c:?}");
    assert_eq!(c.sent, 1);
    assert_eq!(c.delivered, 1);
    fab.reset_counters();
    let c = fab.counters();
    assert_eq!(c.msg_allocs, 0);
    assert_eq!(c.lock_acquisitions, 0);
    // sent/delivered survive reset (in-flight accounting)
    assert_eq!(c.sent, 1);
    assert_eq!(c.delivered, 1);
}

#[test]
fn threaded_sends_use_targeted_wakeups() {
    // a parked receiver is woken by the one sender on its lane. (The
    // receiver parks in short slices, so a send could in principle land
    // in the sliver between parks — retry a few rounds before declaring
    // the wakeup accounting broken.)
    let n = 4;
    let fab = RingFabric::new(n);
    for attempt in 0..4 {
        fab.reset_counters();
        let tasks: Vec<Box<dyn FnOnce() + Send>> = (0..n)
            .map(|r| {
                let port = fab.port(r);
                Box::new(move || {
                    if r == 0 {
                        // park before anyone sends
                        let got: usize = port.recv(port.prev());
                        assert_eq!(got, 99);
                    } else if r == n - 1 {
                        std::thread::sleep(Duration::from_millis(40));
                        port.send(port.next(), 99usize);
                    }
                }) as Box<dyn FnOnce() + Send>
            })
            .collect();
        fab.run_round(LaunchPolicy::Threaded, tasks);
        if fab.counters().wakeups >= 1 {
            return;
        }
        eprintln!("attempt {attempt}: send landed between parks; retrying");
    }
    panic!("no targeted wakeup recorded in 4 rounds: {:?}", fab.counters());
}

#[test]
fn watchdog_reports_rank_edge_and_direction() {
    let fab = RingFabric::new(3);
    fab.set_recv_timeout(Some(Duration::from_millis(150)));
    let tasks: Vec<Box<dyn FnOnce() + Send>> = (0..3)
        .map(|r| {
            let port = fab.port(r);
            Box::new(move || {
                if r == 2 {
                    // rank 2 waits on rank 1 (its prev), which never sends
                    let _: usize = port.recv(1);
                }
            }) as Box<dyn FnOnce() + Send>
        })
        .collect();
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        fab.run_round(LaunchPolicy::Threaded, tasks);
    }));
    let payload = caught.expect_err("watchdog must fire");
    let msg = payload
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_default();
    assert!(msg.contains("rank 2"), "{msg}");
    assert!(msg.contains("link r1->r2"), "{msg}");
    assert!(msg.contains("cw ring direction"), "{msg}");
    assert!(msg.contains("threaded round watchdog"), "{msg}");
    fab.set_recv_timeout(None);
}

#[test]
fn comm_stream_wait_inherits_the_watchdog() {
    // a rank parked in CommStream::wait() on a link whose upstream died
    // must fail via the watchdog with the link identity, not hang
    use rtp::comm::CommStream;
    let fab = RingFabric::new(2);
    fab.set_recv_timeout(Some(Duration::from_millis(150)));
    let tasks: Vec<Box<dyn FnOnce() + Send>> = (0..2)
        .map(|r| {
            let stream = CommStream::new(fab.port(r), true);
            Box::new(move || {
                if r == 0 {
                    let pending = stream.begin(7usize, RotationDir::Clockwise);
                    // upstream (rank 1) never begins its hop
                    let _ = stream.wait(pending);
                }
            }) as Box<dyn FnOnce() + Send>
        })
        .collect();
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        fab.run_round(LaunchPolicy::Threaded, tasks);
    }));
    let payload = caught.expect_err("watchdog must fire inside CommStream::wait");
    let msg = payload
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_default();
    assert!(msg.contains("link r1->r0"), "{msg}");
    fab.set_recv_timeout(None);
    assert_eq!(fab.in_flight(), 0, "poisoned round must flush lanes");
}

#[test]
fn pooled_and_boxed_traffic_interleave_correctly_under_threads() {
    // rotation (boxed tuples) and collectives (pooled vecs) share links;
    // FIFO order per link must hold under real concurrency
    let n = 4;
    let k = 50usize;
    let fab = RingFabric::new(n);
    let tasks: Vec<Box<dyn FnOnce() + Send>> = (0..n)
        .map(|r| {
            let port = fab.port(r);
            Box::new(move || {
                for i in 0..k {
                    port.send(port.next(), (r, i));
                    let mut v = port.lease(port.next(), 3);
                    v.extend_from_slice(&[i as f32; 3]);
                    port.send_vec(port.next(), v);
                }
                for i in 0..k {
                    let (src, seq): (usize, usize) = port.recv(port.prev());
                    assert_eq!((src, seq), (port.prev(), i));
                    let v = port.recv_vec(port.prev());
                    assert_eq!(v, vec![i as f32; 3]);
                    port.release(port.prev(), v);
                }
            }) as Box<dyn FnOnce() + Send>
        })
        .collect();
    fab.run_round(LaunchPolicy::Threaded, tasks);
    assert_eq!(fab.in_flight(), 0);
    assert_eq!(fab.messages_sent(), (2 * n * k) as u64);
}
