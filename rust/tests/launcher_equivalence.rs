//! The launcher contract: `Launcher::Lockstep` (deterministic
//! round-robin coroutines) and `Launcher::Thread` (one free-running OS
//! thread per rank) must produce BIT-IDENTICAL results for every engine —
//! each directed fabric link is FIFO and each rank's program order is
//! fixed, so data flow (including float reduction order) never depends on
//! scheduling. This includes RTP's TRUE async rotation: the Thread
//! launcher puts each outgoing shard on the wire before the step's
//! compute (eager comm streams), which shifts message TIMING but never a
//! link's send order, so it must stay bit-identical to the Lockstep
//! synchronous schedule — asserted here for N ∈ {2, 4, 8} (N=8 via the
//! `tiny-wide` preset, whose 8 heads divide cleanly). Plus fabric stress:
//! concurrent sends in flight on every link must neither deadlock nor
//! drop messages, and a simulated OOM must abort a round cleanly even
//! with a comm-stream rotation in flight.

use rtp::comm::{LaunchPolicy, RingFabric};
use rtp::config::Strategy;
use rtp::model::ModelParams;
use rtp::parallel::fsdp::Granularity;
use rtp::parallel::{build_engine, Batch, EngineOpts, ExecKind, Launcher};
use rtp::util::rng::Rng;

/// Run `steps` real-mode (oracle) steps under `launcher`; return per-step
/// losses + gathered params + gathered grads.
fn run(
    preset: &str,
    strategy: Strategy,
    n: usize,
    launcher: Launcher,
    steps: usize,
) -> (Vec<f32>, ModelParams, ModelParams) {
    let opts = EngineOpts::new(preset, strategy, n, n.max(2))
        .exec(ExecKind::Oracle)
        .launcher(launcher);
    let cfg = opts.cfg().unwrap();
    let mut e = build_engine(&opts).unwrap();
    let mut rng = Rng::new(7);
    let mut losses = Vec::new();
    for _ in 0..steps {
        let batch = Batch::synth(&cfg, n.max(2), &mut rng);
        losses.push(e.step(&batch).unwrap());
    }
    (losses, e.gather_params(), e.gather_grads())
}

/// Bitwise comparison via the full-precision tensor tree (ModelParams
/// derives PartialEq over exact f32s — no tolerance).
fn assert_bit_identical_on(preset: &str, strategy: Strategy, n: usize) {
    let (l_loss, l_p, l_g) = run(preset, strategy, n, Launcher::Lockstep, 2);
    let (t_loss, t_p, t_g) = run(preset, strategy, n, Launcher::Thread, 2);
    assert_eq!(l_loss, t_loss, "{strategy} N={n}: losses diverge");
    assert_eq!(l_p, t_p, "{strategy} N={n}: gathered params diverge");
    assert_eq!(l_g, t_g, "{strategy} N={n}: gathered grads diverge");
}

fn assert_bit_identical(strategy: Strategy, n: usize) {
    assert_bit_identical_on("tiny", strategy, n);
}

#[test]
fn single_is_launcher_invariant() {
    assert_bit_identical(Strategy::Single, 1);
}

#[test]
fn ddp_is_launcher_invariant() {
    for n in [2, 4, 8] {
        assert_bit_identical(Strategy::Ddp, n);
    }
}

#[test]
fn fsdp_is_launcher_invariant() {
    // under the Thread launcher FSDP now runs REAL background collectives
    // (per-rank comm threads: prefetch allgather + backward
    // reduce-scatter) against Lockstep's execute-at-join schedule
    for n in [2, 4, 8] {
        assert_bit_identical(Strategy::Fsdp, n);
    }
}

#[test]
fn fsdp_model_granularity_is_launcher_invariant() {
    for n in [2, 4, 8] {
        let build = |launcher: Launcher| {
            let opts = EngineOpts::new("tiny", Strategy::Fsdp, n, n.max(2))
                .exec(ExecKind::Oracle)
                .fsdp_granularity(Granularity::Model)
                .launcher(launcher);
            let cfg = opts.cfg().unwrap();
            let mut e = build_engine(&opts).unwrap();
            let mut rng = Rng::new(7);
            let mut losses = Vec::new();
            for _ in 0..2 {
                let batch = Batch::synth(&cfg, n.max(2), &mut rng);
                losses.push(e.step(&batch).unwrap());
            }
            (losses, e.gather_params(), e.gather_grads())
        };
        let (l_loss, l_p, l_g) = build(Launcher::Lockstep);
        let (t_loss, t_p, t_g) = build(Launcher::Thread);
        assert_eq!(l_loss, t_loss, "fsdp-model N={n}: losses diverge");
        assert_eq!(l_p, t_p, "fsdp-model N={n}: params diverge");
        assert_eq!(l_g, t_g, "fsdp-model N={n}: grads diverge");
    }
}

#[test]
fn fsdp_background_collectives_match_sync_under_thread_launcher() {
    // isolate the background collective engine itself: Thread launcher
    // with per-rank comm threads vs Thread launcher with execute-at-join
    // streams — the data path must be bit-identical (same ring chunk
    // schedules, same issue order on the background lanes)
    for granularity in [Granularity::Layer, Granularity::Model] {
        for n in [2usize, 4, 8] {
            let run_bg = |background: bool| {
                let opts = EngineOpts::new("tiny", Strategy::Fsdp, n, n.max(2))
                    .exec(ExecKind::Oracle)
                    .fsdp_granularity(granularity)
                    .launcher(Launcher::Thread)
                    .async_rotation(background);
                let cfg = opts.cfg().unwrap();
                let mut e = build_engine(&opts).unwrap();
                let mut rng = Rng::new(11);
                let mut losses = Vec::new();
                for _ in 0..2 {
                    let batch = Batch::synth(&cfg, n.max(2), &mut rng);
                    losses.push(e.step(&batch).unwrap());
                }
                (losses, e.gather_params(), e.gather_grads())
            };
            let (s_loss, s_p, s_g) = run_bg(false);
            let (b_loss, b_p, b_g) = run_bg(true);
            assert_eq!(
                s_loss, b_loss,
                "{granularity:?} N={n}: background collectives changed losses"
            );
            assert_eq!(
                s_p, b_p,
                "{granularity:?} N={n}: background collectives changed params"
            );
            assert_eq!(
                s_g, b_g,
                "{granularity:?} N={n}: background collectives changed grads"
            );
        }
    }
}

#[test]
fn tp_is_launcher_invariant() {
    // tiny has 4 heads: TP shards attention by head, so N ≤ 4
    for n in [2, 4] {
        assert_bit_identical(Strategy::MegatronTp, n);
    }
}

#[test]
fn rtp_inplace_is_launcher_invariant() {
    for n in [2, 4] {
        assert_bit_identical(Strategy::RtpInplace, n);
    }
}

#[test]
fn rtp_outofplace_is_launcher_invariant() {
    // the Thread side runs REAL background rotation (async comm streams,
    // the default) against Lockstep's synchronous schedule
    for n in [2, 4] {
        assert_bit_identical(Strategy::RtpOutOfPlace, n);
    }
    // N=8 needs 8 shardable heads: tiny-wide
    assert_bit_identical_on("tiny-wide", Strategy::RtpOutOfPlace, 8);
}

#[test]
fn rtp_async_rotation_matches_sync_under_thread_launcher() {
    // isolate the comm stream itself: Thread launcher with eager
    // background hops vs Thread launcher with synchronous boundary hops
    for (preset, n) in [("tiny", 2), ("tiny", 4), ("tiny-wide", 8)] {
        let run_async = |async_rot: bool| {
            let opts = EngineOpts::new(preset, Strategy::RtpOutOfPlace, n, n.max(2))
                .exec(ExecKind::Oracle)
                .launcher(Launcher::Thread)
                .async_rotation(async_rot);
            let cfg = opts.cfg().unwrap();
            let mut e = build_engine(&opts).unwrap();
            let mut rng = Rng::new(11);
            let mut losses = Vec::new();
            for _ in 0..2 {
                let batch = Batch::synth(&cfg, n.max(2), &mut rng);
                losses.push(e.step(&batch).unwrap());
            }
            (losses, e.gather_params(), e.gather_grads())
        };
        let (s_loss, s_p, s_g) = run_async(false);
        let (a_loss, a_p, a_g) = run_async(true);
        assert_eq!(s_loss, a_loss, "{preset} N={n}: async rotation changed losses");
        assert_eq!(s_p, a_p, "{preset} N={n}: async rotation changed params");
        assert_eq!(s_g, a_g, "{preset} N={n}: async rotation changed grads");
    }
}

#[test]
fn oom_abort_does_not_deadlock_inflight_comm_streams() {
    // find the step peak, then cap just below it: some rank OOMs mid-step
    // with an eager rotation already on the wire; the round must unwind
    // into an orderly Err (no hang, no poisoned-fabric leak)
    let n = 4;
    let probe = EngineOpts::new("tiny", Strategy::RtpOutOfPlace, n, n)
        .exec(ExecKind::Virtual)
        .launcher(Launcher::Thread);
    let cfg = probe.cfg().unwrap();
    let mk_batch = || Batch {
        ids: rtp::tensor::IntTensor::zeros(&[n, cfg.seq]),
        targets: rtp::tensor::IntTensor::zeros(&[n, cfg.seq]),
    };
    let peak = {
        let mut e = build_engine(&probe).unwrap();
        e.step(&mk_batch()).unwrap();
        e.ctx().cluster.max_peak()
    };
    for launcher in [Launcher::Thread, Launcher::Lockstep] {
        let opts = EngineOpts::new("tiny", Strategy::RtpOutOfPlace, n, n)
            .exec(ExecKind::Virtual)
            .launcher(launcher)
            .capacity(Some(peak - 1));
        let mut e = build_engine(&opts).unwrap();
        let err = e.step(&mk_batch()).unwrap_err().to_string();
        assert!(err.contains("OOM"), "{launcher}: {err}");
        // fabric drained: the aborted round flushed the in-flight shard
        assert_eq!(
            e.ctx().cluster.fabric().in_flight(),
            0,
            "{launcher}: abort left messages in flight"
        );
    }
}

#[test]
fn rtp_moe_is_launcher_invariant() {
    let (l_loss, l_p, l_g) = run("tiny-moe", Strategy::RtpInplace, 2, Launcher::Lockstep, 2);
    let (t_loss, t_p, t_g) = run("tiny-moe", Strategy::RtpInplace, 2, Launcher::Thread, 2);
    assert_eq!(l_loss, t_loss);
    assert_eq!(l_p, t_p);
    assert_eq!(l_g, t_g);
}

#[test]
fn virtual_mode_peaks_are_launcher_invariant() {
    // memory accounting is per-rank state — scheduling must not move peaks
    for strategy in [Strategy::Fsdp, Strategy::RtpInplace, Strategy::RtpOutOfPlace] {
        let peak = |launcher: Launcher| {
            let opts = EngineOpts::new("gpt2-117m", strategy, 4, 8)
                .exec(ExecKind::Virtual)
                .launcher(launcher);
            let cfg = opts.cfg().unwrap();
            let mut e = build_engine(&opts).unwrap();
            let b = Batch {
                ids: rtp::tensor::IntTensor::zeros(&[8, cfg.seq]),
                targets: rtp::tensor::IntTensor::zeros(&[8, cfg.seq]),
            };
            e.step(&b).unwrap();
            (e.ctx().cluster.max_peak(), e.ctx().cluster.total_peak())
        };
        assert_eq!(
            peak(Launcher::Lockstep),
            peak(Launcher::Thread),
            "{strategy}: peaks diverge across launchers"
        );
    }
}

#[test]
fn fabric_concurrent_sends_no_deadlock_no_loss() {
    // every rank floods both links, then drains — under both policies
    for policy in [LaunchPolicy::Lockstep, LaunchPolicy::Threaded] {
        let n = 8;
        let k = 500usize;
        let fab = RingFabric::new(n);
        let tasks: Vec<Box<dyn FnOnce() -> u64 + Send>> = (0..n)
            .map(|r| {
                let port = fab.port(r);
                Box::new(move || {
                    let mut checksum = 0u64;
                    for i in 0..k {
                        port.send(port.next(), (r, i));
                        port.send(port.prev(), (r, i));
                    }
                    for i in 0..k {
                        let (src, seq): (usize, usize) = port.recv(port.prev());
                        assert_eq!((src, seq), (port.prev(), i), "cw link reordered");
                        checksum += (src + seq) as u64;
                        let (src, seq): (usize, usize) = port.recv(port.next());
                        assert_eq!((src, seq), (port.next(), i), "ccw link reordered");
                        checksum += (src + seq) as u64;
                    }
                    checksum
                }) as Box<dyn FnOnce() -> u64 + Send>
            })
            .collect();
        let sums = fab.run_round(policy, tasks);
        assert_eq!(sums.len(), n);
        assert_eq!(fab.in_flight(), 0, "{policy:?}: messages left in flight");
        assert_eq!(fab.messages_sent(), (2 * n * k) as u64);
        assert_eq!(fab.messages_delivered(), (2 * n * k) as u64);
    }
}
