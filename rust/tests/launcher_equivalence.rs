//! The launcher contract: `Launcher::Lockstep` (deterministic
//! round-robin coroutines) and `Launcher::Thread` (one free-running OS
//! thread per rank) must produce BIT-IDENTICAL results for every engine —
//! each directed fabric link is FIFO and each rank's program order is
//! fixed, so data flow (including float reduction order) never depends on
//! scheduling. This includes RTP's TRUE async rotation: the Thread
//! launcher puts each outgoing shard on the wire before the step's
//! compute (eager comm streams), which shifts message TIMING but never a
//! link's send order, so it must stay bit-identical to the Lockstep
//! synchronous schedule — asserted here for N ∈ {2, 4, 8} (N=8 via the
//! `tiny-wide` preset, whose 8 heads divide cleanly). Plus fabric stress:
//! concurrent sends in flight on every link must neither deadlock nor
//! drop messages, and a simulated OOM must abort a round cleanly even
//! with a comm-stream rotation in flight.

use rtp::comm::{CollectiveStream, LaunchPolicy, RingFabric, SchedPolicy, TransportKind};
use rtp::config::Strategy;
use rtp::model::ModelParams;
use rtp::parallel::fsdp::Granularity;
use rtp::parallel::{build_engine, Batch, EngineOpts, ExecKind, Launcher};
use rtp::util::rng::Rng;

/// Run `steps` real-mode (oracle) steps under `launcher`; return per-step
/// losses + gathered params + gathered grads.
fn run(
    preset: &str,
    strategy: Strategy,
    n: usize,
    launcher: Launcher,
    steps: usize,
) -> (Vec<f32>, ModelParams, ModelParams) {
    let opts = EngineOpts::new(preset, strategy, n, n.max(2))
        .exec(ExecKind::Oracle)
        .launcher(launcher);
    let cfg = opts.cfg().unwrap();
    let mut e = build_engine(&opts).unwrap();
    let mut rng = Rng::new(7);
    let mut losses = Vec::new();
    for _ in 0..steps {
        let batch = Batch::synth(&cfg, n.max(2), &mut rng);
        losses.push(e.step(&batch).unwrap());
    }
    (losses, e.gather_params(), e.gather_grads())
}

/// Bitwise comparison via the full-precision tensor tree (ModelParams
/// derives PartialEq over exact f32s — no tolerance).
fn assert_bit_identical_on(preset: &str, strategy: Strategy, n: usize) {
    let (l_loss, l_p, l_g) = run(preset, strategy, n, Launcher::Lockstep, 2);
    let (t_loss, t_p, t_g) = run(preset, strategy, n, Launcher::Thread, 2);
    assert_eq!(l_loss, t_loss, "{strategy} N={n}: losses diverge");
    assert_eq!(l_p, t_p, "{strategy} N={n}: gathered params diverge");
    assert_eq!(l_g, t_g, "{strategy} N={n}: gathered grads diverge");
}

fn assert_bit_identical(strategy: Strategy, n: usize) {
    assert_bit_identical_on("tiny", strategy, n);
}

#[test]
fn single_is_launcher_invariant() {
    assert_bit_identical(Strategy::Single, 1);
}

#[test]
fn ddp_is_launcher_invariant() {
    for n in [2, 4, 8] {
        assert_bit_identical(Strategy::Ddp, n);
    }
}

#[test]
fn fsdp_is_launcher_invariant() {
    // under the Thread launcher FSDP now runs REAL background collectives
    // (per-rank comm threads: prefetch allgather + backward
    // reduce-scatter) against Lockstep's execute-at-join schedule
    for n in [2, 4, 8] {
        assert_bit_identical(Strategy::Fsdp, n);
    }
}

#[test]
fn fsdp_model_granularity_is_launcher_invariant() {
    for n in [2, 4, 8] {
        let build = |launcher: Launcher| {
            let opts = EngineOpts::new("tiny", Strategy::Fsdp, n, n.max(2))
                .exec(ExecKind::Oracle)
                .fsdp_granularity(Granularity::Model)
                .launcher(launcher);
            let cfg = opts.cfg().unwrap();
            let mut e = build_engine(&opts).unwrap();
            let mut rng = Rng::new(7);
            let mut losses = Vec::new();
            for _ in 0..2 {
                let batch = Batch::synth(&cfg, n.max(2), &mut rng);
                losses.push(e.step(&batch).unwrap());
            }
            (losses, e.gather_params(), e.gather_grads())
        };
        let (l_loss, l_p, l_g) = build(Launcher::Lockstep);
        let (t_loss, t_p, t_g) = build(Launcher::Thread);
        assert_eq!(l_loss, t_loss, "fsdp-model N={n}: losses diverge");
        assert_eq!(l_p, t_p, "fsdp-model N={n}: params diverge");
        assert_eq!(l_g, t_g, "fsdp-model N={n}: grads diverge");
    }
}

#[test]
fn fsdp_background_collectives_match_sync_under_thread_launcher() {
    // isolate the background collective engine itself: Thread launcher
    // with per-rank comm threads vs Thread launcher with execute-at-join
    // streams — the data path must be bit-identical (same ring chunk
    // schedules, same issue order on the background lanes)
    for granularity in [Granularity::Layer, Granularity::Model] {
        for n in [2usize, 4, 8] {
            let run_bg = |background: bool| {
                let opts = EngineOpts::new("tiny", Strategy::Fsdp, n, n.max(2))
                    .exec(ExecKind::Oracle)
                    .fsdp_granularity(granularity)
                    .launcher(Launcher::Thread)
                    .async_rotation(background);
                let cfg = opts.cfg().unwrap();
                let mut e = build_engine(&opts).unwrap();
                let mut rng = Rng::new(11);
                let mut losses = Vec::new();
                for _ in 0..2 {
                    let batch = Batch::synth(&cfg, n.max(2), &mut rng);
                    losses.push(e.step(&batch).unwrap());
                }
                (losses, e.gather_params(), e.gather_grads())
            };
            let (s_loss, s_p, s_g) = run_bg(false);
            let (b_loss, b_p, b_g) = run_bg(true);
            assert_eq!(
                s_loss, b_loss,
                "{granularity:?} N={n}: background collectives changed losses"
            );
            assert_eq!(
                s_p, b_p,
                "{granularity:?} N={n}: background collectives changed params"
            );
            assert_eq!(
                s_g, b_g,
                "{granularity:?} N={n}: background collectives changed grads"
            );
        }
    }
}

/// Like [`run`] but with an explicit hop-scheduling policy and gradient
/// bucket size.
fn run_sched(
    preset: &str,
    strategy: Strategy,
    n: usize,
    launcher: Launcher,
    policy: SchedPolicy,
    bucket_bytes: Option<u64>,
) -> (Vec<f32>, ModelParams, ModelParams) {
    let opts = EngineOpts::new(preset, strategy, n, n.max(2))
        .exec(ExecKind::Oracle)
        .launcher(launcher)
        .sched_policy(policy)
        .bucket_bytes(bucket_bytes);
    let cfg = opts.cfg().unwrap();
    let mut e = build_engine(&opts).unwrap();
    let mut rng = Rng::new(7);
    let mut losses = Vec::new();
    for _ in 0..2 {
        let batch = Batch::synth(&cfg, n.max(2), &mut rng);
        losses.push(e.step(&batch).unwrap());
    }
    (losses, e.gather_params(), e.gather_grads())
}

const POLICIES: [SchedPolicy; 3] =
    [SchedPolicy::Fifo, SchedPolicy::RoundRobin, SchedPolicy::Priority];

#[test]
fn sched_policies_are_bit_identical_for_fsdp() {
    // the scheduler changes WHEN hops run, never WHAT they carry: the
    // sub-channel construction (comm/stream.rs module docs) makes every
    // policy bit-identical to the Lockstep/Fifo reference. FSDP is the
    // engine whose stream genuinely holds several collectives at once
    // (prefetch allgather + pending reduce-scatters).
    for n in [2usize, 4, 8] {
        let (r_loss, r_p, r_g) = run_sched(
            "tiny",
            Strategy::Fsdp,
            n,
            Launcher::Lockstep,
            SchedPolicy::Fifo,
            None,
        );
        // Lockstep ignores the policy (deterministic execute-at-join)...
        let (l_loss, l_p, l_g) = run_sched(
            "tiny",
            Strategy::Fsdp,
            n,
            Launcher::Lockstep,
            SchedPolicy::RoundRobin,
            None,
        );
        assert_eq!(r_loss, l_loss, "N={n}: lockstep must ignore the policy");
        assert_eq!(r_p, l_p, "N={n}: lockstep must ignore the policy");
        assert_eq!(r_g, l_g, "N={n}: lockstep must ignore the policy");
        // ...and every Thread-launcher policy matches the reference
        for policy in POLICIES {
            let (t_loss, t_p, t_g) =
                run_sched("tiny", Strategy::Fsdp, n, Launcher::Thread, policy, None);
            let pname = policy.name();
            assert_eq!(r_loss, t_loss, "{pname} N={n}: losses diverge");
            assert_eq!(r_p, t_p, "{pname} N={n}: params diverge");
            assert_eq!(r_g, t_g, "{pname} N={n}: grads diverge");
        }
    }
}

#[test]
fn bucketed_allreduce_is_policy_and_launcher_invariant() {
    // gradient bucketing changes ring-chunk boundaries (and so float
    // summation order) vs the monolithic allreduce — but GIVEN one bucket
    // size, results must stay bit-identical across policies and
    // launchers. 16 KiB on tiny's ~150 KB flat grads yields ~10 buckets,
    // so DDP's backward really does put multiple allreduces in flight.
    let bucket = Some(16u64 << 10);
    for n in [2usize, 4, 8] {
        let (r_loss, r_p, r_g) = run_sched(
            "tiny",
            Strategy::Ddp,
            n,
            Launcher::Lockstep,
            SchedPolicy::Fifo,
            bucket,
        );
        for policy in POLICIES {
            let (t_loss, t_p, t_g) =
                run_sched("tiny", Strategy::Ddp, n, Launcher::Thread, policy, bucket);
            let pname = policy.name();
            assert_eq!(r_loss, t_loss, "{pname} N={n}: bucketed losses diverge");
            assert_eq!(r_p, t_p, "{pname} N={n}: bucketed params diverge");
            assert_eq!(r_g, t_g, "{pname} N={n}: bucketed grads diverge");
        }
    }
    // RTP's replicated-grad allreduce rides the same GradBuckets helper —
    // pin that path too (tiny's 4 heads divide N ∈ {2, 4}; a 1 KiB
    // target keeps even the small replicated grads multi-bucket)
    let rep_bucket = Some(1u64 << 10);
    for n in [2usize, 4] {
        let (r_loss, r_p, r_g) = run_sched(
            "tiny",
            Strategy::RtpOutOfPlace,
            n,
            Launcher::Lockstep,
            SchedPolicy::Fifo,
            rep_bucket,
        );
        for policy in POLICIES {
            let (t_loss, t_p, t_g) = run_sched(
                "tiny",
                Strategy::RtpOutOfPlace,
                n,
                Launcher::Thread,
                policy,
                rep_bucket,
            );
            let pname = policy.name();
            assert_eq!(r_loss, t_loss, "rtp {pname} N={n}: losses diverge");
            assert_eq!(r_p, t_p, "rtp {pname} N={n}: params diverge");
            assert_eq!(r_g, t_g, "rtp {pname} N={n}: grads diverge");
        }
    }
}

#[test]
fn multi_collective_stress_interleaves_without_crosstalk() {
    // fabric-level stress for the hop scheduler: four mixed-kind,
    // mixed-size collectives in flight per rank on the background lanes
    // WHILE the rank body hammers the main lanes — values must match the
    // closed forms, the main-lane traffic must arrive in order (no
    // bg/main crosstalk), and the fairness counters must stay in bounds.
    for policy in [SchedPolicy::RoundRobin, SchedPolicy::Priority] {
        for n in [2usize, 4, 8] {
            let fab = RingFabric::new(n);
            fab.reset_counters();
            let tasks: Vec<Box<dyn FnOnce() + Send>> = (0..n)
                .map(|r| {
                    let port = fab.port(r);
                    Box::new(move || {
                        let stream =
                            CollectiveStream::with_policy(port.clone(), true, policy);
                        assert!(stream.is_background());
                        // integer payloads: sums are exact under any
                        // summation order
                        let big: Vec<f32> =
                            (0..4096).map(|i| ((r + i) % 17) as f32).collect();
                        let rs_full: Vec<f32> =
                            (0..8 * n).map(|i| (r * 100 + i) as f32).collect();
                        let shard = vec![r as f32 + 1.0; 16];
                        let small = vec![r as f32; 32];
                        let h_big = stream.issue_allreduce(big);
                        let h_rs = stream.issue_reduce_scatter(rs_full);
                        let h_ag = stream.issue_allgather(&shard, Vec::new());
                        let h_small = stream.issue_allreduce(small);
                        // concurrent MAIN-lane traffic while all four
                        // collectives are in flight on the bg lanes
                        for i in 0..50usize {
                            port.send(port.next(), (r, i));
                            let (src, seq): (usize, usize) = port.recv(port.prev());
                            assert_eq!(
                                (src, seq),
                                (port.prev(), i),
                                "main lane reordered under bg load"
                            );
                        }
                        // scrambled joins
                        let ag = stream.join(h_ag);
                        let small = stream.join(h_small);
                        let big_out = stream.join(h_big);
                        let rs = stream.join(h_rs);
                        let want_ag: Vec<f32> = (0..n)
                            .flat_map(|s| vec![s as f32 + 1.0; 16])
                            .collect();
                        assert_eq!(ag, want_ag, "{policy:?} n={n}");
                        let want_small =
                            vec![(0..n).map(|s| s as f32).sum::<f32>(); 32];
                        assert_eq!(small, want_small, "{policy:?} n={n}");
                        for (i, v) in big_out.iter().enumerate() {
                            let want: f32 =
                                (0..n).map(|s| ((s + i) % 17) as f32).sum();
                            assert_eq!(*v, want, "{policy:?} n={n} i={i}");
                        }
                        let mine = &rs[r * 8..(r + 1) * 8];
                        for (i, v) in mine.iter().enumerate() {
                            let want: f32 = (0..n)
                                .map(|s| (s * 100 + r * 8 + i) as f32)
                                .sum();
                            assert_eq!(*v, want, "{policy:?} n={n} i={i}");
                        }
                    }) as Box<dyn FnOnce() + Send>
                })
                .collect();
            fab.run_round(LaunchPolicy::Threaded, tasks);
            assert_eq!(fab.in_flight(), 0, "{policy:?} n={n}");
            let c = fab.counters();
            // every rank's comm thread steps exactly its collectives'
            // hops: 2 allreduces (2(n-1) each) + allgather (n-1) +
            // reduce-scatter (n-1) = 6(n-1) per rank
            assert_eq!(
                c.sched_hops,
                (6 * n * (n - 1)) as u64,
                "{policy:?} n={n}: unexpected scheduled hop count"
            );
            // fairness: no collective may monopolize the thread longer
            // than its own hop budget while others are runnable
            assert!(
                c.sched_max_streak <= (2 * (n - 1)) as u64,
                "{policy:?} n={n}: contested streak {} exceeds one \
                 collective's hop budget",
                c.sched_max_streak
            );
            // each thread switches collectives at least once per
            // collective it retires (first hops are switches)
            assert!(
                c.sched_switches >= (4 * n) as u64,
                "{policy:?} n={n}: only {} switches",
                c.sched_switches
            );
        }
    }
}

#[test]
fn tp_is_launcher_invariant() {
    // tiny has 4 heads: TP shards attention by head, so N ≤ 4
    for n in [2, 4] {
        assert_bit_identical(Strategy::MegatronTp, n);
    }
}

#[test]
fn rtp_inplace_is_launcher_invariant() {
    for n in [2, 4] {
        assert_bit_identical(Strategy::RtpInplace, n);
    }
}

#[test]
fn rtp_outofplace_is_launcher_invariant() {
    // the Thread side runs REAL background rotation (async comm streams,
    // the default) against Lockstep's synchronous schedule
    for n in [2, 4] {
        assert_bit_identical(Strategy::RtpOutOfPlace, n);
    }
    // N=8 needs 8 shardable heads: tiny-wide
    assert_bit_identical_on("tiny-wide", Strategy::RtpOutOfPlace, 8);
}

#[test]
fn rtp_async_rotation_matches_sync_under_thread_launcher() {
    // isolate the comm stream itself: Thread launcher with eager
    // background hops vs Thread launcher with synchronous boundary hops
    for (preset, n) in [("tiny", 2), ("tiny", 4), ("tiny-wide", 8)] {
        let run_async = |async_rot: bool| {
            let opts = EngineOpts::new(preset, Strategy::RtpOutOfPlace, n, n.max(2))
                .exec(ExecKind::Oracle)
                .launcher(Launcher::Thread)
                .async_rotation(async_rot);
            let cfg = opts.cfg().unwrap();
            let mut e = build_engine(&opts).unwrap();
            let mut rng = Rng::new(11);
            let mut losses = Vec::new();
            for _ in 0..2 {
                let batch = Batch::synth(&cfg, n.max(2), &mut rng);
                losses.push(e.step(&batch).unwrap());
            }
            (losses, e.gather_params(), e.gather_grads())
        };
        let (s_loss, s_p, s_g) = run_async(false);
        let (a_loss, a_p, a_g) = run_async(true);
        assert_eq!(s_loss, a_loss, "{preset} N={n}: async rotation changed losses");
        assert_eq!(s_p, a_p, "{preset} N={n}: async rotation changed params");
        assert_eq!(s_g, a_g, "{preset} N={n}: async rotation changed grads");
    }
}

#[test]
fn oom_abort_does_not_deadlock_inflight_comm_streams() {
    // find the step peak, then cap just below it: some rank OOMs mid-step
    // with an eager rotation already on the wire; the round must unwind
    // into an orderly Err (no hang, no poisoned-fabric leak)
    let n = 4;
    let probe = EngineOpts::new("tiny", Strategy::RtpOutOfPlace, n, n)
        .exec(ExecKind::Virtual)
        .launcher(Launcher::Thread);
    let cfg = probe.cfg().unwrap();
    let mk_batch = || Batch {
        ids: rtp::tensor::IntTensor::zeros(&[n, cfg.seq]),
        targets: rtp::tensor::IntTensor::zeros(&[n, cfg.seq]),
    };
    let peak = {
        let mut e = build_engine(&probe).unwrap();
        e.step(&mk_batch()).unwrap();
        e.ctx().cluster.max_peak()
    };
    for launcher in [Launcher::Thread, Launcher::Lockstep] {
        let opts = EngineOpts::new("tiny", Strategy::RtpOutOfPlace, n, n)
            .exec(ExecKind::Virtual)
            .launcher(launcher)
            .capacity(Some(peak - 1));
        let mut e = build_engine(&opts).unwrap();
        let err = e.step(&mk_batch()).unwrap_err().to_string();
        assert!(err.contains("OOM"), "{launcher}: {err}");
        // fabric drained: the aborted round flushed the in-flight shard
        assert_eq!(
            e.ctx().cluster.fabric().in_flight(),
            0,
            "{launcher}: abort left messages in flight"
        );
    }
}

#[test]
fn rtp_moe_is_launcher_invariant() {
    let (l_loss, l_p, l_g) = run("tiny-moe", Strategy::RtpInplace, 2, Launcher::Lockstep, 2);
    let (t_loss, t_p, t_g) = run("tiny-moe", Strategy::RtpInplace, 2, Launcher::Thread, 2);
    assert_eq!(l_loss, t_loss);
    assert_eq!(l_p, t_p);
    assert_eq!(l_g, t_g);
}

#[test]
fn virtual_mode_peaks_are_launcher_invariant() {
    // memory accounting is per-rank state — scheduling must not move peaks
    for strategy in [Strategy::Fsdp, Strategy::RtpInplace, Strategy::RtpOutOfPlace] {
        let peak = |launcher: Launcher| {
            let opts = EngineOpts::new("gpt2-117m", strategy, 4, 8)
                .exec(ExecKind::Virtual)
                .launcher(launcher);
            let cfg = opts.cfg().unwrap();
            let mut e = build_engine(&opts).unwrap();
            let b = Batch {
                ids: rtp::tensor::IntTensor::zeros(&[8, cfg.seq]),
                targets: rtp::tensor::IntTensor::zeros(&[8, cfg.seq]),
            };
            e.step(&b).unwrap();
            (e.ctx().cluster.max_peak(), e.ctx().cluster.total_peak())
        };
        assert_eq!(
            peak(Launcher::Lockstep),
            peak(Launcher::Thread),
            "{strategy}: peaks diverge across launchers"
        );
    }
}

// ---------------------------------------------------------------------
// Launcher::Process: the SAME bit-identity contract, but the ranks are
// real OS processes (re-entrant `rtp worker` mode) and every data-plane
// hop crosses a byte transport (shm ring / unix socket). The parent
// drives steps and gathers over the control socket; results must match
// the in-process Lockstep oracle exactly — same build path, same global
// batch, same rank-order loss reduction, binary-exact param roundtrip.
// ---------------------------------------------------------------------

/// Explicit in-process reference: Lockstep launcher on pure lanes,
/// regardless of what `RTP_TRANSPORT`/`RTP_LAUNCHER` the ambient CI
/// matrix leg sets.
fn run_reference(
    preset: &str,
    strategy: Strategy,
    n: usize,
    steps: usize,
) -> (Vec<f32>, ModelParams, ModelParams) {
    let opts = EngineOpts::new(preset, strategy, n, n.max(2))
        .exec(ExecKind::Oracle)
        .launcher(Launcher::Lockstep)
        .transport(TransportKind::Inproc);
    let cfg = opts.cfg().unwrap();
    let mut e = build_engine(&opts).unwrap();
    let mut rng = Rng::new(7);
    let mut losses = Vec::new();
    for _ in 0..steps {
        let batch = Batch::synth(&cfg, n.max(2), &mut rng);
        losses.push(e.step(&batch).unwrap());
    }
    (losses, e.gather_params(), e.gather_grads())
}

/// Like [`run`] but through real worker processes on `transport`.
fn run_process(
    preset: &str,
    strategy: Strategy,
    n: usize,
    transport: TransportKind,
    steps: usize,
) -> (Vec<f32>, ModelParams, ModelParams) {
    // the workers must run THIS build's binary, not whatever `rtp` is on
    // PATH (idempotent across parallel tests — same value everywhere)
    std::env::set_var("RTP_WORKER_EXE", env!("CARGO_BIN_EXE_rtp"));
    let opts = EngineOpts::new(preset, strategy, n, n.max(2))
        .exec(ExecKind::Oracle)
        .launcher(Launcher::Process)
        .transport(transport);
    let cfg = opts.cfg().unwrap();
    let mut e = build_engine(&opts).unwrap();
    let mut rng = Rng::new(7);
    let mut losses = Vec::new();
    for _ in 0..steps {
        let batch = Batch::synth(&cfg, n.max(2), &mut rng);
        losses.push(e.step(&batch).unwrap());
    }
    (losses, e.gather_params(), e.gather_grads())
}

fn assert_process_bit_identical(strategy: Strategy, n: usize, transport: TransportKind) {
    let (l_loss, l_p, l_g) = run_reference("tiny", strategy, n, 2);
    let (p_loss, p_p, p_g) = run_process("tiny", strategy, n, transport, 2);
    let t = transport.name();
    assert_eq!(l_loss, p_loss, "{strategy} N={n} via {t}: losses diverge");
    assert_eq!(l_p, p_p, "{strategy} N={n} via {t}: gathered params diverge");
    assert_eq!(l_g, p_g, "{strategy} N={n} via {t}: gathered grads diverge");
}

#[test]
fn process_launcher_ddp_is_bit_identical() {
    for n in [2, 4] {
        assert_process_bit_identical(Strategy::Ddp, n, TransportKind::Shm);
    }
}

#[test]
fn process_launcher_fsdp_is_bit_identical() {
    for n in [2, 4] {
        assert_process_bit_identical(Strategy::Fsdp, n, TransportKind::Shm);
    }
}

#[test]
fn process_launcher_tp_is_bit_identical() {
    for n in [2, 4] {
        assert_process_bit_identical(Strategy::MegatronTp, n, TransportKind::Shm);
    }
}

#[test]
fn process_launcher_rtp_inplace_is_bit_identical() {
    for n in [2, 4] {
        assert_process_bit_identical(Strategy::RtpInplace, n, TransportKind::Shm);
    }
}

#[test]
fn process_launcher_rtp_outofplace_is_bit_identical() {
    for n in [2, 4] {
        assert_process_bit_identical(Strategy::RtpOutOfPlace, n, TransportKind::Shm);
    }
}

#[test]
fn process_launcher_uds_smoke_is_bit_identical() {
    // the portable Unix-socket reference backend, one engine per ring
    // size — the full five-engine matrix above runs on shm
    assert_process_bit_identical(Strategy::Ddp, 2, TransportKind::Uds);
    assert_process_bit_identical(Strategy::RtpOutOfPlace, 4, TransportKind::Uds);
}

#[test]
fn fabric_concurrent_sends_no_deadlock_no_loss() {
    // every rank floods both links, then drains — under both policies
    for policy in [LaunchPolicy::Lockstep, LaunchPolicy::Threaded] {
        let n = 8;
        let k = 500usize;
        let fab = RingFabric::new(n);
        let tasks: Vec<Box<dyn FnOnce() -> u64 + Send>> = (0..n)
            .map(|r| {
                let port = fab.port(r);
                Box::new(move || {
                    let mut checksum = 0u64;
                    for i in 0..k {
                        port.send(port.next(), (r, i));
                        port.send(port.prev(), (r, i));
                    }
                    for i in 0..k {
                        let (src, seq): (usize, usize) = port.recv(port.prev());
                        assert_eq!((src, seq), (port.prev(), i), "cw link reordered");
                        checksum += (src + seq) as u64;
                        let (src, seq): (usize, usize) = port.recv(port.next());
                        assert_eq!((src, seq), (port.next(), i), "ccw link reordered");
                        checksum += (src + seq) as u64;
                    }
                    checksum
                }) as Box<dyn FnOnce() -> u64 + Send>
            })
            .collect();
        let sums = fab.run_round(policy, tasks);
        assert_eq!(sums.len(), n);
        assert_eq!(fab.in_flight(), 0, "{policy:?}: messages left in flight");
        assert_eq!(fab.messages_sent(), (2 * n * k) as u64);
        assert_eq!(fab.messages_delivered(), (2 * n * k) as u64);
    }
}
