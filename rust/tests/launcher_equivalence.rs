//! The launcher contract: `Launcher::Lockstep` (deterministic
//! round-robin coroutines) and `Launcher::Thread` (one free-running OS
//! thread per rank) must produce BIT-IDENTICAL results for every engine —
//! each directed fabric link is FIFO and each rank's program order is
//! fixed, so data flow (including float reduction order) never depends on
//! scheduling. Plus fabric stress: concurrent sends in flight on every
//! link must neither deadlock nor drop messages.

use rtp::comm::{LaunchPolicy, RingFabric};
use rtp::config::Strategy;
use rtp::model::ModelParams;
use rtp::parallel::{build_engine, Batch, EngineOpts, ExecKind, Launcher};
use rtp::util::rng::Rng;

/// Run `steps` real-mode (oracle) steps under `launcher`; return per-step
/// losses + gathered params + gathered grads.
fn run(
    preset: &str,
    strategy: Strategy,
    n: usize,
    launcher: Launcher,
    steps: usize,
) -> (Vec<f32>, ModelParams, ModelParams) {
    let opts = EngineOpts::new(preset, strategy, n, n.max(2))
        .exec(ExecKind::Oracle)
        .launcher(launcher);
    let cfg = opts.cfg().unwrap();
    let mut e = build_engine(&opts).unwrap();
    let mut rng = Rng::new(7);
    let mut losses = Vec::new();
    for _ in 0..steps {
        let batch = Batch::synth(&cfg, n.max(2), &mut rng);
        losses.push(e.step(&batch).unwrap());
    }
    (losses, e.gather_params(), e.gather_grads())
}

/// Bitwise comparison via the full-precision tensor tree (ModelParams
/// derives PartialEq over exact f32s — no tolerance).
fn assert_bit_identical(strategy: Strategy, n: usize) {
    let (l_loss, l_p, l_g) = run("tiny", strategy, n, Launcher::Lockstep, 2);
    let (t_loss, t_p, t_g) = run("tiny", strategy, n, Launcher::Thread, 2);
    assert_eq!(l_loss, t_loss, "{strategy} N={n}: losses diverge");
    assert_eq!(l_p, t_p, "{strategy} N={n}: gathered params diverge");
    assert_eq!(l_g, t_g, "{strategy} N={n}: gathered grads diverge");
}

#[test]
fn single_is_launcher_invariant() {
    assert_bit_identical(Strategy::Single, 1);
}

#[test]
fn ddp_is_launcher_invariant() {
    for n in [2, 4, 8] {
        assert_bit_identical(Strategy::Ddp, n);
    }
}

#[test]
fn fsdp_is_launcher_invariant() {
    for n in [2, 4, 8] {
        assert_bit_identical(Strategy::Fsdp, n);
    }
}

#[test]
fn tp_is_launcher_invariant() {
    // tiny has 4 heads: TP shards attention by head, so N ≤ 4
    for n in [2, 4] {
        assert_bit_identical(Strategy::MegatronTp, n);
    }
}

#[test]
fn rtp_inplace_is_launcher_invariant() {
    for n in [2, 4] {
        assert_bit_identical(Strategy::RtpInplace, n);
    }
}

#[test]
fn rtp_outofplace_is_launcher_invariant() {
    for n in [2, 4] {
        assert_bit_identical(Strategy::RtpOutOfPlace, n);
    }
}

#[test]
fn rtp_moe_is_launcher_invariant() {
    let (l_loss, l_p, l_g) = run("tiny-moe", Strategy::RtpInplace, 2, Launcher::Lockstep, 2);
    let (t_loss, t_p, t_g) = run("tiny-moe", Strategy::RtpInplace, 2, Launcher::Thread, 2);
    assert_eq!(l_loss, t_loss);
    assert_eq!(l_p, t_p);
    assert_eq!(l_g, t_g);
}

#[test]
fn virtual_mode_peaks_are_launcher_invariant() {
    // memory accounting is per-rank state — scheduling must not move peaks
    for strategy in [Strategy::Fsdp, Strategy::RtpInplace, Strategy::RtpOutOfPlace] {
        let peak = |launcher: Launcher| {
            let opts = EngineOpts::new("gpt2-117m", strategy, 4, 8)
                .exec(ExecKind::Virtual)
                .launcher(launcher);
            let cfg = opts.cfg().unwrap();
            let mut e = build_engine(&opts).unwrap();
            let b = Batch {
                ids: rtp::tensor::IntTensor::zeros(&[8, cfg.seq]),
                targets: rtp::tensor::IntTensor::zeros(&[8, cfg.seq]),
            };
            e.step(&b).unwrap();
            (e.ctx().cluster.max_peak(), e.ctx().cluster.total_peak())
        };
        assert_eq!(
            peak(Launcher::Lockstep),
            peak(Launcher::Thread),
            "{strategy}: peaks diverge across launchers"
        );
    }
}

#[test]
fn fabric_concurrent_sends_no_deadlock_no_loss() {
    // every rank floods both links, then drains — under both policies
    for policy in [LaunchPolicy::Lockstep, LaunchPolicy::Threaded] {
        let n = 8;
        let k = 500usize;
        let fab = RingFabric::new(n);
        let tasks: Vec<Box<dyn FnOnce() -> u64 + Send>> = (0..n)
            .map(|r| {
                let port = fab.port(r);
                Box::new(move || {
                    let mut checksum = 0u64;
                    for i in 0..k {
                        port.send(port.next(), (r, i));
                        port.send(port.prev(), (r, i));
                    }
                    for i in 0..k {
                        let (src, seq): (usize, usize) = port.recv(port.prev());
                        assert_eq!((src, seq), (port.prev(), i), "cw link reordered");
                        checksum += (src + seq) as u64;
                        let (src, seq): (usize, usize) = port.recv(port.next());
                        assert_eq!((src, seq), (port.next(), i), "ccw link reordered");
                        checksum += (src + seq) as u64;
                    }
                    checksum
                }) as Box<dyn FnOnce() -> u64 + Send>
            })
            .collect();
        let sums = fab.run_round(policy, tasks);
        assert_eq!(sums.len(), n);
        assert_eq!(fab.in_flight(), 0, "{policy:?}: messages left in flight");
        assert_eq!(fab.messages_sent(), (2 * n * k) as u64);
        assert_eq!(fab.messages_delivered(), (2 * n * k) as u64);
    }
}
