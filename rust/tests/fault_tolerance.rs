//! Elastic fault tolerance, end to end: deterministic fault injection
//! across {engine} x {phase} x {launcher}, orderly typed failure
//! propagation (no hangs, no leaked fabric messages), and re-sharded
//! resume — a run killed at world size N continues at a new world size
//! N' bit-identically to an uninterrupted run at N'.

use rtp::comm::TransportKind;
use rtp::config::{presets, OptimizerKind, Strategy};
use rtp::parallel::{build_engine, Engine, EngineOpts, ExecKind, Launcher};
use rtp::runtime::{FailureKind, FaultPhase, FaultPlan, ProcessClusterEngine, RankFailure};
use rtp::train::{
    capture_train_state, load_train_state, restore_train_state, save_train_state,
    MarkovCorpus, Optimizer,
};

fn mk(
    preset: &str,
    strategy: Strategy,
    n: usize,
    gb: usize,
    launcher: Launcher,
    plan: Option<FaultPlan>,
) -> Box<dyn Engine> {
    build_engine(
        &EngineOpts::new(preset, strategy, n, gb)
            .exec(ExecKind::Oracle)
            .launcher(launcher)
            .fault_plan(plan),
    )
    .unwrap()
}

/// `steps` training steps; returns the per-step losses (bit-comparable).
fn train(
    eng: &mut dyn Engine,
    opt: &mut Optimizer,
    corpus: &mut MarkovCorpus,
    gb: usize,
    steps: usize,
) -> Vec<f32> {
    (0..steps)
        .map(|_| {
            let b = corpus.next_batch(gb);
            eng.zero_grads();
            let loss = eng.step(&b).unwrap();
            opt.step(&mut *eng);
            loss
        })
        .collect()
}

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("rtp-ft-{name}-{}", std::process::id()))
}

// ---------------------------------------------------------------------
// injection matrix: every phase on every engine under both launchers
// surfaces as ONE typed RankFailure at the step barrier — never a
// watchdog panic, never a hang, never a leaked in-flight message.
// ---------------------------------------------------------------------

fn matrix() -> Vec<(Strategy, Vec<FaultPhase>)> {
    use FaultPhase::*;
    vec![
        (Strategy::Single, vec![Forward, Backward]),
        (Strategy::Ddp, vec![Forward, Backward, CollectiveHop]),
        (Strategy::Fsdp, vec![Forward, Backward, CollectiveHop]),
        (Strategy::MegatronTp, vec![Forward, Backward]),
        (Strategy::RtpInplace, vec![Forward, Backward, RotationHop, CollectiveHop]),
        (Strategy::RtpOutOfPlace, vec![Forward, Backward, RotationHop, CollectiveHop]),
    ]
}

fn assert_injection(strategy: Strategy, phase: FaultPhase, launcher: Launcher) {
    let n = if strategy == Strategy::Single { 1 } else { 2 };
    let victim = n - 1;
    let plan = FaultPlan { rank: victim, step: 1, phase };
    let mut eng = mk("tiny", strategy, n, 4, launcher, Some(plan));
    let cfg = presets::get("tiny").unwrap();
    let mut corpus = MarkovCorpus::new(&cfg, 7);

    // step 0 is healthy — the kill hits a warmed-up engine
    let b = corpus.next_batch(4);
    eng.zero_grads();
    eng.step(&b).unwrap();

    let b = corpus.next_batch(4);
    eng.zero_grads();
    let err = eng
        .step(&b)
        .expect_err(&format!("{strategy}/{phase}/{launcher}: injected death must fail the step"));
    let f = err
        .downcast_ref::<RankFailure>()
        .unwrap_or_else(|| panic!("{strategy}/{phase}/{launcher}: untyped error: {err:#}"));
    assert_eq!(f.failed_rank, victim, "{strategy}/{phase}/{launcher}");
    assert_eq!(
        f.kind,
        FailureKind::Injected { phase },
        "{strategy}/{phase}/{launcher}: wrong failure kind: {f}"
    );
    // orderly teardown: the poisoned round drained every lane
    assert_eq!(
        eng.ctx().cluster.fabric().in_flight(),
        0,
        "{strategy}/{phase}/{launcher}: leaked in-flight messages"
    );
}

#[test]
fn injected_death_is_typed_under_lockstep() {
    for (strategy, phases) in matrix() {
        for phase in phases {
            assert_injection(strategy, phase, Launcher::Lockstep);
        }
    }
}

#[test]
fn injected_death_is_typed_under_thread_launcher() {
    for (strategy, phases) in matrix() {
        for phase in phases {
            assert_injection(strategy, phase, Launcher::Thread);
        }
    }
}

/// The determinism half of the harness contract: a plan whose
/// coordinates never match is indistinguishable — bitwise — from no
/// plan at all.
#[test]
fn unmatched_fault_plan_is_bit_identical_to_no_plan() {
    for strategy in [Strategy::Ddp, Strategy::RtpInplace] {
        let run = |plan: Option<FaultPlan>| {
            let mut eng = mk("tiny", strategy, 2, 4, Launcher::Lockstep, plan);
            let cfg = presets::get("tiny").unwrap();
            let mut corpus = MarkovCorpus::new(&cfg, 5);
            let mut opt = Optimizer::new(OptimizerKind::Adam, 1e-2);
            let losses = train(&mut *eng, &mut opt, &mut corpus, 4, 3);
            (losses, eng.gather_params())
        };
        let (la, pa) = run(None);
        let never = FaultPlan { rank: 0, step: u64::MAX - 1, phase: FaultPhase::Forward };
        let (lb, pb) = run(Some(never));
        assert_eq!(la, lb, "{strategy}: losses diverged under an unmatched plan");
        assert_eq!(pa.max_abs_diff(&pb), 0.0, "{strategy}: params diverged");
    }
}

// ---------------------------------------------------------------------
// resume: same world size, bit-identical continuation
// ---------------------------------------------------------------------

fn assert_same_n_resume(strategy: Strategy, launcher: Launcher, tag: &str) {
    let (n, gb) = if strategy == Strategy::Single { (1, 4) } else { (2, 4) };
    let cfg = presets::get("tiny").unwrap();
    let fresh = || mk("tiny", strategy, n, gb, launcher, None);

    // uninterrupted 6-step reference
    let mut eng_a = fresh();
    let mut opt_a = Optimizer::new(OptimizerKind::Adam, 1e-2);
    let mut corpus_a = MarkovCorpus::new(&cfg, 7);
    let losses_a = train(&mut *eng_a, &mut opt_a, &mut corpus_a, gb, 6);

    // 3 steps, checkpoint through disk, resume into a FRESH engine
    let mut eng_b = fresh();
    let mut opt_b = Optimizer::new(OptimizerKind::Adam, 1e-2);
    let mut corpus_b = MarkovCorpus::new(&cfg, 7);
    train(&mut *eng_b, &mut opt_b, &mut corpus_b, gb, 3);
    let state = capture_train_state(&mut *eng_b, &opt_b, &corpus_b, 3).unwrap();
    let path = tmp(&format!("same-n-{strategy}-{tag}"));
    save_train_state(&state, &path).unwrap();
    let loaded = load_train_state(&cfg, &path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded.step, 3);

    let mut eng_c = fresh();
    let mut opt_c = Optimizer::new(OptimizerKind::Adam, 999.0); // restore overwrites lr
    let mut corpus_c = restore_train_state(&mut *eng_c, &mut opt_c, &cfg, &loaded).unwrap();
    assert_eq!(opt_c.lr, 1e-2);
    let losses_c = train(&mut *eng_c, &mut opt_c, &mut corpus_c, gb, 3);

    assert_eq!(
        &losses_a[3..],
        &losses_c[..],
        "{strategy}/{launcher}: resumed losses diverged from uninterrupted run"
    );
    assert_eq!(
        eng_a.gather_params().max_abs_diff(&eng_c.gather_params()),
        0.0,
        "{strategy}/{launcher}: resumed params diverged"
    );
}

#[test]
fn same_world_size_resume_is_bitwise_for_every_engine() {
    for strategy in [
        Strategy::Single,
        Strategy::Ddp,
        Strategy::Fsdp,
        Strategy::MegatronTp,
        Strategy::RtpInplace,
        Strategy::RtpOutOfPlace,
    ] {
        assert_same_n_resume(strategy, Launcher::Lockstep, "lock");
    }
}

#[test]
fn same_world_size_resume_is_bitwise_under_thread_launcher() {
    for strategy in [Strategy::Ddp, Strategy::RtpOutOfPlace] {
        assert_same_n_resume(strategy, Launcher::Thread, "thr");
    }
}

// ---------------------------------------------------------------------
// resume: NEW world size. The state is world-size independent, so
// re-sharding through each engine's own `load_full` must be lossless:
// capture at N' returns the exact bytes captured at N.
// ---------------------------------------------------------------------

#[test]
fn cross_world_size_reshard_roundtrips_params_and_moments_exactly() {
    // (strategy, preset, n_from, n_to, global_batch)
    let cases = [
        (Strategy::Ddp, "tiny", 4usize, 3usize, 12usize),
        (Strategy::Fsdp, "tiny", 4, 2, 8),
        (Strategy::MegatronTp, "tiny-wide", 4, 8, 8),
        (Strategy::RtpInplace, "tiny-moe", 2, 4, 8),
        (Strategy::RtpOutOfPlace, "tiny-wide", 2, 4, 8),
    ];
    for (strategy, preset, n_from, n_to, gb) in cases {
        let cfg = presets::get(preset).unwrap();
        let mut eng = mk(preset, strategy, n_from, gb, Launcher::Lockstep, None);
        let mut opt = Optimizer::new(OptimizerKind::Adam, 1e-2);
        let mut corpus = MarkovCorpus::new(&cfg, 13);
        train(&mut *eng, &mut opt, &mut corpus, gb, 3);
        let state = capture_train_state(&mut *eng, &opt, &corpus, 3).unwrap();

        let mut eng2 = mk(preset, strategy, n_to, gb, Launcher::Lockstep, None);
        let mut opt2 = Optimizer::new(OptimizerKind::Adam, 1.0);
        let corpus2 = restore_train_state(&mut *eng2, &mut opt2, &cfg, &state).unwrap();
        assert_eq!(opt2.step_count(), 3, "{strategy} {preset}");
        let state2 = capture_train_state(&mut *eng2, &opt2, &corpus2, state.step).unwrap();

        let tag = format!("{strategy} {preset} N={n_from}->{n_to}");
        assert_eq!(
            state.params.max_abs_diff(&state2.params),
            0.0,
            "{tag}: params not bit-exact through re-shard"
        );
        assert_eq!(state.moments.len(), state2.moments.len(), "{tag}");
        for (k, (a, b)) in state.moments.iter().zip(&state2.moments).enumerate() {
            assert_eq!(
                a.max_abs_diff(b),
                0.0,
                "{tag}: optimizer moment {k} not bit-exact through re-shard"
            );
        }
        assert_eq!(state.corpus, state2.corpus, "{tag}: corpus cursor drifted");
    }
}

// ---------------------------------------------------------------------
// the full elastic story: train at N, get killed by an injected rank
// death, rebuild at N' from the last checkpoint — the recovered run is
// bit-identical to a never-faulted run resumed at N' from the same
// checkpoint.
// ---------------------------------------------------------------------

#[test]
fn killed_at_n_resumes_at_new_world_size_bit_identically() {
    // (strategy, preset, n_from, n_to, global_batch) — gb divides both N
    let cases = [
        (Strategy::Ddp, "tiny", 4usize, 3usize, 12usize),
        (Strategy::Fsdp, "tiny", 4, 8, 8),
        (Strategy::MegatronTp, "tiny-wide", 4, 8, 8),
        (Strategy::RtpInplace, "tiny-wide", 4, 2, 8),
        (Strategy::RtpOutOfPlace, "tiny-wide", 4, 2, 8),
    ];
    for (strategy, preset, n_from, n_to, gb) in cases {
        let cfg = presets::get(preset).unwrap();
        let tag = format!("{strategy} {preset} N={n_from}->{n_to}");

        // phase 1: train at N and checkpoint to disk
        let mut eng0 = mk(preset, strategy, n_from, gb, Launcher::Lockstep, None);
        let mut opt0 = Optimizer::new(OptimizerKind::Adam, 1e-2);
        let mut corpus0 = MarkovCorpus::new(&cfg, 17);
        train(&mut *eng0, &mut opt0, &mut corpus0, gb, 3);
        let state = capture_train_state(&mut *eng0, &opt0, &corpus0, 3).unwrap();
        let path = tmp(&format!("elastic-{strategy}-{preset}-{n_from}-{n_to}"));
        save_train_state(&state, &path).unwrap();

        // reference: never-faulted resume at N'
        let loaded = load_train_state(&cfg, &path).unwrap();
        let mut eng_r = mk(preset, strategy, n_to, gb, Launcher::Lockstep, None);
        let mut opt_r = Optimizer::new(OptimizerKind::Adam, 1.0);
        let mut corpus_r =
            restore_train_state(&mut *eng_r, &mut opt_r, &cfg, &loaded).unwrap();
        let losses_r = train(&mut *eng_r, &mut opt_r, &mut corpus_r, gb, 3);

        // faulted: resume at N, die on the second post-resume step
        let plan = FaultPlan { rank: 1, step: 1, phase: FaultPhase::Backward };
        let mut eng_f = mk(preset, strategy, n_from, gb, Launcher::Lockstep, Some(plan));
        let mut opt_f = Optimizer::new(OptimizerKind::Adam, 1.0);
        let mut corpus_f =
            restore_train_state(&mut *eng_f, &mut opt_f, &cfg, &loaded).unwrap();
        let b = corpus_f.next_batch(gb);
        eng_f.zero_grads();
        eng_f.step(&b).unwrap();
        opt_f.step(&mut *eng_f);
        let b = corpus_f.next_batch(gb);
        eng_f.zero_grads();
        let err = eng_f.step(&b).expect_err("planned death must fail the step");
        let f = err
            .downcast_ref::<RankFailure>()
            .unwrap_or_else(|| panic!("{tag}: untyped failure: {err:#}"));
        assert_eq!(f.failed_rank, 1, "{tag}");
        assert_eq!(eng_f.ctx().cluster.fabric().in_flight(), 0, "{tag}");

        // recovery: rebuild at N' from the SAME checkpoint file
        let loaded2 = load_train_state(&cfg, &path).unwrap();
        std::fs::remove_file(&path).ok();
        let mut eng2 = mk(preset, strategy, n_to, gb, Launcher::Lockstep, None);
        let mut opt2 = Optimizer::new(OptimizerKind::Adam, 1.0);
        let mut corpus2 =
            restore_train_state(&mut *eng2, &mut opt2, &cfg, &loaded2).unwrap();
        let losses2 = train(&mut *eng2, &mut opt2, &mut corpus2, gb, 3);

        assert_eq!(
            losses_r, losses2,
            "{tag}: recovered loss trajectory diverged from never-faulted resume"
        );
        assert_eq!(
            eng_r.gather_params().max_abs_diff(&eng2.gather_params()),
            0.0,
            "{tag}: recovered params diverged from never-faulted resume"
        );
    }
}

// ---------------------------------------------------------------------
// crash-atomic checkpointing: SIGKILL a trainer that is writing async
// snapshots as fast as it can — whatever instant the signal lands, the
// checkpoint at the target path is a COMPLETE previous write (the
// in-flight bytes only ever touch the tmp sibling, which rename swaps
// in whole). The previous checkpoint must load; a torn file must not
// exist.
// ---------------------------------------------------------------------

#[test]
fn sigkill_mid_async_checkpoint_leaves_previous_checkpoint_loadable() {
    let path = tmp("sigkill-ckpt");
    std::fs::remove_file(&path).ok();
    let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_rtp"))
        .args([
            "train",
            "--elastic",
            "--preset",
            "tiny",
            "--engine",
            "ddp",
            "--workers",
            "2",
            "--global-batch",
            "4",
            "--steps",
            "200000",
            "--ckpt-every",
            "1",
            "--quiet",
            "--save",
        ])
        .arg(&path)
        .stdout(std::process::Stdio::null())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawning elastic trainer");

    // wait for the first COMPLETED (renamed) checkpoint
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
    while !path.exists() {
        assert!(
            std::time::Instant::now() < deadline,
            "trainer produced no checkpoint within 60s"
        );
        if let Ok(Some(status)) = child.try_wait() {
            panic!("trainer exited before writing a checkpoint: {status}");
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    // let more writes race the step loop, then SIGKILL mid-stream
    std::thread::sleep(std::time::Duration::from_millis(100));
    child.kill().expect("SIGKILL trainer");
    child.wait().unwrap();

    let cfg = presets::get("tiny").unwrap();
    let state = load_train_state(&cfg, &path)
        .expect("checkpoint torn by SIGKILL — write_atomic contract broken");
    assert!(state.step >= 1, "loaded checkpoint has no completed steps");
    assert_eq!(state.world_size, 2);

    std::fs::remove_file(&path).ok();
    // the kill may strand the writer's tmp sibling — tolerated, cleaned
    let mut tmp_sibling = path.clone().into_os_string();
    tmp_sibling.push(".tmp");
    std::fs::remove_file(std::path::PathBuf::from(tmp_sibling)).ok();
}

// ---------------------------------------------------------------------
// Launcher::Process: the REAL fault the in-process injection harness
// simulates — a worker OS process SIGKILLed out from under the run.
// The parent must surface it as the same typed RankFailure the
// injection matrix produces (kind PeerExit, correct rank), promptly
// (no watchdog-length hang), and tear the run down without leaking the
// rendezvous dir and its shm ring segments.
// ---------------------------------------------------------------------

#[test]
fn process_sigkill_is_typed_peer_exit_with_no_leaked_segments() {
    std::env::set_var("RTP_WORKER_EXE", env!("CARGO_BIN_EXE_rtp"));
    let opts = EngineOpts::new("tiny", Strategy::Ddp, 4, 4)
        .exec(ExecKind::Oracle)
        .launcher(Launcher::Process)
        .transport(TransportKind::Shm);
    // short per-worker recv watchdog via the manifest (not process env):
    // survivors blocked on the dead peer must fail fast
    let mut eng = ProcessClusterEngine::build_with(&opts, 2_000, 1).unwrap();
    let dir = eng.endpoint_dir().to_path_buf();
    let cfg = presets::get("tiny").unwrap();
    let mut corpus = MarkovCorpus::new(&cfg, 7);

    // step 0 is healthy — the kill hits a warmed-up run
    let b = corpus.next_batch(4);
    eng.step(&b).unwrap();

    // SIGKILL rank 2's process from a side thread while the main thread
    // keeps stepping: the signal lands either mid-step (survivors poll
    // the dead-rank marker out of their fabric recv, the parent reaps
    // the corpse mid-collect) or between steps (reaped at the next
    // broadcast) — both paths must surface the SAME typed failure
    let victim_pid = eng.worker_pid(2).expect("rank 2 has a live worker");
    let killer = std::thread::spawn(move || {
        std::thread::sleep(std::time::Duration::from_millis(2));
        std::process::Command::new("kill")
            .args(["-KILL", &victim_pid.to_string()])
            .status()
            .expect("spawn kill(1)");
    });
    let t0 = std::time::Instant::now();
    let mut failure = None;
    for _ in 0..1000 {
        let b = corpus.next_batch(4);
        match eng.step(&b) {
            Ok(_) => continue,
            Err(e) => {
                failure = Some(e);
                break;
            }
        }
    }
    killer.join().unwrap();
    let err = failure.expect("SIGKILLed worker never failed a step");
    let f = err
        .downcast_ref::<RankFailure>()
        .unwrap_or_else(|| panic!("untyped failure from SIGKILL: {err:#}"));
    assert_eq!(f.failed_rank, 2, "wrong rank blamed: {f}");
    assert_eq!(f.kind, FailureKind::PeerExit, "wrong failure kind: {f}");
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(60),
        "death took {:?} to surface — hang?",
        t0.elapsed()
    );

    // teardown reclaims the rendezvous dir — manifest, control socket,
    // AND every shm ring segment lives under it, so existence is the
    // leak check
    assert!(dir.exists(), "endpoint dir vanished while the engine was live");
    drop(eng);
    assert!(
        !dir.exists(),
        "leaked rendezvous dir (shm segments): {}",
        dir.display()
    );
}
