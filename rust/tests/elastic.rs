//! The elastic supervisor, end to end: a supervised run that loses a
//! rank mid-training must quiesce, rebuild at N′ (shrink or respawn),
//! restore the latest async snapshot, and continue BIT-IDENTICALLY to a
//! never-faulted run resumed at N′ from the same snapshot — across every
//! parallel engine, under both in-process launchers, through double
//! faults, and with a bounded typed error (never a hang) once the
//! recovery budget is spent. Plus `Launcher::Process` recovery: a worker
//! OS process SIGKILLed out from under the run is replaced (or the run
//! shrinks to the survivors) via `ProcessClusterEngine::rebuild`, into
//! the SAME rendezvous dir over the SAME control listener, and the next
//! step matches the in-process oracle exactly.

use std::time::Duration;

use rtp::comm::TransportKind;
use rtp::config::{presets, OptimizerKind, Strategy};
use rtp::parallel::{build_engine, Batch, Engine, EngineOpts, ExecKind, Launcher};
use rtp::runtime::{
    FailureKind, FaultPhase, FaultPlan, ProcessClusterEngine, RankFailure, RecoveryMode,
    RecoveryPolicy, Supervisor, SupervisorReport,
};
use rtp::train::{
    capture_train_state, load_params, restore_train_state, save_params, MarkovCorpus,
    Optimizer, TrainState,
};
use rtp::util::rng::Rng;

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("rtp-el-{name}-{}", std::process::id()))
}

fn opts_for(
    preset: &str,
    strategy: Strategy,
    n: usize,
    gb: usize,
    launcher: Launcher,
) -> EngineOpts {
    EngineOpts::new(preset, strategy, n, gb)
        .exec(ExecKind::Oracle)
        .launcher(launcher)
        .seed(7)
}

/// Tight test policy: real backoff schedule, milliseconds not seconds.
fn policy(mode: RecoveryMode) -> RecoveryPolicy {
    RecoveryPolicy {
        mode,
        max_recoveries: 3,
        backoff_base: Duration::from_millis(1),
        backoff_cap: Duration::from_millis(4),
        rebuild_budget: Duration::from_secs(60),
    }
}

/// `steps` training steps; returns the per-step losses (bit-comparable).
fn train_steps(
    eng: &mut dyn Engine,
    opt: &mut Optimizer,
    corpus: &mut MarkovCorpus,
    gb: usize,
    steps: usize,
) -> Vec<f32> {
    (0..steps)
        .map(|_| {
            let b = corpus.next_batch(gb);
            eng.zero_grads();
            let loss = eng.step(&b).unwrap();
            opt.step(&mut *eng);
            loss
        })
        .collect()
}

/// Run the supervisor with incarnation-indexed fault plans and a
/// snapshot cadence of 2; return (report, final state read back from the
/// crash-atomic checkpoint).
fn supervised(
    opts: EngineOpts,
    mode: RecoveryMode,
    plans: Vec<Option<FaultPlan>>,
    steps: u64,
    tag: &str,
) -> (SupervisorReport, TrainState) {
    let path = tmp(tag);
    let mut sup = Supervisor::new(opts, OptimizerKind::Adam, 1e-2)
        .policy(policy(mode))
        .ckpt_every(2)
        .ckpt_path(Some(path.clone()))
        .fault_plans(plans);
    let out = sup
        .run_capturing(steps)
        .unwrap_or_else(|e| panic!("{tag}: supervised run failed: {e:#}"));
    std::fs::remove_file(&path).ok();
    out
}

/// The never-faulted oracle the supervisor must reproduce: run at `n0`,
/// and at each `(snapshot_step, n_next)` leg capture, rebuild a FRESH
/// engine at `n_next`, and restore through the world-size-independent
/// RTPC2 path — exactly what one recovery does. Returns the full loss
/// curve and the final capture at `steps`.
fn reference(
    preset: &str,
    strategy: Strategy,
    gb: usize,
    launcher: Launcher,
    n0: usize,
    legs: &[(u64, usize)],
    steps: u64,
) -> (Vec<f32>, TrainState) {
    let cfg = presets::get(preset).unwrap();
    let mk = |n: usize| build_engine(&opts_for(preset, strategy, n, gb, launcher)).unwrap();
    let mut eng = mk(n0);
    let mut opt = Optimizer::new(OptimizerKind::Adam, 1e-2);
    let mut corpus = MarkovCorpus::new(&cfg, 7);
    let mut losses: Vec<f32> = Vec::new();
    let mut done: u64 = 0;
    for &(snap_at, n_next) in legs {
        losses.extend(train_steps(
            &mut *eng,
            &mut opt,
            &mut corpus,
            gb,
            (snap_at - done) as usize,
        ));
        done = snap_at;
        let snap = capture_train_state(&mut *eng, &opt, &corpus, done).unwrap();
        eng = mk(n_next);
        opt = Optimizer::new(OptimizerKind::Adam, 1.0); // restore overwrites lr
        corpus = restore_train_state(&mut *eng, &mut opt, &cfg, &snap).unwrap();
    }
    losses.extend(train_steps(
        &mut *eng,
        &mut opt,
        &mut corpus,
        gb,
        (steps - done) as usize,
    ));
    let fin = capture_train_state(&mut *eng, &opt, &corpus, steps).unwrap();
    (losses, fin)
}

fn assert_states_bitwise(a: &TrainState, b: &TrainState, tag: &str) {
    assert_eq!(a.step, b.step, "{tag}: snapshot step");
    assert_eq!(a.params.max_abs_diff(&b.params), 0.0, "{tag}: params diverged");
    assert_eq!(a.moments.len(), b.moments.len(), "{tag}: moment count");
    for (k, (m, n)) in a.moments.iter().zip(&b.moments).enumerate() {
        assert_eq!(m.max_abs_diff(n), 0.0, "{tag}: optimizer moment {k} diverged");
    }
    assert_eq!(a.corpus, b.corpus, "{tag}: corpus cursor diverged");
}

// ---------------------------------------------------------------------
// supervisor without faults: a supervised run IS a plain run (the
// snapshot machinery must not perturb the trajectory), and every
// submitted snapshot is accounted written-or-skipped with the final one
// guaranteed durable.
// ---------------------------------------------------------------------

#[test]
fn supervised_run_without_faults_is_bitwise_plain_training() {
    let opts = opts_for("tiny", Strategy::RtpOutOfPlace, 2, 4, Launcher::Lockstep);
    let (report, state) =
        supervised(opts, RecoveryMode::Shrink, vec![], 5, "nofault");
    assert!(report.recoveries.is_empty());
    assert_eq!(report.final_workers, 2);
    assert_eq!(state.step, 5);

    let cfg = presets::get("tiny").unwrap();
    let mut eng =
        build_engine(&opts_for("tiny", Strategy::RtpOutOfPlace, 2, 4, Launcher::Lockstep))
            .unwrap();
    let mut opt = Optimizer::new(OptimizerKind::Adam, 1e-2);
    let mut corpus = MarkovCorpus::new(&cfg, 7);
    let losses = train_steps(&mut *eng, &mut opt, &mut corpus, 4, 5);
    assert_eq!(report.losses, losses, "supervision changed the trajectory");
    let fin = capture_train_state(&mut *eng, &opt, &corpus, 5).unwrap();
    assert_states_bitwise(&state, &fin, "nofault");

    // seed (step 0) + periodic (2, 4) + final (5) — and the final submit
    // is the blocking variant, so at least it is always written
    assert_eq!(report.ckpt.submitted, 4, "snapshot cadence drifted");
    assert!(report.ckpt.written >= 1, "final snapshot never reached disk");
    assert_eq!(
        report.ckpt.written + report.ckpt.skipped,
        report.ckpt.submitted,
        "snapshots unaccounted for"
    );
}

// ---------------------------------------------------------------------
// one rank death, every engine: the recovered trajectory is bit-identical
// to a never-faulted run restored at N′ from the same snapshot.
// ---------------------------------------------------------------------

/// Fault at engine step 3 (snapshot exists at step 2), 6 steps total.
fn assert_recovers_bitwise(
    preset: &str,
    strategy: Strategy,
    n_from: usize,
    n_to: usize,
    gb: usize,
    launcher: Launcher,
    mode: RecoveryMode,
) {
    let tag = format!("{strategy}-{preset}-{n_from}to{n_to}-{mode}-{launcher}");
    let plan = FaultPlan { rank: 1, step: 3, phase: FaultPhase::Backward };
    let opts = opts_for(preset, strategy, n_from, gb, launcher);
    let (report, state) = supervised(opts, mode, vec![Some(plan)], 6, &tag);

    assert_eq!(report.recoveries.len(), 1, "{tag}: expected exactly one recovery");
    let ev = &report.recoveries[0];
    assert_eq!(ev.at_step, 3, "{tag}");
    assert_eq!(ev.failed_rank, 1, "{tag}");
    assert_eq!(ev.from_workers, n_from, "{tag}");
    assert_eq!(ev.to_workers, n_to, "{tag}");
    assert_eq!(ev.resumed_from_step, 2, "{tag}: wrong snapshot chosen");
    assert_eq!(report.final_workers, n_to, "{tag}");
    assert_eq!(report.losses.len(), 6, "{tag}");
    assert_eq!(state.step, 6, "{tag}");

    let (ref_losses, ref_state) =
        reference(preset, strategy, gb, launcher, n_from, &[(2, n_to)], 6);
    assert_eq!(
        report.losses, ref_losses,
        "{tag}: recovered loss trajectory diverged from a fresh resume at N'"
    );
    assert_states_bitwise(&state, &ref_state, &tag);
}

#[test]
fn shrink_recovery_is_bitwise_for_every_engine_under_lockstep() {
    // (strategy, preset, n_from, n_to, global_batch) — n_to is the
    // LARGEST valid world size below n_from (shrink_target's pick)
    let cases = [
        (Strategy::Ddp, "tiny", 4usize, 3usize, 12usize),
        (Strategy::Fsdp, "tiny", 4, 2, 8),
        (Strategy::MegatronTp, "tiny-wide", 4, 2, 8),
        (Strategy::RtpInplace, "tiny-wide", 4, 2, 8),
        (Strategy::RtpOutOfPlace, "tiny-wide", 4, 2, 8),
    ];
    for (strategy, preset, n_from, n_to, gb) in cases {
        assert_recovers_bitwise(
            preset,
            strategy,
            n_from,
            n_to,
            gb,
            Launcher::Lockstep,
            RecoveryMode::Shrink,
        );
    }
}

#[test]
fn respawn_recovery_is_bitwise_under_lockstep() {
    for (strategy, preset, gb) in [
        (Strategy::Ddp, "tiny", 8usize),
        (Strategy::RtpOutOfPlace, "tiny-wide", 8),
    ] {
        assert_recovers_bitwise(
            preset,
            strategy,
            4,
            4,
            gb,
            Launcher::Lockstep,
            RecoveryMode::Respawn,
        );
    }
}

#[test]
fn elastic_recovery_is_bitwise_under_thread_launcher() {
    assert_recovers_bitwise(
        "tiny",
        Strategy::Ddp,
        4,
        3,
        12,
        Launcher::Thread,
        RecoveryMode::Shrink,
    );
    assert_recovers_bitwise(
        "tiny",
        Strategy::RtpInplace,
        4,
        4,
        8,
        Launcher::Thread,
        RecoveryMode::Respawn,
    );
}

// ---------------------------------------------------------------------
// double fault: a SECOND rank dies on the rebuilt cluster. Within budget
// the run recovers twice (4 -> 3 -> 2 workers) and stays bit-identical
// to the two-leg reference; past the budget it surfaces the typed
// failure — bounded, never a hang.
// ---------------------------------------------------------------------

fn double_fault_plans() -> Vec<Option<FaultPlan>> {
    vec![
        // incarnation 0 dies at step 3 (snapshot at 2)...
        Some(FaultPlan { rank: 1, step: 3, phase: FaultPhase::Backward }),
        // ...and the REBUILT cluster dies at step 5 (snapshot at 4)
        Some(FaultPlan { rank: 0, step: 5, phase: FaultPhase::Forward }),
    ]
}

#[test]
fn second_death_during_recovered_run_recovers_again_bitwise() {
    let opts = opts_for("tiny", Strategy::Ddp, 4, 12, Launcher::Lockstep);
    let (report, state) =
        supervised(opts, RecoveryMode::Shrink, double_fault_plans(), 8, "double");

    assert_eq!(report.recoveries.len(), 2, "expected two recoveries");
    assert_eq!(report.recoveries[0].from_workers, 4);
    assert_eq!(report.recoveries[0].to_workers, 3);
    assert_eq!(report.recoveries[0].resumed_from_step, 2);
    assert_eq!(report.recoveries[1].from_workers, 3);
    assert_eq!(report.recoveries[1].to_workers, 2);
    assert_eq!(report.recoveries[1].at_step, 5);
    assert_eq!(report.recoveries[1].resumed_from_step, 4);
    assert_eq!(report.final_workers, 2);
    assert_eq!(report.losses.len(), 8);

    let (ref_losses, ref_state) = reference(
        "tiny",
        Strategy::Ddp,
        12,
        Launcher::Lockstep,
        4,
        &[(2, 3), (4, 2)],
        8,
    );
    assert_eq!(report.losses, ref_losses, "double-fault trajectory diverged");
    assert_states_bitwise(&state, &ref_state, "double");
}

#[test]
fn exhausted_recovery_budget_surfaces_typed_error_without_hanging() {
    let opts = opts_for("tiny", Strategy::Ddp, 4, 12, Launcher::Lockstep);
    let mut sup = Supervisor::new(opts, OptimizerKind::Adam, 1e-2)
        .policy(RecoveryPolicy { max_recoveries: 1, ..policy(RecoveryMode::Shrink) })
        .ckpt_every(2)
        .fault_plans(double_fault_plans());
    let t0 = std::time::Instant::now();
    let err = sup.run(8).expect_err("second death must exhaust max_recoveries=1");
    assert!(
        t0.elapsed() < Duration::from_secs(60),
        "budget exhaustion took {:?} — hang?",
        t0.elapsed()
    );
    let msg = format!("{err:#}");
    assert!(
        msg.contains("recovery budget exhausted"),
        "error does not name the budget: {msg}"
    );
    // the underlying typed failure rides the error chain
    let f = err
        .downcast_ref::<RankFailure>()
        .unwrap_or_else(|| panic!("untyped budget error: {msg}"));
    assert_eq!(f.failed_rank, 0, "wrong rank blamed for the second death");
}

// ---------------------------------------------------------------------
// Launcher::Process recovery: ProcessClusterEngine::rebuild respawns (or
// sheds) real worker OS processes into the SAME rendezvous dir and the
// post-recovery step matches the in-process Lockstep oracle bit-exactly.
// ---------------------------------------------------------------------

fn proc_engine(preset: &str, strategy: Strategy, n: usize, gb: usize) -> ProcessClusterEngine {
    // the workers must run THIS build's binary, not whatever `rtp` is on
    // PATH (idempotent across parallel tests — same value everywhere)
    std::env::set_var("RTP_WORKER_EXE", env!("CARGO_BIN_EXE_rtp"));
    let opts = EngineOpts::new(preset, strategy, n, gb)
        .exec(ExecKind::Oracle)
        .launcher(Launcher::Process)
        .transport(TransportKind::Shm)
        .seed(7);
    // short per-worker recv watchdog: survivors blocked on a dead peer
    // fail fast instead of waiting out the 20 s default
    ProcessClusterEngine::build_with(&opts, 2_000, 1).unwrap()
}

/// Step until the injected/real death surfaces; returns the error.
fn step_until_failure(
    eng: &mut ProcessClusterEngine,
    cfg: &rtp::config::ModelCfg,
    gb: usize,
    rng: &mut Rng,
) -> anyhow::Error {
    for _ in 0..1000 {
        let b = Batch::synth(cfg, gb, rng);
        if let Err(e) = eng.step(&b) {
            return e;
        }
    }
    panic!("killed worker never failed a step");
}

/// In-process Lockstep oracle at world size `n`, restored from the same
/// full-params checkpoint: one step on `batch` → (loss, grads).
fn oracle_step(
    preset: &str,
    strategy: Strategy,
    n: usize,
    gb: usize,
    ckpt: &std::path::Path,
    batch: &Batch,
) -> (f32, rtp::model::ModelParams) {
    let opts = opts_for(preset, strategy, n, gb, Launcher::Lockstep)
        .transport(TransportKind::Inproc);
    let cfg = opts.cfg().unwrap();
    let mut eng = build_engine(&opts).unwrap();
    eng.load_full(&load_params(&cfg, ckpt).unwrap()).unwrap();
    eng.zero_grads();
    let loss = eng.step(batch).unwrap();
    (loss, eng.gather_grads())
}

#[test]
fn process_rebuild_shrinks_to_survivors_bit_identically() {
    let (preset, gb) = ("tiny", 12usize);
    let cfg = presets::get(preset).unwrap();
    let mut eng = proc_engine(preset, Strategy::Ddp, 4, gb);
    let dir = eng.endpoint_dir().to_path_buf();
    let mut rng = Rng::new(7);

    // one healthy step, then checkpoint the full params
    let b = Batch::synth(&cfg, gb, &mut rng);
    eng.step(&b).unwrap();
    let params = eng.gather_params();
    let ckpt = tmp("proc-shrink");
    save_params(&params, &ckpt).unwrap();

    eng.kill_worker(3);
    let err = step_until_failure(&mut eng, &cfg, gb, &mut rng);
    let f = err
        .downcast_ref::<RankFailure>()
        .unwrap_or_else(|| panic!("untyped failure: {err:#}"));
    assert_eq!(f.failed_rank, 3);
    assert_eq!(f.kind, FailureKind::PeerExit);

    let t0 = std::time::Instant::now();
    eng.rebuild(3, &ckpt).unwrap();
    assert!(
        t0.elapsed() < Duration::from_secs(60),
        "rebuild took {:?} — hang?",
        t0.elapsed()
    );
    assert_eq!(eng.world_size(), 3);
    assert_eq!(eng.epoch(), 1);
    // the new epoch rendezvouses in a sub-dir of the SAME run dir
    let fdir = eng.current_fabric_dir();
    assert_ne!(fdir, dir, "epoch 1 must not reuse the poisoned epoch-0 dir");
    assert!(fdir.starts_with(&dir), "epoch dir escaped the run dir");

    // the restore is the checkpoint, bit-exact
    assert_eq!(
        eng.gather_params().max_abs_diff(&params),
        0.0,
        "rebuilt workers did not restore the init checkpoint"
    );

    // one post-recovery step must match the in-process oracle at N'=3
    let bx = Batch::synth(&cfg, gb, &mut Rng::new(99));
    eng.zero_grads();
    let loss_p = eng.step(&bx).unwrap();
    let grads_p = eng.gather_grads();
    let (loss_r, grads_r) = oracle_step(preset, Strategy::Ddp, 3, gb, &ckpt, &bx);
    assert_eq!(loss_p, loss_r, "post-rebuild loss diverged from the oracle");
    assert_eq!(grads_p.max_abs_diff(&grads_r), 0.0, "post-rebuild grads diverged");

    std::fs::remove_file(&ckpt).ok();
    drop(eng);
    assert!(!dir.exists(), "leaked rendezvous dir: {}", dir.display());
}

#[test]
fn process_rebuild_respawns_dead_rank_bit_identically() {
    let (preset, gb) = ("tiny", 4usize);
    let cfg = presets::get(preset).unwrap();
    let mut eng = proc_engine(preset, Strategy::RtpOutOfPlace, 4, gb);
    let mut rng = Rng::new(7);

    let b = Batch::synth(&cfg, gb, &mut rng);
    eng.step(&b).unwrap();
    let params = eng.gather_params();
    let ckpt = tmp("proc-respawn");
    save_params(&params, &ckpt).unwrap();
    let old_pids: Vec<u32> = (0..4).map(|r| eng.worker_pid(r).unwrap()).collect();

    eng.kill_worker(1);
    let err = step_until_failure(&mut eng, &cfg, gb, &mut rng);
    assert!(err.downcast_ref::<RankFailure>().is_some(), "untyped failure: {err:#}");

    eng.rebuild(4, &ckpt).unwrap();
    assert_eq!(eng.world_size(), 4);
    assert_eq!(eng.epoch(), 1);

    // survivors compact to ranks 0..3 in old-rank order (their OS
    // processes move with them); the respawn fills rank 3 with a NEW pid
    assert_eq!(eng.worker_pid(0), Some(old_pids[0]));
    assert_eq!(eng.worker_pid(1), Some(old_pids[2]));
    assert_eq!(eng.worker_pid(2), Some(old_pids[3]));
    let fresh = eng.worker_pid(3).expect("respawned rank has no worker");
    assert!(!old_pids.contains(&fresh), "rank 3 was not respawned");

    assert_eq!(eng.gather_params().max_abs_diff(&params), 0.0);
    let bx = Batch::synth(&cfg, gb, &mut Rng::new(99));
    eng.zero_grads();
    let loss_p = eng.step(&bx).unwrap();
    let grads_p = eng.gather_grads();
    let (loss_r, grads_r) = oracle_step(preset, Strategy::RtpOutOfPlace, 4, gb, &ckpt, &bx);
    assert_eq!(loss_p, loss_r, "post-respawn loss diverged from the oracle");
    assert_eq!(grads_p.max_abs_diff(&grads_r), 0.0, "post-respawn grads diverged");

    std::fs::remove_file(&ckpt).ok();
}

#[test]
fn process_rebuild_survives_two_simultaneous_deaths() {
    let (preset, gb) = ("tiny", 8usize);
    let cfg = presets::get(preset).unwrap();
    let mut eng = proc_engine(preset, Strategy::Fsdp, 4, gb);
    let mut rng = Rng::new(7);

    let b = Batch::synth(&cfg, gb, &mut rng);
    eng.step(&b).unwrap();
    let params = eng.gather_params();
    let ckpt = tmp("proc-double");
    save_params(&params, &ckpt).unwrap();

    eng.kill_worker(1);
    eng.kill_worker(2);
    // let both SIGKILLs land so the rebuild reaps BOTH corpses
    std::thread::sleep(Duration::from_millis(100));
    let err = step_until_failure(&mut eng, &cfg, gb, &mut rng);
    let f = err
        .downcast_ref::<RankFailure>()
        .unwrap_or_else(|| panic!("untyped failure: {err:#}"));
    assert!([1, 2].contains(&f.failed_rank), "wrong rank blamed: {f}");

    // respawn BOTH dead ranks: survivors 0,3 compact to 0,1
    eng.rebuild(4, &ckpt).unwrap();
    assert_eq!(eng.world_size(), 4);
    assert_eq!(eng.gather_params().max_abs_diff(&params), 0.0);

    let bx = Batch::synth(&cfg, gb, &mut Rng::new(99));
    eng.zero_grads();
    let loss_p = eng.step(&bx).unwrap();
    let (loss_r, grads_r) = oracle_step(preset, Strategy::Fsdp, 4, gb, &ckpt, &bx);
    assert_eq!(loss_p, loss_r, "double-death recovery diverged from the oracle");
    assert_eq!(eng.gather_grads().max_abs_diff(&grads_r), 0.0);

    std::fs::remove_file(&ckpt).ok();
}

#[test]
fn process_rebuild_with_no_survivors_is_a_typed_bounded_error() {
    let (preset, gb) = ("tiny", 8usize);
    let cfg = presets::get(preset).unwrap();
    let mut eng = proc_engine(preset, Strategy::Ddp, 4, gb);
    let dir = eng.endpoint_dir().to_path_buf();
    let mut rng = Rng::new(7);

    let b = Batch::synth(&cfg, gb, &mut rng);
    eng.step(&b).unwrap();
    let params = eng.gather_params();
    let ckpt = tmp("proc-wipeout");
    save_params(&params, &ckpt).unwrap();

    for r in 0..4 {
        eng.kill_worker(r);
    }
    std::thread::sleep(Duration::from_millis(150));
    let err = step_until_failure(&mut eng, &cfg, gb, &mut rng);
    assert!(err.downcast_ref::<RankFailure>().is_some(), "untyped failure: {err:#}");

    let t0 = std::time::Instant::now();
    let msg = format!("{:#}", eng.rebuild(2, &ckpt).expect_err("nobody left to rebuild"));
    assert!(
        t0.elapsed() < Duration::from_secs(60),
        "wipeout rebuild took {:?} — hang?",
        t0.elapsed()
    );
    assert!(msg.contains("no surviving workers"), "wrong error: {msg}");

    std::fs::remove_file(&ckpt).ok();
    drop(eng);
    assert!(!dir.exists(), "leaked rendezvous dir: {}", dir.display());
}
