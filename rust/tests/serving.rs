//! Serving-path integration tests: launcher-invariant token streams
//! with continuous-batching join/leave, decode-vs-full-forward parity,
//! and KV-cache accounting / admission control.

use rtp::config::{presets, ModelCfg, Strategy};
use rtp::memory::analytic::kv_cache_bytes_per_rank;
use rtp::memory::MemCategory;
use rtp::model::{oracle, MlpParams, ModelParams};
use rtp::parallel::Launcher;
use rtp::runtime::{FailureKind, FaultPhase, FaultPlan, RankFailure};
use rtp::serve::{
    build_serve_engine, build_serve_engine_with_params, Admission, GenRequest, ServeOpts,
};
use rtp::tensor::IntTensor;
use rtp::util::rng::Rng;

/// Staggered arrivals with mixed lengths: requests join while others
/// are mid-decode and leave at different steps — the continuous-batching
/// churn the equivalence matrix must survive.
fn staggered_trace(cfg: &ModelCfg) -> Vec<(u64, GenRequest)> {
    let mut rng = Rng::new(123);
    let spec: [(u64, usize, usize); 6] =
        [(0, 3, 6), (1, 2, 9), (2, 4, 3), (5, 3, 7), (6, 2, 2), (9, 5, 4)];
    spec.iter()
        .enumerate()
        .map(|(i, &(step, prompt_len, max_new))| {
            let prompt = (0..prompt_len).map(|_| rng.below(cfg.vocab) as i32).collect();
            (step, GenRequest { id: i as u64, prompt, max_new })
        })
        .collect()
}

fn run_stream(strategy: Strategy, n: usize, launcher: Launcher) -> Vec<(u64, Vec<i32>)> {
    let cfg = presets::get("tiny").unwrap();
    let opts = ServeOpts::new("tiny")
        .strategy(strategy)
        .workers(n)
        .max_batch(3)
        .page_tokens(4)
        .seed(9)
        .launcher(launcher);
    let mut eng = build_serve_engine(&opts).unwrap();
    eng.run_trace(&staggered_trace(&cfg)).unwrap();
    let rep = eng.report();
    assert_eq!(rep.finished.len(), 6);
    assert!(rep.rejected.is_empty());
    let mut out: Vec<(u64, Vec<i32>)> =
        rep.finished.iter().map(|f| (f.id, f.tokens.clone())).collect();
    out.sort_by_key(|(id, _)| *id);
    out
}

/// The determinism contract of the tentpole: bit-identical token
/// streams under the Lockstep oracle and the threaded launcher, for
/// every sharded strategy at N ∈ {2, 4}, with join/leave churn.
#[test]
fn token_streams_launcher_invariant() {
    for strategy in
        [Strategy::MegatronTp, Strategy::RtpInplace, Strategy::RtpOutOfPlace]
    {
        for n in [2usize, 4] {
            let lock = run_stream(strategy, n, Launcher::Lockstep);
            let thr = run_stream(strategy, n, Launcher::Thread);
            assert_eq!(
                lock, thr,
                "{strategy} N={n}: Lockstep and Thread token streams diverged"
            );
            for (_, tokens) in &lock {
                assert!(!tokens.is_empty());
            }
        }
    }
}

/// Full-sequence oracle forward to logits (the reference path).
fn forward_logits(params: &ModelParams, cfg: &ModelCfg, ids: &[i32]) -> Vec<f32> {
    let idt = IntTensor::from_vec(&[1, cfg.seq], ids.to_vec());
    let mut x = oracle::emb_fwd(&idt, &params.wte, &params.wpe);
    for lp in &params.layers {
        let a = oracle::ln_fwd(&x, &lp.ln1_g, &lp.ln1_b);
        let mut part = oracle::attn_fwd(&a, &lp.wqkv, &lp.bqkv, &lp.wo, cfg.heads);
        part.add_row_broadcast(&lp.bo);
        part.add_assign(&x);
        let m = oracle::ln_fwd(&part, &lp.ln2_g, &lp.ln2_b);
        let (w1, b1, w2, b2) = match &lp.mlp {
            MlpParams::Dense { w1, b1, w2, b2 } => (w1, b1, w2, b2),
            _ => panic!("dense preset expected"),
        };
        let mut mo = oracle::mlp_fwd(&m, w1, b1, w2);
        mo.add_row_broadcast(b2);
        mo.add_assign(&part);
        x = mo;
    }
    let xf = oracle::ln_fwd(&x, &params.lnf_g, &params.lnf_b);
    oracle::lmhead_fwd(&xf, &params.wlm).data
}

/// Satellite 1's core claim, in tier-1: the incremental KV-cache decode
/// emits the exact argmax stream of the O(seq²) full re-forward.
#[test]
fn incremental_decode_matches_full_forward_argmax_stream() {
    let cfg = presets::get("tiny").unwrap();
    let params = ModelParams::init(&cfg, &mut Rng::new(5));
    let prompt_len = 4;
    let gen_len = cfg.seq - prompt_len;
    let mut rng = Rng::new(77);
    let prompt: Vec<i32> = (0..prompt_len).map(|_| rng.below(cfg.vocab) as i32).collect();

    let opts = ServeOpts::new("tiny")
        .strategy(Strategy::Single)
        .workers(1)
        .max_batch(1)
        .page_tokens(3); // deliberately not a divisor of seq
    let mut eng = build_serve_engine_with_params(&opts, &params).unwrap();
    assert_eq!(
        eng.submit(GenRequest { id: 0, prompt: prompt.clone(), max_new: gen_len }),
        Admission::Queued
    );
    eng.drain().unwrap();
    let fast = eng.report().finished[0].tokens.clone();
    assert_eq!(fast.len(), gen_len);

    let mut ids = vec![0i32; cfg.seq];
    ids[..prompt_len].copy_from_slice(&prompt);
    let mut reference = Vec::with_capacity(gen_len);
    for pos in prompt_len..prompt_len + gen_len {
        let logits = forward_logits(&params, &cfg, &ids);
        let row = &logits[(pos - 1) * cfg.vocab..pos * cfg.vocab];
        let next = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        reference.push(next as i32);
        ids[pos] = next as i32;
    }
    assert_eq!(fast, reference);
}

/// Continuous batching demonstrably joins and leaves at token
/// boundaries: a short late request is served entirely inside a long
/// request's lifetime, and a queued request joins only when a slot
/// frees.
#[test]
fn requests_join_and_leave_mid_batch() {
    let cfg = presets::get("tiny").unwrap();
    let mut rng = Rng::new(3);
    let mut prompt = |len: usize| -> Vec<i32> {
        (0..len).map(|_| rng.below(cfg.vocab) as i32).collect()
    };
    let trace = vec![
        (0u64, GenRequest { id: 0, prompt: prompt(2), max_new: 12 }),
        (3, GenRequest { id: 1, prompt: prompt(2), max_new: 2 }),
    ];
    let opts = ServeOpts::new("tiny")
        .strategy(Strategy::RtpInplace)
        .workers(2)
        .max_batch(2)
        .page_tokens(4);
    let mut eng = build_serve_engine(&opts).unwrap();
    eng.run_trace(&trace).unwrap();
    let rep = eng.report();
    assert_eq!(rep.finished.len(), 2);
    let long = rep.finished.iter().find(|f| f.id == 0).unwrap();
    let short = rep.finished.iter().find(|f| f.id == 1).unwrap();
    // the short request's whole life is strictly inside the long one's
    assert!(long.joined_step < short.joined_step);
    assert!(short.finish_step < long.finish_step);
    assert_eq!(short.tokens.len(), 2);
    assert_eq!(long.tokens.len(), 12);
}

/// Tracked KV bytes match the analytic closed form at every growth
/// stage, and everything is freed on retirement/shutdown.
#[test]
fn kv_accounting_matches_analytic() {
    for (strategy, n) in [(Strategy::Single, 1usize), (Strategy::MegatronTp, 2), (Strategy::RtpInplace, 2)]
    {
        let cfg = presets::get("tiny").unwrap();
        let page_tokens = 2;
        let opts = ServeOpts::new("tiny")
            .strategy(strategy)
            .workers(n)
            .max_batch(2)
            .page_tokens(page_tokens);
        let mut eng = build_serve_engine(&opts).unwrap();
        let (prompt_len, max_new) = (3usize, 4usize);
        let total_positions = prompt_len + max_new - 1;
        let mut rng = Rng::new(11);
        let prompt: Vec<i32> =
            (0..prompt_len).map(|_| rng.below(cfg.vocab) as i32).collect();
        eng.submit(GenRequest { id: 0, prompt, max_new });

        for k in 1..=3u64 {
            assert!(eng.step().unwrap());
            // k positions cached after k steps (one token fed per step)
            let want =
                kv_cache_bytes_per_rank(strategy, &cfg, k as usize, page_tokens, n as u64);
            for w in &eng.cluster().workers {
                assert_eq!(
                    w.tracker.live_of(MemCategory::KvCache),
                    want,
                    "{strategy} N={n} step {k}: tracked KV != analytic"
                );
            }
        }
        eng.drain().unwrap();
        let peak_want =
            kv_cache_bytes_per_rank(strategy, &cfg, total_positions, page_tokens, n as u64);
        for w in &eng.cluster().workers {
            assert_eq!(w.tracker.live_of(MemCategory::KvCache), 0);
            assert_eq!(w.tracker.peak_of(MemCategory::KvCache), peak_want);
        }
        eng.shutdown();
        for w in &eng.cluster().workers {
            assert_eq!(w.tracker.outstanding(), 0);
        }
    }
}

/// Admission control: an over-budget request is rejected at submit —
/// facade-side, without aborting the running batch — while requests
/// that fit keep flowing through the same budget.
#[test]
fn admission_rejects_over_budget_without_aborting_peers() {
    let cfg = presets::get("tiny").unwrap();
    let (strategy, n, page_tokens) = (Strategy::MegatronTp, 2usize, 2usize);
    // probe run to learn the fixed (weights + scratch) footprint
    let mk_opts = |capacity: Option<u64>| {
        ServeOpts::new("tiny")
            .strategy(strategy)
            .workers(n)
            .max_batch(2)
            .page_tokens(page_tokens)
            .capacity(capacity)
    };
    let probe = build_serve_engine(&mk_opts(None)).unwrap();
    let base = probe.cluster().workers[0].tracker.live();

    // budget fits exactly one small request (3 positions)
    let small_bytes = kv_cache_bytes_per_rank(strategy, &cfg, 3, page_tokens, n as u64);
    let mut eng = build_serve_engine(&mk_opts(Some(base + small_bytes))).unwrap();
    assert_eq!(eng.kv_budget(), small_bytes);

    let mut rng = Rng::new(21);
    let mut prompt = |len: usize| -> Vec<i32> {
        (0..len).map(|_| rng.below(cfg.vocab) as i32).collect()
    };
    let small = GenRequest { id: 0, prompt: prompt(2), max_new: 2 };
    assert_eq!(eng.submit(small), Admission::Queued);
    assert!(eng.step().unwrap()); // small is now running

    // a request that could never fit alone: rejected immediately, and
    // the running peer is untouched
    let big = GenRequest { id: 1, prompt: prompt(4), max_new: 8 };
    assert!(matches!(eng.submit(big), Admission::Rejected(_)));
    assert_eq!(eng.running_len(), 1);

    // a second small request fits the budget but must wait for the
    // first to retire (head-of-line admission is budget-serialized)
    let small2 = GenRequest { id: 2, prompt: prompt(2), max_new: 2 };
    assert_eq!(eng.submit(small2), Admission::Queued);
    eng.drain().unwrap();
    let rep = eng.report();
    assert_eq!(rep.finished.len(), 2);
    assert_eq!(rep.rejected.len(), 1);
    assert_eq!(rep.rejected[0].0, 1);
    for f in &rep.finished {
        assert_eq!(f.tokens.len(), 2);
    }
}

/// Serving robustness: a rank dying mid-decode fails the running batch
/// with a typed `RankFailure` — not a hang, not a bare panic — releases
/// every KV page on every rank, and REQUEUES the interrupted requests
/// (admission order, queue front) instead of rejecting them, so a
/// recovered engine can finish them.
#[test]
fn rank_death_mid_decode_requeues_batch_without_leaking_kv() {
    for launcher in [Launcher::Lockstep, Launcher::Thread] {
        let cfg = presets::get("tiny").unwrap();
        let plan = FaultPlan { rank: 1, step: 2, phase: FaultPhase::Decode };
        let opts = ServeOpts::new("tiny")
            .strategy(Strategy::MegatronTp)
            .workers(2)
            .max_batch(2)
            .page_tokens(4)
            .launcher(launcher)
            .fault_plan(Some(plan));
        let mut eng = build_serve_engine(&opts).unwrap();
        let mut rng = Rng::new(17);
        for id in 0..2u64 {
            let prompt = (0..3).map(|_| rng.below(cfg.vocab) as i32).collect();
            assert_eq!(
                eng.submit(GenRequest { id, prompt, max_new: 6 }),
                Admission::Queued
            );
        }
        assert!(eng.step().unwrap()); // scheduler step 0
        assert!(eng.step().unwrap()); // scheduler step 1
        let err = eng.step().expect_err("planned decode death must fail the step");
        let f = err
            .downcast_ref::<RankFailure>()
            .unwrap_or_else(|| panic!("{launcher}: untyped serving failure: {err:#}"));
        assert_eq!(f.failed_rank, 1, "{launcher}");
        assert_eq!(
            f.kind,
            FailureKind::Injected { phase: FaultPhase::Decode },
            "{launcher}"
        );
        // the whole batch is unwound into the queue, zero KV leaked
        assert_eq!(eng.running_len(), 0, "{launcher}");
        assert_eq!(eng.queued_len(), 2, "{launcher}: interrupted requests requeue");
        for w in &eng.cluster().workers {
            assert_eq!(
                w.tracker.live_of(MemCategory::KvCache),
                0,
                "{launcher}: leaked KV pages after rank death"
            );
        }
        assert_eq!(eng.cluster().fabric().in_flight(), 0, "{launcher}");
        assert!(eng.report().rejected.is_empty(), "{launcher}");
        eng.shutdown();
        for w in &eng.cluster().workers {
            assert_eq!(w.tracker.outstanding(), 0, "{launcher}");
        }
    }
}

/// Elastic serving: after the typed failure, `recover()` rebuilds the
/// decode ranks from the retained weights and a plain `drain` finishes
/// every request — with token streams bit-identical to a run that never
/// faulted.
#[test]
fn serve_recovers_after_rank_death_with_identical_tokens() {
    let cfg = presets::get("tiny").unwrap();
    let mk_reqs = |cfg: &ModelCfg| -> Vec<GenRequest> {
        let mut rng = Rng::new(17);
        (0..3u64)
            .map(|id| GenRequest {
                id,
                prompt: (0..3).map(|_| rng.below(cfg.vocab) as i32).collect(),
                max_new: 5,
            })
            .collect()
    };

    // reference: the same workload with no fault
    let ref_opts = ServeOpts::new("tiny")
        .strategy(Strategy::RtpInplace)
        .workers(2)
        .max_batch(2)
        .page_tokens(4)
        .seed(9)
        .fault_plan(None);
    let mut reference = build_serve_engine(&ref_opts).unwrap();
    for req in mk_reqs(&cfg) {
        assert_eq!(reference.submit(req), Admission::Queued);
    }
    reference.drain().unwrap();
    let mut want: Vec<(u64, Vec<i32>)> = reference
        .report()
        .finished
        .iter()
        .map(|f| (f.id, f.tokens.clone()))
        .collect();
    want.sort_by_key(|(id, _)| *id);

    // faulted run: rank 1 dies at scheduler step 2, engine recovers
    let opts = ref_opts
        .clone()
        .fault_plan(Some(FaultPlan { rank: 1, step: 2, phase: FaultPhase::Decode }));
    let mut eng = build_serve_engine(&opts).unwrap();
    for req in mk_reqs(&cfg) {
        assert_eq!(eng.submit(req), Admission::Queued);
    }
    let err = eng.drain().expect_err("planned decode death must surface");
    assert!(err.downcast_ref::<RankFailure>().is_some(), "untyped: {err:#}");
    eng.recover().unwrap();
    eng.drain().unwrap();
    let rep = eng.report();
    assert_eq!(rep.finished.len(), 3);
    assert!(rep.rejected.is_empty());
    let mut got: Vec<(u64, Vec<i32>)> =
        rep.finished.iter().map(|f| (f.id, f.tokens.clone())).collect();
    got.sort_by_key(|(id, _)| *id);
    assert_eq!(got, want, "recovered token streams must match the unfaulted run");
    eng.shutdown();
    for w in &eng.cluster().workers {
        assert_eq!(w.tracker.outstanding(), 0);
    }
}

/// KV allocation churn is exactly the page schedule: per finished
/// request, `layers * ceil(total_positions / page_tokens)` tracker
/// allocations — nothing extra on the hot path.
#[test]
fn kv_allocs_per_token_is_page_schedule() {
    let cfg = presets::get("tiny").unwrap();
    let (prompt_len, max_new, page_tokens) = (4usize, 6usize, 4usize);
    let opts = ServeOpts::new("tiny")
        .strategy(Strategy::RtpInplace)
        .workers(2)
        .max_batch(2)
        .page_tokens(page_tokens);
    let mut eng = build_serve_engine(&opts).unwrap();
    let mut rng = Rng::new(8);
    for id in 0..3u64 {
        let prompt = (0..prompt_len).map(|_| rng.below(cfg.vocab) as i32).collect();
        eng.submit(GenRequest { id, prompt, max_new });
    }
    eng.drain().unwrap();
    let rep = eng.report();
    let total_positions = prompt_len + max_new - 1;
    let pages_per_req = cfg.layers * total_positions.div_ceil(page_tokens);
    let want = (3 * pages_per_req) as f64 / (3 * max_new) as f64;
    assert_eq!(rep.kv_allocs_per_token, want);
    assert_eq!(rep.tokens, 3 * max_new as u64);
}
