//! The pluggable-transport contract across every backend that can carry
//! a fabric link — in-process lanes (`inproc`), the shared-memory SPSC
//! ring (`shm`) and the Unix-socket reference (`uds`):
//!
//! * the data plane is BIT-IDENTICAL: the same engine on the same seed
//!   produces the same losses/params/grads whichever bytes carry the
//!   hops (the transport moves payloads, the lanes keep FIFO order);
//! * watchdog diagnostics are uniform: a stalled link panics with the
//!   link identity AND the backend name, and the
//!   `set_recv_timeout`/`set_recv_retries` overrides are honored the
//!   same way on every backend;
//! * shm hygiene: a fabric that owns ring segments removes them (and
//!   their directory) on drop — no `/dev/shm` litter per run.

use std::time::{Duration, Instant};

use rtp::comm::{self, LaunchPolicy, RingFabric, RotationDir, TransportKind};
use rtp::config::Strategy;
use rtp::model::ModelParams;
use rtp::parallel::{build_engine, Batch, EngineOpts, ExecKind, Launcher};
use rtp::util::rng::Rng;

const KINDS: [TransportKind; 3] =
    [TransportKind::Inproc, TransportKind::Shm, TransportKind::Uds];

#[test]
fn rotation_roundtrips_exactly_on_every_backend() {
    // n full ring revolutions return every buffer to its owner bit-exact.
    // 4096-elem frames x enough hops to wrap the shm ring several times
    // (default ring 1 MiB, 16 KiB frames), so the ring's head/tail
    // arithmetic is exercised past the wraparound boundary.
    for kind in KINDS {
        for n in [2usize, 4, 8] {
            let fab = RingFabric::with_transport(n, kind);
            let revs = 100usize;
            let out = comm::spmd_with(&fab, LaunchPolicy::Threaded, |port| {
                let r = port.rank();
                let mut buf: Vec<f32> =
                    (0..4096).map(|i| (r * 100_000 + i) as f32).collect();
                for _ in 0..revs * n {
                    buf = comm::rotate_ring_vec(&port, buf, RotationDir::Clockwise);
                }
                buf
            });
            for (r, buf) in out.iter().enumerate() {
                let want: Vec<f32> =
                    (0..4096).map(|i| (r * 100_000 + i) as f32).collect();
                assert_eq!(buf, &want, "{kind:?} n={n}: rotation corrupted rank {r}");
            }
            assert_eq!(fab.in_flight(), 0, "{kind:?} n={n}: messages left in flight");
        }
    }
}

#[test]
fn oversized_frames_roundtrip_on_every_backend() {
    // a frame larger than half the shm ring takes the jumbo side-file
    // path; the same payload must survive every backend byte-exact
    for kind in KINDS {
        let n = 2usize;
        let fab = RingFabric::with_transport(n, kind);
        let elems = 160_000usize; // 640 KB of f32 > half the 1 MiB ring
        let out = comm::spmd_with(&fab, LaunchPolicy::Threaded, |port| {
            let r = port.rank();
            let mut buf: Vec<f32> = (0..elems).map(|i| (r + i) as f32).collect();
            for _ in 0..n {
                buf = comm::rotate_ring_vec(&port, buf, RotationDir::Clockwise);
            }
            buf
        });
        for (r, buf) in out.iter().enumerate() {
            assert_eq!(buf.len(), elems, "{kind:?}: jumbo frame truncated");
            for (i, v) in buf.iter().enumerate() {
                assert_eq!(*v, (r + i) as f32, "{kind:?} rank {r} elem {i}");
            }
        }
        assert_eq!(fab.in_flight(), 0, "{kind:?}: messages left in flight");
    }
}

fn run_engine(
    strategy: Strategy,
    n: usize,
    launcher: Launcher,
    kind: TransportKind,
) -> (Vec<f32>, ModelParams, ModelParams) {
    let opts = EngineOpts::new("tiny", strategy, n, n.max(2))
        .exec(ExecKind::Oracle)
        .launcher(launcher)
        .transport(kind);
    let cfg = opts.cfg().unwrap();
    let mut e = build_engine(&opts).unwrap();
    let mut rng = Rng::new(7);
    let mut losses = Vec::new();
    for _ in 0..2 {
        let batch = Batch::synth(&cfg, n.max(2), &mut rng);
        losses.push(e.step(&batch).unwrap());
    }
    (losses, e.gather_params(), e.gather_grads())
}

#[test]
fn engines_are_bit_identical_across_backends() {
    // the acceptance engine (out-of-place RTP: rotation + collectives +
    // background comm streams) under the Thread launcher on each byte
    // transport vs the in-process Lockstep oracle
    let (r_loss, r_p, r_g) =
        run_engine(Strategy::RtpOutOfPlace, 4, Launcher::Lockstep, TransportKind::Inproc);
    for kind in KINDS {
        let (t_loss, t_p, t_g) =
            run_engine(Strategy::RtpOutOfPlace, 4, Launcher::Thread, kind);
        assert_eq!(r_loss, t_loss, "{kind:?}: losses diverge");
        assert_eq!(r_p, t_p, "{kind:?}: params diverge");
        assert_eq!(r_g, t_g, "{kind:?}: grads diverge");
    }
}

#[test]
fn watchdog_names_backend_and_honors_overrides_on_every_backend() {
    // rank 2 waits on a link whose upstream never sends: the stall must
    // panic (not hang) naming the link AND the backend, after exactly
    // the overridden timeout x (1 + retries) — the same knobs, the same
    // semantics, whichever bytes carry the link
    for kind in KINDS {
        let fab = RingFabric::with_transport(3, kind);
        fab.set_recv_timeout(Some(Duration::from_millis(120)));
        fab.set_recv_retries(Some(2));
        let t0 = Instant::now();
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let tasks: Vec<Box<dyn FnOnce() + Send>> = (0..3)
                .map(|r| {
                    let port = fab.port(r);
                    Box::new(move || {
                        if r == 2 {
                            let _ = comm::rotate_ring_vec(
                                &port,
                                vec![0.0f32; 16],
                                RotationDir::Clockwise,
                            );
                        }
                    }) as Box<dyn FnOnce() + Send>
                })
                .collect();
            fab.run_round(LaunchPolicy::Threaded, tasks);
        }));
        let payload = caught.expect_err("watchdog must fire");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        let name = kind.name();
        assert!(msg.contains(&format!("via {name} transport")), "{kind:?}: {msg}");
        assert!(msg.contains("link r1->r2"), "{kind:?}: {msg}");
        assert!(msg.contains("threaded round watchdog"), "{kind:?}: {msg}");
        // retry budget honored: 1 initial window + 2 retries >= 360 ms
        assert!(
            t0.elapsed() >= Duration::from_millis(360),
            "{kind:?}: watchdog fired after {:?} — retry override ignored",
            t0.elapsed()
        );
        let failure = fab.rank_failure().expect("typed failure recorded");
        assert_eq!(failure.failed_rank, 1, "{kind:?}: wrong upstream blamed");
        fab.set_recv_timeout(None);
        fab.set_recv_retries(None);
    }
}

#[test]
fn shm_fabric_removes_its_ring_segments_on_drop() {
    let fab = RingFabric::with_transport(4, TransportKind::Shm);
    let dir = fab.shm_dir().expect("shm fabric owns a ring dir");
    assert!(dir.exists(), "ring dir missing while fabric is live");
    let rings = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .filter(|e| e.path().extension().map_or(false, |x| x == "ring"))
        .count();
    assert!(rings > 0, "shm fabric created no ring files in {}", dir.display());
    // exercise the rings so the drop tears down a USED fabric
    let out = comm::spmd_with(&fab, LaunchPolicy::Threaded, |port| {
        comm::rotate_ring_vec(&port, vec![port.rank() as f32; 64], RotationDir::Clockwise)
    });
    assert_eq!(out.len(), 4);
    drop(fab);
    assert!(!dir.exists(), "leaked shm ring dir {}", dir.display());
}

#[test]
fn inproc_and_uds_fabrics_own_no_shm_dir() {
    assert!(RingFabric::with_transport(2, TransportKind::Inproc).shm_dir().is_none());
    assert!(RingFabric::with_transport(2, TransportKind::Uds).shm_dir().is_none());
}
