//! Training-loop integration: loss decreases on the learnable corpus for
//! every engine, curves are seed-identical across engines, and the
//! capacity/OOM machinery surfaces errors instead of corrupting state.

use rtp::config::{presets, OptimizerKind, Strategy, TrainCfg};
use rtp::parallel::{build_engine, EngineOpts, ExecKind};
use rtp::train::{train, MarkovCorpus, Optimizer};


fn short_cfg(steps: usize) -> TrainCfg {
    TrainCfg { steps, log_every: 10_000, lr: 5e-3, optimizer: OptimizerKind::Adam, seed: 42 }
}

#[test]
fn every_engine_learns_the_markov_chain() {
    for (strategy, n) in [
        (Strategy::Single, 1),
        (Strategy::Ddp, 2),
        (Strategy::Fsdp, 2),
        (Strategy::MegatronTp, 2),
        (Strategy::RtpInplace, 4),
        (Strategy::RtpOutOfPlace, 2),
    ] {
        let cfg = presets::get("tiny").unwrap();
        let mut engine = build_engine(
            &EngineOpts::new("tiny", strategy, n, 4).exec(ExecKind::Oracle),
        )
        .unwrap();
        let mut corpus = MarkovCorpus::new(&cfg, 42);
        let mut opt = Optimizer::new(OptimizerKind::Adam, 5e-3);
        let r = train(&mut *engine, &mut opt, &mut corpus, &short_cfg(30), 4, true)
            .unwrap();
        let (head, tail) = r.head_tail_means(5);
        assert!(
            tail < 0.9 * head,
            "{strategy} N={n}: loss {head:.3} -> {tail:.3} (no learning)"
        );
    }
}

#[test]
fn moe_rtp_learns() {
    let cfg = presets::get("tiny-moe").unwrap();
    let mut engine = build_engine(
        &EngineOpts::new("tiny-moe", Strategy::RtpInplace, 2, 4).exec(ExecKind::Oracle),
    )
    .unwrap();
    let mut corpus = MarkovCorpus::new(&cfg, 42);
    let mut opt = Optimizer::new(OptimizerKind::Adam, 5e-3);
    let r = train(&mut *engine, &mut opt, &mut corpus, &short_cfg(30), 4, true).unwrap();
    let (head, tail) = r.head_tail_means(5);
    assert!(tail < 0.9 * head, "moe-rtp: {head:.3} -> {tail:.3}");
}

#[test]
fn loss_curves_identical_across_engines_same_seed() {
    // The repo's strongest training statement: same seed => the SAME loss
    // curve on every engine (within f32 drift across 10 steps).
    let cfg = presets::get("tiny").unwrap();
    let mut reference: Option<Vec<f32>> = None;
    for (strategy, n) in [
        (Strategy::Single, 1),
        (Strategy::Ddp, 4),
        (Strategy::Fsdp, 2),
        (Strategy::MegatronTp, 4),
        (Strategy::RtpInplace, 2),
        (Strategy::RtpOutOfPlace, 4),
    ] {
        let mut engine = build_engine(
            &EngineOpts::new("tiny", strategy, n, 4).exec(ExecKind::Oracle),
        )
        .unwrap();
        let mut corpus = MarkovCorpus::new(&cfg, 7);
        let mut opt = Optimizer::new(OptimizerKind::Sgd, 1e-2);
        let r = train(&mut *engine, &mut opt, &mut corpus, &short_cfg(10), 4, true)
            .unwrap();
        match &reference {
            None => reference = Some(r.losses),
            Some(base) => {
                for (step, (a, b)) in base.iter().zip(&r.losses).enumerate() {
                    assert!(
                        (a - b).abs() < 5e-3 * a.abs().max(1.0),
                        "{strategy} N={n} step {step}: {b} vs single {a}"
                    );
                }
            }
        }
    }
}

#[test]
fn oom_mid_training_is_an_error_not_a_crash() {
    // a capacity that fits the weights+grads but not the activations
    // OOMs on step, not at init (tiny DDP residency is ~267 KiB/worker)
    let opts = EngineOpts::new("tiny", Strategy::Ddp, 2, 4)
        .exec(ExecKind::Virtual)
        .capacity(Some(300 * 1024));
    let mut engine = build_engine(&opts).unwrap();
    let cfg = presets::get("tiny").unwrap();
    let batch = rtp::parallel::Batch::synth(&cfg, 4, &mut rtp::util::rng::Rng::new(1));
    let err = engine.step(&batch).unwrap_err().to_string();
    assert!(err.contains("OOM"), "{err}");
}

#[test]
fn throughput_reported_positive() {
    let cfg = presets::get("tiny").unwrap();
    let mut engine = build_engine(
        &EngineOpts::new("tiny", Strategy::RtpInplace, 2, 4).exec(ExecKind::Oracle),
    )
    .unwrap();
    let mut corpus = MarkovCorpus::new(&cfg, 1);
    let mut opt = Optimizer::new(OptimizerKind::Sgd, 1e-3);
    let r = train(&mut *engine, &mut opt, &mut corpus, &short_cfg(3), 4, true).unwrap();
    assert!(r.tokens_per_s > 0.0);
    assert!(r.peak_bytes_per_worker > 0);
    assert_eq!(r.losses.len(), 3);
}

#[test]
fn checkpoint_transfers_between_engines() {
    // train with RTP, checkpoint, reload into a SINGLE engine via the
    // full-params constructor path, and check the loss matches: the
    // serialized format is engine-independent.
    use rtp::train::{load_params, save_params};
    let cfg = presets::get("tiny").unwrap();
    let mut rtp_engine = build_engine(
        &EngineOpts::new("tiny", Strategy::RtpInplace, 2, 4).exec(ExecKind::Oracle),
    )
    .unwrap();
    let mut corpus = MarkovCorpus::new(&cfg, 42);
    let mut opt = Optimizer::new(OptimizerKind::Adam, 5e-3);
    train(&mut *rtp_engine, &mut opt, &mut corpus, &short_cfg(10), 4, true).unwrap();

    let path = std::env::temp_dir().join(format!("rtp-xfer-{}.ckpt", std::process::id()));
    save_params(&rtp_engine.gather_params(), &path).unwrap();
    let loaded = load_params(&cfg, &path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded.max_abs_diff(&rtp_engine.gather_params()), 0.0);
    assert_eq!(loaded.num_params(), cfg.params_total());
}
