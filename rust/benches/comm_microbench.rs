//! Paper §3.4.2 — communication efficiency: the clockwise /
//! counter-clockwise rotation executed (N-1) times must track one
//! allgather of the same total bytes near-linearly once the message size
//! leaves the latency regime (> 1 MB). Two measurements:
//!
//! 1. the α-β cost model (the NCCL substitute, both hardware presets);
//! 2. REAL data movement through `comm::` on the host (our ring
//!    implementation itself), timed with the mini-harness.

use rtp::bench_util::{bench, Table};
use rtp::comm::{self, LinkModel};
use rtp::perfmodel::{a100_nvlink, v100_pcie};
use rtp::util::rng::Rng;

const N: usize = 8;

fn model_table(link: &LinkModel) {
    let mut t = Table::new(
        &format!("§3.4.2 — (N-1)×rotation vs allgather, α-β model, {} (N={N})", link.name),
        &["message", "rotation×(N-1)", "allgather", "ratio"],
    );
    let mut m: u64 = 1 << 10;
    while m <= 64 << 20 {
        let rot = (N - 1) as f64 * link.rotation_step(m / N as u64);
        let ag = link.allgather(m, N);
        t.row(vec![
            rtp::util::bytes::human(m),
            format!("{:.1} µs", rot * 1e6),
            format!("{:.1} µs", ag * 1e6),
            format!("{:.3}", rot / ag),
        ]);
        m *= 4;
    }
    t.print();
    t.write_csv(&format!("comm_microbench_{}", link.name)).unwrap();
}

fn main() {
    model_table(&a100_nvlink().link);
    model_table(&v100_pcie().link);

    // real host-side data movement: our ring primitives
    let mut t = Table::new(
        "real comm:: data movement (host, per call)",
        &["elems/worker", "rotate_cw", "allgather", "allreduce", "reduce_scatter"],
    );
    let mut rng = Rng::new(9);
    for elems in [1 << 10, 1 << 14, 1 << 18, 1 << 21] {
        let bufs: Vec<Vec<f32>> = (0..N)
            .map(|_| (0..elems).map(|_| rng.normal() as f32).collect())
            .collect();
        let rot = bench(2, 10, || {
            let mut b = bufs.clone();
            comm::rotate_cw(&mut b);
            std::hint::black_box(&b);
        });
        let ag = bench(2, 10, || {
            std::hint::black_box(comm::allgather(&bufs));
        });
        let ar = bench(2, 10, || {
            let mut b = bufs.clone();
            comm::allreduce_sum(&mut b);
            std::hint::black_box(&b);
        });
        let rs = bench(2, 10, || {
            std::hint::black_box(comm::reduce_scatter(&bufs));
        });
        t.row(vec![
            elems.to_string(),
            format!("{:.1} µs", rot.median * 1e6),
            format!("{:.1} µs", ag.median * 1e6),
            format!("{:.1} µs", ar.median * 1e6),
            format!("{:.1} µs", rs.median * 1e6),
        ]);
    }
    t.print();
    t.write_csv("comm_microbench_host").unwrap();
}
