//! Paper §3.4.2 — communication efficiency, now measured per hop on the
//! rank-local ring fabric. Three measurements:
//!
//! 1. the α-β cost model (the NCCL substitute, both hardware presets):
//!    (N-1)×rotation vs one allgather of the same total bytes;
//! 2. one-shot closed-form collective cost vs the sum of its chunked
//!    ring-hop schedule, across N ∈ {2,4,8,16} and message sizes — the
//!    per-hop decomposition must reproduce the closed form exactly;
//! 3. REAL data movement on the host: the god-view reference collectives
//!    vs the chunked ring implementations stepping messages through the
//!    fabric, timed with the mini-harness.

use std::collections::BTreeMap;
use std::time::Instant;

use rtp::bench_util::{bench, merge_overlap_json, Table};
use rtp::comm::{
    self, reference, CommPrim, LaunchPolicy, LinkModel, RingFabric, RotationDir, TransportKind,
};
use rtp::perfmodel::{a100_nvlink, v100_pcie};
use rtp::util::json::Json;
use rtp::util::rng::Rng;

const N: usize = 8;

fn quick() -> bool {
    std::env::var("RTP_BENCH_QUICK").is_ok()
}

fn model_table(link: &LinkModel) {
    let mut t = Table::new(
        &format!("§3.4.2 — (N-1)×rotation vs allgather, α-β model, {} (N={N})", link.name),
        &["message", "rotation×(N-1)", "allgather", "ratio"],
    );
    let mut m: u64 = 1 << 10;
    while m <= 64 << 20 {
        let rot = (N - 1) as f64 * link.rotation_step(m / N as u64);
        let ag = link.allgather(m, N);
        t.row(vec![
            rtp::util::bytes::human(m),
            format!("{:.1} µs", rot * 1e6),
            format!("{:.1} µs", ag * 1e6),
            format!("{:.3}", rot / ag),
        ]);
        m *= 4;
    }
    t.print();
    t.write_csv(&format!("comm_microbench_{}", link.name)).unwrap();
}

/// One-shot closed-form cost vs the per-hop sum of the chunked ring
/// schedule, per primitive, across worker counts and message sizes.
fn hop_decomposition_table(link: &LinkModel) {
    let mut t = Table::new(
        &format!("one-shot vs chunked-ring per-hop cost, α-β model, {}", link.name),
        &["prim", "N", "message", "one-shot", "per-hop sum", "hops", "ratio"],
    );
    for prim in [CommPrim::AllReduce, CommPrim::AllGather, CommPrim::ReduceScatter] {
        for n in [2usize, 4, 8, 16] {
            for m in [1u64 << 16, 1 << 20, 16 << 20] {
                let closed = link.time(prim, m, n);
                let hops = prim.hop_schedule(m, n);
                let per_hop: f64 = hops.iter().map(|&b| link.hop_time_f(b)).sum();
                t.row(vec![
                    prim.to_string(),
                    n.to_string(),
                    rtp::util::bytes::human(m),
                    format!("{:.1} µs", closed * 1e6),
                    format!("{:.1} µs", per_hop * 1e6),
                    hops.len().to_string(),
                    format!("{:.6}", per_hop / closed),
                ]);
                assert!(
                    (per_hop - closed).abs() / closed < 1e-9,
                    "{prim} N={n} m={m}: per-hop {per_hop} != closed {closed}"
                );
            }
        }
    }
    t.print();
    t.write_csv(&format!("comm_microbench_hops_{}", link.name)).unwrap();
}

/// Host-side data movement: god-view reference vs ring fabric, per call.
/// The ring side runs each rank's single-port collective on the
/// deterministic lockstep scheduler (so the measured cost includes the
/// rank-scheduling machinery the engines actually pay).
fn host_table() {
    let mut t = Table::new(
        "real data movement: god-view reference vs ring fabric (host, per call)",
        &["N", "elems/worker", "op", "reference", "ring fabric"],
    );
    let mut rng = Rng::new(9);
    let sizes: &[usize] = if quick() { &[1 << 12, 1 << 16] } else { &[1 << 12, 1 << 16, 1 << 19] };
    for n in [2usize, 4, 8, 16] {
        let fab = RingFabric::new(n);
        for &elems in sizes {
            let len = (elems / n) * n; // divisible for reduce_scatter
            let bufs: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..len).map(|_| rng.normal() as f32).collect())
                .collect();

            let ref_ar = bench(2, 8, || {
                let mut b = bufs.clone();
                reference::allreduce_sum(&mut b);
                std::hint::black_box(&b);
            });
            let ring_ar = bench(2, 8, || {
                let out = comm::spmd(&fab, |port| {
                    let mut b = bufs[port.rank()].clone();
                    comm::allreduce_sum(&port, &mut b);
                    b
                });
                std::hint::black_box(&out);
            });
            t.row(vec![
                n.to_string(),
                len.to_string(),
                "allreduce".into(),
                format!("{:.1} µs", ref_ar.median * 1e6),
                format!("{:.1} µs", ring_ar.median * 1e6),
            ]);

            let ref_ag = bench(2, 8, || {
                std::hint::black_box(reference::allgather(&bufs));
            });
            let ring_ag = bench(2, 8, || {
                let out =
                    comm::spmd(&fab, |port| comm::allgather(&port, &bufs[port.rank()]));
                std::hint::black_box(&out);
            });
            t.row(vec![
                n.to_string(),
                len.to_string(),
                "allgather".into(),
                format!("{:.1} µs", ref_ag.median * 1e6),
                format!("{:.1} µs", ring_ag.median * 1e6),
            ]);

            let ref_rs = bench(2, 8, || {
                std::hint::black_box(reference::reduce_scatter(&bufs));
            });
            let ring_rs = bench(2, 8, || {
                let out = comm::spmd(&fab, |port| {
                    comm::reduce_scatter(&port, &bufs[port.rank()])
                });
                std::hint::black_box(&out);
            });
            t.row(vec![
                n.to_string(),
                len.to_string(),
                "reduce-scatter".into(),
                format!("{:.1} µs", ref_rs.median * 1e6),
                format!("{:.1} µs", ring_rs.median * 1e6),
            ]);

            let ref_rot = bench(2, 8, || {
                let mut b = bufs.clone();
                reference::rotate_cw(&mut b);
                std::hint::black_box(&b);
            });
            let ring_rot = bench(2, 8, || {
                let out = comm::spmd(&fab, |port| {
                    comm::rotate_ring(
                        &port,
                        bufs[port.rank()].clone(),
                        RotationDir::Clockwise,
                    )
                });
                std::hint::black_box(&out);
            });
            t.row(vec![
                n.to_string(),
                len.to_string(),
                "rotate".into(),
                format!("{:.1} µs", ref_rot.median * 1e6),
                format!("{:.1} µs", ring_rot.median * 1e6),
            ]);
        }
        assert_eq!(fab.in_flight(), 0, "bench left fabric messages in flight");
    }
    t.print();
    t.write_csv("comm_microbench_host").unwrap();
}

/// Pooled (`send_vec` lane path) vs boxed (`dyn Any`) rotation on the
/// host fabric: per-hop latency and fabric allocations per hop, under
/// both launch policies. The pooled path must show zero steady-state
/// allocations — the lock-sharded lane + buffer-pool contract.
fn pooled_rotation_table() {
    let mut t = Table::new(
        "pooled vs boxed rotation (host fabric, per hop)",
        &["policy", "elems", "boxed ns/hop", "pooled ns/hop", "pooled allocs/hop"],
    );
    let (reps, iters) = if quick() { (200usize, 4usize) } else { (1000, 8) };
    for policy in [LaunchPolicy::Lockstep, LaunchPolicy::Threaded] {
        for elems in [1usize << 10, 1 << 14, 1 << 17] {
            let fab = RingFabric::new(4);
            let run = |pooled: bool| {
                comm::spmd_with(&fab, policy, |port| {
                    let mut buf = vec![port.rank() as f32; elems];
                    for _ in 0..reps {
                        buf = if pooled {
                            comm::rotate_ring_vec(&port, buf, RotationDir::Clockwise)
                        } else {
                            comm::rotate_ring(&port, buf, RotationDir::Clockwise)
                        };
                    }
                    buf.len()
                });
            };
            run(true); // prime pools / queues
            let boxed = bench(1, iters, || run(false));
            let c0 = fab.counters();
            let pooled = bench(1, iters, || run(true));
            let c1 = fab.counters();
            // bench runs the closure 1 (warmup) + iters times between the
            // two counter snapshots
            let pooled_hops = ((iters + 1) * 4 * reps) as f64;
            t.row(vec![
                format!("{policy:?}"),
                elems.to_string(),
                format!("{:.0}", boxed.median / reps as f64 * 1e9),
                format!("{:.0}", pooled.median / reps as f64 * 1e9),
                format!("{:.4}", (c1.msg_allocs - c0.msg_allocs) as f64 / pooled_hops),
            ]);
            assert_eq!(fab.in_flight(), 0);
        }
    }
    t.print();
    t.write_csv("comm_microbench_pooled").unwrap();
}

/// Transport ablation (process-grade transport PR): the SAME pooled
/// rotation hop on each byte transport that can back a fabric link —
/// in-process lanes (`inproc`, the historical oracle), the
/// shared-memory SPSC ring (`shm`, what `Launcher::Process` runs on)
/// and the Unix-socket portable reference (`uds`) — at N ∈ {2,4,8},
/// 16 KiB payloads, Threaded policy. Reports per-hop latency, aggregate
/// ring bandwidth, and fabric allocations per hop from the
/// `msg_allocs` counter. The N=4 rows land as `transport_*` keys in
/// `figures/BENCH_overlap.json`; scripts/check_bench_overlap.py pins
/// the shm steady-state allocation count at ZERO — the zero-copy
/// contract the Process-launcher overlap numbers rest on.
fn transport_table() {
    let elems = 4096usize; // 16 KiB of f32 per hop
    let hops = if quick() { 512usize } else { 8192 };
    let mut t = Table::new(
        "transport ablation — pooled rotation hop, 16 KiB payload, Thread policy",
        &["transport", "N", "ns/hop", "GB/s aggregate", "allocs/hop"],
    );
    let mut json = BTreeMap::new();
    for kind in [TransportKind::Inproc, TransportKind::Shm, TransportKind::Uds] {
        for n in [2usize, 4, 8] {
            let fab = RingFabric::with_transport(n, kind);
            let run = |k: usize| {
                let out = comm::spmd_with(&fab, LaunchPolicy::Threaded, |port| {
                    let mut buf = vec![port.rank() as f32; elems];
                    for _ in 0..k {
                        buf = comm::rotate_ring_vec(&port, buf, RotationDir::Clockwise);
                    }
                    buf.len()
                });
                std::hint::black_box(&out);
            };
            run(64); // prime lane pools / rings / socket buffers
            fab.reset_counters();
            let t0 = Instant::now();
            run(hops);
            let dt = t0.elapsed().as_secs_f64();
            assert_eq!(fab.in_flight(), 0, "transport bench left messages in flight");
            let allocs = fab.counters().msg_allocs as f64 / (hops * n) as f64;
            let ns_hop = dt / hops as f64 * 1e9;
            let gbs = (hops * n * elems * 4) as f64 / dt / 1e9;
            t.row(vec![
                kind.name().into(),
                n.to_string(),
                format!("{ns_hop:.0}"),
                format!("{gbs:.2}"),
                format!("{allocs:.4}"),
            ]);
            if n == 4 {
                json.insert(
                    format!("transport_{}_ns_per_hop_16k", kind.name()),
                    Json::Num(ns_hop),
                );
                json.insert(
                    format!("transport_{}_gb_per_s_16k", kind.name()),
                    Json::Num(gbs),
                );
                json.insert(
                    format!("transport_{}_allocs_per_hop", kind.name()),
                    Json::Num(allocs),
                );
            }
        }
    }
    t.print();
    t.write_csv("comm_microbench_transport").unwrap();
    let path = merge_overlap_json(json).unwrap();
    println!("merged transport_* keys into {}", path.display());
}

fn main() {
    model_table(&a100_nvlink().link);
    model_table(&v100_pcie().link);
    hop_decomposition_table(&a100_nvlink().link);
    hop_decomposition_table(&v100_pcie().link);
    pooled_rotation_table();
    transport_table();
    host_table();
}
