//! Paper Fig 14 (appendix B) — MoE GPT throughput on 8×V100/PCIe: the
//! all-to-all-free rotation wins biggest where the interconnect is
//! weakest.

use rtp::perfmodel::{simulate::throughput_figure, v100_pcie};

fn main() {
    throughput_figure("gpt2-500m-moe", v100_pcie(), "Fig 14", 8);
}
