//! Paper Fig 11 — throughput vs batch for the MoE GPT2-500M on
//! 8×A100/NVLink. The DP/FSDP baselines pay expert-parallel all-to-alls
//! before and after every MoE block (paper §4 "MOE Block"); RTP's expert
//! rotation replaces them — which is why RTP-MoE closes the gap and
//! overtakes at large batch.

use rtp::config::Strategy;
use rtp::perfmodel::{a100_nvlink, simulate, simulate::throughput_figure, SimSpec};

fn main() {
    throughput_figure("gpt2-500m-moe", a100_nvlink(), "Fig 11", 8);

    // paper §5.4 MoE deltas: RTP −23%…−10% vs DP at small batch
    for batch in [8usize, 64, 512] {
        let rtp = simulate(&SimSpec::new(
            "gpt2-500m-moe",
            Strategy::RtpOutOfPlace,
            8,
            batch,
            a100_nvlink(),
        ))
        .unwrap();
        let ddp = simulate(&SimSpec::new(
            "gpt2-500m-moe",
            Strategy::Ddp,
            8,
            batch,
            a100_nvlink(),
        ))
        .unwrap();
        if rtp.oom.is_none() && ddp.oom.is_none() {
            println!(
                "batch {}/gpu: RTP-MoE vs DP-MoE {:+.1}%",
                batch / 8,
                100.0 * (rtp.wps / ddp.wps - 1.0)
            );
        }
    }
}
