//! L3 hot-path profile (§Perf): real-mode step wall-clock per engine ×
//! executor, with the PJRT runtime's internal breakdown (execute vs
//! host<->literal conversion vs compile) — the numbers the EXPERIMENTS.md
//! §Perf iteration log tracks.

use rtp::bench_util::{bench, Table};
use rtp::config::Strategy;
use rtp::parallel::{build_engine, Batch, EngineOpts, ExecKind};
use rtp::runtime::Exec;
use rtp::util::rng::Rng;

fn main() {
    let preset = "tiny";
    let cfg = rtp::config::presets::get(preset).unwrap();
    let batch = Batch::synth(&cfg, 4, &mut Rng::new(1));

    let mut t = Table::new(
        "hot path — real-mode step wall-clock (tiny, global batch 4)",
        &["engine", "exec", "median step", "p95", "steps/s"],
    );
    for (strategy, n) in [
        (Strategy::Single, 1),
        (Strategy::Ddp, 2),
        (Strategy::Fsdp, 2),
        (Strategy::RtpInplace, 2),
        (Strategy::RtpInplace, 4),
        (Strategy::RtpOutOfPlace, 4),
    ] {
        for exec in [ExecKind::Oracle, ExecKind::Pjrt] {
            if exec == ExecKind::Pjrt
                && !rtp::runtime::artifacts_root().join("tiny/manifest.json").exists()
            {
                continue;
            }
            let mut e =
                build_engine(&EngineOpts::new(preset, strategy, n, 4).exec(exec))
                    .unwrap();
            // warm the executable cache before timing
            e.step(&batch).unwrap();
            let s = bench(1, 8, || {
                e.zero_grads();
                e.step(&batch).unwrap();
            });
            t.row(vec![
                format!("{strategy}/N={n}"),
                format!("{exec:?}"),
                format!("{:.2} ms", s.median * 1e3),
                format!("{:.2} ms", s.p95 * 1e3),
                format!("{:.1}", 1.0 / s.median),
            ]);
        }
    }
    t.print();
    t.write_csv("hotpath").unwrap();

    // PJRT runtime breakdown on an RTP step
    if rtp::runtime::artifacts_root().join("tiny/manifest.json").exists() {
        let mut e = build_engine(
            &EngineOpts::new(preset, Strategy::RtpInplace, 4, 4).exec(ExecKind::Pjrt),
        )
        .unwrap();
        for _ in 0..5 {
            e.zero_grads();
            e.step(&batch).unwrap();
        }
        if let Exec::Pjrt(rt) = &e.ctx().exec {
            let st = &rt.stats;
            let mut b = Table::new(
                "PJRT runtime breakdown (rtp-inplace N=4, 5 steps + warm)",
                &["metric", "value"],
            );
            b.row(vec!["executions".into(), st.executions.to_string()]);
            b.row(vec!["compilations".into(), st.compilations.to_string()]);
            b.row(vec![
                "execute time".into(),
                format!("{:.1} ms", st.exec_seconds * 1e3),
            ]);
            b.row(vec![
                "convert time".into(),
                format!("{:.1} ms", st.convert_seconds * 1e3),
            ]);
            b.row(vec![
                "convert share".into(),
                format!(
                    "{:.0}%",
                    100.0 * st.convert_seconds / (st.exec_seconds + st.convert_seconds)
                ),
            ]);
            b.print();
            b.write_csv("hotpath_pjrt_breakdown").unwrap();
        }
    }
}
