//! L3 hot-path profile (§Perf): real-mode step wall-clock per engine ×
//! executor, with the PJRT runtime's internal breakdown (execute vs
//! host<->literal conversion vs compile) — the numbers the EXPERIMENTS.md
//! §Perf iteration log tracks.
//!
//! Since the true-async-rotation PR this bench also measures the Thread
//! launcher's REAL compute/comm overlap: `RtpOutOfPlace` with eager comm
//! streams vs the synchronous-boundary baseline, fabric allocations per
//! step, and pooled ns/hop. Since the background-collective-engine PR it
//! additionally profiles FSDP's data-path overlap — per-rank comm
//! threads running the prefetch allgather + backward reduce-scatter vs
//! execute-at-join streams — including the counter-based hidden-comm
//! fraction (1 - bg_wait/bg_busy). Since the hop-level-scheduler PR it
//! also runs the multi-collective preset (bucketed allreduces + a
//! latency-critical prefetch allgather in flight at once, fifo vs
//! round-robin vs priority → the `multi_*` JSON keys) and a DDP
//! policy × bucket-size ablation. Since the elastic-supervisor PR it
//! also profiles recovery itself: a supervised run with an injected
//! rank death reports the detect→quiesce→rebuild→restore wall-clock
//! (`elastic_recovery_ms`) and the async checkpointer's per-submit
//! stall on the step path (`ckpt_async_stall_ns` — the off-thread
//! writer's acceptance bar: handing a snapshot over must never wait on
//! disk). Everything lands in
//! `figures/BENCH_overlap.json`, which CI's bench-smoke job diffs
//! against the repo-root `BENCH_overlap.json` baseline
//! (scripts/check_bench_overlap.py: overlap regressions > 10%, any
//! steady-state alloc increase, or a recovery/stall bound blown fail
//! the job). `RTP_BENCH_QUICK=1` trims iteration counts for CI.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use rtp::bench_util::{bench, Table};
use rtp::comm::{self, CollectiveStream, LaunchPolicy, RingFabric, RotationDir, SchedPolicy};
use rtp::config::Strategy;
use rtp::parallel::{build_engine, Batch, EngineOpts, ExecKind, Launcher};
use rtp::perfmodel::a100_nvlink;
use rtp::runtime::Exec;
use rtp::tensor::IntTensor;
use rtp::util::json::Json;
use rtp::util::rng::Rng;

fn quick() -> bool {
    std::env::var("RTP_BENCH_QUICK").is_ok()
}

fn main() {
    let preset = "tiny";
    let cfg = rtp::config::presets::get(preset).unwrap();
    let batch = Batch::synth(&cfg, 4, &mut Rng::new(1));
    let iters = if quick() { 4 } else { 8 };

    let mut t = Table::new(
        "hot path — real-mode step wall-clock (tiny, global batch 4)",
        &["engine", "exec", "median step", "p95", "steps/s"],
    );
    for (strategy, n) in [
        (Strategy::Single, 1),
        (Strategy::Ddp, 2),
        (Strategy::Fsdp, 2),
        (Strategy::RtpInplace, 2),
        (Strategy::RtpInplace, 4),
        (Strategy::RtpOutOfPlace, 4),
    ] {
        for exec in [ExecKind::Oracle, ExecKind::Pjrt] {
            if exec == ExecKind::Pjrt
                && !rtp::runtime::artifacts_root().join("tiny/manifest.json").exists()
            {
                continue;
            }
            let mut e =
                build_engine(&EngineOpts::new(preset, strategy, n, 4).exec(exec))
                    .unwrap();
            // warm the executable cache before timing
            e.step(&batch).unwrap();
            let s = bench(1, iters, || {
                e.zero_grads();
                e.step(&batch).unwrap();
            });
            t.row(vec![
                format!("{strategy}/N={n}"),
                format!("{exec:?}"),
                format!("{:.2} ms", s.median * 1e3),
                format!("{:.2} ms", s.p95 * 1e3),
                format!("{:.1}", 1.0 / s.median),
            ]);
        }
    }
    t.print();
    t.write_csv("hotpath").unwrap();

    let mut overlap = BTreeMap::new();
    async_rotation_profile(preset, &batch, &mut overlap);
    fsdp_profile(preset, &batch, &mut overlap);
    multi_collective_profile(&mut overlap);
    elastic_profile(preset, &mut overlap);
    scheduler_ablation();
    overlap.insert("quick_mode".into(), Json::Bool(quick()));
    // read-merge-write: comm_microbench owns the transport_* keys in the
    // same artifact; running the two targets in either order must not
    // clobber either contribution
    let path = rtp::bench_util::merge_overlap_json(overlap).unwrap();
    println!("wrote {}", path.display());

    // PJRT runtime breakdown on an RTP step
    if rtp::runtime::artifacts_root().join("tiny/manifest.json").exists() {
        let mut e = build_engine(
            &EngineOpts::new(preset, Strategy::RtpInplace, 4, 4).exec(ExecKind::Pjrt),
        )
        .unwrap();
        for _ in 0..5 {
            e.zero_grads();
            e.step(&batch).unwrap();
        }
        if let Exec::Pjrt(rt) = &e.ctx().exec {
            let st = &rt.stats;
            let mut b = Table::new(
                "PJRT runtime breakdown (rtp-inplace N=4, 5 steps + warm)",
                &["metric", "value"],
            );
            b.row(vec!["executions".into(), st.executions.to_string()]);
            b.row(vec!["compilations".into(), st.compilations.to_string()]);
            b.row(vec![
                "execute time".into(),
                format!("{:.1} ms", st.exec_seconds * 1e3),
            ]);
            b.row(vec![
                "convert time".into(),
                format!("{:.1} ms", st.convert_seconds * 1e3),
            ]);
            b.row(vec![
                "convert share".into(),
                format!(
                    "{:.0}%",
                    100.0 * st.convert_seconds / (st.exec_seconds + st.convert_seconds)
                ),
            ]);
            b.print();
            b.write_csv("hotpath_pjrt_breakdown").unwrap();
        }
    }
}

/// One Thread-launcher `RtpOutOfPlace` configuration: warm, measure
/// per-step fabric counters, then time steps. Returns (median step
/// seconds, fabric msg-allocs per step).
fn rtp_thread_step(preset: &str, batch: &Batch, n: usize, async_rot: bool) -> (f64, f64) {
    let mut e = build_engine(
        &EngineOpts::new(preset, Strategy::RtpOutOfPlace, n, n)
            .exec(ExecKind::Oracle)
            .launcher(Launcher::Thread)
            .async_rotation(async_rot),
    )
    .unwrap();
    e.step(batch).unwrap(); // warm (primes lane pools)
    let fab = e.ctx().cluster.fabric().clone();
    let c0 = fab.counters();
    e.zero_grads();
    e.step(batch).unwrap();
    let c1 = fab.counters();
    let allocs = (c1.msg_allocs - c0.msg_allocs) as f64;
    let iters = if quick() { 6 } else { 16 };
    let s = bench(1, iters, || {
        e.zero_grads();
        e.step(batch).unwrap();
    });
    (s.median, allocs)
}

/// Pooled rotation latency: K hops of a 64 KiB shard per rank under the
/// Thread policy; wall-clock / K is the per-hop cost including the lane
/// machinery the engines actually pay.
fn measure_ns_per_hop() -> f64 {
    let n = 4;
    let k = if quick() { 2_000usize } else { 20_000 };
    let elems = 16 * 1024; // 64 KiB of f32
    let fab = RingFabric::new(n);
    let tasks: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..n)
        .map(|r| {
            let port = fab.port(r);
            Box::new(move || {
                let mut buf = vec![r as f32; elems];
                for _ in 0..k {
                    buf = comm::rotate_ring_vec(&port, buf, RotationDir::Clockwise);
                }
                buf.len()
            }) as Box<dyn FnOnce() -> usize + Send>
        })
        .collect();
    let t0 = Instant::now();
    fab.run_round(LaunchPolicy::Threaded, tasks);
    assert_eq!(fab.in_flight(), 0);
    t0.elapsed().as_secs_f64() / k as f64 * 1e9
}

/// Modeled (α-β timeline) overlap fraction of one step of `strategy`.
fn modeled_overlap(preset: &str, strategy: Strategy, n: usize) -> f64 {
    let opts = EngineOpts::new(preset, strategy, n, n)
        .exec(ExecKind::Virtual)
        .hardware(a100_nvlink());
    let cfg = opts.cfg().unwrap();
    let mut e = build_engine(&opts).unwrap();
    let b = Batch {
        ids: IntTensor::zeros(&[n, cfg.seq]),
        targets: IntTensor::zeros(&[n, cfg.seq]),
    };
    e.step(&b).unwrap();
    e.ctx().timeline.as_ref().unwrap().overlap_fraction()
}

/// The §3.4 acceptance measurement: under the Thread launcher, real
/// background rotation must beat the synchronous-boundary baseline, and
/// the measured overlap is compared against the modeled one.
fn async_rotation_profile(preset: &str, batch: &Batch, obj: &mut BTreeMap<String, Json>) {
    let n = 4;
    let (sync_med, sync_allocs) = rtp_thread_step(preset, batch, n, false);
    let (async_med, async_allocs) = rtp_thread_step(preset, batch, n, true);
    let measured_overlap = (1.0 - async_med / sync_med).max(0.0);
    let modeled = modeled_overlap(preset, Strategy::RtpOutOfPlace, n);
    let ns_hop = measure_ns_per_hop();

    let mut t = Table::new(
        &format!(
            "true async rotation — ThreadLauncher, {preset}, oracle, N={n} \
             (sync boundary vs eager comm-stream)"
        ),
        &["rotation", "median step", "fabric allocs/step", "overlap vs sync"],
    );
    t.row(vec![
        "synchronous".into(),
        format!("{:.2} ms", sync_med * 1e3),
        format!("{sync_allocs:.0}"),
        "—".into(),
    ]);
    t.row(vec![
        "async (comm stream)".into(),
        format!("{:.2} ms", async_med * 1e3),
        format!("{async_allocs:.0}"),
        format!("{:.1}%", 100.0 * measured_overlap),
    ]);
    t.print();
    t.write_csv("hotpath_async_rotation").unwrap();
    println!(
        "modeled overlap (α-β timeline): {:.1}%  measured/modeled ratio: {:.2}  \
         pooled rotation: {:.0} ns/hop",
        100.0 * modeled,
        if modeled > 0.0 { measured_overlap / modeled } else { 0.0 },
        ns_hop
    );
    if async_med >= sync_med {
        println!(
            "WARNING: async rotation did not beat the synchronous baseline \
             ({:.3} ms >= {:.3} ms) — overlap regression?",
            async_med * 1e3,
            sync_med * 1e3
        );
    }

    obj.insert("preset".into(), Json::Str(preset.to_string()));
    obj.insert("workers".into(), Json::Num(n as f64));
    obj.insert("launcher".into(), Json::Str("thread".into()));
    obj.insert("sync_step_ms".into(), Json::Num(sync_med * 1e3));
    obj.insert("async_step_ms".into(), Json::Num(async_med * 1e3));
    obj.insert("measured_overlap_fraction".into(), Json::Num(measured_overlap));
    obj.insert("modeled_overlap_fraction".into(), Json::Num(modeled));
    obj.insert(
        "measured_over_modeled_ratio".into(),
        Json::Num(if modeled > 0.0 { measured_overlap / modeled } else { 0.0 }),
    );
    obj.insert("ns_per_hop_pooled_64KiB".into(), Json::Num(ns_hop));
    obj.insert("fabric_allocs_per_step_sync".into(), Json::Num(sync_allocs));
    obj.insert("fabric_allocs_per_step_async".into(), Json::Num(async_allocs));
}

/// Fixed-work compute stand-in for the multi-collective preset (pure
/// integer arithmetic, no allocation, resistant to being optimized out).
fn spin(iters: u64) {
    let mut x = 0u64;
    for i in 0..iters {
        x = x.wrapping_mul(6364136223846793005).wrapping_add(i);
    }
    std::hint::black_box(x);
}

/// Per-policy measurements from the multi-collective preset.
struct MultiStats {
    step_s: f64,
    hidden: f64,
    allocs: f64,
    switches_per_step: f64,
    max_streak: u64,
}

/// The multi-collective hotpath preset: every rank's comm thread holds a
/// latency-critical prefetch allgather AND four bucketed gradient
/// allreduces in flight AT ONCE — the backward-pass shape the hop-level
/// scheduler exists for. The bucket allreduces are issued first (they
/// come out of backward), the prefetch allgather last but JOINED first
/// after a short compute window: under `Fifo` that join convoys behind
/// all four buckets; under `RoundRobin`/`Priority` the allgather's hops
/// interleave (or jump the queue) and the join returns early.
fn multi_collective_step(policy: SchedPolicy, n: usize) -> MultiStats {
    const BUCKETS: usize = 4;
    const BUCKET_ELEMS: usize = 64 * 1024; // 256 KiB per bucket
    const SHARD_ELEMS: usize = 1024; // 4 KiB prefetch shard
    let rounds = if quick() { 30 } else { 300 };
    let fab = RingFabric::new(n);
    let run = |rounds: usize| {
        let tasks: Vec<Box<dyn FnOnce() + Send>> = (0..n)
            .map(|r| {
                let port = fab.port(r);
                Box::new(move || {
                    let stream = CollectiveStream::with_policy(port, true, policy);
                    let mut buckets: Vec<Vec<f32>> = (0..BUCKETS)
                        .map(|b| vec![(r + b) as f32; BUCKET_ELEMS])
                        .collect();
                    let shard = vec![r as f32; SHARD_ELEMS];
                    let mut ag_buf: Vec<f32> = Vec::new();
                    let mut handles = Vec::with_capacity(BUCKETS);
                    for _ in 0..rounds {
                        for b in buckets.drain(..) {
                            handles.push(stream.issue_allreduce(b));
                        }
                        let h_ag = stream
                            .issue_allgather(&shard, std::mem::take(&mut ag_buf));
                        spin(20_000);
                        // latency-critical: the next unit's weights
                        ag_buf = stream.join(h_ag);
                        spin(80_000);
                        for h in handles.drain(..) {
                            buckets.push(stream.join(h));
                        }
                    }
                }) as Box<dyn FnOnce() + Send>
            })
            .collect();
        fab.run_round(LaunchPolicy::Threaded, tasks);
        assert_eq!(fab.in_flight(), 0);
    };
    run(2); // warm the lane pools
    fab.reset_counters();
    let t0 = Instant::now();
    run(rounds);
    let dt = t0.elapsed().as_secs_f64();
    let c = fab.counters();
    let busy = c.bg_busy_ns as f64;
    let wait = c.bg_wait_ns as f64;
    MultiStats {
        step_s: dt / rounds as f64,
        hidden: if busy > 0.0 { (1.0 - wait / busy).max(0.0) } else { 0.0 },
        allocs: c.msg_allocs as f64 / rounds as f64,
        switches_per_step: c.sched_switches as f64 / (rounds * n) as f64,
        max_streak: c.sched_max_streak,
    }
}

/// The scheduler acceptance measurement: per policy, step time,
/// counter-based hidden-comm fraction, steady-state allocations and the
/// fairness counters, on the multi-collective preset. The headline keys —
/// scheduled-vs-convoy step ratio and per-policy hidden fractions — are
/// gated by scripts/check_bench_overlap.py.
fn multi_collective_profile(obj: &mut BTreeMap<String, Json>) {
    let n = 4;
    let fifo = multi_collective_step(SchedPolicy::Fifo, n);
    let mut rr = multi_collective_step(SchedPolicy::RoundRobin, n);
    let mut prio = multi_collective_step(SchedPolicy::Priority, n);
    // measured fractions on a possibly-starved runner: re-measure under
    // the gate floor so CI rejects regressions, not scheduler noise
    for _ in 0..2 {
        if rr.hidden >= 0.02 && prio.hidden >= 0.02 {
            break;
        }
        eprintln!("scheduler hidden-comm fraction below gate floor — re-measuring");
        if rr.hidden < 0.02 {
            rr = multi_collective_step(SchedPolicy::RoundRobin, n);
        }
        if prio.hidden < 0.02 {
            prio = multi_collective_step(SchedPolicy::Priority, n);
        }
    }

    let mut t = Table::new(
        &format!(
            "hop-level scheduler — multi-collective preset (4×256 KiB bucket \
             allreduces + 4 KiB prefetch allgather in flight, N={n}, Thread)"
        ),
        &[
            "policy",
            "median step",
            "hidden-comm",
            "allocs/step",
            "switches/step",
            "max streak",
        ],
    );
    for (name, s) in
        [("fifo", &fifo), ("round-robin", &rr), ("priority", &prio)]
    {
        t.row(vec![
            name.into(),
            format!("{:.3} ms", s.step_s * 1e3),
            format!("{:.1}%", 100.0 * s.hidden),
            format!("{:.1}", s.allocs),
            format!("{:.1}", s.switches_per_step),
            s.max_streak.to_string(),
        ]);
    }
    t.print();
    t.write_csv("hotpath_sched_policies").unwrap();

    let sched_s = rr.step_s.min(prio.step_s);
    let ratio = sched_s / fifo.step_s;
    println!(
        "scheduled/convoy step ratio: {ratio:.3} (fifo {:.3} ms, best scheduled \
         {:.3} ms)",
        fifo.step_s * 1e3,
        sched_s * 1e3
    );
    if ratio > 1.0 {
        println!(
            "WARNING: interleaving policies did not beat the FIFO convoy \
             — scheduler regression?"
        );
    }

    obj.insert("multi_convoy_step_ms".into(), Json::Num(fifo.step_s * 1e3));
    obj.insert("multi_scheduled_step_ms".into(), Json::Num(sched_s * 1e3));
    obj.insert(
        "multi_scheduled_over_convoy_step_ratio".into(),
        Json::Num(ratio),
    );
    obj.insert("multi_fifo_overlap_fraction".into(), Json::Num(fifo.hidden));
    obj.insert("multi_rr_overlap_fraction".into(), Json::Num(rr.hidden));
    obj.insert("multi_priority_overlap_fraction".into(), Json::Num(prio.hidden));
    obj.insert("multi_allocs_per_step_fifo".into(), Json::Num(fifo.allocs));
    obj.insert(
        "multi_allocs_per_step_scheduled".into(),
        Json::Num(rr.allocs.max(prio.allocs)),
    );
    obj.insert(
        "multi_rr_switches_per_step".into(),
        Json::Num(rr.switches_per_step),
    );
    obj.insert("multi_rr_max_streak".into(), Json::Num(rr.max_streak as f64));
}

/// The elastic-supervisor acceptance measurement: a supervised DDP run
/// under the Thread launcher with one injected rank death mid-run. The
/// detect→quiesce→rebuild→restore wall-clock from the `RecoveryEvent`
/// (less the policy-configured backoff sleep) is the
/// `elastic_recovery_ms` gate — recovery must be bounded, not just
/// eventual — and the async checkpointer's mean per-submit stall is the
/// `ckpt_async_stall_ns` gate: the step thread hands snapshots to the
/// off-thread writer without ever waiting on disk. Best of `reps` runs:
/// both metrics are latency bounds, so the minimum is the
/// machine-noise-resistant estimator; checkpoint counters aggregate
/// over all reps.
fn elastic_profile(preset: &str, obj: &mut BTreeMap<String, Json>) {
    use rtp::config::OptimizerKind;
    use rtp::runtime::{FaultPhase, FaultPlan, RecoveryMode, RecoveryPolicy, Supervisor};

    let n = 4;
    let steps: u64 = if quick() { 8 } else { 24 };
    let reps = if quick() { 1 } else { 3 };
    let ckpt = std::env::temp_dir()
        .join(format!("rtp-bench-elastic-{}.ckpt", std::process::id()));
    let policy = RecoveryPolicy {
        mode: RecoveryMode::Shrink,
        backoff_base: Duration::from_millis(1),
        backoff_cap: Duration::from_millis(1),
        ..RecoveryPolicy::default()
    };
    let mut total_ms = f64::INFINITY;
    let (mut rebuild_ms, mut restore_ms) = (0.0f64, 0.0f64);
    let (mut from, mut to) = (n, n);
    let (mut stall_ns, mut submitted, mut written, mut skipped) = (0u64, 0u64, 0u64, 0u64);
    for _ in 0..reps {
        // global batch 12 divides both the original and the shrunk world
        // (4 → 3), so the shrink target is one rank below
        let plan = FaultPlan { rank: 1, step: steps / 2, phase: FaultPhase::Backward };
        let opts = EngineOpts::new(preset, Strategy::Ddp, n, 12)
            .exec(ExecKind::Oracle)
            .launcher(Launcher::Thread)
            .seed(7)
            .fault_plan(Some(plan));
        let report = Supervisor::new(opts, OptimizerKind::Adam, 1e-2)
            .policy(policy.clone())
            .ckpt_every(2)
            .ckpt_path(Some(ckpt.clone()))
            .quiet(true)
            .run(steps)
            .unwrap();
        assert_eq!(report.recoveries.len(), 1, "expected exactly one recovery");
        let ev = &report.recoveries[0];
        let tot = ev.total.saturating_sub(ev.backoff).as_secs_f64() * 1e3;
        if tot < total_ms {
            total_ms = tot;
            rebuild_ms = ev.rebuild.as_secs_f64() * 1e3;
            restore_ms = ev.restore.as_secs_f64() * 1e3;
            from = ev.from_workers;
            to = ev.to_workers;
        }
        stall_ns += report.ckpt.submit_stall_ns;
        submitted += report.ckpt.submitted;
        written += report.ckpt.written;
        skipped += report.ckpt.skipped;
    }
    std::fs::remove_file(&ckpt).ok();
    let stall_per_submit = stall_ns as f64 / submitted.max(1) as f64;

    let mut t = Table::new(
        &format!(
            "elastic recovery — supervised DDP, {preset}, Thread launcher, N={n}, \
             one injected rank death (best of {reps})"
        ),
        &["metric", "value"],
    );
    t.row(vec![
        "recovery total (less backoff)".into(),
        format!("{total_ms:.2} ms"),
    ]);
    t.row(vec!["  rebuild at N'".into(), format!("{rebuild_ms:.2} ms")]);
    t.row(vec![
        "  restore from snapshot".into(),
        format!("{restore_ms:.2} ms"),
    ]);
    t.row(vec!["world size".into(), format!("{from} -> {to}")]);
    t.row(vec![
        "ckpt submit stall / snapshot".into(),
        format!(
            "{stall_per_submit:.0} ns ({submitted} submitted, {written} written, \
             {skipped} skipped)"
        ),
    ]);
    t.print();
    t.write_csv("hotpath_elastic").unwrap();

    obj.insert("elastic_recovery_ms".into(), Json::Num(total_ms));
    obj.insert("elastic_rebuild_ms".into(), Json::Num(rebuild_ms));
    obj.insert("elastic_restore_ms".into(), Json::Num(restore_ms));
    obj.insert("ckpt_async_stall_ns".into(), Json::Num(stall_per_submit));
    obj.insert("ckpt_written".into(), Json::Num(written as f64));
    obj.insert("ckpt_skipped".into(), Json::Num(skipped as f64));
}

/// §Perf ablation: policy × gradient-bucket size at the engine level
/// (DDP under the Thread launcher — the engine whose backward issues the
/// bucketed allreduces the scheduler interleaves), on `tiny` and
/// `tiny-wide`. Printed + CSV only; EXPERIMENTS.md records a snapshot.
fn scheduler_ablation() {
    let iters = if quick() { 4 } else { 12 };
    let mut t = Table::new(
        "scheduler ablation — DDP N=4, Thread launcher, oracle",
        &["preset", "policy", "bucket", "median step"],
    );
    for preset in ["tiny", "tiny-wide"] {
        let cfg = rtp::config::presets::get(preset).unwrap();
        let batch = Batch::synth(&cfg, 4, &mut Rng::new(1));
        for policy in
            [SchedPolicy::Fifo, SchedPolicy::RoundRobin, SchedPolicy::Priority]
        {
            for bucket in [None, Some(256u64 << 10), Some(1u64 << 20)] {
                let mut e = build_engine(
                    &EngineOpts::new(preset, Strategy::Ddp, 4, 4)
                        .exec(ExecKind::Oracle)
                        .launcher(Launcher::Thread)
                        .sched_policy(policy)
                        .bucket_bytes(bucket),
                )
                .unwrap();
                e.step(&batch).unwrap(); // warm
                let s = bench(1, iters, || {
                    e.zero_grads();
                    e.step(&batch).unwrap();
                });
                t.row(vec![
                    preset.into(),
                    policy.name().into(),
                    bucket.map_or("mono".into(), |b| format!("{} KiB", b >> 10)),
                    format!("{:.2} ms", s.median * 1e3),
                ]);
            }
        }
    }
    t.print();
    t.write_csv("hotpath_sched_ablation").unwrap();
}

/// One Thread-launcher FSDP configuration: warm, measure per-step fabric
/// counters (allocations + background busy/wait), then time steps.
/// Returns (median step seconds, fabric allocs/step, hidden-comm
/// fraction).
fn fsdp_thread_step(
    preset: &str,
    batch: &Batch,
    n: usize,
    background: bool,
) -> (f64, f64, f64) {
    let mut e = build_engine(
        &EngineOpts::new(preset, Strategy::Fsdp, n, n)
            .exec(ExecKind::Oracle)
            .launcher(Launcher::Thread)
            .async_rotation(background),
    )
    .unwrap();
    // warm: prime lane pools + reconstruction/staging scratch buffers
    for _ in 0..3 {
        e.zero_grads();
        e.step(batch).unwrap();
    }
    // counters aggregate over the WHOLE timed loop (not one step): on a
    // starved CI runner any single step's scheduling is noise, but across
    // the loop the barrier-joined reduce-scatters reliably show hidden
    // comm, and alloc counts average out transient pool-skew misses
    let fab = e.ctx().cluster.fabric().clone();
    let iters = if quick() { 6 } else { 16 };
    let c0 = fab.counters();
    let s = bench(1, iters, || {
        e.zero_grads();
        e.step(batch).unwrap();
    });
    let c1 = fab.counters();
    let steps = (iters + 1) as f64; // bench's warmup call included
    let allocs = (c1.msg_allocs - c0.msg_allocs) as f64 / steps;
    let busy = (c1.bg_busy_ns - c0.bg_busy_ns) as f64;
    let wait = (c1.bg_wait_ns - c0.bg_wait_ns) as f64;
    let hidden = if busy > 0.0 { (1.0 - wait / busy).max(0.0) } else { 0.0 };
    (s.median, allocs, hidden)
}

/// The FSDP side of the acceptance measurement: real background
/// collectives (prefetch allgather + backward reduce-scatter on per-rank
/// comm threads) vs execute-at-join streams, both under the Thread
/// launcher. The counter-based hidden-comm fraction — `1 - (ns blocked
/// in joins) / (ns executing collective hops)` — is the headline
/// measured overlap: it is strictly positive exactly when the comm
/// threads genuinely hid hops behind compute on the data path.
fn fsdp_profile(preset: &str, batch: &Batch, obj: &mut BTreeMap<String, Json>) {
    let n = 4;
    let (sync_med, sync_allocs, _) = fsdp_thread_step(preset, batch, n, false);
    let (mut async_med, mut async_allocs, mut hidden) =
        fsdp_thread_step(preset, batch, n, true);
    // the hidden fraction is a measured quantity on a possibly-starved
    // machine: a genuinely overlapping engine clears the CI gate's floor
    // (baseline 0.02) easily; a broken one stays at 0 across retries —
    // re-measure anything under the floor so the gate rejects
    // regressions, not scheduler noise
    for _ in 0..2 {
        if hidden >= 0.02 {
            break;
        }
        eprintln!(
            "fsdp hidden-comm fraction {hidden:.4} below gate floor — re-measuring"
        );
        (async_med, async_allocs, hidden) = fsdp_thread_step(preset, batch, n, true);
    }
    let step_overlap = (1.0 - async_med / sync_med).max(0.0);
    let modeled = modeled_overlap(preset, Strategy::Fsdp, n);

    let mut t = Table::new(
        &format!(
            "FSDP background collectives — ThreadLauncher, {preset}, oracle, N={n} \
             (execute-at-join vs per-rank comm threads)"
        ),
        &[
            "collectives",
            "median step",
            "fabric allocs/step",
            "hidden-comm fraction",
        ],
    );
    t.row(vec![
        "sync (at join)".into(),
        format!("{:.2} ms", sync_med * 1e3),
        format!("{sync_allocs:.0}"),
        "—".into(),
    ]);
    t.row(vec![
        "background (comm thread)".into(),
        format!("{:.2} ms", async_med * 1e3),
        format!("{async_allocs:.0}"),
        format!("{:.1}%", 100.0 * hidden),
    ]);
    t.print();
    t.write_csv("hotpath_fsdp_background").unwrap();
    println!(
        "FSDP step-ratio overlap vs sync: {:.1}%  modeled (α-β): {:.1}%",
        100.0 * step_overlap,
        100.0 * modeled
    );
    if hidden <= 0.0 {
        println!(
            "WARNING: FSDP background collectives hid no comm \
             (bg_wait >= bg_busy) — overlap regression?"
        );
    }

    obj.insert("fsdp_sync_step_ms".into(), Json::Num(sync_med * 1e3));
    obj.insert("fsdp_async_step_ms".into(), Json::Num(async_med * 1e3));
    obj.insert("fsdp_measured_overlap_fraction".into(), Json::Num(hidden));
    obj.insert(
        "fsdp_step_speedup_overlap_fraction".into(),
        Json::Num(step_overlap),
    );
    obj.insert("fsdp_modeled_overlap_fraction".into(), Json::Num(modeled));
    obj.insert("fsdp_allocs_per_step_sync".into(), Json::Num(sync_allocs));
    obj.insert("fsdp_allocs_per_step_async".into(), Json::Num(async_allocs));
}
