//! Paper Fig 10 — throughput (wps) vs per-GPU batch for GPT2-500M on
//! 8×A100/NVLink: DDP vs FSDP vs RTP-inplace vs RTP-outofplace, swept to
//! each strategy's maximum batch, with the §5.4 deltas printed.
//!
//! Reproduced shape: RTP within −13%…−1.7% of DDP, converging as the
//! batch grows; FSDP's throughput cliff at its memory limit where RTP
//! overtakes it (the paper's ">50%" observation).

use rtp::perfmodel::{a100_nvlink, simulate::throughput_figure};

fn main() {
    throughput_figure("gpt2-500m", a100_nvlink(), "Fig 10", 8);
}
