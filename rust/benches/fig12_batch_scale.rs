//! Paper Fig 12 (appendix A) — peak memory per GPU vs batch size:
//! RTP scales linearly from the smallest base; DDP/FSDP start higher
//! (replica / reconstruction overheads) and converge toward similar
//! maximum batch sizes as activations dominate.

use rtp::bench_util::Table;
use rtp::config::Strategy;
use rtp::perfmodel::{a100_nvlink, simulate, SimSpec};
use rtp::util::bytes::human;

const PRESET: &str = "gpt2-500m";
const N: usize = 8;

fn main() {
    let strategies = [
        Strategy::Ddp,
        Strategy::Fsdp,
        Strategy::RtpInplace,
        Strategy::RtpOutOfPlace,
    ];
    let mut t = Table::new(
        "Fig 12 — peak memory per GPU vs per-GPU batch (gpt2-500m, 8×A100)",
        &["batch/gpu", "ddp", "fsdp", "rtp-in", "rtp-out"],
    );
    let mut batch = N;
    while batch <= 1024 {
        let mut cells = vec![(batch / N).to_string()];
        for s in strategies {
            let mut spec = SimSpec::new(PRESET, s, N, batch, a100_nvlink());
            spec.enforce_capacity = true;
            let r = simulate(&spec).unwrap();
            cells.push(match r.oom {
                Some(_) => "OOM".into(),
                None => human(r.peak_per_worker),
            });
        }
        t.row(cells);
        batch *= 2;
    }
    t.print();
    t.write_csv("fig12_batch_scale").unwrap();

    // linearity check: RTP-inplace peak growth must be affine in batch
    let peak = |b: usize| {
        let mut spec = SimSpec::new(PRESET, Strategy::RtpInplace, N, b, a100_nvlink());
        spec.enforce_capacity = false;
        simulate(&spec).unwrap().peak_per_worker as f64
    };
    let (p1, p2, p4) = (peak(N), peak(2 * N), peak(4 * N));
    let slope1 = p2 - p1;
    let slope2 = (p4 - p2) / 2.0;
    println!(
        "RTP-inplace linearity: slope {:.1} MiB/sample vs {:.1} MiB/sample (ratio {:.3})",
        slope1 / (1 << 20) as f64,
        slope2 / (1 << 20) as f64,
        slope2 / slope1
    );
}
