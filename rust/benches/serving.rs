//! Serving-path bench: continuous-batching throughput and per-token
//! latency over a Poisson arrival trace, per sharding strategy.
//!
//! Each scenario replays the SAME deterministic step-indexed trace
//! (repo `Rng`, seeded) through a fresh serving engine and reports
//! tokens/s, TPOT p50/p99, KV-page allocations per generated token and
//! per-rank peak KV bytes. The alloc and peak numbers are properties of
//! the allocation *schedule*, not the host, so CI gates them hard; the
//! latency numbers vary with hardware, so CI only gates p99 TPOT
//! against a generous guard-rail baseline (>10% over fails).
//!
//! Run: `cargo bench --bench serving` — prints the table and writes
//! `figures/BENCH_serving.json`, which CI's bench-smoke job diffs
//! against the repo-root `BENCH_serving.json` baseline via
//! scripts/check_bench_overlap.py. `RTP_BENCH_QUICK=1` trims the trace
//! for CI.

use std::collections::BTreeMap;

use rtp::bench_util::{figures_dir, Table};
use rtp::config::Strategy;
use rtp::serve::{build_serve_engine, poisson_trace, ServeOpts, ServeReport};
use rtp::util::json::Json;

const PRESET: &str = "tiny";
const PROMPT_LEN: usize = 4;
const MAX_NEW: usize = 12;
const PAGE_TOKENS: usize = 8;
const MAX_BATCH: usize = 4;
const RATE_PER_STEP: f64 = 0.7;
const TRACE_SEED: u64 = 42;

fn quick() -> bool {
    std::env::var("RTP_BENCH_QUICK").is_ok()
}

fn run_scenario(strategy: Strategy, workers: usize, n_req: usize) -> ServeReport {
    let opts = ServeOpts::new(PRESET)
        .strategy(strategy)
        .workers(workers)
        .max_batch(MAX_BATCH)
        .page_tokens(PAGE_TOKENS)
        .seed(7);
    let cfg = opts.cfg().unwrap();
    let trace =
        poisson_trace(&cfg, n_req, RATE_PER_STEP, PROMPT_LEN, MAX_NEW, TRACE_SEED);
    let mut eng = build_serve_engine(&opts).unwrap();
    eng.run_trace(&trace).unwrap();
    let rep = eng.report();
    assert_eq!(rep.finished.len(), n_req, "{strategy}: trace did not drain");
    assert!(rep.rejected.is_empty());
    eng.shutdown();
    rep
}

fn main() {
    let n_req = if quick() { 6 } else { 24 };
    let scenarios: [(&str, Strategy, usize); 4] = [
        ("single", Strategy::Single, 1),
        ("megatron_tp", Strategy::MegatronTp, 4),
        ("rtp_inplace", Strategy::RtpInplace, 4),
        ("rtp_outofplace", Strategy::RtpOutOfPlace, 4),
    ];

    let mut t = Table::new(
        &format!(
            "serving — continuous batching over a Poisson trace ({PRESET}, \
             {n_req} requests, rate {RATE_PER_STEP}/step, prompt {PROMPT_LEN}, \
             max_new {MAX_NEW}, batch {MAX_BATCH}, page {PAGE_TOKENS})"
        ),
        &[
            "scenario",
            "tokens/s",
            "TPOT p50",
            "TPOT p99",
            "KV allocs/token",
            "KV peak/rank",
        ],
    );
    let mut obj = BTreeMap::new();
    for (name, strategy, workers) in scenarios {
        let rep = run_scenario(strategy, workers, n_req);
        t.row(vec![
            format!("{name}/N={workers}"),
            format!("{:.0}", rep.tokens_per_s),
            format!("{:.3} ms", rep.tpot_p50_ms),
            format!("{:.3} ms", rep.tpot_p99_ms),
            format!("{:.4}", rep.kv_allocs_per_token),
            format!("{} B", rep.kv_peak_bytes_per_rank),
        ]);
        obj.insert(format!("{name}_tokens_per_s"), Json::Num(rep.tokens_per_s));
        obj.insert(format!("{name}_p50_tpot_ms"), Json::Num(rep.tpot_p50_ms));
        obj.insert(format!("{name}_p99_tpot_ms"), Json::Num(rep.tpot_p99_ms));
        obj.insert(
            format!("{name}_kv_allocs_per_token"),
            Json::Num(rep.kv_allocs_per_token),
        );
        obj.insert(
            format!("{name}_kv_peak_bytes_per_rank"),
            Json::Num(rep.kv_peak_bytes_per_rank as f64),
        );
    }
    t.print();
    t.write_csv("serving").unwrap();

    obj.insert("preset".into(), Json::Str(PRESET.into()));
    obj.insert("requests".into(), Json::Num(n_req as f64));
    obj.insert("prompt_len".into(), Json::Num(PROMPT_LEN as f64));
    obj.insert("max_new".into(), Json::Num(MAX_NEW as f64));
    obj.insert("page_tokens".into(), Json::Num(PAGE_TOKENS as f64));
    obj.insert("max_batch".into(), Json::Num(MAX_BATCH as f64));
    obj.insert("quick_mode".into(), Json::Bool(quick()));
    let path = figures_dir().join("BENCH_serving.json");
    std::fs::create_dir_all(figures_dir()).unwrap();
    std::fs::write(&path, format!("{}\n", Json::Obj(obj))).unwrap();
    println!("wrote {}", path.display());
    println!(
        "(kv_allocs_per_token is deterministic — layers × pages-per-request ÷ \
         tokens-per-request — and CI fails on ANY increase; p99 TPOT is gated \
         at +10% over the baseline guard-rail)"
    );
}
