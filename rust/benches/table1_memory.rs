//! Paper Table 1: analytic Activations / Parameters / Memory-Duplication
//! per technique, cross-checked against the MEASURED virtual-mode totals
//! of the engines (whole-model FSDP granularity reproduces the table's
//! worst-case FSDP row).
//!
//! Run: `cargo bench --bench table1_memory` — prints the table and writes
//! `figures/table1_memory.csv`.

use rtp::bench_util::Table;
use rtp::config::{presets, Strategy};
use rtp::memory::analytic::{pipeline_row, table1_row};
use rtp::parallel::fsdp::Granularity;
use rtp::parallel::{build_engine, Batch, EngineOpts, ExecKind};
use rtp::tensor::IntTensor;
use rtp::util::bytes::human;

const PRESET: &str = "gpt2-500m";
const N: usize = 8;
const BATCH: usize = 8;

fn measured_total(strategy: Strategy, granularity: Granularity) -> u64 {
    let opts = EngineOpts::new(PRESET, strategy, N, BATCH)
        .exec(ExecKind::Virtual)
        .fsdp_granularity(granularity);
    let cfg = opts.cfg().unwrap();
    let mut e = build_engine(&opts).unwrap();
    let b = Batch {
        ids: IntTensor::zeros(&[BATCH, cfg.seq]),
        targets: IntTensor::zeros(&[BATCH, cfg.seq]),
    };
    e.step(&b).unwrap();
    e.ctx().cluster.total_peak()
}

fn main() {
    let cfg = presets::get(PRESET).unwrap();
    let a = BATCH as u64 * cfg.activation_bytes_per_sample();
    let w = cfg.weight_bytes();
    let g = w;
    let ideal = a + w + g;

    let mut t = Table::new(
        &format!("Table 1 — memory per technique ({PRESET}, N={N}, batch {BATCH}, G=W)"),
        &["technique", "activations", "parameters", "duplication", "measured total", "meas dup"],
    );
    for strategy in [
        Strategy::Single,
        Strategy::MegatronTp,
        Strategy::Ddp,
        Strategy::Fsdp,
        Strategy::RtpOutOfPlace,
        Strategy::RtpInplace,
    ] {
        let row = table1_row(strategy, a, w, g, N as u64);
        let gran = if strategy == Strategy::Fsdp {
            Granularity::Model // the Table-1 worst case
        } else {
            Granularity::Layer
        };
        let measured = measured_total(strategy, gran);
        t.row(vec![
            row.technique.clone(),
            human(row.activations),
            human(row.parameters),
            human(row.duplication),
            human(measured),
            human(measured.saturating_sub(ideal)),
        ]);
    }
    // pipeline appears in the paper's table but not as an engine (RTP is
    // orthogonal to pipeline parallelism — paper §4)
    let ap = a / (4 * N as u64);
    let p = pipeline_row(a, w, g, ap, N as u64);
    t.row(vec![
        p.technique.clone(),
        human(p.activations),
        human(p.parameters),
        human(p.duplication),
        "— (analytic only)".into(),
        "—".into(),
    ]);
    t.print();
    t.write_csv("table1_memory").unwrap();

    // headline check: RTP dup << FSDP dup (paper: >75% savings)
    let fsdp = table1_row(Strategy::Fsdp, a, w, g, N as u64).duplication;
    let rtp = table1_row(Strategy::RtpOutOfPlace, a, w, g, N as u64).duplication;
    println!(
        "RTP duplication is {:.1}% of FSDP's (paper claims <25%)\n",
        100.0 * rtp as f64 / fsdp as f64
    );
}
