//! Paper Table 1: analytic Activations / Parameters / Memory-Duplication
//! per technique, cross-checked against the MEASURED virtual-mode totals
//! of the engines (whole-model FSDP granularity reproduces the table's
//! worst-case FSDP row).
//!
//! Run: `cargo bench --bench table1_memory` — prints the table and writes
//! `figures/table1_memory.csv`.

use rtp::bench_util::Table;
use rtp::config::{presets, Strategy};
use rtp::memory::analytic::{kv_cache_bytes_per_rank, pipeline_row, table1_row};
use rtp::memory::MemCategory;
use rtp::parallel::fsdp::Granularity;
use rtp::parallel::{build_engine, Batch, EngineOpts, ExecKind};
use rtp::serve::{build_serve_engine, GenRequest, ServeOpts};
use rtp::tensor::IntTensor;
use rtp::util::bytes::human;
use rtp::util::rng::Rng;

const PRESET: &str = "gpt2-500m";
const N: usize = 8;
const BATCH: usize = 8;

fn measured_total(strategy: Strategy, granularity: Granularity) -> u64 {
    let opts = EngineOpts::new(PRESET, strategy, N, BATCH)
        .exec(ExecKind::Virtual)
        .fsdp_granularity(granularity);
    let cfg = opts.cfg().unwrap();
    let mut e = build_engine(&opts).unwrap();
    let b = Batch {
        ids: IntTensor::zeros(&[BATCH, cfg.seq]),
        targets: IntTensor::zeros(&[BATCH, cfg.seq]),
    };
    e.step(&b).unwrap();
    e.ctx().cluster.total_peak()
}

fn main() {
    let cfg = presets::get(PRESET).unwrap();
    let a = BATCH as u64 * cfg.activation_bytes_per_sample();
    let w = cfg.weight_bytes();
    let g = w;
    let ideal = a + w + g;

    let mut t = Table::new(
        &format!("Table 1 — memory per technique ({PRESET}, N={N}, batch {BATCH}, G=W)"),
        &["technique", "activations", "parameters", "duplication", "measured total", "meas dup"],
    );
    for strategy in [
        Strategy::Single,
        Strategy::MegatronTp,
        Strategy::Ddp,
        Strategy::Fsdp,
        Strategy::RtpOutOfPlace,
        Strategy::RtpInplace,
    ] {
        let row = table1_row(strategy, a, w, g, N as u64);
        let gran = if strategy == Strategy::Fsdp {
            Granularity::Model // the Table-1 worst case
        } else {
            Granularity::Layer
        };
        let measured = measured_total(strategy, gran);
        t.row(vec![
            row.technique.clone(),
            human(row.activations),
            human(row.parameters),
            human(row.duplication),
            human(measured),
            human(measured.saturating_sub(ideal)),
        ]);
    }
    // pipeline appears in the paper's table but not as an engine (RTP is
    // orthogonal to pipeline parallelism — paper §4)
    let ap = a / (4 * N as u64);
    let p = pipeline_row(a, w, g, ap, N as u64);
    t.row(vec![
        p.technique.clone(),
        human(p.activations),
        human(p.parameters),
        human(p.duplication),
        "— (analytic only)".into(),
        "—".into(),
    ]);
    t.print();
    t.write_csv("table1_memory").unwrap();

    // headline check: RTP dup << FSDP dup (paper: >75% savings)
    let fsdp = table1_row(Strategy::Fsdp, a, w, g, N as u64).duplication;
    let rtp = table1_row(Strategy::RtpOutOfPlace, a, w, g, N as u64).duplication;
    println!(
        "RTP duplication is {:.1}% of FSDP's (paper claims <25%)\n",
        100.0 * rtp as f64 / fsdp as f64
    );

    serving_kv_table();
}

/// The serving sibling of Table 1: per-rank KV-cache bytes per strategy,
/// analytic closed form vs the bytes the MemTracker actually recorded
/// under `MemCategory::KvCache` while serving one request to completion
/// on the tiny preset. Head-sharded strategies (TP and both RTP
/// variants) hold `hidden/N` of every cached position per rank, so the
/// cache that binds serving memory dedupes N-ways — the paper's
/// deduplication story applied at inference. Also prints the analytic
/// projection for the Table-1 GPT-2 preset at N=8 (too large to decode
/// in a bench, but the closed form is tracker-exact by the tiny rows).
fn serving_kv_table() {
    let cfg = presets::get("tiny").unwrap();
    let (prompt_len, max_new, page_tokens) = (4usize, 8usize, 8usize);
    let total_positions = prompt_len + max_new - 1;

    let mut t = Table::new(
        &format!(
            "serving KV-cache per rank (tiny, 1 request, {total_positions} \
             positions, pages of {page_tokens})"
        ),
        &["technique", "workers", "analytic", "tracked peak", "match"],
    );
    for (strategy, n) in [
        (Strategy::Single, 1usize),
        (Strategy::MegatronTp, 4),
        (Strategy::RtpInplace, 4),
        (Strategy::RtpOutOfPlace, 4),
    ] {
        let opts = ServeOpts::new("tiny")
            .strategy(strategy)
            .workers(n)
            .max_batch(1)
            .page_tokens(page_tokens);
        let mut eng = build_serve_engine(&opts).unwrap();
        let mut rng = Rng::new(4);
        let prompt = (0..prompt_len).map(|_| rng.below(cfg.vocab) as i32).collect();
        eng.submit(GenRequest { id: 0, prompt, max_new });
        eng.drain().unwrap();
        let tracked =
            eng.cluster().workers[0].tracker.peak_of(MemCategory::KvCache);
        let analytic = kv_cache_bytes_per_rank(
            strategy,
            &cfg,
            total_positions,
            page_tokens,
            n as u64,
        );
        assert_eq!(tracked, analytic, "{strategy}: tracked KV peak != analytic");
        t.row(vec![
            format!("{strategy}"),
            n.to_string(),
            human(analytic),
            human(tracked),
            "✓".into(),
        ]);
        eng.shutdown();
    }
    t.print();
    t.write_csv("table1_serving_kv").unwrap();

    // the same closed form at the paper's scale (analytic only)
    let big = presets::get(PRESET).unwrap();
    let positions = big.seq;
    let full = kv_cache_bytes_per_rank(Strategy::Single, &big, positions, 16, 1);
    let shard =
        kv_cache_bytes_per_rank(Strategy::RtpInplace, &big, positions, 16, N as u64);
    println!(
        "at {PRESET} scale, one full-context sequence caches {} of KV — \
         head-sharded over N={N} ranks that is {} per rank\n",
        human(full),
        human(shard)
    );
}
