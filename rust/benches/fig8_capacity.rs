//! Paper Fig 8 — Model Capacity Evaluation: peak memory per GPU for every
//! Table-2 model × strategy at LOCAL_BATCH_SIZE=1 on 8×A100-80GB, with
//! OOM marking.
//!
//! Substitution note (DESIGN.md §2): we run f32 with Adam (16 B/param of
//! state for DDP) where the paper ran fp16 + fp32 optimizer — the
//! *ordering* and the capacity-cliff crossovers are the reproduced shape:
//! RTP fits every model through GPT2-neo-2.7B while DDP OOMs first and
//! FSDP carries max(W,G)·(N-1)/N extra.

use rtp::bench_util::{bar_chart, Table};
use rtp::config::{presets, OptimizerKind, Strategy};
use rtp::perfmodel::{a100_nvlink, simulate, SimSpec};
use rtp::util::bytes::{human, GIB};

const N: usize = 8;

fn main() {
    let strategies = [
        Strategy::Ddp,
        Strategy::Fsdp,
        Strategy::MegatronTp,
        Strategy::RtpOutOfPlace,
        Strategy::RtpInplace,
    ];
    let mut t = Table::new(
        "Fig 8 — peak memory per GPU (8×A100-80GB, local batch 1, Adam)",
        &["model", "ddp", "fsdp", "megatron-tp", "rtp-out", "rtp-in"],
    );
    let mut chart_rows = Vec::new();
    for model in presets::table2() {
        let mut cells = vec![model.name.clone()];
        for strategy in strategies {
            if strategy == Strategy::MegatronTp && model.is_moe() {
                cells.push("n/a".into());
                continue;
            }
            let mut spec =
                SimSpec::new(&model.name, strategy, N, N, a100_nvlink());
            spec.optimizer = OptimizerKind::Adam;
            let r = simulate(&spec).unwrap();
            match r.oom {
                Some(_) => cells.push("OOM".into()),
                None => {
                    if strategy == Strategy::RtpInplace {
                        chart_rows.push((
                            model.name.clone(),
                            r.peak_per_worker as f64 / GIB as f64,
                        ));
                    }
                    cells.push(human(r.peak_per_worker));
                }
            }
        }
        t.row(cells);
    }
    t.print();
    t.write_csv("fig8_capacity").unwrap();
    println!("{}", bar_chart("Fig 8 — RTP-inplace peak per GPU", &chart_rows, "GiB", 50));

    // the paper's headline capacity claim, restated at this testbed's
    // effective budget: the largest Table-2 model each strategy can train
    let mut cap = Table::new(
        "largest Table-2 model trainable (Adam, local batch 1)",
        &["strategy", "80 GB cap", "24 GB cap", "8 GB cap"],
    );
    for strategy in strategies {
        let largest = |capacity: u64| {
            let mut best = "—".to_string();
            for model in presets::table2() {
                let mut hw = a100_nvlink();
                hw.capacity = capacity;
                let mut spec = SimSpec::new(&model.name, strategy, N, N, hw);
                spec.optimizer = OptimizerKind::Adam;
                if simulate(&spec).unwrap().oom.is_none() {
                    best = model.name.clone();
                }
            }
            best
        };
        cap.row(vec![
            strategy.to_string(),
            largest(80 * GIB),
            largest(24 * GIB),
            largest(8 * GIB),
        ]);
    }
    cap.print();
    cap.write_csv("fig8_capacity_cliff").unwrap();
}
