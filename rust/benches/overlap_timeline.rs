//! Paper Figs 3-5 — overlap timelines: ASCII Gantt charts of one step of
//! FSDP (Fig 3), RTP-inplace (Fig 4) and RTP-outofplace (Fig 5) on a
//! GPT2 (117M) layer stack at N=4. Shows FSDP's blocking first allgather,
//! in-place RTP's serialized rotations, and out-of-place RTP's
//! comm-hidden-under-compute (the "expedited startup time", §3.4.3).
//!
//! Since the ring-fabric refactor every comm span is ONE RING HOP: an
//! FSDP allgather renders as its N-1 chunk hops and the footer reports
//! the step's total hop count, so the charts show the real hop schedule
//! rather than opaque per-collective blocks.

use rtp::bench_util::{bench, Table};
use rtp::config::Strategy;
use rtp::parallel::{build_engine, Batch, EngineOpts, ExecKind, Launcher};
use rtp::perfmodel::{a100_nvlink, Timeline};
use rtp::tensor::IntTensor;
use rtp::util::rng::Rng;

const N: usize = 4;
const PRESET: &str = "gpt2-117m";

fn gantt(strategy: Strategy) -> (String, f64, u64) {
    let opts = EngineOpts::new(PRESET, strategy, N, N)
        .exec(ExecKind::Virtual)
        .hardware(a100_nvlink());
    let cfg = opts.cfg().unwrap();
    let mut e = build_engine(&opts).unwrap();
    // flip the timeline into recording mode
    if let Some(tl) = e.ctx_mut().timeline.as_mut() {
        *tl = Timeline::recording(a100_nvlink(), N);
    }
    let b = Batch {
        ids: IntTensor::zeros(&[N, cfg.seq]),
        targets: IntTensor::zeros(&[N, cfg.seq]),
    };
    e.step(&b).unwrap();
    let tl = e.ctx().timeline.as_ref().unwrap();
    (tl.render_gantt(100), tl.time(), tl.hop_count)
}

fn main() {
    let mut times = Vec::new();
    for (fig, strategy) in [
        ("Fig 3 — FSDP", Strategy::Fsdp),
        ("Fig 4 — RTP in-place", Strategy::RtpInplace),
        ("Fig 5 — RTP out-of-place", Strategy::RtpOutOfPlace),
    ] {
        let (g, t, hops) = gantt(strategy);
        println!("== {fig} ({PRESET}, N={N}, local batch 1) ==");
        println!("{g}");
        println!("ring hops this step: {hops}");
        println!();
        times.push((fig, t));
    }
    println!("step latencies: ");
    for (fig, t) in &times {
        println!("  {fig}: {:.3} ms", t * 1e3);
    }
    // §3.4.3 claim: overlap buys out-of-place a faster step than in-place
    assert!(times[2].1 < times[1].1, "out-of-place must beat in-place");
    println!(
        "\nout-of-place hides {:.0}% of in-place's rotation wall-clock",
        100.0 * (1.0 - times[2].1 / times[1].1)
    );

    measured_overlap();
}

/// MEASURED (not modeled) compute/comm overlap: real-mode (oracle) steps
/// on actual host data, once under the deterministic LockstepLauncher
/// (one rank at a time — zero concurrency, the serialized baseline) and
/// once under the ThreadLauncher (one OS thread per rank over the `Send`
/// fabric). The thread/lockstep wall-clock ratio is the realized overlap:
/// how much of the N ranks' compute the threads actually ran
/// concurrently, machine-measured rather than α-β-modeled.
fn measured_overlap() {
    let preset = "tiny";
    let cfg = rtp::config::presets::get(preset).unwrap();
    let n = 4;
    let batch = Batch::synth(&cfg, n, &mut Rng::new(2));
    let mut t = Table::new(
        "measured wall-clock overlap under ThreadLauncher (tiny, oracle, N=4)",
        &["engine", "lockstep", "threaded", "speedup", "parallel efficiency"],
    );
    for strategy in [Strategy::Fsdp, Strategy::RtpInplace, Strategy::RtpOutOfPlace] {
        let step_time = |launcher: Launcher| {
            let mut e = build_engine(
                &EngineOpts::new(preset, strategy, n, n)
                    .exec(ExecKind::Oracle)
                    .launcher(launcher),
            )
            .unwrap();
            e.step(&batch).unwrap(); // warm
            bench(1, 8, || {
                e.zero_grads();
                e.step(&batch).unwrap();
            })
            .median
        };
        let lockstep = step_time(Launcher::Lockstep);
        let threaded = step_time(Launcher::Thread);
        let speedup = lockstep / threaded;
        t.row(vec![
            format!("{strategy}"),
            format!("{:.2} ms", lockstep * 1e3),
            format!("{:.2} ms", threaded * 1e3),
            format!("{speedup:.2}×"),
            format!("{:.0}%", 100.0 * speedup / n as f64),
        ]);
    }
    t.print();
    t.write_csv("overlap_measured").unwrap();
    println!(
        "(speedup > 1 means the ThreadLauncher overlapped rank compute that the \
         lockstep schedule serializes; {n}× is the ideal for compute-bound steps)"
    );
}
