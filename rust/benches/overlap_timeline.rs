//! Paper Figs 3-5 — overlap timelines: ASCII Gantt charts of one step of
//! FSDP (Fig 3), RTP-inplace (Fig 4) and RTP-outofplace (Fig 5) at N=4,
//! rendered for every preset the calibration tracks. Shows FSDP's
//! blocking first allgather, in-place RTP's serialized rotations, and
//! out-of-place RTP's comm-hidden-under-compute (the "expedited startup
//! time", §3.4.3).
//!
//! Since the ring-fabric refactor every comm span is ONE RING HOP: an
//! FSDP allgather renders as its N-1 chunk hops and the footer reports
//! the step's total hop count, so the charts show the real hop schedule
//! rather than opaque per-collective blocks.
//!
//! Next to each modeled Gantt this bench reports the MEASURED Thread
//! launcher overlap (lockstep vs threaded wall-clock, and — for
//! out-of-place RTP — synchronous-boundary vs eager comm-stream
//! rotation), closing the ROADMAP's "calibrated model-vs-measured"
//! item: the final table puts the modeled overlap fraction, the measured
//! one, and their ratio side by side (also written as CSV).

use rtp::bench_util::{bench, Table};
use rtp::comm::cost::{convoy_completion_times, interleaved_completion_times};
use rtp::comm::CommPrim;
use rtp::config::Strategy;
use rtp::parallel::{build_engine, Batch, EngineOpts, ExecKind, Launcher};
use rtp::perfmodel::{a100_nvlink, Timeline};
use rtp::tensor::IntTensor;
use rtp::util::rng::Rng;

const N: usize = 4;
/// Presets the modeled Gantt + calibration run over. `tiny` is the one
/// the measured (oracle, wall-clock) side can afford; the GPT-2 stack is
/// the paper's Figs 3-5 shape.
const PRESETS: &[&str] = &["tiny", "gpt2-117m"];

fn quick() -> bool {
    std::env::var("RTP_BENCH_QUICK").is_ok()
}

/// One modeled step: returns (gantt, step time, hop count, overlap frac).
fn gantt(preset: &str, strategy: Strategy) -> (String, f64, u64, f64) {
    let opts = EngineOpts::new(preset, strategy, N, N)
        .exec(ExecKind::Virtual)
        .hardware(a100_nvlink());
    let cfg = opts.cfg().unwrap();
    let mut e = build_engine(&opts).unwrap();
    // flip the timeline into recording mode
    if let Some(tl) = e.ctx_mut().timeline.as_mut() {
        *tl = Timeline::recording(a100_nvlink(), N);
    }
    let b = Batch {
        ids: IntTensor::zeros(&[N, cfg.seq]),
        targets: IntTensor::zeros(&[N, cfg.seq]),
    };
    e.step(&b).unwrap();
    let tl = e.ctx().timeline.as_ref().unwrap();
    (tl.render_gantt(100), tl.time(), tl.hop_count, tl.overlap_fraction())
}

fn main() {
    let mut modeled_overlap_tiny = 0.0;
    let mut modeled_fsdp_tiny = 0.0;
    for preset in PRESETS {
        let mut times = Vec::new();
        for (fig, strategy) in [
            ("Fig 3 — FSDP", Strategy::Fsdp),
            ("Fig 4 — RTP in-place", Strategy::RtpInplace),
            ("Fig 5 — RTP out-of-place", Strategy::RtpOutOfPlace),
        ] {
            let (g, t, hops, ov) = gantt(preset, strategy);
            println!("== {fig} ({preset}, N={N}, local batch 1) ==");
            println!("{g}");
            println!(
                "ring hops this step: {hops}   modeled overlap: {:.0}%",
                100.0 * ov
            );
            println!();
            times.push((fig, t, ov));
        }
        println!("step latencies ({preset}):");
        for (fig, t, _) in &times {
            println!("  {fig}: {:.3} ms", t * 1e3);
        }
        // §3.4.3 claim: overlap buys out-of-place a faster step than in-place
        assert!(times[2].1 < times[1].1, "out-of-place must beat in-place");
        println!(
            "\nout-of-place hides {:.0}% of in-place's rotation wall-clock\n",
            100.0 * (1.0 - times[2].1 / times[1].1)
        );
        if *preset == "tiny" {
            modeled_overlap_tiny = times[2].2;
            modeled_fsdp_tiny = times[0].2;
        }
    }

    measured_overlap(modeled_overlap_tiny, modeled_fsdp_tiny);
    modeled_scheduler_timelines();
}

/// Modeled hop-level-scheduler timeline (α-β): a prefetch allgather
/// queued behind size-targeted gradient buckets on one rank's background
/// wire, convoy (FIFO) vs round-robin hop interleave. Same hops, same
/// wire — the TOTAL is identical by construction; what the scheduler buys
/// is the latency-critical allgather's completion time.
fn modeled_scheduler_timelines() {
    let link = a100_nvlink().link;
    let n = N;
    let mut t = Table::new(
        "modeled hop scheduler — prefetch allgather behind k grad buckets \
         (α-β, N=4, completion of the allgather)",
        &["buckets", "bucket size", "convoy", "interleaved", "AG completes at"],
    );
    for (k, bucket_bytes) in [(2usize, 1u64 << 20), (4, 1 << 20), (4, 4 << 20)] {
        let mut scheds: Vec<Vec<f64>> = (0..k)
            .map(|_| CommPrim::AllReduce.hop_schedule(bucket_bytes, n))
            .collect();
        scheds.push(CommPrim::AllGather.hop_schedule(256 << 10, n));
        let convoy = convoy_completion_times(&link, &scheds);
        let inter = interleaved_completion_times(&link, &scheds);
        let ag = scheds.len() - 1;
        t.row(vec![
            k.to_string(),
            format!("{} MiB", bucket_bytes >> 20),
            format!("{:.3} ms", convoy[ag] * 1e3),
            format!("{:.3} ms", inter[ag] * 1e3),
            format!("{:.0}% of convoy", 100.0 * inter[ag] / convoy[ag]),
        ]);
        let total_c = convoy.iter().cloned().fold(0.0, f64::max);
        let total_i = inter.iter().cloned().fold(0.0, f64::max);
        assert!(
            (total_c - total_i).abs() <= 1e-9 * total_c,
            "interleaving must not change total wire time"
        );
    }
    t.print();
    t.write_csv("overlap_sched_modeled").unwrap();
    println!(
        "(the interleaved allgather completes in ~hop_count × in-flight-set \
         wire slices instead of waiting out every bucket — the modeled form \
         of the hotpath bench's multi-collective measurement)"
    );
}

/// MEASURED (not modeled) compute/comm overlap: real-mode (oracle) steps
/// on actual host data, once under the deterministic LockstepLauncher
/// (one rank at a time — zero concurrency, the serialized baseline) and
/// once under the ThreadLauncher (one OS thread per rank over the `Send`
/// fabric). The thread/lockstep wall-clock ratio is the realized overlap:
/// how much of the N ranks' compute the threads actually ran
/// concurrently, machine-measured rather than α-β-modeled. For
/// out-of-place RTP a third column isolates the TRUE async rotation win:
/// Thread launcher with eager comm streams vs synchronous boundary hops.
/// For FSDP the same toggle isolates the BACKGROUND COLLECTIVE ENGINE:
/// per-rank comm threads running the prefetch allgather + backward
/// reduce-scatter vs execute-at-join streams.
fn measured_overlap(modeled_overlap_tiny: f64, modeled_fsdp_tiny: f64) {
    let preset = "tiny";
    let cfg = rtp::config::presets::get(preset).unwrap();
    let n = 4;
    let batch = Batch::synth(&cfg, n, &mut Rng::new(2));
    let iters = if quick() { 4 } else { 8 };
    let step_time = |strategy: Strategy, launcher: Launcher, async_rot: bool| {
        let mut e = build_engine(
            &EngineOpts::new(preset, strategy, n, n)
                .exec(ExecKind::Oracle)
                .launcher(launcher)
                .async_rotation(async_rot),
        )
        .unwrap();
        e.step(&batch).unwrap(); // warm
        bench(1, iters, || {
            e.zero_grads();
            e.step(&batch).unwrap();
        })
        .median
    };
    let mut t = Table::new(
        "measured wall-clock overlap under ThreadLauncher (tiny, oracle, N=4)",
        &["engine", "lockstep", "threaded", "speedup", "parallel efficiency"],
    );
    for strategy in [Strategy::Fsdp, Strategy::RtpInplace, Strategy::RtpOutOfPlace] {
        let lockstep = step_time(strategy, Launcher::Lockstep, true);
        let threaded = step_time(strategy, Launcher::Thread, true);
        let speedup = lockstep / threaded;
        t.row(vec![
            format!("{strategy}"),
            format!("{:.2} ms", lockstep * 1e3),
            format!("{:.2} ms", threaded * 1e3),
            format!("{speedup:.2}×"),
            format!("{:.0}%", 100.0 * speedup / n as f64),
        ]);
    }
    t.print();
    t.write_csv("overlap_measured").unwrap();
    println!(
        "(speedup > 1 means the ThreadLauncher overlapped rank compute that the \
         lockstep schedule serializes; {n}× is the ideal for compute-bound steps)"
    );

    // calibration: modeled vs measured ASYNC-ROTATION overlap
    let sync_rot = step_time(Strategy::RtpOutOfPlace, Launcher::Thread, false);
    let async_rot = step_time(Strategy::RtpOutOfPlace, Launcher::Thread, true);
    let measured = (1.0 - async_rot / sync_rot).max(0.0);
    let mut c = Table::new(
        "model-vs-measured rotation overlap (rtp-outofplace, tiny, N=4)",
        &["metric", "value"],
    );
    c.row(vec![
        "sync-rotation step (thread)".into(),
        format!("{:.2} ms", sync_rot * 1e3),
    ]);
    c.row(vec![
        "async-rotation step (thread)".into(),
        format!("{:.2} ms", async_rot * 1e3),
    ]);
    c.row(vec![
        "measured overlap fraction".into(),
        format!("{:.1}%", 100.0 * measured),
    ]);
    c.row(vec![
        "modeled overlap fraction".into(),
        format!("{:.1}%", 100.0 * modeled_overlap_tiny),
    ]);
    c.row(vec![
        "measured / modeled".into(),
        format!(
            "{:.2}",
            if modeled_overlap_tiny > 0.0 { measured / modeled_overlap_tiny } else { 0.0 }
        ),
    ]);
    c.print();
    c.write_csv("overlap_model_vs_measured").unwrap();

    // calibration: modeled vs measured FSDP background-collective overlap
    // (prefetch allgather + backward reduce-scatter on per-rank comm
    // threads vs execute-at-join streams, both under the Thread launcher)
    let fsdp_sync = step_time(Strategy::Fsdp, Launcher::Thread, false);
    let fsdp_async = step_time(Strategy::Fsdp, Launcher::Thread, true);
    let fsdp_measured = (1.0 - fsdp_async / fsdp_sync).max(0.0);
    let mut f = Table::new(
        "model-vs-measured FSDP background collectives (fsdp, tiny, N=4)",
        &["metric", "value"],
    );
    f.row(vec![
        "execute-at-join step (thread)".into(),
        format!("{:.2} ms", fsdp_sync * 1e3),
    ]);
    f.row(vec![
        "background-engine step (thread)".into(),
        format!("{:.2} ms", fsdp_async * 1e3),
    ]);
    f.row(vec![
        "measured overlap fraction".into(),
        format!("{:.1}%", 100.0 * fsdp_measured),
    ]);
    f.row(vec![
        "modeled overlap fraction".into(),
        format!("{:.1}%", 100.0 * modeled_fsdp_tiny),
    ]);
    f.row(vec![
        "measured / modeled".into(),
        format!(
            "{:.2}",
            if modeled_fsdp_tiny > 0.0 { fsdp_measured / modeled_fsdp_tiny } else { 0.0 }
        ),
    ]);
    f.print();
    f.write_csv("overlap_fsdp_model_vs_measured").unwrap();
}
