//! Paper Fig 13 (appendix B) — GPT throughput on 8×V100-32GB over PCIe.
//! The slow interconnect stresses overlap: in-place RTP's blocking
//! rotations hurt most at small batch, out-of-place hides them; RTP
//! overtakes FSDP at large batch ("perfect overlapping", appendix B).

use rtp::perfmodel::{simulate::throughput_figure, v100_pcie};

fn main() {
    throughput_figure("gpt2-500m", v100_pcie(), "Fig 13", 8);
}
