//! Ablations over the design choices DESIGN.md calls out:
//!   1. RTP out-of-place §3.4.4 buffer recycling (on/off) — peak memory;
//!   2. FSDP unit granularity (per-layer vs whole-model) — peak memory;
//!   3. worker-count scaling N ∈ {2,4,8,16} — RTP per-worker peak and
//!      throughput (the paper's "near-linear scalability" claim);
//!   4. in-place vs out-of-place across interconnects (overlap value).

use rtp::bench_util::Table;
use rtp::config::Strategy;
use rtp::parallel::fsdp::Granularity;
use rtp::parallel::{build_engine, Batch, EngineOpts, ExecKind};
use rtp::perfmodel::{a100_nvlink, simulate, v100_pcie, SimSpec};
use rtp::tensor::IntTensor;
use rtp::util::bytes::human;

const PRESET: &str = "gpt2-500m";

fn peak_with(opts: EngineOpts, batch: usize) -> u64 {
    let cfg = opts.cfg().unwrap();
    let mut e = build_engine(&opts).unwrap();
    let b = Batch {
        ids: IntTensor::zeros(&[batch, cfg.seq]),
        targets: IntTensor::zeros(&[batch, cfg.seq]),
    };
    e.step(&b).unwrap();
    e.ctx().cluster.max_peak()
}

fn main() {
    // 1. recycling
    let mut t = Table::new(
        "ablation 1 — RTP-oop §3.4.4 buffer recycling (peak/worker, N=8, batch 8)",
        &["recycle", "peak/worker", "delta"],
    );
    let on = peak_with(
        EngineOpts::new(PRESET, Strategy::RtpOutOfPlace, 8, 8)
            .exec(ExecKind::Virtual)
            .rtp_recycle(true),
        8,
    );
    let off = peak_with(
        EngineOpts::new(PRESET, Strategy::RtpOutOfPlace, 8, 8)
            .exec(ExecKind::Virtual)
            .rtp_recycle(false),
        8,
    );
    t.row(vec!["on".into(), human(on), "—".into()]);
    t.row(vec!["off".into(), human(off), format!("+{}", human(off - on))]);
    t.print();
    t.write_csv("ablation_recycle").unwrap();

    // 2. fsdp granularity
    let mut t = Table::new(
        "ablation 2 — FSDP unit granularity (peak/worker, N=8, batch 8)",
        &["granularity", "peak/worker"],
    );
    for (name, g) in [("per-layer", Granularity::Layer), ("whole-model", Granularity::Model)] {
        let p = peak_with(
            EngineOpts::new(PRESET, Strategy::Fsdp, 8, 8)
                .exec(ExecKind::Virtual)
                .fsdp_granularity(g),
            8,
        );
        t.row(vec![name.into(), human(p)]);
    }
    t.print();
    t.write_csv("ablation_fsdp_granularity").unwrap();

    // 3. N-scaling (memory near-linear, throughput overhead)
    let mut t = Table::new(
        "ablation 3 — RTP scaling with N (batch/gpu = 1)",
        &["N", "peak/worker", "ideal/N", "wps", "wps vs ddp"],
    );
    for n in [2usize, 4, 8, 16] {
        let mut spec = SimSpec::new(PRESET, Strategy::RtpInplace, n, n, a100_nvlink());
        spec.enforce_capacity = false;
        let r = simulate(&spec).unwrap();
        let mut dspec = spec.clone();
        dspec.strategy = Strategy::Ddp;
        let d = simulate(&dspec).unwrap();
        let cfg = rtp::config::presets::get(PRESET).unwrap();
        let ideal = (n as u64 * cfg.activation_bytes_per_sample()
            + 2 * cfg.weight_bytes())
            / n as u64;
        t.row(vec![
            n.to_string(),
            human(r.peak_per_worker),
            human(ideal),
            format!("{:.0}", r.wps),
            format!("{:+.1}%", 100.0 * (r.wps / d.wps - 1.0)),
        ]);
    }
    t.print();
    t.write_csv("ablation_n_scaling").unwrap();

    // 4. overlap value by interconnect
    let mut t = Table::new(
        "ablation 4 — in-place vs out-of-place step time (N=8, batch 8)",
        &["hardware", "rtp-in", "rtp-out", "overlap speedup"],
    );
    for hw in [a100_nvlink(), v100_pcie()] {
        let i = simulate(&SimSpec::new(PRESET, Strategy::RtpInplace, 8, 8, hw.clone()))
            .unwrap();
        let o = simulate(&SimSpec::new(PRESET, Strategy::RtpOutOfPlace, 8, 8, hw.clone()))
            .unwrap();
        t.row(vec![
            hw.name.clone(),
            format!("{:.2} ms", i.step_time * 1e3),
            format!("{:.2} ms", o.step_time * 1e3),
            format!("{:.2}x", i.step_time / o.step_time),
        ]);
    }
    t.print();
    t.write_csv("ablation_overlap").unwrap();
}
