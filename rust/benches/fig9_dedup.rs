//! Paper Fig 9 — Memory Deduplication Evaluation: GLOBAL_BATCH_SIZE=8 on
//! 8 GPUs; per-card peak × 8 compared against the single-device
//! "idealized computer" running the same global batch. RTP variants must
//! land near 1× the ideal; FSDP and TP land 2-4× above.

use rtp::bench_util::{bar_chart, Table};
use rtp::config::Strategy;
use rtp::perfmodel::{a100_nvlink, simulate, SimSpec};
use rtp::util::bytes::human;

const N: usize = 8;
const GLOBAL_BATCH: usize = 8;
const MODELS: [&str; 3] = ["gpt2-117m", "bert-large-340m", "gpt-up-to-a100"];

fn total_of(model: &str, strategy: Strategy, workers: usize) -> u64 {
    let mut spec = SimSpec::new(model, strategy, workers, GLOBAL_BATCH, a100_nvlink());
    spec.enforce_capacity = false; // measurement, not capacity test
    let r = simulate(&spec).unwrap();
    r.peak_total
}

fn main() {
    let mut t = Table::new(
        "Fig 9 — system memory vs single-device ideal (global batch 8, ×/ideal)",
        &["model", "single(ideal)", "rtp-in", "rtp-out", "fsdp", "megatron-tp", "ddp"],
    );
    let mut chart = Vec::new();
    for model in MODELS {
        let ideal = total_of(model, Strategy::Single, 1);
        let ratio = |s: Strategy| {
            let tot = total_of(model, s, N);
            format!("{} ({:.2}x)", human(tot), tot as f64 / ideal as f64)
        };
        t.row(vec![
            model.to_string(),
            human(ideal),
            ratio(Strategy::RtpInplace),
            ratio(Strategy::RtpOutOfPlace),
            ratio(Strategy::Fsdp),
            ratio(Strategy::MegatronTp),
            ratio(Strategy::Ddp),
        ]);
        for s in [
            Strategy::RtpInplace,
            Strategy::RtpOutOfPlace,
            Strategy::Fsdp,
            Strategy::MegatronTp,
            Strategy::Ddp,
        ] {
            chart.push((
                format!("{model}/{s}"),
                total_of(model, s, N) as f64 / ideal as f64,
            ));
        }
    }
    t.print();
    t.write_csv("fig9_dedup").unwrap();
    println!("{}", bar_chart("Fig 9 — memory duplication over ideal (×)", &chart, "x", 48));
    println!(
        "shape check: RTP ≈ 1× ideal (paper: 'in close alignment with the\n\
         single machine'); FSDP/TP multiples above (paper: 2–4×)."
    );
}
