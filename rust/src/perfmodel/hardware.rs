//! Analytic hardware models — the substitute for the paper's A100/NVLink
//! and V100/PCIe testbeds (DESIGN.md §2).
//!
//! Compute follows a roofline with the §3.4.1 small-kernel effects the
//! paper analyzes: per-kernel launch overhead plus an occupancy factor
//! (tiles vs SMs — small GEMMs leave most of the device dark). These two
//! terms are exactly why RTP's N× smaller kernels run below N× speed at
//! small batch and converge as the batch (and thus kernel) grows — the
//! mechanism behind Figs 10/11/13/14.

use crate::comm::LinkModel;
use crate::model::ops::OpCost;
use crate::util::bytes::GIB;

#[derive(Debug, Clone)]
pub struct Hardware {
    pub name: String,
    /// Peak tensor-core-style matmul throughput, FLOP/s.
    pub peak_flops: f64,
    /// Peak vector (elementwise) throughput, FLOP/s.
    pub peak_vector_flops: f64,
    /// Device memory bandwidth, bytes/s.
    pub hbm_bw: f64,
    /// Streaming multiprocessors (occupancy denominator).
    pub num_sms: usize,
    /// Kernel launch overhead, seconds per kernel.
    pub launch_s: f64,
    /// Interconnect.
    pub link: LinkModel,
    /// Device memory capacity, bytes.
    pub capacity: u64,
    /// Synchronous allocator stall when allocating under memory pressure
    /// (the CUDA caching-allocator flush the paper's FSDP cliff comes
    /// from), seconds per stall (floor; the flush itself scales with the
    /// live bytes being defragmented at `flush_bw`).
    pub alloc_stall_s: f64,
    /// Cache-flush re-map bandwidth, bytes/s.
    pub flush_bw: f64,
    /// Live/capacity ratio beyond which comm-buffer allocation stalls.
    pub pressure_threshold: f64,
}

/// 8×A100-80GB with NVLink3 (the paper's §5 primary testbed).
pub fn a100_nvlink() -> Hardware {
    Hardware {
        name: "a100-nvlink".to_string(),
        peak_flops: 312e12,        // fp16 tensor core
        peak_vector_flops: 19.5e12,
        hbm_bw: 2.0e12,
        num_sms: 108,
        launch_s: 6e-6,
        link: LinkModel::new("nvlink3", 4e-6, 250e9),
        capacity: 80 * GIB,
        alloc_stall_s: 2e-3,
        flush_bw: 250e9,
        pressure_threshold: 0.85,
    }
}

/// 8×V100-32GB over PCIe (the paper's appendix-B testbed).
pub fn v100_pcie() -> Hardware {
    Hardware {
        name: "v100-pcie".to_string(),
        peak_flops: 112e12,        // fp16 tensor core
        peak_vector_flops: 14e12,
        hbm_bw: 0.9e12,
        num_sms: 80,
        launch_s: 10e-6,
        link: LinkModel::new("pcie3", 10e-6, 11e9),
        capacity: 32 * GIB,
        alloc_stall_s: 2e-3,
        flush_bw: 120e9,
        pressure_threshold: 0.85,
    }
}

/// The CPU testbed itself (for sanity timelines of real runs).
pub fn cpu_sim() -> Hardware {
    Hardware {
        name: "cpu-sim".to_string(),
        peak_flops: 100e9,
        peak_vector_flops: 50e9,
        hbm_bw: 20e9,
        num_sms: 1,
        launch_s: 1e-6,
        link: LinkModel::new("shm", 1e-6, 10e9),
        capacity: 16 * GIB,
        alloc_stall_s: 1e-4,
        flush_bw: 20e9,
        pressure_threshold: 0.9,
    }
}

pub fn by_name(name: &str) -> Option<Hardware> {
    match name {
        "a100" | "a100-nvlink" => Some(a100_nvlink()),
        "v100" | "v100-pcie" => Some(v100_pcie()),
        "cpu" | "cpu-sim" => Some(cpu_sim()),
        _ => None,
    }
}

/// GEMM tile edge for the occupancy model (cuBLAS-style 64×64 blocks).
const TILE: usize = 64;
/// Fraction of nameplate peak a well-shaped GEMM actually achieves.
const ACHIEVABLE: f64 = 0.55;
/// Per-kernel dispatch floor within one op (stream-queued launches hide
/// under execution unless kernels are shorter than this).
const KERNEL_DISPATCH_S: f64 = 2e-6;

impl Hardware {
    /// Occupancy of one GEMM: how many output tiles it offers vs how many
    /// SMs want work, and a depth factor for skinny-K kernels. This is the
    /// §3.4.1 "GPU occupancy concern": a 1/N-width shard GEMM may not fill
    /// the device.
    fn gemm_efficiency(&self, m: usize, k: usize, n: usize) -> f64 {
        let tiles = m.div_ceil(TILE) * n.div_ceil(TILE);
        let occupancy = (tiles as f64 / self.num_sms as f64).min(1.0);
        let depth = (k as f64 / 64.0).min(1.0);
        (occupancy * depth).max(1e-3)
    }

    /// Roofline time of one GEMM kernel (no dispatch overhead — that is
    /// charged at op granularity).
    pub fn gemm_time(&self, m: usize, k: usize, n: usize) -> f64 {
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        let bytes = ((m * k + k * n + m * n) * 4) as f64;
        let eff = self.gemm_efficiency(m, k, n);
        (flops / (self.peak_flops * ACHIEVABLE * eff)).max(bytes / self.hbm_bw)
    }

    /// Time for one catalog op: one dispatch overhead (`launch_s` — the
    /// §3.4.1 "kernel launch overheads" term, multiplied across RTP's N×
    /// more, N×-smaller op calls), plus the roofline sum of its kernels,
    /// floored by the per-kernel dispatch rate when the kernels are tiny.
    pub fn op_time(&self, cost: &OpCost) -> f64 {
        let mut work: f64 =
            cost.gemms.iter().map(|&[m, k, n]| self.gemm_time(m, k, n)).sum();
        if cost.ew_flops > 0.0 {
            // elementwise kernels run at ~0.5 flop/byte (each value is
            // loaded+stored around little arithmetic); the GEMM terms
            // already carry their own operand traffic, so the op's total
            // io is NOT double-charged here.
            let ew_bytes = 2.0 * cost.ew_flops;
            work +=
                (cost.ew_flops / self.peak_vector_flops).max(ew_bytes / self.hbm_bw);
        }
        let dispatch_floor = cost.kernels() as f64 * KERNEL_DISPATCH_S;
        self.launch_s + work.max(dispatch_floor)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve_by_name() {
        assert_eq!(by_name("a100").unwrap().name, "a100-nvlink");
        assert_eq!(by_name("v100-pcie").unwrap().name, "v100-pcie");
        assert!(by_name("h100").is_none());
    }

    #[test]
    fn small_op_pays_launch_overhead() {
        let hw = a100_nvlink();
        let cost = OpCost { gemms: vec![[16, 16, 16]], ew_flops: 0.0, bytes: 0.0 };
        let t = hw.op_time(&cost);
        // a 16³ GEMM op is pure dispatch overhead
        assert!(t < 2.0 * hw.launch_s, "t={t}");
        assert!(t >= hw.launch_s);
    }

    #[test]
    fn big_gemm_approaches_roofline() {
        let hw = a100_nvlink();
        let (m, k, n) = (8192, 8192, 8192);
        let t = hw.gemm_time(m, k, n);
        let ideal = 2.0 * (m * k * n) as f64 / (hw.peak_flops * ACHIEVABLE);
        assert!(t / ideal < 1.05, "t/ideal = {}", t / ideal);
    }

    #[test]
    fn sharded_op_is_less_than_p_times_faster() {
        // The paper's §3.4.1 inefficiency: N shard op calls run slower
        // than full/N because of dispatch overhead + occupancy.
        let hw = a100_nvlink();
        let full = hw.op_time(&OpCost {
            gemms: vec![[64, 1280, 5120]],
            ew_flops: 0.0,
            bytes: 0.0,
        });
        let shard = hw.op_time(&OpCost {
            gemms: vec![[64, 1280, 5120 / 8]],
            ew_flops: 0.0,
            bytes: 0.0,
        });
        assert!(shard * 8.0 > full * 1.2, "shard {shard} full {full}");
    }

    #[test]
    fn occupancy_penalty_fades_with_batch() {
        // Bigger batch -> more tiles + amortized dispatch -> the 8-shard
        // penalty shrinks (the Fig-10 convergence).
        let hw = a100_nvlink();
        let penalty = |rows: usize| {
            let full = hw.op_time(&OpCost {
                gemms: vec![[rows, 1280, 5120]],
                ew_flops: 0.0,
                bytes: 0.0,
            });
            let shard = hw.op_time(&OpCost {
                gemms: vec![[rows, 1280, 5120 / 8]],
                ew_flops: 0.0,
                bytes: 0.0,
            });
            shard * 8.0 / full
        };
        assert!(penalty(16384) < penalty(512));
    }

    #[test]
    fn v100_slower_than_a100() {
        let cost = OpCost { gemms: vec![[1024, 1280, 5120]], ew_flops: 0.0, bytes: 0.0 };
        assert!(v100_pcie().op_time(&cost) > a100_nvlink().op_time(&cost));
    }

    #[test]
    fn elementwise_is_memory_bound() {
        // elementwise kernels run at ~0.5 flop/byte, so their time is the
        // 2·flops byte traffic over HBM, not the vector-ALU roofline
        let hw = a100_nvlink();
        let cost = OpCost { gemms: vec![], ew_flops: 1e12, bytes: 0.0 };
        let t = hw.op_time(&cost);
        let want = hw.launch_s + 2e12 / hw.hbm_bw;
        assert!((t - want).abs() / t < 1e-9, "t {t} want {want}");
        assert!(2e12 / hw.hbm_bw > 1e12 / hw.peak_vector_flops);
    }
}
