//! Two-stream timeline: the paper's CUDA-streams overlap model
//! (§3.4.3, Figs 3-5).
//!
//! Each (symmetric SPMD) worker has a COMPUTE stream and a COMM stream.
//! Engines running in virtual mode narrate their schedule into the
//! timeline; the stream clocks advance per the hardware model, and the
//! final `time()` is the step latency. Out-of-place RTP / FSDP-prefetch
//! overlap shows up as `comm_async` + `wait`; in-place RTP and naive DDP
//! reductions as `comm_blocking`.
//!
//! Communication is charged PER RING HOP: every collective is expanded
//! through `CommPrim::hop_schedule` into its `2(N-1)` / `N-1` / 1 hops,
//! each hop costing `α + hop_bytes·β` and laying its own span — so the
//! Gantt chart shows the real hop schedule of the ring fabric, and the
//! totals still equal the closed-form α-β costs.
//!
//! The spans record a Gantt chart (rendered by `bench overlap_timeline`,
//! reproducing the paper's Figs 3-5 as ASCII).

use crate::comm::CommPrim;
use crate::model::ops::OpCost;

use super::hardware::Hardware;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stream {
    Compute,
    Comm,
}

#[derive(Debug, Clone)]
pub struct Span {
    pub stream: Stream,
    pub start: f64,
    pub end: f64,
    pub label: String,
}

/// Handle to an in-flight async communication.
#[derive(Debug, Clone, Copy)]
#[must_use = "un-awaited comm leaves the timeline inconsistent"]
pub struct Token(usize);

#[derive(Debug)]
pub struct Timeline {
    pub hw: Hardware,
    /// Worker count for collective pricing.
    pub n: usize,
    compute_t: f64,
    comm_t: f64,
    pending: Vec<f64>,
    /// Busy-time accumulators (utilization metrics).
    pub compute_busy: f64,
    pub comm_busy: f64,
    /// Ring hops charged this step (each comm span is one hop).
    pub hop_count: u64,
    /// Total allocator-pressure stall charged.
    pub stall_s: f64,
    pub stall_count: u64,
    /// Span recording for Gantt output (off in sweeps: memory).
    pub record: bool,
    pub spans: Vec<Span>,
}

impl Timeline {
    pub fn new(hw: Hardware, n: usize) -> Self {
        Timeline {
            hw,
            n,
            compute_t: 0.0,
            comm_t: 0.0,
            pending: Vec::new(),
            compute_busy: 0.0,
            comm_busy: 0.0,
            hop_count: 0,
            stall_s: 0.0,
            stall_count: 0,
            record: false,
            spans: Vec::new(),
        }
    }

    pub fn recording(hw: Hardware, n: usize) -> Self {
        let mut t = Self::new(hw, n);
        t.record = true;
        t
    }

    fn span(&mut self, stream: Stream, start: f64, end: f64, label: &str) {
        if self.record {
            self.spans.push(Span { stream, start, end, label: label.to_string() });
        }
    }

    /// One compute op on the compute stream.
    pub fn compute(&mut self, label: &str, cost: &OpCost) {
        let dur = self.hw.op_time(cost);
        let start = self.compute_t;
        self.compute_t += dur;
        self.compute_busy += dur;
        self.span(Stream::Compute, start, self.compute_t, label);
    }

    /// Lay one span per ring hop of `prim` starting at `start`; advances
    /// and returns the comm-stream cursor. Each hop costs α + hop_bytes·β,
    /// so the total equals the closed-form collective cost.
    fn charge_hops(&mut self, label: &str, prim: CommPrim, bytes: u64, start: f64) -> f64 {
        let mut t = start;
        for hop_bytes in prim.hop_schedule(bytes, self.n) {
            let dur = self.hw.link.hop_time_f(hop_bytes);
            self.comm_busy += dur;
            self.hop_count += 1;
            self.span(Stream::Comm, t, t + dur, label);
            t += dur;
        }
        t
    }

    /// Blocking collective: both streams synchronize, then the hops run
    /// back to back on the comm stream.
    pub fn comm_blocking(&mut self, label: &str, prim: CommPrim, bytes: u64) {
        let start = self.compute_t.max(self.comm_t);
        let end = self.charge_hops(label, prim, bytes, start);
        self.compute_t = end;
        self.comm_t = end;
    }

    /// Async collective issued now (after the compute enqueued so far);
    /// its hops run on the comm stream; completion must be `wait`ed.
    pub fn comm_async(&mut self, label: &str, prim: CommPrim, bytes: u64) -> Token {
        let start = self.comm_t.max(self.compute_t);
        let end = self.charge_hops(label, prim, bytes, start);
        self.comm_t = end;
        self.pending.push(end);
        Token(self.pending.len() - 1)
    }

    /// Async collective whose data is already available (weights in hand):
    /// starts as soon as the comm stream is free, independent of compute —
    /// the RTP property that "computation and communication start
    /// simultaneously" (§3.4.3).
    pub fn comm_async_eager(&mut self, label: &str, prim: CommPrim, bytes: u64) -> Token {
        let start = self.comm_t;
        let end = self.charge_hops(label, prim, bytes, start);
        self.comm_t = end;
        self.pending.push(end);
        Token(self.pending.len() - 1)
    }

    /// Block the compute stream until the async comm completes.
    pub fn wait(&mut self, tok: Token) {
        let end = self.pending[tok.0];
        if end > self.compute_t {
            self.compute_t = end;
        }
    }

    /// Synchronize both streams (step boundary).
    pub fn barrier(&mut self) {
        let t = self.compute_t.max(self.comm_t);
        self.compute_t = t;
        self.comm_t = t;
    }

    /// Allocation under memory pressure stalls the compute stream — the
    /// caching-allocator flush behind the paper's FSDP full-batch cliff
    /// (§5.4 "FSDP throughput drops sharply").
    pub fn alloc_event(&mut self, live: u64, requested: u64) {
        let cap = self.hw.capacity;
        if cap > 0
            && (live + requested) as f64 > self.hw.pressure_threshold * cap as f64
        {
            // the caching allocator flushes + re-maps its live arena to
            // make room — cost scales with the resident bytes
            let stall = self.hw.alloc_stall_s.max(live as f64 / self.hw.flush_bw);
            let start = self.compute_t;
            self.compute_t += stall;
            self.stall_s += stall;
            self.stall_count += 1;
            self.span(Stream::Compute, start, self.compute_t, "alloc-stall");
        }
    }

    /// Current step latency.
    pub fn time(&self) -> f64 {
        self.compute_t.max(self.comm_t)
    }

    /// Modeled overlap fraction: how much of this step's comm-stream busy
    /// time ran UNDER compute rather than extending the step (0 = fully
    /// exposed / serialized, 1 = fully hidden). The calibration metric
    /// the overlap benches compare against the Thread launcher's measured
    /// wall-clock overlap.
    pub fn overlap_fraction(&self) -> f64 {
        if self.comm_busy <= 0.0 {
            return 0.0;
        }
        // wall-clock not accounted to compute work or alloc stalls is
        // exposed communication (waits + blocking collectives)
        let exposed = (self.time() - self.compute_busy - self.stall_s).max(0.0);
        ((self.comm_busy - exposed) / self.comm_busy).clamp(0.0, 1.0)
    }

    /// Reset clocks (keep hardware + recording config) for the next step.
    pub fn reset(&mut self) {
        self.compute_t = 0.0;
        self.comm_t = 0.0;
        self.pending.clear();
        self.compute_busy = 0.0;
        self.comm_busy = 0.0;
        self.hop_count = 0;
        self.stall_s = 0.0;
        self.stall_count = 0;
        self.spans.clear();
    }

    /// ASCII Gantt of the recorded spans (Figs 3-5 renderer).
    pub fn render_gantt(&self, width: usize) -> String {
        let total = self.time().max(1e-12);
        let mut out = String::new();
        for (stream, tag) in [(Stream::Compute, "compute"), (Stream::Comm, "comm   ")] {
            let mut line = vec![' '; width];
            for s in self.spans.iter().filter(|s| s.stream == stream) {
                let a = ((s.start / total) * width as f64) as usize;
                let b = (((s.end / total) * width as f64) as usize).min(width);
                let c = s.label.chars().next().unwrap_or('#');
                for cell in line.iter_mut().take(b).skip(a) {
                    *cell = c;
                }
            }
            out.push_str(tag);
            out.push('|');
            out.extend(line);
            out.push_str("|\n");
        }
        out.push_str(&format!(
            "total {:.3} ms  compute busy {:.0}%  comm busy {:.0}%  {} ring hops\n",
            total * 1e3,
            100.0 * self.compute_busy / total,
            100.0 * self.comm_busy / total,
            self.hop_count
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::hardware::a100_nvlink;

    fn cost(flops_gemm: [usize; 3]) -> OpCost {
        OpCost { gemms: vec![flops_gemm], ew_flops: 0.0, bytes: 0.0 }
    }

    #[test]
    fn blocking_comm_serializes() {
        let mut t = Timeline::new(a100_nvlink(), 8);
        t.compute("a", &cost([1024, 1024, 1024]));
        let after_compute = t.time();
        t.comm_blocking("r", CommPrim::Rotation, 64 << 20);
        assert!(t.time() > after_compute);
        // compute resumes only after the comm
        let comm_end = t.time();
        t.compute("b", &cost([128, 128, 128]));
        assert!(t.time() > comm_end);
    }

    #[test]
    fn async_comm_overlaps_compute() {
        let hw = a100_nvlink();
        let big = cost([4096, 4096, 4096]);
        let msg = 1 << 20;

        // overlap: comm hides under compute
        let mut a = Timeline::new(hw.clone(), 8);
        let tok = a.comm_async_eager("r", CommPrim::Rotation, msg);
        a.compute("c", &big);
        a.wait(tok);
        // serial: comm then compute
        let mut b = Timeline::new(hw, 8);
        b.comm_blocking("r", CommPrim::Rotation, msg);
        b.compute("c", &big);

        assert!(a.time() < b.time(), "overlap {} serial {}", a.time(), b.time());
        // fully hidden: overlap time == compute time alone
        let compute_only = a.hw.op_time(&big);
        assert!((a.time() - compute_only).abs() / compute_only < 1e-9);
    }

    #[test]
    fn wait_blocks_when_comm_longer_than_compute() {
        let hw = a100_nvlink();
        let tiny = cost([64, 64, 64]);
        let mut t = Timeline::new(hw, 8);
        let tok = t.comm_async_eager("r", CommPrim::Rotation, 1 << 30);
        t.compute("c", &tiny);
        let comm_end = t.time(); // dominated by the 1 GiB rotation
        t.wait(tok);
        // compute stream is now pinned to the comm end: the next compute
        // starts after it.
        t.compute("c2", &tiny);
        assert!(t.time() > comm_end);
    }

    #[test]
    fn overlap_fraction_tracks_hiding() {
        let hw = a100_nvlink();
        let big = cost([4096, 4096, 4096]);
        let msg = 1 << 20;
        // fully hidden comm
        let mut a = Timeline::new(hw.clone(), 8);
        let tok = a.comm_async_eager("r", CommPrim::Rotation, msg);
        a.compute("c", &big);
        a.wait(tok);
        assert!(a.overlap_fraction() > 0.99, "{}", a.overlap_fraction());
        // fully exposed comm
        let mut b = Timeline::new(hw.clone(), 8);
        b.comm_blocking("r", CommPrim::Rotation, msg);
        b.compute("c", &big);
        assert!(b.overlap_fraction() < 1e-9, "{}", b.overlap_fraction());
        // no comm at all: defined as 0
        let mut c0 = Timeline::new(hw, 8);
        c0.compute("c", &big);
        assert_eq!(c0.overlap_fraction(), 0.0);
    }

    #[test]
    fn alloc_stall_only_under_pressure() {
        let mut t = Timeline::new(a100_nvlink(), 8);
        let cap = t.hw.capacity;
        t.alloc_event(0, 1 << 20);
        assert_eq!(t.stall_count, 0);
        t.alloc_event((0.9 * cap as f64) as u64, 1 << 20);
        assert_eq!(t.stall_count, 1);
        assert!(t.stall_s > 0.0);
    }

    #[test]
    fn reset_clears_clocks_but_keeps_config() {
        let mut t = Timeline::recording(a100_nvlink(), 4);
        t.compute("a", &cost([256, 256, 256]));
        t.barrier();
        assert!(t.time() > 0.0);
        t.reset();
        assert_eq!(t.time(), 0.0);
        assert!(t.record);
        assert!(t.spans.is_empty());
    }

    #[test]
    fn blocking_allreduce_charges_per_hop() {
        let n = 8;
        let mut t = Timeline::recording(a100_nvlink(), n);
        let bytes = 64 << 20;
        t.comm_blocking("ar", CommPrim::AllReduce, bytes);
        // 2(N-1) hop spans, contiguous, summing to the closed-form cost
        let spans: Vec<_> = t.spans.iter().filter(|s| s.stream == Stream::Comm).collect();
        assert_eq!(spans.len(), 2 * (n - 1));
        assert_eq!(t.hop_count, 2 * (n as u64 - 1));
        for pair in spans.windows(2) {
            assert!((pair[0].end - pair[1].start).abs() < 1e-15);
        }
        let closed = t.hw.link.allreduce(bytes, n);
        assert!((t.time() - closed).abs() / closed < 1e-9);
    }

    #[test]
    fn single_worker_collective_is_free_and_hopless() {
        let mut t = Timeline::new(a100_nvlink(), 1);
        t.comm_blocking("ar", CommPrim::AllReduce, 1 << 20);
        assert_eq!(t.time(), 0.0);
        assert_eq!(t.hop_count, 0);
    }

    #[test]
    fn gantt_renders_two_streams() {
        let mut t = Timeline::recording(a100_nvlink(), 4);
        let tok = t.comm_async_eager("rot", CommPrim::Rotation, 8 << 20);
        t.compute("gemm", &cost([2048, 2048, 2048]));
        t.wait(tok);
        let g = t.render_gantt(40);
        assert!(g.contains("compute|"));
        assert!(g.contains("comm   |"));
        assert!(g.contains('g')); // gemm span
        assert!(g.contains('r')); // rot span
    }
}
