//! Virtual-mode simulation driver: run one engine step at paper scale
//! with a timeline + trackers attached, and report the modeled step time,
//! throughput and peak memory — the generator behind Figs 8-14.

use anyhow::Result;

use crate::config::{OptimizerKind, Strategy};
use crate::memory::tracker::MemCategory;
use crate::parallel::{build_engine, Batch, EngineOpts, ExecKind};
use crate::tensor::IntTensor;
use crate::train::Optimizer;

use super::hardware::Hardware;

#[derive(Debug, Clone)]
pub struct SimResult {
    pub strategy: Strategy,
    pub workers: usize,
    pub global_batch: usize,
    /// Modeled step latency, seconds (fwd+bwd incl. comm).
    pub step_time: f64,
    /// Words (tokens) per second per the paper's wps metric.
    pub wps: f64,
    /// Peak bytes on the busiest worker.
    pub peak_per_worker: u64,
    /// Sum of peaks across workers (the Fig-9 system total).
    pub peak_total: u64,
    pub peak_by_cat: Vec<(MemCategory, u64)>,
    /// Allocator-pressure stalls charged (the FSDP cliff mechanism).
    pub stalls: u64,
    /// Compute/comm busy fractions of the step.
    pub compute_util: f64,
    pub comm_util: f64,
    /// Set when the run OOMed against the device capacity.
    pub oom: Option<String>,
}

#[derive(Debug, Clone)]
pub struct SimSpec {
    pub preset: String,
    pub strategy: Strategy,
    pub workers: usize,
    pub global_batch: usize,
    pub hw: Hardware,
    /// Enforce the device capacity (OOM detection) vs analysis-only.
    pub enforce_capacity: bool,
    pub optimizer: OptimizerKind,
    /// RTP §3.4.4 recycling ablation knob.
    pub rtp_recycle: bool,
}

impl SimSpec {
    pub fn new(preset: &str, strategy: Strategy, workers: usize, batch: usize, hw: Hardware) -> Self {
        SimSpec {
            preset: preset.to_string(),
            strategy,
            workers,
            global_batch: batch,
            hw,
            enforce_capacity: true,
            optimizer: OptimizerKind::Sgd,
            rtp_recycle: true,
        }
    }
}

/// Run one virtual step and collect the modeled metrics.
pub fn simulate(spec: &SimSpec) -> Result<SimResult> {
    let capacity = if spec.enforce_capacity { Some(spec.hw.capacity) } else { None };
    let opts = EngineOpts::new(&spec.preset, spec.strategy, spec.workers, spec.global_batch)
        .exec(ExecKind::Virtual)
        .capacity(capacity)
        .hardware(spec.hw.clone())
        .rtp_recycle(spec.rtp_recycle);
    let cfg = opts.cfg()?;
    let seq = cfg.seq;

    let mut base = SimResult {
        strategy: spec.strategy,
        workers: spec.workers,
        global_batch: spec.global_batch,
        step_time: f64::NAN,
        wps: 0.0,
        peak_per_worker: 0,
        peak_total: 0,
        peak_by_cat: Vec::new(),
        stalls: 0,
        compute_util: 0.0,
        comm_util: 0.0,
        oom: None,
    };

    let mut engine = match build_engine(&opts) {
        Ok(e) => e,
        Err(e) => {
            // init-time OOM (weights alone exceed the device)
            base.oom = Some(format!("{e:#}"));
            return Ok(base);
        }
    };
    let opt = Optimizer::new(spec.optimizer, 1e-3);
    if let Err(e) = opt.attach(&mut *engine) {
        base.oom = Some(format!("{e:#}"));
        return Ok(base);
    }

    // virtual batch: shapes only
    let batch = Batch {
        ids: IntTensor::zeros(&[spec.global_batch, seq]),
        targets: IntTensor::zeros(&[spec.global_batch, seq]),
    };
    match engine.step(&batch) {
        Ok(_) => {}
        Err(e) => {
            base.oom = Some(format!("{e:#}"));
            // peaks up to the OOM point are still informative
            base.peak_per_worker = engine.ctx().cluster.max_peak();
            base.peak_total = engine.ctx().cluster.total_peak();
            return Ok(base);
        }
    }

    let ctx = engine.ctx();
    let tl = ctx.timeline.as_ref().expect("simulate always attaches a timeline");
    let step_time = tl.time();
    let tracker0 = &ctx.cluster.workers[0].tracker;
    Ok(SimResult {
        step_time,
        wps: (spec.global_batch * seq) as f64 / step_time,
        peak_per_worker: ctx.cluster.max_peak(),
        peak_total: ctx.cluster.total_peak(),
        peak_by_cat: MemCategory::ALL
            .iter()
            .map(|&c| (c, tracker0.peak_of(c)))
            .collect(),
        stalls: tl.stall_count,
        compute_util: tl.compute_busy / step_time.max(1e-12),
        comm_util: tl.comm_busy / step_time.max(1e-12),
        oom: None,
        ..base
    })
}

/// The largest global batch that fits, per strategy — the "maximum batch
/// size available" the paper's §5.1 sweeps to. Power-of-two sweep, then
/// binary refinement (the pressure zone near the true maximum is where
/// the paper's FSDP cliff lives).
pub fn max_batch(spec: &SimSpec, limit: usize) -> usize {
    let fits = |b: usize| {
        let mut s = spec.clone();
        s.global_batch = b;
        matches!(simulate(&s), Ok(r) if r.oom.is_none())
    };
    let n = spec.workers;
    let mut best = 0;
    let mut b = n;
    while b <= limit && fits(b) {
        best = b;
        b *= 2;
    }
    if best == 0 {
        return 0;
    }
    // binary refine in (best, min(2*best, limit))
    let mut lo = best;
    let mut hi = (2 * best).min(limit.max(best));
    while hi - lo > n {
        let mid = (lo + hi) / 2 / n * n;
        if mid == lo {
            break;
        }
        if fits(mid) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

/// The Figs 10/11/13/14 generator: throughput-vs-batch sweep for
/// DDP / FSDP / RTP-in / RTP-out on one model + hardware, printed as the
/// paper's series with the §5.4 deltas, CSV'd under `figures/`.
pub fn throughput_figure(preset: &str, hw: Hardware, tag: &str, workers: usize) {
    use crate::bench_util::Table;
    let strategies = [
        Strategy::Ddp,
        Strategy::Fsdp,
        Strategy::RtpInplace,
        Strategy::RtpOutOfPlace,
    ];
    let caps: Vec<usize> = strategies
        .iter()
        .map(|&s| max_batch(&SimSpec::new(preset, s, workers, workers, hw.clone()), 4096))
        .collect();
    let sweep_max = *caps.iter().max().unwrap();

    let mut t = Table::new(
        &format!("{tag} — throughput (wps) vs per-GPU batch, {preset} on {}×{}", workers, hw.name),
        &["batch/gpu", "ddp", "fsdp", "rtp-in", "rtp-out", "rtp-out vs ddp", "rtp-out vs fsdp"],
    );
    let mut batch = workers;
    while batch <= sweep_max {
        let mut wps = Vec::new();
        for (s, cap) in strategies.iter().zip(&caps) {
            if batch > *cap {
                wps.push(None);
                continue;
            }
            let r = simulate(&SimSpec::new(preset, *s, workers, batch, hw.clone())).unwrap();
            wps.push(if r.oom.is_some() { None } else { Some(r.wps) });
        }
        let fmt = |v: &Option<f64>| match v {
            Some(w) => format!("{w:.0}"),
            None => "OOM".to_string(),
        };
        let delta = |a: &Option<f64>, b: &Option<f64>| match (a, b) {
            (Some(x), Some(y)) => format!("{:+.1}%", 100.0 * (x / y - 1.0)),
            _ => "—".to_string(),
        };
        t.row(vec![
            (batch / workers).to_string(),
            fmt(&wps[0]),
            fmt(&wps[1]),
            fmt(&wps[2]),
            fmt(&wps[3]),
            delta(&wps[3], &wps[0]),
            delta(&wps[3], &wps[1]),
        ]);
        batch *= 2;
    }
    // final row: each strategy at its own refined maximum batch — the
    // pressure zone where the paper's FSDP cliff lives
    {
        let mut cells = vec!["max".to_string()];
        let mut at_max = Vec::new();
        for (s, cap) in strategies.iter().zip(&caps) {
            if *cap == 0 {
                cells.push("OOM".into());
                at_max.push(None);
                continue;
            }
            let r = simulate(&SimSpec::new(preset, *s, workers, *cap, hw.clone())).unwrap();
            cells.push(format!("{:.0} (b{})", r.wps, cap / workers));
            at_max.push(Some(r.wps));
        }
        let delta = |a: &Option<f64>, b: &Option<f64>| match (a, b) {
            (Some(x), Some(y)) => format!("{:+.1}%", 100.0 * (x / y - 1.0)),
            _ => "—".to_string(),
        };
        cells.push(delta(&at_max[3], &at_max[0]));
        cells.push(delta(&at_max[3], &at_max[1]));
        t.row(cells);
    }
    t.print();
    t.write_csv(&format!(
        "{}_throughput",
        tag.to_lowercase().replace(' ', "_")
    ))
    .unwrap();

    // the paper's cliff observation: FSDP at ITS max batch vs RTP there
    let fsdp_max = caps[1];
    if fsdp_max > 0 {
        let f = simulate(&SimSpec::new(preset, Strategy::Fsdp, workers, fsdp_max, hw.clone()))
            .unwrap();
        let r = simulate(&SimSpec::new(
            preset,
            Strategy::RtpOutOfPlace,
            workers,
            fsdp_max,
            hw.clone(),
        ))
        .unwrap();
        println!(
            "at FSDP's max batch ({}/gpu): FSDP {:.0} wps ({} alloc stalls) vs \
             RTP-out {:.0} wps => RTP {:+.0}%\n",
            fsdp_max / workers,
            f.wps,
            f.stalls,
            r.wps,
            100.0 * (r.wps / f.wps - 1.0)
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::hardware::a100_nvlink;

    fn spec(strategy: Strategy, batch: usize) -> SimSpec {
        SimSpec::new("gpt2-500m", strategy, 8, batch, a100_nvlink())
    }

    #[test]
    fn rtp_peak_below_fsdp_below_ddp() {
        let rtp = simulate(&spec(Strategy::RtpInplace, 8)).unwrap();
        let fsdp = simulate(&spec(Strategy::Fsdp, 8)).unwrap();
        let ddp = simulate(&spec(Strategy::Ddp, 8)).unwrap();
        assert!(rtp.oom.is_none() && fsdp.oom.is_none() && ddp.oom.is_none());
        assert!(
            rtp.peak_per_worker < fsdp.peak_per_worker,
            "rtp {} !< fsdp {}",
            rtp.peak_per_worker,
            fsdp.peak_per_worker
        );
        assert!(fsdp.peak_per_worker < ddp.peak_per_worker);
    }

    #[test]
    fn rtp_oop_faster_than_inplace() {
        // overlap must buy wall-clock time
        let oop = simulate(&spec(Strategy::RtpOutOfPlace, 8)).unwrap();
        let inp = simulate(&spec(Strategy::RtpInplace, 8)).unwrap();
        assert!(oop.step_time < inp.step_time, "oop {} inp {}", oop.step_time, inp.step_time);
    }

    #[test]
    fn rtp_throughput_within_paper_band_of_ddp() {
        // paper §5.4: −13% … −1.7% vs DP for GPT2-500M on 8×A100
        for batch in [8, 32, 128] {
            let rtp = simulate(&spec(Strategy::RtpOutOfPlace, batch)).unwrap();
            let ddp = simulate(&spec(Strategy::Ddp, batch)).unwrap();
            let delta = rtp.wps / ddp.wps - 1.0;
            assert!(
                (-0.25..=0.05).contains(&delta),
                "batch {batch}: RTP vs DDP delta {delta:.3} outside band"
            );
        }
    }

    #[test]
    fn single_worker_has_no_comm() {
        let mut s = spec(Strategy::RtpInplace, 8);
        s.workers = 1;
        s.preset = "gpt2-117m".into();
        let r = simulate(&s).unwrap();
        assert_eq!(r.comm_util, 0.0);
    }

    #[test]
    fn oom_reported_not_panicked() {
        // gpt2-neo DDP+Adam in f32 needs 16 B/param ≈ 45 GB of state —
        // more than a 32 GB V100 before any activations.
        let mut s = spec(Strategy::Ddp, 8);
        s.preset = "gpt2-neo-2.7b".into();
        s.optimizer = OptimizerKind::Adam;
        s.hw = crate::perfmodel::hardware::v100_pcie();
        let r = simulate(&s).unwrap();
        assert!(r.oom.is_some());
        // RTP-inplace shards it: 45/8 + 2 GB acts fits on the same V100
        s.strategy = Strategy::RtpInplace;
        let r = simulate(&s).unwrap();
        assert!(r.oom.is_none(), "{:?}", r.oom);
    }

    #[test]
    fn max_batch_orders_by_memory_headroom() {
        let rtp = max_batch(&spec(Strategy::RtpInplace, 8), 512);
        let ddp = max_batch(&spec(Strategy::Ddp, 8), 512);
        assert!(rtp >= ddp, "rtp max {rtp} < ddp max {ddp}");
        assert!(rtp > 0);
    }
}
