//! Performance model: analytic hardware (A100/NVLink, V100/PCIe), the
//! two-stream overlap timeline, and the throughput simulation driving the
//! paper's Figs 10-14 (see `simulate`, added with the figure benches).

pub mod hardware;
pub mod simulate;
pub mod timeline;

pub use hardware::{a100_nvlink, by_name, cpu_sim, v100_pcie, Hardware};
pub use simulate::{max_batch, simulate, SimResult, SimSpec};
pub use timeline::{Span, Stream, Timeline, Token};
