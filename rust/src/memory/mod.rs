//! Memory accounting — the instrument behind the paper's headline claims.
//!
//! `tracker` records every allocation the engines make (per worker, per
//! category) and reports live/peak bytes; `analytic` is the closed-form
//! Table-1 model the measurements are cross-checked against.

pub mod analytic;
pub mod tracker;

pub use analytic::{table1_row, Table1Row};
pub use tracker::{AllocId, MemCategory, MemTracker, OomError};
