//! The paper's Table 1, as a closed-form model.
//!
//! Inputs are the idealized single-machine quantities: A (activation
//! bytes), W (weight bytes), G (gradient bytes) and the worker count N.
//! Outputs are per-TECHNIQUE totals over the whole distributed system plus
//! the "Memory Duplication" column — excess over the unlimited-memory
//! idealized computer (A + W + G).
//!
//! | Technique        | Activations | Parameters                  | Duplication        |
//! |------------------|-------------|-----------------------------|--------------------|
//! | No parallelism   | A           | W+G                         | 0                  |
//! | Tensor parallel  | A*N         | W+G                         | A*(N-1)            |
//! | Data parallel    | A           | (W+G)*N                     | (W+G)*(N-1)        |
//! | Pipeline         | A + Ap*N    | W+G                         | Ap*N               |
//! | FSDP             | A           | W+G+max(W,G)*(N-1)          | max(W,G)*(N-1)     |
//! | RTP              | A           | W+G+max(W,G)                | max(W,G)           |
//! | RTP Inplace      | A           | W+G                         | 0                  |

use crate::config::{ModelCfg, Strategy};

/// One Table-1 row (all byte counts are SYSTEM totals across N workers).
#[derive(Debug, Clone, PartialEq)]
pub struct Table1Row {
    pub technique: String,
    pub activations: u64,
    pub parameters: u64,
    pub duplication: u64,
}

/// Closed-form Table-1 row for a technique.
///
/// `a`, `w`, `g` are the single-machine activation/weight/gradient bytes;
/// `ap` is the pipeline's per-stage boundary activation (only used by
/// `pipeline_row`).
pub fn table1_row(strategy: Strategy, a: u64, w: u64, g: u64, n: u64) -> Table1Row {
    let wg = w + g;
    let mx = w.max(g);
    let (act, par) = match strategy {
        Strategy::Single => (a, wg),
        Strategy::MegatronTp => (a * n, wg),
        Strategy::Ddp => (a, wg * n),
        Strategy::Fsdp => (a, wg + mx * (n - 1)),
        Strategy::RtpOutOfPlace => (a, wg + mx),
        Strategy::RtpInplace => (a, wg),
    };
    let ideal = a + wg;
    Table1Row {
        technique: strategy.to_string(),
        activations: act,
        parameters: par,
        duplication: (act + par).saturating_sub(ideal),
    }
}

/// Pipeline parallelism (paper row 4) — not an engine in this repo (the
/// paper calls RTP orthogonal to pipeline), but part of Table 1.
pub fn pipeline_row(a: u64, w: u64, g: u64, ap: u64, n: u64) -> Table1Row {
    Table1Row {
        technique: "pipeline".to_string(),
        activations: a + ap * n,
        parameters: w + g,
        duplication: ap * n,
    }
}

/// Expected PER-WORKER peak for the measured cross-check
/// (tests/integration_memory.rs): the paper's totals divided by N, with
/// the single-worker components that don't shard kept whole.
pub fn per_worker_expected(
    strategy: Strategy,
    a: u64,
    w: u64,
    g: u64,
    n: u64,
) -> u64 {
    let wg = w + g;
    let mx = w.max(g);
    match strategy {
        Strategy::Single => a + wg,
        // DDP: full replica + activation shard.
        Strategy::Ddp => a / n + wg,
        // Megatron TP: full activations + weight shard.
        Strategy::MegatronTp => a + wg / n,
        // FSDP: shard + one reconstructed full unit live at peak.
        Strategy::Fsdp => a / n + wg / n + mx * (n - 1) / n,
        // RTP out-of-place: shard + one in-flight rotation buffer.
        Strategy::RtpOutOfPlace => a / n + wg / n + mx / n,
        // RTP in-place: pure shards.
        Strategy::RtpInplace => a / n + wg / n,
    }
}

// ---------------------------------------------------------------------------
// Serving-time KV-cache (not a Table-1 training category — the tensor
// that binds at inference; tracked under `MemCategory::KvCache`)
// ---------------------------------------------------------------------------

/// How much of the KV-cache one rank holds under a strategy, as a
/// divisor of the full cache: head-sharded strategies (TP and both RTP
/// variants) keep `hidden/N` of every cached position per rank; the
/// replica strategies (single / DDP / FSDP serving a full replica) keep
/// it all.
pub fn kv_shard_divisor(strategy: Strategy, n: u64) -> u64 {
    match strategy {
        Strategy::MegatronTp | Strategy::RtpInplace | Strategy::RtpOutOfPlace => n,
        Strategy::Single | Strategy::Ddp | Strategy::Fsdp => 1,
    }
}

/// Analytic per-rank KV-cache bytes for `positions` cached tokens of ONE
/// sequence: K and V, every layer, `hidden` f32 lanes per position,
/// rounded up to whole pages of `page_tokens` positions (the serve
/// engine allocates page-granular, so the tracker must match this
/// closed form exactly — asserted in `tests/serving.rs`).
pub fn kv_cache_bytes_per_rank(
    strategy: Strategy,
    cfg: &ModelCfg,
    positions: usize,
    page_tokens: usize,
    n: u64,
) -> u64 {
    let pages = positions.div_ceil(page_tokens) as u64;
    let per_pos = (cfg.hidden as u64 / kv_shard_divisor(strategy, n)) * 4;
    2 * cfg.layers as u64 * pages * page_tokens as u64 * per_pos
}

/// Projected per-rank KV bytes for a request that will cache up to
/// `max_positions` tokens — the admission-control bound the serve
/// engine's queue checks against the `MemTracker` budget.
pub fn kv_projected_bytes(
    strategy: Strategy,
    cfg: &ModelCfg,
    max_positions: usize,
    page_tokens: usize,
    n: u64,
) -> u64 {
    kv_cache_bytes_per_rank(strategy, cfg, max_positions, page_tokens, n)
}

#[cfg(test)]
mod tests {
    use super::*;

    const A: u64 = 1000;
    const W: u64 = 600;
    const G: u64 = 600;
    const N: u64 = 8;

    #[test]
    fn single_has_zero_duplication() {
        let r = table1_row(Strategy::Single, A, W, G, N);
        assert_eq!(r.duplication, 0);
        assert_eq!(r.activations + r.parameters, A + W + G);
    }

    #[test]
    fn ddp_duplicates_replicas() {
        let r = table1_row(Strategy::Ddp, A, W, G, N);
        assert_eq!(r.duplication, (W + G) * (N - 1));
    }

    #[test]
    fn tp_duplicates_activations() {
        let r = table1_row(Strategy::MegatronTp, A, W, G, N);
        assert_eq!(r.duplication, A * (N - 1));
    }

    #[test]
    fn fsdp_vs_rtp_ordering() {
        // The paper's claim: dup(RTP-in)=0 < dup(RTP)=max(W,G)
        //                    << dup(FSDP)=max(W,G)*(N-1)
        let fsdp = table1_row(Strategy::Fsdp, A, W, G, N).duplication;
        let rtp = table1_row(Strategy::RtpOutOfPlace, A, W, G, N).duplication;
        let rtp_in = table1_row(Strategy::RtpInplace, A, W, G, N).duplication;
        assert_eq!(rtp_in, 0);
        assert_eq!(rtp, W.max(G));
        assert_eq!(fsdp, W.max(G) * (N - 1));
        assert!(rtp_in < rtp && rtp < fsdp);
    }

    #[test]
    fn pipeline_row_matches_paper() {
        let r = pipeline_row(A, W, G, 50, N);
        assert_eq!(r.duplication, 50 * N);
        assert_eq!(r.parameters, W + G);
    }

    #[test]
    fn per_worker_sums_to_totals_for_sharded() {
        // For RTP-inplace, per-worker * N == ideal total.
        let pw = per_worker_expected(Strategy::RtpInplace, A, W, G, N);
        assert_eq!(pw * N, A + W + G);
    }

    #[test]
    fn rtp_memory_savings_vs_fsdp_exceed_75pct() {
        // Paper abstract: "memory savings in excess of 75% compared to
        // FSDP" (duplication term, large N).
        let fsdp = table1_row(Strategy::Fsdp, A, W, G, N).duplication;
        let rtp = table1_row(Strategy::RtpOutOfPlace, A, W, G, N).duplication;
        assert!((rtp as f64) < 0.25 * fsdp as f64);
    }
}
