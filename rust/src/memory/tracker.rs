//! Per-worker allocation tracker.
//!
//! The substitute for `torch.cuda.max_memory_allocated` + the 80 GB device
//! cap (DESIGN.md §2): engines route every buffer they create through this
//! tracker, in real mode *and* in virtual mode, so peak-memory figures are
//! properties of the allocation schedule, not of host RAM.

use std::collections::HashMap;
use std::fmt;

/// What a buffer is for — the categories of paper Table 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum MemCategory {
    /// Model weights (paper: W).
    Weights,
    /// Gradients (paper: G).
    Grads,
    /// Optimizer state (momentum/Adam moments).
    OptState,
    /// Activations incl. logits (paper: A).
    Activations,
    /// Rotation / allgather communication buffers — the duplication the
    /// paper is about.
    CommBuf,
    /// Serving-time KV-cache pages (per-rank head shard; see
    /// [`crate::serve`]). Not a training category — absent from Table 1,
    /// but first-class at inference where it is the binding tensor.
    KvCache,
}

impl MemCategory {
    pub const ALL: [MemCategory; 6] = [
        MemCategory::Weights,
        MemCategory::Grads,
        MemCategory::OptState,
        MemCategory::Activations,
        MemCategory::CommBuf,
        MemCategory::KvCache,
    ];
}

impl fmt::Display for MemCategory {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            MemCategory::Weights => "weights",
            MemCategory::Grads => "grads",
            MemCategory::OptState => "opt-state",
            MemCategory::Activations => "activations",
            MemCategory::CommBuf => "comm-buf",
            MemCategory::KvCache => "kv-cache",
        };
        f.write_str(s)
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct AllocId(pub u64);

#[derive(Debug)]
pub struct OomError {
    pub worker: usize,
    pub requested: u64,
    pub live: u64,
    pub capacity: u64,
    pub category: MemCategory,
}

impl fmt::Display for OomError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "OOM on worker {}: requested {} B ({}) with {} B live, capacity {} B",
            self.worker, self.requested, self.category, self.live, self.capacity
        )
    }
}

impl std::error::Error for OomError {}

/// Tracks live and peak allocated bytes for one (simulated) device.
#[derive(Debug, Clone)]
pub struct MemTracker {
    pub worker: usize,
    /// None = unlimited (analysis mode); Some = device capacity, alloc
    /// failures surface as OomError like a CUDA OOM would.
    pub capacity: Option<u64>,
    next_id: u64,
    allocs: HashMap<u64, (MemCategory, u64)>,
    live: u64,
    live_by_cat: HashMap<MemCategory, u64>,
    peak: u64,
    /// Per-category live at the moment of the global peak.
    peak_snapshot: HashMap<MemCategory, u64>,
    /// Total bytes ever allocated (allocator churn metric for §Perf).
    pub total_allocated: u64,
    pub alloc_count: u64,
}

impl MemTracker {
    pub fn new(worker: usize, capacity: Option<u64>) -> Self {
        MemTracker {
            worker,
            capacity,
            next_id: 0,
            allocs: HashMap::new(),
            live: 0,
            live_by_cat: HashMap::new(),
            peak: 0,
            peak_snapshot: HashMap::new(),
            total_allocated: 0,
            alloc_count: 0,
        }
    }

    pub fn alloc(
        &mut self,
        cat: MemCategory,
        bytes: u64,
    ) -> Result<AllocId, OomError> {
        if let Some(cap) = self.capacity {
            if self.live + bytes > cap {
                return Err(OomError {
                    worker: self.worker,
                    requested: bytes,
                    live: self.live,
                    capacity: cap,
                    category: cat,
                });
            }
        }
        let id = self.next_id;
        self.next_id += 1;
        self.allocs.insert(id, (cat, bytes));
        self.live += bytes;
        *self.live_by_cat.entry(cat).or_insert(0) += bytes;
        self.total_allocated += bytes;
        self.alloc_count += 1;
        if self.live > self.peak {
            self.peak = self.live;
            self.peak_snapshot = self.live_by_cat.clone();
        }
        Ok(AllocId(id))
    }

    pub fn free(&mut self, id: AllocId) {
        let (cat, bytes) = self
            .allocs
            .remove(&id.0)
            .expect("double free or unknown AllocId");
        self.live -= bytes;
        *self.live_by_cat.get_mut(&cat).unwrap() -= bytes;
    }

    /// Recategorize an allocation in place — the paper §3.4.4 buffer-TTL
    /// recycling: a dead comm buffer's bytes are repurposed for output
    /// activations without a free+alloc cycle (and without touching peak).
    pub fn recycle(&mut self, id: AllocId, to: MemCategory) {
        let entry = self.allocs.get_mut(&id.0).expect("unknown AllocId");
        let (from, bytes) = *entry;
        entry.0 = to;
        *self.live_by_cat.get_mut(&from).unwrap() -= bytes;
        *self.live_by_cat.entry(to).or_insert(0) += bytes;
    }

    pub fn live(&self) -> u64 {
        self.live
    }
    pub fn live_of(&self, cat: MemCategory) -> u64 {
        self.live_by_cat.get(&cat).copied().unwrap_or(0)
    }
    pub fn peak(&self) -> u64 {
        self.peak
    }
    pub fn peak_of(&self, cat: MemCategory) -> u64 {
        self.peak_snapshot.get(&cat).copied().unwrap_or(0)
    }
    pub fn outstanding(&self) -> usize {
        self.allocs.len()
    }

    /// Reset the peak statistic (e.g. after warmup step), keeping live.
    pub fn reset_peak(&mut self) {
        self.peak = self.live;
        self.peak_snapshot = self.live_by_cat.clone();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn live_and_peak() {
        let mut t = MemTracker::new(0, None);
        let a = t.alloc(MemCategory::Weights, 100).unwrap();
        let b = t.alloc(MemCategory::Activations, 50).unwrap();
        assert_eq!(t.live(), 150);
        assert_eq!(t.peak(), 150);
        t.free(b);
        assert_eq!(t.live(), 100);
        assert_eq!(t.peak(), 150);
        let _c = t.alloc(MemCategory::Grads, 20).unwrap();
        assert_eq!(t.peak(), 150); // 120 < 150
        t.free(a);
        assert_eq!(t.live_of(MemCategory::Weights), 0);
    }

    #[test]
    fn peak_snapshot_by_category() {
        let mut t = MemTracker::new(0, None);
        let _w = t.alloc(MemCategory::Weights, 100).unwrap();
        let a = t.alloc(MemCategory::CommBuf, 70).unwrap();
        t.free(a);
        let _b = t.alloc(MemCategory::Activations, 30).unwrap();
        // peak was at weights=100, comm=70
        assert_eq!(t.peak(), 170);
        assert_eq!(t.peak_of(MemCategory::CommBuf), 70);
        assert_eq!(t.peak_of(MemCategory::Activations), 0);
    }

    #[test]
    fn capacity_oom() {
        let mut t = MemTracker::new(3, Some(100));
        let _a = t.alloc(MemCategory::Weights, 80).unwrap();
        let err = t.alloc(MemCategory::Activations, 30).unwrap_err();
        assert_eq!(err.worker, 3);
        assert_eq!(err.live, 80);
        // freeing makes room
    }

    #[test]
    fn recycle_keeps_live_constant() {
        let mut t = MemTracker::new(0, None);
        let c = t.alloc(MemCategory::CommBuf, 64).unwrap();
        let live = t.live();
        t.recycle(c, MemCategory::Activations);
        assert_eq!(t.live(), live);
        assert_eq!(t.live_of(MemCategory::CommBuf), 0);
        assert_eq!(t.live_of(MemCategory::Activations), 64);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut t = MemTracker::new(0, None);
        let a = t.alloc(MemCategory::Weights, 8).unwrap();
        t.free(a);
        t.free(a);
    }

    #[test]
    fn churn_counters() {
        let mut t = MemTracker::new(0, None);
        for _ in 0..5 {
            let a = t.alloc(MemCategory::Activations, 10).unwrap();
            t.free(a);
        }
        assert_eq!(t.total_allocated, 50);
        assert_eq!(t.alloc_count, 5);
        assert_eq!(t.live(), 0);
    }
}
