//! FlatParameter (paper §3.2, last ¶): all parameters of a layer unit are
//! flattened, concatenated, padded, and communicated as ONE message.
//!
//! This is the structure both FSDP (allgather/reduce-scatter granularity)
//! and RTP (rotation message granularity) move around. `FlatLayout`
//! describes where each named tensor lives inside the flat buffer;
//! `pack`/`unpack` convert between a unit's tensors and the flat form, and
//! `shard` views carve the flat buffer into N equal rank-shards.
//!
//! The FlatParameter MOVES through the rank-local ring fabric:
//! [`FlatLayout::allgather_via`] reconstructs every rank's full buffer
//! from the N shards in N-1 neighbor hops, and
//! [`FlatLayout::reduce_scatter_via`] reduces per-rank full gradients back
//! into rank shards in N-1 hops — the two halves of FSDP's unit lifecycle
//! (and, composed, exactly the 2(N-1)-hop ring allreduce).

use crate::comm::{self, RingPort};
use crate::tensor::{numel, HostTensor};

/// One tensor's slot inside a flat buffer.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
}

impl ParamSpec {
    pub fn len(&self) -> usize {
        numel(&self.shape)
    }
}

/// Layout of a unit's FlatParameter, padded to a multiple of `n` so the N
/// rank-shards are equal ("adding padding to the clockwise" in the paper's
/// words — the pad rides at the tail of the last shard).
#[derive(Debug, Clone, PartialEq)]
pub struct FlatLayout {
    pub specs: Vec<ParamSpec>,
    /// Unpadded total element count.
    pub len: usize,
    /// Padded length (multiple of n).
    pub padded: usize,
    pub n: usize,
}

impl FlatLayout {
    pub fn new(params: &[(&str, Vec<usize>)], n: usize) -> Self {
        assert!(n >= 1);
        let mut specs = Vec::with_capacity(params.len());
        let mut offset = 0;
        for (name, shape) in params {
            let spec = ParamSpec { name: name.to_string(), shape: shape.clone(), offset };
            offset += spec.len();
            specs.push(spec);
        }
        let padded = offset.div_ceil(n) * n;
        FlatLayout { specs, len: offset, padded, n }
    }

    /// Elements per rank-shard.
    pub fn shard_len(&self) -> usize {
        self.padded / self.n
    }

    /// Bytes per rank-shard (f32).
    pub fn shard_bytes(&self) -> u64 {
        (self.shard_len() * 4) as u64
    }

    /// Bytes of the full (padded) flat buffer.
    pub fn full_bytes(&self) -> u64 {
        (self.padded * 4) as u64
    }

    /// Flatten `tensors` (in spec order) into one padded buffer.
    pub fn pack(&self, tensors: &[&HostTensor]) -> Vec<f32> {
        assert_eq!(tensors.len(), self.specs.len(), "pack arity mismatch");
        let mut flat = vec![0.0f32; self.padded];
        for (spec, t) in self.specs.iter().zip(tensors) {
            assert_eq!(t.shape, spec.shape, "pack shape mismatch for {}", spec.name);
            flat[spec.offset..spec.offset + spec.len()].copy_from_slice(&t.data);
        }
        flat
    }

    /// Rebuild the tensors from a full flat buffer.
    pub fn unpack(&self, flat: &[f32]) -> Vec<HostTensor> {
        assert!(flat.len() >= self.len, "unpack buffer too short");
        self.specs
            .iter()
            .map(|spec| {
                HostTensor::from_vec(
                    &spec.shape,
                    flat[spec.offset..spec.offset + spec.len()].to_vec(),
                )
            })
            .collect()
    }

    /// Rank-shard `w` of a full flat buffer.
    pub fn shard(&self, flat: &[f32], w: usize) -> Vec<f32> {
        assert!(w < self.n);
        let s = self.shard_len();
        flat[w * s..(w + 1) * s].to_vec()
    }

    /// Scatter a full flat buffer into its N rank-shards.
    pub fn shards(&self, flat: &[f32]) -> Vec<Vec<f32>> {
        (0..self.n).map(|w| self.shard(flat, w)).collect()
    }

    /// This rank's side of the ring-allgather of the N rank-shards:
    /// reconstructs the full (padded) flat buffer from this rank's shard
    /// in N-1 neighbor hops through this rank's own port. Every rank of
    /// the round must call this with its shard.
    pub fn allgather_via(&self, port: &RingPort, shard: &[f32]) -> Vec<f32> {
        assert_eq!(port.n(), self.n, "allgather_via rank arity");
        assert_eq!(shard.len(), self.shard_len(), "allgather_via shard length");
        comm::allgather(port, shard)
    }

    /// This rank's side of the ring reduce-scatter of per-rank full
    /// (padded) buffers back into rank shards (sum), in N-1 neighbor
    /// hops. `full` is this rank's staged full gradient; returns this
    /// rank's reduced shard.
    pub fn reduce_scatter_via(&self, port: &RingPort, full: &[f32]) -> Vec<f32> {
        assert_eq!(port.n(), self.n, "reduce_scatter_via rank arity");
        assert_eq!(full.len(), self.padded, "reduce_scatter_via buffer length");
        comm::reduce_scatter(port, full)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn layout3(n: usize) -> FlatLayout {
        FlatLayout::new(
            &[("w", vec![3, 4]), ("b", vec![4]), ("g", vec![5])],
            n,
        )
    }

    #[test]
    fn offsets_are_cumulative() {
        let l = layout3(2);
        assert_eq!(l.specs[0].offset, 0);
        assert_eq!(l.specs[1].offset, 12);
        assert_eq!(l.specs[2].offset, 16);
        assert_eq!(l.len, 21);
        assert_eq!(l.padded, 22); // next multiple of 2
        assert_eq!(l.shard_len(), 11);
    }

    #[test]
    fn pack_unpack_roundtrip() {
        prop::check("flat pack/unpack roundtrip", 50, |rng| {
            let n = 1 + rng.below(8);
            let l = layout3(n);
            let mut rngf = Rng::new(rng.next_u64());
            let tensors: Vec<HostTensor> = l
                .specs
                .iter()
                .map(|s| HostTensor::randn(&s.shape, 1.0, &mut rngf))
                .collect();
            let refs: Vec<&HostTensor> = tensors.iter().collect();
            let flat = l.pack(&refs);
            if flat.len() != l.padded {
                return Err("padded length wrong".into());
            }
            let back = l.unpack(&flat);
            for (a, b) in back.iter().zip(&tensors) {
                if a != b {
                    return Err(format!("{:?} != {:?}", a.shape, b.shape));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn shards_reassemble_through_fabric() {
        prop::check("shards concat to flat", 50, |rng| {
            let n = 1 + rng.below(8);
            let l = layout3(n);
            let flat: Vec<f32> = (0..l.padded).map(|i| i as f32).collect();
            let shards = l.shards(&flat);
            let fab = crate::comm::RingFabric::new(n);
            let backs = crate::comm::spmd(&fab, |port| {
                l.allgather_via(&port, &shards[port.rank()])
            });
            for back in backs {
                prop::close(&back, &flat, 0.0)?;
            }
            if fab.in_flight() != 0 {
                return Err("fabric not drained".into());
            }
            Ok(())
        });
    }

    #[test]
    fn fabric_reduce_scatter_sums_rank_fulls() {
        prop::check("rs via fabric", 40, |rng| {
            let n = 1 + rng.below(6);
            let l = layout3(n);
            let fulls: Vec<Vec<f32>> = (0..n)
                .map(|w| (0..l.padded).map(|i| (w * 100 + i) as f32).collect())
                .collect();
            let fab = crate::comm::RingFabric::new(n);
            let got = crate::comm::spmd(&fab, |port| {
                l.reduce_scatter_via(&port, &fulls[port.rank()])
            });
            let want = crate::comm::reference::reduce_scatter(&fulls);
            for (g, w) in got.iter().zip(&want) {
                prop::close(g, w, 1e-5)?;
            }
            Ok(())
        });
    }

    #[test]
    fn padding_is_zero_initialized() {
        let l = FlatLayout::new(&[("w", vec![3])], 2);
        assert_eq!(l.padded, 4);
        let t = HostTensor::from_vec(&[3], vec![1., 2., 3.]);
        let flat = l.pack(&[&t]);
        assert_eq!(flat, vec![1., 2., 3., 0.]);
    }

    #[test]
    fn n1_has_no_padding_unless_needed() {
        let l = FlatLayout::new(&[("w", vec![7])], 1);
        assert_eq!(l.padded, 7);
        assert_eq!(l.shard_len(), 7);
    }

    #[test]
    #[should_panic(expected = "pack shape mismatch")]
    fn pack_rejects_wrong_shape() {
        let l = FlatLayout::new(&[("w", vec![2, 2])], 1);
        let t = HostTensor::zeros(&[3]);
        l.pack(&[&t]);
    }
}
