//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime.
//!
//! `artifacts/<preset>/manifest.json` lists one entry per AOT'd op
//! instance: key (`{op}__b{b}__p{p}[__pallas]`), the HLO text file, and
//! the input/output dtype+shape signatures. Loading validates the embedded
//! model config against the rust preset mirror, so a drifted compile is a
//! hard error, not a shape crash mid-run.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::ModelCfg;
use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct ShapeSig {
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl ShapeSig {
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

#[derive(Debug, Clone)]
pub struct Entry {
    pub key: String,
    pub op: String,
    pub b: usize,
    pub p: usize,
    pub pallas: bool,
    /// Path relative to the artifacts root.
    pub file: String,
    pub inputs: Vec<ShapeSig>,
    pub outputs: Vec<ShapeSig>,
}

#[derive(Debug)]
pub struct Manifest {
    pub preset: String,
    pub cfg: ModelCfg,
    pub root: PathBuf,
    pub entries: HashMap<String, Entry>,
}

fn sigs(j: &Json, what: &str) -> Result<Vec<ShapeSig>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("{what} not an array"))?
        .iter()
        .map(|e| {
            let dtype = e
                .idx(0)
                .as_str()
                .ok_or_else(|| anyhow!("{what} missing dtype"))?
                .to_string();
            let shape = e
                .idx(1)
                .as_arr()
                .ok_or_else(|| anyhow!("{what} missing shape"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect::<Result<Vec<_>>>()?;
            Ok(ShapeSig { dtype, shape })
        })
        .collect()
}

fn cfg_from_json(j: &Json) -> Result<ModelCfg> {
    let get = |k: &str| {
        j.get(k)
            .as_usize()
            .ok_or_else(|| anyhow!("manifest config missing {k}"))
    };
    Ok(ModelCfg {
        name: j
            .get("name")
            .as_str()
            .ok_or_else(|| anyhow!("manifest config missing name"))?
            .to_string(),
        vocab: get("vocab")?,
        hidden: get("hidden")?,
        heads: get("heads")?,
        layers: get("layers")?,
        seq: get("seq")?,
        ffn: get("ffn")?,
        experts: get("experts")?,
        expert_ffn: get("expert_ffn")?,
    })
}

impl Manifest {
    /// Load `root/<preset>/manifest.json` (plus `manifest_pallas.json` if
    /// present — its entries carry the `__pallas` key suffix and never
    /// collide).
    pub fn load(root: &Path, preset: &str) -> Result<Manifest> {
        let dir = root.join(preset);
        let mut m = Self::load_one(root, &dir.join("manifest.json"))
            .with_context(|| format!("loading manifest for preset {preset}"))?;
        let pallas = dir.join("manifest_pallas.json");
        if pallas.exists() {
            let extra = Self::load_one(root, &pallas)?;
            m.entries.extend(extra.entries);
        }
        Ok(m)
    }

    fn load_one(root: &Path, path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        let preset = j
            .get("preset")
            .as_str()
            .ok_or_else(|| anyhow!("manifest missing preset"))?
            .to_string();
        let cfg = cfg_from_json(j.get("config"))?;
        let mut entries = HashMap::new();
        for e in j.get("entries").as_arr().unwrap_or(&[]) {
            let entry = Entry {
                key: e
                    .get("key")
                    .as_str()
                    .ok_or_else(|| anyhow!("entry missing key"))?
                    .to_string(),
                op: e.get("op").as_str().unwrap_or("").to_string(),
                b: e.get("b").as_usize().unwrap_or(0),
                p: e.get("p").as_usize().unwrap_or(1),
                pallas: e.get("pallas").as_bool().unwrap_or(false),
                file: e
                    .get("file")
                    .as_str()
                    .ok_or_else(|| anyhow!("entry missing file"))?
                    .to_string(),
                inputs: sigs(e.get("inputs"), "inputs")?,
                outputs: sigs(e.get("outputs"), "outputs")?,
            };
            entries.insert(entry.key.clone(), entry);
        }
        if entries.is_empty() {
            bail!("manifest {} has no entries", path.display());
        }
        Ok(Manifest { preset, cfg, root: root.to_path_buf(), entries })
    }

    pub fn entry(&self, key: &str) -> Result<&Entry> {
        self.entries.get(key).ok_or_else(|| {
            anyhow!(
                "artifact {key} not in manifest for {} ({} entries); \
                 rerun `make artifacts` with the right preset/combos",
                self.preset,
                self.entries.len()
            )
        })
    }

    pub fn hlo_path(&self, entry: &Entry) -> PathBuf {
        self.root.join(&entry.file)
    }

    /// Cross-check the embedded config against a rust preset — catches
    /// python/rust preset drift at startup.
    pub fn check_cfg(&self, expect: &ModelCfg) -> Result<()> {
        if &self.cfg != expect {
            bail!(
                "manifest config for {} does not match rust preset:\n  manifest: {:?}\n  rust:     {:?}",
                self.preset,
                self.cfg,
                expect
            );
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Run manifest (Launcher::Process)
// ---------------------------------------------------------------------------

/// The serialized engine configuration `Launcher::Process` hands each
/// worker: everything a re-entrant `rtp worker` process needs to rebuild
/// its OWN `RankEngine` bit-identically to the in-process launchers —
/// preset, strategy, world size, determinism seed, and the engine knobs
/// that change the float schedule. Written as `manifest.json` into the
/// run's rendezvous dir by the parent, loaded by every worker.
#[derive(Debug, Clone, PartialEq)]
pub struct RunManifest {
    pub preset: String,
    /// `Strategy` display token (round-trips through `Strategy::parse`).
    pub strategy: String,
    pub workers: usize,
    pub global_batch: usize,
    /// `ExecKind` token (`oracle` | `virtual` | `pjrt` | `pallas`).
    pub exec: String,
    pub seed: u64,
    /// `"layer"` | `"model"` (FSDP unit granularity).
    pub fsdp_granularity: String,
    pub rtp_recycle: bool,
    pub async_rotation: bool,
    /// `"fifo"` | `"round-robin"` | `"priority"`.
    pub sched_policy: String,
    /// Gradient bucket size target in bytes; 0 = monolithic.
    pub bucket_bytes: u64,
    /// Transport backend token (`shm` | `uds`).
    pub transport: String,
    /// Recv-watchdog override in ms; 0 = workers read
    /// `RTP_FABRIC_TIMEOUT_SECS` from their (inherited) env.
    pub fabric_timeout_ms: u64,
    /// Recv-retry override stored as value+1; 0 = `RTP_FABRIC_RETRIES`.
    pub fabric_retries_plus1: u64,
    /// Fabric epoch for elastic recovery: epoch 0 rendezvouses in the run
    /// dir itself, epoch e > 0 in `ep<e>/` under it. Read tolerantly
    /// (missing = 0) so pre-elastic manifests stay loadable.
    pub epoch: u64,
    /// Checkpoint file a (re)joining worker loads its shard from before
    /// reporting READY; empty = fresh init. Read tolerantly (missing = "").
    pub init_params: String,
}

impl RunManifest {
    pub fn to_json(&self) -> String {
        let mut m = std::collections::BTreeMap::new();
        m.insert("preset".to_string(), Json::Str(self.preset.clone()));
        m.insert("strategy".to_string(), Json::Str(self.strategy.clone()));
        m.insert("workers".to_string(), Json::Num(self.workers as f64));
        m.insert("global_batch".to_string(), Json::Num(self.global_batch as f64));
        m.insert("exec".to_string(), Json::Str(self.exec.clone()));
        // seed rides as a string: the hand-rolled parser keeps numbers as
        // f64, which cannot hold every u64 exactly
        m.insert("seed".to_string(), Json::Str(self.seed.to_string()));
        m.insert(
            "fsdp_granularity".to_string(),
            Json::Str(self.fsdp_granularity.clone()),
        );
        m.insert("rtp_recycle".to_string(), Json::Bool(self.rtp_recycle));
        m.insert("async_rotation".to_string(), Json::Bool(self.async_rotation));
        m.insert("sched_policy".to_string(), Json::Str(self.sched_policy.clone()));
        m.insert("bucket_bytes".to_string(), Json::Num(self.bucket_bytes as f64));
        m.insert("transport".to_string(), Json::Str(self.transport.clone()));
        m.insert(
            "fabric_timeout_ms".to_string(),
            Json::Num(self.fabric_timeout_ms as f64),
        );
        m.insert(
            "fabric_retries_plus1".to_string(),
            Json::Num(self.fabric_retries_plus1 as f64),
        );
        m.insert("epoch".to_string(), Json::Num(self.epoch as f64));
        m.insert("init_params".to_string(), Json::Str(self.init_params.clone()));
        format!("{}", Json::Obj(m))
    }

    pub fn from_json(text: &str) -> Result<RunManifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("run manifest: {e}"))?;
        let s = |k: &str| -> Result<String> {
            Ok(j.get(k)
                .as_str()
                .ok_or_else(|| anyhow!("run manifest missing {k}"))?
                .to_string())
        };
        let n = |k: &str| -> Result<u64> {
            Ok(j.get(k)
                .as_f64()
                .ok_or_else(|| anyhow!("run manifest missing {k}"))? as u64)
        };
        let b = |k: &str| -> Result<bool> {
            j.get(k)
                .as_bool()
                .ok_or_else(|| anyhow!("run manifest missing {k}"))
        };
        Ok(RunManifest {
            preset: s("preset")?,
            strategy: s("strategy")?,
            workers: n("workers")? as usize,
            global_batch: n("global_batch")? as usize,
            exec: s("exec")?,
            seed: s("seed")?
                .parse::<u64>()
                .map_err(|_| anyhow!("run manifest seed not a u64"))?,
            fsdp_granularity: s("fsdp_granularity")?,
            rtp_recycle: b("rtp_recycle")?,
            async_rotation: b("async_rotation")?,
            sched_policy: s("sched_policy")?,
            bucket_bytes: n("bucket_bytes")?,
            transport: s("transport")?,
            fabric_timeout_ms: n("fabric_timeout_ms")?,
            fabric_retries_plus1: n("fabric_retries_plus1")?,
            // elastic fields are tolerant: pre-elastic manifests lack them
            epoch: j.get("epoch").as_f64().unwrap_or(0.0) as u64,
            init_params: j.get("init_params").as_str().unwrap_or("").to_string(),
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json())
            .with_context(|| format!("writing run manifest {}", path.display()))
    }

    pub fn load_run(path: &Path) -> Result<RunManifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading run manifest {}", path.display()))?;
        Self::from_json(&text)
    }
}

/// Default artifacts root: `$RTP_ARTIFACTS` or `./artifacts` (falling back
/// over the crate root for tests run from other directories).
pub fn artifacts_root() -> PathBuf {
    if let Ok(p) = std::env::var("RTP_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let cwd = PathBuf::from("artifacts");
    if cwd.exists() {
        return cwd;
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn have_artifacts() -> bool {
        artifacts_root().join("tiny/manifest.json").exists()
    }

    #[test]
    fn loads_tiny_manifest() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        }
        let m = Manifest::load(&artifacts_root(), "tiny").unwrap();
        assert_eq!(m.preset, "tiny");
        // the python preset must mirror the rust preset exactly
        m.check_cfg(&presets::get("tiny").unwrap()).unwrap();
        // a known entry with the documented signature
        let e = m.entry("attn_fwd__b2__p2").unwrap();
        assert_eq!(e.inputs.len(), 4);
        assert_eq!(e.inputs[0].dtype, "f32");
        assert_eq!(e.inputs[0].shape, vec![2, 16, 32]); // [b, S, H]
        assert_eq!(e.inputs[1].shape, vec![32, 48]); // [H, 3*H/2]
        assert!(m.hlo_path(e).exists());
    }

    #[test]
    fn manifest_shapes_match_rust_op_catalog() {
        if !have_artifacts() {
            return;
        }
        use crate::model::ops::{self, Op};
        let m = Manifest::load(&artifacts_root(), "tiny").unwrap();
        let cfg = presets::get("tiny").unwrap();
        for e in m.entries.values().filter(|e| !e.pallas) {
            let op = Op::ALL
                .iter()
                .copied()
                .find(|o| o.key_name() == e.op)
                .unwrap_or_else(|| panic!("unknown op {}", e.op));
            let want_in = ops::input_shapes(op, &cfg, e.b, e.p);
            assert_eq!(want_in.len(), e.inputs.len(), "{}", e.key);
            for ((_, ws), have) in want_in.iter().zip(&e.inputs) {
                assert_eq!(ws, &have.shape, "{} inputs", e.key);
            }
            let want_out = ops::output_shapes(op, &cfg, e.b, e.p);
            assert_eq!(want_out.len(), e.outputs.len(), "{}", e.key);
            for (ws, have) in want_out.iter().zip(&e.outputs) {
                assert_eq!(ws, &have.shape, "{} outputs", e.key);
            }
        }
    }

    #[test]
    fn run_manifest_roundtrip() {
        let m = RunManifest {
            preset: "tiny".into(),
            strategy: "rtp-outofplace".into(),
            workers: 4,
            global_batch: 8,
            exec: "oracle".into(),
            seed: u64::MAX - 3, // would lose precision as an f64
            fsdp_granularity: "layer".into(),
            rtp_recycle: true,
            async_rotation: false,
            sched_policy: "priority".into(),
            bucket_bytes: 1 << 16,
            transport: "shm".into(),
            fabric_timeout_ms: 2000,
            fabric_retries_plus1: 0,
            epoch: 3,
            init_params: "/tmp/ckpt-ep3.ckpt".into(),
        };
        let back = RunManifest::from_json(&m.to_json()).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn run_manifest_tolerates_missing_elastic_fields() {
        // a pre-elastic manifest (no epoch/init_params keys) must load
        // with epoch 0 and no init checkpoint
        let text = concat!(
            "{\"preset\":\"tiny\",\"strategy\":\"ddp\",\"workers\":2,",
            "\"global_batch\":4,\"exec\":\"oracle\",\"seed\":\"1\",",
            "\"fsdp_granularity\":\"layer\",\"rtp_recycle\":true,",
            "\"async_rotation\":true,\"sched_policy\":\"fifo\",",
            "\"bucket_bytes\":0,\"transport\":\"shm\",",
            "\"fabric_timeout_ms\":0,\"fabric_retries_plus1\":0}"
        );
        let back = RunManifest::from_json(text).unwrap();
        assert_eq!(back.epoch, 0);
        assert_eq!(back.init_params, "");
        assert_eq!(back.workers, 2);
    }

    #[test]
    fn missing_entry_is_helpful_error() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(&artifacts_root(), "tiny").unwrap();
        let err = m.entry("attn_fwd__b999__p1").unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
