//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime.
//!
//! `artifacts/<preset>/manifest.json` lists one entry per AOT'd op
//! instance: key (`{op}__b{b}__p{p}[__pallas]`), the HLO text file, and
//! the input/output dtype+shape signatures. Loading validates the embedded
//! model config against the rust preset mirror, so a drifted compile is a
//! hard error, not a shape crash mid-run.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::ModelCfg;
use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct ShapeSig {
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl ShapeSig {
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

#[derive(Debug, Clone)]
pub struct Entry {
    pub key: String,
    pub op: String,
    pub b: usize,
    pub p: usize,
    pub pallas: bool,
    /// Path relative to the artifacts root.
    pub file: String,
    pub inputs: Vec<ShapeSig>,
    pub outputs: Vec<ShapeSig>,
}

#[derive(Debug)]
pub struct Manifest {
    pub preset: String,
    pub cfg: ModelCfg,
    pub root: PathBuf,
    pub entries: HashMap<String, Entry>,
}

fn sigs(j: &Json, what: &str) -> Result<Vec<ShapeSig>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("{what} not an array"))?
        .iter()
        .map(|e| {
            let dtype = e
                .idx(0)
                .as_str()
                .ok_or_else(|| anyhow!("{what} missing dtype"))?
                .to_string();
            let shape = e
                .idx(1)
                .as_arr()
                .ok_or_else(|| anyhow!("{what} missing shape"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect::<Result<Vec<_>>>()?;
            Ok(ShapeSig { dtype, shape })
        })
        .collect()
}

fn cfg_from_json(j: &Json) -> Result<ModelCfg> {
    let get = |k: &str| {
        j.get(k)
            .as_usize()
            .ok_or_else(|| anyhow!("manifest config missing {k}"))
    };
    Ok(ModelCfg {
        name: j
            .get("name")
            .as_str()
            .ok_or_else(|| anyhow!("manifest config missing name"))?
            .to_string(),
        vocab: get("vocab")?,
        hidden: get("hidden")?,
        heads: get("heads")?,
        layers: get("layers")?,
        seq: get("seq")?,
        ffn: get("ffn")?,
        experts: get("experts")?,
        expert_ffn: get("expert_ffn")?,
    })
}

impl Manifest {
    /// Load `root/<preset>/manifest.json` (plus `manifest_pallas.json` if
    /// present — its entries carry the `__pallas` key suffix and never
    /// collide).
    pub fn load(root: &Path, preset: &str) -> Result<Manifest> {
        let dir = root.join(preset);
        let mut m = Self::load_one(root, &dir.join("manifest.json"))
            .with_context(|| format!("loading manifest for preset {preset}"))?;
        let pallas = dir.join("manifest_pallas.json");
        if pallas.exists() {
            let extra = Self::load_one(root, &pallas)?;
            m.entries.extend(extra.entries);
        }
        Ok(m)
    }

    fn load_one(root: &Path, path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        let preset = j
            .get("preset")
            .as_str()
            .ok_or_else(|| anyhow!("manifest missing preset"))?
            .to_string();
        let cfg = cfg_from_json(j.get("config"))?;
        let mut entries = HashMap::new();
        for e in j.get("entries").as_arr().unwrap_or(&[]) {
            let entry = Entry {
                key: e
                    .get("key")
                    .as_str()
                    .ok_or_else(|| anyhow!("entry missing key"))?
                    .to_string(),
                op: e.get("op").as_str().unwrap_or("").to_string(),
                b: e.get("b").as_usize().unwrap_or(0),
                p: e.get("p").as_usize().unwrap_or(1),
                pallas: e.get("pallas").as_bool().unwrap_or(false),
                file: e
                    .get("file")
                    .as_str()
                    .ok_or_else(|| anyhow!("entry missing file"))?
                    .to_string(),
                inputs: sigs(e.get("inputs"), "inputs")?,
                outputs: sigs(e.get("outputs"), "outputs")?,
            };
            entries.insert(entry.key.clone(), entry);
        }
        if entries.is_empty() {
            bail!("manifest {} has no entries", path.display());
        }
        Ok(Manifest { preset, cfg, root: root.to_path_buf(), entries })
    }

    pub fn entry(&self, key: &str) -> Result<&Entry> {
        self.entries.get(key).ok_or_else(|| {
            anyhow!(
                "artifact {key} not in manifest for {} ({} entries); \
                 rerun `make artifacts` with the right preset/combos",
                self.preset,
                self.entries.len()
            )
        })
    }

    pub fn hlo_path(&self, entry: &Entry) -> PathBuf {
        self.root.join(&entry.file)
    }

    /// Cross-check the embedded config against a rust preset — catches
    /// python/rust preset drift at startup.
    pub fn check_cfg(&self, expect: &ModelCfg) -> Result<()> {
        if &self.cfg != expect {
            bail!(
                "manifest config for {} does not match rust preset:\n  manifest: {:?}\n  rust:     {:?}",
                self.preset,
                self.cfg,
                expect
            );
        }
        Ok(())
    }
}

/// Default artifacts root: `$RTP_ARTIFACTS` or `./artifacts` (falling back
/// over the crate root for tests run from other directories).
pub fn artifacts_root() -> PathBuf {
    if let Ok(p) = std::env::var("RTP_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let cwd = PathBuf::from("artifacts");
    if cwd.exists() {
        return cwd;
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn have_artifacts() -> bool {
        artifacts_root().join("tiny/manifest.json").exists()
    }

    #[test]
    fn loads_tiny_manifest() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        }
        let m = Manifest::load(&artifacts_root(), "tiny").unwrap();
        assert_eq!(m.preset, "tiny");
        // the python preset must mirror the rust preset exactly
        m.check_cfg(&presets::get("tiny").unwrap()).unwrap();
        // a known entry with the documented signature
        let e = m.entry("attn_fwd__b2__p2").unwrap();
        assert_eq!(e.inputs.len(), 4);
        assert_eq!(e.inputs[0].dtype, "f32");
        assert_eq!(e.inputs[0].shape, vec![2, 16, 32]); // [b, S, H]
        assert_eq!(e.inputs[1].shape, vec![32, 48]); // [H, 3*H/2]
        assert!(m.hlo_path(e).exists());
    }

    #[test]
    fn manifest_shapes_match_rust_op_catalog() {
        if !have_artifacts() {
            return;
        }
        use crate::model::ops::{self, Op};
        let m = Manifest::load(&artifacts_root(), "tiny").unwrap();
        let cfg = presets::get("tiny").unwrap();
        for e in m.entries.values().filter(|e| !e.pallas) {
            let op = Op::ALL
                .iter()
                .copied()
                .find(|o| o.key_name() == e.op)
                .unwrap_or_else(|| panic!("unknown op {}", e.op));
            let want_in = ops::input_shapes(op, &cfg, e.b, e.p);
            assert_eq!(want_in.len(), e.inputs.len(), "{}", e.key);
            for ((_, ws), have) in want_in.iter().zip(&e.inputs) {
                assert_eq!(ws, &have.shape, "{} inputs", e.key);
            }
            let want_out = ops::output_shapes(op, &cfg, e.b, e.p);
            assert_eq!(want_out.len(), e.outputs.len(), "{}", e.key);
            for (ws, have) in want_out.iter().zip(&e.outputs) {
                assert_eq!(ws, &have.shape, "{} outputs", e.key);
            }
        }
    }

    #[test]
    fn missing_entry_is_helpful_error() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(&artifacts_root(), "tiny").unwrap();
        let err = m.entry("attn_fwd__b999__p1").unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
