//! PJRT runtime: load AOT'd HLO text, compile once, execute many.
//!
//! Wraps the `xla` crate (xla_extension 0.5.1, CPU PJRT plugin). HLO text
//! is the interchange format — see DESIGN.md §4 and
//! /opt/xla-example/README.md for why serialized protos are rejected.
//! Executables are compiled lazily on first use and cached for the life of
//! the runtime, so the training hot loop never recompiles.
//!
//! The `xla` crate is an OPTIONAL dependency gated behind the `xla` cargo
//! feature: containers without the xla_extension toolchain still build
//! and run the full oracle/virtual stack. Without the feature,
//! `PjrtRuntime::new` returns a clear error and everything else (tests,
//! benches, the CLI) skips the PJRT path exactly as it already does when
//! AOT artifacts are absent. Enabling `--features xla` requires adding
//! the `xla` crate (xla_extension 0.5.1) to the build environment.

use anyhow::Result;

use crate::tensor::{HostTensor, IntTensor};

/// Counters for the §Perf pass.
#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub executions: u64,
    pub compilations: u64,
    /// Wall time spent inside PJRT execute (s).
    pub exec_seconds: f64,
    /// Wall time spent in host<->literal conversion (s).
    pub convert_seconds: f64,
}

/// A borrowed runtime argument.
#[derive(Debug, Clone, Copy)]
pub enum RtArg<'a> {
    F(&'a HostTensor),
    I(&'a IntTensor),
}

#[cfg_attr(not(feature = "xla"), allow(dead_code))]
impl<'a> RtArg<'a> {
    fn shape(&self) -> &[usize] {
        match self {
            RtArg::F(t) => &t.shape,
            RtArg::I(t) => &t.shape,
        }
    }

    fn dtype(&self) -> &'static str {
        match self {
            RtArg::F(_) => "f32",
            RtArg::I(_) => "i32",
        }
    }
}

#[cfg_attr(not(feature = "xla"), allow(dead_code))]
fn validate(entry: &super::manifest::Entry, args: &[RtArg]) -> Result<()> {
    use anyhow::bail;
    if entry.inputs.len() != args.len() {
        bail!(
            "{}: expected {} args, got {}",
            entry.key,
            entry.inputs.len(),
            args.len()
        );
    }
    for (i, (sig, arg)) in entry.inputs.iter().zip(args).enumerate() {
        if sig.dtype != arg.dtype() || sig.shape != arg.shape() {
            bail!(
                "{} arg {i}: expected {} {:?}, got {} {:?}",
                entry.key,
                sig.dtype,
                sig.shape,
                arg.dtype(),
                arg.shape()
            );
        }
    }
    Ok(())
}

#[cfg(feature = "xla")]
mod real {
    use std::collections::HashMap;
    use std::path::Path;

    use anyhow::{anyhow, bail, Context, Result};

    use super::super::manifest::Manifest;
    use super::{validate, RtArg, RuntimeStats};
    use crate::tensor::HostTensor;

    pub struct PjrtRuntime {
        client: xla::PjRtClient,
        pub manifest: Manifest,
        compiled: HashMap<String, xla::PjRtLoadedExecutable>,
        pub stats: RuntimeStats,
    }

    impl<'a> RtArg<'a> {
        /// Upload straight to a device buffer (§Perf L3 opt #1): skips the
        /// Literal intermediate entirely — one copy instead of two — and,
        /// critically, avoids `PjRtLoadedExecutable::execute(Literal...)`,
        /// whose C-side literal transfer LEAKS ~6 KB + output-size per call
        /// in xla_extension 0.5.1 (measured in EXPERIMENTS.md §Perf; the
        /// `execute_b` device-buffer path is leak-free).
        fn to_device(self, client: &xla::PjRtClient) -> Result<xla::PjRtBuffer> {
            match self {
                RtArg::F(t) => client
                    .buffer_from_host_buffer(&t.data, &t.shape, None)
                    .map_err(|e| anyhow!("host->device upload failed: {e}")),
                RtArg::I(t) => client
                    .buffer_from_host_buffer(&t.data, &t.shape, None)
                    .map_err(|e| anyhow!("host->device upload failed: {e}")),
            }
        }
    }

    impl PjrtRuntime {
        pub fn new(root: &Path, preset: &str) -> Result<Self> {
            let manifest = Manifest::load(root, preset)?;
            let client = xla::PjRtClient::cpu()
                .map_err(|e| anyhow!("PJRT CPU client init failed: {e}"))?;
            Ok(PjrtRuntime {
                client,
                manifest,
                compiled: HashMap::new(),
                stats: RuntimeStats::default(),
            })
        }

        /// Compile (or fetch the cached executable for) one artifact key.
        pub fn ensure_compiled(&mut self, key: &str) -> Result<()> {
            if self.compiled.contains_key(key) {
                return Ok(());
            }
            let entry = self.manifest.entry(key)?;
            let path = self.manifest.hlo_path(entry);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {key}: {e}"))?;
            self.compiled.insert(key.to_string(), exe);
            self.stats.compilations += 1;
            Ok(())
        }

        /// Execute one artifact. Outputs come back as f32 host tensors shaped
        /// per the manifest (the AOT path lowers with `return_tuple=True`, so
        /// the single PJRT output is a tuple we decompose).
        pub fn run(&mut self, key: &str, args: &[RtArg]) -> Result<Vec<HostTensor>> {
            self.ensure_compiled(key)?;
            // borrow (not clone) the entry; stats deltas are applied at the
            // end so no &mut self is needed mid-flight (§Perf L3 opt #2)
            let entry = self.manifest.entry(key)?;
            validate(entry, args)?;

            let t0 = std::time::Instant::now();
            let bufs: Vec<xla::PjRtBuffer> = args
                .iter()
                .map(|a| a.to_device(&self.client))
                .collect::<Result<_>>()
                .with_context(|| format!("uploading args for {key}"))?;
            let mut convert_s = t0.elapsed().as_secs_f64();

            let exe = self.compiled.get(key).expect("just compiled");
            let t1 = std::time::Instant::now();
            let result = exe
                .execute_b::<xla::PjRtBuffer>(&bufs)
                .map_err(|e| anyhow!("executing {key}: {e}"))?;
            let tuple = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetching {key} result: {e}"))?;
            let exec_s = t1.elapsed().as_secs_f64();

            let t2 = std::time::Instant::now();
            let parts = tuple
                .to_tuple()
                .map_err(|e| anyhow!("decomposing {key} tuple: {e}"))?;
            if parts.len() != entry.outputs.len() {
                bail!(
                    "{key}: manifest promises {} outputs, executable returned {}",
                    entry.outputs.len(),
                    parts.len()
                );
            }
            let outs = parts
                .into_iter()
                .zip(&entry.outputs)
                .map(|(lit, sig)| {
                    let data = lit
                        .to_vec::<f32>()
                        .map_err(|e| anyhow!("reading {key} output: {e}"))?;
                    if data.len() != sig.numel() {
                        bail!(
                            "{key}: output has {} elems, expected {}",
                            data.len(),
                            sig.numel()
                        );
                    }
                    Ok(HostTensor::from_vec(&sig.shape, data))
                })
                .collect::<Result<Vec<_>>>()?;
            convert_s += t2.elapsed().as_secs_f64();
            self.stats.convert_seconds += convert_s;
            self.stats.exec_seconds += exec_s;
            self.stats.executions += 1;
            Ok(outs)
        }

        pub fn compiled_count(&self) -> usize {
            self.compiled.len()
        }
    }
}

#[cfg(not(feature = "xla"))]
mod stub {
    use std::path::Path;

    use anyhow::{bail, Result};

    use super::super::manifest::Manifest;
    use super::{RtArg, RuntimeStats};
    use crate::tensor::HostTensor;

    /// Feature-gated stand-in: the build has no xla_extension, so the
    /// PJRT path reports itself unavailable at construction. The rest of
    /// the stack (oracle, virtual, benches, CLI) behaves exactly as it
    /// does when AOT artifacts are absent.
    pub struct PjrtRuntime {
        pub manifest: Manifest,
        pub stats: RuntimeStats,
    }

    impl PjrtRuntime {
        pub fn new(_root: &Path, _preset: &str) -> Result<Self> {
            bail!(
                "PJRT backend unavailable: this build has no `xla` feature \
                 (xla_extension not present). Use the oracle or virtual \
                 executor; enabling the feature also requires adding the \
                 `xla` crate (xla_extension 0.5.1) to [dependencies]."
            )
        }

        pub fn ensure_compiled(&mut self, _key: &str) -> Result<()> {
            bail!("PJRT backend unavailable (built without the `xla` feature)")
        }

        pub fn run(&mut self, _key: &str, _args: &[RtArg]) -> Result<Vec<HostTensor>> {
            bail!("PJRT backend unavailable (built without the `xla` feature)")
        }

        pub fn compiled_count(&self) -> usize {
            0
        }
    }
}

#[cfg(feature = "xla")]
pub use real::PjrtRuntime;
#[cfg(not(feature = "xla"))]
pub use stub::PjrtRuntime;

#[cfg(all(test, feature = "xla"))]
mod tests {
    use super::*;
    use crate::runtime::manifest::artifacts_root;

    fn runtime() -> Option<PjrtRuntime> {
        let root = artifacts_root();
        if !root.join("tiny/manifest.json").exists() {
            eprintln!("skipping: no artifacts");
            return None;
        }
        Some(PjrtRuntime::new(&root, "tiny").unwrap())
    }

    #[test]
    fn ln_fwd_runs_and_matches_oracle() {
        let Some(mut rt) = runtime() else { return };
        let cfg = rt.manifest.cfg.clone();
        let mut rng = crate::util::rng::Rng::new(3);
        let x = HostTensor::randn(&[2, cfg.seq, cfg.hidden], 1.0, &mut rng);
        let g = HostTensor::randn(&[cfg.hidden], 0.5, &mut rng);
        let b = HostTensor::randn(&[cfg.hidden], 0.5, &mut rng);
        let outs = rt
            .run("ln_fwd__b2__p1", &[RtArg::F(&x), RtArg::F(&g), RtArg::F(&b)])
            .unwrap();
        let want = crate::model::oracle::ln_fwd(&x, &g, &b);
        assert!(outs[0].allclose(&want, 1e-4), "diff {}", outs[0].max_abs_diff(&want));
        assert_eq!(rt.stats.executions, 1);
        assert_eq!(rt.stats.compilations, 1);
    }

    #[test]
    fn executable_cache_compiles_once() {
        let Some(mut rt) = runtime() else { return };
        let cfg = rt.manifest.cfg.clone();
        let mut rng = crate::util::rng::Rng::new(4);
        let x = HostTensor::randn(&[2, cfg.seq, cfg.hidden], 1.0, &mut rng);
        let g = HostTensor::randn(&[cfg.hidden], 0.5, &mut rng);
        let b = HostTensor::randn(&[cfg.hidden], 0.5, &mut rng);
        for _ in 0..3 {
            rt.run("ln_fwd__b2__p1", &[RtArg::F(&x), RtArg::F(&g), RtArg::F(&b)])
                .unwrap();
        }
        assert_eq!(rt.stats.compilations, 1);
        assert_eq!(rt.stats.executions, 3);
        assert_eq!(rt.compiled_count(), 1);
    }

    #[test]
    fn shape_mismatch_is_rejected() {
        let Some(mut rt) = runtime() else { return };
        let x = HostTensor::zeros(&[1, 2, 3]);
        let err = rt
            .run("ln_fwd__b2__p1", &[RtArg::F(&x), RtArg::F(&x), RtArg::F(&x)])
            .unwrap_err()
            .to_string();
        assert!(err.contains("expected"), "{err}");
    }
}

#[cfg(all(test, not(feature = "xla")))]
mod stub_tests {
    use super::*;
    use crate::runtime::manifest::artifacts_root;

    #[test]
    fn stub_reports_missing_feature() {
        let err = PjrtRuntime::new(&artifacts_root(), "tiny").unwrap_err().to_string();
        assert!(err.contains("xla"), "{err}");
    }
}
