//! Elastic supervisor: in-run recovery from rank death.
//!
//! PR 8 made failures *detectable* — every peer of a dead rank surfaces
//! one typed [`RankFailure`] at the step barrier, with the fabric's
//! poison path guaranteeing lanes drained and pooled buffers returned.
//! The supervisor closes the loop: it owns the training run, and when a
//! step returns a `RankFailure` it
//!
//! 1. **quiesces** — verifies the poison path left the fabric empty
//!    (`in_flight() == 0`; the drain itself already happened inside the
//!    failed round),
//! 2. **backs off** — a bounded exponential schedule from the
//!    [`RecoveryPolicy`] (attempt counter capped by `max_recoveries`; a
//!    run out of budget surfaces the last failure as a typed error,
//!    never a hang),
//! 3. **tears down** the poisoned engine (dropping the `RingFabric` and
//!    every rank body), and
//! 4. **rebuilds** the cluster in-process at N′ — the same world size
//!    ([`RecoveryMode::Respawn`]) or the largest valid world size below
//!    it ([`RecoveryMode::Shrink`]) — then restores the latest snapshot
//!    through the world-size-independent `RTPC2` path
//!    (`restore_train_state` → each engine's `load_full` re-sharding),
//!    so the post-recovery trajectory is bit-identical to a fresh
//!    `--resume` at N′.
//!
//! Snapshots come from periodic **async checkpointing off the training
//! thread** ([`AsyncCheckpointer`]): every `ckpt_every` steps the
//! supervisor captures a `TrainState` and keeps it as the in-memory
//! recovery point; when a checkpoint path is configured the same
//! `Arc`-shared snapshot is handed to the writer thread, which streams
//! it through the crash-atomic tmp+fsync+rename save.
//!
//! `Launcher::Process` recovery (respawning a dead worker's OS process
//! into the live rendezvous) lives in
//! [`ProcessClusterEngine::rebuild`](super::proc::ProcessClusterEngine);
//! the supervisor itself drives the in-process launchers, because the
//! optimizer walks engine-owned params (`visit_owned`) which cannot
//! cross a process boundary.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{ModelCfg, OptimizerKind, Strategy};
use crate::parallel::{build_engine, Engine, EngineOpts, Launcher};
use crate::train::{
    capture_train_state, restore_train_state, AsyncCheckpointer, CkptStats, MarkovCorpus,
    Optimizer, TrainState,
};

use super::fault::{FaultPlan, RankFailure};

/// What to rebuild toward after a rank death.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecoveryMode {
    /// Rebuild at the largest valid world size below the current one
    /// (the survivors keep going without the dead rank's capacity).
    Shrink,
    /// Rebuild at the SAME world size (the dead rank's slot is re-made:
    /// a fresh in-process rank body, or — under `Launcher::Process` —
    /// a respawned `rtp worker` in the existing rendezvous dir).
    Respawn,
}

impl RecoveryMode {
    pub fn parse(s: &str) -> Option<RecoveryMode> {
        match s {
            "shrink" => Some(RecoveryMode::Shrink),
            "respawn" => Some(RecoveryMode::Respawn),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RecoveryMode::Shrink => "shrink",
            RecoveryMode::Respawn => "respawn",
        }
    }
}

impl std::fmt::Display for RecoveryMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Bounded retry/backoff policy for elastic recovery. Select per engine
/// via `EngineOpts::recovery` or process-wide via `RTP_RECOVERY`
/// (`mode=shrink,max=3,backoff_ms=10,backoff_cap_ms=1000,budget_ms=60000`,
/// fields in any order, all optional).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RecoveryPolicy {
    pub mode: RecoveryMode,
    /// Recoveries allowed per run; the failure after the budget is spent
    /// surfaces as a typed error.
    pub max_recoveries: u32,
    /// First backoff sleep; doubles per consecutive recovery.
    pub backoff_base: Duration,
    /// Backoff ceiling.
    pub backoff_cap: Duration,
    /// Wall-clock bound one quiesce→rebuild→restore cycle must finish
    /// within (the recovery watchdog — a blown budget is an error, not a
    /// hang).
    pub rebuild_budget: Duration,
}

impl Default for RecoveryPolicy {
    fn default() -> Self {
        RecoveryPolicy {
            mode: RecoveryMode::Shrink,
            max_recoveries: 3,
            backoff_base: Duration::from_millis(10),
            backoff_cap: Duration::from_secs(1),
            rebuild_budget: Duration::from_secs(60),
        }
    }
}

impl RecoveryPolicy {
    /// Parse the `RTP_RECOVERY` spec. Unknown keys are errors; absent
    /// keys keep their defaults.
    pub fn parse(spec: &str) -> Result<RecoveryPolicy> {
        let mut p = RecoveryPolicy::default();
        for field in spec.split(',') {
            let field = field.trim();
            if field.is_empty() {
                continue;
            }
            let (k, v) = field
                .split_once('=')
                .ok_or_else(|| anyhow!("recovery field {field:?}: expected key=value"))?;
            let (k, v) = (k.trim(), v.trim());
            let ms = |what: &str| -> Result<Duration> {
                v.parse::<u64>()
                    .map(Duration::from_millis)
                    .map_err(|_| anyhow!("recovery {what} {v:?}: expected milliseconds"))
            };
            match k {
                "mode" => {
                    p.mode = RecoveryMode::parse(v)
                        .ok_or_else(|| anyhow!("recovery mode {v:?}: expected shrink|respawn"))?
                }
                "max" => {
                    p.max_recoveries = v
                        .parse()
                        .map_err(|_| anyhow!("recovery max {v:?}: expected an integer"))?
                }
                "backoff_ms" => p.backoff_base = ms("backoff_ms")?,
                "backoff_cap_ms" => p.backoff_cap = ms("backoff_cap_ms")?,
                "budget_ms" => p.rebuild_budget = ms("budget_ms")?,
                other => bail!(
                    "recovery field {other:?}: expected \
                     mode|max|backoff_ms|backoff_cap_ms|budget_ms"
                ),
            }
        }
        Ok(p)
    }

    /// The process-wide policy from `RTP_RECOVERY` (defaults when unset;
    /// panics on a malformed value so typos do not silently change the
    /// recovery behavior a run asked for).
    pub fn from_env() -> RecoveryPolicy {
        match std::env::var("RTP_RECOVERY") {
            Ok(s) if s.trim().is_empty() => RecoveryPolicy::default(),
            Ok(s) => RecoveryPolicy::parse(&s).unwrap_or_else(|e| panic!("RTP_RECOVERY: {e}")),
            Err(_) => RecoveryPolicy::default(),
        }
    }

    /// Backoff before recovery attempt `attempt` (1-based): base ×
    /// 2^(attempt−1), capped.
    pub fn backoff(&self, attempt: u32) -> Duration {
        let mult = 1u32 << (attempt - 1).min(16);
        self.backoff_base.saturating_mul(mult).min(self.backoff_cap)
    }
}

/// Can this (config, strategy, global batch) combination run at world
/// size `n`? The shrink path walks down to the largest `n` this accepts:
/// batch-sharding engines need `global_batch % n == 0`, tensor-sharding
/// engines additionally need every partitioned dimension divisible.
pub fn world_size_ok(cfg: &ModelCfg, strategy: Strategy, global_batch: usize, n: usize) -> bool {
    if n == 0 {
        return false;
    }
    let dims_ok = cfg.heads % n == 0
        && cfg.hidden % n == 0
        && cfg.ffn % n == 0
        && cfg.vocab % n == 0;
    match strategy {
        Strategy::Single => n == 1,
        Strategy::Ddp | Strategy::Fsdp => global_batch % n == 0,
        Strategy::MegatronTp => dims_ok,
        Strategy::RtpInplace | Strategy::RtpOutOfPlace => {
            global_batch % n == 0 && dims_ok && (cfg.experts == 0 || cfg.experts % n == 0)
        }
    }
}

/// Largest valid world size strictly below `n` — the shrink target.
fn shrink_target(cfg: &ModelCfg, strategy: Strategy, global_batch: usize, n: usize) -> Result<usize> {
    (1..n)
        .rev()
        .find(|&cand| world_size_ok(cfg, strategy, global_batch, cand))
        .ok_or_else(|| {
            anyhow!(
                "no valid world size below {n} for {strategy} on {} \
                 (global batch {global_batch}) — cannot shrink",
                cfg.name
            )
        })
}

/// One recovery, as observed by the supervisor (the detection → quiesce
/// → rebuild → restore methodology EXPERIMENTS.md reports on).
#[derive(Debug, Clone)]
pub struct RecoveryEvent {
    /// Global step index (0-based) of the step the failure surfaced in.
    pub at_step: u64,
    pub failed_rank: usize,
    /// The typed failure, rendered (`rank R failed (injected at ...)`).
    pub failure: String,
    pub from_workers: usize,
    pub to_workers: usize,
    /// The snapshot step training resumed from (steps in
    /// `(resumed_from_step, at_step]` are replayed).
    pub resumed_from_step: u64,
    pub backoff: Duration,
    /// Poisoned-engine teardown + build at N′.
    pub rebuild: Duration,
    /// RTPC2 re-shard restore (`load_full` per moment + params).
    pub restore: Duration,
    /// Detection-to-resumed total (includes the backoff).
    pub total: Duration,
}

/// The supervised run's outcome.
#[derive(Debug, Clone)]
pub struct SupervisorReport {
    /// Per-step losses in GLOBAL step order. Replayed steps overwrite —
    /// the curve is the recovered trajectory, identical to a fresh
    /// resume at N′.
    pub losses: Vec<f32>,
    pub recoveries: Vec<RecoveryEvent>,
    pub final_workers: usize,
    pub steps: u64,
    pub ckpt: CkptStats,
}

/// The elastic training driver: owns engine, optimizer, corpus and
/// snapshots; recovers in-process from typed rank failures. See the
/// module docs for the recovery sequence.
pub struct Supervisor {
    opts: EngineOpts,
    opt_kind: OptimizerKind,
    lr: f32,
    policy: RecoveryPolicy,
    /// Snapshot cadence in steps (a step-0 seed snapshot is always
    /// taken, so recovery is possible before the first periodic one).
    ckpt_every: u64,
    /// Async writer target; `None` keeps snapshots in memory only.
    ckpt_path: Option<PathBuf>,
    /// Incarnation-indexed fault plans (test hook): plans[i] arms the
    /// engine built for incarnation i. Empty = `opts.fault_plan` for
    /// incarnation 0, nothing after — a recovered cluster must NOT
    /// re-arm the plan that killed it, or recovery would loop until the
    /// budget is spent.
    fault_plans: Vec<Option<FaultPlan>>,
    quiet: bool,
}

impl Supervisor {
    pub fn new(opts: EngineOpts, opt_kind: OptimizerKind, lr: f32) -> Supervisor {
        let policy = opts.recovery.clone().unwrap_or_else(RecoveryPolicy::from_env);
        Supervisor {
            opts,
            opt_kind,
            lr,
            policy,
            ckpt_every: 10,
            ckpt_path: None,
            fault_plans: Vec::new(),
            quiet: true,
        }
    }

    pub fn policy(mut self, p: RecoveryPolicy) -> Supervisor {
        self.policy = p;
        self
    }

    pub fn ckpt_every(mut self, every: u64) -> Supervisor {
        self.ckpt_every = every;
        self
    }

    pub fn ckpt_path(mut self, path: Option<PathBuf>) -> Supervisor {
        self.ckpt_path = path;
        self
    }

    /// Test hook: arm fault plan `plans[i]` on the engine of incarnation
    /// `i` (0 = the initial build; double-fault coverage arms a second
    /// plan on the rebuilt cluster).
    pub fn fault_plans(mut self, plans: Vec<Option<FaultPlan>>) -> Supervisor {
        self.fault_plans = plans;
        self
    }

    pub fn quiet(mut self, q: bool) -> Supervisor {
        self.quiet = q;
        self
    }

    fn plan_for(&self, incarnation: usize) -> Option<FaultPlan> {
        if self.fault_plans.is_empty() {
            if incarnation == 0 {
                self.opts.fault_plan
            } else {
                None
            }
        } else {
            self.fault_plans.get(incarnation).copied().flatten()
        }
    }

    /// Run `steps` training steps, recovering from rank failures per the
    /// policy. Never hangs: failure detection is the fabric's bounded
    /// poison/watchdog path, the retry budget is `max_recoveries`, and
    /// each recovery cycle must finish inside `rebuild_budget`.
    pub fn run(&mut self, steps: u64) -> Result<SupervisorReport> {
        if self.opts.launcher == Launcher::Process {
            bail!(
                "the elastic supervisor drives in-process launchers only: the \
                 optimizer walks engine-owned params (visit_owned), which cannot \
                 cross a process boundary. Process-mode recovery (respawn into \
                 the live rendezvous) is ProcessClusterEngine::rebuild."
            );
        }
        let cfg = self.opts.cfg()?;
        let gb = self.opts.global_batch;
        let mut incarnation = 0usize;
        let mut opts = self.opts.clone();
        opts.fault_plan = self.plan_for(incarnation);
        let mut engine = build_engine(&opts)?;
        let mut opt = Optimizer::new(self.opt_kind, self.lr);
        opt.attach(&mut *engine)?;
        let mut corpus = MarkovCorpus::new(&cfg, opts.seed);
        let mut writer = self.ckpt_path.as_ref().map(|p| AsyncCheckpointer::new(p));

        // the step-0 seed snapshot: recovery is possible from the start
        let mut latest: Arc<TrainState> =
            Arc::new(capture_train_state(&mut *engine, &opt, &corpus, 0)?);
        if let Some(w) = writer.as_mut() {
            w.submit(Arc::clone(&latest));
        }

        let mut step = 0u64;
        let mut losses: Vec<f32> = Vec::with_capacity(steps as usize);
        let mut recoveries: Vec<RecoveryEvent> = Vec::new();
        while step < steps {
            let batch = corpus.next_batch(gb);
            engine.zero_grads();
            match engine.step(&batch) {
                Ok(loss) => {
                    opt.step(&mut *engine);
                    step += 1;
                    losses.push(loss);
                    if self.ckpt_every > 0 && step % self.ckpt_every == 0 && step < steps {
                        latest =
                            Arc::new(capture_train_state(&mut *engine, &opt, &corpus, step)?);
                        if let Some(w) = writer.as_mut() {
                            w.submit(Arc::clone(&latest));
                        }
                    }
                }
                Err(e) => {
                    let failure = match e.downcast::<RankFailure>() {
                        Ok(f) => f,
                        // non-failure step errors (OOM & co.) are not
                        // recoverable-by-rebuild: propagate untouched
                        Err(other) => return Err(other),
                    };
                    let t0 = Instant::now();
                    let attempt = recoveries.len() as u32 + 1;
                    if attempt > self.policy.max_recoveries {
                        return Err(anyhow::Error::new(failure).context(format!(
                            "recovery budget exhausted ({} recoveries allowed): \
                             rank failed again at step {step}",
                            self.policy.max_recoveries
                        )));
                    }
                    // quiesce: the poison path drained lanes and
                    // returned pooled buffers inside the failed round —
                    // verify nothing is left in flight
                    let in_flight = engine.ctx().cluster.fabric().in_flight();
                    if in_flight != 0 {
                        bail!(
                            "quiesce after rank failure left {in_flight} fabric \
                             messages in flight (poison drain regressed): {failure}"
                        );
                    }
                    let from_n = engine.ctx().cluster.n();
                    let backoff = self.policy.backoff(attempt);
                    std::thread::sleep(backoff);
                    let to_n = match self.policy.mode {
                        RecoveryMode::Respawn => from_n,
                        RecoveryMode::Shrink => {
                            shrink_target(&cfg, opts.strategy, gb, from_n)?
                        }
                    };
                    // teardown: dropping the facade drops every rank
                    // body and the poisoned RingFabric
                    let t_build = Instant::now();
                    drop(engine);
                    incarnation += 1;
                    opts.workers = to_n;
                    opts.fault_plan = self.plan_for(incarnation);
                    engine = build_engine(&opts)?;
                    let rebuild = t_build.elapsed();
                    // restore the latest snapshot — the exact `--resume`
                    // path, so the continuation is bit-identical to a
                    // fresh resume at N′
                    let t_restore = Instant::now();
                    opt = Optimizer::new(self.opt_kind, self.lr);
                    corpus = restore_train_state(&mut *engine, &mut opt, &cfg, &latest)
                        .with_context(|| {
                            format!("restoring step-{} snapshot at N'={to_n}", latest.step)
                        })?;
                    opt.attach(&mut *engine)?;
                    engine.set_step_base(latest.step);
                    let restore = t_restore.elapsed();
                    let resumed_from = latest.step;
                    losses.truncate(resumed_from as usize);
                    let total = t0.elapsed();
                    if total > self.policy.rebuild_budget {
                        bail!(
                            "recovery exceeded its budget: {total:?} > {:?} \
                             (detect -> quiesce -> rebuild -> restore)",
                            self.policy.rebuild_budget
                        );
                    }
                    if !self.quiet {
                        println!(
                            "recovered from [{failure}] at step {step}: {from_n} -> {to_n} \
                             workers ({}), resumed from step {resumed_from} \
                             (backoff {backoff:?}, rebuild {rebuild:?}, restore {restore:?})",
                            self.policy.mode
                        );
                    }
                    recoveries.push(RecoveryEvent {
                        at_step: step,
                        failed_rank: failure.failed_rank,
                        failure: failure.to_string(),
                        from_workers: from_n,
                        to_workers: to_n,
                        resumed_from_step: resumed_from,
                        backoff,
                        rebuild,
                        restore,
                        total,
                    });
                    step = resumed_from;
                }
            }
        }
        let final_workers = engine.ctx().cluster.n();
        // the final state is also the final checkpoint (crash-atomic):
        // drain the writer and surface any write error
        let ckpt = match writer {
            Some(mut w) => {
                latest = Arc::new(capture_train_state(&mut *engine, &opt, &corpus, step)?);
                // blocking variant: the run's LAST snapshot must never be
                // dropped by a busy writer — it is the resume point
                w.submit_final(Arc::clone(&latest));
                w.finish()?
            }
            None => CkptStats::default(),
        };
        Ok(SupervisorReport { losses, recoveries, final_workers, steps, ckpt })
    }

    /// The engine+optimizer state at the end of a [`run`](Self::run) is
    /// consumed internally; tests compare trajectories through the final
    /// snapshot instead. Run, then return (report, final state).
    pub fn run_capturing(&mut self, steps: u64) -> Result<(SupervisorReport, TrainState)> {
        // re-run with an extra capture at the end: cheapest is to run
        // and capture inside run(); instead expose via a fresh capture
        // from the kept latest snapshot path. For bit-exact final-state
        // assertions, run() already captures `latest` at `steps` when a
        // writer exists; without one we re-run the capture here.
        let report = self.run(steps)?;
        match &self.ckpt_path {
            Some(p) => {
                let cfg = self.opts.cfg()?;
                let state = crate::train::load_train_state(&cfg, p)?;
                Ok((report, state))
            }
            None => bail!("run_capturing needs a ckpt_path to read the final state back"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parses_fields_in_any_order() {
        let p = RecoveryPolicy::parse("max=5, mode=respawn ,backoff_ms=1,budget_ms=2000").unwrap();
        assert_eq!(p.mode, RecoveryMode::Respawn);
        assert_eq!(p.max_recoveries, 5);
        assert_eq!(p.backoff_base, Duration::from_millis(1));
        assert_eq!(p.rebuild_budget, Duration::from_secs(2));
        // unset fields keep defaults
        assert_eq!(p.backoff_cap, RecoveryPolicy::default().backoff_cap);
    }

    #[test]
    fn policy_rejects_malformed_specs() {
        assert!(RecoveryPolicy::parse("mode=sideways").is_err());
        assert!(RecoveryPolicy::parse("max=x").is_err());
        assert!(RecoveryPolicy::parse("bogus").is_err());
        assert!(RecoveryPolicy::parse("tempo=3").is_err());
    }

    #[test]
    fn backoff_doubles_and_caps() {
        let p = RecoveryPolicy {
            backoff_base: Duration::from_millis(10),
            backoff_cap: Duration::from_millis(35),
            ..Default::default()
        };
        assert_eq!(p.backoff(1), Duration::from_millis(10));
        assert_eq!(p.backoff(2), Duration::from_millis(20));
        assert_eq!(p.backoff(3), Duration::from_millis(35)); // capped
        assert_eq!(p.backoff(30), Duration::from_millis(35)); // shift-safe
    }

    #[test]
    fn shrink_target_respects_divisibility() {
        let cfg = crate::config::presets::get("tiny").unwrap();
        // batch-sharding engines only need gb % n == 0
        assert_eq!(shrink_target(&cfg, Strategy::Ddp, 12, 4).unwrap(), 3);
        assert_eq!(shrink_target(&cfg, Strategy::Fsdp, 8, 4).unwrap(), 2);
        // tensor-sharding engines also need the partitioned dims to divide
        let t = shrink_target(&cfg, Strategy::RtpInplace, 8, 4).unwrap();
        assert!(world_size_ok(&cfg, Strategy::RtpInplace, 8, t));
        assert!(t < 4);
        // single cannot shrink below 1
        assert!(shrink_target(&cfg, Strategy::Single, 4, 1).is_err());
    }
}
