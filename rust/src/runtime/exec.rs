//! Executor dispatch + the `Buf` storage abstraction.
//!
//! `Buf` is the engines' universal buffer: `Real` (f32 host tensor) /
//! `Ids` (i32) carry data in real mode; `Virt` carries only a shape in
//! virtual mode (paper-scale accounting runs, DESIGN.md §4 "Execution
//! model"). The SAME engine code path allocates, communicates and frees
//! either kind — which is the argument that the measured figures are
//! properties of the schedule.
//!
//! `Exec` dispatches an op call to one of three backends:
//! - `Pjrt`     — the production path: AOT'd HLO on the PJRT CPU client;
//! - `Oracle`   — pure-rust reference (tests without artifacts, and the
//!                independent numeric cross-check of the HLO path);
//! - `Virtual`  — no compute at all; outputs are shape stubs.

use anyhow::{bail, Result};

use crate::config::ModelCfg;
use crate::model::oracle;
use crate::model::ops::{self, Op};
use crate::tensor::{numel, HostTensor, IntTensor};

use super::client::{PjrtRuntime, RtArg};

/// Engine-visible storage.
#[derive(Debug, Clone)]
pub enum Buf {
    /// Real f32 data.
    Real(HostTensor),
    /// Real i32 data (token ids / targets).
    Ids(IntTensor),
    /// Shape-only stub (virtual mode).
    Virt(Vec<usize>),
}

impl Buf {
    pub fn shape(&self) -> &[usize] {
        match self {
            Buf::Real(t) => &t.shape,
            Buf::Ids(t) => &t.shape,
            Buf::Virt(s) => s,
        }
    }

    pub fn bytes(&self) -> u64 {
        (numel(self.shape()).max(1) * 4) as u64
    }

    pub fn is_virtual(&self) -> bool {
        matches!(self, Buf::Virt(_))
    }

    /// Unwrap real f32 data (panics on type confusion — engine bug).
    pub fn f(&self) -> &HostTensor {
        match self {
            Buf::Real(t) => t,
            other => panic!("expected Real buf, got {:?}", other.shape_kind()),
        }
    }

    pub fn f_mut(&mut self) -> &mut HostTensor {
        match self {
            Buf::Real(t) => t,
            other => panic!("expected Real buf, got {:?}", other.shape_kind()),
        }
    }

    pub fn ids(&self) -> &IntTensor {
        match self {
            Buf::Ids(t) => t,
            other => panic!("expected Ids buf, got {:?}", other.shape_kind()),
        }
    }

    fn shape_kind(&self) -> (&'static str, &[usize]) {
        match self {
            Buf::Real(_) => ("real", self.shape()),
            Buf::Ids(_) => ("ids", self.shape()),
            Buf::Virt(_) => ("virt", self.shape()),
        }
    }

    /// Real zeros of the same shape class as self would require; used by
    /// accumulators. In virtual mode returns a stub.
    pub fn zeros_like_mode(virtual_mode: bool, shape: &[usize]) -> Buf {
        if virtual_mode {
            Buf::Virt(shape.to_vec())
        } else {
            Buf::Real(HostTensor::zeros(shape))
        }
    }
}

/// A borrowed op argument: real f32 / real i32 / virtual placeholder.
/// Engines pass weight tensors and activation bufs without cloning; in
/// virtual mode every arg is `V` and the executor ignores them.
#[derive(Debug, Clone, Copy)]
pub enum ArgRef<'a> {
    F(&'a HostTensor),
    I(&'a IntTensor),
    V,
}

impl Buf {
    pub fn arg(&self) -> ArgRef<'_> {
        match self {
            Buf::Real(t) => ArgRef::F(t),
            Buf::Ids(t) => ArgRef::I(t),
            Buf::Virt(_) => ArgRef::V,
        }
    }
}

/// Wrap an optional real tensor (None in virtual mode).
pub fn arg_of(t: Option<&HostTensor>) -> ArgRef<'_> {
    t.map(ArgRef::F).unwrap_or(ArgRef::V)
}

/// Which compute backend the engines drive.
pub enum Exec {
    Pjrt(Box<PjrtRuntime>),
    /// Like `Pjrt` but routes through the Pallas-kernel artifact set
    /// (keys with the `__pallas` suffix) where available.
    PjrtPallas(Box<PjrtRuntime>),
    Oracle,
    Virtual,
}

impl Exec {
    pub fn is_virtual(&self) -> bool {
        matches!(self, Exec::Virtual)
    }

    pub fn name(&self) -> &'static str {
        match self {
            Exec::Pjrt(_) => "pjrt",
            Exec::PjrtPallas(_) => "pjrt-pallas",
            Exec::Oracle => "oracle",
            Exec::Virtual => "virtual",
        }
    }

    /// Run `op` at local batch `b`, partition `p`. Args in artifact order;
    /// outputs in artifact order (virtual mode: shape stubs).
    pub fn call(
        &mut self,
        op: Op,
        cfg: &ModelCfg,
        b: usize,
        p: usize,
        args: &[ArgRef],
    ) -> Result<Vec<Buf>> {
        // batch-only ops are compiled at p=1 (aot.py convention)
        let eff_p = if op.batch_only() { 1 } else { p };
        match self {
            Exec::Virtual => Ok(ops::output_shapes(op, cfg, b, eff_p)
                .into_iter()
                .map(Buf::Virt)
                .collect()),
            Exec::Oracle => {
                let oargs: Vec<oracle::Arg> = args
                    .iter()
                    .map(|a| match a {
                        ArgRef::F(t) => Ok(oracle::Arg::F(t)),
                        ArgRef::I(t) => Ok(oracle::Arg::I(t)),
                        ArgRef::V => bail!("oracle executor got a virtual arg"),
                    })
                    .collect::<Result<_>>()?;
                Ok(oracle::run(op, cfg, eff_p, &oargs)
                    .into_iter()
                    .map(Buf::Real)
                    .collect())
            }
            Exec::Pjrt(rt) => Self::call_pjrt(rt, false, op, b, eff_p, args),
            Exec::PjrtPallas(rt) => Self::call_pjrt(rt, true, op, b, eff_p, args),
        }
    }

    fn call_pjrt(
        rt: &mut PjrtRuntime,
        pallas: bool,
        op: Op,
        b: usize,
        eff_p: usize,
        args: &[ArgRef],
    ) -> Result<Vec<Buf>> {
        {
            {
                let mut key = op.artifact_key(b, eff_p, pallas);
                if pallas && !rt.manifest.entries.contains_key(&key) {
                    // the pallas artifact set only covers the hot shard
                    // combos (aot.py); fall back to the plain lowering
                    key = op.artifact_key(b, eff_p, false);
                }
                let rargs: Vec<RtArg> = args
                    .iter()
                    .map(|a| match a {
                        ArgRef::F(t) => Ok(RtArg::F(t)),
                        ArgRef::I(t) => Ok(RtArg::I(t)),
                        ArgRef::V => bail!("pjrt executor got a virtual arg"),
                    })
                    .collect::<Result<_>>()?;
                Ok(rt.run(&key, &rargs)?.into_iter().map(Buf::Real).collect())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::util::rng::Rng;

    #[test]
    fn virtual_exec_returns_shapes_only() {
        let cfg = presets::get("tiny").unwrap();
        let mut ex = Exec::Virtual;
        let outs = ex
            .call(Op::MlpFwd, &cfg, 2, 2, &[ArgRef::V, ArgRef::V, ArgRef::V, ArgRef::V])
            .unwrap();
        assert_eq!(outs.len(), 1);
        assert!(outs[0].is_virtual());
        assert_eq!(outs[0].shape(), &[2, cfg.seq, cfg.hidden]);
    }

    #[test]
    fn oracle_exec_matches_direct_oracle() {
        let cfg = presets::get("tiny").unwrap();
        let mut rng = Rng::new(5);
        let x = HostTensor::randn(&[1, cfg.seq, cfg.hidden], 1.0, &mut rng);
        let g = HostTensor::randn(&[cfg.hidden], 0.5, &mut rng);
        let b = HostTensor::randn(&[cfg.hidden], 0.5, &mut rng);
        let mut ex = Exec::Oracle;
        let outs = ex
            .call(Op::LnFwd, &cfg, 1, 1, &[ArgRef::F(&x), ArgRef::F(&g), ArgRef::F(&b)])
            .unwrap();
        let want = oracle::ln_fwd(&x, &g, &b);
        assert_eq!(outs[0].f(), &want);
    }

    #[test]
    fn oracle_rejects_virtual_bufs() {
        let cfg = presets::get("tiny").unwrap();
        let mut ex = Exec::Oracle;
        assert!(ex
            .call(Op::LnFwd, &cfg, 1, 1, &[ArgRef::V, ArgRef::V, ArgRef::V])
            .is_err());
    }

    #[test]
    fn buf_bytes_counts_f32() {
        assert_eq!(Buf::Virt(vec![2, 3]).bytes(), 24);
        assert_eq!(Buf::Virt(vec![]).bytes(), 4); // scalar
        let t = HostTensor::zeros(&[4]);
        assert_eq!(Buf::Real(t).bytes(), 16);
    }
}
