//! Deterministic fault injection: kill a chosen rank at a chosen step and
//! phase, and the typed failure every survivor observes.
//!
//! The harness simulates the production failure mode — a rank process
//! dying mid-step — without any of the orderly-abort courtesy of the
//! `Result` path: the injected death is a panic with a [`RankDeath`]
//! payload, thrown at one of the instrumented *fault points* (forward,
//! backward, a rotation hop, a collective hop — including on the
//! background comm thread, or a serving decode step). The fabric's round
//! wrapper recognizes the payload, records a typed [`RankFailure`] in the
//! round control block and poisons the round, so every surviving rank
//! unwinds to the step barrier where the facade surfaces ONE typed error
//! instead of a watchdog panic or a hang.
//!
//! Determinism contract: a fault point is a pure comparison against the
//! plan — it touches no RNG and no data — so a [`FaultPlan`] that never
//! matches (or no plan at all) leaves every trajectory bit-identical to
//! an uninjected run. Asserted in `tests/fault_tolerance.rs`.
//!
//! Select a plan per engine via `EngineOpts::fault_plan` /
//! `ServeOpts::fault_plan`, or process-wide via the `RTP_FAULT_PLAN`
//! environment variable (`rank=1,step=3,phase=backward`).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

/// Where in a step the injected death fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultPhase {
    /// At the top of the rank's forward pass.
    Forward,
    /// At the top of the rank's backward pass.
    Backward,
    /// Right before an RTP weight-rotation hop.
    RotationHop,
    /// Right before a background-engine collective hop: on the dedicated
    /// comm thread under `Launcher::Thread`, at the deterministic
    /// execute-at-issue point under `Launcher::Lockstep`.
    CollectiveHop,
    /// At the top of a serving decode step.
    Decode,
}

impl FaultPhase {
    pub fn parse(s: &str) -> Option<FaultPhase> {
        Some(match s {
            "forward" => FaultPhase::Forward,
            "backward" => FaultPhase::Backward,
            "rotation" => FaultPhase::RotationHop,
            "collective" => FaultPhase::CollectiveHop,
            "decode" => FaultPhase::Decode,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            FaultPhase::Forward => "forward",
            FaultPhase::Backward => "backward",
            FaultPhase::RotationHop => "rotation",
            FaultPhase::CollectiveHop => "collective",
            FaultPhase::Decode => "decode",
        }
    }
}

impl std::fmt::Display for FaultPhase {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Kill rank `rank` the first time it reaches a `phase` fault point
/// during step `step` (0-based, counted by the engine facade).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultPlan {
    pub rank: usize,
    pub step: u64,
    pub phase: FaultPhase,
}

impl FaultPlan {
    /// Parse `"rank=1,step=3,phase=backward"` (fields in any order).
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let (mut rank, mut step, mut phase) = (None, None, None);
        for field in spec.split(',') {
            let field = field.trim();
            if field.is_empty() {
                continue;
            }
            let (k, v) = field
                .split_once('=')
                .ok_or_else(|| anyhow!("fault plan field {field:?}: expected key=value"))?;
            match k.trim() {
                "rank" => {
                    rank = Some(v.trim().parse::<usize>().map_err(|_| {
                        anyhow!("fault plan rank {v:?}: expected an integer")
                    })?)
                }
                "step" => {
                    step = Some(v.trim().parse::<u64>().map_err(|_| {
                        anyhow!("fault plan step {v:?}: expected an integer")
                    })?)
                }
                "phase" => {
                    phase = Some(FaultPhase::parse(v.trim()).ok_or_else(|| {
                        anyhow!(
                            "fault plan phase {v:?}: expected \
                             forward|backward|rotation|collective|decode"
                        )
                    })?)
                }
                other => bail!("fault plan field {other:?}: expected rank|step|phase"),
            }
        }
        match (rank, step, phase) {
            (Some(rank), Some(step), Some(phase)) => Ok(FaultPlan { rank, step, phase }),
            _ => bail!("fault plan {spec:?}: needs rank=, step= and phase="),
        }
    }

    /// The process-wide plan from `RTP_FAULT_PLAN` (None when unset;
    /// panics on a malformed value so typos do not silently disable the
    /// injection a test asked for).
    pub fn from_env() -> Option<FaultPlan> {
        match std::env::var("RTP_FAULT_PLAN") {
            Ok(s) if s.trim().is_empty() => None,
            Ok(s) => Some(Self::parse(&s).unwrap_or_else(|e| panic!("RTP_FAULT_PLAN: {e}"))),
            Err(_) => None,
        }
    }
}

impl std::fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "rank={},step={},phase={}", self.rank, self.step, self.phase)
    }
}

/// The panic payload of an injected kill. Deliberately NOT an error type:
/// the simulated process death takes no orderly-abort path — the fabric's
/// round wrapper is what notices it, exactly as peers of a dead process
/// would.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RankDeath {
    pub rank: usize,
    pub step: u64,
    pub phase: FaultPhase,
}

/// One engine's shared injection state: the plan plus the facade-owned
/// step counter the fault points compare against. Cloned (`Arc`) into
/// every rank body and every background comm thread.
#[derive(Debug)]
pub struct FaultInjector {
    plan: FaultPlan,
    /// Current step index, stored by the facade at the top of each step.
    /// Starts at a sentinel that matches no plan, so construction-time
    /// fault points (engine init) can never fire.
    step: AtomicU64,
    fired: AtomicBool,
}

const STEP_UNSET: u64 = u64::MAX;

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> Arc<FaultInjector> {
        Arc::new(FaultInjector {
            plan,
            step: AtomicU64::new(STEP_UNSET),
            fired: AtomicBool::new(false),
        })
    }

    pub fn plan(&self) -> FaultPlan {
        self.plan
    }

    /// Facade hook: the 0-based index of the step about to run.
    pub fn begin_step(&self, step: u64) {
        self.step.store(step, Ordering::SeqCst);
    }

    /// Has the planned death already been injected?
    pub fn fired(&self) -> bool {
        self.fired.load(Ordering::SeqCst)
    }

    /// A fault point: dies (panics with a [`RankDeath`] payload) iff this
    /// (rank, phase, current step) is the planned kill and it has not
    /// fired yet. Pure comparison otherwise — bit-identical no-op.
    pub fn fault_point(&self, rank: usize, phase: FaultPhase) {
        if rank != self.plan.rank || phase != self.plan.phase {
            return;
        }
        let step = self.step.load(Ordering::SeqCst);
        if step != self.plan.step || step == STEP_UNSET {
            return;
        }
        if self.fired.swap(true, Ordering::SeqCst) {
            return;
        }
        std::panic::panic_any(RankDeath { rank, step, phase });
    }
}

/// What killed a rank, as recorded by whichever detector saw it first.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FailureKind {
    /// A deterministic injected death ([`FaultInjector`]).
    Injected { phase: FaultPhase },
    /// A peer declared dead by the threaded recv watchdog after the
    /// timeout/retry budget expired (`RTP_FABRIC_TIMEOUT_SECS` ×
    /// (1 + `RTP_FABRIC_RETRIES`)).
    RecvTimeout { retries: u32 },
    /// The rank's background comm thread died.
    CommThread,
    /// The peer's OS PROCESS exited (`Launcher::Process`): detected by
    /// the parent's waitpid (dead-rank marker file) or by EOF on the
    /// link's byte transport — the real-cluster analogue of an injected
    /// kill.
    PeerExit,
}

impl std::fmt::Display for FailureKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FailureKind::Injected { phase } => write!(f, "injected at {phase}"),
            FailureKind::RecvTimeout { retries } => {
                write!(f, "recv timeout after {retries} retries")
            }
            FailureKind::CommThread => f.write_str("comm thread death"),
            FailureKind::PeerExit => f.write_str("peer process exited"),
        }
    }
}

/// The typed rank-death error every SURVIVING rank observes at the step
/// barrier (and the facade returns from `step()`): which rank died, how
/// the death was detected, and the detector's full diagnostic. Recorded
/// first-writer-wins in the fabric's round control block, so secondary
/// stalls caused by the same death never overwrite the root cause.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RankFailure {
    /// The dead rank (for a watchdog detection: the stalled link's
    /// upstream peer — the best identification a survivor has).
    pub failed_rank: usize,
    pub kind: FailureKind,
    /// Detector diagnostic (stalled link, injection plan, ...).
    pub detail: String,
}

impl std::fmt::Display for RankFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "rank {} failed ({}): {}",
            self.failed_rank, self.kind, self.detail
        )
    }
}

impl std::error::Error for RankFailure {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_parses_fields_in_any_order() {
        let p = FaultPlan::parse("phase=rotation, rank=2 ,step=7").unwrap();
        assert_eq!(
            p,
            FaultPlan { rank: 2, step: 7, phase: FaultPhase::RotationHop }
        );
        assert_eq!(p.to_string(), "rank=2,step=7,phase=rotation");
    }

    #[test]
    fn plan_rejects_malformed_specs() {
        assert!(FaultPlan::parse("rank=1,step=2").is_err()); // missing phase
        assert!(FaultPlan::parse("rank=x,step=2,phase=forward").is_err());
        assert!(FaultPlan::parse("rank=1,step=2,phase=sideways").is_err());
        assert!(FaultPlan::parse("bogus").is_err());
    }

    #[test]
    fn fault_point_fires_once_at_the_planned_coordinates() {
        let plan = FaultPlan { rank: 1, step: 3, phase: FaultPhase::Backward };
        let inj = FaultInjector::new(plan);
        // before begin_step nothing fires
        inj.fault_point(1, FaultPhase::Backward);
        inj.begin_step(2);
        inj.fault_point(1, FaultPhase::Backward); // wrong step
        inj.begin_step(3);
        inj.fault_point(0, FaultPhase::Backward); // wrong rank
        inj.fault_point(1, FaultPhase::Forward); // wrong phase
        assert!(!inj.fired());
        let inj2 = inj.clone();
        let death = std::panic::catch_unwind(move || {
            inj2.fault_point(1, FaultPhase::Backward)
        })
        .expect_err("planned fault point must fire");
        let d = death.downcast_ref::<RankDeath>().expect("RankDeath payload");
        assert_eq!((d.rank, d.step, d.phase), (1, 3, FaultPhase::Backward));
        assert!(inj.fired());
        // at most once
        inj.fault_point(1, FaultPhase::Backward);
    }

    #[test]
    fn failure_displays_cause() {
        let f = RankFailure {
            failed_rank: 2,
            kind: FailureKind::Injected { phase: FaultPhase::RotationHop },
            detail: "rank=2,step=1,phase=rotation".into(),
        };
        let s = f.to_string();
        assert!(s.contains("rank 2 failed"));
        assert!(s.contains("injected at rotation"));
    }
}
