//! `Launcher::Process`: one OS process per rank, talking through a byte
//! transport (shm ring or Unix socket) — the launcher that makes overlap
//! and dedup numbers real, because ranks stop sharing an allocator, a
//! page cache, or a panic domain.
//!
//! Topology: the parent (this module's [`ProcessClusterEngine`]) is a
//! pure control plane — it never touches the training data path. It
//! writes a [`RunManifest`] into a fresh rendezvous dir, spawns one
//! re-entrant `rtp worker --manifest M --rank R` child per rank, and
//! drives them over a per-worker Unix control socket with a tiny framed
//! protocol (step / zero-grads / gather / shutdown). The data plane —
//! every rotation hop and collective — runs rank-to-rank over the
//! transport endpoints in the same dir ([`RingFabric::new_remote`]),
//! exactly the lanes the in-process launchers use, minus the shared
//! address space.
//!
//! Failure model: the parent reaps children every poll sweep; a dead
//! child gets a `dead-<rank>` marker file in the rendezvous dir (workers
//! poll it inside blocked recvs) and the step surfaces ONE typed
//! [`RankFailure`] with [`FailureKind::PeerExit`] — the same shape the
//! in-process fault injection produces, so callers handle a real SIGKILL
//! and a simulated one identically. Workers that survive a peer death
//! stay up (they reply with their own typed view) until the parent drops,
//! which shuts down, reaps, and removes the rendezvous dir — transport
//! segments included.
//!
//! Scope: the training data path only. `visit_owned` (the optimizer's
//! in-memory param walk) cannot cross a process boundary and panics;
//! checkpoints move through `gather_params` files instead.

use std::io::{Read, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::os::unix::process::ExitStatusExt;
use std::path::{Path, PathBuf};
use std::process::{Child, Command};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::cli::Args;
use crate::cluster::{Cluster, TraceLog};
use crate::comm::transport::{shm_base_dir, unique_endpoint_dir};
use crate::comm::{RingFabric, SchedPolicy, TransportKind};
use crate::config::{ParallelCfg, Strategy};
use crate::memory::tracker::MemTracker;
use crate::model::ModelParams;
use crate::parallel::builder::{build_rank_engine, make_exec};
use crate::parallel::fsdp::Granularity;
use crate::parallel::{Batch, Ctx, Engine, EngineOpts, ExecKind, Launcher, RankCtx};
use crate::runtime::fault::{FailureKind, FaultInjector, RankDeath, RankFailure};
use crate::runtime::manifest::RunManifest;
use crate::runtime::Exec;
use crate::tensor::{HostTensor, IntTensor};
use crate::train::{load_params, save_params};

// ---------------------------------------------------------------------------
// Control protocol: [op u8][len u32 le][payload]
// ---------------------------------------------------------------------------

const OP_STEP: u8 = 1;
const OP_ZERO: u8 = 2;
const OP_GATHER_P: u8 = 3;
const OP_GATHER_G: u8 = 4;
const OP_SHUTDOWN: u8 = 5;
/// Elastic recovery: `[new_rank u32 le][utf8 path of the epoch manifest]`.
/// The worker drops its (possibly poisoned) fabric and rank engine,
/// reloads the manifest, re-rendezvouses in the new epoch's fabric dir,
/// restores from `init_params`, and sends a fresh READY.
const OP_REBUILD: u8 = 6;
const OP_OK: u8 = 0x80;
const OP_ERR: u8 = 0x81;

/// Bounded exponential backoff for rendezvous/connect polling: sleeps
/// 1ms, 2ms, 4ms, ... capped at 50ms, until the budget is spent. The
/// caller turns exhaustion (`wait() == false`) into its own timeout
/// error naming what never showed up.
struct Backoff {
    deadline: Instant,
    cur: Duration,
}

impl Backoff {
    fn new(budget: Duration) -> Backoff {
        Backoff {
            deadline: Instant::now() + budget,
            cur: Duration::from_millis(1),
        }
    }

    /// Sleep one interval and double it; `false` once the budget is gone.
    fn wait(&mut self) -> bool {
        if Instant::now() >= self.deadline {
            return false;
        }
        std::thread::sleep(self.cur);
        self.cur = (self.cur * 2).min(Duration::from_millis(50));
        true
    }
}

/// Where epoch `e`'s fabric rendezvouses: the run dir itself for the
/// initial epoch, `ep<e>/` under it after an elastic rebuild. Dead-rank
/// markers must land in the CURRENT epoch's dir — that is what live
/// recv loops poll.
fn fab_dir(dir: &Path, epoch: u64) -> PathBuf {
    if epoch == 0 {
        dir.to_path_buf()
    } else {
        dir.join(format!("ep{epoch}"))
    }
}

/// `write_all` that rides out `WouldBlock` (the parent's control sockets
/// are nonblocking for the reply poll loop; frames are small).
fn send_all(s: &mut UnixStream, mut buf: &[u8]) -> std::io::Result<()> {
    while !buf.is_empty() {
        match s.write(buf) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::WriteZero,
                    "control socket closed",
                ))
            }
            Ok(k) => buf = &buf[k..],
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_micros(200));
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

fn send_frame(s: &mut UnixStream, op: u8, payload: &[u8]) -> std::io::Result<()> {
    let mut hdr = [0u8; 5];
    hdr[0] = op;
    hdr[1..5].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    send_all(s, &hdr)?;
    send_all(s, payload)
}

/// Blocking frame read (worker side — the worker has nothing to do but
/// wait for the next command).
fn read_frame(s: &mut UnixStream) -> std::io::Result<(u8, Vec<u8>)> {
    let mut hdr = [0u8; 5];
    s.read_exact(&mut hdr)?;
    let len = u32::from_le_bytes([hdr[1], hdr[2], hdr[3], hdr[4]]) as usize;
    let mut payload = vec![0u8; len];
    s.read_exact(&mut payload)?;
    Ok((hdr[0], payload))
}

/// One worker's control connection on the parent side: a nonblocking
/// socket plus a reassembly buffer for the poll loop.
struct CtlConn {
    s: UnixStream,
    buf: Vec<u8>,
}

impl CtlConn {
    /// Drain whatever is readable and return one complete frame if the
    /// buffer holds one. `Err(UnexpectedEof)` once the worker hung up
    /// with no complete frame pending.
    fn poll_frame(&mut self) -> std::io::Result<Option<(u8, Vec<u8>)>> {
        let mut eof = false;
        let mut tmp = [0u8; 4096];
        loop {
            match self.s.read(&mut tmp) {
                Ok(0) => {
                    eof = true;
                    break;
                }
                Ok(k) => self.buf.extend_from_slice(&tmp[..k]),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
        }
        if self.buf.len() >= 5 {
            let len = u32::from_le_bytes([
                self.buf[1],
                self.buf[2],
                self.buf[3],
                self.buf[4],
            ]) as usize;
            if self.buf.len() >= 5 + len {
                let op = self.buf[0];
                let payload = self.buf[5..5 + len].to_vec();
                self.buf.drain(..5 + len);
                return Ok(Some((op, payload)));
            }
        }
        if eof {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "worker control socket EOF",
            ));
        }
        Ok(None)
    }
}

// ---------------------------------------------------------------------------
// Batch codec (control plane only; the data plane has its own wire format)
// ---------------------------------------------------------------------------

fn enc_u64(v: u64, out: &mut Vec<u8>) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn enc_int_tensor(t: &IntTensor, out: &mut Vec<u8>) {
    enc_u64(t.shape.len() as u64, out);
    for &d in &t.shape {
        enc_u64(d as u64, out);
    }
    enc_u64(t.data.len() as u64, out);
    for &x in &t.data {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn enc_batch(b: &Batch, out: &mut Vec<u8>) {
    enc_int_tensor(&b.ids, out);
    enc_int_tensor(&b.targets, out);
}

struct Rd<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Rd<'a> {
    fn u64(&mut self) -> Result<u64> {
        let end = self.pos + 8;
        if end > self.b.len() {
            bail!("truncated control payload");
        }
        let mut a = [0u8; 8];
        a.copy_from_slice(&self.b[self.pos..end]);
        self.pos = end;
        Ok(u64::from_le_bytes(a))
    }

    fn int_tensor(&mut self) -> Result<IntTensor> {
        let ndim = self.u64()? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(self.u64()? as usize);
        }
        let len = self.u64()? as usize;
        let end = self.pos + len * 4;
        if end > self.b.len() {
            bail!("truncated control payload");
        }
        let mut data = Vec::with_capacity(len);
        for c in self.b[self.pos..end].chunks_exact(4) {
            data.push(i32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        self.pos = end;
        Ok(IntTensor::from_vec(&shape, data))
    }
}

fn dec_batch(payload: &[u8]) -> Result<Batch> {
    let mut rd = Rd { b: payload, pos: 0 };
    Ok(Batch { ids: rd.int_tensor()?, targets: rd.int_tensor()? })
}

// ---------------------------------------------------------------------------
// Manifest <-> EngineOpts
// ---------------------------------------------------------------------------

fn exec_token(e: ExecKind) -> &'static str {
    match e {
        ExecKind::Oracle => "oracle",
        ExecKind::Virtual => "virtual",
        ExecKind::Pjrt => "pjrt",
        ExecKind::PjrtPallas => "pallas",
    }
}

fn manifest_of(
    opts: &EngineOpts,
    workers: usize,
    transport: TransportKind,
    fabric_timeout_ms: u64,
    fabric_retries_plus1: u64,
) -> RunManifest {
    RunManifest {
        preset: opts.preset.clone(),
        strategy: opts.strategy.to_string(),
        workers,
        global_batch: opts.global_batch,
        exec: exec_token(opts.exec).to_string(),
        seed: opts.seed,
        fsdp_granularity: match opts.fsdp_granularity {
            Granularity::Layer => "layer".to_string(),
            Granularity::Model => "model".to_string(),
        },
        rtp_recycle: opts.rtp_recycle,
        async_rotation: opts.async_rotation,
        sched_policy: opts.sched_policy.name().to_string(),
        bucket_bytes: opts.bucket_bytes.unwrap_or(0),
        transport: transport.name().to_string(),
        fabric_timeout_ms,
        fabric_retries_plus1,
        epoch: 0,
        init_params: String::new(),
    }
}

fn opts_of(m: &RunManifest) -> Result<EngineOpts> {
    let strategy = Strategy::parse(&m.strategy)
        .ok_or_else(|| anyhow!("run manifest: unknown strategy {:?}", m.strategy))?;
    let exec = match m.exec.as_str() {
        "oracle" => ExecKind::Oracle,
        "virtual" => ExecKind::Virtual,
        "pjrt" => ExecKind::Pjrt,
        "pallas" => ExecKind::PjrtPallas,
        other => bail!("run manifest: unknown exec {other:?}"),
    };
    let gran = match m.fsdp_granularity.as_str() {
        "layer" => Granularity::Layer,
        "model" => Granularity::Model,
        other => bail!("run manifest: unknown fsdp granularity {other:?}"),
    };
    let sched = match m.sched_policy.as_str() {
        "fifo" => SchedPolicy::Fifo,
        "round-robin" => SchedPolicy::RoundRobin,
        "priority" => SchedPolicy::Priority,
        other => bail!("run manifest: unknown sched policy {other:?}"),
    };
    let transport = TransportKind::parse(&m.transport)
        .ok_or_else(|| anyhow!("run manifest: unknown transport {:?}", m.transport))?;
    Ok(EngineOpts::new(&m.preset, strategy, m.workers, m.global_batch)
        .exec(exec)
        .seed(m.seed)
        .fsdp_granularity(gran)
        .rtp_recycle(m.rtp_recycle)
        .async_rotation(m.async_rotation)
        .sched_policy(sched)
        .bucket_bytes(if m.bucket_bytes == 0 { None } else { Some(m.bucket_bytes) })
        // worker-local field only; rank construction never consults it
        .launcher(Launcher::Lockstep)
        .transport(transport))
}

// ---------------------------------------------------------------------------
// Parent: the Engine facade over N child processes
// ---------------------------------------------------------------------------

/// The mutable control-plane state, behind one lock so the `&self`
/// gathers of the [`Engine`] trait stay sound.
struct ProcState {
    children: Vec<Option<Child>>,
    ctl: Vec<Option<CtlConn>>,
    /// Parent-detected process deaths, first detector wins.
    dead: Vec<Option<RankFailure>>,
    gather_seq: u64,
    /// The parent's listening control socket, kept alive across elastic
    /// rebuilds so respawned workers handshake into the SAME run.
    listener: UnixListener,
    /// The current epoch's fabric rendezvous dir — where `dead-<rank>`
    /// markers go so blocked recv loops actually see them.
    fab_dir: PathBuf,
    epoch: u64,
}

pub struct ProcessClusterEngine {
    /// Facade bookkeeping only (config, world size). The real per-rank
    /// trackers and fabric live in the children.
    ctx: Ctx,
    name: String,
    n: usize,
    dir: PathBuf,
    /// The epoch-0 manifest; elastic rebuilds clone it with a new world
    /// size / epoch / init checkpoint.
    base_manifest: RunManifest,
    st: Mutex<ProcState>,
    /// How long a step may go without every reply before the control
    /// plane itself gives up (a generous multiple of the data-plane
    /// watchdog, which should always fire first).
    reply_budget: Duration,
}

fn worker_exe() -> Result<PathBuf> {
    if let Ok(p) = std::env::var("RTP_WORKER_EXE") {
        if !p.trim().is_empty() {
            return Ok(PathBuf::from(p));
        }
    }
    std::env::current_exe().context("resolving the rtp worker executable")
}

fn env_timeout_ms() -> u64 {
    std::env::var("RTP_FABRIC_TIMEOUT_SECS")
        .ok()
        .and_then(|s| s.trim().parse::<u64>().ok())
        .map(|s| s * 1000)
        .unwrap_or(20_000)
}

fn env_retries() -> u64 {
    std::env::var("RTP_FABRIC_RETRIES")
        .ok()
        .and_then(|s| s.trim().parse::<u64>().ok())
        .unwrap_or(0)
}

/// Reap dead children: record the typed failure and write the
/// `dead-<rank>` marker the data-plane recv loops poll, so blocked peers
/// unwind with [`FailureKind::PeerExit`] instead of waiting out their
/// watchdog.
fn reap_children(st: &mut ProcState) {
    for r in 0..st.children.len() {
        if st.dead[r].is_some() {
            continue;
        }
        let status = match st.children[r].as_mut() {
            Some(c) => match c.try_wait() {
                Ok(Some(s)) => s,
                _ => continue,
            },
            None => continue,
        };
        let how = match status.signal() {
            Some(sig) => format!("killed by signal {sig}"),
            None => format!("exited with status {}", status.code().unwrap_or(-1)),
        };
        let _ = std::fs::write(st.fab_dir.join(format!("dead-{r}")), how.as_bytes());
        st.dead[r] = Some(RankFailure {
            failed_rank: r,
            kind: FailureKind::PeerExit,
            detail: format!("rank {r} worker process {how} mid-run (Launcher::Process)"),
        });
    }
}

fn first_death(st: &ProcState) -> Option<RankFailure> {
    st.dead.iter().flatten().next().cloned()
}

/// Send `op` to every live worker. A broken control pipe is left for the
/// reply sweep to classify.
fn broadcast(st: &mut ProcState, op: u8, payload: &[u8]) -> Result<()> {
    reap_children(st);
    if let Some(f) = first_death(st) {
        return Err(anyhow::Error::new(f));
    }
    for r in 0..st.ctl.len() {
        if st.dead[r].is_some() {
            continue;
        }
        if let Some(c) = st.ctl[r].as_mut() {
            let _ = send_frame(&mut c.s, op, payload);
        }
    }
    Ok(())
}

/// Collect one reply frame from every rank not known dead. Returns
/// per-rank OK payloads; a parent-detected process death beats any
/// secondary error a surviving worker reported.
fn collect_replies(
    st: &mut ProcState,
    budget: Duration,
) -> Result<Vec<Option<Vec<u8>>>> {
    let n = st.ctl.len();
    let mut out: Vec<Option<Vec<u8>>> = (0..n).map(|_| None).collect();
    let mut errs: Vec<(usize, String)> = Vec::new();
    let mut pending: Vec<usize> = (0..n).filter(|&r| st.dead[r].is_none()).collect();
    let deadline = Instant::now() + budget;
    while !pending.is_empty() {
        reap_children(st);
        pending.retain(|&r| st.dead[r].is_none());
        let mut progressed = false;
        let sweep: Vec<usize> = pending.clone();
        for r in sweep {
            let res = match st.ctl[r].as_mut() {
                Some(c) => c.poll_frame(),
                None => continue,
            };
            match res {
                Ok(Some((op, payload))) => {
                    progressed = true;
                    pending.retain(|&p| p != r);
                    if op == OP_OK {
                        out[r] = Some(payload);
                    } else {
                        errs.push((r, String::from_utf8_lossy(&payload).into_owned()));
                    }
                }
                Ok(None) => {}
                Err(_) => {
                    // EOF without a frame: the process is gone (or going);
                    // reap it so the marker file is written
                    progressed = true;
                    pending.retain(|&p| p != r);
                    reap_children(st);
                    if st.dead[r].is_none() {
                        // hung up but not yet waitable — classify as a
                        // peer exit anyway
                        let _ = std::fs::write(
                            st.fab_dir.join(format!("dead-{r}")),
                            b"control EOF",
                        );
                        st.dead[r] = Some(RankFailure {
                            failed_rank: r,
                            kind: FailureKind::PeerExit,
                            detail: format!(
                                "rank {r} worker closed its control socket \
                                 mid-run (Launcher::Process)"
                            ),
                        });
                    }
                }
            }
        }
        if !progressed {
            if Instant::now() > deadline {
                bail!(
                    "Launcher::Process control protocol stalled: ranks {pending:?} \
                     never replied within {budget:?}"
                );
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    }
    if let Some(f) = first_death(st) {
        return Err(anyhow::Error::new(f));
    }
    if let Some((r, msg)) = errs.into_iter().next() {
        bail!("rank {r}: {msg}");
    }
    Ok(out)
}

impl ProcessClusterEngine {
    /// Build with the ambient watchdog budget (`RTP_FABRIC_TIMEOUT_SECS`
    /// / `RTP_FABRIC_RETRIES` in the workers' inherited env).
    pub fn build(opts: &EngineOpts) -> Result<ProcessClusterEngine> {
        Self::build_with(opts, 0, 0)
    }

    /// Build with an explicit per-worker recv watchdog: `fabric_timeout_ms`
    /// (0 = env default) and `fabric_retries_plus1` (0 = env default,
    /// `v` = v-1 retries) ride to every worker in the run manifest. Test
    /// hook — the fault suite shortens the watchdog without mutating
    /// process-global env.
    pub fn build_with(
        opts: &EngineOpts,
        fabric_timeout_ms: u64,
        fabric_retries_plus1: u64,
    ) -> Result<ProcessClusterEngine> {
        let cfg = opts.cfg()?;
        if opts.strategy == Strategy::Single {
            bail!(
                "Launcher::Process needs at least 2 ranks; the single \
                 engine is one rank by definition"
            );
        }
        let workers = opts.workers;
        if workers < 2 {
            bail!("Launcher::Process needs at least 2 workers, got {workers}");
        }
        // the process launcher NEEDS a byte transport; default the
        // in-process kind up to shm rather than failing
        let transport = match opts.transport {
            TransportKind::Inproc => TransportKind::Shm,
            t => t,
        };

        let dir = unique_endpoint_dir(&shm_base_dir(), "run");
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating run dir {}", dir.display()))?;
        let manifest = manifest_of(
            opts,
            workers,
            transport,
            fabric_timeout_ms,
            fabric_retries_plus1,
        );
        let manifest_path = dir.join("manifest.json");
        manifest.save(&manifest_path)?;

        let listener = UnixListener::bind(dir.join("ctl.sock"))
            .with_context(|| format!("binding control socket in {}", dir.display()))?;
        listener.set_nonblocking(true)?;

        let exe = worker_exe()?;
        let mut children: Vec<Option<Child>> = Vec::with_capacity(workers);
        for r in 0..workers {
            let child = Command::new(&exe)
                .arg("worker")
                .arg("--manifest")
                .arg(&manifest_path)
                .arg("--rank")
                .arg(r.to_string())
                .spawn()
                .with_context(|| format!("spawning worker {r} via {}", exe.display()))?;
            children.push(Some(child));
        }

        let engine = ProcessClusterEngine {
            ctx: Ctx {
                cfg,
                par: ParallelCfg {
                    strategy: opts.strategy,
                    workers,
                    global_batch: opts.global_batch,
                },
                exec: Exec::Virtual,
                cluster: Cluster::new_with_transport(workers, None, TransportKind::Inproc),
                timeline: None,
            },
            name: opts.engine_name(),
            n: workers,
            base_manifest: manifest,
            st: Mutex::new(ProcState {
                children,
                ctl: (0..workers).map(|_| None).collect(),
                dead: (0..workers).map(|_| None).collect(),
                gather_seq: 0,
                listener,
                // epoch 0 rendezvouses in the run dir itself
                fab_dir: dir.clone(),
                epoch: 0,
            }),
            dir,
            reply_budget: {
                let t = if fabric_timeout_ms > 0 {
                    fabric_timeout_ms
                } else {
                    env_timeout_ms()
                };
                let retries = if fabric_retries_plus1 > 0 {
                    fabric_retries_plus1 - 1
                } else {
                    env_retries()
                };
                Duration::from_millis(t * (retries + 1) + 30_000)
            },
        };

        {
            let st = &mut *engine.st.lock().unwrap();
            accept_workers(st, &engine.dir, workers, Duration::from_secs(60))?;
            // every worker sends one READY (OP_OK) frame once its fabric
            // has rendezvoused and its rank engine is constructed
            collect_replies(st, Duration::from_secs(300))
                .context("waiting for workers to construct their rank engines")?;
        }
        Ok(engine)
    }

    fn roundtrip(&self, op: u8, payload: &[u8]) -> Result<Vec<Option<Vec<u8>>>> {
        let st = &mut *self.st.lock().unwrap();
        broadcast(st, op, payload)?;
        collect_replies(st, self.reply_budget)
    }

    fn gather(&self, op: u8) -> ModelParams {
        let path = {
            let st = &mut *self.st.lock().unwrap();
            st.gather_seq += 1;
            self.dir.join(format!("gather-{}.ckpt", st.gather_seq))
        };
        let what = if op == OP_GATHER_P { "params" } else { "grads" };
        self.roundtrip(op, path.to_string_lossy().as_bytes())
            .unwrap_or_else(|e| panic!("process gather_{what} failed: {e:#}"));
        let full = load_params(&self.ctx.cfg, &path)
            .unwrap_or_else(|e| panic!("process gather_{what} failed: {e:#}"));
        let _ = std::fs::remove_file(&path);
        full
    }

    /// The rendezvous dir (manifest, control socket, transport endpoints,
    /// dead-rank markers). Test hook.
    pub fn endpoint_dir(&self) -> &Path {
        &self.dir
    }

    /// OS pid of rank `r`'s worker process. Test hook.
    pub fn worker_pid(&self, r: usize) -> Option<u32> {
        self.st.lock().unwrap().children[r].as_ref().map(|c| c.id())
    }

    /// SIGKILL rank `r`'s worker — the real-cluster fault the in-process
    /// injection harness simulates. Test hook. The death is NOT recorded
    /// eagerly: the next step discovers it exactly as it would discover
    /// an external kill (waitpid + dead-rank marker + typed PeerExit).
    pub fn kill_worker(&self, r: usize) {
        let st = &mut *self.st.lock().unwrap();
        if let Some(c) = st.children[r].as_mut() {
            let _ = c.kill();
        }
    }

    /// Where the CURRENT epoch's fabric rendezvouses (== `endpoint_dir`
    /// until the first elastic rebuild). Test hook.
    pub fn current_fabric_dir(&self) -> PathBuf {
        self.st.lock().unwrap().fab_dir.clone()
    }

    /// Elastic recovery epoch (0 until the first rebuild). Test hook.
    pub fn epoch(&self) -> u64 {
        self.st.lock().unwrap().epoch
    }

    /// Current world size (shrinks across elastic rebuilds).
    pub fn world_size(&self) -> usize {
        self.n
    }

    /// Elastic in-run recovery after a [`RankFailure`]: rebuild the run
    /// at world size `new_n`, restarting every surviving worker's rank
    /// engine from the full-params checkpoint at `params` and respawning
    /// fresh `rtp worker` processes for the remaining slots — into the
    /// SAME rendezvous dir, over the SAME control listener.
    ///
    /// Survivors keep their relative order but are compacted to ranks
    /// `0..k`; respawned workers take ranks `k..new_n`. Which OS process
    /// hosts which rank does not matter for bit-identity: every worker
    /// (survivor or fresh) rebuilds its rank engine from the manifest and
    /// restores its shard from `params` via `load_full`, so the
    /// post-recovery trajectory matches a fresh run at `new_n` resumed
    /// from the same checkpoint.
    ///
    /// `new_n` may shrink to the survivor count (or below, if world-size
    /// validity demands it — surplus survivors are shut down) or stay at
    /// the original N (dead ranks respawned). Growing past the original
    /// world size is not supported.
    pub fn rebuild(&mut self, new_n: usize, params: &Path) -> Result<()> {
        if new_n < 2 {
            bail!("Launcher::Process needs at least 2 workers, got {new_n}");
        }
        let st = &mut *self.st.lock().unwrap();
        let old_n = st.children.len();
        if new_n > old_n {
            bail!(
                "elastic rebuild cannot grow past the original world size \
                 ({old_n}), got {new_n}"
            );
        }
        reap_children(st);
        let survivors: Vec<usize> =
            (0..old_n).filter(|&r| st.dead[r].is_none()).collect();
        if survivors.is_empty() {
            bail!("elastic rebuild: no surviving workers");
        }
        let keep: Vec<usize> = survivors.iter().copied().take(new_n).collect();
        // surplus survivors (shrink below the survivor count): orderly
        // shutdown, bounded wait, then force
        for &r in survivors.iter().skip(new_n) {
            if let Some(c) = st.ctl[r].as_mut() {
                let _ = send_frame(&mut c.s, OP_SHUTDOWN, &[]);
            }
            st.ctl[r] = None;
            if let Some(mut child) = st.children[r].take() {
                wait_child(&mut child, Duration::from_secs(5));
            }
        }

        let epoch = st.epoch + 1;
        let fdir = fab_dir(&self.dir, epoch);
        std::fs::create_dir_all(&fdir)
            .with_context(|| format!("creating epoch fabric dir {}", fdir.display()))?;
        let mut m = self.base_manifest.clone();
        m.workers = new_n;
        m.epoch = epoch;
        m.init_params = params.to_string_lossy().into_owned();
        let mpath = self.dir.join(format!("manifest-ep{epoch}.json"));
        m.save(&mpath)?;

        // reindex: kept survivors occupy ranks 0..keep.len() (their old
        // Child + control conn move with them), fresh spawns fill the rest
        let mut children: Vec<Option<Child>> = Vec::with_capacity(new_n);
        let mut ctl: Vec<Option<CtlConn>> = Vec::with_capacity(new_n);
        for &old_r in &keep {
            children.push(st.children[old_r].take());
            ctl.push(st.ctl[old_r].take());
        }
        let exe = worker_exe()?;
        for new_r in keep.len()..new_n {
            let child = Command::new(&exe)
                .arg("worker")
                .arg("--manifest")
                .arg(&mpath)
                .arg("--rank")
                .arg(new_r.to_string())
                .spawn()
                .with_context(|| {
                    format!("respawning worker {new_r} via {}", exe.display())
                })?;
            children.push(Some(child));
            ctl.push(None);
        }
        st.children = children;
        st.ctl = ctl;
        st.dead = (0..new_n).map(|_| None).collect();
        st.epoch = epoch;
        st.fab_dir = fdir;

        // survivors learn their new rank + manifest, drop the poisoned
        // fabric, and re-rendezvous in the epoch dir
        let mut payload = Vec::new();
        for new_r in 0..keep.len() {
            payload.clear();
            payload.extend_from_slice(&(new_r as u32).to_le_bytes());
            payload.extend_from_slice(mpath.to_string_lossy().as_bytes());
            if let Some(c) = st.ctl[new_r].as_mut() {
                send_frame(&mut c.s, OP_REBUILD, &payload)
                    .with_context(|| format!("sending rebuild to rank {new_r}"))?;
            }
        }
        accept_workers(st, &self.dir, new_n, Duration::from_secs(60))?;
        // one READY per rank: survivors after their in-place rebuild,
        // respawned workers after construction + restore
        collect_replies(st, Duration::from_secs(300))
            .context("waiting for rebuilt workers to reconstruct their rank engines")?;

        // facade bookkeeping follows the new world size
        self.n = new_n;
        self.ctx.par.workers = new_n;
        self.ctx.cluster =
            Cluster::new_with_transport(new_n, None, TransportKind::Inproc);
        Ok(())
    }
}

/// Bounded child reap: `try_wait` poll with backoff, SIGKILL + blocking
/// wait once the budget is gone (never leaves a zombie).
fn wait_child(child: &mut Child, budget: Duration) {
    let mut backoff = Backoff::new(budget);
    loop {
        match child.try_wait() {
            Ok(Some(_)) => return,
            _ => {
                if !backoff.wait() {
                    let _ = child.kill();
                    let _ = child.wait();
                    return;
                }
            }
        }
    }
}

/// Accept control-socket handshakes until every rank in `0..n` has a
/// connection (ranks that already hold one — elastic survivors — count
/// as present). Polls with bounded exponential backoff; on timeout the
/// error names exactly which ranks never arrived and where they were
/// expected to rendezvous.
fn accept_workers(
    st: &mut ProcState,
    dir: &Path,
    n: usize,
    budget: Duration,
) -> Result<()> {
    let mut backoff = Backoff::new(budget);
    loop {
        let missing: Vec<usize> = (0..n)
            .filter(|&r| st.ctl[r].is_none() && st.dead[r].is_none())
            .collect();
        if missing.is_empty() {
            return Ok(());
        }
        match st.listener.accept() {
            Ok((mut s, _)) => {
                s.set_nonblocking(false)?;
                s.set_read_timeout(Some(Duration::from_secs(10)))?;
                let mut rank_buf = [0u8; 4];
                s.read_exact(&mut rank_buf)
                    .context("reading worker rank handshake")?;
                let rank = u32::from_le_bytes(rank_buf) as usize;
                if rank >= n || st.ctl[rank].is_some() {
                    bail!("bogus worker handshake for rank {rank}");
                }
                s.set_read_timeout(None)?;
                s.set_nonblocking(true)?;
                st.ctl[rank] = Some(CtlConn { s, buf: Vec::new() });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                // a worker that died before connecting will never show up
                reap_children(st);
                if let Some(r) =
                    (0..n).find(|&r| st.dead[r].is_some() && st.ctl[r].is_none())
                {
                    bail!(
                        "worker {r} died during startup: {}",
                        st.dead[r].as_ref().unwrap()
                    );
                }
                if !backoff.wait() {
                    bail!(
                        "worker rank(s) {missing:?} never connected to the \
                         control socket in rendezvous dir {} within {budget:?}",
                        dir.display()
                    );
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
}

impl Engine for ProcessClusterEngine {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn step(&mut self, batch: &Batch) -> Result<f32> {
        let mut payload = Vec::new();
        enc_batch(batch, &mut payload);
        let replies = self.roundtrip(OP_STEP, &payload)?;
        let mut loss_sum = 0.0f32;
        for (r, reply) in replies.iter().enumerate() {
            let p = reply
                .as_ref()
                .ok_or_else(|| anyhow!("rank {r} sent no step reply"))?;
            if p.len() != 4 {
                bail!("rank {r} step reply malformed ({} bytes)", p.len());
            }
            loss_sum += f32::from_le_bytes([p[0], p[1], p[2], p[3]]);
        }
        Ok(loss_sum / self.n as f32)
    }

    fn gather_params(&self) -> ModelParams {
        self.gather(OP_GATHER_P)
    }

    fn gather_grads(&self) -> ModelParams {
        self.gather(OP_GATHER_G)
    }

    fn visit_owned(&mut self, _f: &mut dyn FnMut(&mut HostTensor, &HostTensor)) {
        panic!(
            "Launcher::Process: visit_owned cannot cross a process boundary. \
             Train under lockstep/thread, or move state through \
             gather_params checkpoints."
        );
    }

    fn zero_grads(&mut self) {
        self.roundtrip(OP_ZERO, &[])
            .unwrap_or_else(|e| panic!("process zero_grads failed: {e:#}"));
    }

    fn load_full(&mut self, _full: &ModelParams) -> Result<()> {
        bail!(
            "Launcher::Process: load_full is not supported — restore \
             checkpoints under an in-process launcher"
        )
    }

    fn ctx(&self) -> &Ctx {
        &self.ctx
    }

    fn ctx_mut(&mut self) -> &mut Ctx {
        &mut self.ctx
    }
}

impl Drop for ProcessClusterEngine {
    fn drop(&mut self) {
        let st = &mut *self.st.lock().unwrap();
        for r in 0..self.n {
            if st.dead[r].is_none() {
                if let Some(c) = st.ctl[r].as_mut() {
                    let _ = send_frame(&mut c.s, OP_SHUTDOWN, &[]);
                }
            }
        }
        let deadline = Instant::now() + Duration::from_secs(5);
        for child in st.children.iter_mut().flatten() {
            loop {
                match child.try_wait() {
                    Ok(Some(_)) => break,
                    Ok(None) if Instant::now() < deadline => {
                        std::thread::sleep(Duration::from_millis(10))
                    }
                    _ => {
                        let _ = child.kill();
                        let _ = child.wait();
                        break;
                    }
                }
            }
        }
        // transport endpoints (shm rings, sockets), manifest, markers —
        // all gone; the fault suite asserts no leaked segments
        let _ = std::fs::remove_dir_all(&self.dir);
    }
}

// ---------------------------------------------------------------------------
// Worker: the `rtp worker` re-entrant mode
// ---------------------------------------------------------------------------

fn connect_ctl(dir: &Path) -> Result<UnixStream> {
    let path = dir.join("ctl.sock");
    let budget = Duration::from_secs(10);
    let mut backoff = Backoff::new(budget);
    loop {
        match UnixStream::connect(&path) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if !backoff.wait() {
                    return Err(e).with_context(|| {
                        format!(
                            "worker could not reach the parent control socket \
                             {} within {budget:?}",
                            path.display()
                        )
                    });
                }
            }
        }
    }
}

fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "rank body panicked".to_string()
    }
}

/// Why one [`worker_serve`] incarnation ended: orderly shutdown, or an
/// elastic rebuild that drops the fabric + engine on scope exit and
/// loops back with a new manifest + rank.
enum ServeExit {
    Shutdown,
    Rebuild { manifest: PathBuf, rank: usize },
}

/// Entry point of `rtp worker --manifest M --rank R`: build this rank's
/// engine from the run manifest, rendezvous the per-process fabric, and
/// serve control commands until shutdown (or parent EOF). `OP_REBUILD`
/// loops: the serve incarnation's whole state — fabric, engine, executor,
/// tracker — drops, and the next incarnation rebuilds from the epoch
/// manifest under a (possibly) new rank.
pub fn worker_main(args: &Args) -> Result<()> {
    let mpath = PathBuf::from(
        args.get("manifest")
            .ok_or_else(|| anyhow!("rtp worker needs --manifest"))?,
    );
    let mut rank: usize = args
        .get("rank")
        .ok_or_else(|| anyhow!("rtp worker needs --rank"))?
        .parse()
        .map_err(|_| anyhow!("--rank expects an integer"))?;
    let mut m = RunManifest::load_run(&mpath)?;
    let dir = mpath
        .parent()
        .ok_or_else(|| anyhow!("manifest path has no parent dir"))?
        .to_path_buf();
    // handshake first, so the parent can tell "slow build" from "dead"
    let mut ctl = connect_ctl(&dir)?;
    ctl.write_all(&(rank as u32).to_le_bytes())?;
    loop {
        let next = worker_serve(&m, rank, &dir, &mut ctl).and_then(|exit| {
            Ok(match exit {
                ServeExit::Shutdown => None,
                ServeExit::Rebuild { manifest, rank } => {
                    Some((RunManifest::load_run(&manifest)?, rank))
                }
            })
        });
        match next {
            Ok(None) => return Ok(()),
            Ok(Some((next_m, next_rank))) => {
                m = next_m;
                rank = next_rank;
            }
            Err(e) => {
                let _ = send_frame(&mut ctl, OP_ERR, format!("{e:#}").as_bytes());
                std::process::exit(101);
            }
        }
    }
}

fn worker_serve(
    m: &RunManifest,
    rank: usize,
    dir: &Path,
    ctl: &mut UnixStream,
) -> Result<ServeExit> {
    let opts = opts_of(m)?;
    let cfg = opts.cfg()?;
    let par = ParallelCfg {
        strategy: opts.strategy,
        workers: m.workers,
        global_batch: m.global_batch,
    };
    let kind = TransportKind::parse(&m.transport)
        .ok_or_else(|| anyhow!("unknown transport {:?}", m.transport))?;
    let fdir = fab_dir(dir, m.epoch);
    let fabric = RingFabric::new_remote(m.workers, rank, kind, &fdir)
        .context("per-process fabric rendezvous")?;
    if m.fabric_timeout_ms > 0 {
        fabric.set_recv_timeout(Some(Duration::from_millis(m.fabric_timeout_ms)));
    }
    if m.fabric_retries_plus1 > 0 {
        fabric.set_recv_retries(Some((m.fabric_retries_plus1 - 1) as u32));
    }
    let port = fabric.port(rank);
    let mut exec = make_exec(opts.exec, &opts.preset)?;
    let mut tracker = MemTracker::new(rank, None);
    let trace = Mutex::new(TraceLog::default());
    let mut engine = build_rank_engine(
        &opts,
        &cfg,
        &par,
        rank,
        &mut exec,
        &mut tracker,
        port.clone(),
        &trace,
    )?;
    // fault plans target the FIRST incarnation only: a rebuilt epoch
    // re-arming the same env plan would fault itself forever
    let injector = if m.epoch == 0 {
        opts.fault_plan.map(FaultInjector::new)
    } else {
        None
    };
    // process ranks are free-running OS processes: comm streams overlap
    // for real whenever the engine asks for async rotation
    let async_comm = m.async_rotation;
    if !m.init_params.is_empty() {
        let full = load_params(&cfg, Path::new(&m.init_params)).with_context(|| {
            format!("loading elastic init checkpoint {}", m.init_params)
        })?;
        engine.load_full(&full)?;
    }

    send_frame(ctl, OP_OK, &[])?; // READY
    let mut steps_done: u64 = 0;
    loop {
        let (op, payload) = match read_frame(ctl) {
            Ok(f) => f,
            // parent gone (dropped, crashed, ^C): exit quietly
            Err(_) => return Ok(ServeExit::Shutdown),
        };
        match op {
            OP_STEP => {
                let batch = dec_batch(&payload)?;
                if let Some(f) = &injector {
                    f.begin_step(steps_done);
                }
                steps_done += 1;
                let res = fabric.run_remote_round(|| {
                    let mut rctx = RankCtx {
                        rank,
                        cfg: &cfg,
                        par: &par,
                        exec: &mut exec,
                        tracker: &mut tracker,
                        port: port.clone(),
                        timeline: None,
                        trace_log: &trace,
                        trace_on: false,
                        async_comm,
                        sched_policy: opts.sched_policy,
                        bucket_bytes: opts.bucket_bytes,
                        fault: injector.clone(),
                    };
                    engine.step_local(&mut rctx, &batch)
                });
                match res {
                    Ok(Ok(loss)) => send_frame(ctl, OP_OK, &loss.to_le_bytes())?,
                    Ok(Err(e)) => send_frame(ctl, OP_ERR, format!("{e:#}").as_bytes())?,
                    Err(p) => {
                        if p.downcast_ref::<RankDeath>().is_some() {
                            // this rank IS the planned casualty: die like
                            // the real process the plan simulates — no
                            // reply, nonzero exit, peers see PeerExit
                            std::process::exit(101);
                        }
                        let msg = fabric
                            .rank_failure()
                            .map(|f| f.to_string())
                            .unwrap_or_else(|| panic_msg(p.as_ref()));
                        send_frame(ctl, OP_ERR, msg.as_bytes())?;
                    }
                }
            }
            OP_ZERO => {
                engine.zero_grads();
                send_frame(ctl, OP_OK, &[])?;
            }
            OP_GATHER_P | OP_GATHER_G => {
                let path =
                    PathBuf::from(String::from_utf8_lossy(&payload).into_owned());
                let res = fabric.run_remote_round(|| {
                    if op == OP_GATHER_P {
                        engine.gather_params_local(&port)
                    } else {
                        engine.gather_grads_local(&port)
                    }
                });
                match res {
                    Ok(full) => {
                        if rank == 0 {
                            save_params(&full, &path)?;
                        }
                        send_frame(ctl, OP_OK, &[])?;
                    }
                    Err(p) => {
                        let msg = fabric
                            .rank_failure()
                            .map(|f| f.to_string())
                            .unwrap_or_else(|| panic_msg(p.as_ref()));
                        send_frame(ctl, OP_ERR, msg.as_bytes())?;
                    }
                }
            }
            OP_REBUILD => {
                if payload.len() < 4 {
                    bail!("malformed rebuild payload ({} bytes)", payload.len());
                }
                let new_rank = u32::from_le_bytes([
                    payload[0], payload[1], payload[2], payload[3],
                ]) as usize;
                let manifest = PathBuf::from(
                    String::from_utf8_lossy(&payload[4..]).into_owned(),
                );
                // returning drops the (possibly poisoned) fabric and this
                // incarnation's engine; the caller rebuilds and READYs
                return Ok(ServeExit::Rebuild { manifest, rank: new_rank });
            }
            OP_SHUTDOWN => {
                let _ = send_frame(ctl, OP_OK, &[]);
                return Ok(ServeExit::Shutdown);
            }
            other => bail!("unknown control op {other}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_codec_roundtrips() {
        let b = Batch {
            ids: IntTensor::from_vec(&[2, 3], vec![1, 2, 3, 4, 5, 6]),
            targets: IntTensor::from_vec(&[2, 3], vec![6, 5, 4, 3, 2, 1]),
        };
        let mut buf = Vec::new();
        enc_batch(&b, &mut buf);
        let back = dec_batch(&buf).unwrap();
        assert_eq!(back.ids.shape, b.ids.shape);
        assert_eq!(back.ids.data, b.ids.data);
        assert_eq!(back.targets.data, b.targets.data);
    }

    #[test]
    fn manifest_opts_roundtrip() {
        let opts = EngineOpts::new("tiny", Strategy::RtpOutOfPlace, 4, 8)
            .seed(7)
            .rtp_recycle(false)
            .async_rotation(false)
            .bucket_bytes(Some(1 << 16))
            .transport(TransportKind::Uds);
        let m = manifest_of(&opts, 4, TransportKind::Uds, 1500, 3);
        let back = opts_of(&m).unwrap();
        assert_eq!(back.preset, "tiny");
        assert_eq!(back.strategy, Strategy::RtpOutOfPlace);
        assert_eq!(back.workers, 4);
        assert_eq!(back.seed, 7);
        assert!(!back.rtp_recycle);
        assert!(!back.async_rotation);
        assert_eq!(back.bucket_bytes, Some(1 << 16));
        assert_eq!(back.transport, TransportKind::Uds);
        assert_eq!(m.fabric_timeout_ms, 1500);
        assert_eq!(m.fabric_retries_plus1, 3);
    }

    #[test]
    fn process_engine_rejects_single() {
        let opts =
            EngineOpts::new("tiny", Strategy::Single, 2, 4).launcher(Launcher::Process);
        assert!(ProcessClusterEngine::build(&opts).is_err());
    }
}
