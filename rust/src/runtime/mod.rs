//! Runtime: AOT artifact loading + PJRT execution + executor dispatch.
//!
//! `manifest` parses the compile-path contract, `client` wraps the PJRT
//! CPU client with an executable cache, `exec` is the three-way dispatch
//! (pjrt / oracle / virtual) every engine computes through.

pub mod client;
pub mod exec;
pub mod manifest;

pub use client::{PjrtRuntime, RtArg, RuntimeStats};
pub use exec::{arg_of, ArgRef, Buf, Exec};
pub use manifest::{artifacts_root, Manifest};
