//! Runtime: AOT artifact loading + PJRT execution + executor dispatch +
//! fault injection + elastic recovery.
//!
//! `manifest` parses the compile-path contract, `client` wraps the PJRT
//! CPU client with an executable cache, `exec` is the three-way dispatch
//! (pjrt / oracle / virtual) every engine computes through, `fault`
//! is the deterministic rank-death harness (plans, injectors, and the
//! typed `RankFailure` surviving ranks observe), and `supervisor` is the
//! elastic driver that recovers a run in-process from those failures.

pub mod client;
pub mod exec;
pub mod fault;
pub mod manifest;
pub mod proc;
pub mod supervisor;

pub use client::{PjrtRuntime, RtArg, RuntimeStats};
pub use exec::{arg_of, ArgRef, Buf, Exec};
pub use fault::{FailureKind, FaultInjector, FaultPhase, FaultPlan, RankDeath, RankFailure};
pub use manifest::{artifacts_root, Manifest, RunManifest};
pub use proc::{worker_main, ProcessClusterEngine};
pub use supervisor::{
    world_size_ok, RecoveryEvent, RecoveryMode, RecoveryPolicy, Supervisor, SupervisorReport,
};
