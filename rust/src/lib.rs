//! RTP: Rethinking Tensor Parallelism with Memory Deduplication — full
//! reproduction (Luo, Zhong & Fox, 2023).
//!
//! Layer-3 coordinator of the three-layer stack: Python/JAX/Pallas author
//! and AOT-compile the compute (Layers 1-2, `python/compile/`), this crate
//! loads the HLO artifacts via PJRT and runs the paper's Rotated Tensor
//! Parallelism plus every baseline it compares against (single-device
//! "idealized computer", DDP, FSDP, Megatron-style TP) on a simulated
//! worker ring with exact memory accounting.
//!
//! Module map (see DESIGN.md §4):
//! - [`config`] — model presets (paper Table 2), strategy/training config
//! - [`tensor`] — host tensors + CPU glue ops
//! - [`memory`] — per-worker allocation tracker + analytic Table-1 model
//! - [`cluster`] — the simulated worker ring: per-worker memory tracker +
//!   `RingPort` fabric endpoint + event trace
//! - [`comm`] — the rank-local ring fabric (`RingFabric`/`RingPort`,
//!   with a separate background lane namespace per link), chunked ring
//!   collectives as resumable per-hop state machines, the BACKGROUND
//!   COLLECTIVE ENGINE (`CollectiveStream`: per-rank comm threads
//!   overlapping multi-hop collectives with compute), the rotation
//!   schedule, the per-hop α-β cost model, and god-view reference
//!   collectives kept only as test oracles
//! - [`flat_param`] — the paper's FlatParameter pack/shard structure (it
//!   moves through the fabric: `allgather_via` / `reduce_scatter_via`)
//! - [`parallel`] — the five engines (single/ddp/fsdp/tp/rtp) as SPMD
//!   per-rank `RankEngine` participants behind a `ClusterEngine` facade,
//!   all communicating exclusively through rank-local fabric ports and
//!   executed by a pluggable `Launcher` (deterministic lockstep
//!   round-robin, or one OS thread per rank)
//! - [`serve`] — continuous-batching generation engine: request queue
//!   with KV-budget admission control, paged head-sharded KV-cache that
//!   rotates with the RTP weight shards, incremental decode steps over
//!   the same launcher/fabric stack
//! - [`perfmodel`] — hardware model + two-stream timeline charging
//!   communication hop by hop
//! - [`util`] — json / rng / stats / prop substrates (offline substitutes)

pub mod bench_util;
pub mod cli;
pub mod cluster;
pub mod comm;
pub mod config;
pub mod flat_param;
pub mod memory;
pub mod model;
pub mod parallel;
pub mod perfmodel;
pub mod runtime;
pub mod serve;
pub mod train;
pub mod tensor;
pub mod util;
