//! Per-rank comm streams: TRUE async rotation (§3.4.3).
//!
//! A [`CommStream`] is one rank's handle for overlapping a rotation hop
//! with the compute that uses the shard being rotated. The paper's claim
//! is that out-of-place RTP *starts computation and communication
//! simultaneously*: the shard a rank computes with this step is, at the
//! same time, already in flight to its clockwise neighbor. On this
//! fabric that is exactly what [`CommStream::begin`] does in async mode —
//! the outgoing payload is enqueued on the neighbor lane BEFORE the
//! compute closure runs, so by the time every rank reaches its step
//! boundary the incoming shard is already sitting in its lane and
//! [`CommStream::wait`] completes without blocking on the upstream
//! neighbor's compute. The lane queue slot is the double-buffered
//! in-flight shard — the `max(W,G)/N` rotation buffer `RtpOutOfPlace`
//! models (and in real mode the payload is an `Arc`, so the in-flight
//! copy DEDUPLICATES against the live shard instead of duplicating it).
//!
//! Under the deterministic `Lockstep` launcher the same API degrades to
//! the classic synchronous boundary hop: `begin` defers the send and
//! `wait` performs send-then-recv exactly where the pre-stream engines
//! did. Because each rank's per-link send order is identical in both
//! modes and every lane is FIFO, the two schedules are BIT-IDENTICAL —
//! asserted for every engine by `tests/launcher_equivalence.rs`.
//!
//! A rank blocked in [`CommStream::wait`] sits in the fabric's threaded
//! `recv`, so it inherits the `RTP_FABRIC_TIMEOUT_SECS` watchdog and a
//! stall is reported with the exact link (rank, edge, ring direction)
//! that never delivered.
//!
//! ## The background collective engine ([`CollectiveStream`])
//!
//! Rotation is a single hop, so eager enqueue suffices; FSDP's prefetch
//! allgather and backward reduce-scatter are MULTI-HOP — hiding them
//! requires someone to keep stepping the hop machine while the rank body
//! computes. A [`CollectiveStream`] is that someone: each rank queues
//! collectives (`issue_allgather` / `issue_reduce_scatter` /
//! `issue_allreduce`, returning joinable [`CollHandle`]s) and, under the
//! Thread launcher, a DEDICATED PER-RANK COMM THREAD executes them over
//! the rank's background lane namespace, so collective hops never
//! interleave with the main thread's rotation traffic on a link. Under
//! Lockstep the same API degrades to deterministic execute-at-join on
//! the caller's thread (draining earlier queued collectives first, so
//! the background lanes see the exact same message order in both modes —
//! the launcher bit-identity argument extends unchanged).
//!
//! ### The hop-level scheduler
//!
//! The comm thread is not a serial pipe: it keeps a SET of in-flight
//! collectives (the `comm/coll.rs` steppers are resumable) and schedules
//! SINGLE HOPS across them under a pluggable [`SchedPolicy`] — `Fifo`
//! reproduces the old convoy exactly, `RoundRobin` rotates across the
//! in-flight set, `Priority` steps latency-critical prefetch allgathers
//! ahead of bandwidth buckets (reduce-scatters / bucketed allreduces).
//! Why any interleaving is safe: collective seq `s` rides background
//! sub-channel `s % BG_SUBCHANNELS` on EVERY rank (the issue discipline
//! below makes seq assignment identical across ranks), a rank steps the
//! collectives of one sub-channel strictly in seq order, and different
//! sub-channels use disjoint link FIFOs — so no rank can ever mis-match
//! a peer's message to the wrong collective, regardless of how policies
//! or timing interleave hops. Results are therefore BIT-IDENTICAL across
//! all policies and both launchers by construction. To stay deadlock-free
//! the scheduler only picks freely among heads whose next incoming
//! message is already waiting (`pending_from`); when nothing is ready it
//! blocks on the OLDEST in-flight collective, which every peer is
//! guaranteed to drive (the convoy order), never on a younger one.
//!
//! Discipline: all ranks must issue the SAME collectives in the SAME
//! order on their streams (symmetric SPMD), and every issued handle must
//! be joined before the step boundary — a joined stream leaves the comm
//! thread idle and the fabric drained. Payload buffers are caller-owned
//! and returned at join, so a persistent rank engine cycles one buffer
//! per collective site across steps: together with the lane pools the
//! whole path performs zero steady-state heap allocations (asserted by
//! `tests/fabric_hotpath.rs`).
//!
//! A comm thread blocked on a stalled link inherits the fabric watchdog;
//! its panic poisons the round, and the rank body blocked in
//! [`CollectiveStream::join`] observes the dead thread and panics with
//! the recorded poison reason instead of hanging.
//!
//! After an ABORTED round (poison / OOM / panic) a stream is dead: its
//! comm thread has unwound (or may still be unwinding while the round
//! teardown flushes the lanes), so the stream — and the rank engine that
//! owns it — must be discarded, not reused for another step. The FABRIC
//! stays reusable (teardown drains it); a fresh engine owns fresh
//! streams. Every in-tree caller already builds a fresh engine after a
//! failed step; this is the contract that keeps that safe.

use std::any::Any;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use super::coll::{CollKind, Collective};
use super::fabric::{RingPort, BG_SUBCHANNELS};
use super::rotation::RotationDir;
use crate::runtime::fault::{FailureKind, FaultInjector, FaultPhase, RankDeath, RankFailure};

/// Which in-flight collective the background comm thread steps next.
/// Selected per engine via `EngineOpts::sched_policy` or globally via
/// `RTP_SCHED_POLICY` (`fifo` | `round-robin` | `priority`). Results are
/// bit-identical across policies (module docs); only the hop
/// interleaving — and with it how much communication hides behind
/// compute — changes. Under Lockstep every policy degrades to the
/// deterministic execute-at-join order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SchedPolicy {
    /// Run each collective to completion in issue order (the convoy —
    /// today's historical behavior, and the baseline the bench compares
    /// against).
    #[default]
    Fifo,
    /// Rotate single hops across the in-flight collectives: every
    /// runnable collective advances before any advances twice.
    RoundRobin,
    /// Prefetch allgathers outrank bucket reductions; ties (and
    /// non-allgathers among themselves) fall back to issue order.
    Priority,
}

impl SchedPolicy {
    /// Read `RTP_SCHED_POLICY`; absent/empty means `Fifo`.
    pub fn from_env() -> SchedPolicy {
        match std::env::var("RTP_SCHED_POLICY").ok().as_deref() {
            None | Some("") | Some("fifo") => SchedPolicy::Fifo,
            Some("round-robin") | Some("roundrobin") | Some("rr") => {
                SchedPolicy::RoundRobin
            }
            Some("priority") | Some("prio") => SchedPolicy::Priority,
            Some(other) => panic!(
                "RTP_SCHED_POLICY={other:?}: unknown policy \
                 (fifo | round-robin | priority)"
            ),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SchedPolicy::Fifo => "fifo",
            SchedPolicy::RoundRobin => "round-robin",
            SchedPolicy::Priority => "priority",
        }
    }
}

/// The background sub-channel collective seq `s` rides on every rank.
fn subchannel_of(seq: u64) -> usize {
    (seq % BG_SUBCHANNELS as u64) as usize
}

/// One rank's rotation stream. Cheap to construct (clones a port handle);
/// `async_mode` decides eager-in-flight vs deferred-synchronous hops.
#[derive(Clone)]
pub struct CommStream {
    port: RingPort,
    async_mode: bool,
}

/// An issued rotation hop, waiting to be joined. Must be `wait`ed before
/// the rotated-in payload is consumed (and before the fabric drain
/// assertion at the step boundary).
#[must_use = "an in-flight rotation must be waited before its shard is consumed"]
pub struct InFlight<T: Any + Send> {
    dir: RotationDir,
    /// Sync mode: the payload still to send at `wait` time. Async mode:
    /// `None` — already on the wire.
    deferred: Option<T>,
}

impl CommStream {
    pub fn new(port: RingPort, async_mode: bool) -> CommStream {
        CommStream { port, async_mode }
    }

    /// Is this stream overlapping hops for real (Thread launcher) rather
    /// than degrading to synchronous boundary hops (Lockstep)?
    pub fn is_async(&self) -> bool {
        self.async_mode
    }

    pub fn port(&self) -> &RingPort {
        &self.port
    }

    /// Issue one rotation hop carrying `item` in direction `dir`.
    ///
    /// Async mode: `item` is enqueued to the downstream neighbor NOW and
    /// travels while the caller computes. Sync mode (and single-rank
    /// rings): the send is deferred to [`CommStream::wait`], reproducing
    /// the deterministic boundary schedule.
    pub fn begin<T: Any + Send>(&self, item: T, dir: RotationDir) -> InFlight<T> {
        let n = self.port.n();
        if self.async_mode && n > 1 {
            let w = self.port.rank();
            self.port.send(dir.send_peer(w, n), item);
            InFlight { dir, deferred: None }
        } else {
            InFlight { dir, deferred: Some(item) }
        }
    }

    /// Join an issued hop: completes the exchange and returns the payload
    /// arriving from the upstream neighbor. On a single-rank ring this is
    /// the identity.
    pub fn wait<T: Any + Send>(&self, inflight: InFlight<T>) -> T {
        let n = self.port.n();
        let w = self.port.rank();
        let InFlight { dir, deferred } = inflight;
        match deferred {
            Some(item) if n <= 1 => item,
            Some(item) => {
                self.port.send(dir.send_peer(w, n), item);
                self.port.recv(dir.recv_peer(w, n))
            }
            None => self.port.recv(dir.recv_peer(w, n)),
        }
    }
}

impl std::fmt::Debug for CommStream {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "CommStream(rank {}/{}, {})",
            self.port.rank(),
            self.port.n(),
            if self.async_mode { "async" } else { "sync" }
        )
    }
}

/// An issued background collective, waiting to be joined. Handles are
/// joined on the stream that issued them; every handle must be joined
/// before the step boundary.
#[must_use = "an issued collective must be joined before the step boundary"]
#[derive(Debug)]
pub struct CollHandle {
    seq: u64,
}

fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// A queued job for the comm thread.
enum Job {
    Run(u64, Collective),
    Shutdown,
}

/// Sync (Lockstep) state: queued-but-unexecuted collectives plus results
/// of collectives drained ahead of their join.
struct SyncQueue {
    next_seq: u64,
    pending: VecDeque<(u64, Collective)>,
    done: HashMap<u64, Vec<f32>>,
}

/// Background (Thread launcher) state: the comm-thread channels.
struct Bg {
    jobs: Mutex<Sender<Job>>,
    results: Mutex<Receiver<(u64, Vec<f32>)>>,
    /// Results received while joining a different handle.
    done: Mutex<HashMap<u64, Vec<f32>>>,
    next_seq: AtomicU64,
    thread: Mutex<Option<JoinHandle<()>>>,
}

enum Inner {
    Sync(Mutex<SyncQueue>),
    Bg(Bg),
}

/// One rank's BACKGROUND COLLECTIVE ENGINE handle (module docs). Create
/// via [`crate::parallel::RankCtx::collectives`] (engines) or
/// [`CollectiveStream::new`] (tests); drop joins the comm thread.
pub struct CollectiveStream {
    /// This rank's background-lane port (sub-channel 0; the comm thread
    /// holds one clone per sub-channel).
    port: RingPort,
    policy: SchedPolicy,
    /// Deterministic fault-injection hook: checked before every collective
    /// hop (on the comm thread in background mode, at execute-at-join in
    /// sync mode), so a planned `CollectiveHop` kill dies exactly where a
    /// real comm-thread death would.
    fault: Option<Arc<FaultInjector>>,
    inner: Inner,
}

impl CollectiveStream {
    /// `background = true` (and N > 1) spawns the dedicated comm thread —
    /// only meaningful when rank bodies run concurrently (Thread
    /// launcher). Otherwise collectives execute at join on the caller's
    /// thread, in issue order. Either way all traffic rides the
    /// background lane namespaces of `port`'s fabric. The hop scheduler
    /// runs under the `RTP_SCHED_POLICY` policy; engines plumb an
    /// explicit choice through [`CollectiveStream::with_policy`].
    pub fn new(port: RingPort, background: bool) -> CollectiveStream {
        CollectiveStream::with_policy(port, background, SchedPolicy::from_env())
    }

    /// [`CollectiveStream::new`] with an explicit hop-scheduling policy.
    pub fn with_policy(
        port: RingPort,
        background: bool,
        policy: SchedPolicy,
    ) -> CollectiveStream {
        CollectiveStream::with_policy_fault(port, background, policy, None)
    }

    /// [`CollectiveStream::with_policy`] plus a fault-injection hook. The
    /// injector rides to the comm thread, so a planned `CollectiveHop`
    /// kill fires THERE under the Thread launcher (the hardest death to
    /// propagate: the rank body is still healthy, blocked in `join`) and
    /// at the deterministic execute-at-join point under Lockstep.
    pub fn with_policy_fault(
        port: RingPort,
        background: bool,
        policy: SchedPolicy,
        fault: Option<Arc<FaultInjector>>,
    ) -> CollectiveStream {
        let port = port.background();
        if background && port.n() > 1 {
            let (jtx, jrx) = channel::<Job>();
            let (rtx, rrx) = channel::<(u64, Vec<f32>)>();
            let tport = port.clone();
            let gport = port.clone();
            let tfault = fault.clone();
            let rank = port.rank();
            let thread = std::thread::Builder::new()
                .name(format!("rtp-comm-r{}", port.rank()))
                .spawn(move || {
                    let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                        move || comm_thread_main(tport, policy, tfault, jrx, rtx),
                    ));
                    if let Err(p) = out {
                        // the comm thread died: record the typed root
                        // cause for every peer (first detector wins). A
                        // thread that unwound OUT of an already-poisoned
                        // recv is a casualty, not a cause — don't let it
                        // overwrite or fabricate a failure record.
                        if let Some(d) = p.downcast_ref::<RankDeath>() {
                            gport.fail_round(RankFailure {
                                failed_rank: d.rank,
                                kind: FailureKind::Injected { phase: d.phase },
                                detail: format!(
                                    "injected kill of rank {}'s comm thread at step {} \
                                     ({} fault point)",
                                    d.rank, d.step, d.phase
                                ),
                            });
                        } else if !gport.is_poisoned() {
                            gport.fail_round(RankFailure {
                                failed_rank: rank,
                                kind: FailureKind::CommThread,
                                detail: format!(
                                    "rank {rank}: background comm thread panicked"
                                ),
                            });
                        }
                    }
                })
                .expect("failed to spawn background comm thread");
            CollectiveStream {
                port,
                policy,
                fault,
                inner: Inner::Bg(Bg {
                    jobs: Mutex::new(jtx),
                    results: Mutex::new(rrx),
                    done: Mutex::new(HashMap::new()),
                    next_seq: AtomicU64::new(0),
                    thread: Mutex::new(Some(thread)),
                }),
            }
        } else {
            CollectiveStream {
                port,
                policy,
                fault,
                inner: Inner::Sync(Mutex::new(SyncQueue {
                    next_seq: 0,
                    pending: VecDeque::new(),
                    done: HashMap::new(),
                })),
            }
        }
    }

    /// The hop-scheduling policy this stream's comm thread runs under
    /// (informational in sync mode, where execute-at-join is always the
    /// deterministic FIFO order).
    pub fn policy(&self) -> SchedPolicy {
        self.policy
    }

    /// Is a dedicated comm thread driving the queue (true overlap), as
    /// opposed to deterministic execute-at-join?
    pub fn is_background(&self) -> bool {
        matches!(self.inner, Inner::Bg(_))
    }

    pub fn port(&self) -> &RingPort {
        &self.port
    }

    /// Queue this rank's side of an equal-shard ring allgather of
    /// `shard`. `buf` is recycled storage for the reconstructed full
    /// buffer (join returns it, `n * shard.len()` long, in rank order).
    pub fn issue_allgather(&self, shard: &[f32], buf: Vec<f32>) -> CollHandle {
        self.issue(Collective::allgather(&self.port, shard, buf))
    }

    /// Queue this rank's side of a ring reduce-scatter of `full` (length
    /// divisible by N). Join returns the buffer with the reduced chunk at
    /// `rank * len/N ..`; other chunks are partial-sum garbage.
    pub fn issue_reduce_scatter(&self, full: Vec<f32>) -> CollHandle {
        self.issue(Collective::reduce_scatter(&self.port, full))
    }

    /// Queue this rank's side of a ring allreduce (sum) of `buf`.
    pub fn issue_allreduce(&self, buf: Vec<f32>) -> CollHandle {
        self.issue(Collective::allreduce(&self.port, buf))
    }

    fn issue(&self, coll: Collective) -> CollHandle {
        self.port.note_bg_collective();
        match &self.inner {
            Inner::Sync(q) => {
                let mut q = lock(q);
                let seq = q.next_seq;
                q.next_seq += 1;
                q.pending.push_back((seq, coll));
                CollHandle { seq }
            }
            Inner::Bg(bg) => {
                let seq = bg.next_seq.fetch_add(1, Ordering::Relaxed);
                if lock(&bg.jobs).send(Job::Run(seq, coll)).is_err() {
                    self.comm_thread_died();
                }
                CollHandle { seq }
            }
        }
    }

    /// Join an issued collective: blocks until its hops have completed
    /// and returns its payload buffer. Sync mode executes the queue (in
    /// issue order, up to and including this handle) on the calling
    /// thread; background mode waits for the comm thread, which may have
    /// finished long ago — that difference is the measured overlap
    /// (`FabricCounters::{bg_busy_ns, bg_wait_ns}`).
    pub fn join(&self, handle: CollHandle) -> Vec<f32> {
        match &self.inner {
            Inner::Sync(q) => {
                let mut q = lock(q);
                if let Some(buf) = q.done.remove(&handle.seq) {
                    return buf;
                }
                let t0 = Instant::now();
                loop {
                    let (seq, mut coll) = q
                        .pending
                        .pop_front()
                        .expect("join of an unknown collective handle");
                    // same seq -> sub-channel mapping as the comm thread,
                    // so both modes put identical message sequences on
                    // identical lanes
                    let sp = self.port.bg_subchannel(subchannel_of(seq));
                    loop {
                        if let Some(f) = &self.fault {
                            f.fault_point(self.port.rank(), FaultPhase::CollectiveHop);
                        }
                        if coll.step(&sp) {
                            break;
                        }
                    }
                    let buf = coll.into_buf();
                    if seq == handle.seq {
                        let d = t0.elapsed();
                        self.port.note_bg_busy(d);
                        self.port.note_bg_wait(d);
                        return buf;
                    }
                    q.done.insert(seq, buf);
                }
            }
            Inner::Bg(bg) => {
                if let Some(buf) = lock(&bg.done).remove(&handle.seq) {
                    return buf;
                }
                let rx = lock(&bg.results);
                loop {
                    let t0 = Instant::now();
                    match rx.recv() {
                        Ok((seq, buf)) => {
                            self.port.note_bg_wait(t0.elapsed());
                            if seq == handle.seq {
                                return buf;
                            }
                            lock(&bg.done).insert(seq, buf);
                        }
                        Err(_) => self.comm_thread_died(),
                    }
                }
            }
        }
    }

    /// The comm thread is gone: surface WHY instead of hanging (it dies
    /// by panicking out of a poisoned fabric recv — watchdogged stalled
    /// link, peer panic, orderly abort).
    fn comm_thread_died(&self) -> ! {
        let why = self
            .port
            .poison_reason_or("comm thread terminated unexpectedly");
        panic!(
            "rank {}: background comm thread died ({why})",
            self.port.rank()
        );
    }
}

impl Drop for CollectiveStream {
    fn drop(&mut self) {
        match &self.inner {
            Inner::Bg(bg) => {
                // best effort: the thread may already be dead (poisoned
                // round)
                let _ = lock(&bg.jobs).send(Job::Shutdown);
                if let Some(t) = lock(&bg.thread).take() {
                    let _ = t.join();
                }
            }
            Inner::Sync(q) => {
                // an entry in `done` is a collective drained ahead of an
                // out-of-order join whose own handle was then never
                // joined — a silent leak of the issue-all/join-all
                // discipline. (Skipped while unwinding: abort paths drop
                // streams with work legitimately outstanding.)
                if !std::thread::panicking() {
                    let q = lock(q);
                    debug_assert!(
                        q.done.is_empty(),
                        "rank {}: CollectiveStream dropped with {} early \
                         result(s) never claimed by a join",
                        self.port.rank(),
                        q.done.len()
                    );
                }
            }
        }
    }
}

impl std::fmt::Debug for CollectiveStream {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "CollectiveStream(rank {}/{}, {})",
            self.port.rank(),
            self.port.n(),
            if self.is_background() { "background" } else { "sync" }
        )
    }
}

/// The per-rank comm thread: the HOP-LEVEL SCHEDULER (module docs).
/// Maintains the set of in-flight collectives, admits newly issued work
/// between hops without blocking, and steps ONE hop of one collective at
/// a time, chosen by `policy`. Exits once `Shutdown` has been seen and
/// the in-flight set has drained, on a dropped job channel, or (by
/// unwinding) a poisoned fabric recv — dropping its result sender either
/// way, which is what a joining rank body observes.
fn comm_thread_main(
    port: RingPort,
    policy: SchedPolicy,
    fault: Option<Arc<FaultInjector>>,
    jobs: Receiver<Job>,
    results: Sender<(u64, Vec<f32>)>,
) {
    let subports: Vec<RingPort> =
        (0..BG_SUBCHANNELS).map(|i| port.bg_subchannel(i)).collect();
    // kept sorted by seq: jobs arrive in issue order
    let mut inflight: VecDeque<(u64, Collective)> = VecDeque::new();
    let mut shutdown = false;
    // fairness accounting: consecutive contested hops on one collective
    let mut last_seq: Option<u64> = None;
    let mut streak: u64 = 0;
    loop {
        if inflight.is_empty() {
            if shutdown {
                return;
            }
            // idle: block for the next job
            match jobs.recv() {
                Ok(Job::Run(seq, coll)) => inflight.push_back((seq, coll)),
                Ok(Job::Shutdown) | Err(_) => return,
            }
        }
        // admit everything already issued, without blocking
        loop {
            match jobs.try_recv() {
                Ok(Job::Run(seq, coll)) => inflight.push_back((seq, coll)),
                Ok(Job::Shutdown) | Err(TryRecvError::Disconnected) => {
                    shutdown = true;
                    break;
                }
                Err(TryRecvError::Empty) => break,
            }
        }

        // per-sub-channel heads: only the OLDEST in-flight collective of
        // each sub-channel may move (strict seq order within a
        // sub-channel is the cross-rank matching invariant)
        let mut head_idx = [usize::MAX; BG_SUBCHANNELS];
        let mut heads = 0usize;
        for (i, (seq, _)) in inflight.iter().enumerate() {
            let sc = subchannel_of(*seq);
            if head_idx[sc] == usize::MAX {
                head_idx[sc] = i;
                heads += 1;
                if heads == BG_SUBCHANNELS {
                    break;
                }
            }
        }
        let pick = pick_head(policy, &inflight, &head_idx, &subports, last_seq);
        let contested = heads > 1;

        let (seq, coll) = &mut inflight[pick];
        let seq = *seq;
        if let Some(f) = &fault {
            // the planned CollectiveHop kill dies HERE, on the comm
            // thread — the panic is caught by the spawn wrapper, which
            // records the typed failure and poisons the round
            f.fault_point(port.rank(), FaultPhase::CollectiveHop);
        }
        let t0 = Instant::now();
        let done = coll.step(&subports[subchannel_of(seq)]);
        port.note_bg_busy(t0.elapsed());

        let switched = last_seq != Some(seq);
        port.note_sched_hop(switched);
        if switched {
            streak = 0;
        } else if contested {
            streak += 1;
            port.note_sched_streak(streak);
        }
        last_seq = Some(seq);

        if done {
            let (s, coll) = inflight.remove(pick).expect("picked head exists");
            if results.send((s, coll.into_buf())).is_err() {
                return; // stream dropped mid-join: nothing to report to
            }
        }
    }
}

/// Choose which head collective steps its next hop. `Fifo` always
/// advances the oldest (the exact historical convoy). The interleaving
/// policies prefer heads whose next incoming message is ALREADY waiting
/// (their hop completes without blocking); when none is ready they fall
/// back to the oldest in-flight collective — the one choice every peer
/// is guaranteed to drive, which keeps blocking deadlock-free.
fn pick_head(
    policy: SchedPolicy,
    inflight: &VecDeque<(u64, Collective)>,
    head_idx: &[usize; BG_SUBCHANNELS],
    subports: &[RingPort],
    last_seq: Option<u64>,
) -> usize {
    if policy == SchedPolicy::Fifo || inflight.len() == 1 {
        return 0;
    }
    let mut ready = [0usize; BG_SUBCHANNELS];
    let mut nready = 0usize;
    for (sc, &i) in head_idx.iter().enumerate() {
        if i != usize::MAX {
            let p = &subports[sc];
            if p.pending_from(p.prev()) > 0 {
                ready[nready] = i;
                nready += 1;
            }
        }
    }
    if nready == 0 {
        return 0;
    }
    let ready = &mut ready[..nready];
    // ready was collected in sub-channel order; policies rank by seq
    ready.sort_unstable_by_key(|&i| inflight[i].0);
    match policy {
        SchedPolicy::Fifo => unreachable!("handled above"),
        // round-robin by seq: the first ready head past the one stepped
        // last, wrapping — with several ready heads the scheduler never
        // steps the same collective twice in a row
        SchedPolicy::RoundRobin => {
            let after = last_seq.unwrap_or(0);
            ready
                .iter()
                .copied()
                .find(|&i| inflight[i].0 > after)
                .unwrap_or(ready[0])
        }
        // allgathers (prefetches) outrank everything; ties in seq order
        SchedPolicy::Priority => ready
            .iter()
            .copied()
            .find(|&i| inflight[i].1.kind() == CollKind::AllGather)
            .unwrap_or(ready[0]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::fabric::{LaunchPolicy, RingFabric};

    /// Drive one rotation "step" per rank: begin before (fake) compute,
    /// wait at the boundary. Returns each rank's final held value.
    fn rotate_with_stream(policy: LaunchPolicy, async_mode: bool, n: usize, hops: usize) -> Vec<usize> {
        let fab = RingFabric::new(n);
        let tasks: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..n)
            .map(|r| {
                let stream = CommStream::new(fab.port(r), async_mode);
                Box::new(move || {
                    let mut held = r;
                    for _ in 0..hops {
                        let pending = stream.begin(held, RotationDir::Clockwise);
                        // (compute with `held` would run here)
                        held = stream.wait(pending);
                    }
                    held
                }) as Box<dyn FnOnce() -> usize + Send>
            })
            .collect();
        let out = fab.run_round(policy, tasks);
        assert_eq!(fab.in_flight(), 0, "stream left messages in flight");
        out
    }

    #[test]
    fn sync_and_async_streams_agree() {
        for n in [1usize, 2, 3, 4, 8] {
            for hops in [1usize, 2, n] {
                let sync = rotate_with_stream(LaunchPolicy::Lockstep, false, n, hops);
                let asy = rotate_with_stream(LaunchPolicy::Threaded, true, n, hops);
                assert_eq!(sync, asy, "n={n} hops={hops}");
                // and matches the schedule math
                for (w, held) in sync.iter().enumerate() {
                    assert_eq!(
                        *held,
                        crate::comm::shard_at(RotationDir::Clockwise, w, hops, n),
                        "n={n} hops={hops} w={w}"
                    );
                }
            }
        }
    }

    #[test]
    fn async_begin_puts_payload_in_flight_immediately() {
        let fab = RingFabric::new(2);
        let tasks: Vec<Box<dyn FnOnce() + Send>> = (0..2)
            .map(|r| {
                let stream = CommStream::new(fab.port(r), true);
                let fabc = fab.clone();
                Box::new(move || {
                    let pending = stream.begin(r, RotationDir::Clockwise);
                    if r == 0 {
                        // own send is on the wire before wait() — the
                        // overlap window the modeled timeline charges
                        assert!(fabc.messages_sent() >= 1);
                    }
                    let got = stream.wait(pending);
                    assert_eq!(got, 1 - r);
                }) as Box<dyn FnOnce() + Send>
            })
            .collect();
        fab.run_round(LaunchPolicy::Threaded, tasks);
    }

    #[test]
    fn single_rank_stream_is_identity() {
        let fab = RingFabric::new(1);
        let stream = CommStream::new(fab.port(0), true);
        let p = stream.begin(41usize, RotationDir::CounterClockwise);
        assert_eq!(stream.wait(p), 41);
        assert_eq!(fab.messages_sent(), 0);
    }

    /// (allgather result, reduce-scatter shard, allreduce result).
    type Triple = (Vec<f32>, Vec<f32>, Vec<f32>);

    /// One rank body: queue an allgather + a reduce-scatter + an
    /// allreduce, join in a scrambled order, return the three results.
    fn drive_collectives(stream: &CollectiveStream, r: usize, n: usize) -> Triple {
        let shard = vec![r as f32 + 1.0; 3];
        let full: Vec<f32> = (0..2 * n).map(|i| (r * 100 + i) as f32).collect();
        let arbuf = vec![r as f32; 5];
        let h_ag = stream.issue_allgather(&shard, Vec::new());
        let h_rs = stream.issue_reduce_scatter(full);
        let h_ar = stream.issue_allreduce(arbuf);
        // join out of issue order: results must still match
        let ar = stream.join(h_ar);
        let ag = stream.join(h_ag);
        let rs_full = stream.join(h_rs);
        let rs = rs_full[r * 2..(r + 1) * 2].to_vec();
        (ag, rs, ar)
    }

    fn run_collective_streams(
        policy: LaunchPolicy,
        background: bool,
        n: usize,
    ) -> Vec<Triple> {
        let fab = RingFabric::new(n);
        let tasks: Vec<Box<dyn FnOnce() -> Triple + Send>> = (0..n)
            .map(|r| {
                let stream = CollectiveStream::new(fab.port(r), background);
                Box::new(move || drive_collectives(&stream, r, n))
                    as Box<dyn FnOnce() -> Triple + Send>
            })
            .collect();
        let out = fab.run_round(policy, tasks);
        assert_eq!(fab.in_flight(), 0, "stream left messages in flight");
        out
    }

    #[test]
    fn background_and_sync_collective_streams_agree() {
        for n in [1usize, 2, 4] {
            let sync = run_collective_streams(LaunchPolicy::Lockstep, false, n);
            let bg = run_collective_streams(LaunchPolicy::Threaded, true, n);
            assert_eq!(sync, bg, "n={n}");
            // spot-check against the math
            let want_ag: Vec<f32> = (0..n)
                .flat_map(|r| vec![r as f32 + 1.0; 3])
                .collect();
            let want_ar = vec![(0..n).map(|r| r as f32).sum::<f32>(); 5];
            for (r, (ag, rs, ar)) in sync.iter().enumerate() {
                assert_eq!(ag, &want_ag, "n={n} r={r}");
                assert_eq!(ar, &want_ar, "n={n} r={r}");
                let want_rs: Vec<f32> = (0..2)
                    .map(|i| {
                        (0..n).map(|s| (s * 100 + r * 2 + i) as f32).sum::<f32>()
                    })
                    .collect();
                assert_eq!(rs, &want_rs, "n={n} r={r}");
            }
        }
    }

    #[test]
    fn background_stream_counts_busy_and_wait() {
        let n = 2;
        let fab = RingFabric::new(n);
        fab.reset_counters();
        let tasks: Vec<Box<dyn FnOnce() + Send>> = (0..n)
            .map(|r| {
                let stream = CollectiveStream::new(fab.port(r), true);
                Box::new(move || {
                    assert!(stream.is_background());
                    let h = stream.issue_allreduce(vec![r as f32; 64]);
                    let _ = stream.join(h);
                }) as Box<dyn FnOnce() + Send>
            })
            .collect();
        fab.run_round(LaunchPolicy::Threaded, tasks);
        let c = fab.counters();
        assert_eq!(c.bg_collectives, n as u64);
        assert!(c.bg_busy_ns > 0, "{c:?}");
    }

    #[test]
    fn sync_stream_executes_in_issue_order_at_join() {
        // the bg lanes must carry collectives in ISSUE order even when
        // joins are scrambled — the cross-mode bit-identity requirement
        let fab = RingFabric::new(2);
        let tasks: Vec<Box<dyn FnOnce() -> Vec<f32> + Send>> = (0..2)
            .map(|r| {
                let stream = CollectiveStream::new(fab.port(r), false);
                Box::new(move || {
                    let h1 = stream.issue_allreduce(vec![1.0 + r as f32]);
                    let h2 = stream.issue_allreduce(vec![10.0 + r as f32]);
                    // joining h2 first must drain h1 first internally
                    let b = stream.join(h2);
                    let a = stream.join(h1);
                    vec![a[0], b[0]]
                }) as Box<dyn FnOnce() -> Vec<f32> + Send>
            })
            .collect();
        let out = fab.run_round(LaunchPolicy::Lockstep, tasks);
        for o in out {
            assert_eq!(o, vec![3.0, 21.0]);
        }
        assert_eq!(fab.in_flight(), 0);
    }

    #[test]
    fn sync_stream_out_of_order_joins_drain_cleanly() {
        // regression for the early-results leak check: scrambled joins
        // that DO claim every handle must leave `done` empty, so the
        // drop-time assertion stays silent. n=1 keeps it hermetic (no
        // round needed — single-rank collectives complete locally).
        let fab = RingFabric::new(1);
        let stream = CollectiveStream::new(fab.port(0), false);
        let h1 = stream.issue_allreduce(vec![1.0]);
        let h2 = stream.issue_allreduce(vec![2.0]);
        let h3 = stream.issue_allreduce(vec![3.0]);
        assert_eq!(stream.join(h3), vec![3.0]);
        assert_eq!(stream.join(h1), vec![1.0]);
        assert_eq!(stream.join(h2), vec![2.0]);
        drop(stream); // must not trip the early-results assertion
        assert_eq!(fab.in_flight(), 0);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "never claimed by a join")]
    fn sync_stream_drop_flags_unclaimed_early_results() {
        // joining h2 drains h1 into `done` (issue-order execution); never
        // claiming h1 afterwards is the leak the drop assertion exists to
        // catch
        let fab = RingFabric::new(1);
        let stream = CollectiveStream::new(fab.port(0), false);
        let _leaked = stream.issue_allreduce(vec![1.0]);
        let h2 = stream.issue_allreduce(vec![2.0]);
        let _ = stream.join(h2);
        drop(stream);
    }
}
