//! Per-rank comm streams: TRUE async rotation (§3.4.3).
//!
//! A [`CommStream`] is one rank's handle for overlapping a rotation hop
//! with the compute that uses the shard being rotated. The paper's claim
//! is that out-of-place RTP *starts computation and communication
//! simultaneously*: the shard a rank computes with this step is, at the
//! same time, already in flight to its clockwise neighbor. On this
//! fabric that is exactly what [`CommStream::begin`] does in async mode —
//! the outgoing payload is enqueued on the neighbor lane BEFORE the
//! compute closure runs, so by the time every rank reaches its step
//! boundary the incoming shard is already sitting in its lane and
//! [`CommStream::wait`] completes without blocking on the upstream
//! neighbor's compute. The lane queue slot is the double-buffered
//! in-flight shard — the `max(W,G)/N` rotation buffer `RtpOutOfPlace`
//! models (and in real mode the payload is an `Arc`, so the in-flight
//! copy DEDUPLICATES against the live shard instead of duplicating it).
//!
//! Under the deterministic `Lockstep` launcher the same API degrades to
//! the classic synchronous boundary hop: `begin` defers the send and
//! `wait` performs send-then-recv exactly where the pre-stream engines
//! did. Because each rank's per-link send order is identical in both
//! modes and every lane is FIFO, the two schedules are BIT-IDENTICAL —
//! asserted for every engine by `tests/launcher_equivalence.rs`.
//!
//! A rank blocked in [`CommStream::wait`] sits in the fabric's threaded
//! `recv`, so it inherits the `RTP_FABRIC_TIMEOUT_SECS` watchdog and a
//! stall is reported with the exact link (rank, edge, ring direction)
//! that never delivered.

use std::any::Any;

use super::fabric::RingPort;
use super::rotation::RotationDir;

/// One rank's rotation stream. Cheap to construct (clones a port handle);
/// `async_mode` decides eager-in-flight vs deferred-synchronous hops.
#[derive(Clone)]
pub struct CommStream {
    port: RingPort,
    async_mode: bool,
}

/// An issued rotation hop, waiting to be joined. Must be `wait`ed before
/// the rotated-in payload is consumed (and before the fabric drain
/// assertion at the step boundary).
#[must_use = "an in-flight rotation must be waited before its shard is consumed"]
pub struct InFlight<T: Any + Send> {
    dir: RotationDir,
    /// Sync mode: the payload still to send at `wait` time. Async mode:
    /// `None` — already on the wire.
    deferred: Option<T>,
}

impl CommStream {
    pub fn new(port: RingPort, async_mode: bool) -> CommStream {
        CommStream { port, async_mode }
    }

    /// Is this stream overlapping hops for real (Thread launcher) rather
    /// than degrading to synchronous boundary hops (Lockstep)?
    pub fn is_async(&self) -> bool {
        self.async_mode
    }

    pub fn port(&self) -> &RingPort {
        &self.port
    }

    /// Issue one rotation hop carrying `item` in direction `dir`.
    ///
    /// Async mode: `item` is enqueued to the downstream neighbor NOW and
    /// travels while the caller computes. Sync mode (and single-rank
    /// rings): the send is deferred to [`CommStream::wait`], reproducing
    /// the deterministic boundary schedule.
    pub fn begin<T: Any + Send>(&self, item: T, dir: RotationDir) -> InFlight<T> {
        let n = self.port.n();
        if self.async_mode && n > 1 {
            let w = self.port.rank();
            self.port.send(dir.send_peer(w, n), item);
            InFlight { dir, deferred: None }
        } else {
            InFlight { dir, deferred: Some(item) }
        }
    }

    /// Join an issued hop: completes the exchange and returns the payload
    /// arriving from the upstream neighbor. On a single-rank ring this is
    /// the identity.
    pub fn wait<T: Any + Send>(&self, inflight: InFlight<T>) -> T {
        let n = self.port.n();
        let w = self.port.rank();
        let InFlight { dir, deferred } = inflight;
        match deferred {
            Some(item) if n <= 1 => item,
            Some(item) => {
                self.port.send(dir.send_peer(w, n), item);
                self.port.recv(dir.recv_peer(w, n))
            }
            None => self.port.recv(dir.recv_peer(w, n)),
        }
    }
}

impl std::fmt::Debug for CommStream {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "CommStream(rank {}/{}, {})",
            self.port.rank(),
            self.port.n(),
            if self.async_mode { "async" } else { "sync" }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::fabric::{LaunchPolicy, RingFabric};

    /// Drive one rotation "step" per rank: begin before (fake) compute,
    /// wait at the boundary. Returns each rank's final held value.
    fn rotate_with_stream(policy: LaunchPolicy, async_mode: bool, n: usize, hops: usize) -> Vec<usize> {
        let fab = RingFabric::new(n);
        let tasks: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..n)
            .map(|r| {
                let stream = CommStream::new(fab.port(r), async_mode);
                Box::new(move || {
                    let mut held = r;
                    for _ in 0..hops {
                        let pending = stream.begin(held, RotationDir::Clockwise);
                        // (compute with `held` would run here)
                        held = stream.wait(pending);
                    }
                    held
                }) as Box<dyn FnOnce() -> usize + Send>
            })
            .collect();
        let out = fab.run_round(policy, tasks);
        assert_eq!(fab.in_flight(), 0, "stream left messages in flight");
        out
    }

    #[test]
    fn sync_and_async_streams_agree() {
        for n in [1usize, 2, 3, 4, 8] {
            for hops in [1usize, 2, n] {
                let sync = rotate_with_stream(LaunchPolicy::Lockstep, false, n, hops);
                let asy = rotate_with_stream(LaunchPolicy::Threaded, true, n, hops);
                assert_eq!(sync, asy, "n={n} hops={hops}");
                // and matches the schedule math
                for (w, held) in sync.iter().enumerate() {
                    assert_eq!(
                        *held,
                        crate::comm::shard_at(RotationDir::Clockwise, w, hops, n),
                        "n={n} hops={hops} w={w}"
                    );
                }
            }
        }
    }

    #[test]
    fn async_begin_puts_payload_in_flight_immediately() {
        let fab = RingFabric::new(2);
        let tasks: Vec<Box<dyn FnOnce() + Send>> = (0..2)
            .map(|r| {
                let stream = CommStream::new(fab.port(r), true);
                let fabc = fab.clone();
                Box::new(move || {
                    let pending = stream.begin(r, RotationDir::Clockwise);
                    if r == 0 {
                        // own send is on the wire before wait() — the
                        // overlap window the modeled timeline charges
                        assert!(fabc.messages_sent() >= 1);
                    }
                    let got = stream.wait(pending);
                    assert_eq!(got, 1 - r);
                }) as Box<dyn FnOnce() + Send>
            })
            .collect();
        fab.run_round(LaunchPolicy::Threaded, tasks);
    }

    #[test]
    fn single_rank_stream_is_identity() {
        let fab = RingFabric::new(1);
        let stream = CommStream::new(fab.port(0), true);
        let p = stream.begin(41usize, RotationDir::CounterClockwise);
        assert_eq!(stream.wait(p), 41);
        assert_eq!(fab.messages_sent(), 0);
    }
}
