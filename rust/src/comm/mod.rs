//! Communication layer: the rank-local ring fabric, the chunked ring
//! collectives built on it, the paper's rotation schedule (§3.3), and the
//! α-β cost model that prices everything per hop.
//!
//! Architecture (this is the substrate of the paper's two contributions):
//!
//! - [`fabric`] — `RingFabric` / `RingPort`: per-rank endpoints over
//!   per-worker mailboxes. A rank can only talk to its ring neighbors, one
//!   hop at a time; every engine transfer goes through `port.send` /
//!   `port.recv`.
//! - this module — the collectives, decomposed into their ring-hop
//!   schedules: all-reduce is reduce-scatter + all-gather in `2(N-1)`
//!   hops of `M/N` bytes; all-gather / reduce-scatter are `N-1` hops;
//!   rotation ([`rotate_ring`]) is ONE hop of the full shard — the §3.4.2
//!   identity "(N-1) rotations ≡ one allgather" is now structural, not a
//!   formula.
//! - [`rotation`] — the schedule math (`RotationDir`, `shard_at`): which
//!   shard sits on which rank after `t` hops.
//! - [`cost`] — the α-β model. `CommPrim::hop_schedule` exposes each
//!   collective's per-hop message sizes; `perfmodel::Timeline` charges hop
//!   by hop, so overlap renders show the real hop schedule.
//! - [`reference`] — the seed's god-view one-shot collectives, kept ONLY
//!   as test oracles for the ring implementations. Engines must not touch
//!   them.
//!
//! Real-mode collectives move actual data through the fabric (replacing
//! NCCL on the simulated ring); virtual-mode engines skip the data and
//! only charge the cost model — the *schedule* is identical because both
//! modes run the same engine code.
//!
//! All collectives here take the full rank set's ports (symmetric SPMD:
//! the single-process simulation steps every rank through the same
//! schedule in program order). Each function documents its hop count; a
//! completed collective always leaves the fabric drained.

pub mod cost;
pub mod fabric;
pub mod reference;
pub mod rotation;

use std::any::Any;

pub use cost::{CommPrim, LinkModel};
pub use fabric::{RingFabric, RingPort};
pub use rotation::{shard_at, RotationDir};

/// Split `len` elements into `n` contiguous chunks whose sizes differ by
/// at most one (the first `len % n` chunks are one longer). Returns
/// `(start, end)` bounds; chunks may be empty when `len < n`.
fn chunk_bounds(len: usize, n: usize) -> Vec<(usize, usize)> {
    let base = len / n;
    let rem = len % n;
    let mut bounds = Vec::with_capacity(n);
    let mut start = 0;
    for i in 0..n {
        let size = base + usize::from(i < rem);
        bounds.push((start, start + size));
        start += size;
    }
    bounds
}

/// Ring all-reduce (sum) in `2(N-1)` hops: a reduce-scatter pass (each
/// rank ends owning the fully-reduced chunk matching its rank) followed by
/// an all-gather pass. Every hop moves ~`len/N` elements per rank to its
/// clockwise neighbor. DDP's gradient reduction; also the replicated-grad
/// reduction in every multi-worker engine.
///
/// Works for any buffer length (chunks may be uneven or empty).
pub fn allreduce_sum(ports: &[RingPort], bufs: &mut [Vec<f32>]) {
    let n = bufs.len();
    if n <= 1 {
        return;
    }
    assert_eq!(ports.len(), n, "allreduce port/buffer arity");
    let len = bufs[0].len();
    assert!(
        bufs.iter().all(|b| b.len() == len),
        "allreduce buffers must be same-length"
    );
    let ch = chunk_bounds(len, n);

    // reduce-scatter pass: after hop s, chunk (w - s - 1) mod n on rank w
    // has accumulated s + 2 contributions; after n-1 hops rank w owns the
    // complete chunk w.
    for s in 0..n - 1 {
        for (w, port) in ports.iter().enumerate() {
            let (a, b) = ch[(w + n - s - 1) % n];
            port.send(port.next(), bufs[w][a..b].to_vec());
        }
        for (w, port) in ports.iter().enumerate() {
            let (a, b) = ch[(w + 2 * n - s - 2) % n];
            let msg: Vec<f32> = port.recv(port.prev());
            for (dst, v) in bufs[w][a..b].iter_mut().zip(&msg) {
                *dst += v;
            }
        }
    }
    // all-gather pass: complete chunks circulate until every rank has all.
    for s in 0..n - 1 {
        for (w, port) in ports.iter().enumerate() {
            let (a, b) = ch[(w + n - s) % n];
            port.send(port.next(), bufs[w][a..b].to_vec());
        }
        for (w, port) in ports.iter().enumerate() {
            let (a, b) = ch[(w + 2 * n - s - 1) % n];
            let msg: Vec<f32> = port.recv(port.prev());
            bufs[w][a..b].copy_from_slice(&msg);
        }
    }
}

/// Ring all-gather in `N-1` hops, returning each rank's view of all N
/// shard payloads (unconcatenated, in rank order). Shards may have
/// different lengths. This is the primitive; [`allgather`] concatenates.
pub fn allgather_parts(ports: &[RingPort], shards: &[Vec<f32>]) -> Vec<Vec<Vec<f32>>> {
    let n = shards.len();
    if n == 0 {
        return Vec::new();
    }
    assert_eq!(ports.len(), n, "allgather port/shard arity");
    if n == 1 {
        return vec![vec![shards[0].clone()]];
    }
    // hold[w][c] = shard c's payload once it has reached rank w
    let mut hold: Vec<Vec<Option<Vec<f32>>>> = (0..n)
        .map(|w| {
            (0..n)
                .map(|c| if c == w { Some(shards[w].clone()) } else { None })
                .collect()
        })
        .collect();
    for s in 0..n - 1 {
        for (w, port) in ports.iter().enumerate() {
            let c = (w + n - s) % n;
            let payload = hold[w][c].clone().expect("allgather schedule hole");
            port.send(port.next(), payload);
        }
        for (w, port) in ports.iter().enumerate() {
            let c = (w + 2 * n - s - 1) % n;
            hold[w][c] = Some(port.recv(port.prev()));
        }
    }
    hold.into_iter()
        .map(|row| row.into_iter().map(|o| o.expect("allgather incomplete")).collect())
        .collect()
}

/// Ring all-gather in `N-1` hops: every rank ends with the concatenation
/// `[shard_0 | shard_1 | ... | shard_{N-1}]`. FSDP's parameter
/// reconstruction. Returns one full buffer per rank (all equal).
pub fn allgather(ports: &[RingPort], shards: &[Vec<f32>]) -> Vec<Vec<f32>> {
    allgather_parts(ports, shards)
        .into_iter()
        .map(|parts| {
            let mut full = Vec::with_capacity(parts.iter().map(|p| p.len()).sum());
            for p in parts {
                full.extend_from_slice(&p);
            }
            full
        })
        .collect()
}

/// Ring reduce-scatter (sum) in `N-1` hops: input is one full-length
/// buffer per rank; rank `w` ends with the sum of everyone's shard `w`.
/// FSDP's gradient reduction. All inputs must be equal length and
/// divisible by N. Empty input returns empty (the seed panicked here).
pub fn reduce_scatter(ports: &[RingPort], fulls: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let n = fulls.len();
    if n == 0 {
        return Vec::new();
    }
    assert_eq!(ports.len(), n, "reduce_scatter port/buffer arity");
    let len = fulls[0].len();
    assert!(
        fulls.iter().all(|f| f.len() == len),
        "reduce_scatter buffers must be same-length"
    );
    assert_eq!(len % n, 0, "reduce_scatter length {len} not divisible by {n}");
    if n == 1 {
        return vec![fulls[0].clone()];
    }
    let shard = len / n;
    let mut acc: Vec<Vec<f32>> = fulls.to_vec();
    for s in 0..n - 1 {
        for (w, port) in ports.iter().enumerate() {
            let c = (w + n - s - 1) % n;
            port.send(port.next(), acc[w][c * shard..(c + 1) * shard].to_vec());
        }
        for (w, port) in ports.iter().enumerate() {
            let c = (w + 2 * n - s - 2) % n;
            let msg: Vec<f32> = port.recv(port.prev());
            for (dst, v) in acc[w][c * shard..(c + 1) * shard].iter_mut().zip(&msg) {
                *dst += v;
            }
        }
    }
    acc.iter()
        .enumerate()
        .map(|(w, a)| a[w * shard..(w + 1) * shard].to_vec())
        .collect()
}

/// Pipelined ring broadcast from `root`: the payload is split into N-1
/// chunks that stream clockwise down the ring, so each LINK forwards
/// exactly `M` bytes over N-1 chunk-sized stages — matching the
/// `α(N-1) + Mβ` closed form and the `hop_schedule` of N-1 hops of
/// `M/(N-1)` (the bottleneck link's stages; the pipeline keeps up to
/// N-1 links busy in the same stage). `(N-1)²` chunk messages total.
pub fn broadcast(ports: &[RingPort], bufs: &mut [Vec<f32>], root: usize) {
    let n = bufs.len();
    if n <= 1 {
        return;
    }
    assert_eq!(ports.len(), n, "broadcast port/buffer arity");
    let len = bufs[root].len();
    assert!(
        bufs.iter().all(|b| b.len() == len),
        "broadcast length mismatch"
    );
    let ch = chunk_bounds(len, n - 1);
    // pipeline stage t: the link (root+j) -> (root+j+1) carries chunk
    // t-j when 0 <= t-j < n-1; link j forwards a chunk the stage after
    // receiving it, so every send payload is already resident.
    for t in 0..2 * n - 3 {
        let active: Vec<usize> =
            (0..n - 1).filter(|&j| t >= j && t - j < n - 1).collect();
        for &j in &active {
            let src = (root + j) % n;
            let (a, b) = ch[t - j];
            ports[src].send((src + 1) % n, bufs[src][a..b].to_vec());
        }
        for &j in &active {
            let src = (root + j) % n;
            let dst = (src + 1) % n;
            let (a, b) = ch[t - j];
            let msg: Vec<f32> = ports[dst].recv(src);
            bufs[dst][a..b].copy_from_slice(&msg);
        }
    }
}

/// Ring all-to-all in `N-1` hops: `bufs[w]` is rank w's send buffer split
/// into N equal chunks; chunk `d` goes to rank `d`. Rank w ends with
/// `[chunk_w_of_0 | chunk_w_of_1 | ...]` — the MoE baselines' token
/// shuffle. Implemented as a relay: each source buffer travels the ring
/// and every rank extracts its chunk as the buffer passes through (the
/// same schedule RTP's Expert-Partition rotation uses).
pub fn all_to_all(ports: &[RingPort], bufs: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let n = bufs.len();
    if n == 0 {
        return Vec::new();
    }
    assert_eq!(ports.len(), n, "all_to_all port/buffer arity");
    let len = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == len));
    assert_eq!(len % n, 0, "all_to_all length {len} not divisible by {n}");
    if n == 1 {
        return vec![bufs[0].clone()];
    }
    let chunk = len / n;
    let mut out: Vec<Vec<f32>> = (0..n).map(|_| vec![0.0f32; len]).collect();
    // own chunk needs no hop
    for w in 0..n {
        out[w][w * chunk..(w + 1) * chunk]
            .copy_from_slice(&bufs[w][w * chunk..(w + 1) * chunk]);
    }
    // each source buffer relays clockwise; rank w peels its chunk off as
    // the buffer visits
    let mut traveling: Vec<(usize, Vec<f32>)> =
        (0..n).map(|w| (w, bufs[w].clone())).collect();
    for _hop in 0..n - 1 {
        for (w, port) in ports.iter().enumerate() {
            let t = std::mem::replace(&mut traveling[w], (usize::MAX, Vec::new()));
            port.send(port.next(), t);
        }
        for (w, port) in ports.iter().enumerate() {
            let (src, data): (usize, Vec<f32>) = port.recv(port.prev());
            out[w][src * chunk..(src + 1) * chunk]
                .copy_from_slice(&data[w * chunk..(w + 1) * chunk]);
            traveling[w] = (src, data);
        }
    }
    out
}

/// One ring rotation hop (the paper's §3.3 primitive): every rank sends
/// its element to `dir.send_peer` and receives from `dir.recv_peer`
/// through the fabric, so after the exchange rank `w` holds what its
/// upstream neighbor held. Generic over the payload: the engines rotate
/// shard structs in real mode and bare shard ids in virtual mode —
/// identical schedule either way.
pub fn rotate_ring<T: Any>(ports: &[RingPort], bufs: &mut Vec<T>, dir: RotationDir) {
    let n = bufs.len();
    if n <= 1 {
        return;
    }
    assert_eq!(ports.len(), n, "rotate port/buffer arity");
    let old = std::mem::take(bufs);
    for (w, item) in old.into_iter().enumerate() {
        ports[w].send(dir.send_peer(w, n), item);
    }
    *bufs = (0..n)
        .map(|w| ports[w].recv::<T>(dir.recv_peer(w, n)))
        .collect();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn rand_bufs(rng: &mut Rng, n: usize, len: usize) -> Vec<Vec<f32>> {
        (0..n)
            .map(|_| (0..len).map(|_| rng.normal() as f32).collect())
            .collect()
    }

    fn ports_of(n: usize) -> (RingFabric, Vec<RingPort>) {
        let fab = RingFabric::new(n.max(1));
        let ports = fab.ports();
        (fab, ports)
    }

    #[test]
    fn chunk_bounds_cover_and_balance() {
        prop::check("chunk bounds", 100, |rng| {
            let n = 1 + rng.below(9);
            let len = rng.below(40);
            let ch = chunk_bounds(len, n);
            if ch.len() != n {
                return Err("wrong chunk count".into());
            }
            if ch[0].0 != 0 || ch[n - 1].1 != len {
                return Err("chunks do not cover".into());
            }
            for i in 1..n {
                if ch[i].0 != ch[i - 1].1 {
                    return Err("chunks not contiguous".into());
                }
            }
            let sizes: Vec<usize> = ch.iter().map(|(a, b)| b - a).collect();
            let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            if mx - mn > 1 {
                return Err(format!("unbalanced chunks {sizes:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn ring_allreduce_is_sum() {
        let (fab, ports) = ports_of(3);
        let mut bufs = vec![vec![1.0, 2.0], vec![10.0, 20.0], vec![100.0, 200.0]];
        allreduce_sum(&ports, &mut bufs);
        for b in &bufs {
            assert_eq!(b, &vec![111.0, 222.0]);
        }
        assert_eq!(fab.in_flight(), 0);
    }

    #[test]
    fn ring_allreduce_matches_reference() {
        prop::check("ring ar == ref ar", 60, |rng| {
            let n = 1 + rng.below(8);
            let len = rng.below(30); // any length, incl. 0 and < n
            let mut r = Rng::new(rng.next_u64());
            let bufs = rand_bufs(&mut r, n, len);
            let mut want = bufs.clone();
            reference::allreduce_sum(&mut want);
            let (fab, ports) = ports_of(n);
            let mut got = bufs;
            allreduce_sum(&ports, &mut got);
            for (g, w) in got.iter().zip(&want) {
                prop::close(g, w, 1e-4)?;
            }
            if fab.in_flight() != 0 {
                return Err("fabric not drained".into());
            }
            Ok(())
        });
    }

    #[test]
    fn ring_allreduce_performs_2n_minus_2_hops() {
        // 2(N-1) hops × N rank-messages per hop
        for n in [2usize, 4, 8] {
            let (fab, ports) = ports_of(n);
            let mut bufs = vec![vec![1.0f32; 4 * n]; n];
            allreduce_sum(&ports, &mut bufs);
            assert_eq!(fab.messages_sent(), (2 * (n - 1) * n) as u64, "n={n}");
            assert_eq!(fab.in_flight(), 0);
        }
    }

    #[test]
    fn ring_allgather_concatenates_in_rank_order() {
        let (_fab, ports) = ports_of(3);
        let shards = vec![vec![1.0], vec![2.0], vec![3.0]];
        for full in allgather(&ports, &shards) {
            assert_eq!(full, vec![1.0, 2.0, 3.0]);
        }
    }

    #[test]
    fn ring_allgather_matches_reference() {
        prop::check("ring ag == ref ag", 60, |rng| {
            let n = 1 + rng.below(8);
            let mut r = Rng::new(rng.next_u64());
            // deliberately unequal shard lengths
            let shards: Vec<Vec<f32>> = (0..n)
                .map(|_| {
                    let l = rng.below(7);
                    (0..l).map(|_| r.normal() as f32).collect()
                })
                .collect();
            let want = reference::allgather(&shards);
            let (fab, ports) = ports_of(n);
            for full in allgather(&ports, &shards) {
                prop::close(&full, &want, 0.0)?;
            }
            if fab.in_flight() != 0 {
                return Err("fabric not drained".into());
            }
            Ok(())
        });
    }

    #[test]
    fn ring_reduce_scatter_matches_reference() {
        prop::check("ring rs == ref rs", 60, |rng| {
            let n = 1 + rng.below(8);
            let len = n * rng.below(7); // divisible, possibly 0
            let mut r = Rng::new(rng.next_u64());
            let fulls = rand_bufs(&mut r, n, len);
            let want = reference::reduce_scatter(&fulls);
            let (fab, ports) = ports_of(n);
            let got = reduce_scatter(&ports, &fulls);
            for (g, w) in got.iter().zip(&want) {
                prop::close(g, w, 1e-4)?;
            }
            if fab.in_flight() != 0 {
                return Err("fabric not drained".into());
            }
            Ok(())
        });
    }

    #[test]
    fn reduce_scatter_then_allgather_is_allreduce() {
        prop::check("rs+ag == ar", 50, |rng| {
            let n = 1 + rng.below(6);
            let len = n * (1 + rng.below(8));
            let mut r = Rng::new(rng.next_u64());
            let bufs = rand_bufs(&mut r, n, len);
            let (_fab, ports) = ports_of(n);
            let mut ar = bufs.clone();
            allreduce_sum(&ports, &mut ar);
            let shards = reduce_scatter(&ports, &bufs);
            let fulls = allgather(&ports, &shards);
            prop::close(&fulls[0], &ar[0], 1e-5)
        });
    }

    #[test]
    fn ring_broadcast_matches_reference() {
        prop::check("ring bc == ref bc", 50, |rng| {
            let n = 1 + rng.below(8);
            let len = rng.below(10);
            let mut r = Rng::new(rng.next_u64());
            let bufs = rand_bufs(&mut r, n, len);
            let root = rng.below(n);
            let mut want = bufs.clone();
            reference::broadcast(&mut want, root);
            let (fab, ports) = ports_of(n);
            let mut got = bufs;
            broadcast(&ports, &mut got, root);
            for (g, w) in got.iter().zip(&want) {
                prop::close(g, w, 0.0)?;
            }
            if fab.in_flight() != 0 {
                return Err("fabric not drained".into());
            }
            Ok(())
        });
    }

    #[test]
    fn ring_all_to_all_matches_reference() {
        prop::check("ring a2a == ref a2a", 50, |rng| {
            let n = 1 + rng.below(6);
            let len = n * rng.below(5);
            let mut r = Rng::new(rng.next_u64());
            let bufs = rand_bufs(&mut r, n, len);
            let want = reference::all_to_all(&bufs);
            let (fab, ports) = ports_of(n);
            let got = all_to_all(&ports, &bufs);
            for (g, w) in got.iter().zip(&want) {
                prop::close(g, w, 0.0)?;
            }
            if fab.in_flight() != 0 {
                return Err("fabric not drained".into());
            }
            Ok(())
        });
    }

    #[test]
    fn ring_all_to_all_twice_is_identity() {
        prop::check("a2a involution", 30, |rng| {
            let n = 1 + rng.below(5);
            let len = n * (1 + rng.below(4));
            let mut r = Rng::new(rng.next_u64());
            let bufs = rand_bufs(&mut r, n, len);
            let (_fab, ports) = ports_of(n);
            let twice = all_to_all(&ports, &all_to_all(&ports, &bufs));
            for (a, b) in twice.iter().zip(&bufs) {
                prop::close(a, b, 0.0)?;
            }
            Ok(())
        });
    }

    #[test]
    fn rotate_ring_matches_reference_rotation() {
        prop::check("ring rotate == ref rotate", 60, |rng| {
            let n = 1 + rng.below(8);
            let (_fab, ports) = ports_of(n);
            for dir in [RotationDir::Clockwise, RotationDir::CounterClockwise] {
                let mut got: Vec<usize> = (0..n).collect();
                let mut want: Vec<usize> = (0..n).collect();
                rotate_ring(&ports, &mut got, dir);
                match dir {
                    RotationDir::Clockwise => reference::rotate_cw(&mut want),
                    RotationDir::CounterClockwise => reference::rotate_ccw(&mut want),
                }
                if got != want {
                    return Err(format!("{dir:?}: {got:?} != {want:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn allreduce_single_worker_noop() {
        let (_fab, ports) = ports_of(1);
        let mut bufs = vec![vec![5.0, 6.0]];
        allreduce_sum(&ports, &mut bufs);
        assert_eq!(bufs[0], vec![5.0, 6.0]);
    }

    #[test]
    fn empty_rank_sets_do_not_panic() {
        let (_fab, ports) = ports_of(1);
        assert!(reduce_scatter(&ports[..0], &[]).is_empty());
        assert!(allgather(&ports[..0], &[]).is_empty());
        assert!(all_to_all(&ports[..0], &[]).is_empty());
        broadcast(&ports[..0], &mut [], 0);
        allreduce_sum(&ports[..0], &mut []);
    }
}
