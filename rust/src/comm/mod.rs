//! Communication layer: ring rotation primitives (the paper's §3.3
//! contribution) plus the standard collectives the baselines use, and the
//! α-β cost model that prices all of them for the perf figures.
//!
//! Real-mode collectives operate on per-worker buffers (`&mut [Vec<f32>]`,
//! index = rank) and move actual data, replacing NCCL on the simulated
//! ring. Virtual-mode engines skip the data movement and only charge the
//! cost model — the *schedule* (who communicates what, when) is identical
//! because both modes run the same engine code.

pub mod cost;
pub mod rotation;

pub use cost::{CommPrim, LinkModel};
pub use rotation::{rotate_ccw, rotate_cw, RotationDir};

/// Ring all-reduce (sum): every worker ends with the elementwise sum of all
/// inputs. DDP's gradient reduction; also used for the replicated-parameter
/// grads in every multi-worker engine.
pub fn allreduce_sum(bufs: &mut [Vec<f32>]) {
    let n = bufs.len();
    if n <= 1 {
        return;
    }
    let len = bufs[0].len();
    assert!(
        bufs.iter().all(|b| b.len() == len),
        "allreduce buffers must be same-length"
    );
    let mut acc = vec![0.0f32; len];
    for b in bufs.iter() {
        for (a, v) in acc.iter_mut().zip(b) {
            *a += v;
        }
    }
    for b in bufs.iter_mut() {
        b.copy_from_slice(&acc);
    }
}

/// Ring all-gather: each worker contributes its shard; every worker ends
/// with the concatenation `[shard_0 | shard_1 | ... | shard_{N-1}]`.
/// FSDP's parameter reconstruction.
pub fn allgather(shards: &[Vec<f32>]) -> Vec<f32> {
    let mut full = Vec::with_capacity(shards.iter().map(|s| s.len()).sum());
    for s in shards {
        full.extend_from_slice(s);
    }
    full
}

/// Ring reduce-scatter (sum): input is one full-length buffer per worker;
/// worker `w` ends with the sum of everyone's shard `w`. FSDP's gradient
/// reduction. Returns one shard per worker; all inputs must be equal length
/// and divisible by N.
pub fn reduce_scatter(fulls: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let n = fulls.len();
    let len = fulls[0].len();
    assert!(
        fulls.iter().all(|f| f.len() == len),
        "reduce_scatter buffers must be same-length"
    );
    assert_eq!(len % n, 0, "reduce_scatter length {len} not divisible by {n}");
    let shard = len / n;
    (0..n)
        .map(|w| {
            let mut out = vec![0.0f32; shard];
            for f in fulls {
                for (o, v) in out.iter_mut().zip(&f[w * shard..(w + 1) * shard]) {
                    *o += v;
                }
            }
            out
        })
        .collect()
}

/// Broadcast from `root` to every worker.
pub fn broadcast(bufs: &mut [Vec<f32>], root: usize) {
    let src = bufs[root].clone();
    for (w, b) in bufs.iter_mut().enumerate() {
        if w != root {
            assert_eq!(b.len(), src.len(), "broadcast length mismatch");
            b.copy_from_slice(&src);
        }
    }
}

/// All-to-all: `bufs[w]` is worker w's send buffer split into N equal
/// chunks; chunk `d` goes to worker `d`. Worker w ends with
/// `[chunk_w_of_0 | chunk_w_of_1 | ...]`. The MoE baselines' token shuffle.
pub fn all_to_all(bufs: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let n = bufs.len();
    let len = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == len));
    assert_eq!(len % n, 0, "all_to_all length {len} not divisible by {n}");
    let chunk = len / n;
    (0..n)
        .map(|dst| {
            let mut out = Vec::with_capacity(len);
            for src in bufs {
                out.extend_from_slice(&src[dst * chunk..(dst + 1) * chunk]);
            }
            out
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn rand_bufs(rng: &mut Rng, n: usize, len: usize) -> Vec<Vec<f32>> {
        (0..n)
            .map(|_| (0..len).map(|_| rng.normal() as f32).collect())
            .collect()
    }

    #[test]
    fn allreduce_is_sum() {
        let mut bufs = vec![vec![1.0, 2.0], vec![10.0, 20.0], vec![100.0, 200.0]];
        allreduce_sum(&mut bufs);
        for b in &bufs {
            assert_eq!(b, &vec![111.0, 222.0]);
        }
    }

    #[test]
    fn allgather_concatenates_in_rank_order() {
        let shards = vec![vec![1.0], vec![2.0], vec![3.0]];
        assert_eq!(allgather(&shards), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn reduce_scatter_then_allgather_is_allreduce() {
        prop::check("rs+ag == ar", 50, |rng| {
            let n = 1 + rng.below(6);
            let len = n * (1 + rng.below(8));
            let bufs = rand_bufs(rng, n, len);
            let mut ar = bufs.clone();
            allreduce_sum(&mut ar);
            let shards = reduce_scatter(&bufs);
            let full = allgather(&shards);
            prop::close(&full, &ar[0], 1e-5)
        });
    }

    #[test]
    fn broadcast_copies_root() {
        let mut bufs = vec![vec![0.0; 2], vec![7.0, 8.0], vec![0.0; 2]];
        broadcast(&mut bufs, 1);
        for b in &bufs {
            assert_eq!(b, &vec![7.0, 8.0]);
        }
    }

    #[test]
    fn all_to_all_is_transpose() {
        // 2 workers, 2 chunks of 1: out[d] = [bufs[0][d], bufs[1][d]]
        let bufs = vec![vec![1.0, 2.0], vec![3.0, 4.0]];
        let out = all_to_all(&bufs);
        assert_eq!(out[0], vec![1.0, 3.0]);
        assert_eq!(out[1], vec![2.0, 4.0]);
    }

    #[test]
    fn all_to_all_twice_is_identity() {
        prop::check("a2a involution", 30, |rng| {
            let n = 1 + rng.below(5);
            let len = n * (1 + rng.below(4));
            let bufs = rand_bufs(rng, n, len);
            let twice = all_to_all(&all_to_all(&bufs));
            for (a, b) in twice.iter().zip(&bufs) {
                prop::close(a, b, 0.0)?;
            }
            Ok(())
        });
    }

    #[test]
    fn allreduce_single_worker_noop() {
        let mut bufs = vec![vec![5.0, 6.0]];
        allreduce_sum(&mut bufs);
        assert_eq!(bufs[0], vec![5.0, 6.0]);
    }
}
