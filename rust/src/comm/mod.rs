//! Communication layer: the rank-local ring fabric, the chunked ring
//! collectives built on it, the paper's rotation schedule (§3.3), and the
//! α-β cost model that prices everything per hop.
//!
//! Architecture (this is the substrate of the paper's two contributions):
//!
//! - [`fabric`] — `RingFabric` / `RingPort`: per-rank endpoints over
//!   lock-sharded per-link lanes (each directed link has its own
//!   mutex+condvar+FIFO+buffer pool), shared across OS threads. A rank
//!   can only talk to its ring neighbors, one hop at a time; every engine
//!   transfer goes through `port.send` / `port.recv`, and bulk `Vec<f32>`
//!   traffic rides the pooled `send_vec` / `recv_vec` / `lease` /
//!   `release` path, which performs zero heap allocations in steady
//!   state. Rank bodies run inside fabric *rounds* under a
//!   [`fabric::LaunchPolicy`]: `Lockstep` (deterministic round-robin
//!   coroutines) or `Threaded` (one OS thread per rank).
//! - [`stream`] — `CommStream`: a rank's handle for TRUE async rotation —
//!   under the Thread launcher the outgoing shard is enqueued before the
//!   step's compute runs (in flight while computing, §3.4.3); under
//!   Lockstep the same API degrades to the synchronous boundary hop, so
//!   both launchers stay bit-identical. Also `CollectiveStream`: the
//!   BACKGROUND COLLECTIVE ENGINE — each rank queues multi-hop
//!   collectives (`issue_allgather` / `issue_reduce_scatter` /
//!   `issue_allreduce`) that a dedicated per-rank comm thread executes
//!   over the fabric's background lane namespace while the rank body
//!   computes (FSDP's prefetch allgather and backward reduce-scatter,
//!   DDP/RTP's gradient allreduce), degrading to deterministic
//!   execute-at-join under Lockstep.
//! - [`coll`] — the resumable per-hop state machines
//!   (`AllGatherStep`/`ReduceScatterStep`/`AllReduceStep`) both the
//!   blocking collectives below and the comm threads drive.
//! - this module — the collectives, written RANK-LOCALLY: each function
//!   takes ONE port (this rank's) and this rank's buffer, and performs
//!   this rank's side of the hop schedule. All-reduce is reduce-scatter +
//!   all-gather in `2(N-1)` hops of `M/N`; all-gather / reduce-scatter
//!   are `N-1` hops; all-to-all is an `N-1`-hop chunk-peeling relay;
//!   rotation ([`rotate_ring`]) is ONE hop of the full shard — the §3.4.2
//!   identity "(N-1) rotations ≡ one allgather" is structural, not a
//!   formula. A collective only completes when every rank runs it — call
//!   them from rank bodies inside a fabric round (or use [`spmd`] /
//!   [`spmd_with`] to drive all ranks from a single test call site).
//! - [`rotation`] — the schedule math (`RotationDir`, `shard_at`): which
//!   shard sits on which rank after `t` hops.
//! - [`cost`] — the α-β model. `CommPrim::hop_schedule` exposes each
//!   collective's per-hop message sizes; `perfmodel::Timeline` charges hop
//!   by hop, so overlap renders show the real hop schedule.
//! - [`reference`] — the seed's god-view one-shot collectives, kept ONLY
//!   as test oracles for the ring implementations. Engines must not touch
//!   them.
//!
//! Real-mode collectives move actual data through the fabric (replacing
//! NCCL on the simulated ring); virtual-mode engines skip the data and
//! only charge the cost model — the *schedule* is identical because both
//! modes run the same engine code.
//!
//! Every function documents its hop count; a completed collective always
//! leaves the fabric drained. Because each directed link is FIFO and each
//! rank issues its port operations in a fixed program order, results are
//! bit-identical under the lockstep and threaded launch policies.

pub mod coll;
pub mod cost;
pub mod fabric;
pub mod reference;
pub mod rotation;
pub mod stream;
pub mod transport;
pub(crate) mod wire;

use std::any::Any;
use std::collections::VecDeque;

pub use coll::{AllGatherStep, AllReduceStep, CollKind, Collective, ReduceScatterStep};
pub use cost::{CommPrim, LinkModel};
pub use fabric::{FabricCounters, LaunchPolicy, RingFabric, RingPort};
pub use rotation::{shard_at, RotationDir};
pub use transport::{Transport, TransportKind};
pub use stream::{CollHandle, CollectiveStream, CommStream, InFlight, SchedPolicy};

use coll::chunk_bounds;

/// Drive one rank-local closure per rank through `fabric` on the
/// deterministic lockstep scheduler and return the per-rank results —
/// the single-call-site entry point tests, benches and oracles use to
/// exercise the SPMD collectives below.
pub fn spmd<T, F>(fabric: &RingFabric, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(RingPort) -> T + Sync,
{
    spmd_with(fabric, LaunchPolicy::Lockstep, f)
}

/// [`spmd`] under an explicit launch policy.
pub fn spmd_with<T, F>(fabric: &RingFabric, policy: LaunchPolicy, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(RingPort) -> T + Sync,
{
    let f = &f;
    let tasks: Vec<Box<dyn FnOnce() -> T + Send + '_>> = (0..fabric.n())
        .map(|r| {
            let port = fabric.port(r);
            Box::new(move || f(port)) as Box<dyn FnOnce() -> T + Send + '_>
        })
        .collect();
    fabric.run_round(policy, tasks)
}

/// This rank's side of a ring all-reduce (sum) in `2(N-1)` hops: a
/// reduce-scatter pass (this rank ends owning the fully-reduced chunk
/// matching its rank) followed by an all-gather pass. Every hop moves
/// ~`len/N` elements to the clockwise neighbor. DDP's gradient reduction;
/// also the replicated-grad reduction in every multi-worker engine.
///
/// Works for any buffer length (chunks may be uneven or empty); all
/// ranks must pass same-length buffers.
pub fn allreduce_sum(port: &RingPort, buf: &mut [f32]) {
    if port.n() <= 1 {
        return;
    }
    // drive the resumable hop machine to completion (per-hop scratch is
    // leased from the outgoing lane's pool and released to the incoming
    // lane's — in steady state the same buffers cycle the ring, zero
    // allocations)
    let mut st = AllReduceStep::new(port, buf.len());
    while !st.step(port, buf) {}
}

/// This rank's side of a ring all-gather in `N-1` hops, returning its
/// view of all N shard payloads (unconcatenated, in shard order). Shards
/// may have different lengths. This is the primitive; [`allgather`]
/// concatenates.
pub fn allgather_parts(port: &RingPort, mine: &[f32]) -> Vec<Vec<f32>> {
    let n = port.n();
    let w = port.rank();
    if n == 1 {
        return vec![mine.to_vec()];
    }
    // hold[c] = shard c's payload once it has reached this rank. The
    // received shards ARE the result, so they are not released back to
    // the lane pools; forwarding copies still lease their scratch.
    let mut hold: Vec<Option<Vec<f32>>> = (0..n).map(|_| None).collect();
    hold[w] = Some(mine.to_vec());
    for s in 0..n - 1 {
        let c_send = (w + n - s) % n;
        let src = hold[c_send].as_ref().expect("allgather schedule hole");
        let mut payload = port.lease(port.next(), src.len());
        payload.extend_from_slice(src);
        port.send_vec(port.next(), payload);
        let c_recv = (w + 2 * n - s - 1) % n;
        hold[c_recv] = Some(port.recv_vec(port.prev()));
    }
    hold.into_iter()
        .map(|o| o.expect("allgather incomplete"))
        .collect()
}

/// This rank's side of a ring all-gather in `N-1` hops: returns the
/// concatenation `[shard_0 | shard_1 | ... | shard_{N-1}]`. FSDP's
/// parameter reconstruction.
pub fn allgather(port: &RingPort, mine: &[f32]) -> Vec<f32> {
    let parts = allgather_parts(port, mine);
    let mut full = Vec::with_capacity(parts.iter().map(|p| p.len()).sum());
    for p in parts {
        full.extend_from_slice(&p);
    }
    full
}

/// [`allgather`] for EQUAL-LENGTH shards, writing the concatenation into
/// a caller-owned buffer (capacity reused across calls) and recycling
/// every received hop buffer back to the lane pools — the
/// zero-steady-state-allocation path the background collective engine
/// drives ([`Collective::allgather`] is the queued form of the same hop
/// machine).
pub fn allgather_into(port: &RingPort, mine: &[f32], out: &mut Vec<f32>) {
    let (n, w, l) = (port.n(), port.rank(), mine.len());
    out.clear();
    out.resize(n * l, 0.0);
    out[w * l..(w + 1) * l].copy_from_slice(mine);
    let mut st = AllGatherStep::new(port, l);
    while !st.step(port, out) {}
}

/// This rank's side of a ring reduce-scatter (sum) in `N-1` hops: input
/// is this rank's full-length buffer; rank `w` ends with the sum of
/// everyone's shard `w`. FSDP's gradient reduction. All inputs must be
/// equal length and divisible by N. Empty input returns empty.
pub fn reduce_scatter(port: &RingPort, full: &[f32]) -> Vec<f32> {
    let n = port.n();
    if n == 1 {
        return full.to_vec();
    }
    let mut acc = full.to_vec();
    let mut st = ReduceScatterStep::new(port, full.len());
    let range = st.shard_range();
    while !st.step(port, &mut acc) {}
    acc[range].to_vec()
}

/// This rank's side of a pipelined ring broadcast from `root`: the
/// payload is split into N-1 chunks that stream clockwise down the ring.
/// The root sends every chunk once; each non-terminal relay forwards each
/// chunk once — `(N-1)²` chunk messages total, and the bottleneck link
/// carries `M` bytes over its N-1 chunk stages, matching the
/// `α(N-1) + Mβ` closed form.
pub fn broadcast(port: &RingPort, buf: &mut [f32], root: usize) {
    let n = port.n();
    if n <= 1 {
        return;
    }
    let w = port.rank();
    // position along the pipeline: 0 = root, n-1 = last receiver
    let j = (w + n - root) % n;
    let ch = chunk_bounds(buf.len(), n - 1);
    if j == 0 {
        for &(a, b) in &ch {
            let mut out = port.lease(port.next(), b - a);
            out.extend_from_slice(&buf[a..b]);
            port.send_vec(port.next(), out);
        }
    } else {
        for &(a, b) in &ch {
            let msg = port.recv_vec(port.prev());
            debug_assert_eq!(msg.len(), b - a, "broadcast peers disagree on length");
            buf[a..b].copy_from_slice(&msg);
            if j < n - 1 {
                // relays forward the SAME buffer onward — zero copies,
                // zero allocations on the pipeline's interior
                port.send_vec(port.next(), msg);
            } else {
                port.release(port.prev(), msg);
            }
        }
    }
}

/// This rank's side of a ring all-to-all in `N-1` hops: `mine` is this
/// rank's send buffer split into N equal chunks; chunk `d` goes to rank
/// `d`. Returns `[chunk_w_of_0 | chunk_w_of_1 | ...]` — the MoE
/// baselines' token shuffle.
///
/// Implemented as a CHUNK-PEELING relay: each source's packet travels
/// clockwise carrying only the chunks not yet delivered, and every rank
/// peels its own chunk off the front as the packet passes through. Hop
/// `h` (1-based) therefore moves `(N-h)·M/N` bytes per rank — exactly
/// the `CommPrim::AllToAll` hop schedule the α-β model charges
/// (`(N-1)·α + M·β·(N-1)/2` total), byte-for-byte.
pub fn all_to_all(port: &RingPort, mine: &[f32]) -> Vec<f32> {
    let n = port.n();
    let w = port.rank();
    let len = mine.len();
    assert_eq!(len % n, 0, "all_to_all length {len} not divisible by {n}");
    if n == 1 {
        return mine.to_vec();
    }
    let chunk = len / n;
    let mut out = vec![0.0f32; len];
    // own chunk needs no hop
    out[w * chunk..(w + 1) * chunk].copy_from_slice(&mine[w * chunk..(w + 1) * chunk]);
    // my packet: chunks for the other ranks in ring-visiting order
    // (front = my clockwise neighbor, who peels first)
    let mut packet: (usize, VecDeque<Vec<f32>>) = (
        w,
        (1..n)
            .map(|d| {
                let dst = (w + d) % n;
                mine[dst * chunk..(dst + 1) * chunk].to_vec()
            })
            .collect(),
    );
    for _hop in 0..n - 1 {
        port.send(port.next(), packet);
        let (src, mut chunks): (usize, VecDeque<Vec<f32>>) = port.recv(port.prev());
        let my_chunk = chunks.pop_front().expect("peeling relay exhausted early");
        debug_assert_eq!(my_chunk.len(), chunk, "all_to_all peers disagree on length");
        out[src * chunk..(src + 1) * chunk].copy_from_slice(&my_chunk);
        packet = (src, chunks);
    }
    debug_assert!(packet.1.is_empty(), "undelivered chunks left in relay");
    out
}

/// One ring rotation hop (the paper's §3.3 primitive): this rank sends
/// `item` to `dir.send_peer` and receives its upstream neighbor's from
/// `dir.recv_peer`. Generic over the payload: the engines rotate shard
/// structs in real mode and bare shard ids in virtual mode — identical
/// schedule either way.
pub fn rotate_ring<T: Any + Send>(port: &RingPort, item: T, dir: RotationDir) -> T {
    let n = port.n();
    if n <= 1 {
        return item;
    }
    let w = port.rank();
    port.send(dir.send_peer(w, n), item);
    port.recv(dir.recv_peer(w, n))
}

/// [`rotate_ring`] on the pooled typed path: the buffer itself travels
/// the ring unboxed (no allocation at all — the ownership of the `Vec`
/// moves through the lane), and this rank returns owning its upstream
/// neighbor's buffer. The zero-steady-state-allocation rotation primitive
/// asserted by `tests/fabric_hotpath.rs`.
pub fn rotate_ring_vec(port: &RingPort, buf: Vec<f32>, dir: RotationDir) -> Vec<f32> {
    let n = port.n();
    if n <= 1 {
        return buf;
    }
    let w = port.rank();
    port.send_vec(dir.send_peer(w, n), buf);
    port.recv_vec(dir.recv_peer(w, n))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn rand_bufs(rng: &mut Rng, n: usize, len: usize) -> Vec<Vec<f32>> {
        (0..n)
            .map(|_| (0..len).map(|_| rng.normal() as f32).collect())
            .collect()
    }

    #[test]
    fn chunk_bounds_cover_and_balance() {
        prop::check("chunk bounds", 100, |rng| {
            let n = 1 + rng.below(9);
            let len = rng.below(40);
            let ch = chunk_bounds(len, n);
            if ch.len() != n {
                return Err("wrong chunk count".into());
            }
            if ch[0].0 != 0 || ch[n - 1].1 != len {
                return Err("chunks do not cover".into());
            }
            for i in 1..n {
                if ch[i].0 != ch[i - 1].1 {
                    return Err("chunks not contiguous".into());
                }
            }
            let sizes: Vec<usize> = ch.iter().map(|(a, b)| b - a).collect();
            let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            if mx - mn > 1 {
                return Err(format!("unbalanced chunks {sizes:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn ring_allreduce_is_sum() {
        let bufs = vec![vec![1.0, 2.0], vec![10.0, 20.0], vec![100.0, 200.0]];
        let fab = RingFabric::new(3);
        let got = spmd(&fab, |port| {
            let mut b = bufs[port.rank()].clone();
            allreduce_sum(&port, &mut b);
            b
        });
        for b in &got {
            assert_eq!(b, &vec![111.0, 222.0]);
        }
        assert_eq!(fab.in_flight(), 0);
    }

    #[test]
    fn ring_allreduce_matches_reference_under_both_policies() {
        prop::check("ring ar == ref ar", 40, |rng| {
            let n = 1 + rng.below(8);
            let len = rng.below(30); // any length, incl. 0 and < n
            let mut r = Rng::new(rng.next_u64());
            let bufs = rand_bufs(&mut r, n, len);
            let mut want = bufs.clone();
            reference::allreduce_sum(&mut want);
            for policy in [LaunchPolicy::Lockstep, LaunchPolicy::Threaded] {
                let fab = RingFabric::new(n);
                let got = spmd_with(&fab, policy, |port| {
                    let mut b = bufs[port.rank()].clone();
                    allreduce_sum(&port, &mut b);
                    b
                });
                for (g, w) in got.iter().zip(&want) {
                    prop::close(g, w, 1e-4)?;
                }
                if fab.in_flight() != 0 {
                    return Err("fabric not drained".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn ring_allreduce_performs_2n_minus_2_hops() {
        // 2(N-1) hops × N rank-messages per hop
        for n in [2usize, 4, 8] {
            let fab = RingFabric::new(n);
            spmd(&fab, |port| {
                let mut b = vec![1.0f32; 4 * n];
                allreduce_sum(&port, &mut b);
            });
            assert_eq!(fab.messages_sent(), (2 * (n - 1) * n) as u64, "n={n}");
            assert_eq!(fab.in_flight(), 0);
        }
    }

    #[test]
    fn ring_allgather_concatenates_in_rank_order() {
        let shards = vec![vec![1.0], vec![2.0], vec![3.0]];
        let fab = RingFabric::new(3);
        for full in spmd(&fab, |port| allgather(&port, &shards[port.rank()])) {
            assert_eq!(full, vec![1.0, 2.0, 3.0]);
        }
    }

    #[test]
    fn ring_allgather_matches_reference() {
        prop::check("ring ag == ref ag", 60, |rng| {
            let n = 1 + rng.below(8);
            let mut r = Rng::new(rng.next_u64());
            // deliberately unequal shard lengths
            let shards: Vec<Vec<f32>> = (0..n)
                .map(|_| {
                    let l = rng.below(7);
                    (0..l).map(|_| r.normal() as f32).collect()
                })
                .collect();
            let want = reference::allgather(&shards);
            let fab = RingFabric::new(n);
            for full in spmd(&fab, |port| allgather(&port, &shards[port.rank()])) {
                prop::close(&full, &want, 0.0)?;
            }
            if fab.in_flight() != 0 {
                return Err("fabric not drained".into());
            }
            Ok(())
        });
    }

    #[test]
    fn allgather_into_matches_reference() {
        prop::check("ag into == ref ag", 40, |rng| {
            let n = 1 + rng.below(8);
            let l = rng.below(6); // equal-length shards, incl. empty
            let mut r = Rng::new(rng.next_u64());
            let shards: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..l).map(|_| r.normal() as f32).collect())
                .collect();
            let want = reference::allgather(&shards);
            let fab = RingFabric::new(n);
            let got = spmd(&fab, |port| {
                let mut out = Vec::new();
                allgather_into(&port, &shards[port.rank()], &mut out);
                out
            });
            for g in &got {
                prop::close(g, &want, 0.0)?;
            }
            if fab.in_flight() != 0 {
                return Err("fabric not drained".into());
            }
            Ok(())
        });
    }

    #[test]
    fn ring_reduce_scatter_matches_reference() {
        prop::check("ring rs == ref rs", 60, |rng| {
            let n = 1 + rng.below(8);
            let len = n * rng.below(7); // divisible, possibly 0
            let mut r = Rng::new(rng.next_u64());
            let fulls = rand_bufs(&mut r, n, len);
            let want = reference::reduce_scatter(&fulls);
            let fab = RingFabric::new(n);
            let got = spmd(&fab, |port| reduce_scatter(&port, &fulls[port.rank()]));
            for (g, w) in got.iter().zip(&want) {
                prop::close(g, w, 1e-4)?;
            }
            if fab.in_flight() != 0 {
                return Err("fabric not drained".into());
            }
            Ok(())
        });
    }

    #[test]
    fn reduce_scatter_then_allgather_is_allreduce() {
        prop::check("rs+ag == ar", 50, |rng| {
            let n = 1 + rng.below(6);
            let len = n * (1 + rng.below(8));
            let mut r = Rng::new(rng.next_u64());
            let bufs = rand_bufs(&mut r, n, len);
            let fab = RingFabric::new(n);
            let (ar, full0) = {
                let out = spmd(&fab, |port| {
                    let w = port.rank();
                    let mut ar = bufs[w].clone();
                    allreduce_sum(&port, &mut ar);
                    let shard = reduce_scatter(&port, &bufs[w]);
                    let full = allgather(&port, &shard);
                    (ar, full)
                });
                let (a, f) = (&out[0].0, &out[0].1);
                (a.clone(), f.clone())
            };
            prop::close(&full0, &ar, 1e-5)
        });
    }

    #[test]
    fn ring_broadcast_matches_reference() {
        prop::check("ring bc == ref bc", 50, |rng| {
            let n = 1 + rng.below(8);
            let len = rng.below(10);
            let mut r = Rng::new(rng.next_u64());
            let bufs = rand_bufs(&mut r, n, len);
            let root = rng.below(n);
            let mut want = bufs.clone();
            reference::broadcast(&mut want, root);
            let fab = RingFabric::new(n);
            let got = spmd(&fab, |port| {
                let mut b = bufs[port.rank()].clone();
                broadcast(&port, &mut b, root);
                b
            });
            for (g, w) in got.iter().zip(&want) {
                prop::close(g, w, 0.0)?;
            }
            if fab.in_flight() != 0 {
                return Err("fabric not drained".into());
            }
            Ok(())
        });
    }

    #[test]
    fn ring_all_to_all_matches_reference() {
        prop::check("ring a2a == ref a2a", 50, |rng| {
            let n = 1 + rng.below(6);
            let len = n * rng.below(5);
            let mut r = Rng::new(rng.next_u64());
            let bufs = rand_bufs(&mut r, n, len);
            let want = reference::all_to_all(&bufs);
            let fab = RingFabric::new(n);
            let got = spmd(&fab, |port| all_to_all(&port, &bufs[port.rank()]));
            for (g, w) in got.iter().zip(&want) {
                prop::close(g, w, 0.0)?;
            }
            if fab.in_flight() != 0 {
                return Err("fabric not drained".into());
            }
            Ok(())
        });
    }

    #[test]
    fn ring_all_to_all_twice_is_identity() {
        prop::check("a2a involution", 30, |rng| {
            let n = 1 + rng.below(5);
            let len = n * (1 + rng.below(4));
            let mut r = Rng::new(rng.next_u64());
            let bufs = rand_bufs(&mut r, n, len);
            let fab = RingFabric::new(n);
            let twice = spmd(&fab, |port| {
                let once = all_to_all(&port, &bufs[port.rank()]);
                all_to_all(&port, &once)
            });
            for (a, b) in twice.iter().zip(&bufs) {
                prop::close(a, b, 0.0)?;
            }
            Ok(())
        });
    }

    #[test]
    fn all_to_all_peels_chunks_per_hop() {
        // the peeling relay sends exactly n(n-1) chunk-carrying messages
        // and the per-hop payload matches the cost model's shrinking
        // schedule (checked indirectly: total chunks moved = n(n-1))
        for n in [2usize, 3, 4, 8] {
            let fab = RingFabric::new(n);
            let bufs: Vec<Vec<f32>> =
                (0..n).map(|w| vec![w as f32; 4 * n]).collect();
            spmd(&fab, |port| all_to_all(&port, &bufs[port.rank()]));
            // one packet message per rank per hop
            assert_eq!(fab.messages_sent(), (n * (n - 1)) as u64, "n={n}");
            assert_eq!(fab.in_flight(), 0);
        }
    }

    #[test]
    fn rotate_ring_matches_reference_rotation() {
        prop::check("ring rotate == ref rotate", 60, |rng| {
            let n = 1 + rng.below(8);
            let fab = RingFabric::new(n);
            for dir in [RotationDir::Clockwise, RotationDir::CounterClockwise] {
                let got = spmd(&fab, |port| rotate_ring(&port, port.rank(), dir));
                let mut want: Vec<usize> = (0..n).collect();
                match dir {
                    RotationDir::Clockwise => reference::rotate_cw(&mut want),
                    RotationDir::CounterClockwise => reference::rotate_ccw(&mut want),
                }
                if got != want {
                    return Err(format!("{dir:?}: {got:?} != {want:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn rotate_ring_vec_matches_boxed_rotation() {
        prop::check("pooled rotate == boxed rotate", 40, |rng| {
            let n = 1 + rng.below(8);
            let len = rng.below(10);
            let mut r = Rng::new(rng.next_u64());
            let bufs = rand_bufs(&mut r, n, len);
            for dir in [RotationDir::Clockwise, RotationDir::CounterClockwise] {
                for policy in [LaunchPolicy::Lockstep, LaunchPolicy::Threaded] {
                    let fab = RingFabric::new(n);
                    let pooled = spmd_with(&fab, policy, |port| {
                        rotate_ring_vec(&port, bufs[port.rank()].clone(), dir)
                    });
                    let fab2 = RingFabric::new(n);
                    let boxed = spmd(&fab2, |port| {
                        rotate_ring(&port, bufs[port.rank()].clone(), dir)
                    });
                    for (p, b) in pooled.iter().zip(&boxed) {
                        prop::close(p, b, 0.0)?;
                    }
                    if fab.in_flight() != 0 {
                        return Err("pooled rotation left messages in flight".into());
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn allreduce_single_worker_noop() {
        let fab = RingFabric::new(1);
        let got = spmd(&fab, |port| {
            let mut b = vec![5.0f32, 6.0];
            allreduce_sum(&port, &mut b);
            b
        });
        assert_eq!(got[0], vec![5.0, 6.0]);
    }

    #[test]
    fn single_rank_collectives_are_local() {
        let fab = RingFabric::new(1);
        let got = spmd(&fab, |port| {
            let rs = reduce_scatter(&port, &[1.0, 2.0]);
            let ag = allgather(&port, &rs);
            let a2a = all_to_all(&port, &ag);
            let mut bc = a2a.clone();
            broadcast(&port, &mut bc, 0);
            bc
        });
        assert_eq!(got[0], vec![1.0, 2.0]);
        assert_eq!(fab.messages_sent(), 0);
    }
}
