//! God-view reference collectives — TEST ORACLES ONLY.
//!
//! These are the seed's original one-shot implementations: each computes a
//! whole collective by directly reading/writing every rank's buffer in one
//! function body. They erase the hop structure the paper's cost analysis
//! and overlap scheduling depend on, so no engine is allowed to call them;
//! they survive solely so the property tests and microbenches can check
//! the chunked ring-fabric implementations in [`crate::comm`] against a
//! trivially-correct baseline.

/// Reference all-reduce (sum): one-shot accumulate + copy-back.
pub fn allreduce_sum(bufs: &mut [Vec<f32>]) {
    let n = bufs.len();
    if n <= 1 {
        return;
    }
    let len = bufs[0].len();
    assert!(
        bufs.iter().all(|b| b.len() == len),
        "allreduce buffers must be same-length"
    );
    let mut acc = vec![0.0f32; len];
    for b in bufs.iter() {
        for (a, v) in acc.iter_mut().zip(b) {
            *a += v;
        }
    }
    for b in bufs.iter_mut() {
        b.copy_from_slice(&acc);
    }
}

/// Reference all-gather: plain concatenation in rank order.
pub fn allgather(shards: &[Vec<f32>]) -> Vec<f32> {
    let mut full = Vec::with_capacity(shards.iter().map(|s| s.len()).sum());
    for s in shards {
        full.extend_from_slice(s);
    }
    full
}

/// Reference reduce-scatter (sum): worker `w` ends with the sum of
/// everyone's shard `w`. Inputs must be equal length, divisible by N.
pub fn reduce_scatter(fulls: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let n = fulls.len();
    if n == 0 {
        return Vec::new();
    }
    let len = fulls[0].len();
    assert!(
        fulls.iter().all(|f| f.len() == len),
        "reduce_scatter buffers must be same-length"
    );
    assert_eq!(len % n, 0, "reduce_scatter length {len} not divisible by {n}");
    if n == 1 {
        return vec![fulls[0].clone()];
    }
    let shard = len / n;
    (0..n)
        .map(|w| {
            let mut out = vec![0.0f32; shard];
            for f in fulls {
                for (o, v) in out.iter_mut().zip(&f[w * shard..(w + 1) * shard]) {
                    *o += v;
                }
            }
            out
        })
        .collect()
}

/// Reference broadcast from `root`.
pub fn broadcast(bufs: &mut [Vec<f32>], root: usize) {
    if bufs.len() <= 1 {
        return;
    }
    let src = bufs[root].clone();
    for (w, b) in bufs.iter_mut().enumerate() {
        if w != root {
            assert_eq!(b.len(), src.len(), "broadcast length mismatch");
            b.copy_from_slice(&src);
        }
    }
}

/// Reference all-to-all: chunk transpose in one shot.
pub fn all_to_all(bufs: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let n = bufs.len();
    if n == 0 {
        return Vec::new();
    }
    let len = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == len));
    assert_eq!(len % n, 0, "all_to_all length {len} not divisible by {n}");
    let chunk = len / n;
    (0..n)
        .map(|dst| {
            let mut out = Vec::with_capacity(len);
            for src in bufs {
                out.extend_from_slice(&src[dst * chunk..(dst + 1) * chunk]);
            }
            out
        })
        .collect()
}

/// Reference clockwise rotation: `new[w] = old[w-1]`, via slice rotate.
pub fn rotate_cw<T>(bufs: &mut [T]) {
    bufs.rotate_right(1);
}

/// Reference counter-clockwise rotation: `new[w] = old[w+1]`.
pub fn rotate_ccw<T>(bufs: &mut [T]) {
    bufs.rotate_left(1);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allreduce_is_sum() {
        let mut bufs = vec![vec![1.0, 2.0], vec![10.0, 20.0], vec![100.0, 200.0]];
        allreduce_sum(&mut bufs);
        for b in &bufs {
            assert_eq!(b, &vec![111.0, 222.0]);
        }
    }

    #[test]
    fn allgather_concatenates_in_rank_order() {
        let shards = vec![vec![1.0], vec![2.0], vec![3.0]];
        assert_eq!(allgather(&shards), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn broadcast_copies_root() {
        let mut bufs = vec![vec![0.0; 2], vec![7.0, 8.0], vec![0.0; 2]];
        broadcast(&mut bufs, 1);
        for b in &bufs {
            assert_eq!(b, &vec![7.0, 8.0]);
        }
    }

    #[test]
    fn all_to_all_is_transpose() {
        let bufs = vec![vec![1.0, 2.0], vec![3.0, 4.0]];
        let out = all_to_all(&bufs);
        assert_eq!(out[0], vec![1.0, 3.0]);
        assert_eq!(out[1], vec![2.0, 4.0]);
    }

    #[test]
    fn empty_inputs_do_not_panic() {
        // the seed indexed fulls[0] unconditionally and panicked here
        assert!(reduce_scatter(&[]).is_empty());
        assert!(all_to_all(&[]).is_empty());
        broadcast(&mut [], 0);
        allreduce_sum(&mut []);
        assert!(allgather(&[]).is_empty());
    }

    #[test]
    fn single_worker_collectives_are_identity() {
        let one = vec![vec![5.0, 6.0]];
        let mut ar = one.clone();
        allreduce_sum(&mut ar);
        assert_eq!(ar, one);
        assert_eq!(reduce_scatter(&one), one);
        assert_eq!(all_to_all(&one), one);
    }

    #[test]
    fn rotations_shift_by_one() {
        let mut v = vec![0, 1, 2, 3];
        rotate_cw(&mut v);
        assert_eq!(v, vec![3, 0, 1, 2]);
        rotate_ccw(&mut v);
        assert_eq!(v, vec![0, 1, 2, 3]);
    }
}
