//! Pluggable byte transports for the ring fabric's directed links.
//!
//! Every directed link × lane namespace of the [`crate::comm::RingFabric`]
//! can route its payload bytes through a [`Transport`] backend:
//!
//! - [`TransportKind::Inproc`] — no byte transport at all: payloads stay
//!   in the in-process lane FIFO (`Vec<f32>` moves under a mutex). The
//!   historical behavior and the bit-identity oracle. Fast, but every
//!   published number measured over it is an in-process artifact: no
//!   serialization, no copy across an OS boundary.
//! - [`TransportKind::Shm`] — a shared-memory SPSC byte ring per directed
//!   link ([`ShmRing`]): a file on `/dev/shm` holding a sender-owned tail
//!   cursor, a receiver-owned head cursor, and a power-of-two data region.
//!   A hop writes the payload in place (one copy into the page cache) and
//!   performs ZERO steady-state heap allocations — the perf hot path, and
//!   the backend `Launcher::Process` workers in different address spaces
//!   meet on.
//! - [`TransportKind::Uds`] — a Unix-domain-socket stream per directed
//!   link ([`UdsLink`]): the portable, deliberately boring reference. Its
//!   length-prefixed framing is exactly what a future TCP backend reuses.
//!
//! ## Framing
//!
//! A frame is `[len: u32 le][len bytes]`. What the bytes mean is the
//! fabric's business: the in-process transport bypass carries raw
//! little-endian `f32` payloads (a lane marker preserves ordering), the
//! cross-process mode carries [`crate::comm::wire`]-encoded messages.
//!
//! ## The never-blocking-send contract
//!
//! Fabric lanes are unbounded: a sender NEVER blocks (the schedule, not
//! backpressure, bounds in-flight data — Lockstep determinism depends on
//! it). Byte transports are bounded, so each backend keeps a sender-side
//! spill: frames that do not fit right now queue in memory and are flushed
//! by [`Transport::pump`] — called by the sender on its next operation and
//! by any receiver polling the link (in process, the receiver can flush
//! the sender's spill directly; across processes each side pumps its own).
//! Frames larger than half the shm ring take the jumbo side-file path, so
//! no payload can jam the ring permanently.

use std::collections::VecDeque;
use std::fs::{File, OpenOptions};
use std::io::{Read, Write};
use std::os::unix::fs::FileExt;
use std::os::unix::net::UnixStream;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

/// Which byte transport backs the fabric's directed links.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process lane FIFOs only (no byte transport). The default.
    Inproc,
    /// Shared-memory SPSC ring per directed link (zero-alloc hot path).
    Shm,
    /// Unix-domain-socket stream per directed link (portable reference).
    Uds,
}

impl TransportKind {
    /// `RTP_TRANSPORT` env knob: `inproc` (default) | `shm` | `uds`.
    pub fn from_env() -> TransportKind {
        match std::env::var("RTP_TRANSPORT") {
            Ok(v) => match v.trim() {
                "" | "inproc" => TransportKind::Inproc,
                "shm" => TransportKind::Shm,
                "uds" | "unix" => TransportKind::Uds,
                other => panic!(
                    "RTP_TRANSPORT={other:?}: expected one of inproc|shm|uds"
                ),
            },
            Err(_) => TransportKind::Inproc,
        }
    }

    pub fn parse(s: &str) -> Option<TransportKind> {
        Some(match s {
            "inproc" => TransportKind::Inproc,
            "shm" => TransportKind::Shm,
            "uds" | "unix" => TransportKind::Uds,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            TransportKind::Inproc => "inproc",
            TransportKind::Shm => "shm",
            TransportKind::Uds => "uds",
        }
    }
}

impl std::fmt::Display for TransportKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One directed byte link. Implementations are internally synchronized
/// (one sender thread and one receiver thread may use the same object).
pub trait Transport: Send + Sync {
    fn kind(&self) -> TransportKind;

    /// Append one frame whose payload is `head` followed by `body`.
    /// NEVER blocks: a frame that does not fit is spilled sender-side.
    fn send_frame_parts(&self, head: &[u8], body: &[u8]);

    /// Pop the oldest complete frame into `out` (cleared first). Returns
    /// false when no complete frame is available right now.
    fn try_recv_frame(&self, out: &mut Vec<u8>) -> bool;

    /// Pop the oldest complete frame, interpreting its payload as raw
    /// little-endian `f32`s (the in-process pooled hot path).
    fn try_recv_f32_frame(&self, out: &mut Vec<f32>) -> bool;

    /// Is a complete frame ready to pop without blocking? (Readiness
    /// heuristic for the hop scheduler — never consumes.)
    fn frame_ready(&self) -> bool;

    /// Flush sender-side spilled bytes into the underlying channel as far
    /// as it will accept them. Safe to call from either side in process;
    /// across processes each side pumps its own endpoint.
    fn pump(&self);

    /// Discard everything in flight (poisoned-round teardown, after all
    /// rank threads have quiesced) so the next round starts clean.
    fn reset(&self);

    /// Has the remote endpoint gone away (EOF on the stream)? Always
    /// false for backends that cannot tell (shm).
    fn peer_gone(&self) -> bool {
        false
    }
}

/// Append one frame composed only of `data` (no head part).
pub fn send_frame(t: &dyn Transport, data: &[u8]) {
    t.send_frame_parts(data, &[]);
}

/// View a `&[f32]` as its raw bytes. On the little-endian targets this
/// crate runs on, this is exactly the le-bytes wire form, with no
/// per-element conversion copy — the "payload written in place" half of
/// the shm hot path.
pub(crate) fn f32s_as_bytes(v: &[f32]) -> &[u8] {
    // SAFETY: f32 has no invalid bit patterns, alignment of u8 (1) is
    // always satisfied, and the length in bytes cannot overflow isize for
    // an existing allocation.
    unsafe { std::slice::from_raw_parts(v.as_ptr().cast::<u8>(), v.len() * 4) }
}

/// Decode raw little-endian `f32` bytes into `out` (cleared first).
pub(crate) fn f32s_from_bytes(b: &[u8], out: &mut Vec<f32>) {
    assert_eq!(b.len() % 4, 0, "f32 frame length {} not a multiple of 4", b.len());
    out.clear();
    out.extend(
        b.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])),
    );
}

// ---------------------------------------------------------------------------
// Endpoint naming
// ---------------------------------------------------------------------------

/// Base directory for shm ring files: `/dev/shm` (tmpfs — page-cache
/// backed, never touches disk) when present, the system temp dir
/// otherwise.
pub fn shm_base_dir() -> PathBuf {
    let p = Path::new("/dev/shm");
    if p.is_dir() {
        p.to_path_buf()
    } else {
        std::env::temp_dir()
    }
}

/// A process-unique endpoint directory name (`rtp-<tag>-<pid>-<seq>`).
pub fn unique_endpoint_dir(base: &Path, tag: &str) -> PathBuf {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    base.join(format!("rtp-{tag}-{}-{seq}", std::process::id()))
}

/// Ring file for directed link `src -> dst` on lane namespace `ch`.
pub fn shm_ring_path(dir: &Path, ch: usize, src: usize, dst: usize) -> PathBuf {
    dir.join(format!("c{ch}-s{src}-d{dst}.ring"))
}

/// Socket path for directed link `src -> dst` on lane namespace `ch`.
pub fn uds_sock_path(dir: &Path, ch: usize, src: usize, dst: usize) -> PathBuf {
    dir.join(format!("c{ch}-s{src}-d{dst}.sock"))
}

/// `RTP_SHM_RING_BYTES` env knob (default 1 MiB, rounded up to a multiple
/// of 8). Ring files are sparse: untouched capacity costs nothing.
pub fn shm_ring_bytes_from_env() -> u64 {
    let v = std::env::var("RTP_SHM_RING_BYTES")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(1 << 20);
    (v.max(64) + 7) & !7
}

// ---------------------------------------------------------------------------
// Shared-memory SPSC ring
// ---------------------------------------------------------------------------

/// File layout: `[tail: u64 le][head: u64 le][pad to 64][data: cap bytes]`.
/// `tail` (bytes ever written) is sender-owned; `head` (bytes ever
/// consumed) is receiver-owned — each side writes only its own cursor, so
/// no cross-side lock exists. Records are 8-byte aligned; a record is
/// `[len: u32][payload][pad]`, with two reserved `len` tags for ring-end
/// skip markers and jumbo side-file frames.
const TAIL_OFF: u64 = 0;
const HEAD_OFF: u64 = 8;
const DATA_OFF: u64 = 64;
/// Record tag: rest of the ring (to the wrap point) is dead space.
const TAG_SKIP: u32 = u32::MAX;
/// Record tag: payload is in the side file `<ring>.jumbo-<seq>`.
const TAG_JUMBO: u32 = u32::MAX - 1;
/// Largest payload carried inline (larger frames take the side file).
const MAX_INLINE: u32 = u32::MAX - 2;

struct ShmTx {
    /// Sender-owned tail cursor (mirrors the file's).
    tail: u64,
    /// Last head value read back from the receiver.
    head_seen: u64,
    /// Frames that did not fit, in order (flushed by `pump`).
    spill: VecDeque<Vec<u8>>,
    /// Monotonic id for jumbo side files.
    jumbo_seq: u64,
}

struct ShmRx {
    /// Receiver-owned head cursor (mirrors the file's).
    head: u64,
    /// Last tail value read from the sender.
    tail_seen: u64,
    /// Reused byte scratch for f32 frame decodes.
    scratch: Vec<u8>,
}

/// The shm backend: one SPSC byte ring in a (tmpfs) file. Used from both
/// ends of a link in process, or one end per process across a
/// `Launcher::Process` boundary (same path, page-cache coherent).
pub struct ShmRing {
    file: File,
    path: PathBuf,
    cap: u64,
    tx: Mutex<ShmTx>,
    rx: Mutex<ShmRx>,
}

impl ShmRing {
    /// Open (creating and sizing if needed) the ring file at `path` with
    /// `cap` data bytes. Both endpoints of a link open the same path with
    /// the same `cap`; creation is idempotent.
    pub fn open(path: &Path, cap: u64) -> std::io::Result<ShmRing> {
        assert!(cap >= 64 && cap % 8 == 0, "ring capacity must be >= 64 and 8-aligned");
        let file = OpenOptions::new().read(true).write(true).create(true).open(path)?;
        let need = DATA_OFF + cap;
        if file.metadata()?.len() < need {
            file.set_len(need)?;
        }
        Ok(ShmRing {
            file,
            path: path.to_path_buf(),
            cap,
            tx: Mutex::new(ShmTx {
                tail: 0,
                head_seen: 0,
                spill: VecDeque::new(),
                jumbo_seq: 0,
            }),
            rx: Mutex::new(ShmRx { head: 0, tail_seen: 0, scratch: Vec::new() }),
        })
    }

    fn read_u32(&self, off: u64) -> u32 {
        let mut b = [0u8; 4];
        self.file.read_exact_at(&mut b, off).expect("shm ring read");
        u32::from_le_bytes(b)
    }

    fn read_u64(&self, off: u64) -> u64 {
        let mut b = [0u8; 8];
        self.file.read_exact_at(&mut b, off).expect("shm ring read");
        u64::from_le_bytes(b)
    }

    fn write_u32(&self, off: u64, v: u32) {
        self.file.write_all_at(&v.to_le_bytes(), off).expect("shm ring write");
    }

    fn write_u64(&self, off: u64, v: u64) {
        self.file.write_all_at(&v.to_le_bytes(), off).expect("shm ring write");
    }

    fn jumbo_path(&self, seq: u64) -> PathBuf {
        let mut s = self.path.as_os_str().to_os_string();
        s.push(format!(".jumbo-{seq}"));
        PathBuf::from(s)
    }

    /// Try to place one frame; false = no space (caller spills).
    fn tx_try_write(&self, tx: &mut ShmTx, head: &[u8], body: &[u8]) -> bool {
        let len = (head.len() + body.len()) as u64;
        if len > (self.cap / 2).min(MAX_INLINE as u64) {
            return self.tx_write_jumbo(tx, head, body);
        }
        let rec = (4 + len + 7) & !7;
        loop {
            let pos = tx.tail % self.cap;
            let to_end = self.cap - pos;
            // worst case we burn the run to the wrap point AND the record
            let need = if to_end < rec { to_end + rec } else { rec };
            if self.cap - (tx.tail - tx.head_seen) < need {
                tx.head_seen = self.read_u64(HEAD_OFF);
                if self.cap - (tx.tail - tx.head_seen) < need {
                    return false;
                }
            }
            if to_end < rec {
                self.write_u32(DATA_OFF + pos, TAG_SKIP);
                tx.tail += to_end;
                continue;
            }
            self.write_u32(DATA_OFF + pos, len as u32);
            let mut off = DATA_OFF + pos + 4;
            if !head.is_empty() {
                self.file.write_all_at(head, off).expect("shm ring write");
                off += head.len() as u64;
            }
            if !body.is_empty() {
                self.file.write_all_at(body, off).expect("shm ring write");
            }
            tx.tail += rec;
            // publish AFTER the payload: a reader that sees the new tail
            // sees the record bytes
            self.write_u64(TAIL_OFF, tx.tail);
            return true;
        }
    }

    /// Oversized frame: payload goes to a side file, the ring carries a
    /// fixed-size pointer record (so ordering is preserved and no frame
    /// can exceed the ring).
    fn tx_write_jumbo(&self, tx: &mut ShmTx, head: &[u8], body: &[u8]) -> bool {
        let rec: u64 = 24; // [tag u32][seq u64][len u64][pad]
        let pos = tx.tail % self.cap;
        let to_end = self.cap - pos;
        let need = if to_end < rec { to_end + rec } else { rec };
        if self.cap - (tx.tail - tx.head_seen) < need {
            tx.head_seen = self.read_u64(HEAD_OFF);
            if self.cap - (tx.tail - tx.head_seen) < need {
                return false;
            }
        }
        let seq = tx.jumbo_seq;
        tx.jumbo_seq += 1;
        let jp = self.jumbo_path(seq);
        let mut f = File::create(&jp).expect("jumbo side file create");
        f.write_all(head).expect("jumbo write");
        f.write_all(body).expect("jumbo write");
        drop(f);
        let mut pos = pos;
        if to_end < rec {
            self.write_u32(DATA_OFF + pos, TAG_SKIP);
            tx.tail += to_end;
            pos = 0;
        }
        self.write_u32(DATA_OFF + pos, TAG_JUMBO);
        self.write_u64(DATA_OFF + pos + 4, seq);
        self.write_u64(DATA_OFF + pos + 12, (head.len() + body.len()) as u64);
        tx.tail += rec;
        self.write_u64(TAIL_OFF, tx.tail);
        true
    }

    fn pump_locked(&self, tx: &mut ShmTx) {
        while let Some(f) = tx.spill.front() {
            // split back into (head, body)? spilled frames are stored
            // pre-joined, so head = frame, body = empty
            if self.tx_try_write_spilled(tx, f.clone()) {
                tx.spill.pop_front();
            } else {
                break;
            }
        }
    }

    fn tx_try_write_spilled(&self, tx: &mut ShmTx, frame: Vec<u8>) -> bool {
        self.tx_try_write(tx, &frame, &[])
    }

    /// Pop the next frame's raw bytes into `out`. Assumes `rx` is locked.
    fn rx_try_read(&self, rx: &mut ShmRx, out: &mut Vec<u8>) -> bool {
        loop {
            if rx.head == rx.tail_seen {
                rx.tail_seen = self.read_u64(TAIL_OFF);
                if rx.head == rx.tail_seen {
                    return false;
                }
            }
            let pos = rx.head % self.cap;
            let tag = self.read_u32(DATA_OFF + pos);
            match tag {
                TAG_SKIP => {
                    rx.head += self.cap - pos;
                    self.write_u64(HEAD_OFF, rx.head);
                }
                TAG_JUMBO => {
                    let seq = self.read_u64(DATA_OFF + pos + 4);
                    let len = self.read_u64(DATA_OFF + pos + 12) as usize;
                    let jp = self.jumbo_path(seq);
                    out.clear();
                    out.resize(len, 0);
                    let f = File::open(&jp).expect("jumbo side file open");
                    f.read_exact_at(out, 0).expect("jumbo side file read");
                    drop(f);
                    let _ = std::fs::remove_file(&jp);
                    rx.head += 24;
                    self.write_u64(HEAD_OFF, rx.head);
                    return true;
                }
                len => {
                    let len = len as usize;
                    out.clear();
                    out.resize(len, 0);
                    self.file
                        .read_exact_at(out, DATA_OFF + pos + 4)
                        .expect("shm ring read");
                    rx.head += (4 + len as u64 + 7) & !7;
                    self.write_u64(HEAD_OFF, rx.head);
                    return true;
                }
            }
        }
    }

    fn lock_tx(&self) -> std::sync::MutexGuard<'_, ShmTx> {
        self.tx.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn lock_rx(&self) -> std::sync::MutexGuard<'_, ShmRx> {
        self.rx.lock().unwrap_or_else(|e| e.into_inner())
    }
}

impl Transport for ShmRing {
    fn kind(&self) -> TransportKind {
        TransportKind::Shm
    }

    fn send_frame_parts(&self, head: &[u8], body: &[u8]) {
        let mut tx = self.lock_tx();
        if !tx.spill.is_empty() {
            self.pump_locked(&mut tx);
        }
        if tx.spill.is_empty() && self.tx_try_write(&mut tx, head, body) {
            return;
        }
        // keep order: once anything is spilled, everything later spills
        // until the spill drains
        let mut f = Vec::with_capacity(head.len() + body.len());
        f.extend_from_slice(head);
        f.extend_from_slice(body);
        tx.spill.push_back(f);
    }

    fn try_recv_frame(&self, out: &mut Vec<u8>) -> bool {
        let got = {
            let mut rx = self.lock_rx();
            self.rx_try_read(&mut rx, out)
        };
        if got {
            return true;
        }
        // in process, the receiver can flush the sender's spill itself
        self.pump();
        let mut rx = self.lock_rx();
        self.rx_try_read(&mut rx, out)
    }

    fn try_recv_f32_frame(&self, out: &mut Vec<f32>) -> bool {
        let mut rx = self.lock_rx();
        let mut scratch = std::mem::take(&mut rx.scratch);
        let mut got = self.rx_try_read(&mut rx, &mut scratch);
        if !got {
            drop(rx);
            self.pump();
            rx = self.lock_rx();
            got = self.rx_try_read(&mut rx, &mut scratch);
        }
        if got {
            f32s_from_bytes(&scratch, out);
        }
        rx.scratch = scratch;
        got
    }

    fn frame_ready(&self) -> bool {
        let mut rx = self.lock_rx();
        if rx.head == rx.tail_seen {
            rx.tail_seen = self.read_u64(TAIL_OFF);
        }
        rx.head != rx.tail_seen
    }

    fn pump(&self) {
        let mut tx = self.lock_tx();
        if !tx.spill.is_empty() {
            self.pump_locked(&mut tx);
        }
    }

    fn reset(&self) {
        let mut tx = self.lock_tx();
        let mut rx = self.lock_rx();
        tx.spill.clear();
        // drop everything unread: head catches up to tail (jumbo side
        // files of dropped frames are removed by path scan)
        let tail = self.read_u64(TAIL_OFF);
        rx.head = tail;
        rx.tail_seen = tail;
        tx.head_seen = tail;
        self.write_u64(HEAD_OFF, tail);
        for seq in 0..tx.jumbo_seq {
            let _ = std::fs::remove_file(self.jumbo_path(seq));
        }
    }
}

// ---------------------------------------------------------------------------
// Unix-domain-socket link
// ---------------------------------------------------------------------------

struct UdsTx {
    s: UnixStream,
    /// Bytes accepted by `send_frame_parts` but not yet by the socket.
    spill: VecDeque<u8>,
}

struct UdsRx {
    s: UnixStream,
    /// Raw received bytes; `pos..` is unparsed.
    buf: Vec<u8>,
    pos: usize,
}

/// The uds backend: one nonblocking stream per directed link. In process
/// both halves of a `UnixStream::pair` live in one object; across a
/// process boundary each endpoint holds only its half.
pub struct UdsLink {
    tx: Option<Mutex<UdsTx>>,
    rx: Option<Mutex<UdsRx>>,
    gone: AtomicBool,
}

impl UdsLink {
    /// In-process link: a socketpair with both ends attached.
    pub fn pair() -> std::io::Result<UdsLink> {
        let (a, b) = UnixStream::pair()?;
        a.set_nonblocking(true)?;
        b.set_nonblocking(true)?;
        Ok(UdsLink {
            tx: Some(Mutex::new(UdsTx { s: a, spill: VecDeque::new() })),
            rx: Some(Mutex::new(UdsRx { s: b, buf: Vec::new(), pos: 0 })),
            gone: AtomicBool::new(false),
        })
    }

    /// Sender endpoint over an established stream (cross-process).
    pub fn from_tx(s: UnixStream) -> std::io::Result<UdsLink> {
        s.set_nonblocking(true)?;
        Ok(UdsLink {
            tx: Some(Mutex::new(UdsTx { s, spill: VecDeque::new() })),
            rx: None,
            gone: AtomicBool::new(false),
        })
    }

    /// Receiver endpoint over an established stream (cross-process).
    pub fn from_rx(s: UnixStream) -> std::io::Result<UdsLink> {
        s.set_nonblocking(true)?;
        Ok(UdsLink {
            tx: None,
            rx: Some(Mutex::new(UdsRx { s, buf: Vec::new(), pos: 0 })),
            gone: AtomicBool::new(false),
        })
    }

    /// Write as much of `b` as the socket accepts; spill the rest.
    fn write_or_spill(&self, tx: &mut UdsTx, b: &[u8]) {
        let mut off = 0;
        if tx.spill.is_empty() {
            while off < b.len() {
                match tx.s.write(&b[off..]) {
                    Ok(0) => {
                        self.gone.store(true, Ordering::SeqCst);
                        return;
                    }
                    Ok(k) => off += k,
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        self.gone.store(true, Ordering::SeqCst);
                        return;
                    }
                }
            }
        }
        if off < b.len() {
            tx.spill.extend(&b[off..]);
        }
    }

    fn pump_locked(&self, tx: &mut UdsTx) {
        while !tx.spill.is_empty() {
            let (a, _) = tx.spill.as_slices();
            let n = match tx.s.write(a) {
                Ok(0) => {
                    self.gone.store(true, Ordering::SeqCst);
                    return;
                }
                Ok(k) => k,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.gone.store(true, Ordering::SeqCst);
                    return;
                }
            };
            tx.spill.drain(..n);
        }
    }

    /// Pull everything currently readable into `rx.buf`.
    fn fill(&self, rx: &mut UdsRx) {
        loop {
            let start = rx.buf.len();
            rx.buf.resize(start + 64 * 1024, 0);
            match rx.s.read(&mut rx.buf[start..]) {
                Ok(0) => {
                    rx.buf.truncate(start);
                    self.gone.store(true, Ordering::SeqCst);
                    return;
                }
                Ok(k) => rx.buf.truncate(start + k),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    rx.buf.truncate(start);
                    return;
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {
                    rx.buf.truncate(start);
                }
                Err(_) => {
                    rx.buf.truncate(start);
                    self.gone.store(true, Ordering::SeqCst);
                    return;
                }
            }
        }
    }

    /// Return the (start, end) byte range of the next complete frame's
    /// payload, if present.
    fn peek_frame(rx: &UdsRx) -> Option<(usize, usize)> {
        let avail = &rx.buf[rx.pos..];
        if avail.len() < 4 {
            return None;
        }
        let len = u32::from_le_bytes([avail[0], avail[1], avail[2], avail[3]]) as usize;
        if avail.len() < 4 + len {
            return None;
        }
        Some((rx.pos + 4, rx.pos + 4 + len))
    }

    fn consume(rx: &mut UdsRx, end: usize) {
        rx.pos = end;
        if rx.pos == rx.buf.len() {
            rx.buf.clear();
            rx.pos = 0;
        } else if rx.pos > 64 * 1024 {
            rx.buf.copy_within(rx.pos.., 0);
            rx.buf.truncate(rx.buf.len() - rx.pos);
            rx.pos = 0;
        }
    }

    fn lock_rx(&self) -> Option<std::sync::MutexGuard<'_, UdsRx>> {
        self.rx.as_ref().map(|m| m.lock().unwrap_or_else(|e| e.into_inner()))
    }
}

impl Transport for UdsLink {
    fn kind(&self) -> TransportKind {
        TransportKind::Uds
    }

    fn send_frame_parts(&self, head: &[u8], body: &[u8]) {
        let tx = self.tx.as_ref().expect("uds link has no sender half");
        let mut tx = tx.lock().unwrap_or_else(|e| e.into_inner());
        if !tx.spill.is_empty() {
            self.pump_locked(&mut tx);
        }
        let len = ((head.len() + body.len()) as u32).to_le_bytes();
        self.write_or_spill(&mut tx, &len);
        self.write_or_spill(&mut tx, head);
        self.write_or_spill(&mut tx, body);
    }

    fn try_recv_frame(&self, out: &mut Vec<u8>) -> bool {
        self.pump();
        let mut rx = match self.lock_rx() {
            Some(g) => g,
            None => return false,
        };
        if Self::peek_frame(&rx).is_none() {
            self.fill(&mut rx);
        }
        match Self::peek_frame(&rx) {
            Some((s, e)) => {
                out.clear();
                out.extend_from_slice(&rx.buf[s..e]);
                Self::consume(&mut rx, e);
                true
            }
            None => false,
        }
    }

    fn try_recv_f32_frame(&self, out: &mut Vec<f32>) -> bool {
        self.pump();
        let mut rx = match self.lock_rx() {
            Some(g) => g,
            None => return false,
        };
        if Self::peek_frame(&rx).is_none() {
            self.fill(&mut rx);
        }
        match Self::peek_frame(&rx) {
            Some((s, e)) => {
                f32s_from_bytes(&rx.buf[s..e], out);
                Self::consume(&mut rx, e);
                true
            }
            None => false,
        }
    }

    fn frame_ready(&self) -> bool {
        self.pump();
        let mut rx = match self.lock_rx() {
            Some(g) => g,
            None => return false,
        };
        if Self::peek_frame(&rx).is_some() {
            return true;
        }
        self.fill(&mut rx);
        Self::peek_frame(&rx).is_some()
    }

    fn pump(&self) {
        if let Some(tx) = self.tx.as_ref() {
            let mut tx = tx.lock().unwrap_or_else(|e| e.into_inner());
            if !tx.spill.is_empty() {
                self.pump_locked(&mut tx);
            }
        }
    }

    fn reset(&self) {
        if let Some(tx) = self.tx.as_ref() {
            tx.lock().unwrap_or_else(|e| e.into_inner()).spill.clear();
        }
        if let Some(mut rx) = self.lock_rx() {
            // drain whatever the socket still buffers, then drop it all
            self.fill(&mut rx);
            rx.buf.clear();
            rx.pos = 0;
        }
    }

    fn peer_gone(&self) -> bool {
        self.gone.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_ring(cap: u64) -> (ShmRing, PathBuf) {
        let dir = unique_endpoint_dir(&std::env::temp_dir(), "ringtest");
        std::fs::create_dir_all(&dir).unwrap();
        let path = shm_ring_path(&dir, 0, 0, 1);
        (ShmRing::open(&path, cap).unwrap(), dir)
    }

    #[test]
    fn shm_roundtrip_in_order() {
        let (r, dir) = tmp_ring(4096);
        send_frame(&r, b"hello");
        r.send_frame_parts(b"wor", b"ld");
        let mut out = Vec::new();
        assert!(r.try_recv_frame(&mut out));
        assert_eq!(out, b"hello");
        assert!(r.try_recv_frame(&mut out));
        assert_eq!(out, b"world");
        assert!(!r.try_recv_frame(&mut out));
        drop(r);
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn shm_wraps_and_skips() {
        let (r, dir) = tmp_ring(128);
        let mut out = Vec::new();
        // records of 40 bytes force wrap-point skip markers quickly
        for i in 0..50u8 {
            send_frame(&r, &[i; 33]);
            assert!(r.try_recv_frame(&mut out), "frame {i}");
            assert_eq!(out, vec![i; 33]);
        }
        drop(r);
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn shm_spills_when_full_and_pumps() {
        let (r, dir) = tmp_ring(128);
        // each is a 24-byte record: 5 fit (120 <= 128), rest spill
        for i in 0..8u8 {
            send_frame(&r, &[i; 17]);
        }
        let mut out = Vec::new();
        for i in 0..8u8 {
            assert!(r.try_recv_frame(&mut out), "frame {i} (spill must pump)");
            assert_eq!(out, vec![i; 17]);
        }
        assert!(!r.try_recv_frame(&mut out));
        drop(r);
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn shm_jumbo_side_file() {
        let (r, dir) = tmp_ring(128);
        let big = vec![7u8; 4096];
        send_frame(&r, b"pre");
        send_frame(&r, &big);
        send_frame(&r, b"post");
        let mut out = Vec::new();
        assert!(r.try_recv_frame(&mut out));
        assert_eq!(out, b"pre");
        assert!(r.try_recv_frame(&mut out));
        assert_eq!(out, big);
        assert!(r.try_recv_frame(&mut out));
        assert_eq!(out, b"post");
        drop(r);
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn shm_f32_frames() {
        let (r, dir) = tmp_ring(4096);
        let payload: Vec<f32> = (0..100).map(|i| i as f32 * 0.5).collect();
        r.send_frame_parts(&[], f32s_as_bytes(&payload));
        let mut out = Vec::new();
        assert!(r.try_recv_f32_frame(&mut out));
        assert_eq!(out, payload);
        drop(r);
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn uds_roundtrip_and_spill() {
        let l = UdsLink::pair().unwrap();
        let payload: Vec<f32> = (0..50_000).map(|i| i as f32).collect();
        // well past the socket buffer: must spill, then pump through
        for _ in 0..4 {
            l.send_frame_parts(&[], f32s_as_bytes(&payload));
        }
        let mut out = Vec::new();
        for i in 0..4 {
            let mut spins = 0;
            while !l.try_recv_f32_frame(&mut out) {
                spins += 1;
                assert!(spins < 1_000_000, "frame {i} never arrived");
            }
            assert_eq!(out, payload);
        }
        assert!(!l.try_recv_f32_frame(&mut out));
    }

    #[test]
    fn uds_peer_gone_on_eof() {
        let (a, b) = UnixStream::pair().unwrap();
        let l = UdsLink::from_rx(a).unwrap();
        drop(b);
        let mut out = Vec::new();
        assert!(!l.try_recv_frame(&mut out));
        assert!(l.peer_gone());
    }

    #[test]
    fn kind_parsing() {
        assert_eq!(TransportKind::parse("shm"), Some(TransportKind::Shm));
        assert_eq!(TransportKind::parse("uds"), Some(TransportKind::Uds));
        assert_eq!(TransportKind::parse("inproc"), Some(TransportKind::Inproc));
        assert_eq!(TransportKind::parse("tcp"), None);
        assert_eq!(TransportKind::Shm.name(), "shm");
    }

    #[test]
    fn reset_discards_in_flight() {
        let (r, dir) = tmp_ring(4096);
        send_frame(&r, b"stale");
        r.reset();
        let mut out = Vec::new();
        assert!(!r.try_recv_frame(&mut out));
        send_frame(&r, b"fresh");
        assert!(r.try_recv_frame(&mut out));
        assert_eq!(out, b"fresh");
        drop(r);
        std::fs::remove_dir_all(dir).unwrap();
    }
}
