//! Resumable per-hop state machines for the ring collectives — the form
//! a background comm thread can drive incrementally.
//!
//! Each stepper holds ONLY schedule state (which hop comes next); the
//! payload buffer is passed into every [`step`] call, so the same machine
//! works over a borrowed slice (the blocking drivers in [`crate::comm`])
//! or an owned `Vec<f32>` (a queued [`Collective`] on a comm thread). One
//! `step` performs exactly one ring hop — a pooled lease/`send_vec` to
//! the clockwise neighbor and a `recv_vec`/`release` from the
//! counter-clockwise one — so an in-flight collective can be suspended
//! between hops and interleaved with other work. In steady state every
//! hop buffer comes from and returns to the lane pools: ZERO heap
//! allocations on the fabric path (asserted by `tests/fabric_hotpath.rs`
//! for the comm-thread allgather).
//!
//! The hop schedules are byte-for-byte the ones the blocking collectives
//! in [`crate::comm`] always used (those are now thin drivers over these
//! machines), so values are bit-identical whether a collective runs
//! inline, at a sync-stream join, or on a background comm thread.
//!
//! [`step`]: AllGatherStep::step

use super::fabric::RingPort;

/// Split `len` elements into `n` contiguous chunks whose sizes differ by
/// at most one (the first `len % n` chunks are one longer).
pub(super) fn chunk_bounds(len: usize, n: usize) -> Vec<(usize, usize)> {
    let base = len / n;
    let rem = len % n;
    let mut bounds = Vec::with_capacity(n);
    let mut start = 0;
    for i in 0..n {
        let size = base + usize::from(i < rem);
        bounds.push((start, start + size));
        start += size;
    }
    bounds
}

/// This rank's side of an EQUAL-SHARD ring all-gather over a full-size
/// buffer: `buf` is `n * shard_len` long with this rank's shard already
/// in chunk `rank`; after `n-1` hops every chunk is filled. Received hop
/// buffers are copied out and released back to the lane pools.
#[derive(Debug)]
pub struct AllGatherStep {
    w: usize,
    n: usize,
    shard_len: usize,
    hop: usize,
}

impl AllGatherStep {
    pub fn new(port: &RingPort, shard_len: usize) -> AllGatherStep {
        AllGatherStep { w: port.rank(), n: port.n(), shard_len, hop: 0 }
    }

    pub fn is_done(&self) -> bool {
        self.hop + 1 >= self.n
    }

    /// One ring hop; returns true when the all-gather is complete. A call
    /// on a completed (or single-rank) machine is a no-op returning true.
    pub fn step(&mut self, port: &RingPort, buf: &mut [f32]) -> bool {
        if self.is_done() {
            return true;
        }
        let (n, w, s, l) = (self.n, self.w, self.hop, self.shard_len);
        debug_assert_eq!(buf.len(), n * l, "allgather buffer arity");
        let c_send = (w + n - s) % n;
        let mut out = port.lease(port.next(), l);
        out.extend_from_slice(&buf[c_send * l..(c_send + 1) * l]);
        port.send_vec(port.next(), out);
        let c_recv = (w + 2 * n - s - 1) % n;
        let msg = port.recv_vec(port.prev());
        debug_assert_eq!(msg.len(), l, "allgather peers disagree on length");
        buf[c_recv * l..(c_recv + 1) * l].copy_from_slice(&msg);
        port.release(port.prev(), msg);
        self.hop += 1;
        self.is_done()
    }
}

/// This rank's side of a ring reduce-scatter (sum) over a full-length
/// buffer (`len` divisible by N): after `n-1` hops chunk `rank` of `buf`
/// holds the sum of every rank's chunk `rank`. Other chunks hold partial
/// sums and are garbage to the caller.
#[derive(Debug)]
pub struct ReduceScatterStep {
    w: usize,
    n: usize,
    shard_len: usize,
    hop: usize,
}

impl ReduceScatterStep {
    pub fn new(port: &RingPort, len: usize) -> ReduceScatterStep {
        let n = port.n();
        assert_eq!(len % n, 0, "reduce_scatter length {len} not divisible by {n}");
        ReduceScatterStep { w: port.rank(), n, shard_len: len / n, hop: 0 }
    }

    /// Element range of this rank's reduced chunk inside the buffer.
    pub fn shard_range(&self) -> std::ops::Range<usize> {
        self.w * self.shard_len..(self.w + 1) * self.shard_len
    }

    pub fn is_done(&self) -> bool {
        self.hop + 1 >= self.n
    }

    /// One ring hop; returns true when the reduce-scatter is complete.
    pub fn step(&mut self, port: &RingPort, buf: &mut [f32]) -> bool {
        if self.is_done() {
            return true;
        }
        let (n, w, s, l) = (self.n, self.w, self.hop, self.shard_len);
        debug_assert_eq!(buf.len(), n * l, "reduce_scatter buffer arity");
        let c = (w + n - s - 1) % n;
        let mut out = port.lease(port.next(), l);
        out.extend_from_slice(&buf[c * l..(c + 1) * l]);
        port.send_vec(port.next(), out);
        let c = (w + 2 * n - s - 2) % n;
        let msg = port.recv_vec(port.prev());
        debug_assert_eq!(msg.len(), l, "reduce_scatter peers disagree on length");
        for (dst, v) in buf[c * l..(c + 1) * l].iter_mut().zip(&msg) {
            *dst += v;
        }
        port.release(port.prev(), msg);
        self.hop += 1;
        self.is_done()
    }
}

/// This rank's side of a ring all-reduce (sum) over a buffer of any
/// length: a reduce-scatter pass then an all-gather pass, `2(n-1)` hops
/// of ~`len/n` each (chunks may be uneven or empty).
#[derive(Debug)]
pub struct AllReduceStep {
    w: usize,
    n: usize,
    bounds: Vec<(usize, usize)>,
    hop: usize,
}

impl AllReduceStep {
    pub fn new(port: &RingPort, len: usize) -> AllReduceStep {
        let n = port.n();
        AllReduceStep { w: port.rank(), n, bounds: chunk_bounds(len, n), hop: 0 }
    }

    pub fn is_done(&self) -> bool {
        self.hop + 2 >= 2 * self.n
    }

    /// One ring hop; returns true when the all-reduce is complete.
    pub fn step(&mut self, port: &RingPort, buf: &mut [f32]) -> bool {
        if self.is_done() {
            return true;
        }
        let (n, w, ch) = (self.n, self.w, &self.bounds);
        if self.hop < n - 1 {
            // reduce-scatter pass: after hop s, chunk (w - s - 1) mod n on
            // this rank has accumulated s + 2 contributions
            let s = self.hop;
            let (a, b) = ch[(w + n - s - 1) % n];
            let mut out = port.lease(port.next(), b - a);
            out.extend_from_slice(&buf[a..b]);
            port.send_vec(port.next(), out);
            let (a, b) = ch[(w + 2 * n - s - 2) % n];
            let msg = port.recv_vec(port.prev());
            debug_assert_eq!(msg.len(), b - a, "allreduce peers disagree on length");
            for (dst, v) in buf[a..b].iter_mut().zip(&msg) {
                *dst += v;
            }
            port.release(port.prev(), msg);
        } else {
            // all-gather pass: complete chunks circulate until every rank
            // has all of them
            let s = self.hop - (n - 1);
            let (a, b) = ch[(w + n - s) % n];
            let mut out = port.lease(port.next(), b - a);
            out.extend_from_slice(&buf[a..b]);
            port.send_vec(port.next(), out);
            let (a, b) = ch[(w + 2 * n - s - 1) % n];
            let msg = port.recv_vec(port.prev());
            debug_assert_eq!(msg.len(), b - a, "allreduce peers disagree on length");
            buf[a..b].copy_from_slice(&msg);
            port.release(port.prev(), msg);
        }
        self.hop += 1;
        self.is_done()
    }
}

enum StepKind {
    AllGather(AllGatherStep),
    ReduceScatter(ReduceScatterStep),
    AllReduce(AllReduceStep),
}

/// The kind of a queued [`Collective`] — what the hop scheduler's
/// `Priority` policy dispatches on (allgathers are latency-critical
/// prefetches; reduce-scatters/allreduces are bandwidth buckets).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CollKind {
    AllGather,
    ReduceScatter,
    AllReduce,
}

/// One QUEUED collective: a stepper plus the owned payload buffer it
/// operates on — the unit of work a background comm thread executes. The
/// buffer is caller-provided and returned at completion, so a persistent
/// rank engine cycles one buffer per collective site across steps (zero
/// steady-state allocations end to end).
pub struct Collective {
    kind: StepKind,
    buf: Vec<f32>,
}

impl Collective {
    /// An all-gather of `shard` into a reconstructed full buffer. `buf` is
    /// recycled storage (its capacity is reused; contents are replaced);
    /// the completed collective's buffer is the `n * shard.len()`
    /// concatenation in rank order.
    pub fn allgather(port: &RingPort, shard: &[f32], mut buf: Vec<f32>) -> Collective {
        let (n, w, l) = (port.n(), port.rank(), shard.len());
        buf.clear();
        buf.resize(n * l, 0.0);
        buf[w * l..(w + 1) * l].copy_from_slice(shard);
        Collective { kind: StepKind::AllGather(AllGatherStep::new(port, l)), buf }
    }

    /// A reduce-scatter of this rank's full-length buffer `full` (length
    /// divisible by N). The completed collective's buffer holds the
    /// reduced chunk at `shard_range`; other chunks are partial-sum
    /// garbage.
    pub fn reduce_scatter(port: &RingPort, full: Vec<f32>) -> Collective {
        Collective {
            kind: StepKind::ReduceScatter(ReduceScatterStep::new(port, full.len())),
            buf: full,
        }
    }

    /// An all-reduce (sum) of this rank's buffer against every peer's.
    pub fn allreduce(port: &RingPort, buf: Vec<f32>) -> Collective {
        Collective { kind: StepKind::AllReduce(AllReduceStep::new(port, buf.len())), buf }
    }

    /// Which collective this is — the hop scheduler's `Priority` policy
    /// ranks prefetch allgathers above bucket reductions.
    pub fn kind(&self) -> CollKind {
        match &self.kind {
            StepKind::AllGather(_) => CollKind::AllGather,
            StepKind::ReduceScatter(_) => CollKind::ReduceScatter,
            StepKind::AllReduce(_) => CollKind::AllReduce,
        }
    }

    /// One ring hop; returns true when the collective is complete.
    pub fn step(&mut self, port: &RingPort) -> bool {
        match &mut self.kind {
            StepKind::AllGather(s) => s.step(port, &mut self.buf),
            StepKind::ReduceScatter(s) => s.step(port, &mut self.buf),
            StepKind::AllReduce(s) => s.step(port, &mut self.buf),
        }
    }

    pub fn is_done(&self) -> bool {
        match &self.kind {
            StepKind::AllGather(s) => s.is_done(),
            StepKind::ReduceScatter(s) => s.is_done(),
            StepKind::AllReduce(s) => s.is_done(),
        }
    }

    /// Take the completed payload buffer.
    pub fn into_buf(self) -> Vec<f32> {
        debug_assert!(self.is_done(), "collective consumed before completion");
        self.buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{reference, spmd, RingFabric};
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn allgather_step_matches_reference() {
        prop::check("ag stepper == ref", 40, |rng| {
            let n = 1 + rng.below(8);
            let l = rng.below(6);
            let mut r = Rng::new(rng.next_u64());
            let shards: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..l).map(|_| r.normal() as f32).collect())
                .collect();
            let want = reference::allgather(&shards);
            let fab = RingFabric::new(n);
            let got = spmd(&fab, |port| {
                let mut c =
                    Collective::allgather(&port, &shards[port.rank()], Vec::new());
                while !c.step(&port) {}
                c.into_buf()
            });
            for g in &got {
                prop::close(g, &want, 0.0)?;
            }
            if fab.in_flight() != 0 {
                return Err("fabric not drained".into());
            }
            Ok(())
        });
    }

    #[test]
    fn reduce_scatter_step_matches_reference() {
        prop::check("rs stepper == ref", 40, |rng| {
            let n = 1 + rng.below(8);
            let len = n * rng.below(6);
            let mut r = Rng::new(rng.next_u64());
            let fulls: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..len).map(|_| r.normal() as f32).collect())
                .collect();
            let want = reference::reduce_scatter(&fulls);
            let fab = RingFabric::new(n);
            let got = spmd(&fab, |port| {
                let mut c =
                    Collective::reduce_scatter(&port, fulls[port.rank()].clone());
                let range = port.rank() * len / n..(port.rank() + 1) * len / n;
                while !c.step(&port) {}
                c.into_buf()[range].to_vec()
            });
            for (g, w) in got.iter().zip(&want) {
                prop::close(g, w, 1e-4)?;
            }
            Ok(())
        });
    }

    #[test]
    fn allreduce_step_matches_reference() {
        prop::check("ar stepper == ref", 40, |rng| {
            let n = 1 + rng.below(8);
            let len = rng.below(20); // any length, incl. 0 and < n
            let mut r = Rng::new(rng.next_u64());
            let bufs: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..len).map(|_| r.normal() as f32).collect())
                .collect();
            let mut want = bufs.clone();
            reference::allreduce_sum(&mut want);
            let fab = RingFabric::new(n);
            let got = spmd(&fab, |port| {
                let mut c = Collective::allreduce(&port, bufs[port.rank()].clone());
                while !c.step(&port) {}
                c.into_buf()
            });
            for (g, w) in got.iter().zip(&want) {
                prop::close(g, w, 1e-4)?;
            }
            Ok(())
        });
    }

    #[test]
    fn steppers_are_resumable_between_hops() {
        // driving hop-by-hop with other traffic interleaved between hops
        // yields the same result as driving to completion
        let n = 4;
        let fab = RingFabric::new(n);
        let got = spmd(&fab, |port| {
            let mut c = Collective::allreduce(&port, vec![port.rank() as f32; 8]);
            let mut hops = 0;
            while !c.step(&port) {
                hops += 1;
                // unrelated traffic on the same (main) lanes between hops
                port.send(port.next(), hops);
                let _: usize = port.recv(port.prev());
            }
            c.into_buf()
        });
        let want = vec![(0..n).map(|r| r as f32).sum::<f32>(); 8];
        for g in &got {
            assert_eq!(g, &want);
        }
        assert_eq!(fab.in_flight(), 0);
    }

    #[test]
    fn single_rank_collectives_complete_without_hops() {
        let fab = RingFabric::new(1);
        let port = fab.port(0);
        let mut c = Collective::allgather(&port, &[1.0, 2.0], Vec::new());
        assert!(c.is_done());
        assert!(c.step(&port));
        assert_eq!(c.into_buf(), vec![1.0, 2.0]);
        let mut c = Collective::allreduce(&port, vec![3.0]);
        assert!(c.step(&port));
        assert_eq!(c.into_buf(), vec![3.0]);
        assert_eq!(fab.messages_sent(), 0);
    }
}
