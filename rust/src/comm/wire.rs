//! Wire codec for fabric payloads crossing a process boundary.
//!
//! In-process launchers move `Box<dyn Any>` payloads through lane FIFOs
//! by ownership transfer — nothing is ever serialized. Under
//! `Launcher::Process` every hop crosses an address-space boundary, so
//! the concrete payload types that actually travel the training data
//! path get an explicit little-endian encoding here. The inventory is
//! closed on purpose: a fixed tag table over the production payloads
//! (rotation ids and shard structs, collective chunk vectors, all-to-all
//! relay packets) rather than a general serializer. An unknown payload
//! type is a loud panic at the send site, not silent corruption.
//!
//! Frame form byte (prefixed by the fabric's remote send path, before
//! the tag): [`FORM_F32`] frames carry raw `f32` payload bytes for the
//! pooled `send_vec`/`recv_vec` hot path; [`FORM_ANY`] frames carry
//! `[tag: u16 le][tag-specific payload]` as encoded by [`encode_any`].

use std::any::Any;
use std::collections::VecDeque;
use std::sync::Arc;

use crate::model::params::ExpertParams;
use crate::model::partition::{AttnShard, MlpShard};
use crate::parallel::rtp::{EmbShard, MlpShardV};
use crate::tensor::HostTensor;

/// Frame form: raw little-endian `f32` payload (pooled hot path).
pub(crate) const FORM_F32: u8 = 0;
/// Frame form: tagged [`encode_any`] payload.
pub(crate) const FORM_ANY: u8 = 1;

const TAG_USIZE: u16 = 1;
const TAG_USIZE2: u16 = 2;
const TAG_F32: u16 = 3;
const TAG_VEC_F32: u16 = 4;
const TAG_RELAY: u16 = 5; // (usize, VecDeque<Vec<f32>>) — all_to_all packet
const TAG_TENSOR: u16 = 6;
const TAG_ID_TENSOR: u16 = 7;
const TAG_ID_TENSOR_ARC: u16 = 8;
const TAG_ID_EMB: u16 = 9;
const TAG_ID_EMB_ARC: u16 = 10;
const TAG_ID_ATTN: u16 = 11;
const TAG_ID_ATTN_ARC: u16 = 12;
const TAG_ID_MLPV: u16 = 13;
const TAG_ID_MLPV_ARC: u16 = 14;

// --------------------------------------------------------------------------
// primitive writers
// --------------------------------------------------------------------------

fn w_u16(buf: &mut Vec<u8>, v: u16) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn w_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn w_f32s(buf: &mut Vec<u8>, v: &[f32]) {
    w_u64(buf, v.len() as u64);
    buf.extend_from_slice(super::transport::f32s_as_bytes(v));
}

fn w_tensor(buf: &mut Vec<u8>, t: &HostTensor) {
    w_u64(buf, t.shape.len() as u64);
    for &d in &t.shape {
        w_u64(buf, d as u64);
    }
    w_f32s(buf, &t.data);
}

fn w_mlp_shard(buf: &mut Vec<u8>, m: &MlpShard) {
    w_tensor(buf, &m.w1);
    w_tensor(buf, &m.b1);
    w_tensor(buf, &m.w2);
}

fn w_mlpv(buf: &mut Vec<u8>, m: &MlpShardV) {
    match m {
        MlpShardV::Dense(d) => {
            buf.push(0);
            w_mlp_shard(buf, d);
        }
        MlpShardV::Experts(es) => {
            buf.push(1);
            w_u64(buf, es.len() as u64);
            for e in es {
                w_tensor(buf, &e.w1);
                w_tensor(buf, &e.b1);
                w_tensor(buf, &e.w2);
            }
        }
    }
}

fn w_emb(buf: &mut Vec<u8>, e: &EmbShard) {
    w_tensor(buf, &e.wte);
    w_tensor(buf, &e.wpe);
}

fn w_attn(buf: &mut Vec<u8>, a: &AttnShard) {
    w_tensor(buf, &a.wqkv);
    w_tensor(buf, &a.bqkv);
    w_tensor(buf, &a.wo);
}

// --------------------------------------------------------------------------
// primitive readers
// --------------------------------------------------------------------------

struct Rd<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Rd<'a> {
    fn take(&mut self, n: usize) -> &'a [u8] {
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        s
    }

    fn u64(&mut self) -> u64 {
        let s = self.take(8);
        u64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]])
    }

    fn u8(&mut self) -> u8 {
        self.take(1)[0]
    }

    fn f32s(&mut self) -> Vec<f32> {
        let n = self.u64() as usize;
        let raw = self.take(n * 4);
        let mut v = Vec::with_capacity(n);
        v.extend(
            raw.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])),
        );
        v
    }

    fn tensor(&mut self) -> HostTensor {
        let nd = self.u64() as usize;
        let shape: Vec<usize> = (0..nd).map(|_| self.u64() as usize).collect();
        let data = self.f32s();
        HostTensor { shape, data }
    }

    fn mlp_shard(&mut self) -> MlpShard {
        MlpShard { w1: self.tensor(), b1: self.tensor(), w2: self.tensor() }
    }

    fn mlpv(&mut self) -> MlpShardV {
        match self.u8() {
            0 => MlpShardV::Dense(self.mlp_shard()),
            1 => {
                let n = self.u64() as usize;
                MlpShardV::Experts(
                    (0..n)
                        .map(|_| ExpertParams {
                            w1: self.tensor(),
                            b1: self.tensor(),
                            w2: self.tensor(),
                        })
                        .collect(),
                )
            }
            v => panic!("wire: bad MlpShardV variant byte {v}"),
        }
    }

    fn emb(&mut self) -> EmbShard {
        EmbShard { wte: self.tensor(), wpe: self.tensor() }
    }

    fn attn(&mut self) -> AttnShard {
        AttnShard { wqkv: self.tensor(), bqkv: self.tensor(), wo: self.tensor() }
    }
}

// --------------------------------------------------------------------------
// encode / decode
// --------------------------------------------------------------------------

/// Encode one `Msg::Any` payload into `buf` (appended; caller owns any
/// frame prefix). `Err` carries the payload's concrete type name for
/// the panic message at the send site.
pub(crate) fn encode_any(msg: &(dyn Any + Send), buf: &mut Vec<u8>) -> Result<(), &'static str> {
    if let Some(v) = msg.downcast_ref::<usize>() {
        w_u16(buf, TAG_USIZE);
        w_u64(buf, *v as u64);
    } else if let Some((a, b)) = msg.downcast_ref::<(usize, usize)>() {
        w_u16(buf, TAG_USIZE2);
        w_u64(buf, *a as u64);
        w_u64(buf, *b as u64);
    } else if let Some(v) = msg.downcast_ref::<f32>() {
        w_u16(buf, TAG_F32);
        buf.extend_from_slice(&v.to_le_bytes());
    } else if let Some(v) = msg.downcast_ref::<Vec<f32>>() {
        w_u16(buf, TAG_VEC_F32);
        w_f32s(buf, v);
    } else if let Some((src, chunks)) = msg.downcast_ref::<(usize, VecDeque<Vec<f32>>)>() {
        w_u16(buf, TAG_RELAY);
        w_u64(buf, *src as u64);
        w_u64(buf, chunks.len() as u64);
        for c in chunks {
            w_f32s(buf, c);
        }
    } else if let Some(t) = msg.downcast_ref::<HostTensor>() {
        w_u16(buf, TAG_TENSOR);
        w_tensor(buf, t);
    } else if let Some((id, t)) = msg.downcast_ref::<(usize, HostTensor)>() {
        w_u16(buf, TAG_ID_TENSOR);
        w_u64(buf, *id as u64);
        w_tensor(buf, t);
    } else if let Some((id, t)) = msg.downcast_ref::<(usize, Arc<HostTensor>)>() {
        w_u16(buf, TAG_ID_TENSOR_ARC);
        w_u64(buf, *id as u64);
        w_tensor(buf, t);
    } else if let Some((id, e)) = msg.downcast_ref::<(usize, EmbShard)>() {
        w_u16(buf, TAG_ID_EMB);
        w_u64(buf, *id as u64);
        w_emb(buf, e);
    } else if let Some((id, e)) = msg.downcast_ref::<(usize, Arc<EmbShard>)>() {
        w_u16(buf, TAG_ID_EMB_ARC);
        w_u64(buf, *id as u64);
        w_emb(buf, e);
    } else if let Some((id, a)) = msg.downcast_ref::<(usize, AttnShard)>() {
        w_u16(buf, TAG_ID_ATTN);
        w_u64(buf, *id as u64);
        w_attn(buf, a);
    } else if let Some((id, a)) = msg.downcast_ref::<(usize, Arc<AttnShard>)>() {
        w_u16(buf, TAG_ID_ATTN_ARC);
        w_u64(buf, *id as u64);
        w_attn(buf, a);
    } else if let Some((id, m)) = msg.downcast_ref::<(usize, MlpShardV)>() {
        w_u16(buf, TAG_ID_MLPV);
        w_u64(buf, *id as u64);
        w_mlpv(buf, m);
    } else if let Some((id, m)) = msg.downcast_ref::<(usize, Arc<MlpShardV>)>() {
        w_u16(buf, TAG_ID_MLPV_ARC);
        w_u64(buf, *id as u64);
        w_mlpv(buf, m);
    } else {
        return Err(std::any::type_name_of_val(msg));
    }
    Ok(())
}

/// Decode a [`FORM_ANY`] frame payload (the bytes after the form byte)
/// back into the exact boxed type [`encode_any`] saw, so the receiving
/// `RingPort::recv::<T>` downcast sees the same concrete type as it
/// would in process.
pub(crate) fn decode_any(b: &[u8]) -> Box<dyn Any + Send> {
    let tag = u16::from_le_bytes([b[0], b[1]]);
    let mut r = Rd { b, pos: 2 };
    match tag {
        TAG_USIZE => Box::new(r.u64() as usize),
        TAG_USIZE2 => Box::new((r.u64() as usize, r.u64() as usize)),
        TAG_F32 => {
            let s = r.take(4);
            Box::new(f32::from_le_bytes([s[0], s[1], s[2], s[3]]))
        }
        TAG_VEC_F32 => Box::new(r.f32s()),
        TAG_RELAY => {
            let src = r.u64() as usize;
            let n = r.u64() as usize;
            let chunks: VecDeque<Vec<f32>> = (0..n).map(|_| r.f32s()).collect();
            Box::new((src, chunks))
        }
        TAG_TENSOR => Box::new(r.tensor()),
        TAG_ID_TENSOR => Box::new((r.u64() as usize, r.tensor())),
        TAG_ID_TENSOR_ARC => Box::new((r.u64() as usize, Arc::new(r.tensor()))),
        TAG_ID_EMB => Box::new((r.u64() as usize, r.emb())),
        TAG_ID_EMB_ARC => Box::new((r.u64() as usize, Arc::new(r.emb()))),
        TAG_ID_ATTN => Box::new((r.u64() as usize, r.attn())),
        TAG_ID_ATTN_ARC => Box::new((r.u64() as usize, Arc::new(r.attn()))),
        TAG_ID_MLPV => Box::new((r.u64() as usize, r.mlpv())),
        TAG_ID_MLPV_ARC => Box::new((r.u64() as usize, Arc::new(r.mlpv()))),
        t => panic!("wire: unknown payload tag {t}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: Box<dyn Any + Send>) -> Box<dyn Any + Send> {
        let mut buf = Vec::new();
        encode_any(&*msg, &mut buf).expect("encodable");
        decode_any(&buf)
    }

    fn t(shape: &[usize]) -> HostTensor {
        let len: usize = shape.iter().product();
        HostTensor {
            shape: shape.to_vec(),
            data: (0..len).map(|i| i as f32 * 0.25 - 1.0).collect(),
        }
    }

    #[test]
    fn scalars_and_vecs() {
        assert_eq!(*roundtrip(Box::new(42usize)).downcast::<usize>().unwrap(), 42);
        assert_eq!(
            *roundtrip(Box::new((3usize, 9usize))).downcast::<(usize, usize)>().unwrap(),
            (3, 9)
        );
        assert_eq!(*roundtrip(Box::new(1.5f32)).downcast::<f32>().unwrap(), 1.5);
        let v = vec![1.0f32, -2.0, 3.5];
        assert_eq!(*roundtrip(Box::new(v.clone())).downcast::<Vec<f32>>().unwrap(), v);
    }

    #[test]
    fn relay_packet() {
        let pkt: (usize, VecDeque<Vec<f32>>) =
            (2, VecDeque::from(vec![vec![1.0, 2.0], vec![3.0]]));
        let got = roundtrip(Box::new(pkt.clone()))
            .downcast::<(usize, VecDeque<Vec<f32>>)>()
            .unwrap();
        assert_eq!(*got, pkt);
    }

    #[test]
    fn tensors_and_shards() {
        let ht = t(&[2, 3]);
        assert_eq!(*roundtrip(Box::new(ht.clone())).downcast::<HostTensor>().unwrap(), ht);

        let got = roundtrip(Box::new((7usize, Arc::new(t(&[4])))))
            .downcast::<(usize, Arc<HostTensor>)>()
            .unwrap();
        assert_eq!(got.0, 7);
        assert_eq!(*got.1, t(&[4]));

        let attn = AttnShard { wqkv: t(&[2, 6]), bqkv: t(&[6]), wo: t(&[2, 2]) };
        let got = roundtrip(Box::new((1usize, attn.clone())))
            .downcast::<(usize, AttnShard)>()
            .unwrap();
        assert_eq!(got.1, attn);

        let mlpv = MlpShardV::Experts(vec![
            ExpertParams { w1: t(&[2, 4]), b1: t(&[4]), w2: t(&[4, 2]) },
            ExpertParams { w1: t(&[2, 4]), b1: t(&[4]), w2: t(&[4, 2]) },
        ]);
        let got = roundtrip(Box::new((0usize, Arc::new(mlpv))))
            .downcast::<(usize, Arc<MlpShardV>)>()
            .unwrap();
        match &*got.1 {
            MlpShardV::Experts(es) => assert_eq!(es.len(), 2),
            _ => panic!("variant lost in roundtrip"),
        }
    }

    #[test]
    fn unknown_type_is_an_error() {
        let msg: Box<dyn Any + Send> = Box::new("not a fabric payload");
        let mut buf = Vec::new();
        assert!(encode_any(&*msg, &mut buf).is_err());
    }
}
