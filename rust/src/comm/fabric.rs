//! The rank-local ring fabric: per-rank `RingPort` endpoints over
//! per-worker mailboxes.
//!
//! This is the substrate the paper's §3.3 rotation primitive and §3.4.3
//! overlap analysis actually live on: communication happens one ring hop
//! at a time, and every transfer is something a single rank does —
//! `port.send(peer, msg)` / `port.recv(peer)` — never a god-view mutation
//! of all ranks' buffers at once. The chunked ring collectives in
//! [`crate::comm`] and the engines' rotation loops are built exclusively
//! from these two calls, so the hop structure (who moves what, when) is
//! explicit in every schedule the engines produce.
//!
//! Topology rules:
//! - The fabric is a ring: a rank may only address its clockwise neighbor
//!   (`next`) or its counter-clockwise neighbor (`prev`). Any other peer
//!   panics — multi-hop transfers must be written as relays, which is
//!   exactly what keeps the per-hop cost model honest.
//! - Each directed link is a FIFO mailbox owned by the *receiving* worker.
//!   A hop is "everyone sends, then everyone receives"; the mailbox slot is
//!   the in-flight double buffer of the out-of-place rotation.
//! - `recv` on an empty mailbox panics: in the single-process SPMD
//!   simulation that is a protocol bug (the distributed equivalent would
//!   deadlock), so it should fail loudly.
//!
//! Payloads are type-erased (`Box<dyn Any>`): the same fabric carries
//! `Vec<f32>` collective chunks, whole shard structs during RTP rotation,
//! and bare shard ids in virtual mode — the schedule is identical whether
//! or not real data rides along (the repo's real/virtual design invariant).
//!
//! Handles are `Rc<RefCell<..>>` clones: the simulation is single-threaded
//! by design (ranks are stepped in program order), and the interior
//! mutability is what lets a rank send from `&self` contexts such as
//! `Engine::gather_params`. Putting ranks on real threads means swapping
//! this inner cell for channels — the port API is already shaped for it.

use std::any::Any;
use std::cell::RefCell;
use std::collections::VecDeque;
use std::fmt;
use std::rc::Rc;

/// One directed-link mailbox: FIFO of in-flight messages.
type Mailbox = VecDeque<Box<dyn Any>>;

struct FabricInner {
    n: usize,
    /// `mailboxes[dst][src]`: messages sent by `src`, awaiting `dst`.
    /// Only the two neighbor columns of each row are ever used.
    mailboxes: Vec<Vec<Mailbox>>,
    /// Messages handed to the fabric since construction.
    sent: u64,
    /// Messages delivered to their destination rank.
    delivered: u64,
}

/// The shared ring interconnect of one worker set. Create one per
/// [`crate::cluster::Cluster`]; hand each rank its [`RingPort`].
#[derive(Clone)]
pub struct RingFabric {
    inner: Rc<RefCell<FabricInner>>,
}

impl RingFabric {
    pub fn new(n: usize) -> RingFabric {
        assert!(n >= 1, "ring fabric needs at least one rank");
        RingFabric {
            inner: Rc::new(RefCell::new(FabricInner {
                n,
                mailboxes: (0..n)
                    .map(|_| (0..n).map(|_| VecDeque::new()).collect())
                    .collect(),
                sent: 0,
                delivered: 0,
            })),
        }
    }

    pub fn n(&self) -> usize {
        self.inner.borrow().n
    }

    /// Rank `rank`'s endpoint. Ports are cheap handle clones; a rank may
    /// hold any number of clones of its own port.
    pub fn port(&self, rank: usize) -> RingPort {
        let n = self.n();
        assert!(rank < n, "rank {rank} out of range for {n}-rank fabric");
        RingPort { rank, n, inner: Rc::clone(&self.inner) }
    }

    /// One port per rank, in rank order — the SPMD driver's view.
    pub fn ports(&self) -> Vec<RingPort> {
        (0..self.n()).map(|r| self.port(r)).collect()
    }

    /// Total messages handed to the fabric so far.
    pub fn messages_sent(&self) -> u64 {
        self.inner.borrow().sent
    }

    /// Total messages delivered to their destination rank so far.
    pub fn messages_delivered(&self) -> u64 {
        self.inner.borrow().delivered
    }

    /// Messages currently sitting in mailboxes. A completed collective or
    /// rotation schedule must leave this at 0 — the engines assert it at
    /// every step boundary.
    pub fn in_flight(&self) -> usize {
        (self.messages_sent() - self.messages_delivered()) as usize
    }
}

impl fmt::Debug for RingFabric {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "RingFabric {{ n: {}, in_flight: {} }}",
            self.n(),
            self.in_flight()
        )
    }
}

/// Rank `rank`'s endpoint on the ring fabric. All engine communication
/// goes through `send`/`recv` on these.
#[derive(Clone)]
pub struct RingPort {
    rank: usize,
    n: usize,
    inner: Rc<RefCell<FabricInner>>,
}

impl RingPort {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Clockwise neighbor (the rank this port sends to in a cw rotation).
    pub fn next(&self) -> usize {
        (self.rank + 1) % self.n
    }

    /// Counter-clockwise neighbor (the rank a cw rotation receives from).
    pub fn prev(&self) -> usize {
        (self.rank + self.n - 1) % self.n
    }

    fn assert_neighbor(&self, peer: usize) {
        assert!(
            peer == self.next() || peer == self.prev(),
            "rank {} cannot address rank {peer}: the ring fabric only links \
             neighbors ({} and {})",
            self.rank,
            self.prev(),
            self.next()
        );
    }

    /// Enqueue `msg` on the directed link to neighbor `peer`. One ring hop
    /// is "every rank sends, then every rank receives".
    pub fn send<T: Any>(&self, peer: usize, msg: T) {
        self.assert_neighbor(peer);
        let mut inner = self.inner.borrow_mut();
        inner.mailboxes[peer][self.rank].push_back(Box::new(msg));
        inner.sent += 1;
    }

    /// Dequeue the oldest message neighbor `peer` sent to this rank.
    /// Panics if the mailbox is empty (protocol bug — the distributed
    /// equivalent would deadlock) or if the payload type does not match.
    pub fn recv<T: Any>(&self, peer: usize) -> T {
        self.assert_neighbor(peer);
        let mut inner = self.inner.borrow_mut();
        let msg = inner.mailboxes[self.rank][peer].pop_front().unwrap_or_else(|| {
            panic!(
                "rank {} recv from {peer}: mailbox empty (ring protocol bug)",
                self.rank
            )
        });
        inner.delivered += 1;
        drop(inner);
        *msg.downcast::<T>().unwrap_or_else(|_| {
            panic!(
                "rank {} recv from {peer}: payload type mismatch (expected {})",
                self.rank,
                std::any::type_name::<T>()
            )
        })
    }

    /// Messages waiting in this rank's mailbox from neighbor `peer`.
    pub fn pending_from(&self, peer: usize) -> usize {
        self.assert_neighbor(peer);
        self.inner.borrow().mailboxes[self.rank][peer].len()
    }
}

impl fmt::Debug for RingPort {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "RingPort(rank {}/{})", self.rank, self.n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn send_then_recv_roundtrips() {
        let fab = RingFabric::new(4);
        let ports = fab.ports();
        ports[0].send(1, vec![1.0f32, 2.0]);
        assert_eq!(fab.in_flight(), 1);
        assert_eq!(ports[1].pending_from(0), 1);
        let got: Vec<f32> = ports[1].recv(0);
        assert_eq!(got, vec![1.0, 2.0]);
        assert_eq!(fab.in_flight(), 0);
        assert_eq!(fab.messages_sent(), 1);
        assert_eq!(fab.messages_delivered(), 1);
    }

    #[test]
    fn links_are_fifo() {
        let fab = RingFabric::new(2);
        let ports = fab.ports();
        ports[0].send(1, 10usize);
        ports[0].send(1, 20usize);
        assert_eq!(ports[1].recv::<usize>(0), 10);
        assert_eq!(ports[1].recv::<usize>(0), 20);
    }

    #[test]
    fn both_directions_are_independent_links() {
        let fab = RingFabric::new(3);
        let ports = fab.ports();
        // rank 1 receives from both neighbors without crosstalk
        ports[0].send(1, 100usize);
        ports[2].send(1, 200usize);
        assert_eq!(ports[1].recv::<usize>(2), 200);
        assert_eq!(ports[1].recv::<usize>(0), 100);
    }

    #[test]
    fn neighbors_wrap_around_the_ring() {
        let fab = RingFabric::new(4);
        let p3 = fab.port(3);
        assert_eq!(p3.next(), 0);
        assert_eq!(p3.prev(), 2);
        p3.send(0, 7usize);
        assert_eq!(fab.port(0).recv::<usize>(3), 7);
    }

    #[test]
    #[should_panic(expected = "only links neighbors")]
    fn non_neighbor_send_rejected() {
        let fab = RingFabric::new(4);
        fab.port(0).send(2, 1usize);
    }

    #[test]
    #[should_panic(expected = "mailbox empty")]
    fn recv_on_empty_mailbox_panics() {
        let fab = RingFabric::new(2);
        fab.port(0).recv::<usize>(1);
    }

    #[test]
    #[should_panic(expected = "payload type mismatch")]
    fn recv_wrong_type_panics() {
        let fab = RingFabric::new(2);
        let ports = fab.ports();
        ports[0].send(1, 1.0f32);
        let _: usize = ports[1].recv(0);
    }

    #[test]
    fn single_rank_ring_links_to_itself() {
        let fab = RingFabric::new(1);
        let p = fab.port(0);
        assert_eq!(p.next(), 0);
        assert_eq!(p.prev(), 0);
        p.send(0, 5usize);
        assert_eq!(p.recv::<usize>(0), 5);
    }
}
