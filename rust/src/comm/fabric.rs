//! The rank-local ring fabric: per-rank `RingPort` endpoints over
//! per-worker mailboxes, shared between OS threads.
//!
//! This is the substrate the paper's §3.3 rotation primitive and §3.4.3
//! overlap analysis actually live on: communication happens one ring hop
//! at a time, and every transfer is something a single rank does —
//! `port.send(peer, msg)` / `port.recv(peer)` — never a god-view mutation
//! of all ranks' buffers at once. The collectives in [`crate::comm`] and
//! the engines' rotation loops are built exclusively from these two calls,
//! each rank driving only its OWN port (true SPMD), so the hop structure
//! (who moves what, when) is explicit in every schedule the engines
//! produce.
//!
//! Topology rules:
//! - The fabric is a ring: a rank may only address its clockwise neighbor
//!   (`next`) or its counter-clockwise neighbor (`prev`). Any other peer
//!   panics — multi-hop transfers must be written as relays, which is
//!   exactly what keeps the per-hop cost model honest.
//! - Each directed link is a FIFO mailbox owned by the *receiving* worker.
//!   The mailbox slot is the in-flight double buffer of the out-of-place
//!   rotation.
//!
//! Execution model: rank bodies run as one closure per rank inside a
//! *round* ([`RingFabric::run_round`]), under one of two policies:
//!
//! - [`LaunchPolicy::Lockstep`] — the deterministic scheduler. Rank
//!   bodies execute one at a time (threads used as coroutines), in
//!   round-robin order: a rank runs until its `recv` finds an empty
//!   mailbox, then yields to the next runnable rank. The schedule depends
//!   only on program structure, never on OS timing, so traces, tracker
//!   interleavings and panics are exactly reproducible. If every live
//!   rank is parked on an empty mailbox the round panics immediately —
//!   the single-process equivalent of a distributed deadlock.
//! - [`LaunchPolicy::Threaded`] — real concurrency. All rank threads run
//!   freely; `recv` blocks on a condvar until the message arrives, with a
//!   watchdog timeout (`RTP_FABRIC_TIMEOUT_SECS`, default 20) so protocol
//!   bugs fail fast instead of hanging the test runner.
//!
//! Outside any round, `recv` on an empty mailbox panics immediately (a
//! single-threaded driver that receives before the matching send is a
//! protocol bug). A panicking rank *poisons* the fabric: every peer
//! blocked in the round is woken and panics too, so a round never hangs
//! on a dead participant.
//!
//! Payloads are type-erased (`Box<dyn Any + Send>`): the same fabric
//! carries `Vec<f32>` collective chunks, whole shard structs during RTP
//! rotation, and bare shard ids in virtual mode — the schedule is
//! identical whether or not real data rides along (the repo's
//! real/virtual design invariant).

use std::any::Any;
use std::collections::VecDeque;
use std::fmt;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::Duration;

/// One directed-link mailbox: FIFO of in-flight messages.
type Mailbox = VecDeque<Box<dyn Any + Send>>;

/// How a round's rank bodies are scheduled. See the module docs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LaunchPolicy {
    /// Deterministic round-robin, one rank at a time (threads as
    /// coroutines; yields only at empty-mailbox `recv`).
    Lockstep,
    /// One free-running OS thread per rank; `recv` blocks until the
    /// message arrives.
    Threaded,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum RankState {
    /// May be scheduled.
    Ready,
    /// Parked in `recv`, waiting for a message from `peer`.
    Waiting(usize),
    /// Rank body returned (or panicked).
    Done,
}

/// Lockstep-round scheduler state.
#[derive(Debug)]
struct Sched {
    /// The rank currently allowed to run.
    turn: usize,
    state: Vec<RankState>,
}

struct FabricInner {
    n: usize,
    /// `mailboxes[dst][src]`: messages sent by `src`, awaiting `dst`.
    /// Only the two neighbor columns of each row are ever used.
    mailboxes: Vec<Vec<Mailbox>>,
    /// Messages handed to the fabric since construction.
    sent: u64,
    /// Messages delivered to their destination rank.
    delivered: u64,
    /// Present while a lockstep round is running.
    sched: Option<Sched>,
    /// True while a threaded round is running (recv blocks).
    threaded: bool,
    /// Watchdog for threaded recv.
    recv_timeout: Duration,
    /// A rank panicked mid-round: wake and fail everyone.
    poisoned: bool,
    /// Why the round was poisoned (surfaced in every peer's panic).
    poison_msg: String,
}

struct FabricShared {
    m: Mutex<FabricInner>,
    cv: Condvar,
}

/// The shared ring interconnect of one worker set. Create one per
/// [`crate::cluster::Cluster`]; hand each rank its [`RingPort`].
#[derive(Clone)]
pub struct RingFabric {
    shared: Arc<FabricShared>,
}

fn lock_inner(shared: &FabricShared) -> MutexGuard<'_, FabricInner> {
    // a poisoned mutex only means a peer panicked while holding it; the
    // fabric has its own `poisoned` flag for orderly teardown
    shared.m.lock().unwrap_or_else(|e| e.into_inner())
}

fn poison(g: &mut FabricInner, msg: &str) {
    if !g.poisoned {
        g.poisoned = true;
        g.poison_msg = msg.to_string();
    }
}

fn recv_timeout_from_env() -> Duration {
    let secs = std::env::var("RTP_FABRIC_TIMEOUT_SECS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(20);
    Duration::from_secs(secs.max(1))
}

impl RingFabric {
    pub fn new(n: usize) -> RingFabric {
        assert!(n >= 1, "ring fabric needs at least one rank");
        RingFabric {
            shared: Arc::new(FabricShared {
                m: Mutex::new(FabricInner {
                    n,
                    mailboxes: (0..n)
                        .map(|_| (0..n).map(|_| VecDeque::new()).collect())
                        .collect(),
                    sent: 0,
                    delivered: 0,
                    sched: None,
                    threaded: false,
                    recv_timeout: Duration::from_secs(20),
                    poisoned: false,
                    poison_msg: String::new(),
                }),
                cv: Condvar::new(),
            }),
        }
    }

    fn lock(&self) -> MutexGuard<'_, FabricInner> {
        lock_inner(&self.shared)
    }

    pub fn n(&self) -> usize {
        self.lock().n
    }

    /// Rank `rank`'s endpoint. Ports are cheap handle clones; a rank may
    /// hold any number of clones of its own port.
    pub fn port(&self, rank: usize) -> RingPort {
        let n = self.n();
        assert!(rank < n, "rank {rank} out of range for {n}-rank fabric");
        RingPort { rank, n, shared: Arc::clone(&self.shared) }
    }

    /// One port per rank, in rank order (handed out at cluster
    /// construction; each rank keeps only its own).
    pub fn ports(&self) -> Vec<RingPort> {
        (0..self.n()).map(|r| self.port(r)).collect()
    }

    /// Total messages handed to the fabric so far.
    pub fn messages_sent(&self) -> u64 {
        self.lock().sent
    }

    /// Total messages delivered to their destination rank so far.
    pub fn messages_delivered(&self) -> u64 {
        self.lock().delivered
    }

    /// Messages currently sitting in mailboxes. A completed collective or
    /// rotation schedule must leave this at 0 — the engines assert it at
    /// every step boundary.
    pub fn in_flight(&self) -> usize {
        let g = self.lock();
        (g.sent - g.delivered) as usize
    }

    /// Poison the active round with an ORDERLY abort (a rank body is
    /// returning an error, e.g. a simulated OOM): every peer blocked on
    /// the fabric is woken and panics with `msg`, so the round unwinds
    /// instead of hanging on the aborting rank's never-sent messages. The
    /// caller of [`RingFabric::try_round`] decides how to surface it.
    pub fn abort_round(&self, msg: &str) {
        let mut g = self.lock();
        poison(&mut g, msg);
        drop(g);
        self.shared.cv.notify_all();
    }

    /// Run one closure per rank to completion under `policy`, returning
    /// the per-rank results in rank order. This is the ONLY way rank
    /// bodies that block in `recv` may execute; a panic in any rank
    /// poisons the round (all peers fail) and is re-raised here.
    pub fn run_round<'env, T: Send>(
        &self,
        policy: LaunchPolicy,
        tasks: Vec<Box<dyn FnOnce() -> T + Send + 'env>>,
    ) -> Vec<T> {
        let n_tasks = tasks.len();
        let results = self.try_round(policy, tasks);
        let mut out = Vec::with_capacity(n_tasks);
        let mut first_panic = None;
        for r in results {
            match r {
                Ok(v) => out.push(v),
                Err(p) => {
                    first_panic.get_or_insert(p);
                }
            }
        }
        if let Some(p) = first_panic {
            std::panic::resume_unwind(p);
        }
        out
    }

    /// [`RingFabric::run_round`] without the panic re-raise: per-rank
    /// results come back as `thread::Result`s so the caller can prefer an
    /// orderly error over the secondary poisoned-round panics it caused
    /// in blocked peers.
    pub fn try_round<'env, T: Send>(
        &self,
        policy: LaunchPolicy,
        tasks: Vec<Box<dyn FnOnce() -> T + Send + 'env>>,
    ) -> Vec<std::thread::Result<T>> {
        let n_tasks = tasks.len();
        assert_eq!(
            n_tasks,
            self.n(),
            "run_round wants exactly one task per fabric rank"
        );
        {
            let mut g = self.lock();
            assert!(
                g.sched.is_none() && !g.threaded,
                "nested fabric rounds are not allowed"
            );
            g.poisoned = false;
            g.poison_msg.clear();
            match policy {
                LaunchPolicy::Lockstep => {
                    g.sched = Some(Sched {
                        turn: 0,
                        state: vec![RankState::Ready; n_tasks],
                    });
                }
                LaunchPolicy::Threaded => {
                    g.threaded = true;
                    g.recv_timeout = recv_timeout_from_env();
                }
            }
        }
        let results: Vec<std::thread::Result<T>> = std::thread::scope(|s| {
            let handles: Vec<_> = tasks
                .into_iter()
                .enumerate()
                .map(|(rank, task)| {
                    s.spawn(move || {
                        if policy == LaunchPolicy::Lockstep {
                            self.lockstep_enter(rank);
                        }
                        let mut guard = RoundGuard {
                            fab: self,
                            rank,
                            lockstep: policy == LaunchPolicy::Lockstep,
                            completed: false,
                        };
                        let out = task();
                        guard.completed = true;
                        drop(guard);
                        out
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join()).collect()
        });
        {
            let mut g = self.lock();
            g.sched = None;
            g.threaded = false;
            if g.poisoned {
                // an aborted round can leave messages mid-collective in
                // the mailboxes; flush them so the fabric is reusable
                for row in &mut g.mailboxes {
                    for link in row {
                        link.clear();
                    }
                }
                g.delivered = g.sent;
            }
            g.poisoned = false;
            g.poison_msg.clear();
        }
        results
    }

    /// Block until it is `rank`'s turn in the active lockstep round.
    fn lockstep_enter(&self, rank: usize) {
        let mut g = self.lock();
        loop {
            if g.poisoned {
                let why = g.poison_msg.clone();
                drop(g);
                panic!("rank {rank}: fabric round poisoned ({why})");
            }
            match g.sched.as_ref() {
                Some(s) if s.turn == rank => return,
                Some(_) => {
                    g = self.shared.cv.wait(g).unwrap_or_else(|e| e.into_inner());
                }
                None => panic!("rank {rank}: no lockstep round active"),
            }
        }
    }

    /// Mark `rank`'s body finished (normally or by panic) and hand the
    /// turn on. Called from a drop guard — must never panic.
    fn lockstep_done(&self, rank: usize, panicked: bool) {
        let mut g = self.lock();
        if let Some(s) = g.sched.as_mut() {
            s.state[rank] = RankState::Done;
        }
        if panicked {
            poison(&mut g, "a peer rank's body panicked");
        } else if g.sched.is_some() && advance_turn(&mut g) {
            // remaining ranks all wait on messages that can never come
            poison(
                &mut g,
                "ring deadlock: a finished rank left every live peer waiting",
            );
        }
        drop(g);
        self.shared.cv.notify_all();
    }
}

/// Move the lockstep turn to the next runnable rank (round-robin from the
/// current turn). Returns true if no rank is runnable but some are still
/// live — a deadlock.
fn advance_turn(g: &mut FabricInner) -> bool {
    let n_ranks = match g.sched.as_ref() {
        Some(s) => s.state.len(),
        None => return false,
    };
    let from = g.sched.as_ref().unwrap().turn;
    for step in 1..=n_ranks {
        let r = (from + step) % n_ranks;
        match g.sched.as_ref().unwrap().state[r] {
            RankState::Done => continue,
            RankState::Ready => {
                g.sched.as_mut().unwrap().turn = r;
                return false;
            }
            RankState::Waiting(peer) => {
                if !g.mailboxes[r][peer].is_empty() {
                    let s = g.sched.as_mut().unwrap();
                    s.state[r] = RankState::Ready;
                    s.turn = r;
                    return false;
                }
            }
        }
    }
    g.sched
        .as_ref()
        .unwrap()
        .state
        .iter()
        .any(|s| !matches!(s, RankState::Done))
}

/// Who waits on whom — the deadlock diagnostic.
fn wait_graph(g: &FabricInner) -> String {
    match g.sched.as_ref() {
        Some(s) => s
            .state
            .iter()
            .enumerate()
            .filter_map(|(r, st)| match st {
                RankState::Waiting(p) => Some(format!("r{r}<-r{p}")),
                _ => None,
            })
            .collect::<Vec<_>>()
            .join(" "),
        None => String::new(),
    }
}

/// Panic-safe round teardown for one rank body.
struct RoundGuard<'a> {
    fab: &'a RingFabric,
    rank: usize,
    lockstep: bool,
    completed: bool,
}

impl Drop for RoundGuard<'_> {
    fn drop(&mut self) {
        let panicked = !self.completed;
        if self.lockstep {
            self.fab.lockstep_done(self.rank, panicked);
        } else if panicked {
            let mut g = self.fab.lock();
            poison(&mut g, "a peer rank's body panicked");
            drop(g);
            self.fab.shared.cv.notify_all();
        }
    }
}

impl fmt::Debug for RingFabric {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "RingFabric {{ n: {}, in_flight: {} }}",
            self.n(),
            self.in_flight()
        )
    }
}

/// Rank `rank`'s endpoint on the ring fabric. All engine communication
/// goes through `send`/`recv` on these; each rank drives only its own
/// port. Ports are `Send` — the `Threaded` launch policy runs one rank
/// per OS thread over the same fabric.
#[derive(Clone)]
pub struct RingPort {
    rank: usize,
    n: usize,
    shared: Arc<FabricShared>,
}

impl RingPort {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Clockwise neighbor (the rank this port sends to in a cw rotation).
    pub fn next(&self) -> usize {
        (self.rank + 1) % self.n
    }

    /// Counter-clockwise neighbor (the rank a cw rotation receives from).
    pub fn prev(&self) -> usize {
        (self.rank + self.n - 1) % self.n
    }

    fn assert_neighbor(&self, peer: usize) {
        assert!(
            peer == self.next() || peer == self.prev(),
            "rank {} cannot address rank {peer}: the ring fabric only links \
             neighbors ({} and {})",
            self.rank,
            self.prev(),
            self.next()
        );
    }

    fn lock(&self) -> MutexGuard<'_, FabricInner> {
        lock_inner(&self.shared)
    }

    /// Enqueue `msg` on the directed link to neighbor `peer`. Never
    /// blocks (the mailbox is unbounded — the schedule, not backpressure,
    /// bounds in-flight messages).
    pub fn send<T: Any + Send>(&self, peer: usize, msg: T) {
        self.assert_neighbor(peer);
        let mut g = self.lock();
        if g.poisoned {
            let why = g.poison_msg.clone();
            drop(g);
            panic!("rank {}: fabric round poisoned ({why})", self.rank);
        }
        g.mailboxes[peer][self.rank].push_back(Box::new(msg));
        g.sent += 1;
        drop(g);
        self.shared.cv.notify_all();
    }

    /// Dequeue the oldest message neighbor `peer` sent to this rank.
    ///
    /// Blocking behavior depends on the active round policy (module
    /// docs): lockstep yields the turn until the message arrives (ring
    /// deadlock panics), threaded blocks on the condvar (watchdog
    /// timeout panics), and outside any round an empty mailbox panics
    /// immediately (protocol bug). Panics on payload type mismatch.
    pub fn recv<T: Any>(&self, peer: usize) -> T {
        self.assert_neighbor(peer);
        let mut g = self.lock();
        loop {
            if g.poisoned {
                let why = g.poison_msg.clone();
                drop(g);
                panic!("rank {}: fabric round poisoned ({why})", self.rank);
            }
            if let Some(msg) = g.mailboxes[self.rank][peer].pop_front() {
                g.delivered += 1;
                drop(g);
                return *msg.downcast::<T>().unwrap_or_else(|_| {
                    panic!(
                        "rank {} recv from {peer}: payload type mismatch (expected {})",
                        self.rank,
                        std::any::type_name::<T>()
                    )
                });
            }
            if g.sched.is_some() {
                g = self.lockstep_yield(g, peer);
            } else if g.threaded {
                g = self.threaded_wait(g, peer);
            } else {
                panic!(
                    "rank {} recv from {peer}: mailbox empty (ring protocol bug)",
                    self.rank
                );
            }
        }
    }

    /// Lockstep: park this rank as waiting-on-`peer`, hand the turn on,
    /// and block until the scheduler hands it back (which it only does
    /// once the message is there).
    fn lockstep_yield<'g>(
        &self,
        mut g: MutexGuard<'g, FabricInner>,
        peer: usize,
    ) -> MutexGuard<'g, FabricInner> {
        {
            let s = g.sched.as_mut().expect("lockstep round active");
            debug_assert_eq!(s.turn, self.rank, "only the turn holder may run");
            s.state[self.rank] = RankState::Waiting(peer);
        }
        if advance_turn(&mut g) {
            let diag = wait_graph(&g);
            let msg =
                format!("ring deadlock: every live rank is waiting on an empty mailbox ({diag})");
            poison(&mut g, &msg);
            drop(g);
            self.shared.cv.notify_all();
            panic!("{msg}");
        }
        self.shared.cv.notify_all();
        loop {
            if g.poisoned {
                let why = g.poison_msg.clone();
                drop(g);
                panic!("rank {}: fabric round poisoned ({why})", self.rank);
            }
            match g.sched.as_ref() {
                Some(s) if s.turn == self.rank => return g,
                Some(_) => {
                    g = self.shared.cv.wait(g).unwrap_or_else(|e| e.into_inner());
                }
                // round torn down under us — can only follow a poison
                None => {
                    drop(g);
                    panic!("rank {}: lockstep round ended mid-recv", self.rank);
                }
            }
        }
    }

    /// Threaded: block until a message (or the watchdog fires).
    fn threaded_wait<'g>(
        &self,
        g: MutexGuard<'g, FabricInner>,
        peer: usize,
    ) -> MutexGuard<'g, FabricInner> {
        let timeout = g.recv_timeout;
        let (mut g, res) = self
            .shared
            .cv
            .wait_timeout(g, timeout)
            .unwrap_or_else(|e| e.into_inner());
        if res.timed_out()
            && !g.poisoned
            && g.mailboxes[self.rank][peer].is_empty()
        {
            let msg = format!(
                "rank {} recv from {peer}: no message after {timeout:?} — \
                 ring deadlock (threaded round watchdog)",
                self.rank
            );
            poison(&mut g, &msg);
            drop(g);
            self.shared.cv.notify_all();
            panic!("{msg}");
        }
        g
    }

    /// Messages waiting in this rank's mailbox from neighbor `peer`.
    pub fn pending_from(&self, peer: usize) -> usize {
        self.assert_neighbor(peer);
        self.lock().mailboxes[self.rank][peer].len()
    }
}

impl fmt::Debug for RingPort {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "RingPort(rank {}/{})", self.rank, self.n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn send_then_recv_roundtrips() {
        let fab = RingFabric::new(4);
        let ports = fab.ports();
        ports[0].send(1, vec![1.0f32, 2.0]);
        assert_eq!(fab.in_flight(), 1);
        assert_eq!(ports[1].pending_from(0), 1);
        let got: Vec<f32> = ports[1].recv(0);
        assert_eq!(got, vec![1.0, 2.0]);
        assert_eq!(fab.in_flight(), 0);
        assert_eq!(fab.messages_sent(), 1);
        assert_eq!(fab.messages_delivered(), 1);
    }

    #[test]
    fn links_are_fifo() {
        let fab = RingFabric::new(2);
        let ports = fab.ports();
        ports[0].send(1, 10usize);
        ports[0].send(1, 20usize);
        assert_eq!(ports[1].recv::<usize>(0), 10);
        assert_eq!(ports[1].recv::<usize>(0), 20);
    }

    #[test]
    fn both_directions_are_independent_links() {
        let fab = RingFabric::new(3);
        let ports = fab.ports();
        // rank 1 receives from both neighbors without crosstalk
        ports[0].send(1, 100usize);
        ports[2].send(1, 200usize);
        assert_eq!(ports[1].recv::<usize>(2), 200);
        assert_eq!(ports[1].recv::<usize>(0), 100);
    }

    #[test]
    fn neighbors_wrap_around_the_ring() {
        let fab = RingFabric::new(4);
        let p3 = fab.port(3);
        assert_eq!(p3.next(), 0);
        assert_eq!(p3.prev(), 2);
        p3.send(0, 7usize);
        assert_eq!(fab.port(0).recv::<usize>(3), 7);
    }

    #[test]
    #[should_panic(expected = "only links neighbors")]
    fn non_neighbor_send_rejected() {
        let fab = RingFabric::new(4);
        fab.port(0).send(2, 1usize);
    }

    #[test]
    #[should_panic(expected = "mailbox empty")]
    fn recv_on_empty_mailbox_panics_outside_rounds() {
        let fab = RingFabric::new(2);
        fab.port(0).recv::<usize>(1);
    }

    #[test]
    #[should_panic(expected = "payload type mismatch")]
    fn recv_wrong_type_panics() {
        let fab = RingFabric::new(2);
        let ports = fab.ports();
        ports[0].send(1, 1.0f32);
        let _: usize = ports[1].recv(0);
    }

    #[test]
    fn single_rank_ring_links_to_itself() {
        let fab = RingFabric::new(1);
        let p = fab.port(0);
        assert_eq!(p.next(), 0);
        assert_eq!(p.prev(), 0);
        p.send(0, 5usize);
        assert_eq!(p.recv::<usize>(0), 5);
    }

    /// One neighbor exchange per rank, written rank-locally.
    fn exchange_round(policy: LaunchPolicy, n: usize) -> Vec<usize> {
        let fab = RingFabric::new(n);
        let tasks: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..n)
            .map(|r| {
                let port = fab.port(r);
                Box::new(move || {
                    port.send(port.next(), r * 10);
                    port.recv::<usize>(port.prev())
                }) as Box<dyn FnOnce() -> usize + Send>
            })
            .collect();
        let out = fab.run_round(policy, tasks);
        assert_eq!(fab.in_flight(), 0);
        out
    }

    #[test]
    fn lockstep_round_exchanges_blockingly() {
        for n in [1usize, 2, 3, 4, 8] {
            let got = exchange_round(LaunchPolicy::Lockstep, n);
            let want: Vec<usize> = (0..n).map(|r| ((r + n - 1) % n) * 10).collect();
            assert_eq!(got, want, "n={n}");
        }
    }

    #[test]
    fn threaded_round_exchanges_blockingly() {
        for n in [1usize, 2, 4, 8] {
            let got = exchange_round(LaunchPolicy::Threaded, n);
            let want: Vec<usize> = (0..n).map(|r| ((r + n - 1) % n) * 10).collect();
            assert_eq!(got, want, "n={n}");
        }
    }

    #[test]
    fn lockstep_order_is_deterministic_round_robin() {
        // ranks record the global order in which their bodies ran to
        // completion; with no blocking recv the order is exactly 0..n
        let n = 5;
        let fab = RingFabric::new(n);
        let order = Mutex::new(Vec::new());
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..n)
            .map(|r| {
                let order = &order;
                Box::new(move || {
                    order.lock().unwrap().push(r);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        fab.run_round(LaunchPolicy::Lockstep, tasks);
        assert_eq!(*order.lock().unwrap(), (0..n).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic(expected = "ring deadlock")]
    fn lockstep_detects_deadlock() {
        let fab = RingFabric::new(2);
        let tasks: Vec<Box<dyn FnOnce() + Send>> = (0..2)
            .map(|r| {
                let port = fab.port(r);
                // everyone receives first — nobody ever sends
                Box::new(move || {
                    let _: usize = port.recv(port.prev());
                }) as Box<dyn FnOnce() + Send>
            })
            .collect();
        fab.run_round(LaunchPolicy::Lockstep, tasks);
    }

    #[test]
    fn rank_panic_poisons_the_round() {
        let fab = RingFabric::new(2);
        let tasks: Vec<Box<dyn FnOnce() + Send>> = (0..2)
            .map(|r| {
                let port = fab.port(r);
                Box::new(move || {
                    if r == 0 {
                        panic!("rank 0 exploded");
                    }
                    // rank 1 would otherwise wait forever
                    let _: usize = port.recv(port.prev());
                }) as Box<dyn FnOnce() + Send>
            })
            .collect();
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            fab.run_round(LaunchPolicy::Lockstep, tasks);
        }));
        assert!(caught.is_err());
        // the fabric is reusable after the failed round
        let p = fab.port(0);
        p.send(1, 3usize);
        assert_eq!(fab.port(1).recv::<usize>(0), 3);
    }

    #[test]
    fn threaded_round_survives_heavy_bidirectional_traffic() {
        // concurrent sends in both directions on every link must neither
        // deadlock nor drop or reorder messages (per-link FIFO)
        let n = 4;
        let k = 200usize;
        let fab = RingFabric::new(n);
        let tasks: Vec<Box<dyn FnOnce() + Send>> = (0..n)
            .map(|r| {
                let port = fab.port(r);
                Box::new(move || {
                    for i in 0..k {
                        port.send(port.next(), (r, i));
                        port.send(port.prev(), (r, i + 1000));
                    }
                    for i in 0..k {
                        let (src, seq): (usize, usize) = port.recv(port.prev());
                        assert_eq!((src, seq), (port.prev(), i));
                        let (src, seq): (usize, usize) = port.recv(port.next());
                        assert_eq!((src, seq), (port.next(), i + 1000));
                    }
                }) as Box<dyn FnOnce() + Send>
            })
            .collect();
        fab.run_round(LaunchPolicy::Threaded, tasks);
        assert_eq!(fab.in_flight(), 0);
        assert_eq!(fab.messages_sent(), (2 * n * k) as u64);
        assert_eq!(fab.messages_delivered(), (2 * n * k) as u64);
    }
}
