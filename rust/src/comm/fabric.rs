//! The rank-local ring fabric: per-rank `RingPort` endpoints over
//! per-link mailbox *lanes*, shared between OS threads.
//!
//! This is the substrate the paper's §3.3 rotation primitive and §3.4.3
//! overlap analysis actually live on: communication happens one ring hop
//! at a time, and every transfer is something a single rank does —
//! `port.send(peer, msg)` / `port.recv(peer)` — never a god-view mutation
//! of all ranks' buffers at once. The collectives in [`crate::comm`] and
//! the engines' rotation loops are built exclusively from these calls,
//! each rank driving only its OWN port (true SPMD), so the hop structure
//! (who moves what, when) is explicit in every schedule the engines
//! produce.
//!
//! ## Concurrency model (lock-sharded lanes)
//!
//! Each DIRECTED ring link `src -> dst` is an independent [`Lane`]: its
//! own mutex + condvar + FIFO queue + recycled-buffer pool. Senders and
//! receivers on different links never contend; a blocked threaded
//! receiver parks on ITS lane's condvar and is woken by a targeted
//! `notify_one` from the one sender that can unblock it — there is no
//! global broadcast on the message hot path. The only global lock is the
//! small `ctl` mutex that owns the lockstep scheduler state and the
//! poison diagnostics; the threaded data path touches it only on round
//! setup/teardown and failure.
//!
//! ## Payloads and the pooled hot path
//!
//! Two message forms ride each lane's single FIFO (so cross-type ordering
//! is preserved):
//!
//! - `Msg::Any` — type-erased `Box<dyn Any + Send>`: shard structs during
//!   RTP rotation, bare shard ids in virtual mode, relay packets. One
//!   heap allocation per message (counted).
//! - `Msg::F32` — a bare `Vec<f32>`, enqueued WITHOUT boxing. Collectives
//!   lease their per-hop scratch from the lane's buffer pool
//!   ([`RingPort::lease`]), send with [`RingPort::send_vec`], and the
//!   receiver returns consumed payloads with [`RingPort::release`] — in
//!   steady state the same buffers cycle around the ring and the fabric
//!   performs ZERO heap allocations per hop (asserted by
//!   `tests/fabric_hotpath.rs` via [`RingFabric::counters`]).
//!
//! [`RingFabric::counters`] exposes allocation / lock-acquisition /
//! wakeup counts so benches and tests can track the fabric's per-step
//! overhead as a first-class artifact.
//!
//! Topology rules:
//! - The fabric is a ring: a rank may only address its clockwise neighbor
//!   (`next`) or its counter-clockwise neighbor (`prev`). Any other peer
//!   panics — multi-hop transfers must be written as relays, which is
//!   exactly what keeps the per-hop cost model honest.
//! - Each directed link is FIFO and owned by the *receiving* worker. The
//!   lane queue slot is the in-flight double buffer of the out-of-place
//!   rotation ([`crate::comm::CommStream`] keeps at most one eager shard
//!   per link in flight).
//! - Every directed link exists TWICE: once in the MAIN lane namespace
//!   (rank-body traffic: rotation hops, blocking collectives) and once in
//!   the BACKGROUND lane namespace ([`RingPort::background`]), which the
//!   per-rank comm threads of [`crate::comm::CollectiveStream`] drive.
//!   The two namespaces never share a FIFO, so a background multi-hop
//!   collective can be in flight on a link while the main thread rotates
//!   a shard over the same edge — each class keeps its own deterministic
//!   per-link order, which is what keeps the Lockstep and Thread
//!   launchers bit-identical even with collectives running concurrently
//!   with rotation.
//!
//! Execution model: rank bodies run as one closure per rank inside a
//! *round* ([`RingFabric::run_round`]), under one of two policies:
//!
//! - [`LaunchPolicy::Lockstep`] — the deterministic scheduler. Rank
//!   bodies execute one at a time (threads used as coroutines), in
//!   round-robin order: a rank runs until its `recv` finds an empty
//!   lane, then yields to the next runnable rank. The schedule depends
//!   only on program structure, never on OS timing, so traces, tracker
//!   interleavings and panics are exactly reproducible. If every live
//!   rank is parked on an empty lane the round panics immediately —
//!   the single-process equivalent of a distributed deadlock.
//! - [`LaunchPolicy::Threaded`] — real concurrency. All rank threads run
//!   freely; `recv` blocks on its lane's condvar until the message
//!   arrives, with a watchdog timeout (`RTP_FABRIC_TIMEOUT_SECS`, default
//!   20) that poisons the round and names the STALLED LINK — rank, edge
//!   `rSRC->rDST`, and ring direction — so protocol bugs fail fast and
//!   diagnosably instead of hanging the test runner. A rank blocked in
//!   `CommStream::wait()` goes through the same `recv` and inherits the
//!   same watchdog.
//!
//! Outside any round, `recv` on an empty lane panics immediately (a
//! single-threaded driver that receives before the matching send is a
//! protocol bug). A panicking rank *poisons* the fabric: every peer
//! blocked in the round is woken and panics too, so a round never hangs
//! on a dead participant.

use std::any::Any;
use std::cell::RefCell;
use std::collections::VecDeque;
use std::fmt;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use super::transport::{
    f32s_as_bytes, f32s_from_bytes, shm_base_dir, shm_ring_bytes_from_env, shm_ring_path,
    uds_sock_path, unique_endpoint_dir, ShmRing, Transport, TransportKind, UdsLink,
};
use super::wire;
use crate::runtime::fault::{FailureKind, RankDeath, RankFailure};

/// Max recycled buffers kept per lane pool (a rotation/collective keeps
/// at most a couple of buffers in flight per link; beyond that the pool
/// would just hoard memory).
const POOL_CAP: usize = 8;

/// Threaded receivers park in short slices so a poison raised between the
/// empty-queue check and the condvar wait is picked up promptly even if
/// its notification raced past (the targeted `notify_one` is the fast
/// path; this is the lost-wakeup backstop, not the wakeup mechanism).
const PARK_SLICE: Duration = Duration::from_millis(25);

/// Lane namespace of the rank bodies: rotation hops + blocking collectives.
const CH_MAIN: usize = 0;
/// First background lane namespace
/// ([`crate::comm::CollectiveStream`]): queued multi-hop collectives.
/// Channels `CH_BG..CH_BG + BG_SUBCHANNELS` are all background.
const CH_BG: usize = 1;
/// Independent background sub-channels per directed link. The hop-level
/// comm scheduler maps collective seq `s` onto sub-channel
/// `s % BG_SUBCHANNELS` on EVERY rank, so hops of collectives on
/// different sub-channels may interleave in any order (their FIFOs never
/// mix) while each sub-channel individually keeps strict issue order —
/// that is what makes scheduling-policy choices timing-independent and
/// bit-identical by construction.
pub(crate) const BG_SUBCHANNELS: usize = 4;
/// How many independent lane namespaces each directed link carries.
const CHANNELS: usize = CH_BG + BG_SUBCHANNELS;

/// How a round's rank bodies are scheduled. See the module docs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LaunchPolicy {
    /// Deterministic round-robin, one rank at a time (threads as
    /// coroutines; yields only at empty-mailbox `recv`).
    Lockstep,
    /// One free-running OS thread per rank; `recv` blocks until the
    /// message arrives.
    Threaded,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum RankState {
    /// May be scheduled.
    Ready,
    /// Parked in `recv`, waiting for a message from `peer` on lane
    /// namespace `ch`.
    Waiting { peer: usize, ch: usize },
    /// Rank body returned (or panicked).
    Done,
}

/// Lockstep-round scheduler state.
#[derive(Debug)]
struct Sched {
    /// The rank currently allowed to run.
    turn: usize,
    state: Vec<RankState>,
}

/// One message on a lane. `F32` rides unboxed so the pooled hot path
/// allocates nothing; both forms share one FIFO so cross-type order on a
/// link is exactly program order.
enum Msg {
    Any(Box<dyn Any + Send>),
    F32(Vec<f32>),
    /// The payload bytes crossed the link's byte [`Transport`]; this
    /// marker holds the lane's place in the FIFO (cross-type ordering,
    /// blocking, watchdog and poison semantics all unchanged) and carries
    /// the element count so the receiver can size its pooled buffer. Only
    /// `Vec<f32>` traffic rides the byte transport in process — exactly
    /// the traffic whose cost a transport ablation needs to be honest
    /// about; `Msg::Any` control payloads stay in-FIFO.
    Via(usize),
}

struct LaneBox {
    q: VecDeque<Msg>,
    /// Recycled `Vec<f32>` payload buffers (leased by the link's sender,
    /// returned by its receiver).
    pool: Vec<Vec<f32>>,
    /// A threaded receiver is parked on this lane's condvar.
    waiting: bool,
}

/// One directed link `src -> dst`: its own lock, condvar, FIFO and pool.
struct Lane {
    m: Mutex<LaneBox>,
    cv: Condvar,
    /// Queue-length mirror readable without the lane lock (the lockstep
    /// scheduler's runnability check, `pending_from`, diagnostics).
    pending: AtomicUsize,
}

impl Lane {
    fn new() -> Lane {
        Lane {
            m: Mutex::new(LaneBox { q: VecDeque::new(), pool: Vec::new(), waiting: false }),
            cv: Condvar::new(),
            pending: AtomicUsize::new(0),
        }
    }

    fn lock(&self, c: &CounterCells) -> MutexGuard<'_, LaneBox> {
        c.lock_acquisitions.fetch_add(1, Ordering::Relaxed);
        self.m.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// Monotonic fabric-overhead counters (since construction or the last
/// [`RingFabric::reset_counters`]). Diff two snapshots to get per-step
/// figures.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FabricCounters {
    /// Messages handed to the fabric.
    pub sent: u64,
    /// Messages delivered to their destination rank.
    pub delivered: u64,
    /// Heap allocations performed by the message layer: every boxed
    /// `dyn Any` payload plus every pool-miss buffer lease. The pooled
    /// `Vec<f32>` path contributes ZERO of these in steady state.
    pub msg_allocs: u64,
    /// Buffer leases served from a lane pool (steady-state pooled traffic).
    pub pool_hits: u64,
    /// Mutex acquisitions (lane + control locks).
    pub lock_acquisitions: u64,
    /// Condvar notifications issued (targeted `notify_one` wakeups plus
    /// round-teardown / poison broadcasts).
    pub wakeups: u64,
    /// Collectives issued to the background engine
    /// ([`crate::comm::CollectiveStream`]), both modes.
    pub bg_collectives: u64,
    /// Nanoseconds the background engine spent EXECUTING collective hops
    /// (on the comm thread in background mode; inline at join in sync
    /// mode).
    pub bg_busy_ns: u64,
    /// Nanoseconds rank bodies spent BLOCKED in
    /// `CollectiveStream::join`. `1 - bg_wait_ns / bg_busy_ns` is the
    /// measured fraction of collective time hidden behind compute.
    pub bg_wait_ns: u64,
    /// Single collective hops stepped by the background comm threads'
    /// hop-level scheduler.
    pub sched_hops: u64,
    /// Scheduler hops that switched to a DIFFERENT in-flight collective
    /// than the previous hop (interleaving actually happening).
    pub sched_switches: u64,
    /// Longest run of consecutive hops a comm thread spent on ONE
    /// collective while at least one other collective was runnable — the
    /// hop-starvation witness. `RoundRobin` bounds this at 1 by
    /// construction; `Fifo` lets it grow to a full collective's hop count.
    pub sched_max_streak: u64,
    /// Messages drained out of the lanes at poisoned-round teardown.
    /// Pooled `Vec<f32>` payloads among them are RETURNED to their lane
    /// pool (up to the pool cap), so an aborted round leaks neither
    /// messages nor buffers — `tests/fault_tolerance.rs` asserts both
    /// this counter and `in_flight() == 0` after every injected death.
    pub poison_drained: u64,
}

#[derive(Default)]
struct CounterCells {
    msg_allocs: AtomicU64,
    pool_hits: AtomicU64,
    lock_acquisitions: AtomicU64,
    wakeups: AtomicU64,
    bg_collectives: AtomicU64,
    bg_busy_ns: AtomicU64,
    bg_wait_ns: AtomicU64,
    sched_hops: AtomicU64,
    sched_switches: AtomicU64,
    sched_max_streak: AtomicU64,
    poison_drained: AtomicU64,
}

/// Global (non-hot-path) round state: the lockstep scheduler and the
/// poison diagnostic. Everything per-message lives on the lanes.
struct Ctl {
    /// Present while a lockstep round is running.
    sched: Option<Sched>,
    /// Why the round was poisoned (surfaced in every peer's panic).
    poison_msg: String,
    /// The typed identity of the rank whose death poisoned the round
    /// (first detector wins; secondary stalls never overwrite the root
    /// cause). Survives round teardown so the engine facade can surface
    /// it as an error instead of a panic; cleared at the next round start.
    failure: Option<RankFailure>,
}

const MODE_NONE: u8 = 0;
const MODE_LOCKSTEP: u8 = 1;
const MODE_THREADED: u8 = 2;

struct FabricShared {
    n: usize,
    /// `lanes[(ch * n + dst) * n + src]` — one lane per directed link per
    /// channel; only the neighbor links are ever used.
    lanes: Vec<Lane>,
    /// Which byte transport backs the links (lane FIFOs only when
    /// `Inproc`).
    transport_kind: TransportKind,
    /// Byte transports, indexed exactly like `lanes`. `Some` only for
    /// neighbor links when `transport_kind != Inproc`. An in-process
    /// fabric stores the full link object at `(ch, dst, src)` (both
    /// sides use it); a remote fabric holds only its own rank's halves —
    /// tx at `(ch, peer, local)`, rx at `(ch, local, peer)`.
    transports: Vec<Option<Arc<dyn Transport>>>,
    /// Directory holding this fabric's shm ring files, when THIS fabric
    /// owns it (in-process shm; removed on drop). A remote fabric's rings
    /// live in the launcher-owned endpoint dir instead.
    shm_dir: Option<PathBuf>,
    /// `Some(local_rank)` when this fabric is ONE rank's endpoint of a
    /// cross-process ring (`Launcher::Process` worker): all traffic goes
    /// through the byte transports, lanes are unused.
    remote_rank: Option<usize>,
    /// The launcher's rendezvous dir (remote fabrics): polled for
    /// `dead-<rank>` marker files so a SIGKILLed peer surfaces promptly
    /// even on transports with no EOF (shm).
    endpoint_dir: Option<PathBuf>,
    ctl: Mutex<Ctl>,
    /// Lockstep ranks park here waiting for the turn.
    ctl_cv: Condvar,
    /// Which round kind is active (MODE_*).
    mode: AtomicU8,
    /// A rank panicked / aborted mid-round: wake and fail everyone.
    poisoned: AtomicBool,
    sent: AtomicU64,
    delivered: AtomicU64,
    /// Active threaded-round watchdog, in ms.
    recv_timeout_ms: AtomicU64,
    /// Test override for the watchdog (0 = use RTP_FABRIC_TIMEOUT_SECS).
    timeout_override_ms: AtomicU64,
    /// Active retry budget: how many EXTRA watchdog windows a threaded
    /// receiver burns before declaring the peer dead.
    recv_retries: AtomicU64,
    /// Test override for the retry budget, stored as value+1 (0 = use
    /// RTP_FABRIC_RETRIES).
    retries_override: AtomicU64,
    counters: CounterCells,
}

impl FabricShared {
    fn lane(&self, ch: usize, dst: usize, src: usize) -> &Lane {
        &self.lanes[(ch * self.n + dst) * self.n + src]
    }

    /// The byte transport of directed link `src -> dst` on `ch`, if one
    /// backs it (None = in-FIFO lane traffic).
    fn transport(&self, ch: usize, dst: usize, src: usize) -> Option<&Arc<dyn Transport>> {
        if self.transports.is_empty() {
            return None;
        }
        self.transports[(ch * self.n + dst) * self.n + src].as_ref()
    }

    fn lock_ctl(&self) -> MutexGuard<'_, Ctl> {
        self.counters.lock_acquisitions.fetch_add(1, Ordering::Relaxed);
        self.ctl.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Record the poison reason (first writer wins) and wake every parked
    /// thread — lockstep ranks on the ctl condvar, threaded receivers on
    /// their lanes. Never panics (called from drop guards).
    fn poison(&self, msg: &str) {
        {
            let mut ctl = self.lock_ctl();
            if !self.poisoned.swap(true, Ordering::SeqCst) {
                ctl.poison_msg = msg.to_string();
            }
        }
        self.counters.wakeups.fetch_add(1, Ordering::Relaxed);
        self.ctl_cv.notify_all();
        for lane in &self.lanes {
            self.counters.wakeups.fetch_add(1, Ordering::Relaxed);
            lane.cv.notify_all();
        }
    }

    fn poison_reason(&self) -> String {
        self.lock_ctl().poison_msg.clone()
    }

    /// Record the typed identity of a failed rank (first detector wins).
    /// Call BEFORE the matching `poison` so a survivor that observes the
    /// poison flag can already see the root cause.
    fn record_failure(&self, f: RankFailure) {
        let mut ctl = self.lock_ctl();
        if ctl.failure.is_none() {
            ctl.failure = Some(f);
        }
    }

    /// Move the lockstep turn to the next runnable rank (round-robin from
    /// the current turn). Returns true if no rank is runnable but some
    /// are still live — a deadlock.
    fn advance_turn(&self, ctl: &mut Ctl) -> bool {
        let n_ranks = match ctl.sched.as_ref() {
            Some(s) => s.state.len(),
            None => return false,
        };
        let from = ctl.sched.as_ref().unwrap().turn;
        for step in 1..=n_ranks {
            let r = (from + step) % n_ranks;
            match ctl.sched.as_ref().unwrap().state[r] {
                RankState::Done => continue,
                RankState::Ready => {
                    ctl.sched.as_mut().unwrap().turn = r;
                    return false;
                }
                RankState::Waiting { peer, ch } => {
                    if self.lane(ch, r, peer).pending.load(Ordering::SeqCst) > 0 {
                        let s = ctl.sched.as_mut().unwrap();
                        s.state[r] = RankState::Ready;
                        s.turn = r;
                        return false;
                    }
                }
            }
        }
        ctl.sched
            .as_ref()
            .unwrap()
            .state
            .iter()
            .any(|s| !matches!(s, RankState::Done))
    }
}

impl Drop for FabricShared {
    fn drop(&mut self) {
        // an in-process shm fabric owns its ring files: drop the
        // transports (closing their file handles) and remove the dir so
        // repeated fabric construction cannot leak /dev/shm segments
        if let Some(dir) = self.shm_dir.take() {
            self.transports.clear();
            let _ = std::fs::remove_dir_all(dir);
        }
    }
}

/// The shared ring interconnect of one worker set. Create one per
/// [`crate::cluster::Cluster`]; hand each rank its [`RingPort`].
#[derive(Clone)]
pub struct RingFabric {
    shared: Arc<FabricShared>,
}

fn recv_timeout_from_env() -> Duration {
    let secs = std::env::var("RTP_FABRIC_TIMEOUT_SECS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(20);
    Duration::from_secs(secs.max(1))
}

/// Extra watchdog windows a threaded receiver waits before declaring the
/// peer dead (total patience = timeout × (1 + retries)). Default 0 keeps
/// historical detection latency.
fn recv_retries_from_env() -> u32 {
    std::env::var("RTP_FABRIC_RETRIES")
        .ok()
        .and_then(|v| v.parse::<u32>().ok())
        .unwrap_or(0)
}

/// The unique neighbor links of an `n`-ring: every directed pair
/// `(src, dst)` with `dst` adjacent to `src` (each appears once; for
/// n == 2 the cw and ccw edges coincide).
fn neighbor_links(n: usize) -> Vec<(usize, usize)> {
    let mut links = Vec::new();
    for src in 0..n {
        for dst in [(src + 1) % n, (src + n - 1) % n] {
            if dst != src && !links.contains(&(src, dst)) {
                links.push((src, dst));
            }
        }
    }
    links
}

impl RingFabric {
    /// A fabric on the transport selected by `RTP_TRANSPORT` (in-process
    /// lanes by default) — so the whole suite exercises the shm/uds
    /// backends when the env knob is set.
    pub fn new(n: usize) -> RingFabric {
        RingFabric::with_transport(n, TransportKind::from_env())
    }

    /// A fabric whose `Vec<f32>` data plane rides `kind`. All ranks stay
    /// in this process (lanes still carry ordering and control payloads);
    /// `Inproc` is the historical pure-lane fabric.
    pub fn with_transport(n: usize, kind: TransportKind) -> RingFabric {
        assert!(n >= 1, "ring fabric needs at least one rank");
        let mut transports: Vec<Option<Arc<dyn Transport>>> = Vec::new();
        let mut shm_dir = None;
        if kind != TransportKind::Inproc && n > 1 {
            transports = (0..CHANNELS * n * n).map(|_| None).collect();
            let dir = match kind {
                TransportKind::Shm => {
                    let d = unique_endpoint_dir(&shm_base_dir(), "fab");
                    std::fs::create_dir_all(&d).expect("create shm fabric dir");
                    shm_dir = Some(d.clone());
                    Some(d)
                }
                _ => None,
            };
            let cap = shm_ring_bytes_from_env();
            for ch in 0..CHANNELS {
                for &(src, dst) in &neighbor_links(n) {
                    let t: Arc<dyn Transport> = match kind {
                        TransportKind::Shm => {
                            let p = shm_ring_path(dir.as_ref().unwrap(), ch, src, dst);
                            Arc::new(ShmRing::open(&p, cap).expect("open shm ring"))
                        }
                        TransportKind::Uds => {
                            Arc::new(UdsLink::pair().expect("uds socketpair"))
                        }
                        TransportKind::Inproc => unreachable!(),
                    };
                    transports[(ch * n + dst) * n + src] = Some(t);
                }
            }
        }
        RingFabric {
            shared: Arc::new(FabricShared {
                n,
                lanes: (0..CHANNELS * n * n).map(|_| Lane::new()).collect(),
                transport_kind: kind,
                transports,
                shm_dir,
                remote_rank: None,
                endpoint_dir: None,
                ctl: Mutex::new(Ctl {
                    sched: None,
                    poison_msg: String::new(),
                    failure: None,
                }),
                ctl_cv: Condvar::new(),
                mode: AtomicU8::new(MODE_NONE),
                poisoned: AtomicBool::new(false),
                sent: AtomicU64::new(0),
                delivered: AtomicU64::new(0),
                recv_timeout_ms: AtomicU64::new(20_000),
                timeout_override_ms: AtomicU64::new(0),
                recv_retries: AtomicU64::new(0),
                retries_override: AtomicU64::new(0),
                counters: CounterCells::default(),
            }),
        }
    }

    /// Rank `local_rank`'s endpoint of a CROSS-PROCESS ring: this process
    /// holds only its own rank; all traffic (data AND control payloads,
    /// wire-encoded) crosses `kind` through per-link endpoints named
    /// under `dir` (the `Launcher::Process` rendezvous dir). The uds
    /// backend rendezvouses here: bind every incoming link's listener
    /// first, then connect every outgoing link (retrying until the peer
    /// has bound), then accept.
    pub fn new_remote(
        n: usize,
        local_rank: usize,
        kind: TransportKind,
        dir: &Path,
    ) -> std::io::Result<RingFabric> {
        assert!(n >= 2, "a cross-process ring needs at least two ranks");
        assert!(local_rank < n, "rank {local_rank} out of range for {n}-rank fabric");
        assert!(
            kind != TransportKind::Inproc,
            "Launcher::Process needs a byte transport (shm or uds), not inproc"
        );
        let mut transports: Vec<Option<Arc<dyn Transport>>> =
            (0..CHANNELS * n * n).map(|_| None).collect();
        let next = (local_rank + 1) % n;
        let prev = (local_rank + n - 1) % n;
        let peers: Vec<usize> =
            if next == prev { vec![next] } else { vec![next, prev] };
        match kind {
            TransportKind::Shm => {
                let cap = shm_ring_bytes_from_env();
                for ch in 0..CHANNELS {
                    for &peer in &peers {
                        let tx = ShmRing::open(&shm_ring_path(dir, ch, local_rank, peer), cap)?;
                        transports[(ch * n + peer) * n + local_rank] = Some(Arc::new(tx));
                        let rx = ShmRing::open(&shm_ring_path(dir, ch, peer, local_rank), cap)?;
                        transports[(ch * n + local_rank) * n + peer] = Some(Arc::new(rx));
                    }
                }
            }
            TransportKind::Uds => {
                use std::os::unix::net::{UnixListener, UnixStream};
                let deadline = Instant::now() + Duration::from_secs(10);
                // phase 1: bind all incoming-link listeners
                let mut listeners = Vec::new();
                for ch in 0..CHANNELS {
                    for &peer in &peers {
                        let p = uds_sock_path(dir, ch, peer, local_rank);
                        listeners.push((ch, peer, UnixListener::bind(&p)?));
                    }
                }
                // phase 2: connect all outgoing links (peers bind before
                // they connect, so retry-until-deadline converges)
                for ch in 0..CHANNELS {
                    for &peer in &peers {
                        let p = uds_sock_path(dir, ch, local_rank, peer);
                        let s = loop {
                            match UnixStream::connect(&p) {
                                Ok(s) => break s,
                                Err(e) => {
                                    if Instant::now() >= deadline {
                                        return Err(e);
                                    }
                                    std::thread::sleep(Duration::from_millis(5));
                                }
                            }
                        };
                        transports[(ch * n + peer) * n + local_rank] =
                            Some(Arc::new(UdsLink::from_tx(s)?));
                    }
                }
                // phase 3: accept the incoming connections (already in
                // each listener's backlog once the peers pass phase 2)
                for (ch, peer, l) in listeners {
                    l.set_nonblocking(true)?;
                    let s = loop {
                        match l.accept() {
                            Ok((s, _)) => break s,
                            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                if Instant::now() >= deadline {
                                    return Err(e);
                                }
                                std::thread::sleep(Duration::from_millis(5));
                            }
                            Err(e) => return Err(e),
                        }
                    };
                    transports[(ch * n + local_rank) * n + peer] =
                        Some(Arc::new(UdsLink::from_rx(s)?));
                }
            }
            TransportKind::Inproc => unreachable!(),
        }
        Ok(RingFabric {
            shared: Arc::new(FabricShared {
                n,
                lanes: (0..CHANNELS * n * n).map(|_| Lane::new()).collect(),
                transport_kind: kind,
                transports,
                shm_dir: None,
                remote_rank: Some(local_rank),
                endpoint_dir: Some(dir.to_path_buf()),
                ctl: Mutex::new(Ctl {
                    sched: None,
                    poison_msg: String::new(),
                    failure: None,
                }),
                ctl_cv: Condvar::new(),
                mode: AtomicU8::new(MODE_NONE),
                poisoned: AtomicBool::new(false),
                sent: AtomicU64::new(0),
                delivered: AtomicU64::new(0),
                recv_timeout_ms: AtomicU64::new(20_000),
                timeout_override_ms: AtomicU64::new(0),
                recv_retries: AtomicU64::new(0),
                retries_override: AtomicU64::new(0),
                counters: CounterCells::default(),
            }),
        })
    }

    /// The transport backend backing this fabric's links.
    pub fn transport_kind(&self) -> TransportKind {
        self.shared.transport_kind
    }

    /// Directory holding this fabric's shm ring files, when this fabric
    /// owns one (test hook: cleanup-on-drop assertions).
    pub fn shm_dir(&self) -> Option<PathBuf> {
        self.shared.shm_dir.clone()
    }

    pub fn n(&self) -> usize {
        self.shared.n
    }

    /// Rank `rank`'s endpoint on the MAIN lane namespace. Ports are cheap
    /// handle clones; a rank may hold any number of clones of its own
    /// port.
    pub fn port(&self, rank: usize) -> RingPort {
        let n = self.n();
        assert!(rank < n, "rank {rank} out of range for {n}-rank fabric");
        RingPort { rank, n, ch: CH_MAIN, shared: Arc::clone(&self.shared) }
    }

    /// Rank `rank`'s endpoint on the BACKGROUND lane namespace — what a
    /// per-rank comm thread drives. Same edges, independent FIFOs.
    pub fn bg_port(&self, rank: usize) -> RingPort {
        self.port(rank).background()
    }

    /// One port per rank, in rank order (handed out at cluster
    /// construction; each rank keeps only its own).
    pub fn ports(&self) -> Vec<RingPort> {
        (0..self.n()).map(|r| self.port(r)).collect()
    }

    /// Total messages handed to the fabric so far.
    pub fn messages_sent(&self) -> u64 {
        self.shared.sent.load(Ordering::SeqCst)
    }

    /// Total messages delivered to their destination rank so far.
    pub fn messages_delivered(&self) -> u64 {
        self.shared.delivered.load(Ordering::SeqCst)
    }

    /// Messages currently sitting in lanes. A completed collective or
    /// rotation schedule must leave this at 0 — the engines assert it at
    /// every step boundary. (Reads `delivered` before `sent` and
    /// saturates: a concurrent send+delivery between the two loads must
    /// not wrap the difference.)
    pub fn in_flight(&self) -> usize {
        let delivered = self.messages_delivered();
        let sent = self.messages_sent();
        sent.saturating_sub(delivered) as usize
    }

    /// Snapshot of the fabric-overhead counters. Diff two snapshots for
    /// per-step allocation / lock / wakeup figures.
    pub fn counters(&self) -> FabricCounters {
        let s = &self.shared;
        FabricCounters {
            sent: s.sent.load(Ordering::SeqCst),
            delivered: s.delivered.load(Ordering::SeqCst),
            msg_allocs: s.counters.msg_allocs.load(Ordering::SeqCst),
            pool_hits: s.counters.pool_hits.load(Ordering::SeqCst),
            lock_acquisitions: s.counters.lock_acquisitions.load(Ordering::SeqCst),
            wakeups: s.counters.wakeups.load(Ordering::SeqCst),
            bg_collectives: s.counters.bg_collectives.load(Ordering::SeqCst),
            bg_busy_ns: s.counters.bg_busy_ns.load(Ordering::SeqCst),
            bg_wait_ns: s.counters.bg_wait_ns.load(Ordering::SeqCst),
            sched_hops: s.counters.sched_hops.load(Ordering::SeqCst),
            sched_switches: s.counters.sched_switches.load(Ordering::SeqCst),
            sched_max_streak: s.counters.sched_max_streak.load(Ordering::SeqCst),
            poison_drained: s.counters.poison_drained.load(Ordering::SeqCst),
        }
    }

    /// Zero the overhead counters (NOT sent/delivered, which the in-flight
    /// accounting depends on).
    pub fn reset_counters(&self) {
        let c = &self.shared.counters;
        c.msg_allocs.store(0, Ordering::SeqCst);
        c.pool_hits.store(0, Ordering::SeqCst);
        c.lock_acquisitions.store(0, Ordering::SeqCst);
        c.wakeups.store(0, Ordering::SeqCst);
        c.bg_collectives.store(0, Ordering::SeqCst);
        c.bg_busy_ns.store(0, Ordering::SeqCst);
        c.bg_wait_ns.store(0, Ordering::SeqCst);
        c.sched_hops.store(0, Ordering::SeqCst);
        c.sched_switches.store(0, Ordering::SeqCst);
        c.sched_max_streak.store(0, Ordering::SeqCst);
        c.poison_drained.store(0, Ordering::SeqCst);
    }

    /// Override the threaded-recv watchdog for subsequent rounds on this
    /// fabric (`None` = back to `RTP_FABRIC_TIMEOUT_SECS`). Test hook —
    /// avoids process-global env mutation in concurrent test binaries.
    pub fn set_recv_timeout(&self, d: Option<Duration>) {
        let ms = d.map(|d| (d.as_millis() as u64).max(1)).unwrap_or(0);
        self.shared.timeout_override_ms.store(ms, Ordering::SeqCst);
    }

    /// Override the threaded-recv retry budget for subsequent rounds on
    /// this fabric (`None` = back to `RTP_FABRIC_RETRIES`). Test hook.
    pub fn set_recv_retries(&self, r: Option<u32>) {
        let v = r.map(|r| r as u64 + 1).unwrap_or(0);
        self.shared.retries_override.store(v, Ordering::SeqCst);
    }

    /// The typed identity of the rank whose death poisoned the current or
    /// most recent round (injected kill, watchdog timeout, comm-thread
    /// death), if any detector recorded one. Survives round teardown —
    /// the engine facade reads it to surface a `RankFailure` error to the
    /// caller instead of re-raising the poison panic. Cleared when the
    /// next round starts.
    pub fn rank_failure(&self) -> Option<RankFailure> {
        self.shared.lock_ctl().failure.clone()
    }

    /// Poison the active round with an ORDERLY abort (a rank body is
    /// returning an error, e.g. a simulated OOM): every peer blocked on
    /// the fabric — including comm streams parked in an in-flight
    /// rotation recv — is woken and panics with `msg`, so the round
    /// unwinds instead of hanging on the aborting rank's never-sent
    /// messages. The caller of [`RingFabric::try_round`] decides how to
    /// surface it.
    pub fn abort_round(&self, msg: &str) {
        self.shared.poison(msg);
    }

    /// Run one closure per rank to completion under `policy`, returning
    /// the per-rank results in rank order. This is the ONLY way rank
    /// bodies that block in `recv` may execute; a panic in any rank
    /// poisons the round (all peers fail) and is re-raised here.
    pub fn run_round<'env, T: Send>(
        &self,
        policy: LaunchPolicy,
        tasks: Vec<Box<dyn FnOnce() -> T + Send + 'env>>,
    ) -> Vec<T> {
        let n_tasks = tasks.len();
        let results = self.try_round(policy, tasks);
        let mut out = Vec::with_capacity(n_tasks);
        let mut first_panic = None;
        for r in results {
            match r {
                Ok(v) => out.push(v),
                Err(p) => {
                    first_panic.get_or_insert(p);
                }
            }
        }
        if let Some(p) = first_panic {
            std::panic::resume_unwind(p);
        }
        out
    }

    /// [`RingFabric::run_round`] without the panic re-raise: per-rank
    /// results come back as `thread::Result`s so the caller can prefer an
    /// orderly error over the secondary poisoned-round panics it caused
    /// in blocked peers.
    pub fn try_round<'env, T: Send>(
        &self,
        policy: LaunchPolicy,
        tasks: Vec<Box<dyn FnOnce() -> T + Send + 'env>>,
    ) -> Vec<std::thread::Result<T>> {
        let sh = &self.shared;
        let n_tasks = tasks.len();
        assert_eq!(n_tasks, self.n(), "run_round wants exactly one task per fabric rank");
        {
            let mut ctl = sh.lock_ctl();
            assert!(
                ctl.sched.is_none() && sh.mode.load(Ordering::SeqCst) == MODE_NONE,
                "nested fabric rounds are not allowed"
            );
            sh.poisoned.store(false, Ordering::SeqCst);
            ctl.poison_msg.clear();
            ctl.failure = None;
            match policy {
                LaunchPolicy::Lockstep => {
                    ctl.sched = Some(Sched { turn: 0, state: vec![RankState::Ready; n_tasks] });
                    sh.mode.store(MODE_LOCKSTEP, Ordering::SeqCst);
                }
                LaunchPolicy::Threaded => {
                    let ov = sh.timeout_override_ms.load(Ordering::SeqCst);
                    let t = if ov > 0 {
                        Duration::from_millis(ov)
                    } else {
                        recv_timeout_from_env()
                    };
                    sh.recv_timeout_ms
                        .store((t.as_millis() as u64).max(1), Ordering::SeqCst);
                    let rov = sh.retries_override.load(Ordering::SeqCst);
                    let retries = if rov > 0 {
                        rov - 1
                    } else {
                        recv_retries_from_env() as u64
                    };
                    sh.recv_retries.store(retries, Ordering::SeqCst);
                    sh.mode.store(MODE_THREADED, Ordering::SeqCst);
                }
            }
        }
        let results: Vec<std::thread::Result<T>> = std::thread::scope(|s| {
            let handles: Vec<_> = tasks
                .into_iter()
                .enumerate()
                .map(|(rank, task)| {
                    s.spawn(move || {
                        if policy == LaunchPolicy::Lockstep {
                            self.lockstep_enter(rank);
                        }
                        let mut guard = RoundGuard {
                            fab: self,
                            rank,
                            lockstep: policy == LaunchPolicy::Lockstep,
                            completed: false,
                        };
                        let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(task));
                        if let Err(p) = &out {
                            // an injected rank death: record the typed
                            // root cause (and poison with it) before the
                            // guard's generic peer-panicked poison
                            if let Some(d) = p.downcast_ref::<RankDeath>() {
                                let f = RankFailure {
                                    failed_rank: d.rank,
                                    kind: FailureKind::Injected { phase: d.phase },
                                    detail: format!(
                                        "injected kill of rank {} at step {} ({} fault point)",
                                        d.rank, d.step, d.phase
                                    ),
                                };
                                let msg = f.to_string();
                                self.shared.record_failure(f);
                                self.shared.poison(&msg);
                            }
                        }
                        guard.completed = out.is_ok();
                        drop(guard);
                        match out {
                            Ok(v) => v,
                            Err(p) => std::panic::resume_unwind(p),
                        }
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join()).collect()
        });
        {
            let mut ctl = sh.lock_ctl();
            ctl.sched = None;
            sh.mode.store(MODE_NONE, Ordering::SeqCst);
            if sh.poisoned.load(Ordering::SeqCst) {
                // an aborted round can leave messages mid-collective in
                // the lanes; drain them so the fabric is reusable,
                // returning pooled payloads to their lane pool so a dead
                // rank leaks neither messages nor buffers
                for lane in &sh.lanes {
                    let mut b = lane.lock(&sh.counters);
                    while let Some(m) = b.q.pop_front() {
                        sh.counters.poison_drained.fetch_add(1, Ordering::Relaxed);
                        if let Msg::F32(mut v) = m {
                            if b.pool.len() < POOL_CAP {
                                v.clear();
                                b.pool.push(v);
                            }
                        }
                    }
                    lane.pending.store(0, Ordering::SeqCst);
                }
                // stale frames on the byte transports would desync the
                // marker/frame alignment of the next round — drop them
                // with the lane messages
                for t in sh.transports.iter().flatten() {
                    t.reset();
                }
                sh.delivered
                    .store(sh.sent.load(Ordering::SeqCst), Ordering::SeqCst);
            }
            sh.poisoned.store(false, Ordering::SeqCst);
            ctl.poison_msg.clear();
        }
        results
    }

    /// Block until it is `rank`'s turn in the active lockstep round.
    fn lockstep_enter(&self, rank: usize) {
        let sh = &self.shared;
        let mut ctl = sh.lock_ctl();
        loop {
            if sh.poisoned.load(Ordering::SeqCst) {
                let why = ctl.poison_msg.clone();
                drop(ctl);
                panic!("rank {rank}: fabric round poisoned ({why})");
            }
            match ctl.sched.as_ref() {
                Some(s) if s.turn == rank => return,
                Some(_) => {
                    ctl = sh.ctl_cv.wait(ctl).unwrap_or_else(|e| e.into_inner());
                }
                None => panic!("rank {rank}: no lockstep round active"),
            }
        }
    }

    /// Mark `rank`'s body finished (normally or by panic) and hand the
    /// turn on. Called from a drop guard — must never panic.
    fn lockstep_done(&self, rank: usize, panicked: bool) {
        let sh = &self.shared;
        let mut ctl = sh.lock_ctl();
        if let Some(s) = ctl.sched.as_mut() {
            s.state[rank] = RankState::Done;
        }
        let mut deadlock = false;
        if !panicked && ctl.sched.is_some() {
            deadlock = sh.advance_turn(&mut ctl);
        }
        drop(ctl);
        if panicked {
            sh.poison("a peer rank's body panicked");
        } else if deadlock {
            // remaining ranks all wait on messages that can never come
            sh.poison("ring deadlock: a finished rank left every live peer waiting");
        }
        sh.counters.wakeups.fetch_add(1, Ordering::Relaxed);
        sh.ctl_cv.notify_all();
    }

    /// Run THIS process's one rank body of a cross-process round — the
    /// remote counterpart of [`RingFabric::try_round`]. Arms the
    /// threaded-mode watchdog from the same overrides/env knobs, catches
    /// the body's panic, maps an injected [`RankDeath`] to its typed
    /// failure exactly as the in-process launcher does, and tears the
    /// round down with the transports drained so the fabric is reusable
    /// after a poisoned round.
    pub fn run_remote_round<T>(&self, task: impl FnOnce() -> T) -> std::thread::Result<T> {
        let sh = &self.shared;
        assert!(
            sh.remote_rank.is_some(),
            "run_remote_round needs a remote (per-process) fabric"
        );
        {
            let mut ctl = sh.lock_ctl();
            assert!(
                sh.mode.load(Ordering::SeqCst) == MODE_NONE,
                "nested fabric rounds are not allowed"
            );
            sh.poisoned.store(false, Ordering::SeqCst);
            ctl.poison_msg.clear();
            ctl.failure = None;
            let ov = sh.timeout_override_ms.load(Ordering::SeqCst);
            let t = if ov > 0 {
                Duration::from_millis(ov)
            } else {
                recv_timeout_from_env()
            };
            sh.recv_timeout_ms
                .store((t.as_millis() as u64).max(1), Ordering::SeqCst);
            let rov = sh.retries_override.load(Ordering::SeqCst);
            let retries = if rov > 0 {
                rov - 1
            } else {
                recv_retries_from_env() as u64
            };
            sh.recv_retries.store(retries, Ordering::SeqCst);
            sh.mode.store(MODE_THREADED, Ordering::SeqCst);
        }
        let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(task));
        if let Err(p) = &out {
            if let Some(d) = p.downcast_ref::<RankDeath>() {
                let f = RankFailure {
                    failed_rank: d.rank,
                    kind: FailureKind::Injected { phase: d.phase },
                    detail: format!(
                        "injected kill of rank {} at step {} ({} fault point)",
                        d.rank, d.step, d.phase
                    ),
                };
                let msg = f.to_string();
                sh.record_failure(f);
                sh.poison(&msg);
            } else if !sh.poisoned.load(Ordering::SeqCst) {
                sh.poison("this rank's body panicked");
            }
        }
        {
            let mut ctl = sh.lock_ctl();
            sh.mode.store(MODE_NONE, Ordering::SeqCst);
            if sh.poisoned.load(Ordering::SeqCst) {
                for t in sh.transports.iter().flatten() {
                    t.reset();
                }
                sh.delivered
                    .store(sh.sent.load(Ordering::SeqCst), Ordering::SeqCst);
            }
            sh.poisoned.store(false, Ordering::SeqCst);
            ctl.poison_msg.clear();
        }
        out
    }
}

/// Who waits on whom — the deadlock diagnostic.
fn wait_graph(ctl: &Ctl) -> String {
    match ctl.sched.as_ref() {
        Some(s) => s
            .state
            .iter()
            .enumerate()
            .filter_map(|(r, st)| match st {
                RankState::Waiting { peer, ch } => Some(format!(
                    "r{r}<-r{peer}{}",
                    if *ch >= CH_BG { "(bg)" } else { "" }
                )),
                _ => None,
            })
            .collect::<Vec<_>>()
            .join(" "),
        None => String::new(),
    }
}

/// Panic-safe round teardown for one rank body.
struct RoundGuard<'a> {
    fab: &'a RingFabric,
    rank: usize,
    lockstep: bool,
    completed: bool,
}

impl Drop for RoundGuard<'_> {
    fn drop(&mut self) {
        let panicked = !self.completed;
        if self.lockstep {
            self.fab.lockstep_done(self.rank, panicked);
        } else if panicked {
            self.fab.shared.poison("a peer rank's body panicked");
        }
    }
}

/// Per-recv watchdog state of a threaded receiver: the active deadline
/// plus how many timeout windows it has already burned from the retry
/// budget. Reset for every `recv_msg` call.
struct ThreadedWatch {
    deadline: Option<Instant>,
    retries_used: u32,
}

impl fmt::Debug for RingFabric {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "RingFabric {{ n: {}, in_flight: {} }}",
            self.n(),
            self.in_flight()
        )
    }
}

/// Rank `rank`'s endpoint on the ring fabric. All engine communication
/// goes through `send`/`recv` (and the pooled `send_vec`/`recv_vec`) on
/// these; each rank drives only its own port. Ports are `Send` — the
/// `Threaded` launch policy runs one rank per OS thread over the same
/// fabric. A port is bound to ONE lane namespace: the main one
/// ([`RingFabric::port`]) or the background one ([`RingPort::background`],
/// driven by the per-rank comm threads).
#[derive(Clone)]
pub struct RingPort {
    rank: usize,
    n: usize,
    /// Lane namespace this port sends and receives on (CH_MAIN / CH_BG).
    ch: usize,
    shared: Arc<FabricShared>,
}

impl RingPort {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// This rank's endpoint on the first BACKGROUND lane namespace: the
    /// same ring edges, but an independent set of FIFO lanes that never
    /// interleaves with main-thread traffic. Idempotent. Equivalent to
    /// `bg_subchannel(0)`.
    pub fn background(&self) -> RingPort {
        self.bg_subchannel(0)
    }

    /// This rank's endpoint on background sub-channel `i` (of
    /// [`BG_SUBCHANNELS`]). The hop scheduler keys each collective's
    /// traffic to ONE sub-channel on every rank, so collectives on
    /// different sub-channels can interleave hop-by-hop without their
    /// link FIFOs ever mixing.
    pub(crate) fn bg_subchannel(&self, i: usize) -> RingPort {
        assert!(i < BG_SUBCHANNELS, "bg sub-channel {i} out of range");
        RingPort {
            rank: self.rank,
            n: self.n,
            ch: CH_BG + i,
            shared: Arc::clone(&self.shared),
        }
    }

    /// Is this port bound to a background lane namespace?
    pub fn is_background(&self) -> bool {
        self.ch >= CH_BG
    }

    /// Background-engine accounting: one collective issued.
    pub(crate) fn note_bg_collective(&self) {
        self.shared.counters.bg_collectives.fetch_add(1, Ordering::Relaxed);
    }

    /// Background-engine accounting: time spent executing collective hops.
    pub(crate) fn note_bg_busy(&self, d: Duration) {
        self.shared
            .counters
            .bg_busy_ns
            .fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Background-engine accounting: time a rank body spent blocked in a
    /// collective join.
    pub(crate) fn note_bg_wait(&self, d: Duration) {
        self.shared
            .counters
            .bg_wait_ns
            .fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Scheduler accounting: one hop stepped by the comm thread's
    /// hop-level scheduler. `switched` = a different collective than the
    /// previous hop on this thread.
    pub(crate) fn note_sched_hop(&self, switched: bool) {
        self.shared.counters.sched_hops.fetch_add(1, Ordering::Relaxed);
        if switched {
            self.shared.counters.sched_switches.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Scheduler accounting: fold one comm thread's longest
    /// same-collective-while-contested hop streak into the global max.
    pub(crate) fn note_sched_streak(&self, streak: u64) {
        self.shared
            .counters
            .sched_max_streak
            .fetch_max(streak, Ordering::Relaxed);
    }

    /// The active poison reason, or `fallback` when none was recorded
    /// (diagnostics for a dead background comm thread).
    pub(crate) fn poison_reason_or(&self, fallback: &str) -> String {
        if self.shared.poisoned.load(Ordering::SeqCst) {
            self.shared.poison_reason()
        } else {
            fallback.to_string()
        }
    }

    /// Record a typed rank failure (first detector wins) and poison the
    /// round with it — how a background comm thread that watched its rank
    /// die surfaces the death to every peer.
    pub(crate) fn fail_round(&self, f: RankFailure) {
        let msg = f.to_string();
        self.shared.record_failure(f);
        self.shared.poison(&msg);
    }

    /// Is the active round poisoned?
    pub(crate) fn is_poisoned(&self) -> bool {
        self.shared.poisoned.load(Ordering::SeqCst)
    }

    /// Clockwise neighbor (the rank this port sends to in a cw rotation).
    pub fn next(&self) -> usize {
        (self.rank + 1) % self.n
    }

    /// Counter-clockwise neighbor (the rank a cw rotation receives from).
    pub fn prev(&self) -> usize {
        (self.rank + self.n - 1) % self.n
    }

    fn assert_neighbor(&self, peer: usize) {
        assert!(
            peer == self.next() || peer == self.prev(),
            "rank {} cannot address rank {peer}: the ring fabric only links \
             neighbors ({} and {})",
            self.rank,
            self.prev(),
            self.next()
        );
    }

    fn check_poison(&self) {
        if self.shared.poisoned.load(Ordering::SeqCst) {
            self.panic_poisoned();
        }
    }

    fn panic_poisoned(&self) -> ! {
        let why = self.shared.poison_reason();
        panic!("rank {}: fabric round poisoned ({why})", self.rank);
    }

    /// Ring direction of the incoming link `peer -> self`: messages from
    /// `prev` carry clockwise traffic, messages from `next` carry
    /// counter-clockwise traffic. (With n <= 2 the two coincide; cw is
    /// reported.)
    fn link_direction(&self, peer: usize) -> &'static str {
        if peer == self.prev() {
            "cw"
        } else {
            "ccw"
        }
    }

    /// Enqueue one message on the directed link to `peer`. Never blocks
    /// (lanes are unbounded — the schedule, not backpressure, bounds
    /// in-flight messages). Wakes the one receiver that can consume it.
    fn push_msg(&self, peer: usize, msg: Msg) {
        self.assert_neighbor(peer);
        self.check_poison();
        let sh = &self.shared;
        let lane = sh.lane(self.ch, peer, self.rank);
        let mut b = lane.lock(&sh.counters);
        b.q.push_back(msg);
        lane.pending.fetch_add(1, Ordering::SeqCst);
        sh.sent.fetch_add(1, Ordering::SeqCst);
        let wake = b.waiting;
        drop(b);
        if wake {
            sh.counters.wakeups.fetch_add(1, Ordering::Relaxed);
            lane.cv.notify_one();
        }
    }

    /// Dequeue the oldest message `peer` sent to this rank, blocking per
    /// the active round policy (see the module docs).
    fn recv_msg(&self, peer: usize) -> Msg {
        self.assert_neighbor(peer);
        let sh = &self.shared;
        let lane = sh.lane(self.ch, self.rank, peer);
        let mut watch = ThreadedWatch { deadline: None, retries_used: 0 };
        loop {
            self.check_poison();
            {
                let mut b = lane.lock(&sh.counters);
                if let Some(m) = b.q.pop_front() {
                    lane.pending.fetch_sub(1, Ordering::SeqCst);
                    sh.delivered.fetch_add(1, Ordering::SeqCst);
                    return m;
                }
            }
            match sh.mode.load(Ordering::SeqCst) {
                MODE_LOCKSTEP => self.lockstep_yield(peer),
                MODE_THREADED => self.threaded_wait(lane, peer, &mut watch),
                _ => panic!(
                    "rank {} recv from {peer}: mailbox empty (ring protocol bug)",
                    self.rank
                ),
            }
        }
    }

    /// Enqueue `msg` on the directed link to neighbor `peer` (type-erased
    /// path: one boxing allocation per message; bulk `Vec<f32>` traffic
    /// should use [`RingPort::send_vec`]). On a cross-process fabric the
    /// payload is wire-encoded ([`crate::comm::wire`]) and crosses the
    /// byte transport; a payload type outside the wire inventory panics
    /// at the send site.
    pub fn send<T: Any + Send>(&self, peer: usize, msg: T) {
        if self.shared.remote_rank.is_some() {
            self.remote_send_any(peer, &msg);
            return;
        }
        self.shared.counters.msg_allocs.fetch_add(1, Ordering::Relaxed);
        self.push_msg(peer, Msg::Any(Box::new(msg)));
    }

    /// Dequeue the oldest message neighbor `peer` sent to this rank.
    ///
    /// Blocking behavior depends on the active round policy (module
    /// docs): lockstep yields the turn until the message arrives (ring
    /// deadlock panics), threaded blocks on the lane condvar (watchdog
    /// timeout names the stalled link and panics), and outside any round
    /// an empty lane panics immediately (protocol bug). Panics on payload
    /// type mismatch.
    pub fn recv<T: Any>(&self, peer: usize) -> T {
        fn mismatch<T>(rank: usize, peer: usize) -> ! {
            panic!(
                "rank {rank} recv from {peer}: payload type mismatch (expected {})",
                std::any::type_name::<T>()
            )
        }
        if self.shared.remote_rank.is_some() {
            return self.remote_recv_any::<T>(peer);
        }
        match self.recv_msg(peer) {
            Msg::Any(b) => *b
                .downcast::<T>()
                .unwrap_or_else(|_| mismatch::<T>(self.rank, peer)),
            Msg::F32(v) => {
                // cross-typed pickup of a pooled message: re-box (one
                // allocation) — off the pooled hot path by construction
                self.shared.counters.msg_allocs.fetch_add(1, Ordering::Relaxed);
                let b: Box<dyn Any> = Box::new(v);
                *b.downcast::<T>()
                    .unwrap_or_else(|_| mismatch::<T>(self.rank, peer))
            }
            Msg::Via(len) => {
                // cross-typed pickup of a transport frame: decode + re-box
                let v = self.take_frame(peer, len);
                self.shared.counters.msg_allocs.fetch_add(1, Ordering::Relaxed);
                let b: Box<dyn Any> = Box::new(v);
                *b.downcast::<T>()
                    .unwrap_or_else(|_| mismatch::<T>(self.rank, peer))
            }
        }
    }

    /// Lease a send buffer for the link to `peer` from that lane's
    /// recycled pool (empty, with capacity >= `len` when the pool can
    /// serve it). Fill it and pass it to [`RingPort::send_vec`]; the
    /// receiver returns it to the same pool with [`RingPort::release`].
    pub fn lease(&self, peer: usize, len: usize) -> Vec<f32> {
        self.assert_neighbor(peer);
        let sh = &self.shared;
        let lane = sh.lane(self.ch, peer, self.rank);
        let got = {
            let mut b = lane.lock(&sh.counters);
            b.pool.pop()
        };
        match got {
            Some(mut v) => {
                v.clear();
                if v.capacity() < len {
                    sh.counters.msg_allocs.fetch_add(1, Ordering::Relaxed);
                    // v is empty, so this guarantees capacity >= len
                    v.reserve(len);
                } else {
                    sh.counters.pool_hits.fetch_add(1, Ordering::Relaxed);
                }
                v
            }
            None => {
                sh.counters.msg_allocs.fetch_add(1, Ordering::Relaxed);
                Vec::with_capacity(len)
            }
        }
    }

    /// Enqueue a bare `Vec<f32>` payload on the link to `peer` — the
    /// pooled typed hot path: no boxing, no allocation. When a byte
    /// transport backs the link, the payload BYTES cross it (written in
    /// place for shm) and a [`Msg::Via`] marker holds the lane's FIFO
    /// slot; the `Vec` is recycled straight back into the pool it was
    /// leased from, so the path stays zero-allocation in steady state.
    pub fn send_vec(&self, peer: usize, v: Vec<f32>) {
        if self.shared.remote_rank.is_some() {
            self.remote_send_f32(peer, v);
            return;
        }
        let sh = &self.shared;
        if let Some(t) = sh.transport(self.ch, peer, self.rank) {
            self.assert_neighbor(peer);
            self.check_poison();
            t.send_frame_parts(f32s_as_bytes(&v), &[]);
            let len = v.len();
            {
                let lane = sh.lane(self.ch, peer, self.rank);
                let mut b = lane.lock(&sh.counters);
                if b.pool.len() < POOL_CAP {
                    let mut v = v;
                    v.clear();
                    b.pool.push(v);
                }
            }
            self.push_msg(peer, Msg::Via(len));
            return;
        }
        self.push_msg(peer, Msg::F32(v));
    }

    /// Dequeue a `Vec<f32>` payload from neighbor `peer`. Counterpart of
    /// [`RingPort::send_vec`]; also accepts a boxed `Vec<f32>` sent via
    /// the generic path. Once consumed, hand the buffer back with
    /// [`RingPort::release`] to keep the link pool primed.
    pub fn recv_vec(&self, peer: usize) -> Vec<f32> {
        if self.shared.remote_rank.is_some() {
            return self.remote_recv_vec(peer);
        }
        match self.recv_msg(peer) {
            Msg::F32(v) => v,
            Msg::Via(len) => self.take_frame(peer, len),
            Msg::Any(b) => *b.downcast::<Vec<f32>>().unwrap_or_else(|_| {
                panic!(
                    "rank {} recv from {peer}: payload type mismatch (expected Vec<f32>)",
                    self.rank
                )
            }),
        }
    }

    /// Pop the byte-transport frame matching a [`Msg::Via`] marker into a
    /// buffer leased from the arrival lane's pool. The marker was
    /// enqueued AFTER the frame was written, so the frame is already in
    /// the channel or in the sender's spill (which the receiver pumps) —
    /// the wait below is bounded bookkeeping, not a blocking recv.
    fn take_frame(&self, peer: usize, len: usize) -> Vec<f32> {
        let sh = &self.shared;
        let t = sh
            .transport(self.ch, self.rank, peer)
            .expect("Msg::Via marker without a transport on its link");
        let mut v = self.lease_incoming(peer, len);
        let start = Instant::now();
        while !t.try_recv_f32_frame(&mut v) {
            t.pump();
            if start.elapsed() > Duration::from_secs(10) {
                panic!(
                    "rank {} recv from {peer}: lane marker arrived but its {} \
                     transport frame never did (transport protocol bug)",
                    self.rank, sh.transport_kind
                );
            }
            std::hint::spin_loop();
        }
        assert_eq!(
            v.len(),
            len,
            "rank {} recv from {peer}: transport frame length disagrees with its \
             lane marker",
            self.rank
        );
        v
    }

    /// Lease a receive buffer from the ARRIVAL lane's pool (`peer ->
    /// self`) — the pool [`RingPort::release`] refills, so transport
    /// receives recycle buffers exactly like the in-FIFO pooled path.
    fn lease_incoming(&self, peer: usize, len: usize) -> Vec<f32> {
        let sh = &self.shared;
        let lane = sh.lane(self.ch, self.rank, peer);
        let got = { lane.lock(&sh.counters).pool.pop() };
        match got {
            Some(mut v) => {
                v.clear();
                if v.capacity() < len {
                    sh.counters.msg_allocs.fetch_add(1, Ordering::Relaxed);
                    v.reserve(len);
                } else {
                    sh.counters.pool_hits.fetch_add(1, Ordering::Relaxed);
                }
                v
            }
            None => {
                sh.counters.msg_allocs.fetch_add(1, Ordering::Relaxed);
                Vec::with_capacity(len)
            }
        }
    }

    /// Return a consumed payload buffer to the pool of the lane it
    /// arrived on (`peer -> self`), so the link's sender can lease it
    /// again — the zero-allocation steady state.
    pub fn release(&self, peer: usize, mut v: Vec<f32>) {
        self.assert_neighbor(peer);
        let sh = &self.shared;
        let lane = sh.lane(self.ch, self.rank, peer);
        let mut b = lane.lock(&sh.counters);
        if b.pool.len() < POOL_CAP {
            v.clear();
            b.pool.push(v);
        }
    }

    /// Lockstep: park this rank as waiting-on-`peer`, hand the turn on,
    /// and block until the scheduler hands it back (which it only does
    /// once the message is there).
    fn lockstep_yield(&self, peer: usize) {
        let sh = &self.shared;
        let mut ctl = sh.lock_ctl();
        if sh.poisoned.load(Ordering::SeqCst) {
            drop(ctl);
            self.panic_poisoned();
        }
        // a message may have landed between the lane check and taking the
        // ctl lock (it cannot under pure lockstep, but abort paths may
        // interleave) — just retry the pop
        if sh.lane(self.ch, self.rank, peer).pending.load(Ordering::SeqCst) > 0 {
            return;
        }
        {
            let s = ctl.sched.as_mut().expect("lockstep round active");
            debug_assert_eq!(s.turn, self.rank, "only the turn holder may run");
            s.state[self.rank] = RankState::Waiting { peer, ch: self.ch };
        }
        if sh.advance_turn(&mut ctl) {
            let diag = wait_graph(&ctl);
            let msg =
                format!("ring deadlock: every live rank is waiting on an empty mailbox ({diag})");
            drop(ctl);
            sh.poison(&msg);
            panic!("{msg}");
        }
        sh.counters.wakeups.fetch_add(1, Ordering::Relaxed);
        sh.ctl_cv.notify_all();
        loop {
            if sh.poisoned.load(Ordering::SeqCst) {
                let why = ctl.poison_msg.clone();
                drop(ctl);
                panic!("rank {}: fabric round poisoned ({why})", self.rank);
            }
            match ctl.sched.as_ref() {
                Some(s) if s.turn == self.rank => return,
                Some(_) => {
                    ctl = sh.ctl_cv.wait(ctl).unwrap_or_else(|e| e.into_inner());
                }
                // round torn down under us — can only follow a poison
                None => {
                    drop(ctl);
                    panic!("rank {}: lockstep round ended mid-recv", self.rank);
                }
            }
        }
    }

    /// Threaded: park on this lane's condvar until a message (or the
    /// watchdog fires, poisoning the round with the stalled link's
    /// identity). Parks in short slices so poison raised concurrently is
    /// observed promptly even without a notification. Each expired
    /// watchdog window burns one unit of the round's retry budget
    /// (`RTP_FABRIC_RETRIES` / [`RingFabric::set_recv_retries`]) before
    /// the peer is declared dead; the final expiry records a typed
    /// [`RankFailure`] naming the stalled link's upstream rank.
    fn threaded_wait(&self, lane: &Lane, peer: usize, watch: &mut ThreadedWatch) {
        let sh = &self.shared;
        let timeout =
            Duration::from_millis(sh.recv_timeout_ms.load(Ordering::SeqCst).max(1));
        let dl = *watch.deadline.get_or_insert_with(|| Instant::now() + timeout);
        {
            let mut b = lane.lock(&sh.counters);
            if !b.q.is_empty() || sh.poisoned.load(Ordering::SeqCst) {
                return;
            }
            b.waiting = true;
            let (mut b2, _res) = lane
                .cv
                .wait_timeout(b, PARK_SLICE)
                .unwrap_or_else(|e| e.into_inner());
            b2.waiting = false;
            if !b2.q.is_empty() {
                return;
            }
        }
        if sh.poisoned.load(Ordering::SeqCst) {
            return; // outer loop raises the poison panic
        }
        if Instant::now() >= dl && sh.mode.load(Ordering::SeqCst) == MODE_THREADED {
            // last-instant recheck: a message that raced in exactly at
            // the deadline must not poison the round
            if !lane.lock(&sh.counters).q.is_empty() {
                return;
            }
            let budget = sh.recv_retries.load(Ordering::SeqCst) as u32;
            if watch.retries_used < budget {
                watch.retries_used += 1;
                watch.deadline = Some(Instant::now() + timeout);
                return;
            }
            let msg = format!(
                "rank {} recv from {peer}: no message after {timeout:?} on link \
                 r{peer}->r{}{} ({} ring direction) via {} transport — stalled link \
                 (threaded round watchdog)",
                self.rank,
                self.rank,
                if self.ch >= CH_BG { " [bg lane]" } else { "" },
                self.link_direction(peer),
                sh.transport_kind
            );
            sh.record_failure(RankFailure {
                failed_rank: peer,
                kind: FailureKind::RecvTimeout { retries: watch.retries_used },
                detail: msg.clone(),
            });
            sh.poison(&msg);
            panic!("{msg}");
        }
    }

    /// Messages waiting in this rank's mailbox from neighbor `peer` (this
    /// port's lane namespace only). On a cross-process fabric there is no
    /// lane: readiness is whether the link's transport has a complete
    /// frame — which keeps the hop scheduler's readiness poll working
    /// identically under `Launcher::Process`.
    pub fn pending_from(&self, peer: usize) -> usize {
        self.assert_neighbor(peer);
        if self.shared.remote_rank.is_some() {
            return self
                .shared
                .transport(self.ch, self.rank, peer)
                .map(|t| t.frame_ready() as usize)
                .unwrap_or(0);
        }
        self.shared
            .lane(self.ch, self.rank, peer)
            .pending
            .load(Ordering::SeqCst)
    }

    // --- cross-process (Launcher::Process) data path ----------------------

    fn remote_tx(&self, peer: usize) -> &Arc<dyn Transport> {
        self.shared
            .transport(self.ch, peer, self.rank)
            .expect("remote fabric missing its tx transport")
    }

    fn remote_rx(&self, peer: usize) -> &Arc<dyn Transport> {
        self.shared
            .transport(self.ch, self.rank, peer)
            .expect("remote fabric missing its rx transport")
    }

    /// Has the launcher marked `peer`'s process dead (its `dead-<rank>`
    /// marker file exists in the rendezvous dir)? The parent writes these
    /// the moment `waitpid` reports a child gone, so shm links — which
    /// have no EOF — still surface a SIGKILLed peer promptly.
    fn peer_dead_marker(&self, peer: usize) -> bool {
        match &self.shared.endpoint_dir {
            Some(d) => d.join(format!("dead-{peer}")).exists(),
            None => false,
        }
    }

    fn remote_send_any(&self, peer: usize, msg: &(dyn Any + Send)) {
        self.assert_neighbor(peer);
        self.check_poison();
        let sh = &self.shared;
        sh.counters.msg_allocs.fetch_add(1, Ordering::Relaxed);
        WIRE_BUF.with(|b| {
            let mut buf = b.borrow_mut();
            buf.clear();
            buf.push(wire::FORM_ANY);
            if let Err(ty) = wire::encode_any(msg, &mut buf) {
                panic!(
                    "rank {}: payload type {ty} cannot cross a process boundary (no \
                     wire codec) — Launcher::Process supports the training data \
                     path only",
                    self.rank
                );
            }
            self.remote_tx(peer).send_frame_parts(&buf, &[]);
        });
        sh.sent.fetch_add(1, Ordering::SeqCst);
    }

    fn remote_send_f32(&self, peer: usize, v: Vec<f32>) {
        self.assert_neighbor(peer);
        self.check_poison();
        let sh = &self.shared;
        self.remote_tx(peer)
            .send_frame_parts(&[wire::FORM_F32], f32s_as_bytes(&v));
        sh.sent.fetch_add(1, Ordering::SeqCst);
        // the bytes crossed the boundary; the Vec recycles locally into
        // the pool lease() serves this link from
        let lane = sh.lane(self.ch, peer, self.rank);
        let mut b = lane.lock(&sh.counters);
        if b.pool.len() < POOL_CAP {
            let mut v = v;
            v.clear();
            b.pool.push(v);
        }
    }

    /// Blocking cross-process frame receive, with the SAME watchdog
    /// semantics (and overrides) as the in-process threaded wait, plus
    /// peer-death detection: transport EOF or the launcher's dead-rank
    /// marker surfaces as a typed [`FailureKind::PeerExit`].
    fn remote_recv_frame(&self, peer: usize, out: &mut Vec<u8>) {
        self.assert_neighbor(peer);
        let sh = &self.shared;
        let t = self.remote_rx(peer);
        let timeout =
            Duration::from_millis(sh.recv_timeout_ms.load(Ordering::SeqCst).max(1));
        let budget = sh.recv_retries.load(Ordering::SeqCst) as u32;
        let mut retries_used = 0u32;
        let mut deadline = Instant::now() + timeout;
        let mut polls: u32 = 0;
        loop {
            self.check_poison();
            if t.try_recv_frame(out) {
                sh.delivered.fetch_add(1, Ordering::SeqCst);
                return;
            }
            // our own spilled sends may be exactly what the peer is
            // blocked on — flush them while we wait
            for tx in sh.transports.iter().flatten() {
                tx.pump();
            }
            if t.peer_gone() || (polls % 16 == 0 && self.peer_dead_marker(peer)) {
                let f = RankFailure {
                    failed_rank: peer,
                    kind: FailureKind::PeerExit,
                    detail: format!(
                        "rank {} recv from {peer}: peer process exited mid-round on \
                         link r{peer}->r{} via {} transport",
                        self.rank, self.rank, sh.transport_kind
                    ),
                };
                let msg = f.to_string();
                sh.record_failure(f);
                sh.poison(&msg);
                panic!("{msg}");
            }
            polls = polls.wrapping_add(1);
            if Instant::now() >= deadline {
                if retries_used < budget {
                    retries_used += 1;
                    deadline = Instant::now() + timeout;
                    continue;
                }
                let msg = format!(
                    "rank {} recv from {peer}: no message after {timeout:?} on link \
                     r{peer}->r{}{} ({} ring direction) via {} transport — stalled \
                     link (threaded round watchdog)",
                    self.rank,
                    self.rank,
                    if self.ch >= CH_BG { " [bg lane]" } else { "" },
                    self.link_direction(peer),
                    sh.transport_kind
                );
                sh.record_failure(RankFailure {
                    failed_rank: peer,
                    kind: FailureKind::RecvTimeout { retries: retries_used },
                    detail: msg.clone(),
                });
                sh.poison(&msg);
                panic!("{msg}");
            }
            std::thread::sleep(Duration::from_micros(200));
        }
    }

    fn remote_recv_any<T: Any>(&self, peer: usize) -> T {
        let sh = &self.shared;
        WIRE_BUF.with(|b| {
            let mut buf = b.borrow_mut();
            self.remote_recv_frame(peer, &mut buf);
            assert!(!buf.is_empty(), "empty transport frame");
            let payload = &buf[1..];
            let boxed: Box<dyn Any> = match buf[0] {
                wire::FORM_ANY => {
                    sh.counters.msg_allocs.fetch_add(1, Ordering::Relaxed);
                    wire::decode_any(payload)
                }
                wire::FORM_F32 => {
                    // cross-typed pickup of a pooled frame
                    sh.counters.msg_allocs.fetch_add(1, Ordering::Relaxed);
                    let mut v = Vec::new();
                    f32s_from_bytes(payload, &mut v);
                    Box::new(v)
                }
                f => panic!("rank {}: unknown frame form byte {f}", self.rank),
            };
            *boxed.downcast::<T>().unwrap_or_else(|_| {
                panic!(
                    "rank {} recv from {peer}: payload type mismatch (expected {})",
                    self.rank,
                    std::any::type_name::<T>()
                )
            })
        })
    }

    fn remote_recv_vec(&self, peer: usize) -> Vec<f32> {
        let sh = &self.shared;
        WIRE_BUF.with(|b| {
            let mut buf = b.borrow_mut();
            self.remote_recv_frame(peer, &mut buf);
            assert!(!buf.is_empty(), "empty transport frame");
            match buf[0] {
                wire::FORM_F32 => {
                    let mut v = self.lease_incoming(peer, (buf.len() - 1) / 4);
                    f32s_from_bytes(&buf[1..], &mut v);
                    v
                }
                wire::FORM_ANY => {
                    sh.counters.msg_allocs.fetch_add(1, Ordering::Relaxed);
                    let boxed = wire::decode_any(&buf[1..]);
                    *boxed.downcast::<Vec<f32>>().unwrap_or_else(|_| {
                        panic!(
                            "rank {} recv from {peer}: payload type mismatch \
                             (expected Vec<f32>)",
                            self.rank
                        )
                    })
                }
                f => panic!("rank {}: unknown frame form byte {f}", self.rank),
            }
        })
    }
}

thread_local! {
    /// Reused wire-encode/-decode scratch of this thread's remote sends
    /// and receives (zero steady-state allocations once warmed).
    static WIRE_BUF: RefCell<Vec<u8>> = RefCell::new(Vec::new());
}

impl fmt::Debug for RingPort {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "RingPort(rank {}/{}{})",
            self.rank,
            self.n,
            if self.ch >= CH_BG { ", bg" } else { "" }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn send_then_recv_roundtrips() {
        let fab = RingFabric::new(4);
        let ports = fab.ports();
        ports[0].send(1, vec![1.0f32, 2.0]);
        assert_eq!(fab.in_flight(), 1);
        assert_eq!(ports[1].pending_from(0), 1);
        let got: Vec<f32> = ports[1].recv(0);
        assert_eq!(got, vec![1.0, 2.0]);
        assert_eq!(fab.in_flight(), 0);
        assert_eq!(fab.messages_sent(), 1);
        assert_eq!(fab.messages_delivered(), 1);
    }

    #[test]
    fn links_are_fifo() {
        let fab = RingFabric::new(2);
        let ports = fab.ports();
        ports[0].send(1, 10usize);
        ports[0].send(1, 20usize);
        assert_eq!(ports[1].recv::<usize>(0), 10);
        assert_eq!(ports[1].recv::<usize>(0), 20);
    }

    #[test]
    fn mixed_typed_and_pooled_traffic_stays_fifo() {
        // boxed and pooled messages share one lane FIFO: order holds
        let fab = RingFabric::new(2);
        let ports = fab.ports();
        ports[0].send(1, 7usize);
        ports[0].send_vec(1, vec![1.0, 2.0]);
        ports[0].send(1, 9usize);
        assert_eq!(ports[1].recv::<usize>(0), 7);
        assert_eq!(ports[1].recv_vec(0), vec![1.0, 2.0]);
        assert_eq!(ports[1].recv::<usize>(0), 9);
    }

    #[test]
    fn pooled_send_recv_release_cycles_buffers() {
        let fab = RingFabric::new(2);
        let ports = fab.ports();
        // prime: first lease misses the pool
        let mut v = ports[0].lease(1, 4);
        v.extend_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        ports[0].send_vec(1, v);
        let got = ports[1].recv_vec(0);
        assert_eq!(got, vec![1.0, 2.0, 3.0, 4.0]);
        ports[1].release(0, got);
        let c0 = fab.counters();
        // steady state: lease hits the pool, no new allocations
        for i in 0..10 {
            let mut v = ports[0].lease(1, 4);
            v.extend_from_slice(&[i as f32; 4]);
            ports[0].send_vec(1, v);
            let got = ports[1].recv_vec(0);
            assert_eq!(got, vec![i as f32; 4]);
            ports[1].release(0, got);
        }
        let c1 = fab.counters();
        assert_eq!(c1.msg_allocs, c0.msg_allocs, "pooled path allocated");
        assert_eq!(c1.pool_hits - c0.pool_hits, 10);
    }

    #[test]
    fn generic_recv_accepts_pooled_payload() {
        let fab = RingFabric::new(2);
        let ports = fab.ports();
        ports[0].send_vec(1, vec![5.0]);
        let got: Vec<f32> = ports[1].recv(0);
        assert_eq!(got, vec![5.0]);
        // and vice versa: boxed Vec<f32> picked up by recv_vec
        ports[0].send(1, vec![6.0f32]);
        assert_eq!(ports[1].recv_vec(0), vec![6.0]);
    }

    #[test]
    fn background_lanes_are_independent_of_main_lanes() {
        // the same directed edge carries two independent FIFOs: main
        // traffic and background (comm-thread) traffic never interleave
        let fab = RingFabric::new(2);
        let main0 = fab.port(0);
        let bg0 = fab.bg_port(0);
        let main1 = fab.port(1);
        let bg1 = main1.background();
        assert!(bg0.is_background() && !main0.is_background());
        main0.send(1, 1usize);
        bg0.send(1, 2usize);
        main0.send(1, 3usize);
        // bg receiver sees ONLY the bg message, regardless of send order
        assert_eq!(bg1.pending_from(0), 1);
        assert_eq!(main1.pending_from(0), 2);
        assert_eq!(bg1.recv::<usize>(0), 2);
        assert_eq!(main1.recv::<usize>(0), 1);
        assert_eq!(main1.recv::<usize>(0), 3);
        assert_eq!(fab.in_flight(), 0);
    }

    #[test]
    fn background_pools_are_separate() {
        // pooled buffers released on a bg lane do not feed the main lane
        let fab = RingFabric::new(2);
        let bg0 = fab.bg_port(0);
        let bg1 = fab.bg_port(1);
        let mut v = bg0.lease(1, 2);
        v.extend_from_slice(&[1.0, 2.0]);
        bg0.send_vec(1, v);
        let got = bg1.recv_vec(0);
        assert_eq!(got, vec![1.0, 2.0]);
        bg1.release(0, got);
        // steady state on the bg lane: lease hits the bg pool
        let c0 = fab.counters();
        let mut v = bg0.lease(1, 2);
        v.extend_from_slice(&[3.0, 4.0]);
        bg0.send_vec(1, v);
        bg1.release(0, bg1.recv_vec(0));
        let c1 = fab.counters();
        assert_eq!(c1.msg_allocs, c0.msg_allocs, "bg pool missed");
        assert_eq!(c1.pool_hits - c0.pool_hits, 1);
    }

    #[test]
    fn both_directions_are_independent_links() {
        let fab = RingFabric::new(3);
        let ports = fab.ports();
        // rank 1 receives from both neighbors without crosstalk
        ports[0].send(1, 100usize);
        ports[2].send(1, 200usize);
        assert_eq!(ports[1].recv::<usize>(2), 200);
        assert_eq!(ports[1].recv::<usize>(0), 100);
    }

    #[test]
    fn neighbors_wrap_around_the_ring() {
        let fab = RingFabric::new(4);
        let p3 = fab.port(3);
        assert_eq!(p3.next(), 0);
        assert_eq!(p3.prev(), 2);
        p3.send(0, 7usize);
        assert_eq!(fab.port(0).recv::<usize>(3), 7);
    }

    #[test]
    #[should_panic(expected = "only links neighbors")]
    fn non_neighbor_send_rejected() {
        let fab = RingFabric::new(4);
        fab.port(0).send(2, 1usize);
    }

    #[test]
    #[should_panic(expected = "mailbox empty")]
    fn recv_on_empty_mailbox_panics_outside_rounds() {
        let fab = RingFabric::new(2);
        fab.port(0).recv::<usize>(1);
    }

    #[test]
    #[should_panic(expected = "payload type mismatch")]
    fn recv_wrong_type_panics() {
        let fab = RingFabric::new(2);
        let ports = fab.ports();
        ports[0].send(1, 1.0f32);
        let _: usize = ports[1].recv(0);
    }

    #[test]
    fn single_rank_ring_links_to_itself() {
        let fab = RingFabric::new(1);
        let p = fab.port(0);
        assert_eq!(p.next(), 0);
        assert_eq!(p.prev(), 0);
        p.send(0, 5usize);
        assert_eq!(p.recv::<usize>(0), 5);
    }

    /// One neighbor exchange per rank, written rank-locally.
    fn exchange_round(policy: LaunchPolicy, n: usize) -> Vec<usize> {
        let fab = RingFabric::new(n);
        let tasks: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..n)
            .map(|r| {
                let port = fab.port(r);
                Box::new(move || {
                    port.send(port.next(), r * 10);
                    port.recv::<usize>(port.prev())
                }) as Box<dyn FnOnce() -> usize + Send>
            })
            .collect();
        let out = fab.run_round(policy, tasks);
        assert_eq!(fab.in_flight(), 0);
        out
    }

    #[test]
    fn lockstep_round_exchanges_blockingly() {
        for n in [1usize, 2, 3, 4, 8] {
            let got = exchange_round(LaunchPolicy::Lockstep, n);
            let want: Vec<usize> = (0..n).map(|r| ((r + n - 1) % n) * 10).collect();
            assert_eq!(got, want, "n={n}");
        }
    }

    #[test]
    fn threaded_round_exchanges_blockingly() {
        for n in [1usize, 2, 4, 8] {
            let got = exchange_round(LaunchPolicy::Threaded, n);
            let want: Vec<usize> = (0..n).map(|r| ((r + n - 1) % n) * 10).collect();
            assert_eq!(got, want, "n={n}");
        }
    }

    #[test]
    fn lockstep_order_is_deterministic_round_robin() {
        // ranks record the global order in which their bodies ran to
        // completion; with no blocking recv the order is exactly 0..n
        let n = 5;
        let fab = RingFabric::new(n);
        let order = Mutex::new(Vec::new());
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..n)
            .map(|r| {
                let order = &order;
                Box::new(move || {
                    order.lock().unwrap().push(r);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        fab.run_round(LaunchPolicy::Lockstep, tasks);
        assert_eq!(*order.lock().unwrap(), (0..n).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic(expected = "ring deadlock")]
    fn lockstep_detects_deadlock() {
        let fab = RingFabric::new(2);
        let tasks: Vec<Box<dyn FnOnce() + Send>> = (0..2)
            .map(|r| {
                let port = fab.port(r);
                // everyone receives first — nobody ever sends
                Box::new(move || {
                    let _: usize = port.recv(port.prev());
                }) as Box<dyn FnOnce() + Send>
            })
            .collect();
        fab.run_round(LaunchPolicy::Lockstep, tasks);
    }

    #[test]
    fn rank_panic_poisons_the_round() {
        let fab = RingFabric::new(2);
        let tasks: Vec<Box<dyn FnOnce() + Send>> = (0..2)
            .map(|r| {
                let port = fab.port(r);
                Box::new(move || {
                    if r == 0 {
                        panic!("rank 0 exploded");
                    }
                    // rank 1 would otherwise wait forever
                    let _: usize = port.recv(port.prev());
                }) as Box<dyn FnOnce() + Send>
            })
            .collect();
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            fab.run_round(LaunchPolicy::Lockstep, tasks);
        }));
        assert!(caught.is_err());
        // the fabric is reusable after the failed round
        let p = fab.port(0);
        p.send(1, 3usize);
        assert_eq!(fab.port(1).recv::<usize>(0), 3);
    }

    #[test]
    fn threaded_watchdog_names_the_stalled_link() {
        let fab = RingFabric::new(2);
        fab.set_recv_timeout(Some(Duration::from_millis(150)));
        let tasks: Vec<Box<dyn FnOnce() + Send>> = (0..2)
            .map(|r| {
                let port = fab.port(r);
                Box::new(move || {
                    if r == 0 {
                        // waits on a message rank 1 never sends
                        let _: usize = port.recv(1);
                    }
                    // rank 1 returns immediately
                }) as Box<dyn FnOnce() + Send>
            })
            .collect();
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            fab.run_round(LaunchPolicy::Threaded, tasks);
        }));
        let payload = caught.expect_err("watchdog must fire");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(msg.contains("link r1->r0"), "missing link identity: {msg}");
        assert!(msg.contains("threaded round watchdog"), "{msg}");
        fab.set_recv_timeout(None);
        // the fabric is reusable after the poisoned round
        assert_eq!(fab.in_flight(), 0);
        let p = fab.port(0);
        p.send(1, 3usize);
        assert_eq!(fab.port(1).recv::<usize>(0), 3);
    }

    #[test]
    fn watchdog_records_typed_rank_failure() {
        let fab = RingFabric::new(2);
        fab.set_recv_timeout(Some(Duration::from_millis(150)));
        let tasks: Vec<Box<dyn FnOnce() + Send>> = (0..2)
            .map(|r| {
                let port = fab.port(r);
                Box::new(move || {
                    if r == 0 {
                        let _: usize = port.recv(1);
                    }
                }) as Box<dyn FnOnce() + Send>
            })
            .collect();
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            fab.run_round(LaunchPolicy::Threaded, tasks);
        }));
        assert!(caught.is_err());
        fab.set_recv_timeout(None);
        let f = fab.rank_failure().expect("watchdog must record the failed rank");
        assert_eq!(f.failed_rank, 1, "{f}");
        assert!(matches!(f.kind, FailureKind::RecvTimeout { retries: 0 }), "{f}");
        assert!(f.detail.contains("link r1->r0"), "{f}");
        // a later healthy round clears the record
        let tasks: Vec<Box<dyn FnOnce() + Send>> =
            (0..2).map(|_| Box::new(|| {}) as Box<dyn FnOnce() + Send>).collect();
        fab.run_round(LaunchPolicy::Threaded, tasks);
        assert!(fab.rank_failure().is_none());
    }

    #[test]
    fn recv_retry_budget_extends_the_watchdog() {
        // one 120ms window would declare the sender dead; 4 extra retry
        // windows cover its 250ms stall
        let fab = RingFabric::new(2);
        fab.set_recv_timeout(Some(Duration::from_millis(120)));
        fab.set_recv_retries(Some(4));
        let tasks: Vec<Box<dyn FnOnce() + Send>> = (0..2)
            .map(|r| {
                let port = fab.port(r);
                Box::new(move || {
                    if r == 0 {
                        assert_eq!(port.recv::<usize>(1), 42);
                    } else {
                        std::thread::sleep(Duration::from_millis(250));
                        port.send(0, 42usize);
                    }
                }) as Box<dyn FnOnce() + Send>
            })
            .collect();
        fab.run_round(LaunchPolicy::Threaded, tasks);
        assert!(fab.rank_failure().is_none());
        // exhausted budget still records the burned retries
        let tasks: Vec<Box<dyn FnOnce() + Send>> = (0..2)
            .map(|r| {
                let port = fab.port(r);
                Box::new(move || {
                    if r == 0 {
                        let _: usize = port.recv(1);
                    }
                }) as Box<dyn FnOnce() + Send>
            })
            .collect();
        fab.set_recv_retries(Some(1));
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            fab.run_round(LaunchPolicy::Threaded, tasks);
        }));
        assert!(caught.is_err());
        let f = fab.rank_failure().expect("typed failure after budget exhaustion");
        assert!(matches!(f.kind, FailureKind::RecvTimeout { retries: 1 }), "{f}");
        fab.set_recv_timeout(None);
        fab.set_recv_retries(None);
    }

    #[test]
    fn injected_rank_death_is_recorded_as_typed_failure() {
        use crate::runtime::fault::FaultPhase;
        for policy in [LaunchPolicy::Lockstep, LaunchPolicy::Threaded] {
            let fab = RingFabric::new(2);
            fab.set_recv_timeout(Some(Duration::from_secs(5)));
            let tasks: Vec<Box<dyn FnOnce() + Send>> = (0..2)
                .map(|r| {
                    let port = fab.port(r);
                    Box::new(move || {
                        if r == 1 {
                            std::panic::panic_any(RankDeath {
                                rank: 1,
                                step: 7,
                                phase: FaultPhase::Forward,
                            });
                        }
                        let _: usize = port.recv(1);
                    }) as Box<dyn FnOnce() + Send>
                })
                .collect();
            let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                fab.run_round(policy, tasks);
            }));
            assert!(caught.is_err());
            let f = fab.rank_failure().expect("injected death must be typed");
            assert_eq!(f.failed_rank, 1, "{policy:?}: {f}");
            assert!(
                matches!(f.kind, FailureKind::Injected { phase: FaultPhase::Forward }),
                "{policy:?}: {f}"
            );
            assert_eq!(fab.in_flight(), 0, "{policy:?}");
        }
    }

    #[test]
    fn poison_teardown_returns_pooled_buffers() {
        let fab = RingFabric::new(2);
        let tasks: Vec<Box<dyn FnOnce() + Send>> = (0..2)
            .map(|r| {
                let port = fab.port(r);
                Box::new(move || {
                    if r == 0 {
                        // leave a pooled payload in flight, then die
                        let mut v = port.lease(1, 4);
                        v.extend_from_slice(&[1.0, 2.0, 3.0, 4.0]);
                        port.send_vec(1, v);
                        panic!("rank 0 died with a message in flight");
                    }
                    let _: usize = port.recv(0);
                }) as Box<dyn FnOnce() + Send>
            })
            .collect();
        let c0 = fab.counters();
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            fab.run_round(LaunchPolicy::Lockstep, tasks);
        }));
        assert!(caught.is_err());
        let c1 = fab.counters();
        assert_eq!(c1.poison_drained - c0.poison_drained, 1, "in-flight message drained");
        assert_eq!(fab.in_flight(), 0);
        // the drained payload went back to the lane pool: the next lease
        // on the same link is a pool hit, not an allocation
        let c2 = fab.counters();
        let v = fab.port(0).lease(1, 4);
        let c3 = fab.counters();
        assert!(v.capacity() >= 4);
        assert_eq!(c3.pool_hits - c2.pool_hits, 1, "drained buffer not pooled");
        assert_eq!(c3.msg_allocs, c2.msg_allocs);
    }

    #[test]
    fn threaded_round_survives_heavy_bidirectional_traffic() {
        // concurrent sends in both directions on every link must neither
        // deadlock nor drop or reorder messages (per-link FIFO)
        let n = 4;
        let k = 200usize;
        let fab = RingFabric::new(n);
        let tasks: Vec<Box<dyn FnOnce() + Send>> = (0..n)
            .map(|r| {
                let port = fab.port(r);
                Box::new(move || {
                    for i in 0..k {
                        port.send(port.next(), (r, i));
                        port.send(port.prev(), (r, i + 1000));
                    }
                    for i in 0..k {
                        let (src, seq): (usize, usize) = port.recv(port.prev());
                        assert_eq!((src, seq), (port.prev(), i));
                        let (src, seq): (usize, usize) = port.recv(port.next());
                        assert_eq!((src, seq), (port.next(), i + 1000));
                    }
                }) as Box<dyn FnOnce() + Send>
            })
            .collect();
        fab.run_round(LaunchPolicy::Threaded, tasks);
        assert_eq!(fab.in_flight(), 0);
        assert_eq!(fab.messages_sent(), (2 * n * k) as u64);
        assert_eq!(fab.messages_delivered(), (2 * n * k) as u64);
    }

    #[test]
    fn counters_track_sends_locks_and_wakeups() {
        let fab = RingFabric::new(2);
        fab.reset_counters();
        let ports = fab.ports();
        ports[0].send(1, 1usize); // one boxed message
        let _: usize = ports[1].recv(0);
        let c = fab.counters();
        assert_eq!(c.msg_allocs, 1);
        assert!(c.lock_acquisitions >= 2, "{c:?}");
        // threaded round with a blocking recv: targeted wakeup counted.
        // (The receiver parks in slices; retry the round if the send ever
        // lands in the sliver between parks.)
        for attempt in 0..4 {
            fab.reset_counters();
            let tasks: Vec<Box<dyn FnOnce() + Send>> = (0..2)
                .map(|r| {
                    let port = fab.port(r);
                    Box::new(move || {
                        if r == 1 {
                            // give rank 0 a chance to park first
                            std::thread::sleep(Duration::from_millis(30));
                            port.send(0, 9usize);
                        } else {
                            let _: usize = port.recv(1);
                        }
                    }) as Box<dyn FnOnce() + Send>
                })
                .collect();
            fab.run_round(LaunchPolicy::Threaded, tasks);
            if fab.counters().wakeups >= 1 {
                return;
            }
            eprintln!("attempt {attempt}: send landed between parks; retrying");
        }
        panic!("no targeted wakeup recorded in 4 rounds");
    }
}
