//! α-β interconnect cost model.
//!
//! Prices every communication primitive the engines issue, replacing the
//! NCCL timings of the paper's testbed (DESIGN.md §2). `alpha` is the
//! per-message latency in seconds (dominant for small transfers — the
//! paper's §3.3 concern), `beta` is seconds per byte (1 / bandwidth).
//!
//! Ring-algorithm costs (You et al. 2018, the paper's reference):
//!   sendrecv(M)        = α + M·β                      (one ring hop)
//!   rotation(M)        = α + M·β                      (all workers in parallel)
//!   allgather(M)       = (N-1)·(α + (M/N)·β)
//!   reduce_scatter(M)  = (N-1)·(α + (M/N)·β)
//!   allreduce(M)       = 2·(N-1)·(α + (M/N)·β)
//!   broadcast(M)       = α·(N-1) + M·β                (pipelined ring)
//!   all_to_all(M)      = (N-1)·α + M·β·(N-1)/2        (chunk-peeling relay)
//!
//! `M` is the *full* message size in bytes (for allgather/reduce_scatter:
//! the reconstructed full buffer; for rotation/sendrecv: the shard moved).
//! The §3.4.2 claim — rotation executed (N-1) times costs the same as one
//! allgather of the full buffer — falls straight out of these formulas and
//! is checked by `comm_microbench`.
//!
//! Since the ring-fabric refactor the model is charged PER HOP, not per
//! collective: [`CommPrim::hop_schedule`] decomposes each primitive into
//! its ring-hop message sizes (matching the chunked implementations in
//! [`crate::comm`]), each hop costs `α + hop_bytes·β`
//! ([`LinkModel::hop_time_f`]), and the closed forms above are exactly the
//! per-hop sums — asserted by `hop_schedule_sums_to_closed_form` below.

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CommPrim {
    SendRecv,
    Rotation,
    AllGather,
    ReduceScatter,
    AllReduce,
    Broadcast,
    AllToAll,
}

impl CommPrim {
    /// The ring-hop decomposition of this primitive for a full message of
    /// `bytes` across `n` ranks: one entry per hop, holding the bytes each
    /// rank moves to its neighbor on that hop (fractional so the per-hop
    /// sum reproduces the closed-form α-β cost exactly).
    ///
    /// - `SendRecv` / `Rotation`: 1 hop of the whole shard
    /// - `AllGather` / `ReduceScatter`: N-1 hops of M/N
    /// - `AllReduce`: 2(N-1) hops of M/N (reduce-scatter + all-gather)
    /// - `AllToAll`: N-1 hops of SHRINKING size — hop `h` (1-based)
    ///   carries `(N-h)·M/N` per rank, matching `comm::all_to_all`'s
    ///   chunk-peeling relay byte-for-byte (each rank peels its chunk off
    ///   the passing packet, so the packet sheds M/N per hop)
    /// - `Broadcast`: N-1 stages of M/(N-1) — the bottleneck LINK's
    ///   schedule; the pipeline keeps several links busy per stage, so
    ///   wall-clock is one link's serialized traffic (`comm::broadcast`
    ///   implements exactly this chunk stream)
    pub fn hop_schedule(&self, bytes: u64, n: usize) -> Vec<f64> {
        let m = bytes as f64;
        match self {
            CommPrim::SendRecv | CommPrim::Rotation => vec![m],
            CommPrim::AllGather | CommPrim::ReduceScatter => {
                if n <= 1 {
                    Vec::new()
                } else {
                    vec![m / n as f64; n - 1]
                }
            }
            CommPrim::AllToAll => {
                if n <= 1 {
                    Vec::new()
                } else {
                    (1..n).map(|h| (n - h) as f64 * m / n as f64).collect()
                }
            }
            CommPrim::AllReduce => {
                if n <= 1 {
                    Vec::new()
                } else {
                    vec![m / n as f64; 2 * (n - 1)]
                }
            }
            CommPrim::Broadcast => {
                if n <= 1 {
                    Vec::new()
                } else {
                    vec![m / (n - 1) as f64; n - 1]
                }
            }
        }
    }

    /// Number of ring hops this primitive takes across `n` ranks.
    pub fn hop_count(&self, n: usize) -> usize {
        self.hop_schedule(0, n).len()
    }
}

impl std::fmt::Display for CommPrim {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            CommPrim::SendRecv => "sendrecv",
            CommPrim::Rotation => "rotation",
            CommPrim::AllGather => "allgather",
            CommPrim::ReduceScatter => "reduce-scatter",
            CommPrim::AllReduce => "allreduce",
            CommPrim::Broadcast => "broadcast",
            CommPrim::AllToAll => "all-to-all",
        };
        f.write_str(s)
    }
}

/// One interconnect: α-β parameters. See `perfmodel::hardware` for the
/// calibrated NVLink / PCIe instances.
#[derive(Debug, Clone)]
pub struct LinkModel {
    pub name: String,
    /// Per-message latency, seconds.
    pub alpha: f64,
    /// Seconds per byte (1 / effective bandwidth).
    pub beta: f64,
}

impl LinkModel {
    pub fn new(name: &str, alpha: f64, bandwidth_bytes_per_s: f64) -> Self {
        LinkModel { name: name.to_string(), alpha, beta: 1.0 / bandwidth_bytes_per_s }
    }

    /// One neighbor exchange of `bytes` (both directions concurrently —
    /// full-duplex links, as NVLink/PCIe are).
    pub fn sendrecv(&self, bytes: u64) -> f64 {
        self.alpha + bytes as f64 * self.beta
    }

    /// One ring hop moving a (possibly fractional) `bytes` payload — the
    /// unit the per-hop timeline charges.
    pub fn hop_time_f(&self, bytes: f64) -> f64 {
        self.alpha + bytes * self.beta
    }

    /// One rotation step moves one shard per worker simultaneously; on a
    /// full-duplex ring this costs a single sendrecv of the shard.
    pub fn rotation_step(&self, shard_bytes: u64) -> f64 {
        self.sendrecv(shard_bytes)
    }

    /// Ring allgather reconstructing `full_bytes` across `n` workers.
    pub fn allgather(&self, full_bytes: u64, n: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        (n - 1) as f64 * (self.alpha + full_bytes as f64 / n as f64 * self.beta)
    }

    /// Ring reduce-scatter of a `full_bytes` buffer.
    pub fn reduce_scatter(&self, full_bytes: u64, n: usize) -> f64 {
        self.allgather(full_bytes, n)
    }

    /// Ring allreduce (reduce-scatter + allgather).
    pub fn allreduce(&self, full_bytes: u64, n: usize) -> f64 {
        2.0 * self.allgather(full_bytes, n)
    }

    /// Pipelined ring broadcast.
    pub fn broadcast(&self, bytes: u64, n: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        self.alpha * (n - 1) as f64 + bytes as f64 * self.beta
    }

    /// Chunk-peeling ring all-to-all of `bytes` per worker: N-1 hops,
    /// hop `h` moving `(N-h)·M/N` — the packet sheds one delivered chunk
    /// per hop, so the bandwidth term sums to `M·(N-1)/2` (the honest
    /// neighbor-relay cost: Σ_{h=1}^{N-1} (N-h)·M/N = M·(N-1)/2).
    pub fn all_to_all(&self, bytes: u64, n: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        (n - 1) as f64 * self.alpha + bytes as f64 * self.beta * (n - 1) as f64 / 2.0
    }

    /// Dispatch by primitive. `bytes` is the full-message convention above.
    pub fn time(&self, prim: CommPrim, bytes: u64, n: usize) -> f64 {
        match prim {
            CommPrim::SendRecv => self.sendrecv(bytes),
            CommPrim::Rotation => self.rotation_step(bytes),
            CommPrim::AllGather => self.allgather(bytes, n),
            CommPrim::ReduceScatter => self.reduce_scatter(bytes, n),
            CommPrim::AllReduce => self.allreduce(bytes, n),
            CommPrim::Broadcast => self.broadcast(bytes, n),
            CommPrim::AllToAll => self.all_to_all(bytes, n),
        }
    }
}

/// Completion time of each of several collectives run as a CONVOY on one
/// serialized wire: collective `i+1`'s first hop starts only after
/// collective `i`'s last hop — the FIFO background comm thread's
/// schedule. `hop_scheds[i]` is collective `i`'s per-hop byte list
/// ([`CommPrim::hop_schedule`]).
pub fn convoy_completion_times(link: &LinkModel, hop_scheds: &[Vec<f64>]) -> Vec<f64> {
    let mut t = 0.0;
    hop_scheds
        .iter()
        .map(|hops| {
            t += hops.iter().map(|&b| link.hop_time_f(b)).sum::<f64>();
            t
        })
        .collect()
}

/// Completion time of the same collectives with their hops ROUND-ROBIN
/// interleaved on the serialized wire — the hop-level scheduler's
/// schedule. Total wire time is identical to the convoy (same hops, same
/// wire), but short collectives stop queueing behind long ones: a
/// latency-critical prefetch finishes after ~its own hops × the number
/// of in-flight peers, not after the whole convoy ahead of it.
pub fn interleaved_completion_times(
    link: &LinkModel,
    hop_scheds: &[Vec<f64>],
) -> Vec<f64> {
    let mut done = vec![0.0; hop_scheds.len()];
    let mut next_hop = vec![0usize; hop_scheds.len()];
    let mut remaining = hop_scheds.iter().filter(|h| !h.is_empty()).count();
    let mut t = 0.0;
    while remaining > 0 {
        for (i, hops) in hop_scheds.iter().enumerate() {
            if next_hop[i] < hops.len() {
                t += link.hop_time_f(hops[next_hop[i]]);
                next_hop[i] += 1;
                if next_hop[i] == hops.len() {
                    done[i] = t;
                    remaining -= 1;
                }
            }
        }
    }
    done
}

#[cfg(test)]
mod tests {
    use super::*;

    fn link() -> LinkModel {
        // 5 µs latency, 100 GB/s
        LinkModel::new("test", 5e-6, 100e9)
    }

    #[test]
    fn sendrecv_latency_dominates_small() {
        let l = link();
        // 1 KiB at 100 GB/s ~ 10 ns << 5 µs latency
        let t = l.sendrecv(1024);
        assert!(t > 0.99 * l.alpha && t < 1.1 * l.alpha);
    }

    #[test]
    fn rotation_n_minus_1_approx_allgather() {
        // Paper §3.4.2: (N-1) rotations of M/N ≈ one allgather of M for
        // message sizes past the latency regime (> 1 MB).
        let l = link();
        let n = 8;
        let m: u64 = 64 << 20;
        let rot = (n - 1) as f64 * l.rotation_step(m / n as u64);
        let ag = l.allgather(m, n as usize);
        let ratio = rot / ag;
        assert!((ratio - 1.0).abs() < 1e-9, "ratio {ratio}");
    }

    #[test]
    fn allreduce_is_twice_allgather() {
        let l = link();
        assert!((l.allreduce(1 << 20, 8) - 2.0 * l.allgather(1 << 20, 8)).abs() < 1e-12);
    }

    #[test]
    fn single_worker_collectives_free() {
        let l = link();
        assert_eq!(l.allgather(1 << 20, 1), 0.0);
        assert_eq!(l.allreduce(1 << 20, 1), 0.0);
        assert_eq!(l.all_to_all(1 << 20, 1), 0.0);
    }

    #[test]
    fn bandwidth_term_scales_linearly() {
        let l = link();
        let t1 = l.sendrecv(10 << 20) - l.alpha;
        let t2 = l.sendrecv(20 << 20) - l.alpha;
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn dispatch_matches_direct() {
        let l = link();
        let m = 3 << 20;
        assert_eq!(l.time(CommPrim::AllGather, m, 4), l.allgather(m, 4));
        assert_eq!(l.time(CommPrim::Rotation, m, 4), l.rotation_step(m));
        assert_eq!(l.time(CommPrim::Broadcast, m, 4), l.broadcast(m, 4));
    }

    #[test]
    fn hop_schedule_sums_to_closed_form() {
        // the per-hop decomposition must reproduce the closed-form α-β
        // costs: allreduce = 2(N-1) hops of M/N, etc.
        let l = link();
        let prims = [
            CommPrim::SendRecv,
            CommPrim::Rotation,
            CommPrim::AllGather,
            CommPrim::ReduceScatter,
            CommPrim::AllReduce,
            CommPrim::Broadcast,
            CommPrim::AllToAll,
        ];
        for n in [1usize, 2, 3, 4, 8, 16] {
            for m in [0u64, 1 << 10, 3 << 20, 64 << 20] {
                for prim in prims {
                    let hops = prim.hop_schedule(m, n);
                    let sum: f64 = hops.iter().map(|&b| l.hop_time_f(b)).sum();
                    let closed = l.time(prim, m, n);
                    let err = (sum - closed).abs() / closed.max(1e-30);
                    assert!(
                        err < 1e-9,
                        "{prim} n={n} m={m}: per-hop {sum} vs closed {closed}"
                    );
                }
            }
        }
    }

    #[test]
    fn interleaving_preserves_total_but_frees_short_collectives() {
        // one big bucketed allreduce convoying ahead of a small prefetch
        // allgather: interleaving must not change the total wire time,
        // but the allgather's completion must drop well below its convoy
        // position at the back of the queue
        let l = link();
        let n = 8;
        let scheds = vec![
            CommPrim::AllReduce.hop_schedule(64 << 20, n),
            CommPrim::AllGather.hop_schedule(256 << 10, n),
        ];
        let convoy = convoy_completion_times(&l, &scheds);
        let inter = interleaved_completion_times(&l, &scheds);
        let total_c = convoy.iter().cloned().fold(0.0, f64::max);
        let total_i = inter.iter().cloned().fold(0.0, f64::max);
        assert!(
            (total_c - total_i).abs() / total_c < 1e-9,
            "same hops, same wire: {total_c} vs {total_i}"
        );
        // round-robin bound: the 7-hop allgather completes after 7
        // rounds of (one AR hop + one AG hop) ≈ half the 14-AR-hop
        // convoy, instead of waiting out the whole allreduce first
        assert!(
            inter[1] < 0.6 * convoy[1],
            "allgather should escape the convoy: {} vs {}",
            inter[1],
            convoy[1]
        );
        // empty schedules (n = 1) complete at time 0 under both
        let empty = vec![CommPrim::AllGather.hop_schedule(1 << 20, 1)];
        assert_eq!(convoy_completion_times(&l, &empty), vec![0.0]);
        assert_eq!(interleaved_completion_times(&l, &empty), vec![0.0]);
    }

    #[test]
    fn allreduce_hop_count_is_2n_minus_2() {
        assert_eq!(CommPrim::AllReduce.hop_count(8), 14);
        assert_eq!(CommPrim::AllGather.hop_count(8), 7);
        assert_eq!(CommPrim::Rotation.hop_count(8), 1);
        assert_eq!(CommPrim::AllReduce.hop_count(1), 0);
    }
}
