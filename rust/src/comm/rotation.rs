//! The paper's rotation schedule (§3.3, Fig 2).
//!
//! Clockwise rotation: every worker sends its buffer to the *next* worker
//! on the ring and receives from the *previous* one — after the exchange,
//! worker `w` holds what worker `w-1` held. Used for the forward pass.
//! Counter-clockwise is the mirror (worker `w` receives from `w+1`), used
//! for the backward pass so that after N-1 steps every shard is back home.
//!
//! This module is the schedule MATH only: which neighbor a rank talks to
//! ([`RotationDir::send_peer`] / [`RotationDir::recv_peer`]) and which
//! shard sits where after `t` hops ([`shard_at`]). The data movement
//! itself is [`crate::comm::rotate_ring`] (type-erased) or
//! [`crate::comm::rotate_ring_vec`] (the pooled zero-allocation lane
//! path) — one true neighbor send/recv per rank through the ring fabric —
//! and, when the hop should overlap compute, a
//! [`crate::comm::CommStream`] issuing the same exchange eagerly. The old
//! whole-array `rotate_right(1)` shortcut survives only in
//! [`crate::comm::reference`].

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RotationDir {
    /// Forward-pass direction: `w` receives from `w-1`.
    Clockwise,
    /// Backward-pass direction: `w` receives from `w+1`.
    CounterClockwise,
}

impl RotationDir {
    /// The rank `w` receives from under this direction.
    pub fn recv_peer(&self, w: usize, n: usize) -> usize {
        match self {
            RotationDir::Clockwise => (w + n - 1) % n,
            RotationDir::CounterClockwise => (w + 1) % n,
        }
    }

    /// The rank `w` sends to under this direction.
    pub fn send_peer(&self, w: usize, n: usize) -> usize {
        match self {
            RotationDir::Clockwise => (w + 1) % n,
            RotationDir::CounterClockwise => (w + n - 1) % n,
        }
    }
}

/// Which original shard worker `w` holds after `t` rotations in direction
/// `dir`, given that worker `w` started with shard `w`. This is the shard
/// schedule the RTP engines compute against at each step.
pub fn shard_at(dir: RotationDir, w: usize, t: usize, n: usize) -> usize {
    match dir {
        RotationDir::Clockwise => (w + n - (t % n)) % n,
        RotationDir::CounterClockwise => (w + t) % n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::fabric::RingFabric;
    use crate::comm::{reference, rotate_ring};
    use crate::util::prop;

    /// `t` fabric rotation hops, each rank carrying its own payload
    /// through its own port (starting from shard id == rank).
    fn rotated(n: usize, t: usize, dir: RotationDir) -> Vec<usize> {
        let fab = RingFabric::new(n.max(1));
        let v = crate::comm::spmd(&fab, |port| {
            let mut held = port.rank();
            for _ in 0..t {
                held = rotate_ring(&port, held, dir);
            }
            held
        });
        assert_eq!(fab.in_flight(), 0, "rotation left messages in flight");
        v
    }

    #[test]
    fn cw_moves_to_next() {
        // worker 1 now holds what worker 0 had
        assert_eq!(rotated(4, 1, RotationDir::Clockwise), vec![3, 0, 1, 2]);
    }

    #[test]
    fn ccw_moves_to_prev() {
        assert_eq!(rotated(4, 1, RotationDir::CounterClockwise), vec![1, 2, 3, 0]);
    }

    #[test]
    fn n_rotations_is_identity() {
        prop::check("rotate^N == id", 100, |rng| {
            let n = 1 + rng.below(9);
            let orig: Vec<usize> = (0..n).collect();
            for dir in [RotationDir::Clockwise, RotationDir::CounterClockwise] {
                let v = rotated(n, n, dir);
                if v != orig {
                    return Err(format!("{dir:?}^{n} != id: {v:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn cw_then_ccw_cancels() {
        let fab = RingFabric::new(3);
        let v = crate::comm::spmd(&fab, |port| {
            let held = 10 * (port.rank() + 1);
            let held = rotate_ring(&port, held, RotationDir::Clockwise);
            rotate_ring(&port, held, RotationDir::CounterClockwise)
        });
        assert_eq!(v, vec![10, 20, 30]);
    }

    #[test]
    fn shard_at_matches_fabric_rotation() {
        prop::check("shard_at tracks rotate", 100, |rng| {
            let n = 1 + rng.below(8);
            let t = rng.below(3 * n + 1);
            for dir in [RotationDir::Clockwise, RotationDir::CounterClockwise] {
                let v = rotated(n, t, dir);
                for w in 0..n {
                    let want = shard_at(dir, w, t, n);
                    if v[w] != want {
                        return Err(format!(
                            "{dir:?} n={n} t={t} w={w}: got {} want {want}",
                            v[w]
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn fabric_rotation_agrees_with_reference() {
        prop::check("fabric == reference rotation", 60, |rng| {
            let n = 1 + rng.below(8);
            let t = rng.below(2 * n + 1);
            for dir in [RotationDir::Clockwise, RotationDir::CounterClockwise] {
                let got = rotated(n, t, dir);
                let mut want: Vec<usize> = (0..n).collect();
                for _ in 0..t {
                    match dir {
                        RotationDir::Clockwise => reference::rotate_cw(&mut want),
                        RotationDir::CounterClockwise => reference::rotate_ccw(&mut want),
                    }
                }
                if got != want {
                    return Err(format!("{dir:?} n={n} t={t}: {got:?} != {want:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn every_worker_sees_every_shard_exactly_once() {
        // The paper's balanced-workload claim: over the N steps of a
        // forward pass, each worker computes against each shard once.
        prop::check("coverage", 50, |rng| {
            let n = 1 + rng.below(8);
            for w in 0..n {
                let mut seen = vec![false; n];
                for t in 0..n {
                    let s = shard_at(RotationDir::Clockwise, w, t, n);
                    if seen[s] {
                        return Err(format!("worker {w} saw shard {s} twice"));
                    }
                    seen[s] = true;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn backward_returns_weights_home() {
        // After fwd (N-1 cw hops) worker w holds shard (w+1)%N; after
        // bwd (N-1 ccw hops) it holds shard w again (paper Fig 1).
        for n in 1..=8 {
            let fab = RingFabric::new(n);
            for w in 0..n {
                let after_fwd = shard_at(RotationDir::Clockwise, w, n - 1, n);
                assert_eq!(after_fwd, (w + 1) % n);
            }
            // bwd starts from the post-forward assignment, rank-locally
            let v = crate::comm::spmd(&fab, |port| {
                let mut held =
                    shard_at(RotationDir::Clockwise, port.rank(), n - 1, n);
                for _ in 0..n - 1 {
                    held = rotate_ring(&port, held, RotationDir::CounterClockwise);
                }
                held
            });
            for (w, held) in v.iter().enumerate() {
                assert_eq!(*held, w, "n={n} w={w}");
            }
        }
    }

    #[test]
    fn peers_are_ring_neighbors() {
        let d = RotationDir::Clockwise;
        assert_eq!(d.send_peer(3, 4), 0);
        assert_eq!(d.recv_peer(0, 4), 3);
        let d = RotationDir::CounterClockwise;
        assert_eq!(d.send_peer(0, 4), 3);
        assert_eq!(d.recv_peer(3, 4), 0);
    }
}
