//! Support substrates: JSON, PRNG, stats, property testing, byte formatting.
//!
//! All hand-rolled because the build environment's crate cache is offline
//! (no serde/rand/proptest/criterion) — see DESIGN.md §2 for the
//! substitution table.

pub mod bytes;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
