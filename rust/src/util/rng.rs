//! Deterministic PRNG (xoshiro256** core) — the offline crate cache has no
//! `rand`, and determinism across runs matters more than statistical
//! sophistication: every engine-equivalence test relies on seeding the same
//! weights on every engine.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed into the xoshiro state
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// The raw xoshiro state — checkpointable (see [`Rng::from_state`]).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild from a [`Rng::state`] snapshot: continues the exact
    /// sequence the snapshotted generator would have produced.
    pub fn from_state(s: [u64; 4]) -> Self {
        Rng { s }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.uniform() * n as f64) as usize % n
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.uniform() * (hi - lo)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fill with N(0, std) f32s (weight init).
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal() as f32 * std;
        }
    }

    pub fn fill_uniform_i32(&mut self, out: &mut [i32], n: i32) {
        for v in out.iter_mut() {
            *v = self.below(n as usize) as i32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let xs: Vec<f64> = (0..20000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}
