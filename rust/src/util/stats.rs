//! Summary statistics for the bench harness (criterion substitute).

#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    pub p95: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary::default();
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n.max(2).saturating_sub(1) as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Online mean/variance (Welford), used by the memory ledger summaries.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn n(&self) -> u64 {
        self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert!((percentile_sorted(&v, 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile_sorted(&v, 95.0) - 9.5).abs() < 1e-12);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::default();
        for x in xs {
            w.push(x);
        }
        let s = Summary::of(&xs);
        assert!((w.mean() - s.mean).abs() < 1e-12);
        assert!((w.var().sqrt() - s.std).abs() < 1e-12);
    }

    #[test]
    fn empty_is_safe() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
    }
}
