//! Minimal JSON parser/writer.
//!
//! The offline crate cache has no serde, so the artifact manifest and config
//! files are handled by this hand-rolled implementation. It supports the
//! full JSON grammar minus exotic number forms; numbers are kept as f64
//! (fine for the manifest: shapes, counts, flags).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// `obj["a"]["b"]`-style access; returns Null for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }
    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        self.as_arr().and_then(|a| a.get(i)).unwrap_or(&NULL)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }
    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }
    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(arr)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // re-decode multi-byte utf8 from the source slice
                    let start = self.pos - 1;
                    let width = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + width).min(self.b.len());
                    if let Ok(chunk) = std::str::from_utf8(&self.b[start..end]) {
                        s.push_str(chunk);
                    }
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

// ---------------------------------------------------------------------------
// writer
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\t' => write!(f, "\\t")?,
            '\r' => write!(f, "\\r")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Convenience builders used by the figure writers.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn num(n: f64) -> Json {
    Json::Num(n)
}
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(j.get("a").idx(2).get("b").as_str(), Some("x"));
        assert_eq!(j.get("c").as_bool(), Some(false));
        assert_eq!(j.get("missing"), &Json::Null);
    }

    #[test]
    fn parse_unicode_escape() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }

    #[test]
    fn parse_utf8_passthrough() {
        let j = Json::parse("\"héllo→\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo→"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"b":true,"n":null,"o":{"k":-3}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn real_manifest_shape() {
        let src = r#"{"entries":[{"key":"mlp_fwd__b2__p2","inputs":[["f32",[2,16,32]]]}]}"#;
        let j = Json::parse(src).unwrap();
        let e = j.get("entries").idx(0);
        assert_eq!(e.get("key").as_str(), Some("mlp_fwd__b2__p2"));
        assert_eq!(e.get("inputs").idx(0).idx(1).idx(2).as_usize(), Some(32));
    }
}
