//! Byte-size formatting + constants (memory figures are the paper's core).

pub const KIB: u64 = 1 << 10;
pub const MIB: u64 = 1 << 20;
pub const GIB: u64 = 1 << 30;

/// `1234567` -> `"1.18 MiB"` — used by every memory report.
pub fn human(bytes: u64) -> String {
    let b = bytes as f64;
    if bytes >= GIB {
        format!("{:.2} GiB", b / GIB as f64)
    } else if bytes >= MIB {
        format!("{:.2} MiB", b / MIB as f64)
    } else if bytes >= KIB {
        format!("{:.2} KiB", b / KIB as f64)
    } else {
        format!("{bytes} B")
    }
}

/// f32 element count -> bytes.
pub fn f32_bytes(elems: usize) -> u64 {
    (elems * 4) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats() {
        assert_eq!(human(512), "512 B");
        assert_eq!(human(2048), "2.00 KiB");
        assert_eq!(human(3 * MIB + MIB / 2), "3.50 MiB");
        assert_eq!(human(80 * GIB), "80.00 GiB");
    }

    #[test]
    fn f32_sizes() {
        assert_eq!(f32_bytes(1024), 4096);
    }
}
