//! Mini property-testing harness (proptest substitute — the offline crate
//! cache has no proptest; DESIGN.md §2 records the substitution).
//!
//! Usage:
//! ```ignore
//! prop::check("rotation is identity", 200, |rng| {
//!     let n = 1 + rng.below(8);
//!     // ... build a case from rng ...
//!     if bad { return Err(format!("n={n} broke")); }
//!     Ok(())
//! });
//! ```
//! On failure it panics with the seed + case index so the exact case can be
//! replayed with `PROP_SEED`.

use super::rng::Rng;

/// Base seed: override with env PROP_SEED to replay a failure.
fn base_seed() -> u64 {
    std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// Run `cases` random cases of `prop`. Each case gets an Rng derived from
/// (base_seed, case index) so failures are independently replayable.
pub fn check<F>(name: &str, cases: usize, prop: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    let seed = base_seed();
    for i in 0..cases {
        let mut rng = Rng::new(seed ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15));
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at case {i}/{cases} \
                 (PROP_SEED={seed}): {msg}"
            );
        }
    }
}

/// Helper: assert approximate equality of slices inside a property.
pub fn close(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let denom = x.abs().max(y.abs()).max(1.0);
        if (x - y).abs() / denom > tol {
            return Err(format!("elem {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial() {
        check("trivial", 50, |_| Ok(()));
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn reports_failure() {
        check("fails", 10, |rng| {
            if rng.below(3) == 0 {
                Err("boom".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn close_detects_mismatch() {
        assert!(close(&[1.0, 2.0], &[1.0, 2.0], 1e-6).is_ok());
        assert!(close(&[1.0], &[1.1], 1e-3).is_err());
        assert!(close(&[1.0], &[1.0, 2.0], 1e-3).is_err());
    }
}
