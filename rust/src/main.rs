//! `rtp` — the leader binary: train, simulate, trace and inspect
//! subcommands over the RTP engines.
//!
//! Examples:
//!   rtp train --preset tiny --engine rtp-inplace --workers 2 --steps 50
//!   rtp train --preset e2e-small --engine rtp-outofplace --workers 2 \
//!       --exec pjrt --steps 200
//!   rtp simulate --preset gpt2-500m --engine fsdp --workers 8 --batch 64
//!   rtp trace --workers 4
//!   rtp inspect --presets

use anyhow::{anyhow, bail, Result};

use rtp::bench_util::Table;
use rtp::cli::Args;
use rtp::config::{presets, OptimizerKind, Strategy, TrainCfg};
use rtp::parallel::{build_engine, Batch, EngineOpts, ExecKind, Launcher};
use rtp::perfmodel::{by_name, simulate, SimSpec};
use rtp::runtime::{FaultPlan, RecoveryPolicy, Supervisor};
use rtp::serve::{build_serve_engine, poisson_trace, ServeOpts};
use rtp::train::{
    capture_train_state, load_train_state, restore_train_state, save_train_state, train,
    MarkovCorpus, Optimizer,
};
use rtp::util::bytes::human;
use rtp::util::rng::Rng;

const USAGE: &str = "\
rtp — Rotated Tensor Parallelism (paper reproduction)

USAGE: rtp <subcommand> [flags]

SUBCOMMANDS
  train     run the training loop on the synthetic Markov corpus
            --preset tiny|tiny-moe|e2e-small|e2e-100m   (default tiny)
            --engine single|ddp|fsdp|tp|rtp-inplace|rtp-outofplace
            --workers N  --global-batch B  --steps K  --lr F
            --optimizer sgd|momentum|adam  --exec pjrt|pallas|oracle
            --launcher lockstep|thread  (or RTP_LAUNCHER env)
            --save PATH (write an RTPC2 checkpoint after the run)
            --resume PATH (restore an RTPC2 checkpoint before the run;
              the world size may differ from the one that saved it)
            --fault-plan rank=R,step=S,phase=forward|backward|rotation|collective
              (deterministically kill rank R at step S; or RTP_FAULT_PLAN env)
            --elastic (supervise the run: recover in-process from rank
              failures by shrinking to N' or respawning, resuming from the
              latest async snapshot)
            --ckpt-every K (elastic snapshot cadence in steps; default 10)
            --recovery mode=shrink|respawn,max=3,backoff_ms=10,...
              (elastic retry/backoff policy; or RTP_RECOVERY env)
            --seed S  --quiet
  simulate  model one step at paper scale (virtual mode)
            --preset gpt2-500m|...  --engine ...  --workers N
            --batch B  --hw a100|v100  --no-capacity  --no-recycle
  serve     continuous-batching generation over a Poisson arrival trace
            --preset tiny|...  --engine single|tp|rtp-inplace|rtp-outofplace
            --workers N  --requests R  --rate F (arrivals/step)
            --prompt-len P  --max-new T  --max-batch B  --page-tokens K
            --capacity-mb M (KV admission budget; default unlimited)
            --launcher lockstep|thread  --seed S
  trace     print the rotation schedule (paper Figs 1-2)
            --workers N  --preset tiny
  inspect   --presets (Table 2) | --preset <name> (config + memory model)
  help      this text

Figures/benches: `cargo bench` regenerates every paper table and figure
into figures/ (see DESIGN.md §5 for the index).

Transports: RTP_TRANSPORT=inproc|shm|uds selects the fabric's data-plane
byte transport (default inproc). Launcher::Process (--launcher process,
step/gather paths only) spawns one `rtp worker` OS process per rank over
shm or uds.
";

fn exec_kind(args: &Args) -> Result<ExecKind> {
    Ok(match args.get_or("exec", "oracle") {
        "pjrt" => ExecKind::Pjrt,
        "pallas" => ExecKind::PjrtPallas,
        "oracle" => ExecKind::Oracle,
        "virtual" => ExecKind::Virtual,
        other => bail!("unknown --exec {other:?}"),
    })
}

fn strategy(args: &Args) -> Result<Strategy> {
    let name = args.get_or("engine", "rtp-inplace");
    Strategy::parse(name).ok_or_else(|| anyhow!("unknown --engine {name:?}"))
}

fn launcher(args: &Args) -> Result<Launcher> {
    match args.get("launcher") {
        None => Ok(Launcher::from_env()),
        Some(name) => Launcher::parse(name)
            .ok_or_else(|| anyhow!("unknown --launcher {name:?} (lockstep|thread|process)")),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let preset = args.get_or("preset", "tiny");
    let strategy = strategy(args)?;
    let workers = args.usize_or("workers", 2)?;
    let global_batch = args.usize_or("global-batch", 4)?;
    let tcfg = TrainCfg {
        steps: args.usize_or("steps", 50)?,
        lr: args.f32_or("lr", 1e-3)?,
        optimizer: OptimizerKind::parse(args.get_or("optimizer", "adam"))
            .ok_or_else(|| anyhow!("unknown --optimizer"))?,
        seed: args.u64_or("seed", 42)?,
        log_every: args.usize_or("log-every", 10)?,
    };
    let picked_launcher = launcher(args)?;
    if picked_launcher == Launcher::Process {
        bail!(
            "rtp train cannot use --launcher process: the optimizer walks \
             engine-owned params in memory (visit_owned), which cannot cross \
             a process boundary. Use lockstep or thread; Launcher::Process \
             drives step/gather paths (benches, equivalence and fault suites)."
        );
    }
    let mut opts = EngineOpts::new(preset, strategy, workers, global_batch)
        .exec(exec_kind(args)?)
        .launcher(picked_launcher)
        .seed(tcfg.seed);
    if let Some(spec) = args.get("fault-plan") {
        opts = opts.fault_plan(Some(FaultPlan::parse(spec)?));
    }
    if let Some(spec) = args.get("recovery") {
        opts = opts.recovery(Some(RecoveryPolicy::parse(spec)?));
    }
    if args.switch("elastic") {
        return cmd_train_elastic(args, opts, &tcfg);
    }
    let cfg = opts.cfg()?;
    let mut engine = build_engine(&opts)?;
    println!(
        "training {preset} ({} params) with {} on {} workers, global batch {global_batch}, exec {}",
        cfg.params_total(),
        engine.name(),
        engine.ctx().cluster.n(),
        args.get_or("exec", "oracle"),
    );
    let mut corpus = MarkovCorpus::new(&cfg, tcfg.seed);
    let mut opt = Optimizer::new(tcfg.optimizer, tcfg.lr);
    let mut base_step: u64 = 0;
    if let Some(path) = args.get("resume") {
        let state = load_train_state(&cfg, std::path::Path::new(path))?;
        base_step = state.step;
        corpus = restore_train_state(&mut *engine, &mut opt, &cfg, &state)?;
        println!(
            "resumed from {path} (saved at step {base_step} on {} workers)",
            state.world_size
        );
    }
    let report = train(
        &mut *engine,
        &mut opt,
        &mut corpus,
        &tcfg,
        global_batch,
        args.switch("quiet"),
    )?;
    let (head, tail) = report.head_tail_means(5);
    println!(
        "done: {} steps in {:.1}s ({:.0} tok/s), loss {head:.4} -> {tail:.4}, peak/worker {}",
        report.steps,
        report.wall_s,
        report.tokens_per_s,
        human(report.peak_bytes_per_worker)
    );
    if let Some(path) = args.get("save") {
        let state = capture_train_state(
            &mut *engine,
            &opt,
            &corpus,
            base_step + report.steps as u64,
        )?;
        save_train_state(&state, std::path::Path::new(path))?;
        println!("saved RTPC2 checkpoint to {path} (step {})", state.step);
    }
    Ok(())
}

/// `rtp train --elastic`: the supervised run — async off-thread
/// snapshots every `--ckpt-every` steps (written crash-atomically to
/// `--save` when given) and in-process recovery from rank failures per
/// the `--recovery` / `RTP_RECOVERY` policy.
fn cmd_train_elastic(args: &Args, opts: EngineOpts, tcfg: &TrainCfg) -> Result<()> {
    if args.get("resume").is_some() {
        bail!(
            "--elastic does not combine with --resume: the supervisor seeds \
             recovery from its own step-0 snapshot"
        );
    }
    let cfg = opts.cfg()?;
    println!(
        "elastic training {} ({} params) with {} on {} workers, global batch {}",
        opts.preset,
        cfg.params_total(),
        opts.strategy,
        opts.workers,
        opts.global_batch,
    );
    let mut sup = Supervisor::new(opts, tcfg.optimizer, tcfg.lr)
        .ckpt_every(args.u64_or("ckpt-every", 10)?)
        .ckpt_path(args.get("save").map(std::path::PathBuf::from))
        .quiet(args.switch("quiet"));
    let report = sup.run(tcfg.steps as u64)?;
    let n = report.losses.len();
    let (head, tail) = (
        report.losses.iter().take(5).sum::<f32>() / 5f32.min(n as f32).max(1.0),
        report.losses.iter().rev().take(5).sum::<f32>() / 5f32.min(n as f32).max(1.0),
    );
    println!(
        "done: {} steps, {} recoveries, final world size {}, loss {head:.4} -> {tail:.4}",
        report.steps,
        report.recoveries.len(),
        report.final_workers,
    );
    for ev in &report.recoveries {
        println!(
            "  step {}: rank {} failed; {} -> {} workers, resumed from step {} \
             (backoff {:?}, rebuild {:?}, restore {:?}, total {:?})",
            ev.at_step,
            ev.failed_rank,
            ev.from_workers,
            ev.to_workers,
            ev.resumed_from_step,
            ev.backoff,
            ev.rebuild,
            ev.restore,
            ev.total,
        );
    }
    if let Some(path) = args.get("save") {
        println!(
            "async RTPC2 checkpoints to {path}: {} submitted, {} written, {} skipped",
            report.ckpt.submitted, report.ckpt.written, report.ckpt.skipped
        );
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let hw = by_name(args.get_or("hw", "a100"))
        .ok_or_else(|| anyhow!("unknown --hw (a100|v100|cpu)"))?;
    let mut spec = SimSpec::new(
        args.get_or("preset", "gpt2-500m"),
        strategy(args)?,
        args.usize_or("workers", 8)?,
        args.usize_or("batch", 8)?,
        hw,
    );
    spec.enforce_capacity = !args.switch("no-capacity");
    spec.rtp_recycle = !args.switch("no-recycle");
    if let Some(o) = args.get("optimizer") {
        spec.optimizer =
            OptimizerKind::parse(o).ok_or_else(|| anyhow!("unknown --optimizer"))?;
    }
    let r = simulate(&spec)?;
    let mut t = Table::new(
        &format!(
            "simulate {} / {} / N={} / batch {} on {}",
            spec.preset, spec.strategy, spec.workers, spec.global_batch, spec.hw.name
        ),
        &["metric", "value"],
    );
    if let Some(oom) = &r.oom {
        t.row(vec!["OOM".into(), oom.clone()]);
    } else {
        t.row(vec!["step time".into(), format!("{:.3} ms", r.step_time * 1e3)]);
        t.row(vec!["throughput".into(), format!("{:.0} wps", r.wps)]);
        t.row(vec!["compute util".into(), format!("{:.0}%", r.compute_util * 100.0)]);
        t.row(vec!["comm util".into(), format!("{:.0}%", r.comm_util * 100.0)]);
        t.row(vec!["alloc stalls".into(), r.stalls.to_string()]);
    }
    t.row(vec!["peak/worker".into(), human(r.peak_per_worker)]);
    t.row(vec!["peak total".into(), human(r.peak_total)]);
    for (cat, v) in &r.peak_by_cat {
        t.row(vec![format!("  at-peak {cat}"), human(*v)]);
    }
    t.print();
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let preset = args.get_or("preset", "tiny");
    let strategy = strategy(args)?;
    let workers = args.usize_or("workers", 2)?;
    let capacity = args
        .get("capacity-mb")
        .map(|v| {
            v.parse::<u64>()
                .map(|mb| mb * 1024 * 1024)
                .map_err(|_| anyhow!("--capacity-mb expects an integer, got {v:?}"))
        })
        .transpose()?;
    let opts = ServeOpts::new(preset)
        .strategy(strategy)
        .workers(workers)
        .max_batch(args.usize_or("max-batch", 4)?)
        .page_tokens(args.usize_or("page-tokens", 8)?)
        .capacity(capacity)
        .seed(args.u64_or("seed", 42)?)
        .launcher(launcher(args)?);
    let cfg = opts.cfg()?;
    let mut engine = build_serve_engine(&opts)?;
    let trace = poisson_trace(
        &cfg,
        args.usize_or("requests", 16)?,
        args.f32_or("rate", 0.5)? as f64,
        args.usize_or("prompt-len", 4)?,
        args.usize_or("max-new", 8)?,
        opts.seed.wrapping_add(1),
    );
    println!(
        "serving {} requests on {preset} / {strategy} / N={} ({}), kv budget {}",
        trace.len(),
        engine.n(),
        opts.launcher,
        if engine.kv_budget() == u64::MAX {
            "unlimited".to_string()
        } else {
            human(engine.kv_budget())
        },
    );
    engine.run_trace(&trace)?;
    let r = engine.report();
    let mut t = Table::new("serving report", &["metric", "value"]);
    t.row(vec!["finished".into(), r.finished.len().to_string()]);
    t.row(vec!["rejected".into(), r.rejected.len().to_string()]);
    t.row(vec!["scheduler steps".into(), r.steps.to_string()]);
    t.row(vec!["decode steps".into(), r.decode_steps.to_string()]);
    t.row(vec!["tokens".into(), r.tokens.to_string()]);
    t.row(vec!["tokens/s".into(), format!("{:.0}", r.tokens_per_s)]);
    t.row(vec!["TPOT p50".into(), format!("{:.3} ms", r.tpot_p50_ms)]);
    t.row(vec!["TPOT p99".into(), format!("{:.3} ms", r.tpot_p99_ms)]);
    t.row(vec!["KV pages/token".into(), format!("{:.4}", r.kv_allocs_per_token)]);
    t.row(vec!["KV peak/rank".into(), human(r.kv_peak_bytes_per_rank)]);
    t.print();
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<()> {
    let workers = args.usize_or("workers", 4)?;
    let preset = args.get_or("preset", "tiny");
    let opts = EngineOpts::new(preset, Strategy::RtpInplace, workers, workers)
        .exec(ExecKind::Virtual)
        .trace(true);
    let cfg = opts.cfg()?;
    let mut engine = build_engine(&opts)?;
    let batch = Batch::synth(&cfg, workers, &mut Rng::new(1));
    engine.step(&batch)?;
    println!("{}", engine.ctx().cluster.trace.render());
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    if args.switch("presets") {
        let mut t = Table::new(
            "model presets (paper Table 2 + runtime)",
            &["name", "vocab", "hidden", "heads", "layers", "seq", "ffn", "params", "weights"],
        );
        for name in presets::all_names() {
            let m = presets::get(&name).unwrap();
            t.row(vec![
                m.name.clone(),
                m.vocab.to_string(),
                m.hidden.to_string(),
                m.heads.to_string(),
                m.layers.to_string(),
                m.seq.to_string(),
                m.ffn.to_string(),
                m.params_total().to_string(),
                human(m.weight_bytes()),
            ]);
        }
        t.print();
        return Ok(());
    }
    let name = args
        .get("preset")
        .ok_or_else(|| anyhow!("inspect needs --presets or --preset <name>"))?;
    let m = presets::get(name).ok_or_else(|| anyhow!("unknown preset {name:?}"))?;
    println!("{m:#?}");
    let (a, w) = (m.activation_bytes_per_sample(), m.weight_bytes());
    println!("weights: {}", human(w));
    println!("activations/sample: {}", human(a));
    let mut t = Table::new(
        "Table 1 (analytic, N=8, batch 8, G=W)",
        &["technique", "activations", "parameters", "duplication"],
    );
    for s in Strategy::ALL {
        let r = rtp::memory::analytic::table1_row(s, 8 * a, w, w, 8);
        t.row(vec![
            r.technique,
            human(r.activations),
            human(r.parameters),
            human(r.duplication),
        ]);
    }
    t.print();
    Ok(())
}

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".to_string());
    let result = match sub.as_str() {
        "train" => cmd_train(&args),
        // re-entrant child mode of Launcher::Process — not in USAGE on
        // purpose (spawned by the parent, not typed by hand)
        "worker" => rtp::runtime::worker_main(&args),
        "serve" => cmd_serve(&args),
        "simulate" => cmd_simulate(&args),
        "trace" => cmd_trace(&args),
        "inspect" => cmd_inspect(&args),
        "help" | "--help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand {other:?}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
