//! The simulated worker ring — the substitute for the paper's 8-GPU node
//! (DESIGN.md §2).
//!
//! A `Cluster` is N `Worker`s joined in a ring. Each worker owns a
//! `MemTracker` (its device memory) and a `RingPort` — its rank-local
//! endpoint on the shared [`RingFabric`] interconnect — so every engine
//! allocation is accounted per-device exactly as
//! `torch.cuda.max_memory_allocated` would have recorded it, and every
//! inter-worker transfer is a neighbor hop through the worker's own port.
//! The cluster also keeps an event trace that the rotation-trace example
//! and the overlap figures render.

pub mod trace;

use crate::comm::{RingFabric, RingPort, TransportKind};
use crate::memory::tracker::MemTracker;

pub use trace::{TraceEvent, TraceLog};

/// One simulated device.
#[derive(Debug)]
pub struct Worker {
    pub rank: usize,
    pub tracker: MemTracker,
    /// This worker's mailbox endpoint on the ring fabric.
    pub port: RingPort,
}

/// N workers on a ring.
#[derive(Debug)]
pub struct Cluster {
    pub workers: Vec<Worker>,
    pub trace: TraceLog,
    fabric: RingFabric,
    /// Rank-ordered port set, built once (the rotation loops ask for it
    /// every hop).
    ports: Vec<RingPort>,
}

impl Cluster {
    /// `capacity` = per-device memory cap in bytes (None = unlimited,
    /// analysis mode).
    pub fn new(n: usize, capacity: Option<u64>) -> Self {
        Self::new_with_transport(n, capacity, TransportKind::from_env())
    }

    /// [`Cluster::new`] over an explicit data-plane transport backend
    /// instead of the `RTP_TRANSPORT` env default.
    pub fn new_with_transport(
        n: usize,
        capacity: Option<u64>,
        transport: TransportKind,
    ) -> Self {
        assert!(n >= 1, "cluster needs at least one worker");
        let fabric = RingFabric::with_transport(n, transport);
        Cluster {
            workers: (0..n)
                .map(|rank| Worker {
                    rank,
                    tracker: MemTracker::new(rank, capacity),
                    port: fabric.port(rank),
                })
                .collect(),
            trace: TraceLog::default(),
            ports: fabric.ports(),
            fabric,
        }
    }

    pub fn n(&self) -> usize {
        self.workers.len()
    }

    /// The shared ring interconnect (hop/message accounting lives here).
    pub fn fabric(&self) -> &RingFabric {
        &self.fabric
    }

    /// Every worker's fabric port, in rank order — what the SPMD
    /// collective drivers in [`crate::comm`] consume.
    pub fn ports(&self) -> &[RingPort] {
        &self.ports
    }

    /// Next rank clockwise (the rank `w` sends to in a cw rotation).
    pub fn next_cw(&self, w: usize) -> usize {
        (w + 1) % self.n()
    }

    /// Previous rank (the rank `w` receives from in a cw rotation).
    pub fn prev_cw(&self, w: usize) -> usize {
        (w + self.n() - 1) % self.n()
    }

    pub fn tracker(&mut self, w: usize) -> &mut MemTracker {
        &mut self.workers[w].tracker
    }

    /// Max peak across workers (the "peak memory allocated" the paper
    /// reports is per-GPU; with symmetric engines all workers peak alike).
    pub fn max_peak(&self) -> u64 {
        self.workers.iter().map(|w| w.tracker.peak()).max().unwrap_or(0)
    }

    /// Sum of peaks — the whole-system memory of paper Table 1 /  Fig 9.
    pub fn total_peak(&self) -> u64 {
        self.workers.iter().map(|w| w.tracker.peak()).sum()
    }

    pub fn reset_peaks(&mut self) {
        for w in &mut self.workers {
            w.tracker.reset_peak();
        }
    }

    /// Total outstanding allocations (must be 0 after a clean engine drop —
    /// asserted by the integration tests).
    pub fn outstanding(&self) -> usize {
        self.workers.iter().map(|w| w.tracker.outstanding()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::tracker::MemCategory;

    #[test]
    fn ring_neighbors_wrap() {
        let c = Cluster::new(4, None);
        assert_eq!(c.next_cw(3), 0);
        assert_eq!(c.prev_cw(0), 3);
        assert_eq!(c.next_cw(1), 2);
    }

    #[test]
    fn peaks_aggregate() {
        let mut c = Cluster::new(2, None);
        let a = c.tracker(0).alloc(MemCategory::Weights, 100).unwrap();
        let _b = c.tracker(1).alloc(MemCategory::Weights, 40).unwrap();
        assert_eq!(c.max_peak(), 100);
        assert_eq!(c.total_peak(), 140);
        c.tracker(0).free(a);
        assert_eq!(c.outstanding(), 1);
        // peaks survive frees
        assert_eq!(c.max_peak(), 100);
    }

    #[test]
    fn capacity_propagates() {
        let mut c = Cluster::new(2, Some(64));
        assert!(c.tracker(0).alloc(MemCategory::Weights, 65).is_err());
        assert!(c.tracker(1).alloc(MemCategory::Weights, 64).is_ok());
    }

    #[test]
    #[should_panic(expected = "at least one worker")]
    fn zero_workers_rejected() {
        Cluster::new(0, None);
    }

    #[test]
    fn workers_share_one_fabric() {
        let c = Cluster::new(3, None);
        // worker 0 sends through ITS port; worker 1 receives through its own
        c.workers[0].port.send(1, 42usize);
        assert_eq!(c.fabric().in_flight(), 1);
        assert_eq!(c.workers[1].port.recv::<usize>(0), 42);
        assert_eq!(c.fabric().in_flight(), 0);
        assert_eq!(c.ports().len(), 3);
    }
}
