//! Structured event trace of an engine step.
//!
//! Engines append semantic events (compute on shard s, rotate cw, ...);
//! `examples/rotation_trace.rs` renders the trace as the paper's Fig 1 /
//! Fig 2 diagrams, and the tests assert schedule invariants on it (every
//! worker touches every shard exactly once per pass, weights end up home).

use std::fmt;

use crate::comm::CommPrim;

#[derive(Debug, Clone, PartialEq)]
pub enum TraceEvent {
    /// Worker computed one partition step.
    Compute { worker: usize, unit: String, shard: usize, step: usize },
    /// A collective involving all workers.
    Collective { prim: CommPrim, bytes: u64, note: String },
    /// One rotation step (all workers exchange simultaneously).
    Rotate { dir: &'static str, bytes_per_worker: u64, step: usize },
    /// One ring-fabric hop of a collective: hop `hop` of `of`, every rank
    /// moving `bytes_per_rank` to its clockwise neighbor. A chunked ring
    /// allreduce shows up as its full 2(N-1)-hop schedule.
    Hop { prim: CommPrim, hop: usize, of: usize, bytes_per_rank: u64 },
    /// Phase marker (forward / backward / optimizer).
    Phase { name: String },
}

impl fmt::Display for TraceEvent {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TraceEvent::Compute { worker, unit, shard, step } => {
                write!(f, "  w{worker} compute {unit}[shard {shard}] (step {step})")
            }
            TraceEvent::Collective { prim, bytes, note } => {
                write!(f, "  {prim} {bytes}B {note}")
            }
            TraceEvent::Rotate { dir, bytes_per_worker, step } => {
                write!(f, "  rotate-{dir} {bytes_per_worker}B/worker (step {step})")
            }
            TraceEvent::Hop { prim, hop, of, bytes_per_rank } => {
                write!(f, "  {prim} hop {}/{of} {bytes_per_rank}B/rank", hop + 1)
            }
            TraceEvent::Phase { name } => write!(f, "== {name} =="),
        }
    }
}

#[derive(Debug, Default)]
pub struct TraceLog {
    pub events: Vec<TraceEvent>,
    /// Recording is off by default: per-step tracing in a thousand-step
    /// training run would swamp memory for no benefit.
    pub enabled: bool,
}

impl TraceLog {
    pub fn enabled() -> Self {
        TraceLog { events: Vec::new(), enabled: true }
    }

    pub fn push(&mut self, e: TraceEvent) {
        if self.enabled {
            self.events.push(e);
        }
    }

    pub fn phase(&mut self, name: &str) {
        self.push(TraceEvent::Phase { name: name.to_string() });
    }

    /// All (worker, shard) compute pairs for a given unit substring —
    /// schedule-invariant checks key off this.
    pub fn compute_pairs(&self, unit_contains: &str) -> Vec<(usize, usize)> {
        self.events
            .iter()
            .filter_map(|e| match e {
                TraceEvent::Compute { worker, unit, shard, .. }
                    if unit.contains(unit_contains) =>
                {
                    Some((*worker, *shard))
                }
                _ => None,
            })
            .collect()
    }

    pub fn rotations(&self) -> usize {
        self.events
            .iter()
            .filter(|e| matches!(e, TraceEvent::Rotate { .. }))
            .count()
    }

    /// Ring-fabric hops traced for the chunked collectives (rotation hops
    /// are counted separately by [`TraceLog::rotations`]).
    pub fn fabric_hops(&self) -> usize {
        self.events
            .iter()
            .filter(|e| matches!(e, TraceEvent::Hop { .. }))
            .count()
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        for e in &self.events {
            s.push_str(&e.to_string());
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_log_records_nothing() {
        let mut log = TraceLog::default();
        log.push(TraceEvent::Phase { name: "fwd".into() });
        assert!(log.events.is_empty());
    }

    #[test]
    fn enabled_log_records_and_filters() {
        let mut log = TraceLog::enabled();
        log.phase("forward");
        log.push(TraceEvent::Compute {
            worker: 0,
            unit: "attn.l0".into(),
            shard: 1,
            step: 0,
        });
        log.push(TraceEvent::Compute {
            worker: 1,
            unit: "mlp.l0".into(),
            shard: 0,
            step: 0,
        });
        log.push(TraceEvent::Rotate { dir: "cw", bytes_per_worker: 64, step: 0 });
        assert_eq!(log.compute_pairs("attn"), vec![(0, 1)]);
        assert_eq!(log.rotations(), 1);
        let text = log.render();
        assert!(text.contains("== forward =="));
        assert!(text.contains("rotate-cw"));
    }

    #[test]
    fn hop_events_render_and_count() {
        let mut log = TraceLog::enabled();
        for h in 0..3 {
            log.push(TraceEvent::Hop {
                prim: CommPrim::AllReduce,
                hop: h,
                of: 3,
                bytes_per_rank: 128,
            });
        }
        assert_eq!(log.fabric_hops(), 3);
        assert_eq!(log.rotations(), 0);
        let text = log.render();
        assert!(text.contains("allreduce hop 1/3 128B/rank"));
        assert!(text.contains("allreduce hop 3/3"));
    }
}
