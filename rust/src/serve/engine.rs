//! The serving facade: request queue, admission control, and the
//! continuous-batching scheduler over the SPMD rank set.
//!
//! Scheduling is indexed by decode step, not wall clock: requests join
//! the running batch at the first step boundary where a slot is free
//! and their projected KV bytes fit the tracker budget, and leave at
//! the boundary after their last token. The per-step batch plan is
//! therefore a pure function of (trace, config) — the same plan runs on
//! every rank under either launcher, which is what makes the emitted
//! token streams bit-identical between `Launcher::Lockstep` (the
//! determinism oracle) and `Launcher::Thread` (asserted in
//! tests/serving.rs). Wall time is only *recorded* (TPOT metrics),
//! never consulted.
//!
//! Admission control is two-level, all in projected bytes from
//! [`crate::memory::analytic::kv_projected_bytes`]:
//! * `submit` rejects a request that could never fit the KV budget even
//!   alone — a pure facade decision, no SPMD involvement, so running
//!   peers are untouched;
//! * the scheduler admits the queue head only while admitted
//!   projections fit the budget, so `KvCache::ensure` on the hot path
//!   can never OOM by construction.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::cluster::Cluster;
use crate::comm::CommStream;
use crate::config::{presets, ModelCfg, Strategy};
use crate::memory::analytic::kv_projected_bytes;
use crate::memory::{MemCategory, OomError};
use crate::model::ModelParams;
use crate::parallel::Launcher;
use crate::runtime::fault::{FaultInjector, FaultPhase, FaultPlan, RankFailure};
use crate::util::rng::Rng;

use super::decode::{DecodePlan, DecodeRank, PlanEntry};
use super::request::{Admission, FinishedRequest, GenRequest, ServeReport};

/// Builder-style serving options (the serving sibling of `EngineOpts`).
#[derive(Debug, Clone)]
pub struct ServeOpts {
    pub preset: String,
    pub strategy: Strategy,
    pub workers: usize,
    /// Concurrent decode slots (max running batch).
    pub max_batch: usize,
    /// Positions per KV page.
    pub page_tokens: usize,
    /// Per-device capacity in bytes (None = unlimited, analysis mode).
    pub capacity: Option<u64>,
    /// Seed for `ModelParams::init` when no params are supplied.
    pub seed: u64,
    pub launcher: Launcher,
    /// Deterministic fault injection (`FaultPhase::Decode` fires before
    /// the chosen rank's decode step). Defaults to `RTP_FAULT_PLAN`; a
    /// plan that never matches is a bit-identical no-op.
    pub fault_plan: Option<FaultPlan>,
}

impl ServeOpts {
    pub fn new(preset: &str) -> ServeOpts {
        ServeOpts {
            preset: preset.to_string(),
            strategy: Strategy::Single,
            workers: 1,
            max_batch: 4,
            page_tokens: 8,
            capacity: None,
            seed: 0,
            launcher: Launcher::from_env(),
            fault_plan: FaultPlan::from_env(),
        }
    }
    pub fn strategy(mut self, s: Strategy) -> Self {
        self.strategy = s;
        self
    }
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = n;
        self
    }
    pub fn max_batch(mut self, b: usize) -> Self {
        self.max_batch = b;
        self
    }
    pub fn page_tokens(mut self, p: usize) -> Self {
        self.page_tokens = p;
        self
    }
    pub fn capacity(mut self, c: Option<u64>) -> Self {
        self.capacity = c;
        self
    }
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
    pub fn launcher(mut self, l: Launcher) -> Self {
        self.launcher = l;
        self
    }
    pub fn fault_plan(mut self, p: Option<FaultPlan>) -> Self {
        self.fault_plan = p;
        self
    }

    pub fn cfg(&self) -> Result<ModelCfg> {
        presets::get(&self.preset)
            .ok_or_else(|| anyhow!("unknown preset {:?}", self.preset))
    }
}

struct RunningReq {
    req: GenRequest,
    slot: usize,
    /// Positions fed so far (== the position of the next token to feed).
    fed: usize,
    generated: Vec<i32>,
    joined_step: u64,
    token_ms: Vec<f64>,
    projected: u64,
}

pub struct ServeEngine {
    cfg: ModelCfg,
    strategy: Strategy,
    n: usize,
    launcher: Launcher,
    max_batch: usize,
    page_tokens: usize,

    cluster: Cluster,
    ranks: Vec<DecodeRank>,
    /// The serving weights, retained so [`ServeEngine::recover`] can
    /// rebuild the decode ranks after a rank death.
    params: ModelParams,
    capacity: Option<u64>,

    queue: VecDeque<GenRequest>,
    running: Vec<RunningReq>,
    finished: Vec<FinishedRequest>,
    rejected: Vec<(u64, String)>,

    /// Per-rank KV byte budget (capacity minus weights+scratch).
    kv_budget: u64,
    /// Projected KV bytes of everything admitted and not yet retired.
    kv_projected: u64,

    step_idx: u64,
    decode_steps: u64,
    wall_ms: f64,

    fault: Option<Arc<FaultInjector>>,
}

/// Build a serving engine with freshly initialized parameters
/// (`ModelParams::init` from `opts.seed`).
pub fn build_serve_engine(opts: &ServeOpts) -> Result<ServeEngine> {
    let cfg = opts.cfg()?;
    let params = ModelParams::init(&cfg, &mut Rng::new(opts.seed));
    build_serve_engine_with_params(opts, &params)
}

/// Build a serving engine around existing (e.g. checkpointed) params.
pub fn build_serve_engine_with_params(
    opts: &ServeOpts,
    params: &ModelParams,
) -> Result<ServeEngine> {
    let cfg = opts.cfg()?;
    let n = opts.workers;
    if opts.launcher == Launcher::Process {
        bail!(
            "serve does not support Launcher::Process: the decode engine \
             streams KV state through engine-owned memory (use lockstep or \
             thread)"
        );
    }
    if cfg.is_moe() {
        bail!("serve supports dense presets only (got MoE preset {:?})", cfg.name);
    }
    if opts.max_batch < 1 || opts.page_tokens < 1 {
        bail!("serve needs max_batch >= 1 and page_tokens >= 1");
    }
    match opts.strategy {
        Strategy::Single => {
            if n != 1 {
                bail!("strategy single serves on exactly 1 worker (got {n})");
            }
        }
        Strategy::MegatronTp | Strategy::RtpInplace | Strategy::RtpOutOfPlace => {
            if n < 1 {
                bail!("need at least one worker");
            }
            for (dim, name) in [
                (cfg.heads, "heads"),
                (cfg.hidden, "hidden"),
                (cfg.ffn, "ffn"),
                (cfg.vocab, "vocab"),
            ] {
                if dim % n != 0 {
                    bail!("{name} {dim} not divisible by {n} workers");
                }
            }
        }
        Strategy::Ddp | Strategy::Fsdp => {
            bail!(
                "{} is a training strategy; serve shards over heads \
                 (single / megatron-tp / rtp-inplace / rtp-outofplace)",
                opts.strategy
            )
        }
    }

    let rotate = matches!(opts.strategy, Strategy::RtpInplace | Strategy::RtpOutOfPlace);
    let async_rot =
        matches!(opts.strategy, Strategy::RtpOutOfPlace) && opts.launcher.overlaps_comm();

    let mut cluster = Cluster::new(n, opts.capacity);
    let fabric = cluster.fabric().clone();
    let mut ranks = Vec::with_capacity(n);
    for rank in 0..n {
        let stream = if rotate && n > 1 {
            Some(CommStream::new(fabric.bg_port(rank), async_rot))
        } else {
            None
        };
        let dr = DecodeRank::new(
            rank,
            n,
            &cfg,
            params,
            rotate,
            stream,
            opts.max_batch,
            opts.page_tokens,
            &mut cluster.workers[rank].tracker,
        )
        .map_err(anyhow::Error::new)?;
        ranks.push(dr);
    }

    let live = cluster.workers[0].tracker.live();
    let kv_budget = match opts.capacity {
        Some(cap) => cap.saturating_sub(live),
        None => u64::MAX,
    };

    Ok(ServeEngine {
        cfg,
        strategy: opts.strategy,
        n,
        launcher: opts.launcher,
        max_batch: opts.max_batch,
        page_tokens: opts.page_tokens,
        cluster,
        ranks,
        params: params.clone(),
        capacity: opts.capacity,
        queue: VecDeque::new(),
        running: Vec::new(),
        finished: Vec::new(),
        rejected: Vec::new(),
        kv_budget,
        kv_projected: 0,
        step_idx: 0,
        decode_steps: 0,
        wall_ms: 0.0,
        fault: opts.fault_plan.map(FaultInjector::new),
    })
}

impl ServeEngine {
    pub fn cfg(&self) -> &ModelCfg {
        &self.cfg
    }
    pub fn n(&self) -> usize {
        self.n
    }
    pub fn kv_budget(&self) -> u64 {
        self.kv_budget
    }
    pub fn cluster(&self) -> &Cluster {
        &self.cluster
    }
    pub fn step_idx(&self) -> u64 {
        self.step_idx
    }
    pub fn running_len(&self) -> usize {
        self.running.len()
    }
    pub fn queued_len(&self) -> usize {
        self.queue.len()
    }

    /// Projected per-rank KV bytes for `req` under this engine's
    /// strategy/page geometry.
    pub fn projected_bytes(&self, req: &GenRequest) -> u64 {
        kv_projected_bytes(
            self.strategy,
            &self.cfg,
            req.total_positions(),
            self.page_tokens,
            self.n as u64,
        )
    }

    /// Submit a request. Statically unservable requests are rejected
    /// here (facade-side — running peers never see them); everything
    /// else queues for the scheduler.
    pub fn submit(&mut self, req: GenRequest) -> Admission {
        if req.prompt.is_empty() || req.max_new == 0 {
            return self.reject(req, "empty prompt or zero max_new".into());
        }
        if let Some(&t) = req.prompt.iter().find(|&&t| t < 0 || t as usize >= self.cfg.vocab)
        {
            return self.reject(req, format!("prompt token {t} outside vocab"));
        }
        if req.total_positions() > self.cfg.seq {
            let why = format!(
                "needs {} positions, model seq is {}",
                req.total_positions(),
                self.cfg.seq
            );
            return self.reject(req, why);
        }
        let proj = self.projected_bytes(&req);
        if proj > self.kv_budget {
            return self.reject(
                req,
                format!("projected KV {proj} B exceeds budget {} B", self.kv_budget),
            );
        }
        self.queue.push_back(req);
        Admission::Queued
    }

    fn reject(&mut self, req: GenRequest, why: String) -> Admission {
        self.rejected.push((req.id, why.clone()));
        Admission::Rejected(why)
    }

    /// Admit queue-head requests while a slot and KV budget are free —
    /// the join half of continuous batching, always at a step boundary.
    fn admit(&mut self) {
        while self.running.len() < self.max_batch {
            let Some(front) = self.queue.front() else { break };
            let proj = self.projected_bytes(front);
            if self.kv_projected + proj > self.kv_budget {
                break; // FIFO head-of-line: deterministic, no starvation
            }
            let req = self.queue.pop_front().unwrap();
            let mut used: Vec<usize> = self.running.iter().map(|r| r.slot).collect();
            used.sort_unstable();
            let mut slot = 0;
            for u in used {
                if u == slot {
                    slot += 1;
                }
            }
            for rank in self.ranks.iter_mut() {
                rank.kv.occupy(slot);
            }
            self.kv_projected += proj;
            self.running.push(RunningReq {
                req,
                slot,
                fed: 0,
                generated: Vec::new(),
                joined_step: self.step_idx,
                token_ms: Vec::new(),
                projected: proj,
            });
            self.running.sort_by_key(|r| r.slot);
        }
    }

    /// One scheduler step: admit → batched decode round → consume
    /// tokens → retire finished requests. Returns false on an idle tick
    /// (nothing running or admittable).
    pub fn step(&mut self) -> Result<bool> {
        if let Some(f) = &self.fault {
            // the fault plan's `step` is the 0-based scheduler step
            f.begin_step(self.step_idx);
        }
        self.step_idx += 1;
        self.admit();
        if self.running.is_empty() {
            return Ok(false);
        }

        let plan = DecodePlan {
            entries: self
                .running
                .iter()
                .map(|r| PlanEntry {
                    slot: r.slot,
                    token: if r.fed < r.req.prompt.len() {
                        r.req.prompt[r.fed]
                    } else {
                        r.generated[r.fed - r.req.prompt.len()]
                    },
                    pos: r.fed,
                    need_logits: r.fed + 1 >= r.req.prompt.len(),
                })
                .collect(),
        };

        let fabric = self.cluster.fabric().clone();
        let fault = self.fault.clone();
        let t0 = Instant::now();
        let results: Vec<std::thread::Result<Result<Vec<i32>, OomError>>> = {
            let plan_ref = &plan;
            let tasks: Vec<Box<dyn FnOnce() -> Result<Vec<i32>, OomError> + Send + '_>> =
                self.ranks
                    .iter_mut()
                    .zip(self.cluster.workers.iter_mut())
                    .map(|(rank, worker)| {
                        let fab = fabric.clone();
                        let fault = fault.clone();
                        let port = worker.port.clone();
                        let tracker = &mut worker.tracker;
                        Box::new(move || {
                            if let Some(f) = &fault {
                                f.fault_point(rank.rank(), FaultPhase::Decode);
                            }
                            let out = rank.decode_step(tracker, &port, plan_ref);
                            if let Err(e) = &out {
                                // orderly abort: wake peers blocked on
                                // this rank so the round unwinds
                                fab.abort_round(&format!(
                                    "rank {} aborted decode: {e}",
                                    rank.rank()
                                ));
                            }
                            out
                        })
                            as Box<dyn FnOnce() -> Result<Vec<i32>, OomError> + Send + '_>
                    })
                    .collect();
            self.launcher.try_run(&fabric, tasks)
        };
        let dt_ms = t0.elapsed().as_secs_f64() * 1e3;

        // prefer a rank's orderly Err over the secondary poisoned-round
        // panics it caused in peers (same policy as ClusterEngine::step)
        let mut outs: Vec<Vec<i32>> = Vec::with_capacity(self.n);
        let mut first_err: Option<OomError> = None;
        let mut first_panic = None;
        for res in results {
            match res {
                Ok(Ok(tokens)) => outs.push(tokens),
                Ok(Err(e)) => {
                    first_err.get_or_insert(e);
                }
                Err(p) => {
                    first_panic.get_or_insert(p);
                }
            }
        }
        if let Some(e) = first_err {
            return Err(anyhow::Error::new(e));
        }
        if let Some(p) = first_panic {
            // a rank DIED (injected kill / stalled link): fail the whole
            // running batch with the typed root cause instead of
            // re-raising the poison panic, releasing every KV page so
            // nothing leaks
            if let Some(f) = fabric.rank_failure() {
                self.fail_batch(&f);
                return Err(anyhow::Error::new(f));
            }
            std::panic::resume_unwind(p);
        }
        debug_assert!(
            outs.iter().all(|o| *o == outs[0]),
            "ranks disagree on decoded tokens"
        );
        let tokens = outs.swap_remove(0);

        let mut ti = 0;
        for r in self.running.iter_mut() {
            let need = r.fed + 1 >= r.req.prompt.len();
            r.fed += 1;
            if need {
                r.generated.push(tokens[ti]);
                ti += 1;
                r.token_ms.push(dt_ms);
            }
        }
        debug_assert_eq!(ti, tokens.len());
        self.decode_steps += 1;
        self.wall_ms += dt_ms;

        // the leave half of continuous batching: retire at the boundary
        let mut still = Vec::with_capacity(self.running.len());
        for r in std::mem::take(&mut self.running) {
            if r.generated.len() >= r.req.max_new {
                for (rank, worker) in
                    self.ranks.iter_mut().zip(self.cluster.workers.iter_mut())
                {
                    rank.kv.release(r.slot, &mut worker.tracker);
                }
                self.kv_projected -= r.projected;
                self.finished.push(FinishedRequest {
                    id: r.req.id,
                    prompt_len: r.req.prompt.len(),
                    tokens: r.generated,
                    joined_step: r.joined_step,
                    finish_step: self.step_idx,
                    token_ms: r.token_ms,
                });
            } else {
                still.push(r);
            }
        }
        self.running = still;
        Ok(true)
    }

    /// Unwind the running batch after a rank death: release every slot's
    /// KV pages on every rank (allocations the dead rank made before
    /// dying included — `KvCache::release` frees whatever pages a slot
    /// holds) and REQUEUE each interrupted request at the queue front, in
    /// its original admission order, with its decode progress reset.
    /// After [`ServeEngine::recover`] the scheduler decodes them from
    /// scratch — deterministically, so the tokens match an unfaulted run.
    /// Queued requests are untouched; nothing is rejected (`_f` names the
    /// root cause only for the step's returned error).
    fn fail_batch(&mut self, _f: &RankFailure) {
        let mut interrupted = std::mem::take(&mut self.running);
        // admission order: join step, then slot (admit assigns ascending
        // free slots within one boundary)
        interrupted.sort_by_key(|r| (r.joined_step, r.slot));
        for r in interrupted.into_iter().rev() {
            for (rank, worker) in self.ranks.iter_mut().zip(self.cluster.workers.iter_mut())
            {
                rank.kv.release(r.slot, &mut worker.tracker);
            }
            self.kv_projected -= r.projected;
            self.queue.push_front(r.req);
        }
    }

    /// Rebuild the SPMD decode set after a rank death: fresh cluster
    /// (the old fabric is poisoned by the failed round), fresh
    /// [`DecodeRank`]s from the retained weights, empty KV. The request
    /// state machine — queue (including the batch
    /// [`fail_batch`](Self::fail_batch) requeued), finished, rejected,
    /// step counter — carries over, so a drain after recovery completes
    /// every admitted request. A fault plan that already fired does not
    /// re-arm.
    pub fn recover(&mut self) -> Result<()> {
        // return the poisoned incarnation's buffers (weights, scratch,
        // leftover KV) before rebuilding — trackers must balance
        for (rank, worker) in self.ranks.iter_mut().zip(self.cluster.workers.iter_mut()) {
            rank.free_all(&mut worker.tracker);
        }
        debug_assert_eq!(self.kv_projected, 0, "recover with live admissions");
        let rotate =
            matches!(self.strategy, Strategy::RtpInplace | Strategy::RtpOutOfPlace);
        let async_rot = matches!(self.strategy, Strategy::RtpOutOfPlace)
            && self.launcher.overlaps_comm();
        let mut cluster = Cluster::new(self.n, self.capacity);
        let fabric = cluster.fabric().clone();
        let mut ranks = Vec::with_capacity(self.n);
        for rank in 0..self.n {
            let stream = if rotate && self.n > 1 {
                Some(CommStream::new(fabric.bg_port(rank), async_rot))
            } else {
                None
            };
            let dr = DecodeRank::new(
                rank,
                self.n,
                &self.cfg,
                &self.params,
                rotate,
                stream,
                self.max_batch,
                self.page_tokens,
                &mut cluster.workers[rank].tracker,
            )
            .map_err(anyhow::Error::new)?;
            ranks.push(dr);
        }
        let live = cluster.workers[0].tracker.live();
        self.kv_budget = match self.capacity {
            Some(cap) => cap.saturating_sub(live),
            None => u64::MAX,
        };
        self.cluster = cluster;
        self.ranks = ranks;
        // one recovery disarms injection: the rebuilt engine must not
        // re-fire the plan that killed its predecessor
        self.fault = None;
        Ok(())
    }

    /// Run every queued/running request to completion.
    pub fn drain(&mut self) -> Result<()> {
        while !(self.queue.is_empty() && self.running.is_empty()) {
            self.step()?;
        }
        Ok(())
    }

    /// Replay a step-indexed arrival trace (as from
    /// [`super::request::poisson_trace`]) to completion.
    pub fn run_trace(&mut self, trace: &[(u64, GenRequest)]) -> Result<()> {
        let mut ti = 0;
        loop {
            while ti < trace.len() && trace[ti].0 <= self.step_idx {
                self.submit(trace[ti].1.clone());
                ti += 1;
            }
            if self.queue.is_empty() && self.running.is_empty() {
                if ti >= trace.len() {
                    break;
                }
                self.step_idx += 1; // idle tick toward the next arrival
                continue;
            }
            self.step()?;
        }
        Ok(())
    }

    /// Aggregate metrics so far (finished requests only).
    pub fn report(&self) -> ServeReport {
        ServeReport::from_finished(
            self.finished.clone(),
            self.rejected.clone(),
            self.step_idx,
            self.decode_steps,
            self.wall_ms,
            self.ranks[0].kv.pages_allocated(),
            self.cluster.workers[0].tracker.peak_of(MemCategory::KvCache),
        )
    }

    /// Free every tracked buffer (weights, scratch, leftover KV) — after
    /// this the trackers must show zero outstanding allocations.
    pub fn shutdown(&mut self) {
        self.queue.clear();
        self.running.clear();
        for (rank, worker) in self.ranks.iter_mut().zip(self.cluster.workers.iter_mut()) {
            rank.free_all(&mut worker.tracker);
        }
    }
}
