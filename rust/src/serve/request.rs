//! Request / trace / report types for the serving engine.
//!
//! Arrivals are indexed by *decode step*, never by wall clock: the batch
//! composition at every step is a pure function of the trace, which is
//! what makes the scheduler's token streams bit-identical under the
//! Lockstep and Thread launchers (wall time only feeds the latency
//! metrics, which are reported, not consumed).

use crate::config::ModelCfg;
use crate::util::rng::Rng;
use crate::util::stats::percentile_sorted;

/// One generation request: greedy-decode `max_new` tokens after
/// `prompt`.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
}

impl GenRequest {
    /// Positions this request will cache at its peak: the prompt plus
    /// every generated token except the last (which is emitted, never
    /// fed back). Admission control projects KV bytes from this.
    pub fn total_positions(&self) -> usize {
        self.prompt.len() + self.max_new - 1
    }
}

/// Verdict of [`crate::serve::ServeEngine::submit`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Admission {
    /// Accepted into the arrival queue (joins the batch when a slot and
    /// KV budget free up).
    Queued,
    /// Statically unservable — would exceed the KV budget even alone, or
    /// malformed. The rejection never involves the SPMD ranks, so peers
    /// in the running batch are unaffected.
    Rejected(String),
}

/// A completed request with its measured per-token latencies.
#[derive(Debug, Clone)]
pub struct FinishedRequest {
    pub id: u64,
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    /// Step index at which the request joined the running batch.
    pub joined_step: u64,
    /// Step index at which its last token was produced.
    pub finish_step: u64,
    /// Wall-clock ms of the decode step that produced each token
    /// (time-per-output-token samples).
    pub token_ms: Vec<f64>,
}

/// Aggregate serving metrics over one trace run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub finished: Vec<FinishedRequest>,
    pub rejected: Vec<(u64, String)>,
    /// Scheduler steps taken (including idle ticks waiting on arrivals).
    pub steps: u64,
    /// Steps that actually ran a decode round.
    pub decode_steps: u64,
    pub tokens: u64,
    pub wall_ms: f64,
    pub tokens_per_s: f64,
    pub tpot_p50_ms: f64,
    pub tpot_p99_ms: f64,
    /// KV pages tracker-allocated per generated token (deterministic:
    /// a property of the allocation schedule, not the host).
    pub kv_allocs_per_token: f64,
    /// Peak tracked KvCache bytes on rank 0 (ranks are symmetric).
    pub kv_peak_bytes_per_rank: u64,
}

impl ServeReport {
    /// Build the aggregate from per-request results. `kv_pages` is the
    /// monotonic page-allocation count, `kv_peak` the tracker's
    /// KvCache-category peak.
    pub fn from_finished(
        finished: Vec<FinishedRequest>,
        rejected: Vec<(u64, String)>,
        steps: u64,
        decode_steps: u64,
        wall_ms: f64,
        kv_pages: u64,
        kv_peak: u64,
    ) -> ServeReport {
        let tokens: u64 = finished.iter().map(|f| f.tokens.len() as u64).sum();
        let mut tpot: Vec<f64> =
            finished.iter().flat_map(|f| f.token_ms.iter().copied()).collect();
        tpot.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let (p50, p99) = if tpot.is_empty() {
            (0.0, 0.0)
        } else {
            (percentile_sorted(&tpot, 50.0), percentile_sorted(&tpot, 99.0))
        };
        ServeReport {
            finished,
            rejected,
            steps,
            decode_steps,
            tokens,
            wall_ms,
            tokens_per_s: if wall_ms > 0.0 { tokens as f64 / (wall_ms / 1e3) } else { 0.0 },
            tpot_p50_ms: p50,
            tpot_p99_ms: p99,
            kv_allocs_per_token: if tokens > 0 { kv_pages as f64 / tokens as f64 } else { 0.0 },
            kv_peak_bytes_per_rank: kv_peak,
        }
    }
}

/// A Poisson arrival trace: requests with exp(rate)-distributed
/// inter-arrival gaps measured in decode steps, uniform-random prompts.
/// Deterministic in `seed` (repo [`Rng`]), so the same trace replays
/// bit-identically under every launcher.
pub fn poisson_trace(
    cfg: &ModelCfg,
    n_req: usize,
    rate_per_step: f64,
    prompt_len: usize,
    max_new: usize,
    seed: u64,
) -> Vec<(u64, GenRequest)> {
    assert!(rate_per_step > 0.0);
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    (0..n_req)
        .map(|i| {
            let u = rng.uniform().max(1e-12);
            t += -u.ln() / rate_per_step;
            let prompt =
                (0..prompt_len).map(|_| rng.below(cfg.vocab) as i32).collect();
            (t.floor() as u64, GenRequest { id: i as u64, prompt, max_new })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn poisson_trace_is_deterministic_and_monotone() {
        let cfg = presets::get("tiny").unwrap();
        let a = poisson_trace(&cfg, 10, 0.5, 3, 4, 7);
        let b = poisson_trace(&cfg, 10, 0.5, 3, 4, 7);
        assert_eq!(a.len(), 10);
        for ((sa, ra), (sb, rb)) in a.iter().zip(&b) {
            assert_eq!(sa, sb);
            assert_eq!(ra.prompt, rb.prompt);
        }
        assert!(a.windows(2).all(|w| w[0].0 <= w[1].0));
        assert!(a.iter().all(|(_, r)| r.prompt.iter().all(|&t| (t as usize) < cfg.vocab)));
    }

    #[test]
    fn report_percentiles() {
        let f = FinishedRequest {
            id: 0,
            prompt_len: 1,
            tokens: vec![1, 2, 3, 4],
            joined_step: 0,
            finish_step: 3,
            token_ms: vec![1.0, 2.0, 3.0, 4.0],
        };
        let r = ServeReport::from_finished(vec![f], vec![], 4, 4, 10.0, 8, 128);
        assert_eq!(r.tokens, 4);
        assert_eq!(r.kv_allocs_per_token, 2.0);
        assert!(r.tpot_p50_ms >= 1.0 && r.tpot_p99_ms <= 4.0);
        assert!((r.tokens_per_s - 400.0).abs() < 1e-9);
    }
}
