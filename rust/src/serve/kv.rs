//! The per-rank KV-cache: the tensor that binds at serving time.
//!
//! One `KvCache` lives on each rank. It is head-sharded exactly like the
//! rank's attention weights — `lanes = hidden/N` f32 per cached position
//! (the rank's head group), full `hidden` when unsharded — and paged:
//! capacity grows in fixed blocks of `page_tokens` positions so a
//! sequence's footprint is `ceil(len/page_tokens)` pages per layer, with
//! K and V packed in the same page. Every page is allocated through the
//! rank's [`MemTracker`] under [`MemCategory::KvCache`], so admission
//! control and the Table-1-style accounting see serving memory the same
//! way they see training memory (the closed form is
//! [`crate::memory::analytic::kv_cache_bytes_per_rank`]; equality is
//! asserted in tests/serving.rs).
//!
//! Under RTP the cache *rotates with the weights*: a rank must attend
//! with the head group of the weight shard it currently holds, so on
//! each hop the page *contents* travel one rank clockwise while the
//! device allocations stay put — the slot/page structure is symmetric
//! across ranks, so this is the paper's in-place exchange: no tracker
//! traffic, no duplication. [`KvCache::export_data`] /
//! [`KvCache::import_data`] implement the two ends of the hop in a
//! deterministic slot→layer→page order.

use crate::memory::{AllocId, MemCategory, MemTracker, OomError};

/// One page: `page_tokens` K rows then `page_tokens` V rows, `lanes`
/// f32 each, in a single tracked buffer.
#[derive(Debug)]
pub struct KvPage {
    pub data: Vec<f32>,
    id: AllocId,
}

/// Pages of one occupied decode slot, `pages[layer][page]`.
#[derive(Debug)]
struct SlotKv {
    pages: Vec<Vec<KvPage>>,
    len: usize,
}

#[derive(Debug)]
pub struct KvCache {
    layers: usize,
    lanes: usize,
    page_tokens: usize,
    slots: Vec<Option<SlotKv>>,
    /// Monotonic count of pages ever allocated (the per-token KV
    /// allocation-churn metric of BENCH_serving.json).
    pages_allocated: u64,
}

impl KvCache {
    pub fn new(max_slots: usize, layers: usize, lanes: usize, page_tokens: usize) -> Self {
        assert!(page_tokens >= 1 && lanes >= 1 && layers >= 1);
        KvCache {
            layers,
            lanes,
            page_tokens,
            slots: (0..max_slots).map(|_| None).collect(),
            pages_allocated: 0,
        }
    }

    pub fn lanes(&self) -> usize {
        self.lanes
    }
    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }
    pub fn max_slots(&self) -> usize {
        self.slots.len()
    }
    pub fn pages_allocated(&self) -> u64 {
        self.pages_allocated
    }

    /// Tracked bytes of one page: K + V blocks of `page_tokens` rows.
    pub fn page_bytes(&self) -> u64 {
        (2 * self.page_tokens * self.lanes * 4) as u64
    }

    /// Claim a free slot for a joining request (pages arrive lazily via
    /// [`KvCache::ensure`] as the sequence grows).
    pub fn occupy(&mut self, slot: usize) {
        assert!(self.slots[slot].is_none(), "slot {slot} already occupied");
        self.slots[slot] = Some(SlotKv {
            pages: (0..self.layers).map(|_| Vec::new()).collect(),
            len: 0,
        });
    }

    /// Release a finished/evicted slot, freeing every page back to the
    /// tracker.
    pub fn release(&mut self, slot: usize, tracker: &mut MemTracker) {
        let sk = self.slots[slot].take().expect("release of empty slot");
        for layer in sk.pages {
            for page in layer {
                tracker.free(page.id);
            }
        }
    }

    /// Release every occupied slot (engine shutdown / accounting tests).
    pub fn release_all(&mut self, tracker: &mut MemTracker) {
        for slot in 0..self.slots.len() {
            if self.slots[slot].is_some() {
                self.release(slot, tracker);
            }
        }
    }

    /// Grow `slot` to hold `new_len` positions in every layer —
    /// page-granular, every new page tracker-allocated (layer-ascending
    /// order, so the accounting trace is deterministic).
    pub fn ensure(
        &mut self,
        slot: usize,
        new_len: usize,
        tracker: &mut MemTracker,
    ) -> Result<(), OomError> {
        let (pt, lanes) = (self.page_tokens, self.lanes);
        let bytes = self.page_bytes();
        let need = new_len.div_ceil(pt);
        let sk = self.slots[slot].as_mut().expect("ensure on empty slot");
        for layer in sk.pages.iter_mut() {
            while layer.len() < need {
                let id = tracker.alloc(MemCategory::KvCache, bytes)?;
                layer.push(KvPage { data: vec![0.0; 2 * pt * lanes], id });
                self.pages_allocated += 1;
            }
        }
        Ok(())
    }

    /// Write the cached K/V rows for `pos` of `slot`/`layer` (the
    /// post-bias k/v slices of the fused qkv row — exactly what the
    /// full-sequence forward would have computed for that position).
    pub fn append(&mut self, slot: usize, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        let (pt, lanes) = (self.page_tokens, self.lanes);
        debug_assert_eq!(k.len(), lanes);
        debug_assert_eq!(v.len(), lanes);
        let sk = self.slots[slot].as_mut().expect("append on empty slot");
        let page = &mut sk.pages[layer][pos / pt];
        let r = pos % pt;
        page.data[r * lanes..(r + 1) * lanes].copy_from_slice(k);
        let vbase = (pt + r) * lanes;
        page.data[vbase..vbase + lanes].copy_from_slice(v);
    }

    /// Mark one more position cached (call once per slot per decode step,
    /// after every layer appended).
    pub fn advance(&mut self, slot: usize) {
        self.slots[slot].as_mut().expect("advance on empty slot").len += 1;
    }

    pub fn slot_len(&self, slot: usize) -> usize {
        self.slots[slot].as_ref().map_or(0, |s| s.len)
    }

    pub fn is_occupied(&self, slot: usize) -> bool {
        self.slots[slot].is_some()
    }

    /// Page `pg` of `slot`/`layer`; `KvPage::data[..pt*lanes]` are the K
    /// rows, the rest the V rows.
    pub fn page(&self, slot: usize, layer: usize, pg: usize) -> &KvPage {
        &self.slots[slot].as_ref().expect("page of empty slot").pages[layer][pg]
    }

    /// Take every occupied page's contents (slot→layer→page order) for a
    /// rotation hop. Allocations stay: only data travels.
    pub fn export_data(&mut self) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        for slot in self.slots.iter_mut().flatten() {
            for layer in slot.pages.iter_mut() {
                for page in layer.iter_mut() {
                    out.push(std::mem::take(&mut page.data));
                }
            }
        }
        out
    }

    /// Install page contents received from the counter-clockwise
    /// neighbor — same traversal order as [`KvCache::export_data`]; the
    /// slot/page structure is identical on every rank (the scheduler is
    /// SPMD), so the shapes line up by construction.
    pub fn import_data(&mut self, data: Vec<Vec<f32>>) {
        let mut it = data.into_iter();
        for slot in self.slots.iter_mut().flatten() {
            for layer in slot.pages.iter_mut() {
                for page in layer.iter_mut() {
                    let d = it.next().expect("rotation payload has too few pages");
                    debug_assert_eq!(d.len(), page.data.len());
                    page.data = d;
                }
            }
        }
        assert!(it.next().is_none(), "rotation payload has extra pages");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pages_are_tracked_and_freed() {
        let mut t = MemTracker::new(0, None);
        let mut kv = KvCache::new(2, 3, 8, 4);
        kv.occupy(0);
        kv.ensure(0, 1, &mut t).unwrap(); // 1 page x 3 layers
        assert_eq!(t.live_of(MemCategory::KvCache), 3 * kv.page_bytes());
        kv.ensure(0, 4, &mut t).unwrap(); // still 1 page
        assert_eq!(kv.pages_allocated(), 3);
        kv.ensure(0, 5, &mut t).unwrap(); // second page per layer
        assert_eq!(t.live_of(MemCategory::KvCache), 6 * kv.page_bytes());
        kv.release(0, &mut t);
        assert_eq!(t.live_of(MemCategory::KvCache), 0);
        assert_eq!(t.outstanding(), 0);
    }

    #[test]
    fn append_lands_in_k_and_v_blocks() {
        let mut t = MemTracker::new(0, None);
        let mut kv = KvCache::new(1, 1, 2, 2);
        kv.occupy(0);
        kv.ensure(0, 3, &mut t).unwrap();
        kv.append(0, 0, 0, &[1.0, 2.0], &[3.0, 4.0]);
        kv.append(0, 0, 2, &[5.0, 6.0], &[7.0, 8.0]); // second page, row 0
        let p0 = kv.page(0, 0, 0);
        assert_eq!(&p0.data[..2], &[1.0, 2.0]);
        assert_eq!(&p0.data[4..6], &[3.0, 4.0]);
        let p1 = kv.page(0, 0, 1);
        assert_eq!(&p1.data[..2], &[5.0, 6.0]);
        assert_eq!(&p1.data[4..6], &[7.0, 8.0]);
        kv.release(0, &mut t);
    }

    #[test]
    fn export_import_round_trips() {
        let mut t = MemTracker::new(0, None);
        let mut kv = KvCache::new(2, 2, 2, 2);
        kv.occupy(1);
        kv.ensure(1, 2, &mut t).unwrap();
        kv.append(1, 0, 0, &[1.0, 1.0], &[2.0, 2.0]);
        let data = kv.export_data();
        assert_eq!(data.len(), 2); // one page per layer
        kv.import_data(data);
        assert_eq!(&kv.page(1, 0, 0).data[..2], &[1.0, 1.0]);
        kv.release(1, &mut t);
        assert_eq!(t.outstanding(), 0);
    }
}
