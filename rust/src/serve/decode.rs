//! The per-rank incremental decode engine.
//!
//! One `DecodeRank` is the serving analogue of a training `RankEngine`:
//! it holds this rank's weight shards (tracked under
//! `MemCategory::Weights`), its [`KvCache`] shard, and persistent
//! scratch (tracked once under `MemCategory::Activations`), and executes
//! one batched decode step per scheduler round. The batch is replicated
//! across ranks; weights are head/column-sharded; per layer the partial
//! attention/MLP outputs meet in an `allreduce_sum`, and the final
//! vocab-sharded logits meet in an `allgather` before a replicated
//! argmax — so every rank computes the same token ids (the facade takes
//! rank 0's, debug-asserting agreement).
//!
//! Under the RTP strategies the weight shards AND the KV page contents
//! hop one rank clockwise per step, exactly like training-time rotation:
//! the out-of-place variant ships the payload on the background lane
//! namespace through a [`CommStream`] begun right after the shard's last
//! use (the LM-head matmul) and joined after the argmax, overlapping the
//! hop with the logits allgather when the launcher runs ranks
//! concurrently; in-place / Lockstep degrades to the deterministic
//! boundary exchange. Either way the device allocations never move —
//! the page/shard structure is rank-symmetric, so rotation is
//! tracker-silent (the paper's memory-deduplication point, now at
//! serving time).
//!
//! Numerics: every kernel call below is one of the decode helpers in
//! [`crate::model::oracle`], which replay the full-sequence kernels'
//! float accumulation order bit-exactly — the basis for the
//! decode-vs-full-forward argmax-stream equality asserted in
//! tests/serving.rs and examples/generate.rs.

use crate::comm::{allgather_into, allreduce_sum, CommStream, RingPort, RotationDir};
use crate::config::ModelCfg;
use crate::memory::{AllocId, MemCategory, MemTracker, OomError};
use crate::model::oracle;
use crate::model::partition::{attn_shard, mlp_shard, shard_cols, AttnShard, MlpShard};
use crate::model::{MlpParams, ModelParams};
use crate::tensor::HostTensor;

use super::kv::KvCache;

/// One batch row of a decode step: feed `token` at position `pos` of
/// the sequence in `slot`; emit an output token when `need_logits`
/// (false while a joining request is still streaming prompt tokens in).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlanEntry {
    pub slot: usize,
    pub token: i32,
    pub pos: usize,
    pub need_logits: bool,
}

/// The scheduler's per-step batch plan, entries sorted by slot. Shared
/// verbatim by every rank — batch composition is part of the SPMD
/// program, which is what makes the token streams launcher-invariant.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DecodePlan {
    pub entries: Vec<PlanEntry>,
}

/// Replicated (unsharded) per-layer parameters.
struct RepLayer {
    ln1_g: HostTensor,
    ln1_b: HostTensor,
    bo: HostTensor,
    ln2_g: HostTensor,
    ln2_b: HostTensor,
    b2: HostTensor,
}

/// The sharded pair that travels on rotation.
struct LayerShards {
    attn: AttnShard,
    mlp: MlpShard,
}

/// Everything that hops one rank clockwise on an RTP rotation: the
/// weight shards plus the KV page contents that belong to their head
/// group. Buffers stay home; only values travel.
struct RotPayload {
    shards: Vec<LayerShards>,
    wte_s: HostTensor,
    wpe_s: HostTensor,
    wlm_s: HostTensor,
    kv: Vec<Vec<f32>>,
}

fn take_tensor(t: &mut HostTensor) -> HostTensor {
    std::mem::replace(t, HostTensor::zeros(&[1]))
}

pub struct DecodeRank {
    rank: usize,
    n: usize,
    cfg: ModelCfg,
    rotate: bool,
    /// Rotation transport on the background lane namespace (None when
    /// not rotating). Async only when the launcher really overlaps.
    stream: Option<CommStream>,
    /// Completed clockwise hops; the shard currently held is
    /// `(rank + n - rot) % n`, shard `s` lives on rank `(s + rot) % n`.
    rot: usize,

    rep: Vec<RepLayer>,
    shards: Vec<LayerShards>,
    wte_s: HostTensor,
    wpe_s: HostTensor,
    wlm_s: HostTensor,
    lnf_g: HostTensor,
    lnf_b: HostTensor,

    pub kv: KvCache,
    weights_id: Option<AllocId>,
    scratch_id: Option<AllocId>,

    // persistent scratch — steady-state zero-alloc decode loop
    xloc: Vec<f32>,
    x: Vec<f32>,
    a: Vec<f32>,
    qkv: Vec<f32>,
    attn_o: Vec<f32>,
    part: Vec<f32>,
    mid: Vec<f32>,
    sub: Vec<f32>,
    logits_loc: Vec<f32>,
    gather: Vec<f32>,
    scores: Vec<f32>,
    logit_rows: Vec<usize>,
}

impl DecodeRank {
    /// Build rank `rank`'s shard set from the replicated `params`
    /// (serving-side Flyweight: every rank slices the same master copy;
    /// only the shards are tracked as device weights).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        rank: usize,
        n: usize,
        cfg: &ModelCfg,
        params: &ModelParams,
        rotate: bool,
        stream: Option<CommStream>,
        max_slots: usize,
        page_tokens: usize,
        tracker: &mut MemTracker,
    ) -> Result<DecodeRank, OomError> {
        assert!(n >= 1 && rank < n);
        let (h, f, v) = (cfg.hidden, cfg.ffn, cfg.vocab);
        let (heads, hd) = (cfg.heads, cfg.head_dim());
        let shard_id = rank; // rot = 0
        let mut rep = Vec::with_capacity(cfg.layers);
        let mut shards = Vec::with_capacity(cfg.layers);
        for lp in &params.layers {
            let (w1, b1, w2, b2) = match &lp.mlp {
                MlpParams::Dense { w1, b1, w2, b2 } => (w1, b1, w2, b2),
                MlpParams::Moe { .. } => {
                    panic!("serve: MoE layers are not supported (dense presets only)")
                }
            };
            rep.push(RepLayer {
                ln1_g: lp.ln1_g.clone(),
                ln1_b: lp.ln1_b.clone(),
                bo: lp.bo.clone(),
                ln2_g: lp.ln2_g.clone(),
                ln2_b: lp.ln2_b.clone(),
                b2: b2.clone(),
            });
            shards.push(LayerShards {
                attn: attn_shard(&lp.wqkv, &lp.bqkv, &lp.wo, shard_id, n, heads, hd),
                mlp: mlp_shard(w1, b1, w2, shard_id, n),
            });
        }
        let wte_s = shard_cols(&params.wte, shard_id, n);
        let wpe_s = shard_cols(&params.wpe, shard_id, n);
        let wlm_s = shard_cols(&params.wlm, shard_id, n);
        let lnf_g = params.lnf_g.clone();
        let lnf_b = params.lnf_b.clone();

        let mut weight_bytes: u64 = wte_s.bytes() + wpe_s.bytes() + wlm_s.bytes()
            + lnf_g.bytes() + lnf_b.bytes();
        for (r, s) in rep.iter().zip(&shards) {
            weight_bytes += r.ln1_g.bytes() + r.ln1_b.bytes() + r.bo.bytes()
                + r.ln2_g.bytes() + r.ln2_b.bytes() + r.b2.bytes();
            weight_bytes += s.attn.wqkv.bytes() + s.attn.bqkv.bytes() + s.attn.wo.bytes();
            weight_bytes += s.mlp.w1.bytes() + s.mlp.b1.bytes() + s.mlp.w2.bytes();
        }
        let weights_id = Some(tracker.alloc(MemCategory::Weights, weight_bytes)?);

        let (hp, fp, vp) = (h / n, f / n, v / n);
        let b = max_slots;
        let scratch_elems = b * hp           // xloc
            + 2 * b * h                      // x, a
            + b * 3 * hp                     // qkv
            + b * hp                         // attn_o
            + b * h                          // part
            + b * fp                         // mid
            + b * h                          // sub
            + b * vp                         // logits_loc
            + n * b * hp.max(vp)             // gather
            + cfg.seq;                       // scores
        let scratch_id = match tracker.alloc(MemCategory::Activations, (scratch_elems * 4) as u64) {
            Ok(id) => Some(id),
            Err(e) => {
                tracker.free(weights_id.unwrap());
                return Err(e);
            }
        };

        let lanes = h / n;
        Ok(DecodeRank {
            rank,
            n,
            cfg: cfg.clone(),
            rotate: rotate && n > 1,
            stream,
            rot: 0,
            rep,
            shards,
            wte_s,
            wpe_s,
            wlm_s,
            lnf_g,
            lnf_b,
            kv: KvCache::new(max_slots, cfg.layers, lanes, page_tokens),
            weights_id,
            scratch_id,
            xloc: Vec::with_capacity(b * hp),
            x: Vec::with_capacity(b * h),
            a: Vec::with_capacity(b * h),
            qkv: Vec::with_capacity(b * 3 * hp),
            attn_o: Vec::with_capacity(b * hp),
            part: Vec::with_capacity(b * h),
            mid: Vec::with_capacity(b * fp),
            sub: Vec::with_capacity(b * h),
            logits_loc: Vec::with_capacity(b * vp),
            gather: Vec::with_capacity(n * b * hp.max(vp)),
            scores: vec![0.0; cfg.seq],
            logit_rows: Vec::with_capacity(b),
        })
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    /// The shard id this rank currently holds.
    pub fn current_shard(&self) -> usize {
        (self.rank + self.n - self.rot % self.n) % self.n
    }

    fn install(&mut self, p: RotPayload) {
        self.shards = p.shards;
        self.wte_s = p.wte_s;
        self.wpe_s = p.wpe_s;
        self.wlm_s = p.wlm_s;
        self.kv.import_data(p.kv);
    }

    /// Free every tracked buffer this rank holds (engine shutdown; the
    /// accounting tests assert `tracker.outstanding() == 0` after).
    pub fn free_all(&mut self, tracker: &mut MemTracker) {
        self.kv.release_all(tracker);
        if let Some(id) = self.weights_id.take() {
            tracker.free(id);
        }
        if let Some(id) = self.scratch_id.take() {
            tracker.free(id);
        }
    }

    /// Execute one batched decode step: feed every plan entry's token at
    /// its position, return the argmax token per `need_logits` entry (in
    /// plan order). Identical on every rank.
    pub fn decode_step(
        &mut self,
        tracker: &mut MemTracker,
        port: &RingPort,
        plan: &DecodePlan,
    ) -> Result<Vec<i32>, OomError> {
        let n = self.n;
        let (h, f, v) = (self.cfg.hidden, self.cfg.ffn, self.cfg.vocab);
        let (hp, fp, vp) = (h / n, f / n, v / n);
        let nh_p = self.cfg.heads / n;
        let hd = self.cfg.head_dim();
        let scale = 1.0 / (hd as f32).sqrt();
        let (pt, lanes) = (self.kv.page_tokens(), self.kv.lanes());
        let b = plan.entries.len();
        assert!(b >= 1, "decode_step needs a non-empty plan");

        // grow KV capacity first (admission control has already bounded
        // this, so an OomError here means a scheduler bug — it still
        // unwinds orderly through the engine)
        for e in &plan.entries {
            self.kv.ensure(e.slot, e.pos + 1, tracker)?;
        }

        // -- embedding: this rank's hidden-column shard, gathered -------
        let ids: Vec<i32> = plan.entries.iter().map(|e| e.token).collect();
        let positions: Vec<usize> = plan.entries.iter().map(|e| e.pos).collect();
        if n == 1 {
            oracle::emb_decode_rows(&ids, &positions, &self.wte_s, &self.wpe_s, &mut self.x);
        } else {
            oracle::emb_decode_rows(&ids, &positions, &self.wte_s, &self.wpe_s, &mut self.xloc);
            allgather_into(port, &self.xloc, &mut self.gather);
            self.x.clear();
            self.x.resize(b * h, 0.0);
            for s in 0..n {
                let src = (s + self.rot) % n;
                for bi in 0..b {
                    let from = &self.gather[(src * b + bi) * hp..(src * b + bi + 1) * hp];
                    self.x[bi * h + s * hp..bi * h + (s + 1) * hp].copy_from_slice(from);
                }
            }
        }

        // -- transformer layers ----------------------------------------
        for li in 0..self.cfg.layers {
            // attention
            oracle::ln_rows_into(&self.x, &self.rep[li].ln1_g, &self.rep[li].ln1_b, &mut self.a);
            oracle::mm_into(&self.a, b, h, &self.shards[li].attn.wqkv.data, 3 * hp, &mut self.qkv);
            oracle::add_bias_rows(&mut self.qkv, &self.shards[li].attn.bqkv.data);
            self.attn_o.clear();
            self.attn_o.resize(b * hp, 0.0);
            for (bi, e) in plan.entries.iter().enumerate() {
                let len = e.pos + 1;
                let npg = len.div_ceil(pt);
                let row = &self.qkv[bi * 3 * hp..(bi + 1) * 3 * hp];
                self.kv.append(e.slot, li, e.pos, &row[hp..2 * hp], &row[2 * hp..3 * hp]);
                for head in 0..nh_p {
                    let q_head = &row[head * hd..(head + 1) * hd];
                    let mut max = f32::MIN;
                    for pg in 0..npg {
                        let rows = pt.min(len - pg * pt);
                        let page = self.kv.page(e.slot, li, pg);
                        max = oracle::attn_decode_scores(
                            q_head,
                            &page.data,
                            rows,
                            lanes,
                            head * hd,
                            scale,
                            max,
                            &mut self.scores[pg * pt..pg * pt + rows],
                        );
                    }
                    oracle::softmax_decode(&mut self.scores[..len], max);
                    let out_head =
                        &mut self.attn_o[bi * hp + head * hd..bi * hp + (head + 1) * hd];
                    for pg in 0..npg {
                        let rows = pt.min(len - pg * pt);
                        let page = self.kv.page(e.slot, li, pg);
                        oracle::attn_decode_weighted_sum(
                            &self.scores[pg * pt..pg * pt + rows],
                            &page.data[pt * lanes..],
                            lanes,
                            head * hd,
                            out_head,
                        );
                    }
                }
            }
            oracle::mm_into(&self.attn_o, b, hp, &self.shards[li].attn.wo.data, h, &mut self.part);
            if n > 1 {
                allreduce_sum(port, &mut self.part);
            }
            oracle::add_bias_rows(&mut self.part, &self.rep[li].bo.data);
            for (xv, pv) in self.x.iter_mut().zip(self.part.iter()) {
                *xv += *pv;
            }

            // MLP
            oracle::ln_rows_into(&self.x, &self.rep[li].ln2_g, &self.rep[li].ln2_b, &mut self.a);
            oracle::mm_into(&self.a, b, h, &self.shards[li].mlp.w1.data, fp, &mut self.mid);
            oracle::bias_gelu_rows(&mut self.mid, &self.shards[li].mlp.b1.data);
            oracle::mm_into(&self.mid, b, fp, &self.shards[li].mlp.w2.data, h, &mut self.part);
            if n > 1 {
                allreduce_sum(port, &mut self.part);
            }
            oracle::add_bias_rows(&mut self.part, &self.rep[li].b2.data);
            for (xv, pv) in self.x.iter_mut().zip(self.part.iter()) {
                *xv += *pv;
            }
        }

        // -- final LN + LM head over rows that need a token -------------
        oracle::ln_rows_into(&self.x, &self.lnf_g, &self.lnf_b, &mut self.a);
        self.logit_rows.clear();
        for (bi, e) in plan.entries.iter().enumerate() {
            if e.need_logits {
                self.logit_rows.push(bi);
            }
        }
        let bl = self.logit_rows.len();
        if bl > 0 {
            self.sub.clear();
            for &bi in &self.logit_rows {
                self.sub.extend_from_slice(&self.a[bi * h..(bi + 1) * h]);
            }
            oracle::mm_into(&self.sub, bl, h, &self.wlm_s.data, vp, &mut self.logits_loc);
        }

        // weights had their last use in the LM-head matmul: begin the
        // rotation hop now so (in async mode) it rides under the logits
        // allgather + argmax
        let inflight = if self.rotate {
            let payload = RotPayload {
                shards: std::mem::take(&mut self.shards),
                wte_s: take_tensor(&mut self.wte_s),
                wpe_s: take_tensor(&mut self.wpe_s),
                wlm_s: take_tensor(&mut self.wlm_s),
                kv: self.kv.export_data(),
            };
            let stream = self.stream.as_ref().expect("rotating rank without a stream");
            Some(stream.begin(payload, RotationDir::Clockwise))
        } else {
            None
        };

        let mut out = Vec::with_capacity(bl);
        if bl > 0 {
            if n > 1 {
                allgather_into(port, &self.logits_loc, &mut self.gather);
                for ri in 0..bl {
                    let mut best = f32::MIN;
                    let mut arg = 0usize;
                    for s in 0..n {
                        let src = (s + self.rot) % n;
                        let base = (src * bl + ri) * vp;
                        for j in 0..vp {
                            let val = self.gather[base + j];
                            if val >= best {
                                best = val;
                                arg = s * vp + j;
                            }
                        }
                    }
                    out.push(arg as i32);
                }
            } else {
                for ri in 0..bl {
                    let rowv = &self.logits_loc[ri * vp..(ri + 1) * vp];
                    let mut best = f32::MIN;
                    let mut arg = 0usize;
                    for (j, &val) in rowv.iter().enumerate() {
                        if val >= best {
                            best = val;
                            arg = j;
                        }
                    }
                    out.push(arg as i32);
                }
            }
        }

        for e in &plan.entries {
            self.kv.advance(e.slot);
        }

        if let Some(inf) = inflight {
            let stream = self.stream.as_ref().expect("rotating rank without a stream");
            let p = stream.wait(inf);
            self.install(p);
            self.rot = (self.rot + 1) % n;
        }

        Ok(out)
    }
}
