//! Continuous-batching generation engine over the RTP SPMD stack.
//!
//! The paper's memory-deduplication story, applied at inference: RTP's
//! sharded weights leave device memory for the tensor that actually
//! binds serving — the KV-cache. This module serves generation requests
//! on the same simulated cluster the training engines run on:
//!
//! * [`request`] — request/trace/report types; arrivals are indexed by
//!   decode step so scheduling is deterministic per trace.
//! * [`kv`] — the paged, head-sharded, `MemTracker`-accounted per-rank
//!   KV-cache ([`MemCategory::KvCache`]); under RTP its page contents
//!   rotate with the weight shards.
//! * [`decode`] — the per-rank incremental decode step (attend over
//!   cached K/V, append one position), built from the bit-parity decode
//!   kernels in [`crate::model::oracle`].
//! * [`engine`] — the facade: admission control against the KV budget,
//!   the continuous-batching scheduler (join/leave at token
//!   boundaries), and the launcher-driven decode rounds.
//!
//! Determinism contract: the emitted token streams are bit-identical
//! under `Launcher::Lockstep` and `Launcher::Thread`, and — via the
//! kernel parity contract — an incrementally decoded stream equals the
//! full-forward argmax stream position for position.
//!
//! [`MemCategory::KvCache`]: crate::memory::MemCategory

pub mod decode;
pub mod engine;
pub mod kv;
pub mod request;

pub use decode::{DecodePlan, DecodeRank, PlanEntry};
pub use engine::{build_serve_engine, build_serve_engine_with_params, ServeEngine, ServeOpts};
pub use kv::KvCache;
pub use request::{poisson_trace, Admission, FinishedRequest, GenRequest, ServeReport};
