//! Checkpointing: a simple self-describing binary format for model
//! parameters (`RTPC` magic + named f32 tensors). Any engine can
//! checkpoint via `gather_params()`; loading reconstructs a full
//! `ModelParams` that seeds a fresh engine or the `generate` example.
//!
//! Format (little-endian):
//!   magic "RTPC1\0"  | u32 tensor count
//!   per tensor: u32 name_len | name bytes | u32 ndim | u64 dims... |
//!               f32 data...

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::ModelCfg;
use crate::model::ModelParams;
use crate::tensor::HostTensor;

const MAGIC: &[u8; 6] = b"RTPC1\0";

pub fn save_params(params: &ModelParams, path: &Path) -> Result<()> {
    let mut entries: Vec<(String, Vec<usize>, Vec<f32>)> = Vec::new();
    params.visit(&mut |name, t| {
        entries.push((name.to_string(), t.shape.clone(), t.data.clone()));
    });
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?,
    );
    f.write_all(MAGIC)?;
    f.write_all(&(entries.len() as u32).to_le_bytes())?;
    for (name, shape, data) in entries {
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name.as_bytes())?;
        f.write_all(&(shape.len() as u32).to_le_bytes())?;
        for d in &shape {
            f.write_all(&(*d as u64).to_le_bytes())?;
        }
        // SAFETY: f32 slice reinterpreted as bytes for the write
        let bytes = unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
        };
        f.write_all(bytes)?;
    }
    Ok(())
}

pub fn load_params(cfg: &ModelCfg, path: &Path) -> Result<ModelParams> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
    );
    let mut magic = [0u8; 6];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{}: not an RTP checkpoint", path.display());
    }
    let mut u32buf = [0u8; 4];
    let mut u64buf = [0u8; 8];
    f.read_exact(&mut u32buf)?;
    let count = u32::from_le_bytes(u32buf) as usize;
    let mut tensors: std::collections::BTreeMap<String, HostTensor> = Default::default();
    for _ in 0..count {
        f.read_exact(&mut u32buf)?;
        let mut name = vec![0u8; u32::from_le_bytes(u32buf) as usize];
        f.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("tensor name not utf8")?;
        f.read_exact(&mut u32buf)?;
        let ndim = u32::from_le_bytes(u32buf) as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            f.read_exact(&mut u64buf)?;
            shape.push(u64::from_le_bytes(u64buf) as usize);
        }
        let numel: usize = shape.iter().product();
        let mut data = vec![0f32; numel];
        // SAFETY: fill the f32 buffer through its byte view
        let bytes = unsafe {
            std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, numel * 4)
        };
        f.read_exact(bytes)?;
        tensors.insert(name, HostTensor::from_vec(&shape, data));
    }
    // pour into a cfg-shaped ModelParams, validating coverage and shapes
    let mut out = ModelParams::zeros_like(cfg);
    let mut missing = Vec::new();
    out.visit_mut(&mut |name, t| match tensors.remove(name) {
        Some(loaded) if loaded.shape == t.shape => *t = loaded,
        Some(loaded) => missing.push(format!(
            "{name}: shape {:?} != expected {:?}",
            loaded.shape, t.shape
        )),
        None => missing.push(format!("{name}: absent")),
    });
    if !missing.is_empty() {
        bail!("checkpoint does not match config: {}", missing.join("; "));
    }
    if !tensors.is_empty() {
        bail!(
            "checkpoint has {} extra tensors (e.g. {:?})",
            tensors.len(),
            tensors.keys().next()
        );
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("rtp-ckpt-{name}-{}", std::process::id()))
    }

    #[test]
    fn roundtrip_exact() {
        let cfg = presets::get("tiny").unwrap();
        let p = ModelParams::init(&cfg, &mut Rng::new(3));
        let path = tmp("roundtrip");
        save_params(&p, &path).unwrap();
        let q = load_params(&cfg, &path).unwrap();
        assert_eq!(p.max_abs_diff(&q), 0.0);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn moe_roundtrip() {
        let cfg = presets::get("tiny-moe").unwrap();
        let p = ModelParams::init(&cfg, &mut Rng::new(4));
        let path = tmp("moe");
        save_params(&p, &path).unwrap();
        let q = load_params(&cfg, &path).unwrap();
        assert_eq!(p.max_abs_diff(&q), 0.0);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn wrong_config_rejected() {
        let cfg = presets::get("tiny").unwrap();
        let p = ModelParams::init(&cfg, &mut Rng::new(5));
        let path = tmp("wrongcfg");
        save_params(&p, &path).unwrap();
        let other = presets::get("tiny-moe").unwrap();
        assert!(load_params(&other, &path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn garbage_rejected() {
        let path = tmp("garbage");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        let cfg = presets::get("tiny").unwrap();
        assert!(load_params(&cfg, &path).is_err());
        std::fs::remove_file(path).ok();
    }
}
